//! Quickstart: the co-design GEMM API in five minutes.
//!
//! Shows what the paper proposes, concretely: for a skinny-k GEMM (the
//! shape every blocked factorization generates) the engine consults the
//! refined analytical model per call, picks CCPs *and* a micro-kernel for
//! this architecture + shape, and beats the static BLIS-style baseline.
//!
//! Run: `cargo run --release --example quickstart`

use dla_codesign::arch::detect_host;
use dla_codesign::gemm::{ConfigMode, GemmEngine};
use dla_codesign::model::{GemmDims, MicroKernel};
use dla_codesign::util::timer::measure;
use dla_codesign::util::{MatrixF64, Pcg64};

fn main() {
    let arch = detect_host();
    println!("host: {} | peak {:.1} GFLOPS/core\n", arch.name, arch.peak_gflops_core());

    // The paper's shape of interest: m = n large, k small (trailing
    // update of a blocked factorization with block size b = k).
    let (m, n, k) = (1200, 1200, 96);
    let dims = GemmDims::new(m, n, k);
    let mut rng = Pcg64::seed(7);
    let a = MatrixF64::random(m, k, &mut rng);
    let b = MatrixF64::random(k, n, &mut rng);

    println!("GEMM {m}x{n}x{k} (the skinny-k trailing-update shape)\n");
    for (label, mode) in [
        ("BLIS-static baseline", ConfigMode::BlisStatic),
        ("original analytical model", ConfigMode::OriginalModel),
        ("refined model, MK pinned 8x6", ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6))),
        ("refined + dynamic micro-kernel", ConfigMode::Refined),
    ] {
        let mut engine = GemmEngine::new(arch.clone(), mode);
        let cfg = engine.plan_config(dims);
        let mut c = MatrixF64::zeros(m, n);
        let meas = measure(3, 0.3, || {
            engine.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        });
        println!(
            "  {label:<32} {} -> {:>7.2} GFLOPS",
            cfg,
            meas.gflops(dims.flops())
        );
    }

    println!("\nThe refined configurations enlarge mc to fill the L2 once k is");
    println!("known (paper §3.3), and the dynamic mode additionally selects the");
    println!("micro-kernel shape per call (paper §3.4).");
}
