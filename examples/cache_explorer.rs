//! Cache explorer: replay a blocked-GEMM access trace through the
//! simulated cache hierarchy of either paper platform and inspect what
//! the paper could only infer from PMU counters.
//!
//! Run: `cargo run --release --example cache_explorer -- --arch carmel --k 96`
//! Options: --arch carmel|epyc7282|host   --mn 1000   --k 96   --mk 6x8

use dla_codesign::arch::preset_by_name;
use dla_codesign::harness::{cfg_blis, cfg_mod};
use dla_codesign::model::{GemmDims, MicroKernel};
use dla_codesign::trace::{simulate_gemm, TraceOptions};
use dla_codesign::util::cli::Args;
use dla_codesign::util::table::Table;

fn main() {
    let args = Args::from_env();
    let arch_name = args.get_str("arch", "carmel");
    let arch = preset_by_name(arch_name).unwrap_or_else(|| panic!("unknown arch {arch_name}"));
    let mn = args.get_usize("mn", 1000);
    let k = args.get_usize("k", 96);
    let mk_str = args.get_str("mk", "6x8");
    let (mr, nr) = mk_str.split_once('x').expect("--mk like 6x8");
    let mk = MicroKernel::new(mr.parse().unwrap(), nr.parse().unwrap());

    let dims = GemmDims::new(mn, mn, k);
    println!("arch: {}\nGEMM {dims} | micro-kernel MK{mk_str}\n", arch.name);

    let configs = [
        ("BLIS static", cfg_blis(&arch, dims)),
        ("MOD refined", cfg_mod(&arch, mk, dims)),
    ];
    let mut t = Table::new(
        "simulated cache behaviour (PMU substitute)",
        &["config", "ccp", "L1 hit%", "L2 hit%", "L3 hit%", "DRAM lines", "L2->L1 traffic MB"],
    );
    for (label, cfg) in configs {
        let s = simulate_gemm(&arch, dims, &cfg, TraceOptions::sampled(), false);
        let scale = 1.0 / s.coverage;
        let l2_bytes = s.l2.accesses as f64 * scale * arch.l1().line_bytes as f64;
        t.row(&[
            label.to_string(),
            format!("{}", cfg.ccp),
            format!("{:.1}", 100.0 * s.l1.hit_ratio()),
            format!("{:.1}", 100.0 * s.l2.hit_ratio()),
            format!("{:.1}", 100.0 * s.l3.map(|l| l.hit_ratio()).unwrap_or(0.0)),
            format!("{:.0}", s.dram_lines_scaled()),
            format!("{:.1}", l2_bytes / 1e6),
        ]);
    }
    t.print();
    t.write_tsv("results/cache_explorer.tsv").ok();

    println!("Higher L2 hit ratio for MOD at small k is the paper's Figure 11 (bottom) effect.");
}
