//! Serving example: the coordinator under a synthetic request trace.
//!
//! Generates a mixed stream of DLA requests shaped like real
//! factorization workloads (skinny-k trailing updates of varying k,
//! interspersed full LU factorizations), runs it through the
//! [`CoordinatorServer`] under both the static-BLIS and the co-design
//! policies, and reports latency/throughput — the serving-layer view of
//! the paper's claim.
//!
//! Run: `cargo run --release --example serve_trace -- --requests 40`

use dla_codesign::arch::detect_host;
use dla_codesign::coordinator::{CoordinatorServer, DlaRequest, ServerConfig};
use dla_codesign::gemm::ConfigMode;
use dla_codesign::util::cli::Args;
use dla_codesign::util::{MatrixF64, Pcg64, Stopwatch};

fn synth_trace(n_requests: usize, seed: u64) -> Vec<DlaRequest> {
    let mut rng = Pcg64::seed(seed);
    let mut reqs = Vec::new();
    for i in 0..n_requests {
        if i % 8 == 7 {
            // A full factorization now and then.
            let s = *rng.choose(&[96usize, 128, 160]);
            reqs.push(DlaRequest::LuFactor { a: MatrixF64::random_diag_dominant(s, &mut rng), block: 32 });
        } else {
            // Trailing-update GEMMs: large-ish m = n, small k = b.
            let mn = rng.range(300, 700);
            let k = *rng.choose(&[32usize, 64, 96, 128]);
            reqs.push(DlaRequest::Gemm {
                alpha: -1.0,
                a: MatrixF64::random(mn, k, &mut rng),
                b: MatrixF64::random(k, mn, &mut rng),
                beta: 1.0,
                c: MatrixF64::random(mn, mn, &mut rng),
            });
        }
    }
    reqs
}

fn main() {
    let args = Args::from_env();
    let n = args.get_usize("requests", 40);
    let arch = detect_host();
    println!("serving {n} synthetic DLA requests on {}\n", arch.name);

    for (label, mode) in [
        ("BLIS static policy", ConfigMode::BlisStatic),
        ("co-design (refined dynamic)", ConfigMode::Refined),
    ] {
        let server = CoordinatorServer::start(ServerConfig::new(arch.clone(), mode))
            .expect("server start");
        let trace = synth_trace(n, 11);
        let total_flops: f64 = trace.iter().map(|r| r.flops()).sum();
        let sw = Stopwatch::start();
        let mut pending = Vec::new();
        for req in trace {
            pending.push(server.submit(req).expect("admission rejected"));
        }
        for rx in pending {
            rx.recv().unwrap().expect("request failed");
        }
        let wall = sw.elapsed_secs();
        let metrics = server.shutdown();
        println!("--- {label} ---");
        println!("  wall {:.2}s | {:.2} GFLOPS aggregate", wall, total_flops / wall / 1e9);
        print!("{}", metrics.summary());
        println!();
    }
}
