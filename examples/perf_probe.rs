use dla_codesign::arch::detect_host;
use dla_codesign::gemm::{ConfigMode, GemmEngine};
use dla_codesign::model::{GemmDims, MicroKernel};
use dla_codesign::util::{MatrixF64, Pcg64};
use dla_codesign::util::timer::measure;
fn main() {
    let arch = detect_host();
    let mut rng = Pcg64::seed(1);
    for (m, n, k) in [(2000, 2000, 256), (2000, 2000, 96)] {
        let dims = GemmDims::new(m, n, k);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::zeros(m, n);
        for (label, mode) in [
            ("BLIS-static", ConfigMode::BlisStatic),
            ("MOD 8x6", ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6))),
            ("dynamic", ConfigMode::Refined),
        ] {
            let mut e = GemmEngine::new(arch.clone(), mode);
            let meas = measure(3, 1.0, || e.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut()));
            println!("{m}x{n}x{k} {label:<12} {:>7.2} GFLOPS (best {:.2})", meas.gflops(dims.flops()), meas.gflops_best(dims.flops()));
        }
    }
}
