//! End-to-end driver (DESIGN.md §6): the full three-layer system on a
//! real small workload.
//!
//! The Rust coordinator loads the AOT artifacts (JAX blocked-LU step with
//! the Pallas GEMM trailing update), factors a random s = 256 system
//! **through the PJRT hot path** (Python not running), solves A x = b,
//! verifies ‖PA − LU‖ and the solve residual, and reports per-step
//! latency/GFLOPS. It then runs the same workload through the native
//! co-design engine under the three policies the paper compares (BLIS
//! static / original model / refined dynamic) and prints the headline
//! speedup.
//!
//! Run: `make artifacts && cargo run --release --example e2e_lu`

use dla_codesign::arch::detect_host;
use dla_codesign::coordinator::lu_driver::lu_via_artifacts;
use dla_codesign::coordinator::{Coordinator, DlaRequest, DlaResponse};
use dla_codesign::gemm::ConfigMode;
use dla_codesign::lapack::lu::lu_flops;
use dla_codesign::lapack::LuFactors;
use dla_codesign::runtime::Registry;
use dla_codesign::util::table::Table;
use dla_codesign::util::{MatrixF64, Pcg64, Stopwatch};

fn main() -> anyhow::Result<()> {
    let (s, b) = (256usize, 32usize);
    println!("== e2e: blocked LU (s={s}, b={b}) through the three-layer stack ==\n");

    // ---------- Layer 3 loads the AOT artifacts ------------------------
    let sw = Stopwatch::start();
    let registry = Registry::load(Registry::default_dir())?;
    println!(
        "[runtime] {} artifacts compiled on '{}' in {:.2}s",
        registry.len(),
        registry.engine.platform(),
        sw.elapsed_secs()
    );

    // ---------- A real small workload ----------------------------------
    let mut rng = Pcg64::seed(2026);
    let a0 = MatrixF64::random_diag_dominant(s, &mut rng);
    let x_true = MatrixF64::random(s, 4, &mut rng);
    let mut rhs = MatrixF64::zeros(s, 4);
    dla_codesign::gemm::gemm_reference(1.0, a0.view(), x_true.view(), 0.0, &mut rhs.view_mut());

    // ---------- Factor through the PJRT hot path ------------------------
    let res = lu_via_artifacts(&registry, &a0, b)?;
    let factors = LuFactors { lu: res.lu.clone(), pivots: res.pivots.clone(), block: b };
    let recon = factors.reconstruction_error(&a0);
    let x = factors.solve(&rhs);
    let xerr = x.max_abs_diff(&x_true);
    println!(
        "\n[e2e] total {:.1} ms  ({:.3} GFLOPS over {:.1} Mflop)",
        res.total_seconds * 1e3,
        res.gflops(),
        lu_flops(s) / 1e6
    );
    println!("[e2e] |PA - LU| / |A|      = {recon:.3e}   (require < 1e-10)");
    println!("[e2e] max |x - x_true|     = {xerr:.3e}   (require < 1e-8)");
    assert!(recon < 1e-10, "reconstruction failed");
    assert!(xerr < 1e-8, "solve failed");

    let mut t = Table::new("per-step latency (PJRT path)", &["step", "k", "ms"]);
    for (i, dt) in res.step_seconds.iter().enumerate() {
        t.row(&[i.to_string(), (i * b).to_string(), format!("{:.3}", dt * 1e3)]);
    }
    t.print();
    t.write_tsv("results/e2e_lu_steps.tsv").ok();

    // ---------- Headline: co-design policies on the same workload ------
    println!("\n== native engine: configuration policies on the same LU ==\n");
    let arch = detect_host();
    let mut rows = Vec::new();
    for (label, mode) in [
        ("BLIS static (R1 baseline)", ConfigMode::BlisStatic),
        ("original model", ConfigMode::OriginalModel),
        ("refined dynamic (co-design)", ConfigMode::Refined),
    ] {
        let mut co = Coordinator::new(arch.clone(), mode);
        // Warm-up + best-of-3 (the paper reports averages; min is stabler
        // at this tiny size).
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let resp = co.handle(DlaRequest::LuFactor { a: a0.clone(), block: b })?;
            if let DlaResponse::Lu { seconds, .. } = resp {
                best = best.min(seconds);
            }
        }
        rows.push((label, best, lu_flops(s) / best / 1e9));
    }
    let mut t = Table::new("policy comparison", &["policy", "ms", "GFLOPS", "speedup vs BLIS"]);
    let base = rows[0].1;
    for (label, secs, gf) in &rows {
        t.row(&[
            label.to_string(),
            format!("{:.2}", secs * 1e3),
            format!("{gf:.2}"),
            format!("{:.2}x", base / secs),
        ]);
    }
    t.print();
    t.write_tsv("results/e2e_lu_policies.tsv").ok();

    println!("\ne2e OK");
    Ok(())
}
