"""AOT exporter: lower the L2/L1 functions to HLO *text* artifacts the
Rust PJRT runtime loads at startup.

HLO text — NOT ``lowered.compile()`` or serialized protos — is the
interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that the xla crate's xla_extension 0.5.1 rejects; the
text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).

Usage:  python -m compile.aot --out-dir ../artifacts [--quick]

Writes one ``<name>.hlo.txt`` per artifact plus ``manifest.tsv`` with
columns: name, file, kind, params (key=value;...). The Rust
``runtime::registry`` parses the manifest.
"""

import argparse
import os

import jax

jax.config.update("jax_enable_x64", True)  # the paper's FP64 precision

import jax.numpy as jnp  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from . import model  # noqa: E402
from .kernels import gemm_pallas  # noqa: E402


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float64)


def i64(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int64)


def artifact_list(quick: bool):
    """(name, fn, example_args, kind, params) for every artifact."""
    arts = []

    # --- GEMM kernels: the e2e LU shape + bench shapes + variants -----
    gemm_shapes = [(256, 256, 32), (128, 128, 128)]
    if not quick:
        gemm_shapes += [(256, 256, 64), (512, 512, 64)]
    for (m, n, k) in gemm_shapes:
        for variant in (["mk8x8", "mk12x4"] if not quick else ["mk8x8"]):
            name = f"gemm_{m}x{n}x{k}_{variant}"
            fn = model.make_gemm(variant=variant)
            arts.append(
                (name, fn, (f64(m, k), f64(k, n)), "gemm",
                 dict(m=m, n=n, k=k, variant=variant))
            )
    # Trailing-update form used by the coordinator's LU driver.
    for (m, n, k) in [(256, 256, 32)] + ([] if quick else [(512, 512, 64)]):
        name = f"gemm_update_{m}x{n}x{k}_mk8x8"
        fn = model.make_gemm_update(variant="mk8x8")
        arts.append(
            (name, fn, (f64(m, n), f64(m, k), f64(k, n)), "gemm_update",
             dict(m=m, n=n, k=k, variant="mk8x8"))
        )

    # --- LU step + full factorization ---------------------------------
    lu_shapes = [(256, 32)]
    if not quick:
        lu_shapes += [(128, 16)]
    for (s, b) in lu_shapes:
        step = model.make_lu_step(s, b)
        arts.append(
            (f"lu_step_s{s}_b{b}", step, (f64(s, s), i64(s), i64()), "lu_step",
             dict(s=s, b=b))
        )
        full = model.make_lu_full(s, b)
        arts.append(
            (f"lu_full_s{s}_b{b}", full, (f64(s, s),), "lu_full",
             dict(s=s, b=b))
        )
    return arts


def export_all(out_dir: str, quick: bool = False) -> None:
    os.makedirs(out_dir, exist_ok=True)
    manifest_rows = []
    for name, fn, args, kind, params in artifact_list(quick):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        pstr = ";".join(f"{k}={v}" for k, v in sorted(params.items()))
        manifest_rows.append(f"{name}\t{fname}\t{kind}\t{pstr}")
        print(f"  {name}: {len(text)} chars")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("# name\tfile\tkind\tparams\n")
        f.write("\n".join(manifest_rows) + "\n")
    print(f"wrote {len(manifest_rows)} artifacts to {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--quick", action="store_true", help="minimal artifact set")
    args = ap.parse_args()
    export_all(args.out_dir, args.quick)


if __name__ == "__main__":
    main()
