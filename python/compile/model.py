"""Layer-2: the JAX compute graph — blocked LU with partial pivoting.

This is the paper's Figure 2 algorithm expressed at fixed shape so one
compiled artifact serves every iteration: the step index ``k`` is a traced
scalar and all panel/strip extractions use static-size dynamic slices plus
masking. The trailing update is the paper's skinny-k GEMM and runs through
the Layer-1 Pallas kernel.

Exported entry points (see aot.py):

- ``gemm_fn(a, b)``            — the Pallas GEMM at a fixed shape.
- ``lu_step_fn(a, piv, k)``    — one blocked-LU iteration (PFACT + swaps
                                 + TSOLVE + GEMM); the Rust coordinator
                                 drives the loop over ``k``.
- ``lu_full_fn(a)``            — the whole factorization as one artifact
                                 (``fori_loop`` over steps).

Everything is FP64 (the paper's precision); aot.py enables x64.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import gemm_pallas


def tri_solve_unit_lower(l11, r):
    """R := Lower_unit(L11)^{-1} R by forward substitution.

    Hand-rolled (fori_loop of masked rank-1 updates) instead of
    ``jax.scipy.linalg.solve_triangular``: the SciPy route lowers to a
    LAPACK typed-FFI custom-call that the Rust runtime's XLA
    (xla_extension 0.5.1) cannot execute, while this version is pure HLO.
    Only the strictly-lower part of ``l11`` is referenced.
    """
    b = l11.shape[0]
    assert l11.shape == (b, b) and r.shape[0] == b
    rows = jnp.arange(b)

    def body(i, r):
        row_i = jax.lax.dynamic_index_in_dim(r, i, axis=0, keepdims=False)
        col_i = jax.lax.dynamic_index_in_dim(l11, i, axis=1, keepdims=False)
        col_i = jnp.where(rows > i, col_i, 0.0)
        return r - jnp.outer(col_i, row_i)

    return jax.lax.fori_loop(0, b, body, r)


def _panel_factor(strip, k, s, b):
    """Factor the s x b panel ``strip`` (global rows, columns [k, k+b))
    with partial pivoting, restricted to rows >= k + j at local column j.

    Returns (factored strip, local pivot rows as global indices, ok flag).
    Rows above the diagonal of the panel are left untouched.
    """
    rows = jnp.arange(s)

    def step(j, carry):
        a, piv, ok = carry
        col = k + j  # global row of the panel diagonal
        colv = jax.lax.dynamic_index_in_dim(a, j, axis=1, keepdims=False)
        valid = rows >= col
        mag = jnp.where(valid, jnp.abs(colv), -1.0)
        p = jnp.argmax(mag)  # global pivot row
        ok = jnp.logical_and(ok, mag[p] > 0.0)
        # Swap rows col <-> p of the strip.
        rowc = jax.lax.dynamic_slice(a, (col, 0), (1, b))
        rowp = jax.lax.dynamic_slice(a, (p, 0), (1, b))
        a = jax.lax.dynamic_update_slice(a, rowp, (col, 0))
        a = jax.lax.dynamic_update_slice(a, rowc, (p, 0))
        piv = piv.at[col].set(p)
        # Scale the sub-column and apply the rank-1 update to the panel.
        colv = jax.lax.dynamic_index_in_dim(a, j, axis=1, keepdims=False)
        pivot = colv[col]
        inv = jnp.where(pivot != 0.0, 1.0 / pivot, 0.0)
        below = rows > col
        lcol = jnp.where(below, colv * inv, colv)
        a = a.at[:, j].set(lcol)
        # Rank-1 on panel columns > j, rows > col.
        urow = jax.lax.dynamic_index_in_dim(a, col, axis=0, keepdims=False)  # length b
        cols_p = jnp.arange(b)
        umask = jnp.where(cols_p > j, urow, 0.0)
        lmask = jnp.where(below, lcol, 0.0)
        a = a - jnp.outer(lmask, umask)
        return a, piv, ok

    piv0 = jnp.arange(s)
    strip, piv, ok = jax.lax.fori_loop(0, b, step, (strip, piv0, jnp.bool_(True)))
    return strip, piv, ok


def make_lu_step(s, b, variant=gemm_pallas.DEFAULT_VARIANT):
    """Build the fixed-shape LU step function for matrix order ``s`` and
    algorithmic block size ``b`` (both static)."""
    assert s % b == 0, "s must be a multiple of b for the exported artifact"

    def lu_step(a, piv, k):
        """One iteration of the blocked right-looking LU at panel start
        ``k`` (traced scalar). Returns (a', piv', ok)."""
        rows = jnp.arange(s)
        cols = jnp.arange(s)
        # ---- PFACT on the s x b panel --------------------------------
        strip = jax.lax.dynamic_slice(a, (0, k), (s, b))
        strip_f, piv_step, ok = _panel_factor(strip, k, s, b)
        # ---- Row interchanges on the rest of the matrix --------------
        # Apply the same swap sequence to the complement columns; the
        # panel columns are replaced wholesale by the factored strip.
        def apply_swap(j, am):
            col = k + j
            p = piv_step[col]
            rowc = jax.lax.dynamic_slice(am, (col, 0), (1, s))
            rowp = jax.lax.dynamic_slice(am, (p, 0), (1, s))
            am = jax.lax.dynamic_update_slice(am, rowp, (col, 0))
            am = jax.lax.dynamic_update_slice(am, rowc, (p, 0))
            return am

        a = jax.lax.fori_loop(0, b, apply_swap, a)
        a = jax.lax.dynamic_update_slice(a, strip_f, (0, k))
        # Record pivots at their global positions.
        in_panel = jnp.logical_and(rows >= k, rows < k + b)
        piv = jnp.where(in_panel, piv_step, piv)
        # ---- TSOLVE: U12 = L11^{-1} A12 ------------------------------
        l11 = jax.lax.dynamic_slice(a, (k, k), (b, b))
        rstrip = jax.lax.dynamic_slice(a, (k, 0), (b, s))
        solved = tri_solve_unit_lower(l11, rstrip)
        right = cols >= k + b
        rstrip = jnp.where(right[None, :], solved, rstrip)
        a = jax.lax.dynamic_update_slice(a, rstrip, (k, 0))
        # ---- GEMM: A22 -= A21 * U12 (k-dim = b), via Pallas ----------
        below = rows >= k + b
        a21 = jax.lax.dynamic_slice(a, (0, k), (s, b))
        a21 = jnp.where(below[:, None], a21, 0.0)
        u12 = jnp.where(right[None, :], rstrip, 0.0)
        a = a - gemm_pallas.gemm(a21, u12, variant=variant)
        return a, piv, ok

    return lu_step


def make_lu_full(s, b, variant=gemm_pallas.DEFAULT_VARIANT):
    """Whole blocked LU as a single function (fori_loop over steps)."""
    lu_step = make_lu_step(s, b, variant)

    def lu_full(a):
        piv0 = jnp.arange(s)
        ok0 = jnp.bool_(True)

        def body(i, carry):
            a, piv, ok = carry
            a, piv, ok_i = lu_step(a, piv, i * b)
            return a, piv, jnp.logical_and(ok, ok_i)

        return jax.lax.fori_loop(0, s // b, body, (a, piv0, ok0))

    return lu_full


def make_gemm(variant=gemm_pallas.DEFAULT_VARIANT, block_k=None):
    """Fixed-variant GEMM entry point (shape fixed at lowering time)."""

    def gemm_fn(a, b):
        return gemm_pallas.gemm(a, b, variant=variant, block_k=block_k)

    return gemm_fn


def make_gemm_update(variant=gemm_pallas.DEFAULT_VARIANT):
    """Trailing-update GEMM: C := C - A @ B (alpha = -1, beta = 1)."""

    def gemm_update_fn(c, a, b):
        return gemm_pallas.gemm_update(c, a, b, alpha=-1.0, beta=1.0, variant=variant)

    return gemm_update_fn


@functools.lru_cache(maxsize=None)
def jitted_lu_step(s, b, variant=gemm_pallas.DEFAULT_VARIANT):
    return jax.jit(make_lu_step(s, b, variant))


@functools.lru_cache(maxsize=None)
def jitted_lu_full(s, b, variant=gemm_pallas.DEFAULT_VARIANT):
    return jax.jit(make_lu_full(s, b, variant))
