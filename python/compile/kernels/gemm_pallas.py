"""Layer-1: the GEMM hot spot as Pallas kernels.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper tiles for
CPU caches and NEON/AVX register files; on TPU the same co-design insight
maps onto BlockSpec tile selection for VMEM and the MXU:

- packed buffer ``Ac`` in L2          -> the (bm, bk) A tile staged in VMEM
- micro-panel ``Br`` in L1            -> the (bk, bn) B tile in VMEM
- ``mr x nr`` register micro-tile     -> the (bm, bn) MXU accumulator tile
- CCP choice (mc, nc, kc)             -> (bm, bn, bk) chosen from VMEM
                                          capacity by the same refined,
                                          dimension-aware model

The kernel *variants* mirror the paper's micro-kernel family: each scales
an ``mr x nr`` aspect ratio up to MXU-aligned tiles, and the co-design
selector (Rust layer 3) decides which compiled artifact serves a request.

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and the numerics of the interpret path are
exactly those the Rust runtime replays (see /opt/xla-example/README.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's micro-kernel family, scaled by 16x to MXU-aligned tiles
# (e.g. MK8x6 -> 128 x 96). Keys match the Rust selector's variant names.
VARIANTS = {
    "mk8x6": (128, 96),
    "mk6x8": (96, 128),
    "mk12x4": (192, 64),
    "mk4x12": (64, 192),
    "mk8x8": (128, 128),
}

DEFAULT_VARIANT = "mk8x8"


def _gemm_kernel_fullk(a_ref, b_ref, o_ref):
    """2-D grid kernel: each program computes one (bm, bn) output tile
    from a full-k (bm, K) x (K, bn) pair of VMEM tiles."""
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def _gemm_kernel_blockk(a_ref, b_ref, o_ref):
    """3-D grid kernel: k is blocked too; program (i, j, p) accumulates
    the p-th (bm, bk) x (bk, bn) partial product into the output tile.

    The K grid axis iterates innermost ("arbitrary" semantics in
    interpret mode), so the accumulation o += a @ b is safe: the same
    (i, j) tile is revisited across p with the partial sums persisted.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=o_ref.dtype)


def _round_block(dim, want):
    """Largest block <= want dividing dim (fall back to dim itself)."""
    want = min(want, dim)
    for cand in range(want, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


@functools.partial(jax.jit, static_argnames=("variant", "block_k"))
def gemm(a, b, variant=DEFAULT_VARIANT, block_k=None):
    """C = A @ B through the Pallas kernel.

    ``variant`` selects the tile aspect ratio (micro-kernel analogue);
    ``block_k`` enables the 3-D-grid accumulator kernel with the given k
    block (the kc analogue), otherwise the full-k kernel is used.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    bm_want, bn_want = VARIANTS[variant]
    bm = _round_block(m, bm_want)
    bn = _round_block(n, bn_want)
    out_shape = jax.ShapeDtypeStruct((m, n), a.dtype)
    if block_k is None:
        grid = (m // bm, n // bn)
        return pl.pallas_call(
            _gemm_kernel_fullk,
            out_shape=out_shape,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
                pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            ],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            interpret=True,
        )(a, b)
    bk = _round_block(k, block_k)
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel_blockk,
        out_shape=out_shape,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, p: (i, p)),
            pl.BlockSpec((bk, bn), lambda i, j, p: (p, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, p: (i, j)),
        interpret=True,
    )(a, b)


def gemm_update(c, a, b, alpha=1.0, beta=1.0, variant=DEFAULT_VARIANT):
    """C := alpha * A @ B + beta * C — the LU trailing-update form."""
    return alpha * gemm(a, b, variant=variant) + beta * c


def vmem_bytes(variant, k, dtype_bytes=8):
    """Estimated VMEM footprint of one program instance of the full-k
    kernel: A tile + B tile + O tile. Used by DESIGN.md's §Perf L1 notes
    and asserted against the 16 MB VMEM budget in tests."""
    bm, bn = VARIANTS[variant]
    return dtype_bytes * (bm * k + k * bn + bm * bn)


def mxu_alignment(variant):
    """Fraction of the tile that is MXU-aligned (128-multiples)."""
    bm, bn = VARIANTS[variant]
    am = (bm // 128) * 128 / bm if bm >= 128 else bm / 128
    an = (bn // 128) * 128 / bn if bn >= 128 else bn / 128
    return am * an
