"""Pure-jnp correctness oracles for the Pallas kernels and the JAX model.

Everything here is deliberately written in the most transparent way
possible (no tiling, no pallas, no clever masking): pytest compares the
production kernels against these, making this file the root of the
correctness chain for the Python layers.
"""

import jax.numpy as jnp
import numpy as np


def gemm_ref(a, b):
    """C = A @ B, plain jnp."""
    return jnp.matmul(a, b)


def gemm_update_ref(c, a, b, alpha=1.0, beta=1.0):
    """C := alpha * A @ B + beta * C (the trailing-update form)."""
    return alpha * jnp.matmul(a, b) + beta * c


def lu_partial_pivot_ref(a):
    """Unblocked LU with partial pivoting, numpy loops (oracle only).

    Returns (lu, piv) in LAPACK convention: lu holds L (strict lower,
    unit diagonal implicit) and U; piv[j] = row swapped with j at step j.
    """
    a = np.array(a, dtype=np.float64, copy=True)
    s = a.shape[0]
    assert a.shape == (s, s)
    piv = np.zeros(s, dtype=np.int64)
    for j in range(s):
        p = j + int(np.argmax(np.abs(a[j:, j])))
        piv[j] = p
        if p != j:
            a[[j, p], :] = a[[p, j], :]
        if a[j, j] == 0.0:
            raise ZeroDivisionError(f"singular at column {j}")
        a[j + 1 :, j] /= a[j, j]
        a[j + 1 :, j + 1 :] -= np.outer(a[j + 1 :, j], a[j, j + 1 :])
    return a, piv


def apply_pivots_ref(x, piv):
    """Apply the pivot sequence to rows of x (compute P @ x)."""
    x = np.array(x, copy=True)
    for j, p in enumerate(piv):
        if p != j:
            x[[j, p]] = x[[p, j]]
    return x


def reconstruct_ref(lu, piv, a0):
    """max |P A0 - L U| (normalized by max|A0|)."""
    s = lu.shape[0]
    lo = np.tril(lu, -1) + np.eye(s)
    up = np.triu(lu)
    pa = apply_pivots_ref(a0, piv)
    err = np.max(np.abs(pa - lo @ up))
    return err / max(np.max(np.abs(np.array(a0))), 1e-300)
