"""AOT path checks: HLO text is well-formed and executable by a fresh
XLA client — the same contract the Rust runtime relies on."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_to_hlo_text_structure():
    fn = model.make_gemm()
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((32, 16), jnp.float64),
        jax.ShapeDtypeStruct((16, 24), jnp.float64),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text
    assert "f64[32,16]" in text
    assert "f64[16,24]" in text


def test_hlo_text_parses_back():
    """The emitted HLO text must parse back through XLA's text parser —
    the id-reassigning path the Rust runtime uses
    (`HloModuleProto::from_text_file`). Execution through the PJRT C API
    is covered by the Rust integration test `runtime::tests` /
    `rust/tests/e2e_artifacts.rs`, which loads these exact artifacts.
    """
    from jax._src.lib import xla_client as xc

    fn = model.make_gemm()
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float64),
        jax.ShapeDtypeStruct((8, 8), jnp.float64),
    )
    text = aot.to_hlo_text(lowered)
    hm = xc._xla.hlo_module_from_text(text)
    # Round-trip: proto -> text again must keep the entry computation.
    assert "ENTRY" in hm.to_string()
    proto = hm.as_serialized_hlo_module_proto()
    assert len(proto) > 100


def test_export_all_quick(tmp_path):
    aot.export_all(str(tmp_path), quick=True)
    manifest = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert manifest[0].startswith("#")
    rows = [l.split("\t") for l in manifest[1:]]
    assert len(rows) >= 4
    kinds = {r[2] for r in rows}
    assert {"gemm", "gemm_update", "lu_step", "lu_full"} <= kinds
    for name, fname, kind, params in rows:
        text = (tmp_path / fname).read_text()
        assert "HloModule" in text, f"{name} missing HloModule header"
        assert "ENTRY" in text
        # params parse as key=value pairs
        kv = dict(p.split("=") for p in params.split(";"))
        assert kv, f"{name} has no params"


def test_artifact_list_params_consistent():
    for name, fn, args, kind, params in aot.artifact_list(quick=True):
        if kind == "gemm":
            m, n, k = params["m"], params["n"], params["k"]
            assert args[0].shape == (m, k)
            assert args[1].shape == (k, n)
        elif kind == "lu_step":
            s = params["s"]
            assert args[0].shape == (s, s)
            assert s % params["b"] == 0
