"""Shared pytest configuration: FP64 everywhere (the paper's precision)."""

import jax

jax.config.update("jax_enable_x64", True)
