"""L1 kernel correctness: the Pallas GEMM vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes, as required: the kernel must be
exact (up to accumulation roundoff) for every variant, every tile-divide
and non-divide shape, and both grid styles (full-k and blocked-k).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm_pallas, ref

VARIANTS = sorted(gemm_pallas.VARIANTS)


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(shape).astype(dtype)


def _tol(dtype, k):
    eps = np.finfo(dtype).eps
    return 20 * eps * max(k, 1)


@pytest.mark.parametrize("variant", VARIANTS)
def test_exact_tile_shapes(variant):
    bm, bn = gemm_pallas.VARIANTS[variant]
    a = _rand((bm * 2, 64), np.float64, 1)
    b = _rand((64, bn * 2), np.float64, 2)
    got = np.array(gemm_pallas.gemm(a, b, variant=variant))
    want = np.array(ref.gemm_ref(a, b))
    np.testing.assert_allclose(got, want, atol=_tol(np.float64, 64))


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    n=st.integers(1, 96),
    k=st.integers(1, 96),
    variant=st.sampled_from(VARIANTS),
)
def test_hypothesis_shapes_f64(m, n, k, variant):
    a = _rand((m, k), np.float64, m * 7 + k)
    b = _rand((k, n), np.float64, n * 13 + k)
    got = np.array(gemm_pallas.gemm(a, b, variant=variant))
    want = a @ b
    np.testing.assert_allclose(got, want, atol=_tol(np.float64, k))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 64),
    n=st.integers(1, 64),
    k=st.integers(1, 64),
)
def test_hypothesis_shapes_f32(m, n, k):
    a = _rand((m, k), np.float32, m + k)
    b = _rand((k, n), np.float32, n + 2 * k)
    got = np.array(gemm_pallas.gemm(a, b))
    want = a @ b
    assert got.dtype == np.float32
    np.testing.assert_allclose(got, want, atol=_tol(np.float32, k))


@settings(max_examples=15, deadline=None)
@given(
    mt=st.integers(1, 3),
    nt=st.integers(1, 3),
    kt=st.integers(1, 4),
    bk=st.sampled_from([8, 16, 32]),
)
def test_blocked_k_accumulator(mt, nt, kt, bk):
    """The 3-D-grid kernel (kc analogue) must accumulate correctly."""
    m, n, k = 32 * mt, 32 * nt, bk * kt
    a = _rand((m, k), np.float64, m + k)
    b = _rand((k, n), np.float64, n + k)
    got = np.array(gemm_pallas.gemm(a, b, block_k=bk))
    np.testing.assert_allclose(got, a @ b, atol=_tol(np.float64, k))


def test_gemm_update_alpha_beta():
    c = _rand((48, 40), np.float64, 3)
    a = _rand((48, 24), np.float64, 4)
    b = _rand((24, 40), np.float64, 5)
    got = np.array(gemm_pallas.gemm_update(c, a, b, alpha=-1.0, beta=1.0))
    want = np.array(ref.gemm_update_ref(c, a, b, alpha=-1.0, beta=1.0))
    np.testing.assert_allclose(got, want, atol=1e-12)


def test_identity_and_zero():
    a = _rand((33, 33), np.float64, 9)
    eye = np.eye(33)
    np.testing.assert_allclose(np.array(gemm_pallas.gemm(a, eye)), a, atol=1e-13)
    z = np.zeros((33, 17))
    assert np.all(np.array(gemm_pallas.gemm(a, z)) == 0.0)


@pytest.mark.parametrize("variant", VARIANTS)
def test_vmem_budget(variant):
    """DESIGN.md §Perf L1: every exported tile configuration must fit the
    16 MB VMEM budget at the largest exported k."""
    assert gemm_pallas.vmem_bytes(variant, k=512) < 16 * 1024 * 1024


def test_mxu_alignment_reported():
    # The default variant is fully MXU-aligned; skinny family members
    # trade alignment for shape, mirroring the paper's micro-kernels.
    assert gemm_pallas.mxu_alignment("mk8x8") == 1.0
    assert 0.0 < gemm_pallas.mxu_alignment("mk12x4") <= 1.0


def test_inner_dim_mismatch_raises():
    a = np.zeros((4, 5))
    b = np.zeros((6, 4))
    with pytest.raises(AssertionError):
        gemm_pallas.gemm(a, b)
