"""L2 model correctness: the masked fixed-shape blocked LU vs the numpy
partial-pivoting oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _rand(s, seed):
    return np.random.default_rng(seed).standard_normal((s, s))


@pytest.mark.parametrize("s,b", [(32, 8), (64, 16), (64, 64), (96, 32)])
def test_lu_full_matches_oracle(s, b):
    a0 = _rand(s, s + b)
    lu, piv, ok = model.jitted_lu_full(s, b)(a0)
    lu, piv = np.array(lu), np.array(piv)
    assert bool(ok)
    lu_ref, piv_ref = ref.lu_partial_pivot_ref(a0)
    # Same pivot sequence (partial pivoting is deterministic) and the
    # same factors.
    assert np.array_equal(piv, piv_ref), "pivot sequences differ"
    np.testing.assert_allclose(lu, lu_ref, atol=1e-11)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([(24, 8), (48, 12), (40, 10)]))
def test_lu_reconstruction_property(seed, shape):
    s, b = shape
    a0 = _rand(s, seed)
    lu, piv, ok = model.jitted_lu_full(s, b)(a0)
    assert bool(ok)
    err = ref.reconstruct_ref(np.array(lu), np.array(piv), a0)
    assert err < 1e-12 * s, f"|PA - LU| = {err}"


def test_lu_step_composes_to_full():
    """Driving lu_step iteratively (the Rust coordinator's loop) must
    give the same result as the single lu_full artifact."""
    s, b = 64, 16
    a0 = _rand(s, 77)
    step = model.jitted_lu_step(s, b)
    a = jnp.asarray(a0)
    piv = jnp.arange(s)
    for i in range(s // b):
        a, piv, ok = step(a, piv, i * b)
        assert bool(ok)
    full_a, full_piv, _ = model.jitted_lu_full(s, b)(a0)
    np.testing.assert_allclose(np.array(a), np.array(full_a), atol=1e-12)
    assert np.array_equal(np.array(piv), np.array(full_piv))


def test_lu_multipliers_bounded():
    """Partial pivoting bounds every multiplier by 1."""
    s, b = 48, 12
    a0 = _rand(s, 5)
    lu, piv, ok = model.jitted_lu_full(s, b)(a0)
    lo = np.tril(np.array(lu), -1)
    assert np.max(np.abs(lo)) <= 1.0 + 1e-12


def test_lu_singular_flag():
    """A singular matrix must clear the ok flag instead of silently
    producing NaNs-as-answers."""
    s, b = 32, 8
    a0 = _rand(s, 6)
    a0[:, 0] = 0.0  # exactly zero pivot column
    _, _, ok = model.jitted_lu_full(s, b)(a0)
    assert not bool(ok)


def test_lu_identity():
    s, b = 32, 8
    lu, piv, ok = model.jitted_lu_full(s, b)(np.eye(s))
    assert bool(ok)
    np.testing.assert_allclose(np.array(lu), np.eye(s), atol=1e-15)
    assert np.array_equal(np.array(piv), np.arange(s))


def test_lu_pallas_variant_consistency():
    """The LU must be numerically identical regardless of which Pallas
    GEMM variant serves the trailing update."""
    s, b = 64, 16
    a0 = _rand(s, 11)
    lu1, piv1, _ = model.jitted_lu_full(s, b, "mk8x8")(a0)
    lu2, piv2, _ = model.jitted_lu_full(s, b, "mk12x4")(a0)
    np.testing.assert_allclose(np.array(lu1), np.array(lu2), atol=1e-12)
    assert np.array_equal(np.array(piv1), np.array(piv2))
