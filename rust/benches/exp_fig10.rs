//! Bench target regenerating Figure 10 (LU vs b on Carmel, sequential +
//! 8-core G4 model).
use dla_codesign::harness::{fig10, HarnessOpts};

fn main() {
    println!("=== exp_fig10 ===");
    let mut opts = HarnessOpts::default();
    opts.lu_s = std::env::var("DLA_LU_S").ok().and_then(|v| v.parse().ok()).unwrap_or(opts.lu_s);
    fig10::run(&opts, false);
    fig10::run(&opts, true);
}
