//! Bench target regenerating Figure 9 (GEMM variants, Carmel model +
//! host measured).
use dla_codesign::harness::{fig9, HarnessOpts};

fn main() {
    println!("=== exp_fig9 ===");
    let mut opts = HarnessOpts::default();
    opts.gemm_mn = std::env::var("DLA_MN").ok().and_then(|v| v.parse().ok()).unwrap_or(opts.gemm_mn);
    fig9::run(&opts);
}
