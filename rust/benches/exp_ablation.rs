//! Ablation benches for the design choices DESIGN.md calls out:
//!
//! 1. **Model vs exhaustive autotuning** — the paper's claim that the
//!    refined analytical model "avoids a costly optimization search":
//!    sweep a CCP grid on the host, compare the best-found configuration
//!    against the model's one-shot pick (quality and search cost).
//! 2. **Micro-kernel family sweep** — every SIMD kernel under MOD CCPs
//!    (the §3.4 selection space).
//! 3. **Workspace pooling** — pooled packing buffers (the paper's
//!    "sufficiently large workspace") vs per-call allocation.
//! 4. **Persistent pool vs spawn-per-block** — the paper's hot sequence
//!    (LU-style trailing updates: m = n shrinking, k = b) on the
//!    persistent worker pool vs the seed's spawn-per-macro-block driver,
//!    with the trajectory written to `BENCH_gemm.json` for future PRs.
//! 5. **Lookahead on/off blocked LU** — the fused split-team pipeline vs
//!    the serialized panel/update path, per matrix order, with the
//!    pool's leader-wait and between-job idle counters showing where the
//!    recovered time comes from. Appended to the same `BENCH_gemm.json`
//!    (per ROADMAP: extend the entries, don't replace them).
//! 6. **Static vs dynamic deep lookahead** — PR 2's static depth-1 fixed
//!    `t_p` vs the work-queue pipeline at depth {2, 3} vs depth-2 with
//!    model-driven malleable `t_p`, on the same blocked-LU sweep, with
//!    the per-phase pool idle deltas (panel idle / update idle /
//!    queue-empty stalls) and the team-size selector cache hit-rate.
//!    Appended to `BENCH_gemm.json` alongside the earlier ablations.
//! 7. **Batched vs serialized server** — a small-GEMM request mix
//!    through the coordinator server with the batch scheduler on vs
//!    pinned off: requests/s, plus the new batch metrics (fused
//!    dispatch count, mean batch size, per-request queue wait).
//!    Appended to the same `BENCH_gemm.json`.
//! 8. **Element width** — the same GEMM in f32 vs f64 through the same
//!    engine (f32 gets twice the SIMD lanes and the model's doubled
//!    cache params), and the mixed-precision LU solve (factor f32 +
//!    iteratively refine to f64 residual accuracy) vs the plain f64
//!    factor+solve. Appended to the same `BENCH_gemm.json`.
//! 9. **ABFT overhead** — the same GEMM and blocked LU with
//!    checksum verification (`VerifyPolicy::Detect`) armed vs off.
//!    Detect mode is bitwise identical to plain when no fault fires,
//!    so the delta is pure checksum work (target <= 10%). Appended to
//!    the same `BENCH_gemm.json`.
//! 10. **Fused lookahead vs tile-DAG scheduler** — the same blocked LU
//!     and Cholesky sweep under the fused split-team pipeline vs the
//!     dataflow drain (`SchedPolicy::Dag`: work-stealing deques on the
//!     same persistent pool, no stop-the-world rejoins). The per-phase
//!     rejoin-idle deltas (panel/update/queue-stall rank-ms — zero by
//!     construction under the DAG) and the steal-side counters
//!     (executed tasks, steals, failed probes, deque high-water) show
//!     where the dataflow drain spends the recovered wait time.
//!     Appended to the same `BENCH_gemm.json`.
//! 11. **Analytic-only vs measurement-calibrated selection** — the same
//!     engine with and without an online [`PerfProfile`] attached, over
//!     the two workloads where a profile has time to get hot: the
//!     LU-style trailing-update sweep (m = n shrinking, skinny fixed k)
//!     and a repeated-shape small-GEMM serving mix through the
//!     coordinator server (`CalibratePolicy` pinned per arm). The store
//!     /memo counters land next to the timings. Appended to the same
//!     `BENCH_gemm.json`.
use dla_codesign::arch::detect_host;
use dla_codesign::coordinator::{BatchPolicy, CoordinatorServer, DlaRequest, ServerConfig};
use dla_codesign::bench::{BenchGroup, JsonBench};
use dla_codesign::gemm::microkernel::for_shape;
use dla_codesign::gemm::parallel::{gemm_parallel, gemm_parallel_spawning};
use dla_codesign::gemm::{
    gemm_blocked, gemm_reference, ConfigMode, GemmEngine, Lookahead, ParallelLoop, SchedPolicy,
    ThreadPlan, VerifyPolicy, Workspace, AUTO_PANEL_WORKERS,
};
use dla_codesign::lapack::refine::{lu_solve_f64, lu_solve_mixed, RefineOptions};
use dla_codesign::lapack::{cholesky_blocked, getf2, lu_blocked, lu_flops};
use dla_codesign::model::ccp::GemmConfig;
use dla_codesign::model::{refined_ccp, CalibratePolicy, Ccp, GemmDims, MicroKernel, PerfProfile};
use std::sync::Arc;
use dla_codesign::runtime::pool::WorkerPool;
use dla_codesign::util::timer::measure;
use dla_codesign::util::{MatrixF32, MatrixF64, Pcg64, Stopwatch};

fn main() {
    let arch = detect_host();
    let mn = std::env::var("DLA_MN").ok().and_then(|v| v.parse().ok()).unwrap_or(768usize);
    let k = 96;
    let dims = GemmDims::new(mn, mn, k);
    let mut rng = Pcg64::seed(5);
    let a = MatrixF64::random(mn, k, &mut rng);
    let b = MatrixF64::random(k, mn, &mut rng);
    let mut c = MatrixF64::zeros(mn, mn);
    let mk = MicroKernel::new(8, 6);
    let kernel = for_shape(mk).unwrap();

    // --- 1. model pick vs exhaustive grid search -----------------------
    println!("=== ablation 1: refined model vs exhaustive CCP search ({mn}x{mn}x{k}) ===");
    let model_ccp = refined_ccp(&arch, mk, dims).clamp_to(dims);
    let mut g = BenchGroup::new("model vs autotune");
    let mut ws = Workspace::new();
    g.case(&format!("model pick {model_ccp}"), dims.flops(), || {
        let cfg = GemmConfig { mk, ccp: model_ccp };
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut ws);
    });
    let sweep = Stopwatch::start();
    let mut best = (Ccp::new(1, 1, 1), 0.0f64);
    let mut tried = 0;
    for mc in [48, 96, 192, 384, 768, 1536] {
        for nc in [96, 192, 384, 768, 1536] {
            for kc in [32, 64, 96] {
                let ccp = Ccp::new(mc, nc, kc).clamp_to(dims);
                let cfg = GemmConfig { mk, ccp };
                let m = measure(1, 0.05, || {
                    gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut ws);
                });
                let gf = m.gflops_best(dims.flops());
                tried += 1;
                if gf > best.1 {
                    best = (ccp, gf);
                }
            }
        }
    }
    let sweep_s = sweep.elapsed_secs();
    g.record(&format!("autotune best {} ({tried} configs, {sweep_s:.1}s search)", best.0),
             dims.flops() / best.1 / 1e9, dims.flops());
    g.finish("bench_ablation_autotune");
    println!("-> search cost {sweep_s:.1}s vs model cost ~0s; quality gap = model/best ratio above\n");

    // --- 2. micro-kernel family under MOD CCPs --------------------------
    println!("=== ablation 2: micro-kernel family at {mn}x{mn}x{k} ===");
    let mut g2 = BenchGroup::new("micro-kernel family (MOD CCPs)");
    let eng = GemmEngine::new(arch.clone(), ConfigMode::Refined);
    for spec in eng.family() {
        let kern = match for_shape(spec) {
            Some(kk) => kk,
            None => continue,
        };
        let ccp = refined_ccp(&arch, spec, dims).clamp_to(dims);
        let cfg = GemmConfig { mk: spec, ccp };
        g2.case(&format!("{spec} {ccp}"), dims.flops(), || {
            gemm_blocked(&cfg, &kern, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut ws);
        });
    }
    g2.finish("bench_ablation_family");

    // --- 3. workspace pooling vs per-call allocation --------------------
    println!("=== ablation 3: pooled vs per-call workspace ===");
    let mut g3 = BenchGroup::new("workspace pooling");
    let cfg = GemmConfig { mk, ccp: model_ccp };
    g3.case("pooled workspace", dims.flops(), || {
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut ws);
    });
    g3.case("fresh workspace per call", dims.flops(), || {
        let mut fresh = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut fresh);
    });
    g3.finish("bench_ablation_workspace");

    // --- 4. persistent pool vs spawn-per-block -------------------------
    // The paper's hot sequence: one blocked-factorization sweep of
    // trailing updates (m = n shrinking by b per step, k = b). The seed
    // architecture spawned threads inside every macro-block; the pool
    // broadcasts one job per GEMM to parked workers.
    let threads: usize =
        std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4).max(1);
    println!("=== ablation 4: persistent pool vs spawn-per-block (x{threads}, k={k}) ===");
    let mut sizes = Vec::new();
    let mut s = mn.saturating_sub(k);
    while s >= k {
        sizes.push(s);
        s -= k;
    }
    if sizes.is_empty() {
        println!("-> DLA_MN too small for a trailing sweep; skipping");
        return;
    }
    let total_flops: f64 = sizes.iter().map(|&s| 2.0 * (s * s * k) as f64).sum();
    let cfg_for = |s: usize| {
        let d = GemmDims::new(s, s, k);
        GemmConfig { mk, ccp: refined_ccp(&arch, mk, d).clamp_to(d) }
    };
    let pool = WorkerPool::new(threads);
    let mut g4 = BenchGroup::new("pool vs spawn-per-block (trailing sweep)");
    let pooled = g4
        .case(&format!("pooled x{threads} G4"), total_flops, || {
            for &s in &sizes {
                let cfg = cfg_for(s);
                let mut cv = c.sub_mut(0, 0, s, s);
                gemm_parallel(
                    &cfg, &kernel, 1.0, a.sub(0, 0, s, k), b.sub(0, 0, k, s), 0.0, &mut cv,
                    ParallelLoop::G4, &pool,
                );
            }
        })
        .clone();
    let mut ws_spawn = Workspace::new();
    let spawning = g4
        .case(&format!("spawn-per-block x{threads} (seed path)"), total_flops, || {
            for &s in &sizes {
                let cfg = cfg_for(s);
                let mut cv = c.sub_mut(0, 0, s, s);
                gemm_parallel_spawning(
                    &cfg, &kernel, 1.0, a.sub(0, 0, s, k), b.sub(0, 0, k, s), 0.0, &mut cv,
                    threads, &mut ws_spawn,
                );
            }
        })
        .clone();
    g4.finish("bench_ablation_pool");
    assert_eq!(
        pool.spawned_workers(),
        threads.saturating_sub(1),
        "pool must never respawn workers"
    );

    // Config-selection memo accounting over the same sweep, engine-driven.
    let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined);
    for _ in 0..2 {
        for &s in &sizes {
            let mut cv = c.sub_mut(0, 0, s, s);
            eng.gemm(1.0, a.sub(0, 0, s, k), b.sub(0, 0, k, s), 0.0, &mut cv);
        }
    }
    let stats = eng.config_cache_stats();

    let mut j = JsonBench::new("gemm trailing-update sweep (m=n shrinking, k=b)");
    j.entry(
        "pooled_g4",
        &[
            ("threads", threads as f64),
            ("mean_seconds", pooled.measurement.mean_s),
            ("min_seconds", pooled.measurement.min_s),
            ("gflops", pooled.gflops()),
        ],
    );
    j.entry(
        "spawn_per_block",
        &[
            ("threads", threads as f64),
            ("mean_seconds", spawning.measurement.mean_s),
            ("min_seconds", spawning.measurement.min_s),
            ("gflops", spawning.gflops()),
        ],
    );
    j.entry(
        "pooled_speedup_vs_spawn",
        &[("mean", spawning.measurement.mean_s / pooled.measurement.mean_s)],
    );
    j.entry(
        "config_cache",
        &[("hits", stats.hits as f64), ("misses", stats.misses as f64)],
    );

    // --- 5. lookahead on/off blocked LU --------------------------------
    // The fused pipeline vs the serialized panel/update path, per matrix
    // order, with the pool idle counters (leader drain-wait + between-job
    // parked time) that the lookahead exists to shrink. DLA_LU_SIZES
    // overrides the sweep (comma-separated orders), DLA_LU_BLOCK the
    // algorithmic block size.
    let lu_sizes: Vec<usize> = std::env::var("DLA_LU_SIZES")
        .ok()
        .map(|v| v.split(',').filter_map(|t| t.trim().parse().ok()).collect())
        .filter(|v: &Vec<usize>| !v.is_empty())
        .unwrap_or_else(|| vec![512, 1024, 2048]);
    let lu_block: usize =
        std::env::var("DLA_LU_BLOCK").ok().and_then(|v| v.parse().ok()).unwrap_or(128);
    println!("=== ablation 5: lookahead on/off blocked LU (x{threads}, b={lu_block}) ===");
    let mut g5 = BenchGroup::new("lookahead on/off blocked LU");
    for &s in &lu_sizes {
        let mut rng_lu = Pcg64::seed(s as u64);
        let a0 = MatrixF64::random_diag_dominant(s, &mut rng_lu);
        // Per-iteration component profile for context: the panel getf2
        // cost the serialized path pays between pooled jobs (measured on
        // the shrinking panel sequence of the first factorization).
        let panel_estimate = {
            let sw = Stopwatch::start();
            let mut a = a0.clone();
            let mut k = 0;
            while k < s {
                let b = lu_block.min(s - k);
                let mut panel = a.sub_mut(k, k, s - k, b);
                let mut piv = vec![0usize; b];
                let _ = getf2(&mut panel, &mut piv);
                k += b;
            }
            sw.elapsed_secs()
        };
        for la_on in [false, true] {
            let label = if la_on { "lookahead" } else { "serialized" };
            let la = if la_on {
                Lookahead { depth: 1, panel_workers: (threads / 8).max(1) }
            } else {
                Lookahead::disabled()
            };
            let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined)
                .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
                .with_lookahead(la);
            let pool_stats_before = eng.pool().map(|p| p.stats()).unwrap_or_default();
            let case = g5
                .case(&format!("lu {s} b={lu_block} {label} x{threads}"), lu_flops(s), || {
                    let mut a = a0.clone();
                    lu_blocked(&mut a, lu_block, &mut eng).expect("diag-dominant LU");
                })
                .clone();
            let pool_stats = eng.pool().map(|p| p.stats()).unwrap_or_default();
            let d_wait = pool_stats.leader_wait_ns.saturating_sub(pool_stats_before.leader_wait_ns);
            let d_idle = pool_stats.idle_ns.saturating_sub(pool_stats_before.idle_ns);
            let d_jobs = pool_stats.jobs.saturating_sub(pool_stats_before.jobs);
            j.entry(
                &format!("lu_lookahead_n{s}_{}", if la_on { "on" } else { "off" }),
                &[
                    ("threads", threads as f64),
                    ("block", lu_block as f64),
                    ("lookahead", if la_on { 1.0 } else { 0.0 }),
                    ("mean_seconds", case.measurement.mean_s),
                    ("min_seconds", case.measurement.min_s),
                    ("gflops", case.gflops()),
                    ("panel_getf2_estimate_seconds", panel_estimate),
                    ("pool_jobs", d_jobs as f64),
                    ("pool_leader_wait_ms", d_wait as f64 / 1e6),
                    ("pool_idle_ms", d_idle as f64 / 1e6),
                ],
            );
        }
    }
    g5.finish("bench_ablation_lookahead");

    // --- 6. static depth-1 vs dynamic deep vs malleable t_p ------------
    // The work-queue pipeline against PR 2's static arm on the same
    // blocked-LU sweep. Idle deltas are split per phase: total pool idle
    // (leader-wait + between-job) plus the split-job rejoin buckets
    // (panel idle / update idle / queue-empty stalls, in rank-ms).
    println!("=== ablation 6: static vs dynamic deep lookahead (x{threads}, b={lu_block}) ===");
    let static_tp = (threads / 8).max(1);
    let arms: [(&str, Lookahead); 4] = [
        ("static_d1", Lookahead { depth: 1, panel_workers: static_tp }),
        ("dynamic_d2", Lookahead { depth: 2, panel_workers: static_tp }),
        ("dynamic_d3", Lookahead { depth: 3, panel_workers: static_tp }),
        ("dynamic_d2_malleable", Lookahead { depth: 2, panel_workers: AUTO_PANEL_WORKERS }),
    ];
    let mut g6 = BenchGroup::new("static vs dynamic deep lookahead blocked LU");
    for &s in &lu_sizes {
        let mut rng_lu = Pcg64::seed(s as u64);
        let a0 = MatrixF64::random_diag_dominant(s, &mut rng_lu);
        let mut arm_idle_ms: Vec<(String, f64)> = Vec::new();
        for (label, la) in arms {
            let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined)
                .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
                .with_lookahead(la);
            let before = eng.pool().map(|p| p.stats()).unwrap_or_default();
            let case = g6
                .case(&format!("lu {s} b={lu_block} {label} x{threads}"), lu_flops(s), || {
                    let mut a = a0.clone();
                    lu_blocked(&mut a, lu_block, &mut eng).expect("diag-dominant LU");
                })
                .clone();
            let after = eng.pool().map(|p| p.stats()).unwrap_or_default();
            let tstats = eng.team_size_cache_stats();
            let d = |x: u64, y: u64| x.saturating_sub(y) as f64 / 1e6;
            let total_idle_ms =
                d(after.leader_wait_ns, before.leader_wait_ns) + d(after.idle_ns, before.idle_ns);
            arm_idle_ms.push((label.to_string(), total_idle_ms));
            j.entry(
                &format!("lu_deep_lookahead_n{s}_{label}"),
                &[
                    ("threads", threads as f64),
                    ("block", lu_block as f64),
                    ("depth", la.depth as f64),
                    ("malleable_tp", if la.panel_workers == AUTO_PANEL_WORKERS { 1.0 } else { 0.0 }),
                    ("mean_seconds", case.measurement.mean_s),
                    ("min_seconds", case.measurement.min_s),
                    ("gflops", case.gflops()),
                    ("pool_jobs", after.jobs.saturating_sub(before.jobs) as f64),
                    ("pool_total_idle_ms", total_idle_ms),
                    ("pool_leader_wait_ms", d(after.leader_wait_ns, before.leader_wait_ns)),
                    ("pool_between_job_idle_ms", d(after.idle_ns, before.idle_ns)),
                    ("panel_idle_rank_ms", d(after.panel_idle_ns, before.panel_idle_ns)),
                    ("update_idle_rank_ms", d(after.update_idle_ns, before.update_idle_ns)),
                    ("queue_stall_rank_ms", d(after.queue_stall_ns, before.queue_stall_ns)),
                    ("teamsize_cache_hits", tstats.hits as f64),
                    ("teamsize_cache_misses", tstats.misses as f64),
                ],
            );
        }
        let base_idle = arm_idle_ms[0].1;
        for (label, idle) in &arm_idle_ms[1..] {
            println!(
                "  n={s}: {label} total idle {idle:.3} ms vs static_d1 {base_idle:.3} ms \
                 ({}{:.3} ms)",
                if *idle <= base_idle { "-" } else { "+" },
                (idle - base_idle).abs()
            );
        }
    }
    g6.finish("bench_ablation_deep_lookahead");

    // --- 7. batched vs serialized server throughput --------------------
    // A small-GEMM request mix through the coordinator server: the batch
    // scheduler coalesces shape-bucketed requests into fused pool epochs
    // vs the serialized baseline where every request runs one whole pool
    // dispatch under the leader lock. DLA_BATCH_REQS overrides the mix
    // size.
    let nreq: usize =
        std::env::var("DLA_BATCH_REQS").ok().and_then(|v| v.parse().ok()).unwrap_or(240);
    println!("=== ablation 7: batched vs serialized server ({nreq} small GEMMs, x{threads}) ===");
    let shapes: [(usize, usize, usize); 3] = [(48, 48, 32), (32, 64, 16), (64, 32, 24)];
    let mix_flops: f64 = (0..nreq)
        .map(|i| {
            let (m, n, kk) = shapes[i % shapes.len()];
            2.0 * (m * n * kk) as f64
        })
        .sum();
    let mut g7 = BenchGroup::new("batched vs serialized server (small-GEMM mix)");
    for batched in [false, true] {
        let label = if batched { "batched" } else { "serialized" };
        let policy = if batched {
            BatchPolicy::default().admit_all()
        } else {
            BatchPolicy::disabled()
        };
        let server = CoordinatorServer::start(
            ServerConfig::new(arch.clone(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(threads)
                .with_batching(policy),
        )
        .expect("server start");
        // One timed pass (no bench reps): the batch counters come from
        // the server's whole lifetime, so timing exactly one pass keeps
        // requests/dispatch counts/queue waits mutually consistent.
        let sw = Stopwatch::start();
        {
            let mut rng7 = Pcg64::seed(7);
            let mut pending = Vec::with_capacity(nreq);
            for i in 0..nreq {
                let (m, n, kk) = shapes[i % shapes.len()];
                pending.push(
                    server
                        .submit(DlaRequest::Gemm {
                            alpha: 1.0,
                            a: MatrixF64::random(m, kk, &mut rng7),
                            b: MatrixF64::random(kk, n, &mut rng7),
                            beta: 0.0,
                            c: MatrixF64::zeros(m, n),
                        })
                        .expect("submit"),
                );
            }
            for rx in pending {
                rx.recv().unwrap().unwrap();
            }
        }
        let secs = sw.elapsed_secs();
        g7.record(&format!("{label} x{threads} ({nreq} reqs)"), secs, mix_flops);
        let metrics = server.shutdown();
        let bm = metrics.batch_stats().clone();
        println!(
            "  {label}: {:.0} req/s, {} fused dispatches (mean size {:.2}), {} solo, \
             queue-wait mean {:.1} us",
            nreq as f64 / secs,
            bm.batches,
            bm.mean_batch_size(),
            bm.solo,
            bm.queue_wait_ns.mean() / 1e3,
        );
        j.entry(
            &format!("server_batching_{}", if batched { "on" } else { "off" }),
            &[
                ("threads", threads as f64),
                ("workers", 2.0),
                ("requests", nreq as f64),
                ("mean_seconds", secs),
                ("req_per_s", nreq as f64 / secs),
                ("gflops", mix_flops / secs / 1e9),
                ("fused_dispatches", bm.batches as f64),
                ("coalesced_requests", bm.coalesced_requests as f64),
                ("solo_dispatches", bm.solo as f64),
                ("mean_batch_size", bm.mean_batch_size()),
                ("queue_wait_mean_us", bm.queue_wait_ns.mean() / 1e3),
                ("queue_wait_max_us", bm.queue_wait_ns.max.max(0.0) / 1e3),
            ],
        );
    }
    g7.finish("bench_ablation_server_batching");

    // --- 8. element width: f32 vs f64 GEMM, mixed vs plain-f64 solve ----
    // The dtype-generic stack's payoff, measured: (a) the same GEMM in
    // f32 vs f64 through the same engine (f32 gets 2x SIMD lanes and the
    // model's doubled cache params), and (b) the mixed-precision LU
    // solve (factor f32 + refine to f64 residual accuracy) vs the plain
    // f64 factor+solve, per matrix order. Appended to BENCH_gemm.json
    // alongside ablations 4-7.
    println!("=== ablation 8: f32 vs f64 GEMM + mixed-precision LU solve (x{threads}) ===");
    let mut g8 = BenchGroup::new("element width: f32 vs f64");
    {
        let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        let a32 = MatrixF32::convert_from(&a);
        let b32 = MatrixF32::convert_from(&b);
        let mut c32 = MatrixF32::zeros(mn, mn);
        let f64_case = g8
            .case(&format!("gemm f64 {mn}x{mn}x{k} x{threads}"), dims.flops(), || {
                eng.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
            })
            .clone();
        let f32_case = g8
            .case(&format!("gemm f32 {mn}x{mn}x{k} x{threads}"), dims.flops(), || {
                eng.gemm_f32(1.0, a32.view(), b32.view(), 0.0, &mut c32.view_mut());
            })
            .clone();
        let ratio = f64_case.measurement.mean_s / f32_case.measurement.mean_s;
        println!(
            "  f32 {:.2} GFLOPS vs f64 {:.2} GFLOPS ({ratio:.2}x)",
            f32_case.gflops(),
            f64_case.gflops()
        );
        j.entry(
            "dtype_gemm_f32_vs_f64",
            &[
                ("threads", threads as f64),
                ("mn", mn as f64),
                ("k", k as f64),
                ("f64_gflops", f64_case.gflops()),
                ("f32_gflops", f32_case.gflops()),
                ("f32_speedup", ratio),
            ],
        );
    }
    for &s in &lu_sizes {
        let mut rng8 = Pcg64::seed(s as u64 ^ 0x5eed);
        let a0 = MatrixF64::random_diag_dominant(s, &mut rng8);
        let rhs = MatrixF64::random(s, 1, &mut rng8);
        let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        let sw = Stopwatch::start();
        let x64 = lu_solve_f64(&a0, &rhs, lu_block, &mut eng).expect("diag-dominant solve");
        let t_f64 = sw.elapsed_secs();
        let opts = RefineOptions { block: lu_block, ..Default::default() };
        let sw = Stopwatch::start();
        let res = lu_solve_mixed(&a0, &rhs, &opts, &mut eng).expect("diag-dominant mixed solve");
        let t_mixed = sw.elapsed_secs();
        assert!(res.x.max_abs_diff(&x64) < 1e-6, "mixed and f64 answers must agree");
        println!(
            "  n={s}: mixed {:.4}s ({} iters, fallback={}) vs f64 {:.4}s ({:.2}x)",
            t_mixed,
            res.iterations,
            res.fell_back,
            t_f64,
            t_f64 / t_mixed
        );
        g8.record(&format!("lu solve f64 n={s} b={lu_block} x{threads}"), t_f64, lu_flops(s));
        g8.record(&format!("lu solve mixed n={s} b={lu_block} x{threads}"), t_mixed, lu_flops(s));
        j.entry(
            &format!("mixed_lu_solve_n{s}"),
            &[
                ("threads", threads as f64),
                ("block", lu_block as f64),
                ("f64_solve_seconds", t_f64),
                ("mixed_solve_seconds", t_mixed),
                ("mixed_speedup", t_f64 / t_mixed),
                ("refine_iters", res.iterations as f64),
                ("fell_back", if res.fell_back { 1.0 } else { 0.0 }),
                ("f32_factor_seconds", res.f32_factor_seconds),
                ("refine_seconds", res.refine_seconds),
                ("residual", res.residual),
            ],
        );
    }
    g8.finish("bench_ablation_dtype");

    // --- 9. ABFT overhead: checksum-verified vs plain GEMM + LU --------
    // The robustness tax, measured: the same GEMM and blocked LU with
    // `VerifyPolicy::Detect` armed (checksummed packing + the macro-block
    // verification epilogue + LU panel re-verification) vs verification
    // off. Detect mode with no fault firing is bitwise identical to the
    // plain path, so the delta is pure checksum work; the target from
    // the ABFT literature — and this stack's acceptance bar — is <= 10%.
    // Appended to the same BENCH_gemm.json.
    println!("=== ablation 9: ABFT overhead, verified vs plain (x{threads}) ===");
    let mut g9 = BenchGroup::new("abft: verified vs plain");
    {
        let mut plain = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        let mut verified = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
            .with_verify(VerifyPolicy::Detect);
        let mut c9 = MatrixF64::zeros(mn, mn);
        let base = g9
            .case(&format!("gemm plain {mn}x{mn}x{k} x{threads}"), dims.flops(), || {
                plain.gemm(1.0, a.view(), b.view(), 0.0, &mut c9.view_mut());
            })
            .clone();
        let checked = g9
            .case(&format!("gemm verified {mn}x{mn}x{k} x{threads}"), dims.flops(), || {
                verified.gemm(1.0, a.view(), b.view(), 0.0, &mut c9.view_mut());
            })
            .clone();
        let stats = verified.abft_stats().snapshot();
        assert_eq!(stats.detected, 0, "no fault armed: the bench must verify clean");
        let overhead = checked.measurement.mean_s / base.measurement.mean_s - 1.0;
        println!(
            "  gemm: verified {:.2} GFLOPS vs plain {:.2} GFLOPS ({:+.2}% overhead)",
            checked.gflops(),
            base.gflops(),
            overhead * 100.0
        );
        j.entry(
            "abft_gemm_overhead",
            &[
                ("threads", threads as f64),
                ("mn", mn as f64),
                ("k", k as f64),
                ("plain_gflops", base.gflops()),
                ("verified_gflops", checked.gflops()),
                ("overhead_frac", overhead),
                ("verified_epochs", stats.verified_epochs as f64),
                ("verified_blocks", stats.verified_blocks as f64),
                ("checksum_work_ms", stats.overhead_ns as f64 / 1e6),
            ],
        );
    }
    for &s in &lu_sizes {
        let mut rng9 = Pcg64::seed(s as u64 ^ 0xabf7);
        let a0 = MatrixF64::random_diag_dominant(s, &mut rng9);
        let mut plain = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        let mut verified = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
            .with_verify(VerifyPolicy::Detect);
        let base = g9
            .case(&format!("lu plain n={s} b={lu_block} x{threads}"), lu_flops(s), || {
                let mut m = a0.clone();
                lu_blocked(&mut m, lu_block, &mut plain).expect("diag-dominant LU");
            })
            .clone();
        let checked = g9
            .case(&format!("lu verified n={s} b={lu_block} x{threads}"), lu_flops(s), || {
                let mut m = a0.clone();
                lu_blocked(&mut m, lu_block, &mut verified).expect("diag-dominant LU");
            })
            .clone();
        let stats = verified.abft_stats().snapshot();
        assert_eq!(stats.detected, 0, "no fault armed: the bench must verify clean");
        let overhead = checked.measurement.mean_s / base.measurement.mean_s - 1.0;
        println!(
            "  lu n={s}: verified {:.4}s vs plain {:.4}s ({:+.2}% overhead)",
            checked.measurement.mean_s,
            base.measurement.mean_s,
            overhead * 100.0
        );
        j.entry(
            &format!("abft_lu_overhead_n{s}"),
            &[
                ("threads", threads as f64),
                ("block", lu_block as f64),
                ("plain_seconds", base.measurement.mean_s),
                ("verified_seconds", checked.measurement.mean_s),
                ("overhead_frac", overhead),
                ("verified_epochs", stats.verified_epochs as f64),
                ("checksum_work_ms", stats.overhead_ns as f64 / 1e6),
            ],
        );
    }
    g9.finish("bench_ablation_abft");

    // --- 10. fused lookahead vs tile-DAG dataflow scheduler ------------
    // The same blocked LU and Cholesky sweep under the two schedulers on
    // the same persistent pool. The lookahead arm pays its fused-rejoin
    // waits in the per-phase buckets (panel/update idle, queue stalls);
    // the DAG arm has no rejoin at all — its phase buckets stay zero by
    // construction and the steal counters show how the deques kept the
    // ranks fed instead. Results are bitwise identical between arms
    // (tests/dag.rs), so the delta is pure scheduling.
    println!("=== ablation 10: fused lookahead vs tile-DAG scheduler (x{threads}, b={lu_block}) ===");
    let mut g10 = BenchGroup::new("lookahead vs tile-DAG factorizations");
    let sched_arms: [(&str, SchedPolicy); 2] =
        [("lookahead", SchedPolicy::Lookahead), ("dag", SchedPolicy::Dag)];
    for &s in &lu_sizes {
        let mut rng10 = Pcg64::seed(s as u64 ^ 0xda6);
        let a0 = MatrixF64::random_diag_dominant(s, &mut rng10);
        // SPD input for the Cholesky arm: M Mᵀ + s I.
        let spd = {
            let m = MatrixF64::random(s, s, &mut rng10);
            let mt = m.transposed();
            let mut sym = MatrixF64::zeros(s, s);
            gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut sym.view_mut());
            for i in 0..s {
                sym[(i, i)] += s as f64;
            }
            sym
        };
        for (label, sched) in sched_arms {
            let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined)
                .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
                .with_lookahead(Lookahead { depth: 1, panel_workers: (threads / 8).max(1) })
                .with_sched(sched);
            let d = |x: u64, y: u64| x.saturating_sub(y) as f64 / 1e6;
            for (kind, flops) in [("lu", lu_flops(s)), ("chol", (s * s * s) as f64 / 3.0)] {
                let before = eng.pool().map(|p| p.stats()).unwrap_or_default();
                let case = g10
                    .case(&format!("{kind} {s} b={lu_block} {label} x{threads}"), flops, || {
                        match kind {
                            "lu" => {
                                let mut m = a0.clone();
                                lu_blocked(&mut m, lu_block, &mut eng).expect("diag-dominant LU");
                            }
                            _ => {
                                let mut m = spd.clone();
                                cholesky_blocked(&mut m, lu_block, &mut eng).expect("SPD Cholesky");
                            }
                        }
                    })
                    .clone();
                let after = eng.pool().map(|p| p.stats()).unwrap_or_default();
                j.entry(
                    &format!("sched_{kind}_n{s}_{label}"),
                    &[
                        ("threads", threads as f64),
                        ("block", lu_block as f64),
                        ("dag", if matches!(sched, SchedPolicy::Dag) { 1.0 } else { 0.0 }),
                        ("mean_seconds", case.measurement.mean_s),
                        ("min_seconds", case.measurement.min_s),
                        ("gflops", case.gflops()),
                        ("pool_jobs", after.jobs.saturating_sub(before.jobs) as f64),
                        ("pool_leader_wait_ms", d(after.leader_wait_ns, before.leader_wait_ns)),
                        ("pool_between_job_idle_ms", d(after.idle_ns, before.idle_ns)),
                        ("panel_idle_rank_ms", d(after.panel_idle_ns, before.panel_idle_ns)),
                        ("update_idle_rank_ms", d(after.update_idle_ns, before.update_idle_ns)),
                        ("queue_stall_rank_ms", d(after.queue_stall_ns, before.queue_stall_ns)),
                        ("dag_tasks", after.dag_tasks.saturating_sub(before.dag_tasks) as f64),
                        ("dag_steals", after.dag_steals.saturating_sub(before.dag_steals) as f64),
                        (
                            "dag_steal_fails",
                            after.dag_steal_fails.saturating_sub(before.dag_steal_fails) as f64,
                        ),
                        ("dag_deque_high_water", after.dag_deque_high_water as f64),
                    ],
                );
                let rejoin_ms = d(after.panel_idle_ns, before.panel_idle_ns)
                    + d(after.update_idle_ns, before.update_idle_ns)
                    + d(after.queue_stall_ns, before.queue_stall_ns);
                println!(
                    "  {kind} n={s} {label}: {:.2} GFLOPS, rejoin idle {rejoin_ms:.3} rank-ms, \
                     {} tasks / {} steals",
                    case.gflops(),
                    after.dag_tasks.saturating_sub(before.dag_tasks),
                    after.dag_steals.saturating_sub(before.dag_steals),
                );
            }
        }
    }
    g10.finish("bench_ablation_sched");

    // --- 11. analytic-only vs measurement-calibrated selection ---------
    // Calibration off is bitwise-identical selection (tests/calibration),
    // so any delta here is the measured-truth re-ranking plus the
    // warm-state pack discount actually paying for themselves. Two
    // repeated-shape workloads where the store has time to get hot; the
    // acceptance bar is match-or-beat on both.
    println!("=== ablation 11: analytic-only vs calibrated selection (x{threads}) ===");
    let mut g11 = BenchGroup::new("analytic vs calibrated selection");
    // (a) The factorization hot sequence: trailing updates m = n
    // shrinking at a skinny fixed k — the shape class the warm-state
    // discount targets (the k-panel stays resident between updates).
    let calib_k = 64usize;
    let mut trail = Vec::new();
    let mut st = mn.saturating_sub(calib_k);
    while st >= calib_k {
        trail.push(st);
        st -= calib_k;
    }
    let trail_flops: f64 = trail.iter().map(|&s| 2.0 * (s * s * calib_k) as f64).sum();
    let mut trail_secs = [0.0f64; 2];
    for calibrated in [false, true] {
        let label = if calibrated { "calibrated" } else { "analytic" };
        let mut eng = GemmEngine::new(arch.clone(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        let profile = calibrated.then(|| Arc::new(PerfProfile::new()));
        if let Some(p) = &profile {
            eng.set_calibration(Some(Arc::clone(p)));
        }
        // Two untimed warm passes for both arms: the analytic arm warms
        // its config memo, the calibrated arm additionally records its
        // first measurements so the timed passes run on a hot store.
        for _ in 0..2 {
            for &s in &trail {
                let mut cv = c.sub_mut(0, 0, s, s);
                eng.gemm(1.0, a.sub(0, 0, s, calib_k), b.sub(0, 0, calib_k, s), 0.0, &mut cv);
            }
        }
        let case = g11
            .case(&format!("trailing sweep {label} k={calib_k} x{threads}"), trail_flops, || {
                for &s in &trail {
                    let mut cv = c.sub_mut(0, 0, s, s);
                    eng.gemm(1.0, a.sub(0, 0, s, calib_k), b.sub(0, 0, calib_k, s), 0.0, &mut cv);
                }
            })
            .clone();
        trail_secs[calibrated as usize] = case.measurement.mean_s;
        let ps = profile.as_ref().map(|p| p.stats()).unwrap_or_default();
        j.entry(
            &format!("calib_trailing_{label}"),
            &[
                ("threads", threads as f64),
                ("k", calib_k as f64),
                ("updates", trail.len() as f64),
                ("mean_seconds", case.measurement.mean_s),
                ("min_seconds", case.measurement.min_s),
                ("gflops", case.gflops()),
                ("observations", ps.observations as f64),
                ("store_entries", ps.entries as f64),
                ("blended", ps.blended as f64),
                ("explorations", ps.explorations as f64),
            ],
        );
    }
    println!(
        "  trailing sweep: calibrated {:.4}s vs analytic {:.4}s ({:.3}x)",
        trail_secs[1],
        trail_secs[0],
        trail_secs[0] / trail_secs[1]
    );
    j.entry("calib_trailing_speedup", &[("mean", trail_secs[0] / trail_secs[1])]);
    // (b) The repeated-shape serving mix of ablation 7 (batching pinned
    // off in both arms so the delta is selection, not coalescing): the
    // calibrated server learns from its own request stream mid-run.
    let mut serve_secs = [0.0f64; 2];
    for calibrated in [false, true] {
        let label = if calibrated { "calibrated" } else { "analytic" };
        let policy = if calibrated { CalibratePolicy::On } else { CalibratePolicy::Off };
        let server = CoordinatorServer::start(
            ServerConfig::new(arch.clone(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(threads)
                .with_batching(BatchPolicy::disabled())
                .with_calibration(policy),
        )
        .expect("server start");
        let sw = Stopwatch::start();
        {
            let mut rng11 = Pcg64::seed(11);
            let mut pending = Vec::with_capacity(nreq);
            for i in 0..nreq {
                let (m, n, kk) = shapes[i % shapes.len()];
                pending.push(
                    server
                        .submit(DlaRequest::Gemm {
                            alpha: 1.0,
                            a: MatrixF64::random(m, kk, &mut rng11),
                            b: MatrixF64::random(kk, n, &mut rng11),
                            beta: 0.0,
                            c: MatrixF64::zeros(m, n),
                        })
                        .expect("submit"),
                );
            }
            for rx in pending {
                rx.recv().unwrap().unwrap();
            }
        }
        let secs = sw.elapsed_secs();
        serve_secs[calibrated as usize] = secs;
        g11.record(&format!("serve {label} x{threads} ({nreq} reqs)"), secs, mix_flops);
        let metrics = server.shutdown();
        let cs = *metrics.calibration_stats();
        println!(
            "  serve {label}: {:.0} req/s, {} observations ({} store entries), {} blended",
            nreq as f64 / secs,
            cs.observations,
            cs.store_entries,
            cs.blended,
        );
        j.entry(
            &format!("calib_serving_{label}"),
            &[
                ("threads", threads as f64),
                ("workers", 2.0),
                ("requests", nreq as f64),
                ("mean_seconds", secs),
                ("req_per_s", nreq as f64 / secs),
                ("gflops", mix_flops / secs / 1e9),
                ("observations", cs.observations as f64),
                ("store_entries", cs.store_entries as f64),
                ("blended", cs.blended as f64),
                ("explorations", cs.explorations as f64),
                ("config_hits", cs.config_hits as f64),
                ("config_misses", cs.config_misses as f64),
            ],
        );
    }
    j.entry("calib_serving_speedup", &[("mean", serve_secs[0] / serve_secs[1])]);
    g11.finish("bench_ablation_calibration");

    match j.write("BENCH_gemm.json") {
        Ok(()) => println!(
            "-> BENCH_gemm.json written: pooled {:.2}x vs spawn-per-block at x{threads}, \
             + lookahead on/off LU sweep for n in {lu_sizes:?}",
            spawning.measurement.mean_s / pooled.measurement.mean_s
        ),
        Err(e) => eprintln!("warning: could not write BENCH_gemm.json: {e}"),
    }
}
