//! Cache-simulator throughput bench (DESIGN.md §Perf target:
//! >= 50 M line-accesses/s) plus a GEMM-trace replay cost profile.
use dla_codesign::arch::carmel;
use dla_codesign::bench::BenchGroup;
use dla_codesign::cachesim::Hierarchy;
use dla_codesign::harness::cfg_mod;
use dla_codesign::model::{GemmDims, MicroKernel};
use dla_codesign::trace::{simulate_gemm, TraceOptions};
use dla_codesign::util::Pcg64;

fn main() {
    println!("=== exp_cachesim ===");
    let arch = carmel();
    let mut g = BenchGroup::new("cache simulator");
    // Raw access throughput: streaming + random mixes.
    let n_acc = 2_000_000u64;
    let mut h = Hierarchy::new(&arch);
    g.case("stream 2M line accesses", n_acc as f64, || {
        for i in 0..n_acc {
            h.access_line(i * 64 % (8 * 1024 * 1024));
        }
    });
    let mut h2 = Hierarchy::new(&arch);
    let mut rng = Pcg64::seed(3);
    let addrs: Vec<u64> = (0..n_acc).map(|_| rng.next_below(64 * 1024 * 1024)).collect();
    g.case("random 2M line accesses", n_acc as f64, || {
        for &a in &addrs {
            h2.access_line(a);
        }
    });
    // Full GEMM trace replay (the fig11 hit-ratio workload).
    let dims = GemmDims::new(1000, 1000, 96);
    let cfg = cfg_mod(&arch, MicroKernel::new(6, 8), dims);
    g.case("gemm trace 1000x1000x96 sampled", dims.flops(), || {
        let _ = simulate_gemm(&arch, dims, &cfg, TraceOptions::sampled(), false);
    });
    g.finish("bench_cachesim");
    eprintln!("note: 'GFLOPS' column = accesses/s * 1e-9 for the access cases");
}
