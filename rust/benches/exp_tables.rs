//! Bench target for Tables 1-2 + Figure 6 (left): pure-model table
//! regeneration (timed for completeness; the content is the deliverable).
use dla_codesign::bench::BenchGroup;
use dla_codesign::harness::tables;

fn main() {
    println!("=== exp_tables: Tables 1, 2 and Figure 6 (left) ===");
    tables::run();
    let mut g = BenchGroup::new("table regeneration cost");
    g.case("table1+table2+fig6left", 0.0, || {
        let _ = tables::table1().render();
        let _ = tables::table2().render();
        let _ = tables::fig6_left().render();
    });
    g.finish("bench_tables");
}
