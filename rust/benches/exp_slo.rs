//! Open-loop SLO stress harness for the QoS serving path.
//!
//! Drives the coordinator server with sustained open-loop load at a
//! multiple of its calibrated capacity (default 2×) — mixed problem
//! shapes, mixed dtypes (f64 + f32 GEMMs), mixed priority tiers
//! (~50% Interactive / 30% Batch / 20% Background) — through the async
//! submit API, and reports per-tier latency percentiles (p50/p95/p99),
//! shed/reject rates, and the server's own QoS ledger. Unlike the
//! closed-loop ablation benches, arrivals do not wait for completions:
//! overload actually accumulates queue delay, so the adaptive shedder
//! and the per-tier retry budgets are exercised for real.
//!
//! Knobs: `DLA_THREADS` (pool width, default 4), `DLA_SLO_REQS` (total
//! requests, default 600), `DLA_SLO_RATE_X` (offered-load multiple of
//! calibrated capacity, default 2.0). Results append to the
//! `BENCH_gemm.json` trend file (see ROADMAP).

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use dla_codesign::arch::detect_host;
use dla_codesign::bench::JsonBench;
use dla_codesign::coordinator::{
    CoordinatorServer, DlaError, DlaRequest, JobHandle, Priority, ServerConfig,
};
use dla_codesign::gemm::ConfigMode;
use dla_codesign::runtime::FaultPlan;
use dla_codesign::util::{MatrixF32, MatrixF64, Pcg64};

/// Percentile of an ascending-sorted slice (nearest-rank).
fn pct(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// The mixed-size / mixed-dtype request generator: three small-GEMM
/// shapes, every fourth request in f32.
fn request(i: usize, rng: &mut Pcg64) -> DlaRequest {
    let shapes: [(usize, usize, usize); 3] = [(48, 48, 32), (32, 64, 16), (64, 32, 24)];
    let (m, n, k) = shapes[i % shapes.len()];
    if i % 4 == 3 {
        DlaRequest::GemmF32 {
            alpha: 1.0,
            a: MatrixF32::random(m, k, rng),
            b: MatrixF32::random(k, n, rng),
            beta: 0.0,
            c: MatrixF32::zeros(m, n),
        }
    } else {
        DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::random(m, k, rng),
            b: MatrixF64::random(k, n, rng),
            beta: 0.0,
            c: MatrixF64::zeros(m, n),
        }
    }
}

/// ~50/30/20 tier mix, deterministic in the request index.
fn tier_for(i: usize) -> Priority {
    match i % 10 {
        0..=4 => Priority::Interactive,
        5..=7 => Priority::Batch,
        _ => Priority::Background,
    }
}

fn main() {
    let arch = detect_host();
    let threads: usize =
        std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4).max(1);
    let nreq: usize =
        std::env::var("DLA_SLO_REQS").ok().and_then(|v| v.parse().ok()).unwrap_or(600).max(30);
    let rate_x: f64 = std::env::var("DLA_SLO_RATE_X")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|x: &f64| x.is_finite() && *x > 0.0)
        .unwrap_or(2.0);
    let workers = 2usize;

    // Pin the empty armed plan: a reproducible harness must not pick up
    // whatever DLA_FAULTS drill the environment has exported.
    let server = CoordinatorServer::start(
        ServerConfig::new(arch, ConfigMode::Refined)
            .with_workers(workers)
            .with_gemm_threads(threads)
            .with_faults(FaultPlan::parse("arm").expect("armed empty plan")),
    )
    .expect("server start");

    // --- calibrate capacity: sequential closed-loop service rate -------
    let mut rng = Pcg64::seed(90);
    let cal_n = 20;
    let sw = Instant::now();
    for i in 0..cal_n {
        server.call(request(i, &mut rng)).expect("calibration request");
    }
    let mean_service = sw.elapsed().as_secs_f64() / cal_n as f64;
    let capacity_rps = workers as f64 / mean_service;
    let offered_rps = rate_x * capacity_rps;
    let interval = std::time::Duration::from_secs_f64(1.0 / offered_rps);
    println!(
        "=== slo stress: {nreq} reqs open-loop at {offered_rps:.0} req/s \
         ({rate_x:.1}x of ~{capacity_rps:.0} req/s capacity, x{threads} pool, {workers} workers) ==="
    );

    // --- open-loop drive ------------------------------------------------
    // Per-tier collector threads wait on the async handles in submission
    // order, so a slow tier cannot inflate another tier's measured
    // latency.
    let mut txs = Vec::new();
    let mut collectors = Vec::new();
    for _ in Priority::ALL {
        let (tx, rx) = mpsc::channel::<(Instant, JobHandle)>();
        txs.push(tx);
        collectors.push(thread::spawn(move || {
            let mut lat_s: Vec<f64> = Vec::new();
            let mut failed = 0u64;
            for (t0, handle) in rx {
                match handle.wait() {
                    Ok(_) => lat_s.push(t0.elapsed().as_secs_f64()),
                    Err(_) => failed += 1,
                }
            }
            (lat_s, failed)
        }));
    }
    let mut client_shed = [0u64; 3];
    let mut client_rejected = [0u64; 3];
    let drive = Instant::now();
    for i in 0..nreq {
        let next_at = drive + interval.mul_f64(i as f64);
        // Open loop: pace arrivals on the clock, never on completions.
        loop {
            let now = Instant::now();
            if now >= next_at {
                break;
            }
            let ahead = next_at - now;
            if ahead > std::time::Duration::from_micros(200) {
                thread::sleep(ahead - std::time::Duration::from_micros(100));
            } else {
                std::hint::spin_loop();
            }
        }
        let tier = tier_for(i);
        let t0 = Instant::now();
        match server.submit_async_at(request(i, &mut rng), tier) {
            Ok(handle) => {
                let _ = txs[tier.index()].send((t0, handle));
            }
            Err(DlaError::Overloaded { .. }) => client_shed[tier.index()] += 1,
            Err(DlaError::QueueFull { .. }) | Err(DlaError::Timeout { .. }) => {
                client_rejected[tier.index()] += 1
            }
            Err(e) => panic!("unexpected admission error: {e:?}"),
        }
    }
    let drive_s = drive.elapsed().as_secs_f64();
    drop(txs);
    let mut per_tier: Vec<(Vec<f64>, u64)> = Vec::new();
    for c in collectors {
        per_tier.push(c.join().expect("collector thread"));
    }
    let drain_s = drive.elapsed().as_secs_f64();

    let metrics = server.shutdown();
    let qos = metrics.qos_stats();
    println!("{}", metrics.summary());

    // --- report ----------------------------------------------------------
    let mut j = JsonBench::new(
        "open-loop SLO stress (mixed shapes/dtypes/tiers at a capacity multiple)",
    );
    j.entry(
        "slo_open_loop",
        &[
            ("threads", threads as f64),
            ("workers", workers as f64),
            ("requests", nreq as f64),
            ("rate_multiple", rate_x),
            ("capacity_rps_estimate", capacity_rps),
            ("offered_rps", offered_rps),
            ("drive_seconds", drive_s),
            ("drain_seconds", drain_s),
        ],
    );
    for tier in Priority::ALL {
        let i = tier.index();
        let (mut lat, failed) = (per_tier[i].0.clone(), per_tier[i].1);
        lat.sort_by(f64::total_cmp);
        let us = |s: f64| s * 1e6;
        let p50 = us(pct(&lat, 0.50));
        let p95 = us(pct(&lat, 0.95));
        let p99 = us(pct(&lat, 0.99));
        let submitted = qos.submitted[i];
        let shed_rate = if submitted > 0 { qos.shed[i] as f64 / submitted as f64 } else { 0.0 };
        println!(
            "  {:<11} {:>4} completed / {:>4} submitted | p50 {:>9.0} us  p95 {:>9.0} us  \
             p99 {:>9.0} us | {} shed ({:.0}%), {} rejected, {} failed",
            tier.label(),
            lat.len(),
            submitted,
            p50,
            p95,
            p99,
            qos.shed[i],
            shed_rate * 100.0,
            qos.rejected[i],
            qos.failed[i] + failed,
        );
        j.entry(
            &format!("slo_tier_{}", tier.label()),
            &[
                ("submitted", submitted as f64),
                ("completed", qos.completed[i] as f64),
                ("shed", qos.shed[i] as f64),
                ("rejected", qos.rejected[i] as f64),
                ("failed", qos.failed[i] as f64),
                ("cancelled", qos.cancelled[i] as f64),
                ("shed_rate", shed_rate),
                ("p50_us", p50),
                ("p95_us", p95),
                ("p99_us", p99),
                ("client_shed_seen", client_shed[i] as f64),
                ("client_rejected_seen", client_rejected[i] as f64),
            ],
        );
    }
    assert!(
        qos.reconciles(),
        "the ledger must reconcile — no silent drops under overload: {qos:?}"
    );
    match j.write("BENCH_gemm.json") {
        Ok(()) => println!("-> BENCH_gemm.json written (per-tier SLO percentiles + shed rates)"),
        Err(e) => eprintln!("warning: could not write BENCH_gemm.json: {e}"),
    }
}
