//! Bench target regenerating Figure 11 (GEMM on EPYC + L2 hit ratio).
use dla_codesign::harness::{fig11, HarnessOpts};

fn main() {
    println!("=== exp_fig11 ===");
    let mut opts = HarnessOpts::default();
    opts.gemm_mn = std::env::var("DLA_MN").ok().and_then(|v| v.parse().ok()).unwrap_or(opts.gemm_mn);
    fig11::run(&opts, true);
}
