//! Bench target regenerating Figure 6 (occupancy + BLIS GFLOPS vs k).
use dla_codesign::harness::{fig6, HarnessOpts};

fn main() {
    println!("=== exp_fig6 ===");
    let mut opts = HarnessOpts::default();
    opts.gemm_mn = std::env::var("DLA_MN").ok().and_then(|v| v.parse().ok()).unwrap_or(opts.gemm_mn);
    fig6::run(&opts);
}
