//! Micro-kernel microbenchmarks: every registered kernel over packed
//! panels (the paper's §3.4 comparison at the smallest granularity),
//! plus an ablation of the prefetch variants.
use dla_codesign::bench::BenchGroup;
use dla_codesign::gemm::microkernel::registry;
use dla_codesign::gemm::packing::{pack_a, pack_b, packed_a_len, packed_b_len};
use dla_codesign::util::{MatrixF64, Pcg64};

fn main() {
    println!("=== exp_microkernels ===");
    let kc = 256;
    let reps_inner = 2000; // tiles per measured call to amortize timer cost
    let mut g = BenchGroup::new(&format!("micro-kernels, kc={kc}, {reps_inner} tiles/call"));
    for imp in registry() {
        let (mr, nr) = (imp.spec.mr, imp.spec.nr);
        let mut rng = Pcg64::seed(1);
        let a = MatrixF64::random(mr, kc, &mut rng);
        let b = MatrixF64::random(kc, nr, &mut rng);
        let mut c = MatrixF64::zeros(mr, nr);
        let mut abuf = vec![0.0; packed_a_len(mr, kc, mr)];
        let mut bbuf = vec![0.0; packed_b_len(kc, nr, nr)];
        pack_a(a.view(), &mut abuf, mr, 1.0);
        pack_b(b.view(), &mut bbuf, nr);
        let ldc = c.ld();
        let flops = 2.0 * (mr * nr * kc) as f64 * reps_inner as f64;
        g.case(imp.name, flops, || {
            for _ in 0..reps_inner {
                unsafe { (imp.func)(kc, abuf.as_ptr(), bbuf.as_ptr(), c.as_mut_ptr(), ldc) };
            }
        });
    }
    g.finish("bench_microkernels");
}
