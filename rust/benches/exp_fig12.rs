//! Bench target regenerating Figure 12 (LU on EPYC: sequential, G3, G4).
use dla_codesign::harness::{fig12, fig12::Panel, HarnessOpts};

fn main() {
    println!("=== exp_fig12 ===");
    let mut opts = HarnessOpts::default();
    opts.lu_s = std::env::var("DLA_LU_S").ok().and_then(|v| v.parse().ok()).unwrap_or(opts.lu_s);
    fig12::run(&opts, Panel::Sequential);
    fig12::run(&opts, Panel::ParallelG3);
    fig12::run(&opts, Panel::ParallelG4);
}
