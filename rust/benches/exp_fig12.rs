//! Bench target regenerating Figure 12 (LU on EPYC: sequential, G3, G4).
//!
//! Knobs: `DLA_LU_S` sets the measured host LU order; `DLA_THREADS=<n>`
//! runs the measured host trailing updates on an n-thread persistent
//! worker pool (loop G4) instead of sequentially — the pool is spawned
//! once per engine and reused across the whole b sweep.
use dla_codesign::harness::{fig12, fig12::Panel, HarnessOpts};

fn main() {
    println!("=== exp_fig12 ===");
    let defaults = HarnessOpts::default();
    let lu_s =
        std::env::var("DLA_LU_S").ok().and_then(|v| v.parse().ok()).unwrap_or(defaults.lu_s);
    let opts = HarnessOpts { lu_s, ..defaults };
    fig12::run(&opts, Panel::Sequential);
    fig12::run(&opts, Panel::ParallelG3);
    fig12::run(&opts, Panel::ParallelG4);
}
