//! Vendored, std-only subset of the `anyhow` error-handling API.
//!
//! The build environment for this repository has no network access to
//! crates.io, so the small slice of `anyhow` the crate actually uses is
//! re-implemented here: [`Error`], [`Result`], the [`Context`] extension
//! trait (for `Result` and `Option`), and the `anyhow!` / `bail!` /
//! `ensure!` macros. The API shapes follow the real crate so that
//! swapping in upstream `anyhow` is a one-line Cargo change.
//!
//! Like upstream, [`Error`] intentionally does **not** implement
//! `std::error::Error`; that is what lets the blanket
//! `impl<E: std::error::Error> From<E> for Error` coexist with the
//! identity `From<Error>` used by `?`.

use std::error::Error as StdError;
use std::fmt::{self, Debug, Display};

/// A dynamic error with a human-readable message and an optional source
/// chain (a drop-in for `anyhow::Error` within this workspace).
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

impl Error {
    /// Build an error from a plain message.
    pub fn msg<M: Display>(message: M) -> Self {
        Error { msg: message.to_string(), source: None }
    }

    /// Prepend a context message (what `.context(...)` does).
    fn wrap<C: Display>(self, context: C) -> Self {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The root cause, if a concrete source error was captured.
    pub fn source(&self) -> Option<&(dyn StdError + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        Display::fmt(&self.msg, f)
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut cause: Option<&dyn StdError> =
            self.source.as_deref().and_then(|e| e.source());
        if cause.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(c) = cause {
            write!(f, "\n    {c}")?;
            cause = c.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }
}

/// `anyhow::Result<T>`: a `Result` defaulting to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

mod ext {
    use super::*;

    /// Sealed helper that lets [`Context`] accept both concrete
    /// `std::error::Error` types and [`Error`] itself (the same trick
    /// upstream anyhow uses: `Error` is local and does not implement
    /// `std::error::Error`, so the two impls cannot overlap).
    pub trait IntoError {
        fn into_error(self) -> Error;
    }

    impl<E: StdError + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> Error {
            Error::from(self)
        }
    }

    impl IntoError for Error {
        fn into_error(self) -> Error {
            self
        }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: ext::IntoError> Context<T> for std::result::Result<T, E> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into_error().wrap(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().wrap(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($tok:tt)*) => {
        return Err($crate::anyhow!($($tok)*))
    };
}

/// Return early with an error when a condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($tok:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($tok)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<u32> {
        let n: u32 = "not-a-number".parse().context("parsing the knob")?;
        Ok(n)
    }

    #[test]
    fn context_wraps_and_chains() {
        let err = io_fail().unwrap_err();
        let text = format!("{err}");
        assert!(text.starts_with("parsing the knob:"), "got {text:?}");
        assert!(err.source().is_some());
    }

    #[test]
    fn option_context_and_macros() {
        let missing: Option<u32> = None;
        let err = missing.with_context(|| format!("slot {}", 7)).unwrap_err();
        assert_eq!(format!("{err}"), "slot 7");

        fn f(flag: bool) -> Result<()> {
            ensure!(flag, "flag was {}", flag);
            bail!("unreachable for true? no: always bails");
        }
        assert!(f(false).is_err());
        assert!(f(true).is_err());
        let e = anyhow!("code {code}", code = 3);
        assert_eq!(format!("{e}"), "code 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn g() -> Result<String> {
            let s = std::str::from_utf8(&[0xff, 0xfe])?;
            Ok(s.to_string())
        }
        assert!(g().is_err());
    }
}
