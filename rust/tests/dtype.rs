//! Dtype-parity and mixed-precision suite: the f32 instantiation of the
//! element-generic stack must (a) match an f32 reference within f32
//! tolerance through every driver — sequential blocked, pooled G3, pooled
//! G4, fused batch, lookahead LU — while staying **bitwise deterministic**
//! across team widths (the same determinism contract the f64 suite
//! asserts), and (b) the mixed-precision LU (factor f32, refine f64) must
//! reach f64-level residuals on well-conditioned systems and fall back
//! cleanly on ill-conditioned ones.
//!
//! `DLA_THREADS` widens the pooled legs (the CI matrix runs 1 and 4).

use dla_codesign::arch::host_xeon;
use dla_codesign::gemm::{
    gemm_reference, ConfigMode, GemmBatchItem, GemmEngine, Lookahead, ParallelLoop, ThreadPlan,
    AUTO_PANEL_WORKERS,
};
use dla_codesign::lapack::refine::{lu_solve_f64, lu_solve_mixed, RefineOptions};
use dla_codesign::lapack::{lu_factor_t, LuFactors};
use dla_codesign::model::GemmDims;
use dla_codesign::util::{DType, MatrixF32, MatrixF64, Pcg64};

fn threads_from_env() -> usize {
    std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(4).max(1)
}

fn engine(threads: usize, target: ParallelLoop) -> GemmEngine {
    let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    if threads > 1 {
        eng.with_plan(ThreadPlan { threads, target })
    } else {
        eng
    }
}

/// f32 GEMM through the engine: reference accuracy on every driver, and
/// bitwise equality between the sequential and every pooled width (the
/// drivers' determinism contract, now at f32).
#[test]
fn f32_gemm_parity_across_g3_g4_widths() {
    let threads = threads_from_env();
    let shapes = [(61usize, 53usize, 29usize), (96, 80, 40), (33, 17, 9)];
    for &(m, n, k) in &shapes {
        let mut rng = Pcg64::seed((m * 13 + n * 5 + k) as u64);
        let a = MatrixF32::random(m, k, &mut rng);
        let b = MatrixF32::random(k, n, &mut rng);
        let c0 = MatrixF32::random(m, n, &mut rng);
        let mut expect = c0.clone();
        gemm_reference(1.5f32, a.view(), b.view(), -0.5f32, &mut expect.view_mut());
        // Sequential engine result: the accuracy baseline and the
        // bitwise oracle for the pooled paths.
        let mut c_seq = c0.clone();
        let mut seq = engine(1, ParallelLoop::G4);
        seq.gemm_f32(1.5, a.view(), b.view(), -0.5, &mut c_seq.view_mut());
        assert!(
            c_seq.max_abs_diff(&expect) < 1e-4 * k as f64,
            "{m}x{n}x{k}: f32 blocked diverges from f32 reference"
        );
        for target in [ParallelLoop::G4, ParallelLoop::G3] {
            for t in [2usize, threads.max(2)] {
                let mut eng = engine(t, target);
                let mut c = c0.clone();
                eng.gemm_f32(1.5, a.view(), b.view(), -0.5, &mut c.view_mut());
                assert_eq!(
                    c.max_abs_diff(&c_seq),
                    0.0,
                    "{m}x{n}x{k} {target:?} x{t}: pooled f32 must be bitwise identical"
                );
            }
        }
    }
}

/// The model hands the f32 path larger cache params than the f64 path
/// for the same problem, and the config cache keys by dtype (two misses,
/// not one).
#[test]
fn f32_configs_are_larger_and_dtype_keyed() {
    use dla_codesign::model::MicroKernel;
    // Pinned kernel: the element-width effect isolated from kernel
    // choice — kc doubles outright at deep k (same L1, half the bytes
    // per element).
    let eng = GemmEngine::new(
        host_xeon(),
        ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6)),
    );
    let dims = GemmDims::new(2000, 2000, 2000);
    let c64 = eng.plan_config(dims);
    let c32 = eng.plan_config_t::<f32>(dims);
    assert_eq!(
        c32.ccp.kc,
        2 * c64.ccp.kc,
        "f32 kc must double f64 kc at equal (deep-k) dims: {c32} vs {c64}"
    );
    assert!(c32.ccp.mc >= c64.ccp.mc);
    let stats = eng.config_cache_stats();
    assert_eq!(stats.misses, 2, "same dims, two dtypes -> two cache entries: {stats:?}");
    assert_eq!(stats.hits, 0);
    // Repeat lookups hit per dtype.
    eng.plan_config(dims);
    eng.plan_config_t::<f32>(dims);
    assert_eq!(eng.config_cache_stats().hits, 2);
    assert_eq!(DType::F32.size_bytes(), 4);
    // Dynamic selection also picks a runnable, wider-lane family member.
    let dyn_eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let c32dyn = dyn_eng.plan_config_t::<f32>(dims);
    assert!(c32dyn.ccp.kc >= c64.ccp.kc, "{c32dyn} vs {c64}");
}

/// A kernel pinned for the f64 harness that has no f32 registry twin
/// (MK12x4) must not panic the f32 path: the engine falls back to the
/// width-aware dynamic selection, while f64 keeps the pin.
#[test]
fn f32_falls_back_when_pinned_kernel_has_no_f32_twin() {
    use dla_codesign::model::MicroKernel;
    let pinned = MicroKernel::new(12, 4);
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::RefinedWithKernel(pinned));
    let dims = GemmDims::new(40, 30, 20);
    assert_eq!(eng.plan_config(dims).mk, pinned, "f64 must honor the pin");
    let c32 = eng.plan_config_t::<f32>(dims);
    assert_ne!(c32.mk, pinned, "f32 must fall back off the f64-only shape");
    // And the full GEMM runs (no 'no f32 implementation' panic) and is
    // accurate.
    let mut rng = Pcg64::seed(12);
    let a = MatrixF32::random(40, 20, &mut rng);
    let b = MatrixF32::random(20, 30, &mut rng);
    let mut c = MatrixF32::zeros(40, 30);
    let mut expect = MatrixF32::zeros(40, 30);
    gemm_reference(1.0f32, a.view(), b.view(), 0.0f32, &mut expect.view_mut());
    eng.gemm_f32(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
    assert!(c.max_abs_diff(&expect) < 1e-4);
}

/// Batched f32 GEMMs: fused pool epochs must be bitwise identical to the
/// serial engine path per member (the f64 batching contract at f32).
#[test]
fn f32_batched_gemm_bitwise_matches_serial() {
    let threads = threads_from_env().max(2);
    let shapes = [(40usize, 24usize, 16usize), (24, 40, 8), (33, 17, 9), (40, 24, 16)];
    let coeffs = [(1.0f32, 0.0f32), (-1.0, 1.0), (0.5, -2.0), (2.0, 1.0)];
    let mut rng = Pcg64::seed(4242);
    let inputs: Vec<(MatrixF32, MatrixF32, MatrixF32)> = shapes
        .iter()
        .map(|&(m, n, k)| {
            (
                MatrixF32::random(m, k, &mut rng),
                MatrixF32::random(k, n, &mut rng),
                MatrixF32::random(m, n, &mut rng),
            )
        })
        .collect();
    // Serial reference: one request at a time.
    let mut refs = Vec::new();
    {
        let mut eng = engine(threads, ParallelLoop::G4);
        for ((a, b, c0), (alpha, beta)) in inputs.iter().zip(coeffs) {
            let mut c = c0.clone();
            eng.gemm_f32(alpha, a.view(), b.view(), beta, &mut c.view_mut());
            refs.push(c);
        }
    }
    for t in [1usize, threads] {
        let mut eng = engine(t, ParallelLoop::G4);
        let mut cs: Vec<MatrixF32> = inputs.iter().map(|(_, _, c0)| c0.clone()).collect();
        let mut items: Vec<GemmBatchItem<'_, f32>> = inputs
            .iter()
            .zip(cs.iter_mut())
            .zip(coeffs)
            .map(|(((a, b, _), c), (alpha, beta))| GemmBatchItem {
                alpha,
                a: a.view(),
                b: b.view(),
                beta,
                c: c.view_mut(),
            })
            .collect();
        let configs = eng.gemm_batch_t::<f32>(&mut items);
        drop(items);
        assert_eq!(configs.len(), 4);
        assert_eq!(configs[0], configs[3], "repeated shape must memoize per dtype");
        for (i, (c, expect)) in cs.iter().zip(&refs).enumerate() {
            assert_eq!(
                c.max_abs_diff(expect),
                0.0,
                "f32 batch member {i} (x{t}) must be bitwise identical to serial"
            );
        }
    }
}

/// f32 LU through the lookahead pipeline: every depth and width must be
/// bitwise identical to the serialized f32 baseline, and accurate to f32
/// tolerance.
#[test]
fn f32_lookahead_lu_bitwise_matches_baseline() {
    use dla_codesign::gemm::SchedPolicy;
    let threads = threads_from_env().max(2);
    let (s, b) = (96usize, 16usize);
    let mut rng = Pcg64::seed(s as u64);
    let a0 = MatrixF32::random_diag_dominant(s, &mut rng);
    // Serialized baseline (lookahead off, sequential engine). The sched
    // pin keeps this a *lookahead* test under the CI `DLA_SCHED=dag`
    // leg (tests/dag.rs covers the DAG driver at f32).
    let mut base_eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
        .with_lookahead(Lookahead::disabled())
        .with_sched(SchedPolicy::Lookahead);
    let base: LuFactors<f32> = lu_factor_t::<f32>(&a0, b, &mut base_eng).unwrap();
    assert!(base.reconstruction_error(&a0) < 1e-4);
    for depth in [1usize, 2] {
        let mut eng = engine(threads, ParallelLoop::G4)
            .with_lookahead(Lookahead { depth, panel_workers: AUTO_PANEL_WORKERS })
            .with_sched(SchedPolicy::Lookahead);
        let f = lu_factor_t::<f32>(&a0, b, &mut eng).unwrap();
        assert_eq!(f.pivots, base.pivots, "depth {depth}: f32 pivots must match baseline");
        assert_eq!(
            f.lu.max_abs_diff(&base.lu),
            0.0,
            "depth {depth} x{threads}: f32 lookahead LU must be bitwise identical"
        );
    }
}

/// Mixed-precision solve: f64-level residual on a well-conditioned
/// system (on a pooled engine), within a small iteration budget.
#[test]
fn mixed_precision_converges_on_pooled_engine() {
    let threads = threads_from_env();
    let mut rng = Pcg64::seed(2718);
    let n = 160;
    let a = MatrixF64::random_diag_dominant(n, &mut rng);
    let x_true = MatrixF64::random(n, 3, &mut rng);
    let mut b = MatrixF64::zeros(n, 3);
    gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut b.view_mut());
    let mut eng = engine(threads, ParallelLoop::G4);
    let opts = RefineOptions { block: 32, ..Default::default() };
    let res = lu_solve_mixed(&a, &b, &opts, &mut eng).unwrap();
    assert!(!res.fell_back);
    assert!(res.residual <= 1e-10, "relative residual {}", res.residual);
    assert!(res.iterations >= 1 && res.iterations <= opts.max_iters);
    assert!(res.x.max_abs_diff(&x_true) < 1e-8);
}

/// Ill-conditioned input: the refinement cannot contract the error in
/// f32, so the solver must fall back and return exactly the plain-f64
/// answer.
#[test]
fn mixed_precision_falls_back_cleanly() {
    let n = 12;
    let a = MatrixF64::from_fn(n, n, |i, j| 1.0 / ((i + j + 1) as f64));
    let mut rng = Pcg64::seed(11);
    let b = MatrixF64::random(n, 1, &mut rng);
    let opts = RefineOptions { block: 4, max_iters: 8, ..Default::default() };
    let res = lu_solve_mixed(&a, &b, &opts, &mut engine(1, ParallelLoop::G4)).unwrap();
    assert!(res.fell_back, "Hilbert(12) must trigger the f64 fallback");
    let x64 = lu_solve_f64(&a, &b, opts.block, &mut engine(1, ParallelLoop::G4)).unwrap();
    assert_eq!(res.x.max_abs_diff(&x64), 0.0, "fallback must equal the plain f64 solve");
}

/// The f64 paths must be unperturbed by the generic refactor: the
/// dtype-keyed cache serves the same f64 configs, and an f64 GEMM on a
/// pool is still bitwise equal to the sequential engine (the historical
/// determinism contract, re-asserted here beside the f32 twin).
#[test]
fn f64_determinism_is_unperturbed() {
    let threads = threads_from_env().max(2);
    let (m, n, k) = (77usize, 65usize, 31usize);
    let mut rng = Pcg64::seed(8);
    let a = MatrixF64::random(m, k, &mut rng);
    let b = MatrixF64::random(k, n, &mut rng);
    let c0 = MatrixF64::random(m, n, &mut rng);
    let mut c_seq = c0.clone();
    let mut seq = engine(1, ParallelLoop::G4);
    seq.gemm(1.0, a.view(), b.view(), 1.0, &mut c_seq.view_mut());
    let mut c_par = c0.clone();
    let mut par = engine(threads, ParallelLoop::G4);
    par.gemm(1.0, a.view(), b.view(), 1.0, &mut c_par.view_mut());
    assert_eq!(c_par.max_abs_diff(&c_seq), 0.0);
    // Same dims in both precisions never collide in the cache.
    assert_eq!(seq.plan_config(GemmDims::new(m, n, k)), seq.plan_config(GemmDims::new(m, n, k)));
}
