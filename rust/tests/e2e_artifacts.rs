//! Integration tests over the real AOT artifacts: the full three-layer
//! path (Rust PJRT runtime -> XLA executable -> Pallas-lowered HLO).
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.tsv`;
//! run via `make test`. Compiling the registry once per process keeps the
//! suite fast.

use dla_codesign::coordinator::lu_driver::{lu_full_via_artifact, lu_via_artifacts};
use dla_codesign::lapack::LuFactors;
use dla_codesign::runtime::convert::{literal_to_matrix, matrix_to_literal};
use dla_codesign::runtime::{execute_tupled, ArtifactKind, Registry};
use dla_codesign::util::{MatrixF64, Pcg64};

// The xla crate's PJRT handles hold raw pointers (not Sync), so each test
// builds its own registry; the artifacts are small and compile in
// milliseconds on the CPU client.
fn registry() -> Registry {
    Registry::load(Registry::default_dir())
        .expect("artifacts missing: run `make artifacts` before `cargo test`")
}

#[test]
fn registry_loads_all_kinds() {
    let reg = &registry();
    assert!(reg.len() >= 4, "expected several artifacts, got {}", reg.len());
    assert!(!reg.by_kind(ArtifactKind::Gemm).is_empty());
    assert!(!reg.by_kind(ArtifactKind::LuStep).is_empty());
    assert!(!reg.by_kind(ArtifactKind::LuFull).is_empty());
    assert!(reg.by_name("lu_step_s256_b32").is_some());
}

#[test]
fn gemm_artifact_matches_native_reference() {
    let reg = &registry();
    for art in reg.by_kind(ArtifactKind::Gemm) {
        let (m, n, k) = (
            art.param_usize("m").unwrap(),
            art.param_usize("n").unwrap(),
            art.param_usize("k").unwrap(),
        );
        let mut rng = Pcg64::seed((m + n + k) as u64);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let outs = execute_tupled(
            &art.exe,
            &[matrix_to_literal(&a).unwrap(), matrix_to_literal(&b).unwrap()],
        )
        .unwrap();
        assert_eq!(outs.len(), 1, "{}", art.name);
        let c = literal_to_matrix(&outs[0]).unwrap();
        let mut expect = MatrixF64::zeros(m, n);
        dla_codesign::gemm::gemm_reference(1.0, a.view(), b.view(), 0.0, &mut expect.view_mut());
        let err = c.max_abs_diff(&expect);
        assert!(err < 1e-10 * k as f64, "{}: artifact GEMM diverges by {err}", art.name);
    }
}

#[test]
fn gemm_update_artifact_is_trailing_update() {
    let reg = &registry();
    let art = reg
        .by_kind(ArtifactKind::GemmUpdate)
        .into_iter()
        .next()
        .expect("gemm_update artifact");
    let (m, n, k) = (
        art.param_usize("m").unwrap(),
        art.param_usize("n").unwrap(),
        art.param_usize("k").unwrap(),
    );
    let mut rng = Pcg64::seed(7);
    let c0 = MatrixF64::random(m, n, &mut rng);
    let a = MatrixF64::random(m, k, &mut rng);
    let b = MatrixF64::random(k, n, &mut rng);
    let outs = execute_tupled(
        &art.exe,
        &[
            matrix_to_literal(&c0).unwrap(),
            matrix_to_literal(&a).unwrap(),
            matrix_to_literal(&b).unwrap(),
        ],
    )
    .unwrap();
    let c = literal_to_matrix(&outs[0]).unwrap();
    // C := C - A @ B
    let mut expect = c0.clone();
    dla_codesign::gemm::gemm_reference(-1.0, a.view(), b.view(), 1.0, &mut expect.view_mut());
    assert!(c.max_abs_diff(&expect) < 1e-10 * k as f64);
}

#[test]
fn lu_step_driver_reconstructs_pa() {
    let reg = &registry();
    let mut rng = Pcg64::seed(42);
    let a0 = MatrixF64::random(256, 256, &mut rng);
    let res = lu_via_artifacts(reg, &a0, 32).unwrap();
    assert_eq!(res.step_seconds.len(), 256 / 32);
    let factors = LuFactors { lu: res.lu.clone(), pivots: res.pivots.clone(), block: 32 };
    let err = factors.reconstruction_error(&a0);
    assert!(err < 1e-10, "|PA - LU| = {err}");
}

#[test]
fn lu_artifact_matches_native_lu_exactly() {
    // The PJRT path and the native Rust path must agree bit-for-bit on
    // pivots and closely on factors (same algorithm, same pivoting rule).
    let reg = &registry();
    let mut rng = Pcg64::seed(43);
    let a0 = MatrixF64::random(128, 128, &mut rng);
    let art_res = lu_via_artifacts(reg, &a0, 16).unwrap();
    let mut engine = dla_codesign::gemm::GemmEngine::new(
        dla_codesign::arch::host_xeon(),
        dla_codesign::gemm::ConfigMode::Refined,
    );
    let native = dla_codesign::lapack::lu_factor(&a0, 16, &mut engine).unwrap();
    assert_eq!(art_res.pivots, native.pivots, "pivot sequences differ");
    assert!(art_res.lu.max_abs_diff(&native.lu) < 1e-9);
}

#[test]
fn lu_full_artifact_agrees_with_step_driver() {
    let reg = &registry();
    let mut rng = Pcg64::seed(44);
    let a0 = MatrixF64::random(256, 256, &mut rng);
    let stepped = lu_via_artifacts(reg, &a0, 32).unwrap();
    let full = lu_full_via_artifact(reg, &a0, 32).unwrap();
    assert_eq!(stepped.pivots, full.pivots);
    assert!(stepped.lu.max_abs_diff(&full.lu) < 1e-11);
}

#[test]
fn lu_driver_flags_singular_input() {
    let reg = &registry();
    let mut a0 = MatrixF64::zeros(256, 256);
    for i in 0..256 {
        a0[(i, i)] = 1.0;
    }
    // Zero out a pivot column entirely.
    for i in 0..256 {
        a0[(i, 5)] = 0.0;
    }
    a0[(5, 5)] = 0.0;
    let res = lu_via_artifacts(reg, &a0, 32);
    assert!(res.is_err(), "singular input must be rejected");
}

#[test]
fn registry_gemm_lookup_prefers_variant() {
    let reg = &registry();
    if let Some(a) = reg.find_gemm(256, 256, 32, "mk12x4") {
        assert_eq!(a.variant(), "mk12x4");
    }
    let any = reg.find_gemm(256, 256, 32, "not_a_variant");
    assert!(any.is_some(), "fallback to any variant must work");
    assert!(reg.find_gemm(9999, 1, 1, "mk8x8").is_none());
}
