//! Regression tests for the persistent-pool GEMM runtime:
//!
//! - parallel-vs-sequential **bitwise** determinism across G3/G4 for every
//!   registered kernel and awkward (non-multiple) dimensions,
//! - pool reuse (zero thread spawns after construction) across
//!   consecutive GEMMs and across whole LU factorizations,
//! - config-selection memo-cache hit accounting,
//! - the spawn-per-block ablation baseline staying numerically identical.

use std::sync::Arc;

use dla_codesign::arch::host_xeon;
use dla_codesign::gemm::microkernel::registry;
use dla_codesign::gemm::parallel::{gemm_parallel, gemm_parallel_spawning};
use dla_codesign::gemm::{
    gemm_blocked, ConfigMode, GemmEngine, ParallelLoop, ThreadPlan, Workspace,
};
use dla_codesign::lapack::lu_factor;
use dla_codesign::model::ccp::GemmConfig;
use dla_codesign::model::Ccp;
use dla_codesign::runtime::pool::WorkerPool;
use dla_codesign::util::{MatrixF64, Pcg64};

/// Pooled G3/G4 must be bitwise identical to the sequential blocked path
/// for every registered (non-prefetch) kernel and awkward shapes.
#[test]
fn pooled_gemm_is_bitwise_deterministic_for_all_kernels() {
    let pool = Arc::new(WorkerPool::new(4));
    for imp in registry() {
        if imp.prefetch {
            continue;
        }
        let (mr, nr) = (imp.spec.mr, imp.spec.nr);
        // Awkward: dims not multiples of the tile, CCPs not multiples of
        // the dims, plus a skinny-k paper shape.
        let shapes =
            [(2 * mr + 3, 2 * nr + 1, 33), (61, 53, 29), (3 * mr, 4 * nr, 16), (97, 89, 8)];
        for (m, n, k) in shapes {
            let ccp = Ccp::new((2 * mr).max(5), (3 * nr).max(7), 13);
            let cfg = GemmConfig { mk: imp.spec, ccp };
            let mut rng = Pcg64::seed((m * 131 + n * 17 + k) as u64);
            let a = MatrixF64::random(m, k, &mut rng);
            let b = MatrixF64::random(k, n, &mut rng);
            let c0 = MatrixF64::random(m, n, &mut rng);

            let mut c_seq = c0.clone();
            let mut ws = Workspace::new();
            gemm_blocked(&cfg, &imp, 1.0, a.view(), b.view(), 1.0, &mut c_seq.view_mut(), &mut ws);

            for target in [ParallelLoop::G3, ParallelLoop::G4] {
                let mut c_par = c0.clone();
                gemm_parallel(
                    &cfg, &imp, 1.0, a.view(), b.view(), 1.0, &mut c_par.view_mut(), target,
                    &pool,
                );
                assert_eq!(
                    c_par.max_abs_diff(&c_seq),
                    0.0,
                    "{} {target:?} {m}x{n}x{k} is not bitwise deterministic",
                    imp.name
                );
            }
        }
    }
    // The whole sweep above ran on three workers, spawned exactly once.
    assert_eq!(pool.spawned_workers(), 3);
}

/// One pool serves >= 3 consecutive GEMMs of different shapes with zero
/// additional thread spawns.
#[test]
fn pool_reuse_across_consecutive_gemms_spawns_nothing() {
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
        .with_plan(ThreadPlan { threads: 4, target: ParallelLoop::G4 });
    let pool = Arc::clone(eng.pool().expect("pool provisioned"));
    let mut rng = Pcg64::seed(42);
    for (i, (m, n, k)) in [(80, 64, 24), (57, 91, 13), (120, 40, 33), (64, 64, 64)]
        .into_iter()
        .enumerate()
    {
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::zeros(m, n);
        let mut expect = MatrixF64::zeros(m, n);
        dla_codesign::gemm::gemm_reference(1.0, a.view(), b.view(), 0.0, &mut expect.view_mut());
        eng.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        assert!(c.max_abs_diff(&expect) < 1e-12 * k as f64, "gemm #{i}");
        assert_eq!(pool.spawned_workers(), 3, "gemm #{i} must not spawn threads");
    }
}

/// A whole LU factorization (many trailing updates) performs zero thread
/// spawns after pool construction, and the pooled result is bitwise
/// identical to the sequential engine's.
#[test]
fn lu_on_pooled_engine_is_deterministic_and_spawn_free() {
    let mut rng = Pcg64::seed(7);
    let a0 = MatrixF64::random_diag_dominant(96, &mut rng);

    let mut seq_eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let f_seq = lu_factor(&a0, 16, &mut seq_eng).unwrap();

    for target in [ParallelLoop::G3, ParallelLoop::G4] {
        let mut par_eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 4, target });
        let pool = Arc::clone(par_eng.pool().unwrap());
        let f_par = lu_factor(&a0, 16, &mut par_eng).unwrap();
        assert_eq!(f_par.pivots, f_seq.pivots, "{target:?}: pivot sequences differ");
        assert_eq!(
            f_par.lu.max_abs_diff(&f_seq.lu),
            0.0,
            "{target:?}: LU factors are not bitwise identical"
        );
        assert_eq!(
            pool.spawned_workers(),
            3,
            "{target:?}: LU must reuse the pool, not spawn per block"
        );
        // A second factorization on the same engine still spawns nothing.
        let _ = lu_factor(&a0, 16, &mut par_eng).unwrap();
        assert_eq!(pool.spawned_workers(), 3);
    }
}

/// The config memo cache: an LU sweep scores each distinct trailing shape
/// once; a repeated factorization is pure hits.
#[test]
fn config_cache_accounting_across_lu_factorizations() {
    let mut rng = Pcg64::seed(9);
    let a0 = MatrixF64::random_diag_dominant(64, &mut rng);
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    lu_factor(&a0, 16, &mut eng).unwrap();
    let first = eng.config_cache_stats();
    // s=64, b=16 -> trailing GEMMs of 48, 32, 16: three distinct shapes.
    assert_eq!(first.misses, 3, "one selector run per distinct trailing shape");
    lu_factor(&a0, 16, &mut eng).unwrap();
    let second = eng.config_cache_stats();
    assert_eq!(second.misses, first.misses, "repeat factorization must be all cache hits");
    assert_eq!(second.hits, first.hits + 3);
}

/// Repeated identical GEMM requests hit the cache (the serving pattern).
#[test]
fn config_cache_hits_on_repeated_requests() {
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let mut rng = Pcg64::seed(11);
    let a = MatrixF64::random(48, 24, &mut rng);
    let b = MatrixF64::random(24, 36, &mut rng);
    for _ in 0..5 {
        let mut c = MatrixF64::zeros(48, 36);
        eng.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
    }
    let stats = eng.config_cache_stats();
    assert_eq!((stats.misses, stats.hits), (1, 4));
}

/// The retained spawn-per-block baseline stays bitwise identical to the
/// pooled path (same arithmetic, different threading architecture).
#[test]
fn spawning_baseline_matches_pooled_path() {
    let imp = registry().into_iter().find(|k| !k.prefetch).unwrap();
    let cfg = GemmConfig { mk: imp.spec, ccp: Ccp::new(24, 18, 11) };
    let mut rng = Pcg64::seed(13);
    let (m, n, k) = (59, 47, 23);
    let a = MatrixF64::random(m, k, &mut rng);
    let b = MatrixF64::random(k, n, &mut rng);
    let c0 = MatrixF64::random(m, n, &mut rng);

    let pool = WorkerPool::new(3);
    let mut c_pool = c0.clone();
    gemm_parallel(
        &cfg, &imp, 1.0, a.view(), b.view(), 0.5, &mut c_pool.view_mut(), ParallelLoop::G4,
        &pool,
    );
    let mut c_spawn = c0.clone();
    let mut ws = Workspace::new();
    gemm_parallel_spawning(
        &cfg, &imp, 1.0, a.view(), b.view(), 0.5, &mut c_spawn.view_mut(), 3, &mut ws,
    );
    assert_eq!(c_pool.max_abs_diff(&c_spawn), 0.0);
}
