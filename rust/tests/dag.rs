//! Tile-DAG scheduler equivalence suite (`DLA_SCHED=dag` /
//! `SchedPolicy::Dag` — the ISSUE 9 acceptance): the dataflow pipeline
//! must be a pure *scheduling* change. For LU, Cholesky and QR — at
//! every thread width {1, 2, 4} (plus the CI `DLA_THREADS` leg) and
//! both dtypes — the DAG drivers must produce factors bitwise
//! identical to the serialized baseline, resolve the `block == 0`
//! model-tile sentinel identically, propagate breakdowns (singular /
//! non-SPD) with the same failing column, keep the pool's no-spawn
//! invariant (and populate the steal counters while never touching the
//! lookahead phase-idle ones), compose with ABFT panel verification,
//! and survive an injected pool panic with the pool recovered and
//! reusable.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;

use dla_codesign::arch::host_xeon;
use dla_codesign::gemm::{
    gemm_reference, ConfigMode, GemmElem, GemmEngine, ParallelLoop, SchedPolicy, ThreadPlan,
    VerifyPolicy,
};
use dla_codesign::lapack::{
    cholesky_blocked_t, cholesky_residual, lu_factor, lu_factor_t, qr_blocked_t,
};
use dla_codesign::runtime::{FaultPlan, FaultState, WorkerPool};
use dla_codesign::util::{Matrix, MatrixF64, Pcg64};

/// A DAG-scheduled engine at the given team width (width 1 has no pool
/// and drains the same graph serially).
fn dag_engine(threads: usize) -> GemmEngine {
    let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined).with_sched(SchedPolicy::Dag);
    if threads > 1 {
        eng.with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
    } else {
        eng
    }
}

/// The serialized oracle: sequential engine, fork-join scheduler pinned
/// (so the suite keeps comparing DAG *against the baseline* even when
/// the CI matrix exports `DLA_SCHED=dag`).
fn base_engine() -> GemmEngine {
    GemmEngine::new(host_xeon(), ConfigMode::Refined).with_sched(SchedPolicy::Lookahead)
}

/// Thread widths under test: the fixed {1, 2, 4} of the acceptance
/// criteria plus the CI matrix width from `DLA_THREADS`.
fn thread_sweep() -> Vec<usize> {
    let mut t = vec![1, 2, 4];
    if let Some(extra) = std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()) {
        if !t.contains(&extra) {
            t.push(extra);
        }
    }
    t
}

/// An SPD matrix at dtype `E`: `M M^T + s I`.
fn spd_t<E: GemmElem>(s: usize, rng: &mut Pcg64) -> Matrix<E> {
    let m = Matrix::<E>::random(s, s, rng);
    let mt = m.transposed();
    let mut a = Matrix::<E>::zeros(s, s);
    gemm_reference(E::ONE, m.view(), mt.view(), E::ZERO, &mut a.view_mut());
    for i in 0..s {
        let d = a[(i, i)];
        a[(i, i)] = d + E::from_f64(s as f64);
    }
    a
}

/// LU sweep at one dtype: DAG factors and pivots bitwise-identical to
/// the serialized baseline at every width, and accurate.
fn lu_sweep<E: GemmElem>(tol: f64, seed: u64) {
    let mut rng = Pcg64::seed(seed);
    // Non-divisible blockings on purpose: short trailing panels and
    // nr-misaligned column splits stress the tile-edge cases.
    for (s, b) in [(37usize, 5usize), (64, 16), (96, 32)] {
        let a0 = Matrix::<E>::random(s, s, &mut rng);
        let base = lu_factor_t::<E>(&a0, b, &mut base_engine()).unwrap();
        for threads in thread_sweep() {
            let dag = lu_factor_t::<E>(&a0, b, &mut dag_engine(threads)).unwrap();
            assert_eq!(dag.pivots, base.pivots, "s={s} b={b} x{threads}: pivot vectors differ");
            assert_eq!(
                dag.lu.max_abs_diff(&base.lu),
                0.0,
                "s={s} b={b} x{threads}: factors not bitwise identical"
            );
            let err = dag.reconstruction_error(&a0);
            assert!(err < tol, "s={s} b={b} x{threads}: |PA-LU| = {err}");
        }
    }
}

#[test]
fn dag_lu_bitwise_identical_to_serialized_baseline_f64() {
    lu_sweep::<f64>(1e-10, 9001);
}

#[test]
fn dag_lu_bitwise_identical_to_serialized_baseline_f32() {
    lu_sweep::<f32>(1e-3, 9002);
}

/// Cholesky sweep at one dtype: identical lower triangles (the upper is
/// workspace) at every width.
fn cholesky_sweep<E: GemmElem>(seed: u64) {
    let mut rng = Pcg64::seed(seed);
    for (s, b) in [(33usize, 7usize), (45, 8), (64, 16)] {
        let a0 = spd_t::<E>(s, &mut rng);
        let mut base = a0.clone();
        cholesky_blocked_t::<E>(&mut base, b, &mut base_engine()).unwrap();
        for threads in thread_sweep() {
            let mut dag = a0.clone();
            cholesky_blocked_t::<E>(&mut dag, b, &mut dag_engine(threads)).unwrap();
            for j in 0..s {
                for i in j..s {
                    assert_eq!(
                        dag[(i, j)].to_f64().to_bits(),
                        base[(i, j)].to_f64().to_bits(),
                        "s={s} b={b} x{threads}: L({i},{j}) differs"
                    );
                }
            }
        }
    }
}

#[test]
fn dag_cholesky_bitwise_identical_to_serialized_baseline_f64() {
    // One representative residual check on top of the bitwise sweep.
    cholesky_sweep::<f64>(9003);
    let mut rng = Pcg64::seed(9203);
    let a0 = spd_t::<f64>(48, &mut rng);
    let mut l = a0.clone();
    cholesky_blocked_t::<f64>(&mut l, 16, &mut dag_engine(4)).unwrap();
    let res = cholesky_residual(&a0, &l);
    assert!(res < 1e-11, "residual {res}");
}

#[test]
fn dag_cholesky_bitwise_identical_to_serialized_baseline_f32() {
    cholesky_sweep::<f32>(9004);
}

/// QR sweep at one dtype: packed factors and tau bitwise-identical at
/// every width, square and tall shapes.
fn qr_sweep<E: GemmElem>(tol: f64, seed: u64) {
    let mut rng = Pcg64::seed(seed);
    for (m, n, b) in [(40usize, 24usize, 8usize), (33, 17, 5), (48, 48, 16)] {
        let a0 = Matrix::<E>::random(m, n, &mut rng);
        let base = qr_blocked_t::<E>(&a0, b, &mut base_engine());
        for threads in thread_sweep() {
            let dag = qr_blocked_t::<E>(&a0, b, &mut dag_engine(threads));
            assert_eq!(
                dag.qr.max_abs_diff(&base.qr),
                0.0,
                "m={m} n={n} b={b} x{threads}: packed factors differ"
            );
            for (j, (tf, tb)) in dag.tau.iter().zip(&base.tau).enumerate() {
                assert_eq!(
                    tf.to_f64().to_bits(),
                    tb.to_f64().to_bits(),
                    "m={m} n={n} b={b} x{threads}: tau[{j}] differs"
                );
            }
            let err = dag.reconstruction_error(&a0);
            assert!(err < tol, "m={m} n={n} b={b} x{threads}: |A-QR| = {err}");
        }
    }
}

#[test]
fn dag_qr_bitwise_identical_to_serialized_baseline_f64() {
    qr_sweep::<f64>(1e-10, 9005);
}

#[test]
fn dag_qr_bitwise_identical_to_serialized_baseline_f32() {
    qr_sweep::<f32>(1e-2, 9006);
}

#[test]
fn dag_block_zero_resolves_the_model_tile_identically() {
    // `block == 0` asks the analytic scorer for the tile width; the
    // selection depends only on (arch, mode, dtype, order), so every
    // engine resolves the same b and the factors stay bitwise equal.
    let mut rng = Pcg64::seed(9007);
    let a0 = MatrixF64::random(64, 64, &mut rng);
    let base = lu_factor(&a0, 0, &mut base_engine()).unwrap();
    assert!(base.block >= 1, "sentinel must resolve to a real tile size");
    for threads in thread_sweep() {
        let dag = lu_factor(&a0, 0, &mut dag_engine(threads)).unwrap();
        assert_eq!(dag.block, base.block, "x{threads}: model tile must not depend on the team");
        assert_eq!(dag.pivots, base.pivots, "x{threads}");
        assert_eq!(dag.lu.max_abs_diff(&base.lu), 0.0, "x{threads}");
    }
}

#[test]
fn dag_lu_detects_singularity_like_baseline() {
    // Column 3 duplicates column 2: every width must report the same
    // failing column, and the cancellation must drain the graph (the
    // test completing at all is the no-hang assertion).
    let mut a = MatrixF64::identity(12);
    for i in 0..12 {
        let v = a[(i, 2)];
        a[(i, 3)] = v;
    }
    let base = lu_factor(&a, 4, &mut base_engine());
    let Err(jb) = base.map(|_| ()) else {
        panic!("rank-deficient matrix must be detected on the baseline");
    };
    for threads in thread_sweep() {
        let dag = lu_factor(&a, 4, &mut dag_engine(threads));
        let Err(jd) = dag.map(|_| ()) else {
            panic!("rank-deficient matrix must be detected at x{threads}");
        };
        assert_eq!(jb, jd, "failing column must agree at x{threads}");
    }
}

#[test]
fn dag_cholesky_detects_non_spd_like_baseline() {
    let mut a0 = MatrixF64::identity(24);
    a0[(17, 17)] = -1.0;
    let mut base = a0.clone();
    let Err(jb) = cholesky_blocked_t::<f64>(&mut base, 4, &mut base_engine()) else {
        panic!("non-SPD must be detected on the baseline");
    };
    for threads in thread_sweep() {
        let mut m = a0.clone();
        let Err(jd) = cholesky_blocked_t::<f64>(&mut m, 4, &mut dag_engine(threads)) else {
            panic!("non-SPD must be detected at x{threads}");
        };
        assert_eq!(jb, jd, "failing column must agree at x{threads}");
    }
}

#[test]
fn dag_composes_with_abft_verification_bitwise() {
    // ABFT panel checksums ride inside the Panel tasks; verification
    // must not move a bit, and the checked-panel counter must advance.
    let mut rng = Pcg64::seed(9008);
    let a0 = MatrixF64::random_diag_dominant(64, &mut rng);
    let plain = lu_factor(&a0, 16, &mut dag_engine(4)).unwrap();
    let mut eng = dag_engine(4).with_verify(VerifyPolicy::Detect);
    let verified = lu_factor(&a0, 16, &mut eng).unwrap();
    assert_eq!(verified.pivots, plain.pivots, "verification changed pivots");
    assert_eq!(verified.lu.max_abs_diff(&plain.lu), 0.0, "verification moved bits");
    let snap = eng.abft_stats().snapshot();
    assert!(snap.verified_blocks > 0, "panel checks must have run: {snap:?}");
}

#[test]
fn dag_factorizations_never_spawn_and_populate_steal_counters() {
    // The no-spawn invariant: the whole DAG drains inside broadcast
    // jobs on the team parked at construction. The dag task counter
    // must advance; the lookahead phase-idle counters must stay zero —
    // the DAG path has no stop-the-world rejoin to account (the
    // structural form of the idle-time acceptance).
    let mut rng = Pcg64::seed(9009);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let mut eng = dag_engine(4);
    let pool = Arc::clone(eng.pool().expect("parallel plan provisions a pool"));
    assert_eq!(pool.spawned_workers(), 3);
    for _ in 0..2 {
        lu_factor(&a0, 16, &mut eng).unwrap();
    }
    let spd_m = spd_t::<f64>(64, &mut rng);
    let mut chol = spd_m.clone();
    cholesky_blocked_t::<f64>(&mut chol, 16, &mut eng).unwrap();
    qr_blocked_t::<f64>(&a0, 16, &mut eng);
    assert_eq!(
        pool.spawned_workers(),
        3,
        "DAG factorizations must reuse the pool, never spawn"
    );
    let s = pool.stats();
    assert!(s.jobs > 0, "the DAG drains run as pool jobs");
    assert!(s.dag_tasks > 0, "executed tile tasks must be counted: {s:?}");
    assert!(s.dag_deque_high_water > 0, "seeded deques must report a high-water mark: {s:?}");
    assert_eq!(s.panel_idle_ns, 0, "the DAG path has no fused-rejoin panel waits: {s:?}");
    assert_eq!(s.update_idle_ns, 0, "the DAG path has no fused-rejoin update waits: {s:?}");
    assert_eq!(s.queue_stall_ns, 0, "the DAG path has no lookahead queue stalls: {s:?}");
}

#[test]
fn dag_survives_pool_panic_and_pool_stays_usable() {
    // One-shot worker panic in the first broadcast epoch, injected
    // outside any tile task (the hardest spot: idle ranks must notice
    // the poisoned epoch and bail out of the drain loop rather than
    // spin forever). The drain must unwind, the pool must recover, and
    // the same engine must then factor bitwise-correctly.
    let plan = FaultPlan::parse("panic@1:1").expect("fault spec");
    let pool = Arc::new(WorkerPool::with_fault_state(4, Some(Arc::new(FaultState::new(plan)))));
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined).with_sched(SchedPolicy::Dag);
    eng.set_shared_pool(Arc::clone(&pool));
    let mut rng = Pcg64::seed(9010);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let shot = catch_unwind(AssertUnwindSafe(|| lu_factor(&a0, 16, &mut eng)));
    assert!(shot.is_err(), "the injected panic must unwind out of the DAG drain");
    let s = pool.stats();
    assert!(s.epochs_poisoned >= 1, "the shot must poison an epoch: {s:?}");
    assert_eq!(s.recoveries, s.epochs_poisoned, "every poisoned epoch must recover: {s:?}");
    // Post-recovery, same pool and engine: bitwise-correct factors.
    let base = lu_factor(&a0, 16, &mut base_engine()).unwrap();
    let redo = lu_factor(&a0, 16, &mut eng).unwrap();
    assert_eq!(redo.pivots, base.pivots, "post-recovery pivots differ");
    assert_eq!(redo.lu.max_abs_diff(&base.lu), 0.0, "post-recovery factors differ");
}
