//! Chaos suite: fault-injection drills through the public serving API.
//!
//! Every test pins a [`FaultPlan`] on its own server (no environment
//! mutation, no cross-test interference; the `DLA_FAULTS` env override
//! is exercised by the CI chaos leg instead) and asserts the three
//! serving-resilience invariants end to end:
//!
//! 1. **Isolation** — an injected fault costs exactly the requests it
//!    hits; every other request completes with the *same bits* a
//!    fault-free server produces (the pooled G4 schedule is team-width
//!    independent, so a serial engine is the oracle).
//! 2. **Typed failure** — the victims observe a typed [`DlaError`]
//!    (`Internal`, `Timeout`, `QueueFull`), never a hang, a poisoned
//!    lock, or a torn result.
//! 3. **Recovery** — the pool's poisoned epochs are recovered
//!    (`recoveries == epochs_poisoned`), the degraded window drains, and
//!    the shutdown metrics account for every fault delivered.

use std::thread;
use std::time::Duration;

use dla_codesign::arch::host_xeon;
use dla_codesign::coordinator::{
    BatchPolicy, CoordinatorServer, DlaRequest, DlaResponse, DlaError, Priority, ServerConfig,
};
use dla_codesign::gemm::{ConfigMode, GemmEngine};
use dla_codesign::runtime::FaultPlan;
use dla_codesign::util::{MatrixF64, Pcg64};

/// The serial oracle: what a solo, pool-less dispatch of this GEMM
/// produces (bitwise — see `tests/batching.rs` for the invariant).
fn serial_gemm(alpha: f64, a: &MatrixF64, b: &MatrixF64, beta: f64, c0: &MatrixF64) -> MatrixF64 {
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let mut c = c0.clone();
    eng.gemm(alpha, a.view(), b.view(), beta, &mut c.view_mut());
    c
}

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).expect("test fault spec must parse")
}

/// A one-shot panic injected inside a pooled epoch costs exactly one
/// request; the survivors (degraded window included) are bitwise equal
/// to the serial oracle, the pool recovers its poisoned epoch, and the
/// metrics account for the whole incident.
#[test]
fn injected_pool_panic_is_isolated_and_recovered() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_batching(BatchPolicy::disabled())
            .with_faults(plan("panic@1:1")),
    )
    .expect("server start");
    let faults = server.fault_state().expect("pinned plan must be armed");

    let mut rng = Pcg64::seed(600);
    let n = 10;
    let inputs: Vec<_> = (0..n)
        .map(|_| {
            (
                MatrixF64::random(96, 64, &mut rng),
                MatrixF64::random(64, 80, &mut rng),
                MatrixF64::random(96, 80, &mut rng),
            )
        })
        .collect();
    let pending: Vec<_> = inputs
        .iter()
        .map(|(a, b, c0)| {
            server
                .submit(DlaRequest::Gemm {
                    alpha: 1.0,
                    a: a.clone(),
                    b: b.clone(),
                    beta: 1.0,
                    c: c0.clone(),
                })
                .expect("submit")
        })
        .collect();

    // Request 0 triggers the first pooled epoch and takes the shot; with
    // one worker the order is deterministic.
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv().expect("every request must be answered");
        if i == 0 {
            match resp {
                Err(DlaError::Internal { reason }) => {
                    assert!(reason.contains("panicked"), "untyped reason: {reason}")
                }
                Err(other) => panic!("victim must see Internal, got {other:?}"),
                Ok(_) => panic!("victim request must fail"),
            }
        } else {
            let (a, b, c0) = &inputs[i];
            let DlaResponse::Matrix { result, .. } = resp.expect("survivor must succeed") else {
                panic!("unexpected response kind");
            };
            let oracle = serial_gemm(1.0, a, b, 1.0, c0);
            assert_eq!(
                result.max_abs_diff(&oracle),
                0.0,
                "request {i} diverged from the serial oracle after the fault"
            );
        }
    }
    assert_eq!(faults.injected().panics, 1, "the shot is one-shot");

    let metrics = server.shutdown();
    let f = metrics.fault_stats();
    assert_eq!(f.worker_panics, 1);
    // The panic arms an 8-request degraded window; 9 survivors drain it.
    assert_eq!(f.degraded_requests, 8);
    let pool = metrics.pool_stats().expect("pooled server must report pool stats");
    assert!(pool.epochs_poisoned >= 1, "the injected panic poisons an epoch");
    assert_eq!(
        pool.recoveries, pool.epochs_poisoned,
        "every poisoned epoch must be recovered"
    );
    let summary = metrics.summary();
    assert!(summary.contains("resilience:"), "faulted run must report a resilience line");
    assert!(summary.contains("epochs poisoned"), "pool line must surface the poison count");
}

/// A panic during a factorization unwinds through the blocked-LU sweep;
/// the pool recovers and later factorizations on the same pool are
/// correct.
#[test]
fn factorization_survives_pool_panic_and_pool_stays_usable() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(3)
            .with_faults(plan("panic@2:1")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(601);
    let a0 = MatrixF64::random_diag_dominant(96, &mut rng);
    let err = server
        .call(DlaRequest::LuFactor { a: a0.clone(), block: 24 })
        .err()
        .expect("first factorization takes the shot");
    assert!(matches!(err, DlaError::Internal { .. }), "got {err:?}");

    // Same pool, post-recovery: factorizations and solves are healthy.
    let a1 = MatrixF64::random_diag_dominant(80, &mut rng);
    let resp = server
        .call(DlaRequest::LuFactor { a: a1.clone(), block: 20 })
        .expect("post-recovery factorization");
    let DlaResponse::Lu { factors, .. } = resp else { panic!("unexpected response kind") };
    assert!(factors.reconstruction_error(&a1) < 1e-10);

    let metrics = server.shutdown();
    assert_eq!(metrics.fault_stats().worker_panics, 1);
    let pool = metrics.pool_stats().expect("pool stats");
    assert_eq!(pool.recoveries, pool.epochs_poisoned);
}

/// With a deadline armed and the worker stalled past it, requests get a
/// typed [`DlaError::Timeout`] instead of a late answer or a hang, and
/// the expiry is accounted in the metrics.
#[test]
fn stalled_requests_expire_with_typed_timeouts() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_deadline(Duration::from_millis(25))
            .with_faults(plan("stall:120")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(602);
    let mut pending = Vec::new();
    for _ in 0..2 {
        let req = DlaRequest::Gemm {
            alpha: 1.0,
            a: MatrixF64::random(32, 16, &mut rng),
            b: MatrixF64::random(16, 24, &mut rng),
            beta: 0.0,
            c: MatrixF64::zeros(32, 24),
        };
        pending.push(server.submit(req).expect("submit"));
    }
    for rx in pending {
        let resp = rx.recv().expect("expired requests are answered, not dropped");
        match resp {
            Err(DlaError::Timeout { waited_ms }) => {
                assert!(waited_ms >= 25, "reported wait {waited_ms}ms is under the deadline")
            }
            Err(other) => panic!("stalled request must time out, got {other:?}"),
            Ok(_) => panic!("stalled request must time out, got a late answer"),
        }
    }
    let metrics = server.shutdown();
    let f = metrics.fault_stats();
    assert_eq!(f.timeouts, 2);
    assert_eq!(f.expired_in_queue, 2, "both expired before being served");
}

/// Forced queue-full bursts: a short burst is absorbed by the jittered
/// admission retries (the caller never notices), a burst longer than the
/// retry budget surfaces as a typed [`DlaError::QueueFull`] — and both
/// outcomes land in the shutdown metrics.
#[test]
fn queue_full_bursts_are_retried_then_rejected() {
    let mut rng = Pcg64::seed(603);
    let mut req = || DlaRequest::Gemm {
        alpha: 1.0,
        a: MatrixF64::random(24, 12, &mut rng),
        b: MatrixF64::random(12, 16, &mut rng),
        beta: 0.0,
        c: MatrixF64::zeros(24, 16),
    };

    // Burst shorter than the retry budget: absorbed. The pinned jitter
    // seed makes the backoff sleeps (and so the drill's timing) a
    // deterministic function of the plan, not of scheduling noise.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_faults(plan("queuefull:3"))
            .with_jitter_seed(0xC0FF_EE00),
    )
    .expect("server start");
    let resp = server.call(req()).expect("short burst must be absorbed by retries");
    assert!(matches!(resp, DlaResponse::Matrix { .. }));
    let metrics = server.shutdown();
    let f = metrics.fault_stats();
    assert_eq!(f.retries, 3, "one retry per forced rejection");
    assert_eq!(f.queue_full_rejections, 0);

    // Burst outlasting the budget: typed rejection, then recovery.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_faults(plan("queuefull:64"))
            .with_jitter_seed(0xC0FF_EE00),
    )
    .expect("server start");
    let err = server.call(req()).err().expect("endless burst must reject");
    assert!(matches!(err, DlaError::QueueFull { retries } if retries >= 1), "got {err:?}");
    let metrics = server.shutdown();
    assert!(metrics.fault_stats().queue_full_rejections >= 1);
}

/// Per-tier retry budgets under a sustained queue-full burst: the same
/// burst that a Background submission gives up on (typed
/// [`DlaError::QueueFull`] after its 2-attempt budget, with bounded
/// latency — no unbounded retry amplification) is absorbed by an
/// Interactive submission's larger budget, and the survivor is bitwise
/// identical to the serial oracle.
#[test]
fn retry_budget_exhaustion_is_tiered_typed_and_bounded() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_faults(plan("queuefull:3"))
            .with_jitter_seed(0xC0FF_EE00),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(604);
    let t0 = std::time::Instant::now();
    let err = server
        .submit_at(
            DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::random(24, 12, &mut rng),
                b: MatrixF64::random(12, 16, &mut rng),
                beta: 0.0,
                c: MatrixF64::zeros(24, 16),
            },
            Priority::Background,
        )
        .err()
        .expect("the burst outlasts the background budget");
    assert_eq!(err, DlaError::QueueFull { retries: 2 }, "budget = 2 attempts, typed");
    assert!(err.is_transient());
    // 2 attempts = at most one backoff sleep (≤ 10 ms cap): the tight
    // budget bounds rejection latency instead of amplifying the storm.
    assert!(t0.elapsed() < Duration::from_secs(2), "rejection must be prompt");

    // The same storm has one forced rejection left; the Interactive
    // budget (8 attempts) absorbs it without the caller noticing.
    let a = MatrixF64::random(24, 12, &mut rng);
    let b = MatrixF64::random(12, 16, &mut rng);
    let c0 = MatrixF64::zeros(24, 16);
    let rx = server
        .submit_at(
            DlaRequest::Gemm { alpha: 1.0, a: a.clone(), b: b.clone(), beta: 0.0, c: c0.clone() },
            Priority::Interactive,
        )
        .expect("interactive budget must absorb the burst tail");
    let DlaResponse::Matrix { result, .. } =
        rx.recv().expect("answered").expect("survivor succeeds")
    else {
        panic!("unexpected response kind");
    };
    assert_eq!(
        result.max_abs_diff(&serial_gemm(1.0, &a, &b, 0.0, &c0)),
        0.0,
        "the survivor is bitwise identical to the serial oracle"
    );

    let metrics = server.shutdown();
    let f = metrics.fault_stats();
    assert_eq!(f.retries, 3, "2 background + 1 interactive: every forced shot costs one retry");
    assert_eq!(f.queue_full_rejections, 1, "only the background submission was rejected");
    let q = metrics.qos_stats();
    assert_eq!(q.rejected[Priority::Background.index()], 1, "{q:?}");
    assert_eq!(q.completed[Priority::Interactive.index()], 1, "{q:?}");
    assert!(q.reconciles(), "every submission is accounted: {q:?}");
}

/// The storm drill: concurrent submitters, a slow rank, and a one-shot
/// pool panic at once. Every request is answered (no hangs, no lost
/// replies), at most the panic's victim fails, and the pool ends the
/// run fully recovered.
#[test]
fn concurrent_storm_answers_every_request() {
    let server = std::sync::Arc::new(
        CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(2)
                .with_batching(BatchPolicy::disabled())
                .with_faults(plan("slow@1:2,panic@0:3")),
        )
        .expect("server start"),
    );

    let per_thread = 8;
    let submitters = 3;
    let mut joins = Vec::new();
    for t in 0..submitters {
        let server = std::sync::Arc::clone(&server);
        joins.push(thread::spawn(move || {
            let mut rng = Pcg64::seed(700 + t as u64);
            let mut outcomes = Vec::new();
            for i in 0..per_thread {
                let resp = if i % 4 == 3 {
                    server.call(DlaRequest::LuFactor {
                        a: MatrixF64::random_diag_dominant(48, &mut rng),
                        block: 12,
                    })
                } else {
                    server.call(DlaRequest::Gemm {
                        alpha: 1.0,
                        a: MatrixF64::random(48, 32, &mut rng),
                        b: MatrixF64::random(32, 40, &mut rng),
                        beta: 0.0,
                        c: MatrixF64::zeros(48, 40),
                    })
                };
                outcomes.push(resp);
            }
            outcomes
        }));
    }
    let mut ok = 0usize;
    let mut failed = 0usize;
    for j in joins {
        for resp in j.join().expect("submitter thread must not die") {
            match resp {
                Ok(_) => ok += 1,
                Err(DlaError::Internal { .. }) => failed += 1,
                Err(other) => panic!("unexpected error under the storm: {other:?}"),
            }
        }
    }
    assert_eq!(ok + failed, submitters * per_thread, "every request is answered");
    assert!(failed <= 1, "only the panic's victim may fail, got {failed}");

    let faults = server.fault_state().expect("armed");
    assert_eq!(faults.injected().panics, 1);
    assert!(faults.injected().delays >= 1, "the slow rank must actually have slept");

    let server = std::sync::Arc::into_inner(server).expect("all submitters joined");
    let metrics = server.shutdown();
    assert_eq!(metrics.fault_stats().worker_panics, 1);
    let pool = metrics.pool_stats().expect("pool stats");
    assert_eq!(pool.recoveries, pool.epochs_poisoned, "storm must end recovered");
}

/// The deflake knob: with [`ServerConfig::with_jitter_seed`] pinned,
/// the retry drill is a pure function of the fault plan — two runs see
/// the *same* typed outcome, the same retry count, and the same
/// per-tier ledger. (The default seed is a fixed constant too; this
/// drill guards the override path so CI retry drills stay
/// reproducible.)
#[test]
fn pinned_jitter_seed_makes_retry_drills_deterministic() {
    let run = || {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_faults(plan("queuefull:8"))
                .with_jitter_seed(0x5EED_CAFE),
        )
        .expect("server start");
        let mut rng = Pcg64::seed(605);
        let outcome = server.submit_at(
            DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::random(24, 12, &mut rng),
                b: MatrixF64::random(12, 16, &mut rng),
                beta: 0.0,
                c: MatrixF64::zeros(24, 16),
            },
            Priority::Background,
        );
        let err = outcome.err().expect("the burst outlasts the background budget");
        let metrics = server.shutdown();
        let f = *metrics.fault_stats();
        (err, f.retries, f.queue_full_rejections)
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "same seed, same plan => same drill outcome");
    assert_eq!(first.0, DlaError::QueueFull { retries: 2 });
}
