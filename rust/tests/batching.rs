//! The batched request scheduler, end to end: bitwise batched-vs-serial
//! equality per request, concurrent-submit stress, batch bypass for
//! factorizations, shutdown drain, and metrics counter sanity.
//!
//! The load-bearing invariant everywhere: a request served from a fused
//! multi-GEMM pool epoch produces **exactly** the bits a solo dispatch
//! of that request would have produced — the batcher is a scheduling
//! change only. An independent sequential engine (same arch + mode, so
//! the same memoized per-shape config) is the oracle: the G4 schedule's
//! results are team-width independent, so `gemm_blocked` bits == pooled
//! bits == batched bits.

use std::thread;

use dla_codesign::arch::host_xeon;
use dla_codesign::coordinator::{
    BatchPolicy, CoordinatorServer, DlaRequest, DlaResponse, ServerConfig,
};
use dla_codesign::gemm::{ConfigMode, GemmBatchItem, GemmEngine, ParallelLoop, ThreadPlan};
use dla_codesign::util::{MatrixF64, Pcg64};

/// The serial oracle: what a solo dispatch of this GEMM produces.
fn serial_gemm(alpha: f64, a: &MatrixF64, b: &MatrixF64, beta: f64, c0: &MatrixF64) -> MatrixF64 {
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let mut c = c0.clone();
    eng.gemm(alpha, a.view(), b.view(), beta, &mut c.view_mut());
    c
}

fn gemm_req(alpha: f64, a: &MatrixF64, b: &MatrixF64, beta: f64, c0: &MatrixF64) -> DlaRequest {
    DlaRequest::Gemm { alpha, a: a.clone(), b: b.clone(), beta, c: c0.clone() }
}

#[test]
fn engine_batch_is_bitwise_identical_to_serial_for_every_member() {
    // Mixed shapes and coefficients, batch wider than the team
    // (chunking), on sequential and pooled engines.
    let shapes = [
        (40usize, 24usize, 16usize),
        (24, 40, 8),
        (33, 17, 9),
        (40, 24, 16),
        (12, 12, 12),
        (64, 6, 30),
    ];
    let coeffs = [(1.0, 0.0), (-1.0, 1.0), (0.5, -2.0), (2.0, 1.0), (1.0, 1.0), (-0.5, 0.0)];
    let mut rng = Pcg64::seed(31337);
    let inputs: Vec<(MatrixF64, MatrixF64, MatrixF64)> = shapes
        .iter()
        .map(|&(m, n, k)| {
            (
                MatrixF64::random(m, k, &mut rng),
                MatrixF64::random(k, n, &mut rng),
                MatrixF64::random(m, n, &mut rng),
            )
        })
        .collect();
    let refs: Vec<MatrixF64> = inputs
        .iter()
        .zip(coeffs)
        .map(|((a, b, c0), (alpha, beta))| serial_gemm(alpha, a, b, beta, c0))
        .collect();
    for threads in [1usize, 2, 4] {
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        let mut cs: Vec<MatrixF64> = inputs.iter().map(|(_, _, c0)| c0.clone()).collect();
        let mut items: Vec<GemmBatchItem<'_>> = inputs
            .iter()
            .zip(cs.iter_mut())
            .zip(coeffs)
            .map(|(((a, b, _), c), (alpha, beta))| GemmBatchItem {
                alpha,
                a: a.view(),
                b: b.view(),
                beta,
                c: c.view_mut(),
            })
            .collect();
        eng.gemm_batch(&mut items);
        drop(items);
        for (i, (c, expect)) in cs.iter().zip(&refs).enumerate() {
            assert_eq!(
                c.max_abs_diff(expect),
                0.0,
                "member {i} (x{threads}) must be bitwise identical to the serial path"
            );
        }
    }
}

#[test]
fn batched_server_is_bitwise_identical_to_serialized_server() {
    // The same request stream through a batching server and a pinned-off
    // server must produce byte-identical responses.
    let mut rng = Pcg64::seed(99);
    let shapes = [(32usize, 32usize, 16usize), (24, 48, 8)];
    let reqs: Vec<(f64, MatrixF64, MatrixF64, f64, MatrixF64)> = (0..12)
        .map(|i| {
            let (m, n, k) = shapes[i % shapes.len()];
            (
                1.0 - (i % 3) as f64,
                MatrixF64::random(m, k, &mut rng),
                MatrixF64::random(k, n, &mut rng),
                (i % 2) as f64,
                MatrixF64::random(m, n, &mut rng),
            )
        })
        .collect();
    let run = |batching: BatchPolicy| -> Vec<MatrixF64> {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3)
                .with_batching(batching),
        )
        .unwrap();
        let pending: Vec<_> = reqs
            .iter()
            .map(|(alpha, a, b, beta, c0)| server.submit(gemm_req(*alpha, a, b, *beta, c0)).unwrap())
            .collect();
        // Recv after shutdown: the drain guarantees every reply.
        server.shutdown();
        pending
            .into_iter()
            .map(|rx| match rx.recv().unwrap().unwrap() {
                DlaResponse::Matrix { result, .. } => result,
                _ => panic!("unexpected response kind"),
            })
            .collect()
    };
    let serial = run(BatchPolicy::disabled());
    let batched = run(BatchPolicy::default().with_max_batch(4).with_wait_us(2_000).admit_all());
    for (i, (s, b)) in serial.iter().zip(&batched).enumerate() {
        assert_eq!(s.max_abs_diff(b), 0.0, "request {i}: batched bits differ from serialized");
    }
    // And both match the independent serial oracle.
    for (i, ((alpha, a, b, beta, c0), got)) in reqs.iter().zip(&batched).enumerate() {
        let expect = serial_gemm(*alpha, a, b, *beta, c0);
        assert_eq!(got.max_abs_diff(&expect), 0.0, "request {i} diverges from the oracle");
    }
}

#[test]
fn concurrent_submitters_all_get_exact_results() {
    // Many small GEMMs from many OS threads, racing into the admission
    // queue; every reply must be exact and every request accounted for.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(3)
            .with_gemm_threads(3)
            .with_batching(BatchPolicy::default().with_max_batch(4).with_wait_us(300).admit_all()),
    )
    .unwrap();
    let shapes = [(24usize, 24usize, 12usize), (16, 32, 8), (33, 9, 7)];
    const SUBMITTERS: usize = 6;
    const PER_THREAD: usize = 8;
    thread::scope(|s| {
        for t in 0..SUBMITTERS {
            let server = &server;
            s.spawn(move || {
                let mut rng = Pcg64::seed(5000 + t as u64);
                for i in 0..PER_THREAD {
                    let (m, n, k) = shapes[(t + i) % shapes.len()];
                    let a = MatrixF64::random(m, k, &mut rng);
                    let b = MatrixF64::random(k, n, &mut rng);
                    let c0 = MatrixF64::random(m, n, &mut rng);
                    let alpha = 1.0 + (i % 2) as f64;
                    let beta = (i % 3) as f64 - 1.0;
                    let resp = server.call(gemm_req(alpha, &a, &b, beta, &c0)).unwrap();
                    let DlaResponse::Matrix { result, .. } = resp else {
                        panic!("unexpected response kind");
                    };
                    let expect = serial_gemm(alpha, &a, &b, beta, &c0);
                    assert_eq!(
                        result.max_abs_diff(&expect),
                        0.0,
                        "submitter {t} request {i} not bitwise identical"
                    );
                }
            });
        }
    });
    let metrics = server.shutdown();
    let total = (SUBMITTERS * PER_THREAD) as u64;
    assert_eq!(metrics.count("gemm"), total);
    let b = metrics.batch_stats();
    assert_eq!(b.total_requests(), total, "every small gemm goes through the batcher: {b:?}");
    assert_eq!(b.queue_wait_ns.count, total);
}

#[test]
fn factorizations_and_large_gemms_bypass_batching() {
    // Default admission threshold: a 256^3 GEMM is model-rejected, LU and
    // Cholesky are never admitted. With a long wait, anything wrongly
    // admitted would stall visibly; everything must return promptly via
    // the solo (lookahead-composed) path.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_gemm_threads(3)
            .with_batching(BatchPolicy::default().with_wait_us(30_000_000)),
    )
    .unwrap();
    let mut rng = Pcg64::seed(77);
    // Large GEMM: solo path.
    let a = MatrixF64::random(256, 256, &mut rng);
    let b = MatrixF64::random(256, 256, &mut rng);
    let resp = server
        .call(DlaRequest::Gemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 0.0,
            c: MatrixF64::zeros(256, 256),
        })
        .unwrap();
    let DlaResponse::Matrix { result, .. } = resp else { panic!() };
    let expect = serial_gemm(1.0, &a, &b, 0.0, &MatrixF64::zeros(256, 256));
    assert_eq!(result.max_abs_diff(&expect), 0.0);
    // LU: bypass + correct.
    let spd = MatrixF64::random_diag_dominant(64, &mut rng);
    let resp = server.call(DlaRequest::LuFactor { a: spd.clone(), block: 16 }).unwrap();
    let DlaResponse::Lu { factors, .. } = resp else { panic!() };
    assert!(factors.reconstruction_error(&spd) < 1e-10);
    let metrics = server.shutdown();
    assert_eq!(metrics.count("gemm"), 1);
    assert_eq!(metrics.count("lu"), 1);
    assert_eq!(
        metrics.batch_stats().total_requests(),
        0,
        "nothing here is small enough to batch"
    );
}

#[test]
fn shutdown_drains_queued_batches_without_waiting() {
    // A pathological coalescing window: only the shutdown drain can
    // answer these requests, and it must do so immediately (stage-2 of
    // the documented drain semantics).
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_gemm_threads(3)
            .with_batching(
                BatchPolicy::default().with_max_batch(64).with_wait_us(3_600_000_000).admit_all(),
            ),
    )
    .unwrap();
    let mut rng = Pcg64::seed(1234);
    let inputs: Vec<(MatrixF64, MatrixF64, MatrixF64)> = (0..5)
        .map(|_| {
            (
                MatrixF64::random(20, 12, &mut rng),
                MatrixF64::random(12, 16, &mut rng),
                MatrixF64::random(20, 16, &mut rng),
            )
        })
        .collect();
    let pending: Vec<_> =
        inputs.iter().map(|(a, b, c0)| server.submit(gemm_req(1.0, a, b, 1.0, c0)).unwrap()).collect();
    let t0 = std::time::Instant::now();
    let metrics = server.shutdown();
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(60),
        "shutdown must flush, not sit out the hour-long window"
    );
    for (rx, (a, b, c0)) in pending.into_iter().zip(&inputs) {
        let DlaResponse::Matrix { result, .. } = rx.recv().unwrap().unwrap() else { panic!() };
        let expect = serial_gemm(1.0, a, b, 1.0, c0);
        assert_eq!(result.max_abs_diff(&expect), 0.0);
    }
    assert_eq!(metrics.count("gemm"), 5);
    let bm = metrics.batch_stats();
    assert_eq!(bm.total_requests(), 5);
    // All five share one shape bucket, so the close-time flush coalesces
    // them into a single fused dispatch.
    assert_eq!((bm.batches, bm.coalesced_requests, bm.solo), (1, 5, 0), "{bm:?}");
}

#[test]
fn dropping_without_shutdown_still_answers_and_exits() {
    // Dropping the server (no shutdown) closes the channel and the
    // admission queue: parked buckets are flushed by the batcher's
    // closed-path, and anything a worker admits after the close is
    // handed back and served solo — every reply still arrives, and no
    // thread is left parked holding the pool.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_gemm_threads(3)
            .with_batching(
                BatchPolicy::default().with_max_batch(64).with_wait_us(3_600_000_000).admit_all(),
            ),
    )
    .unwrap();
    let mut rng = Pcg64::seed(555);
    let inputs: Vec<(MatrixF64, MatrixF64, MatrixF64)> = (0..4)
        .map(|_| {
            (
                MatrixF64::random(16, 8, &mut rng),
                MatrixF64::random(8, 12, &mut rng),
                MatrixF64::random(16, 12, &mut rng),
            )
        })
        .collect();
    let pending: Vec<_> =
        inputs.iter().map(|(a, b, c0)| server.submit(gemm_req(1.0, a, b, 0.5, c0)).unwrap()).collect();
    drop(server);
    for (rx, (a, b, c0)) in pending.into_iter().zip(&inputs) {
        let DlaResponse::Matrix { result, .. } = rx.recv().unwrap().unwrap() else { panic!() };
        let expect = serial_gemm(1.0, a, b, 0.5, c0);
        assert_eq!(result.max_abs_diff(&expect), 0.0);
    }
}

#[test]
fn batch_metrics_are_sane_under_forced_coalescing() {
    // Deterministic coalescing: exactly max_batch identical-shape
    // requests + an effectively infinite window => one full-trigger
    // dispatch of exactly max_batch members.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_gemm_threads(4)
            .with_batching(
                BatchPolicy::default().with_max_batch(4).with_wait_us(3_600_000_000).admit_all(),
            ),
    )
    .unwrap();
    let mut rng = Pcg64::seed(4321);
    let pending: Vec<_> = (0..4)
        .map(|_| {
            let a = MatrixF64::random(24, 16, &mut rng);
            let b = MatrixF64::random(16, 24, &mut rng);
            let c0 = MatrixF64::zeros(24, 24);
            server.submit(gemm_req(1.0, &a, &b, 0.0, &c0)).unwrap()
        })
        .collect();
    for rx in pending {
        // Replies must arrive *before* shutdown: the full trigger fires
        // on its own.
        rx.recv().unwrap().unwrap();
    }
    let metrics = server.shutdown();
    let bm = metrics.batch_stats();
    assert_eq!(bm.total_requests(), 4);
    assert_eq!(bm.solo, 0, "{bm:?}");
    assert_eq!(bm.batches, 1, "{bm:?}");
    assert_eq!(bm.size_hist[3], 1, "one dispatch of size 4: {bm:?}");
    assert_eq!(bm.queue_wait_ns.count, 4);
    assert!(bm.queue_wait_ns.max >= 0.0);
    let s = metrics.summary();
    assert!(s.contains("batching: 1 fused dispatches"), "{s}");
}

#[test]
fn f32_batched_server_is_bitwise_identical_to_serialized_f32() {
    use dla_codesign::util::MatrixF32;
    // The dtype-aware buckets: a stream of same-shape f32 GEMMs through
    // a batching server must coalesce (dtype-keyed bucket, fused
    // gemm_batch_t::<f32> dispatch) and every member must be bitwise
    // identical to a solo f32 engine dispatch. Mixed-precision
    // interleaving exercises the key: f64 requests of the *same shape*
    // flow alongside and must never share a fused epoch with the f32s.
    let mut rng = Pcg64::seed(271828);
    let shapes = [(32usize, 32usize, 16usize), (24, 48, 8)];
    let reqs32: Vec<(f32, MatrixF32, MatrixF32, f32, MatrixF32)> = (0..8)
        .map(|i| {
            let (m, n, k) = shapes[i % shapes.len()];
            (
                1.0 - (i % 3) as f32,
                MatrixF32::random(m, k, &mut rng),
                MatrixF32::random(k, n, &mut rng),
                (i % 2) as f32,
                MatrixF32::random(m, n, &mut rng),
            )
        })
        .collect();
    let run = |batching: BatchPolicy| -> Vec<MatrixF32> {
        let server = CoordinatorServer::start(
            ServerConfig::new(host_xeon(), ConfigMode::Refined)
                .with_workers(2)
                .with_gemm_threads(3)
                .with_batching(batching),
        )
        .unwrap();
        let pending: Vec<_> = reqs32
            .iter()
            .map(|(alpha, a, b, beta, c0)| {
                // Same-shape f64 decoy sharing the admission window.
                let a64 = MatrixF64::random(a.rows(), a.cols(), &mut Pcg64::seed(7));
                let b64 = MatrixF64::random(b.rows(), b.cols(), &mut Pcg64::seed(8));
                let c64 = MatrixF64::zeros(a.rows(), b.cols());
                let _ = server.submit(gemm_req(1.0, &a64, &b64, 0.0, &c64)).unwrap();
                server
                    .submit(DlaRequest::GemmF32 {
                        alpha: *alpha,
                        a: a.clone(),
                        b: b.clone(),
                        beta: *beta,
                        c: c0.clone(),
                    })
                    .unwrap()
            })
            .collect();
        server.shutdown();
        pending
            .into_iter()
            .map(|rx| match rx.recv().unwrap().unwrap() {
                DlaResponse::MatrixF32 { result, .. } => result,
                _ => panic!("f32 request must answer as MatrixF32"),
            })
            .collect()
    };
    let serial = run(BatchPolicy::disabled());
    let batched = run(BatchPolicy::default().with_max_batch(4).with_wait_us(2_000).admit_all());
    for (i, (s, b)) in serial.iter().zip(&batched).enumerate() {
        assert_eq!(s.max_abs_diff(b), 0.0, "f32 request {i}: batched bits differ from serialized");
    }
    // And both match an independent solo f32 engine oracle.
    for (i, ((alpha, a, b, beta, c0), got)) in reqs32.iter().zip(&batched).enumerate() {
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let mut c = c0.clone();
        eng.gemm_t::<f32>(*alpha, a.view(), b.view(), *beta, &mut c.view_mut());
        assert_eq!(got.max_abs_diff(&c), 0.0, "f32 request {i} diverges from the solo oracle");
    }
}
