//! Measurement-calibrated selection suite (`DLA_CALIBRATE` /
//! `ServerConfig::with_calibration` — see `model::profile`): calibration
//! **off** must be bitwise invisible (attach-then-detach restores the
//! pure-analytic engine across the lookahead AND DAG schedulers, a cold
//! store selects exactly the analytic config), calibration **on** must
//! converge (overwhelming measured evidence steers the selection to the
//! measured-best candidate), stale measurements must not outlive
//! `clear_config_cache`, exploration must be deterministic, bounded, and
//! gated off for Interactive-tier traffic, the store must round-trip
//! through its JSON persistence (including the server's `DLA_PROFILE`
//! save-at-shutdown), and a mid-epoch pool panic must never corrupt the
//! store.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

use dla_codesign::arch::host_xeon;
use dla_codesign::coordinator::{
    CoordinatorServer, DlaRequest, Priority, ServerConfig,
};
use dla_codesign::gemm::{ConfigMode, GemmEngine, ParallelLoop, SchedPolicy, ThreadPlan};
use dla_codesign::lapack::lu_factor;
use dla_codesign::model::ccp::GemmConfig;
use dla_codesign::model::selector::{select_from_elem, AnalyticScorer};
use dla_codesign::model::{CalibratePolicy, GemmDims, PerfProfile};
use dla_codesign::runtime::{FaultPlan, FaultState, WorkerPool};
use dla_codesign::util::{DType, MatrixF64, Pcg64};

/// Serializes the tests that read or write process environment
/// (`DLA_PROFILE`) or that start calibrated servers which consult it.
static ENV_LOCK: Mutex<()> = Mutex::new(());

fn engine(threads: usize, sched: SchedPolicy) -> GemmEngine {
    let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined).with_sched(sched);
    if threads > 1 {
        eng.with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
    } else {
        eng
    }
}

fn gemm_req(rng: &mut Pcg64, m: usize, n: usize, k: usize) -> DlaRequest {
    DlaRequest::Gemm {
        alpha: 1.0,
        a: MatrixF64::random(m, k, rng),
        b: MatrixF64::random(k, n, rng),
        beta: 0.0,
        c: MatrixF64::zeros(m, n),
    }
}

#[test]
fn calibration_off_is_bitwise_invisible_across_schedulers() {
    // The transparency acceptance: an engine that had a profile attached
    // and detached again is the pure-analytic engine — factors bitwise
    // identical to a never-calibrated baseline, under both the lookahead
    // and the DAG scheduler, sequential and pooled.
    let mut rng = Pcg64::seed(7101);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    for sched in [SchedPolicy::Lookahead, SchedPolicy::Dag] {
        for threads in [1usize, 4] {
            let base = lu_factor(&a0, 16, &mut engine(threads, sched)).unwrap();
            let mut detached = engine(threads, sched);
            detached.set_calibration(Some(Arc::new(PerfProfile::new())));
            detached.set_calibration(None);
            let redo = lu_factor(&a0, 16, &mut detached).unwrap();
            assert_eq!(redo.pivots, base.pivots, "{sched:?} x{threads}: pivots differ");
            assert_eq!(
                redo.lu.max_abs_diff(&base.lu),
                0.0,
                "{sched:?} x{threads}: factors not bitwise identical"
            );
        }
    }
}

#[test]
fn cold_profile_selects_exactly_the_analytic_config() {
    // Zero observations → the blend returns the analytic prior exactly,
    // so a freshly attached store cannot move a selection. Distinct k
    // per query keeps the warm-sequence discount (a deliberate prior
    // change on repeated k) out of this transparency check.
    let analytic = engine(1, SchedPolicy::Lookahead);
    let mut calibrated = engine(1, SchedPolicy::Lookahead);
    let profile = Arc::new(PerfProfile::new());
    calibrated.set_calibration(Some(Arc::clone(&profile)));
    calibrated.set_explore_allowed(false);
    for (m, n, k) in [(64, 64, 64), (512, 512, 32), (300, 200, 100), (48, 1000, 16)] {
        let dims = GemmDims::new(m, n, k);
        assert_eq!(
            calibrated.plan_config(dims),
            analytic.plan_config(dims),
            "cold-store selection must equal the analytic one for {m}x{n}x{k}"
        );
    }
    let s = profile.stats();
    assert_eq!(s.blended, 0, "no observation may have entered a blend: {s:?}");
    assert_eq!(s.observations, 0, "plan_config alone must not record: {s:?}");
}

/// Steer one engine: overwhelming synthetic evidence that the
/// analytically-worst family member is actually the fastest. Returns
/// `None` (nothing to steer) on a single-kernel family.
fn steer() -> Option<(GemmEngine, Arc<PerfProfile>, GemmDims, GemmConfig, GemmConfig)> {
    let mut eng = engine(1, SchedPolicy::Lookahead);
    let profile = Arc::new(PerfProfile::new());
    eng.set_calibration(Some(Arc::clone(&profile)));
    eng.set_explore_allowed(false);
    let dims = GemmDims::new(256, 256, 64);
    let family = eng.family();
    if family.len() < 2 {
        return None;
    }
    let analytic_best = eng.plan_config(dims);
    let sel = select_from_elem(&host_xeon(), dims, &AnalyticScorer, &family, 8);
    assert_eq!(sel.config, analytic_best, "the memoized selection is the scorer's best");
    let worst = sel.ranked.last().unwrap().0;
    assert_ne!(worst, analytic_best, "ranked list must have distinct ends");
    // 64 observations at ~8 TFLOPS (2*256*256*64 flops in 1 µs): enough
    // to cross two generation bumps (so the memoized analytic selection
    // re-misses) and to pull the blend weight to 64/(64+4) ≈ 0.94.
    for _ in 0..64 {
        profile.record(dims, DType::F64, worst, 1, 1e-6);
    }
    Some((eng, profile, dims, analytic_best, worst))
}

#[test]
fn observations_steer_the_selection_to_the_measured_best() {
    let Some((eng, profile, dims, analytic_best, worst)) = steer() else {
        eprintln!("single-kernel family on this host; nothing to steer");
        return;
    };
    let steered = eng.plan_config(dims);
    assert_eq!(
        steered, worst,
        "measured truth must override the analytic ranking (analytic best {analytic_best:?})"
    );
    let s = profile.stats();
    assert!(s.blended > 0, "the re-selection must have consulted the store: {s:?}");
    assert_eq!(s.observations, 64, "{s:?}");
}

#[test]
fn clear_config_cache_drops_stale_measurements() {
    // The plan/arch-change regression: measurements taken under an old
    // configuration must not survive `clear_config_cache` — the store
    // empties, its generation bumps (so memoized decisions re-miss), and
    // the next selection is the pure-analytic one again.
    let Some((mut eng, profile, dims, analytic_best, worst)) = steer() else {
        eprintln!("single-kernel family on this host; nothing to steer");
        return;
    };
    assert_eq!(eng.plan_config(dims), worst, "precondition: the store steers the selection");
    let gen_before = profile.generation();
    eng.clear_config_cache();
    assert!(profile.is_empty(), "clear must empty the shared store");
    assert_eq!(profile.stats().observations, 0);
    assert!(profile.generation() > gen_before, "clear must bump the generation");
    assert_eq!(
        eng.plan_config(dims),
        analytic_best,
        "stale measurements must not outlive the clear"
    );
}

#[test]
fn exploration_is_deterministic_bounded_and_gated() {
    let mut eng = engine(1, SchedPolicy::Lookahead);
    let profile = Arc::new(PerfProfile::new());
    eng.set_calibration(Some(Arc::clone(&profile)));
    if eng.family().len() < 2 {
        eprintln!("single-kernel family on this host; exploration has no runner-up");
        return;
    }
    // Forbidden (the Interactive-tier stance): any number of cache-missing
    // re-selections, zero explorations.
    eng.set_explore_allowed(false);
    for i in 0..40 {
        let _ = eng.plan_config(GemmDims::new(32 + i, 32, 32));
    }
    assert_eq!(
        profile.stats().explorations,
        0,
        "explore-forbidden engines must never take the runner-up"
    );
    // Allowed: every 16th missing re-selection explores — ticks 41..=80
    // contain the multiples 48, 64, 80, so exactly 3 explorations, with
    // no RNG anywhere (re-runs reproduce the count bit for bit).
    eng.set_explore_allowed(true);
    for i in 0..40 {
        let _ = eng.plan_config(GemmDims::new(200 + i, 48, 24));
    }
    assert_eq!(profile.stats().explorations, 3, "deterministic 1-in-16 exploration");
    // A fresh engine on a fresh store restarts the tick: 40 misses from
    // zero hit the multiples 16 and 32.
    let mut eng2 = engine(1, SchedPolicy::Lookahead);
    let p2 = Arc::new(PerfProfile::new());
    eng2.set_calibration(Some(Arc::clone(&p2)));
    for i in 0..40 {
        let _ = eng2.plan_config(GemmDims::new(32 + i, 32, 32));
    }
    assert_eq!(p2.stats().explorations, 2, "tick restarts with the attachment");
}

#[test]
fn profile_round_trips_through_disk() {
    let profile = Arc::new(PerfProfile::new());
    let mut eng = engine(1, SchedPolicy::Lookahead);
    eng.set_calibration(Some(Arc::clone(&profile)));
    let dims = GemmDims::new(128, 96, 32);
    let cfg = eng.plan_config(dims);
    for _ in 0..8 {
        profile.record(dims, DType::F64, cfg, 1, 2e-6);
    }
    for _ in 0..3 {
        profile.record(dims, DType::F32, cfg, 2, 1e-6);
    }
    let path = std::env::temp_dir()
        .join(format!("dla_profile_rt_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    profile.save_to_path(&path).expect("temp-dir write");
    let restored = PerfProfile::new();
    assert_eq!(restored.load_from_path(&path), profile.len(), "every entry must reload");
    // Canonical writer: the reloaded store serializes byte-identically,
    // and blends exactly like the original.
    assert_eq!(restored.to_json(), profile.to_json());
    let analytic = 1.0;
    assert_eq!(
        restored.blend(dims, DType::F64, cfg, 1, analytic),
        profile.blend(dims, DType::F64, cfg, 1, analytic),
        "a reloaded store must blend identically"
    );
    std::fs::remove_file(&path).ok();
}

#[test]
fn calibrated_server_records_persists_and_never_explores_interactive() {
    let _guard = ENV_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    let path = std::env::temp_dir()
        .join(format!("dla_profile_server_{}.json", std::process::id()))
        .to_string_lossy()
        .into_owned();
    std::fs::remove_file(&path).ok();
    std::env::set_var("DLA_PROFILE", &path);
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(2)
            .with_calibration(CalibratePolicy::On),
    )
    .unwrap();
    let profile = server.profile().expect("calibrated server must expose its store");
    let mut rng = Pcg64::seed(7107);
    for _ in 0..6 {
        let rx = server.submit_at(gemm_req(&mut rng, 48, 40, 16), Priority::Interactive).unwrap();
        rx.recv().unwrap().unwrap();
    }
    assert!(profile.stats().observations > 0, "served GEMMs must be timed into the store");
    assert_eq!(
        profile.stats().explorations,
        0,
        "Interactive traffic must never pay for exploration"
    );
    let metrics = server.shutdown();
    std::env::remove_var("DLA_PROFILE");
    let c = *metrics.calibration_stats();
    assert!(c.enabled, "{c:?}");
    assert!(c.observations > 0, "{c:?}");
    assert!(c.config_misses > 0, "the memo counters must surface too: {c:?}");
    let s = metrics.summary();
    assert!(s.contains("calibration:"), "{s}");
    let j = metrics.snapshot_json();
    assert!(j.contains("\"calibration\":{\"enabled\":true"), "{j}");
    // The shutdown save landed and a fresh store reloads it (the
    // cross-process DLA_PROFILE round-trip).
    let restored = PerfProfile::new();
    assert!(restored.load_from_path(&path) > 0, "persisted store must reload");
    std::fs::remove_file(&path).ok();
}

#[test]
fn uncalibrated_server_attaches_no_store() {
    let _guard = ENV_LOCK.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
    // Pinned Off wins over any ambient DLA_CALIBRATE (the CI calibrate
    // leg exports it): no store, no timing, and the summary keeps its
    // pre-calibration shape.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_calibration(CalibratePolicy::Off),
    )
    .unwrap();
    assert!(server.profile().is_none(), "Off must attach nothing");
    let mut rng = Pcg64::seed(7109);
    let rx = server.submit(gemm_req(&mut rng, 30, 20, 10)).unwrap();
    rx.recv().unwrap().unwrap();
    let metrics = server.shutdown();
    assert!(!metrics.calibration_stats().enabled);
    assert!(
        !metrics.summary().contains("calibration:"),
        "default summary output must stay byte-identical"
    );
}

#[test]
fn pool_panic_never_corrupts_the_profile_store() {
    // One-shot worker panic inside the first pooled epoch of a
    // calibrated factorization: the unwinding dispatch must skip its
    // timing hook (no garbage sample), the store must stay internally
    // consistent (its canonical JSON still parses), and the same engine
    // must keep calibrating afterwards.
    let plan = FaultPlan::parse("panic@1:1").expect("fault spec");
    let pool = Arc::new(WorkerPool::with_fault_state(4, Some(Arc::new(FaultState::new(plan)))));
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    eng.set_shared_pool(Arc::clone(&pool));
    let profile = Arc::new(PerfProfile::new());
    eng.set_calibration(Some(Arc::clone(&profile)));
    let mut rng = Pcg64::seed(7108);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let shot = catch_unwind(AssertUnwindSafe(|| lu_factor(&a0, 16, &mut eng)));
    assert!(shot.is_err(), "the injected panic must unwind out of the dispatch");
    let s = pool.stats();
    assert!(s.epochs_poisoned >= 1, "the shot must poison an epoch: {s:?}");
    let before = profile.stats();
    let json = profile.to_json();
    let restored = PerfProfile::new();
    restored.load_json(&json).expect("post-panic store must still serialize consistently");
    assert_eq!(restored.len(), profile.len());
    // Post-recovery, same pool, same engine, same store: accurate
    // factors and a growing observation count.
    let redo = lu_factor(&a0, 16, &mut eng).unwrap();
    let err = redo.reconstruction_error(&a0);
    assert!(err < 1e-10, "|PA-LU| = {err}");
    assert!(
        profile.stats().observations > before.observations,
        "the recovered engine must keep recording: {:?} -> {:?}",
        before,
        profile.stats()
    );
}
