//! QoS suite: priority tiers, weighted-fair dispatch, async submit
//! handles, adaptive load shedding, and the degraded-window override —
//! all through the public serving API.
//!
//! Tests that assert exact counters pin a [`FaultPlan`] (the empty
//! `arm` plan when no faults are wanted): a pinned plan always beats the
//! `DLA_FAULTS` environment override, so the CI overload leg's
//! `flood:64` cannot skew these ledgers.

use std::time::{Duration, Instant};

use dla_codesign::arch::host_xeon;
use dla_codesign::coordinator::qos::QosQueue;
use dla_codesign::coordinator::{
    BatchPolicy, CoordinatorServer, DlaError, DlaRequest, DlaResponse, OverloadLevel, Priority,
    ServerConfig,
};
use dla_codesign::gemm::{ConfigMode, GemmEngine};
use dla_codesign::runtime::FaultPlan;
use dla_codesign::util::{MatrixF64, Pcg64};

/// The serial oracle: what a solo, pool-less dispatch of this GEMM
/// produces (bitwise — the pooled G4 schedule is team-width
/// independent, see `tests/batching.rs`).
fn serial_gemm(alpha: f64, a: &MatrixF64, b: &MatrixF64, beta: f64, c0: &MatrixF64) -> MatrixF64 {
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let mut c = c0.clone();
    eng.gemm(alpha, a.view(), b.view(), beta, &mut c.view_mut());
    c
}

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).expect("test fault spec must parse")
}

fn gemm_req(rng: &mut Pcg64, m: usize, n: usize, k: usize) -> DlaRequest {
    DlaRequest::Gemm {
        alpha: 1.0,
        a: MatrixF64::random(m, k, rng),
        b: MatrixF64::random(k, n, rng),
        beta: 0.0,
        c: MatrixF64::zeros(m, n),
    }
}

/// Weighted-fair dispatch with a hard starvation bound, under sustained
/// higher-tier pressure: a parked Background item is dequeued within one
/// credit cycle even though Interactive work keeps arriving.
#[test]
fn background_survives_sustained_interactive_pressure() {
    let q = QosQueue::<u32>::new(64);
    q.try_push(Priority::Background, 200).expect("push");
    for i in 0..4u32 {
        q.try_push(Priority::Interactive, i).expect("push");
    }
    let mut seq = Vec::new();
    for i in 0..6u32 {
        seq.push(q.pop().expect("queue is non-empty"));
        // Sustained pressure: every dequeue is matched by a fresh
        // Interactive arrival.
        q.try_push(Priority::Interactive, 10 + i).expect("push");
    }
    // One cycle: 4 Interactive credits spend first, then (Batch empty)
    // the Background credit — the parked item cannot be starved.
    assert_eq!(seq[..4], [0, 1, 2, 3], "interactive drains FIFO first");
    assert_eq!(seq[4], 200, "background dispatches within its credit cycle");
    assert_eq!(seq[5], 10, "refilled credits return to interactive");
    let bg_position = seq.iter().position(|&v| v == 200).expect("background served");
    assert!(bg_position < 7, "starvation bound is one full credit cycle");
    // Close → drain-then-None.
    q.close();
    let mut drained = 0;
    while q.pop().is_some() {
        drained += 1;
    }
    assert_eq!(drained, 5, "close drains the already-queued items");
    assert!(q.pop().is_none(), "closed and drained");
    assert!(
        q.try_push(Priority::Interactive, 99).is_err(),
        "closed queue refuses new work"
    );
}

/// Async handles across all three tiers: poll → wait round-trips, every
/// completed request bitwise identical to the serial oracle, and the
/// per-tier ledger reconciles exactly.
#[test]
fn async_mixed_tier_results_are_bitwise_identical() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(2)
            .with_gemm_threads(4)
            .with_batching(BatchPolicy::disabled())
            .with_faults(plan("arm")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(800);
    let inputs: Vec<_> = (0..9)
        .map(|_| {
            (
                MatrixF64::random(96, 64, &mut rng),
                MatrixF64::random(64, 80, &mut rng),
                MatrixF64::random(96, 80, &mut rng),
            )
        })
        .collect();
    let handles: Vec<_> = inputs
        .iter()
        .enumerate()
        .map(|(i, (a, b, c0))| {
            let tier = Priority::ALL[i % 3];
            server
                .submit_async_at(
                    DlaRequest::Gemm {
                        alpha: 1.0,
                        a: a.clone(),
                        b: b.clone(),
                        beta: 1.0,
                        c: c0.clone(),
                    },
                    tier,
                )
                .expect("submit_async_at")
        })
        .collect();
    for (i, mut h) in handles.into_iter().enumerate() {
        // Exercise the poll path before the blocking wait: polling must
        // never lose the result.
        let t0 = Instant::now();
        while !h.poll() {
            assert!(t0.elapsed() < Duration::from_secs(60), "request {i} must complete");
            std::thread::sleep(Duration::from_millis(1));
        }
        let resp = h.wait().expect("polled-ready request must succeed");
        let DlaResponse::Matrix { result, .. } = resp else { panic!("unexpected response kind") };
        let (a, b, c0) = &inputs[i];
        let oracle = serial_gemm(1.0, a, b, 1.0, c0);
        assert_eq!(result.max_abs_diff(&oracle), 0.0, "request {i} diverged from the oracle");
    }

    let metrics = server.shutdown();
    let q = metrics.qos_stats();
    assert_eq!(q.submitted, [3, 3, 3], "{q:?}");
    assert_eq!(q.completed, [3, 3, 3], "{q:?}");
    assert!(q.reconciles(), "{q:?}");
    let s = metrics.summary();
    assert!(s.contains("qos interactive: 3 submitted, 3 completed"), "{s}");
    assert!(s.contains("qos background: 3 submitted, 3 completed"), "{s}");
}

/// Cancellation semantics: a still-queued job is guaranteed cancellable
/// (typed [`DlaError::Cancelled`], never started); a claimed job runs to
/// completion and reports that the cancel lost.
#[test]
fn cancel_is_guaranteed_for_queued_work_only() {
    // One worker stalling 100 ms per request: the second submission is
    // reliably still queued when we cancel it.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_faults(plan("stall:100")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(801);
    let mut in_flight = server.submit_async(gemm_req(&mut rng, 24, 24, 12)).expect("submit");
    assert!(
        in_flight.wait_for(Duration::from_millis(1)).is_none(),
        "stalled request cannot be done after 1 ms; the handle stays usable"
    );
    // Give the worker time to claim the first job, then park a second.
    std::thread::sleep(Duration::from_millis(30));
    let mut queued = server.submit_async(gemm_req(&mut rng, 24, 24, 12)).expect("submit");
    assert!(queued.cancel(), "still-queued work must be cancellable");
    assert!(!queued.cancel(), "a second cancel reports the job already cancelled");
    let err = queued.wait().err().expect("cancelled job must not produce a result");
    assert_eq!(err, DlaError::Cancelled);
    assert!(!err.is_transient(), "a cancelled request must not be blindly retried");

    // The in-flight job ran to completion; cancelling it now loses.
    let t0 = Instant::now();
    while !in_flight.poll() {
        assert!(t0.elapsed() < Duration::from_secs(30), "first request must complete");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(!in_flight.cancel(), "completed work cannot be cancelled");
    in_flight.wait().expect("in-flight work runs to completion");

    let metrics = server.shutdown();
    let q = metrics.qos_stats();
    assert_eq!(q.submitted[Priority::Interactive.index()], 2, "{q:?}");
    assert_eq!(q.completed[Priority::Interactive.index()], 1, "{q:?}");
    assert_eq!(q.cancelled[Priority::Interactive.index()], 1, "{q:?}");
    assert!(q.reconciles(), "{q:?}");
    assert!(metrics.summary().contains("1 cancelled"), "{}", metrics.summary());
}

/// Adaptive shedding under sustained overload: Background submissions
/// are refused with a typed [`DlaError::Overloaded`] once measured queue
/// delay runs far ahead of the cost baseline, Interactive is still
/// admitted, every accepted request completes, and the ledger
/// reconciles — no silent drops.
#[test]
fn background_sheds_under_overload_while_interactive_is_admitted() {
    // One worker, 30 ms stall per request: queue wait grows ~30 ms per
    // parked request while measured service cost stays small, so the
    // wait/cost ratio crosses the Background shed threshold quickly.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_faults(plan("stall:30")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(802);
    let mut accepted = Vec::new();
    for _ in 0..8 {
        accepted.push(
            server
                .submit_at(gemm_req(&mut rng, 16, 16, 8), Priority::Background)
                .expect("cold server must admit background work"),
        );
    }
    // Let the worker observe the growing queue waits.
    std::thread::sleep(Duration::from_millis(250));
    let mut shed = None;
    for _ in 0..50 {
        match server.submit_at(gemm_req(&mut rng, 16, 16, 8), Priority::Background) {
            Ok(rx) => accepted.push(rx),
            Err(e) => {
                shed = Some(e);
                break;
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    }
    let err = shed.expect("sustained overload must shed background work");
    match &err {
        DlaError::Overloaded { tier, queue_delay_us } => {
            assert_eq!(*tier, "background");
            assert!(*queue_delay_us > 0, "the rejection reports the measured delay");
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    assert!(err.is_transient(), "overload is retryable later");
    assert!(
        server.overload_level() >= OverloadLevel::SheddingBackground,
        "the detector must report the shedding level"
    );
    // Interactive is never shed: it still gets in while Background is
    // refused.
    let vip = server
        .submit_at(gemm_req(&mut rng, 16, 16, 8), Priority::Interactive)
        .expect("interactive must be admitted under overload");

    // Every accepted request completes (shedding only refuses at
    // admission; it never drops queued work).
    for rx in accepted {
        rx.recv().expect("accepted request is answered").expect("and succeeds");
    }
    vip.recv().expect("answered").expect("succeeds");

    let metrics = server.shutdown();
    let q = metrics.qos_stats();
    let bg = Priority::Background.index();
    assert!(q.shed[bg] >= 1, "{q:?}");
    assert_eq!(q.submitted[bg], q.completed[bg] + q.shed[bg], "{q:?}");
    assert_eq!(q.completed[Priority::Interactive.index()], 1, "{q:?}");
    assert!(q.reconciles(), "{q:?}");
    assert!(metrics.summary().contains("shed"), "{}", metrics.summary());
}

/// The `flood:N` drill: the server injects N synthetic Background
/// requests through the real admission path at start; they are served,
/// counted, and the ledger reconciles.
#[test]
fn flood_drill_is_injected_served_and_ledgered() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined).with_faults(plan("flood:16")),
    )
    .expect("server start");
    let faults = server.fault_state().expect("pinned plan must be armed");
    assert_eq!(faults.injected().floods, 16, "the flood is claimed at start, exactly once");

    let metrics = server.shutdown();
    assert_eq!(metrics.count("gemm"), 16, "every probe is a real served gemm");
    let q = metrics.qos_stats();
    let bg = Priority::Background.index();
    assert_eq!(q.submitted[bg], 16, "{q:?}");
    assert_eq!(q.completed[bg], 16, "{q:?}");
    assert!(q.reconciles(), "{q:?}");
}

/// The degraded-window override: a pinned window of 4 arms exactly 4
/// serial-fallback slots after a panic; the unconsumed remainder
/// surfaces as the `degraded-window remaining` gauge.
#[test]
fn degraded_window_override_and_remaining_gauge() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_batching(BatchPolicy::disabled())
            .with_degraded_window(4)
            .with_faults(plan("panic@1:1")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(803);
    let inputs: Vec<_> = (0..3)
        .map(|_| {
            (
                MatrixF64::random(96, 64, &mut rng),
                MatrixF64::random(64, 80, &mut rng),
                MatrixF64::random(96, 80, &mut rng),
            )
        })
        .collect();
    for (i, (a, b, c0)) in inputs.iter().enumerate() {
        let resp = server.call(DlaRequest::Gemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 1.0,
            c: c0.clone(),
        });
        if i == 0 {
            assert!(
                matches!(resp, Err(DlaError::Internal { .. })),
                "the first pooled epoch takes the shot: {resp:?}"
            );
        } else {
            let DlaResponse::Matrix { result, .. } = resp.expect("degraded survivor") else {
                panic!("unexpected response kind");
            };
            let oracle = serial_gemm(1.0, a, b, 1.0, c0);
            assert_eq!(result.max_abs_diff(&oracle), 0.0, "degraded path must stay bitwise");
        }
    }

    let metrics = server.shutdown();
    let f = metrics.fault_stats();
    assert_eq!(f.worker_panics, 1);
    assert_eq!(f.degraded_requests, 2, "two survivors consumed two of the four slots");
    assert_eq!(f.degraded_remaining, 2, "the rest of the pinned window is still armed");
    let s = metrics.summary();
    assert!(s.contains("2 degraded-window remaining"), "{s}");
}

/// The pinned default tier routes bare `submit` calls: the ledger books
/// them under the configured tier, not Interactive.
#[test]
fn pinned_default_priority_routes_bare_submits() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_default_priority(Priority::Batch)
            .with_faults(plan("arm")),
    )
    .expect("server start");
    let mut rng = Pcg64::seed(804);
    let rx = server.submit(gemm_req(&mut rng, 24, 24, 8)).expect("submit");
    rx.recv().expect("answered").expect("succeeds");
    let metrics = server.shutdown();
    let q = metrics.qos_stats();
    assert_eq!(q.submitted[Priority::Batch.index()], 1, "{q:?}");
    assert_eq!(q.completed[Priority::Batch.index()], 1, "{q:?}");
    assert_eq!(q.submitted[Priority::Interactive.index()], 0, "{q:?}");
    assert!(q.reconciles(), "{q:?}");
}
