//! Lookahead-vs-baseline equivalence suite (ISSUE 2 acceptance): the
//! fused split-team pipeline must be a pure *scheduling* change — for LU,
//! pivot vectors and factors bitwise identical to the non-lookahead
//! pooled path; for QR and Cholesky, identical factors — across thread
//! counts, panel-team widths and non-divisible block sizes, with the
//! pool's no-spawn invariant intact.
//!
//! The `DLA_THREADS` environment variable (set by the CI matrix to 1 and
//! 4) adds that team width to the sweep, so both pool shapes are
//! exercised by the tier-1 job.

use std::sync::Arc;

use dla_codesign::arch::host_xeon;
use dla_codesign::gemm::{ConfigMode, GemmEngine, Lookahead, ParallelLoop, ThreadPlan};
use dla_codesign::lapack::{self, cholesky::cholesky_blocked, lu_factor, qr_blocked};
use dla_codesign::util::{MatrixF64, Pcg64};

fn engine(threads: usize, la: Lookahead) -> GemmEngine {
    GemmEngine::new(host_xeon(), ConfigMode::Refined)
        .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
        .with_lookahead(la)
}

/// Thread widths under test: the fixed {1, 2, 4} of the acceptance
/// criteria plus the CI matrix width from `DLA_THREADS`.
fn thread_sweep() -> Vec<usize> {
    let mut t = vec![1, 2, 4];
    if let Some(extra) = std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()) {
        if !t.contains(&extra) {
            t.push(extra);
        }
    }
    t
}

#[test]
fn lu_lookahead_bitwise_identical_to_baseline() {
    let mut rng = Pcg64::seed(1001);
    // Non-divisible block sizes on purpose: 37/5, 50/8, 96/32 leave
    // short trailing panels and nr-misaligned column splits.
    for (s, b) in [(37, 5), (50, 8), (96, 32), (64, 16)] {
        let a0 = MatrixF64::random(s, s, &mut rng);
        for threads in thread_sweep() {
            let base = lu_factor(&a0, b, &mut engine(threads, Lookahead::disabled())).unwrap();
            for t_p in [1, 2] {
                let la = Lookahead { depth: 1, panel_workers: t_p };
                let fused = lu_factor(&a0, b, &mut engine(threads, la)).unwrap();
                assert_eq!(
                    fused.pivots, base.pivots,
                    "s={s} b={b} x{threads} t_p={t_p}: pivot vectors differ"
                );
                assert_eq!(
                    fused.lu.max_abs_diff(&base.lu),
                    0.0,
                    "s={s} b={b} x{threads} t_p={t_p}: factors not bitwise identical"
                );
                let err = fused.reconstruction_error(&a0);
                assert!(err < 1e-10, "s={s} b={b} x{threads} t_p={t_p}: |PA-LU| = {err}");
            }
        }
    }
}

#[test]
fn lu_lookahead_detects_singularity_like_baseline() {
    // Column 3 duplicates column 2: both paths must fail at the same
    // column.
    let mut a = MatrixF64::identity(12);
    for i in 0..12 {
        let v = a[(i, 2)];
        a[(i, 3)] = v;
    }
    let base = lu_factor(&a, 4, &mut engine(2, Lookahead::disabled()));
    let fused = lu_factor(&a, 4, &mut engine(2, Lookahead { depth: 1, panel_workers: 1 }));
    let (Err(jb), Err(jf)) = (base.map(|_| ()), fused.map(|_| ())) else {
        panic!("rank-deficient matrix must be detected on both paths");
    };
    assert_eq!(jb, jf, "failing column must agree");
}

#[test]
fn cholesky_lookahead_matches_baseline() {
    let mut rng = Pcg64::seed(1002);
    for (s, b) in [(45, 8), (33, 7), (64, 16)] {
        // SPD input: M M^T + s I.
        let m = MatrixF64::random(s, s, &mut rng);
        let mt = m.transposed();
        let mut a0 = MatrixF64::zeros(s, s);
        dla_codesign::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a0.view_mut());
        for i in 0..s {
            a0[(i, i)] += s as f64;
        }
        for threads in thread_sweep() {
            let mut base = a0.clone();
            cholesky_blocked(&mut base, b, &mut engine(threads, Lookahead::disabled())).unwrap();
            for t_p in [1, 2] {
                let la = Lookahead { depth: 1, panel_workers: t_p };
                let mut fused = a0.clone();
                cholesky_blocked(&mut fused, b, &mut engine(threads, la)).unwrap();
                // Compare the lower triangles (the upper is workspace).
                for j in 0..s {
                    for i in j..s {
                        assert_eq!(
                            fused[(i, j)].to_bits(),
                            base[(i, j)].to_bits(),
                            "s={s} b={b} x{threads} t_p={t_p}: L({i},{j}) differs"
                        );
                    }
                }
                let res = lapack::cholesky::cholesky_residual(&a0, &fused);
                assert!(res < 1e-11, "s={s} b={b} x{threads} t_p={t_p}: residual {res}");
            }
        }
    }
}

#[test]
fn qr_lookahead_matches_baseline() {
    let mut rng = Pcg64::seed(1003);
    for (m, n, b) in [(40, 24, 8), (33, 17, 5), (48, 48, 16)] {
        let a0 = MatrixF64::random(m, n, &mut rng);
        for threads in thread_sweep() {
            let base = qr_blocked(&a0, b, &mut engine(threads, Lookahead::disabled()));
            for t_p in [1, 2] {
                let la = Lookahead { depth: 1, panel_workers: t_p };
                let fused = qr_blocked(&a0, b, &mut engine(threads, la));
                assert_eq!(
                    fused.qr.max_abs_diff(&base.qr),
                    0.0,
                    "m={m} n={n} b={b} x{threads} t_p={t_p}: packed factors differ"
                );
                for (j, (tf, tb)) in fused.tau.iter().zip(&base.tau).enumerate() {
                    assert_eq!(
                        tf.to_bits(),
                        tb.to_bits(),
                        "m={m} n={n} b={b} x{threads} t_p={t_p}: tau[{j}] differs"
                    );
                }
                let err = fused.reconstruction_error(&a0);
                assert!(err < 1e-10, "m={m} n={n} b={b} x{threads} t_p={t_p}: |A-QR| = {err}");
            }
        }
    }
}

#[test]
fn lookahead_factorizations_never_spawn_threads() {
    // The no-spawn invariant under lookahead: the fused jobs, the
    // sub-team panel factorization and the pooled laswp all run on the
    // same parked team.
    let mut rng = Pcg64::seed(1004);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let mut eng = engine(4, Lookahead { depth: 1, panel_workers: 2 });
    let pool = Arc::clone(eng.pool().expect("parallel plan provisions a pool"));
    assert_eq!(pool.spawned_workers(), 3);
    for _ in 0..3 {
        lu_factor(&a0, 32, &mut eng).unwrap();
    }
    let spd = {
        let m = MatrixF64::random(64, 64, &mut rng);
        let mt = m.transposed();
        let mut a = MatrixF64::zeros(64, 64);
        dla_codesign::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a.view_mut());
        for i in 0..64 {
            a[(i, i)] += 64.0;
        }
        a
    };
    let mut chol = spd.clone();
    cholesky_blocked(&mut chol, 16, &mut eng).unwrap();
    qr_blocked(&a0, 16, &mut eng);
    assert_eq!(
        pool.spawned_workers(),
        3,
        "lookahead factorizations must reuse the pool, never spawn"
    );
    // And the fused jobs actually ran on the pool.
    assert!(pool.stats().jobs > 0);
}

#[test]
fn lookahead_reduces_or_preserves_pool_jobs_shape() {
    // Sanity on the pipeline structure rather than wall-clock (the host
    // may be single-core): with lookahead the panel factorization rides
    // inside the fused trailing-update job, so the per-iteration job
    // count does not grow even though more work moved onto the pool.
    let mut rng = Pcg64::seed(1005);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let mut on = engine(4, Lookahead { depth: 1, panel_workers: 1 });
    lu_factor(&a0, 16, &mut on).unwrap();
    let jobs_on = on.pool().unwrap().stats().jobs;
    let mut off = engine(4, Lookahead::disabled());
    lu_factor(&a0, 16, &mut off).unwrap();
    let jobs_off = off.pool().unwrap().stats().jobs;
    assert!(
        jobs_on <= jobs_off,
        "fused pipeline must not add pool jobs: on={jobs_on} off={jobs_off}"
    );
}
