//! Lookahead-vs-baseline equivalence suite (ISSUE 2 + ISSUE 3
//! acceptance): the fused pipeline — static depth-1 and the dynamic
//! deep work-queue alike — must be a pure *scheduling* change. For LU,
//! pivot vectors and factors bitwise identical to the non-lookahead
//! pooled path; for QR and Cholesky, identical factors — across
//! depth ∈ {1, 2, 3}, thread counts {1, 2, 4}, panel-team policies
//! (model-driven, pinned, per-iteration schedule) and non-divisible
//! block sizes, with the pool's no-spawn invariant intact.
//!
//! The `DLA_THREADS` environment variable (set by the CI matrix to 1 and
//! 4) adds that team width to the sweep, so both pool shapes are
//! exercised by the tier-1 job; `DLA_LOOKAHEAD=2` in the CI matrix flips
//! every un-pinned engine in the whole test suite onto the depth-2 queue.

use std::sync::Arc;

use dla_codesign::gemm::{
    ConfigMode, GemmEngine, Lookahead, ParallelLoop, SchedPolicy, ThreadPlan, AUTO_PANEL_WORKERS,
};
use dla_codesign::arch::host_xeon;
use dla_codesign::lapack::{self, cholesky::cholesky_blocked, lu_factor, qr_blocked};
use dla_codesign::util::{MatrixF64, Pcg64};

/// Every engine in this suite pins the lookahead scheduler: the CI
/// matrix's `DLA_SCHED=dag` leg must not silently turn these into
/// DAG-vs-DAG comparisons (the DAG suite is `tests/dag.rs`).
fn engine(threads: usize, la: Lookahead) -> GemmEngine {
    GemmEngine::new(host_xeon(), ConfigMode::Refined)
        .with_plan(ThreadPlan { threads, target: ParallelLoop::G4 })
        .with_lookahead(la)
        .with_sched(SchedPolicy::Lookahead)
}

/// Thread widths under test: the fixed {1, 2, 4} of the acceptance
/// criteria plus the CI matrix width from `DLA_THREADS`.
fn thread_sweep() -> Vec<usize> {
    let mut t = vec![1, 2, 4];
    if let Some(extra) = std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()) {
        if !t.contains(&extra) {
            t.push(extra);
        }
    }
    t
}

/// Depths under test; panel-team policies per depth (model-driven AUTO
/// and a pinned 1-rank team — t_p must never change results).
const DEPTHS: [usize; 3] = [1, 2, 3];

fn spd(s: usize, rng: &mut Pcg64) -> MatrixF64 {
    let m = MatrixF64::random(s, s, rng);
    let mt = m.transposed();
    let mut a = MatrixF64::zeros(s, s);
    dla_codesign::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a.view_mut());
    for i in 0..s {
        a[(i, i)] += s as f64;
    }
    a
}

#[test]
fn lu_lookahead_bitwise_identical_to_baseline() {
    let mut rng = Pcg64::seed(1001);
    // Non-divisible block sizes on purpose: 37/5, 50/8, 96/32 leave
    // short trailing panels and nr-misaligned column splits; 37/5 runs
    // 8 panels, deep enough for the depth-3 window to ramp up and down.
    for (s, b) in [(37, 5), (50, 8), (96, 32), (64, 16)] {
        let a0 = MatrixF64::random(s, s, &mut rng);
        for threads in thread_sweep() {
            let base = lu_factor(&a0, b, &mut engine(threads, Lookahead::disabled())).unwrap();
            for depth in DEPTHS {
                for t_p in [AUTO_PANEL_WORKERS, 1] {
                    let la = Lookahead { depth, panel_workers: t_p };
                    let fused = lu_factor(&a0, b, &mut engine(threads, la)).unwrap();
                    assert_eq!(
                        fused.pivots, base.pivots,
                        "s={s} b={b} x{threads} d={depth} t_p={t_p}: pivot vectors differ"
                    );
                    assert_eq!(
                        fused.lu.max_abs_diff(&base.lu),
                        0.0,
                        "s={s} b={b} x{threads} d={depth} t_p={t_p}: factors not bitwise identical"
                    );
                    let err = fused.reconstruction_error(&a0);
                    assert!(err < 1e-10, "s={s} b={b} x{threads} d={depth} t_p={t_p}: {err}");
                }
            }
        }
    }
}

#[test]
fn lu_deep_lookahead_with_wide_panel_team() {
    // Cooperative getf2_team inside the deep chain with t_p = 2: the
    // factored-ahead panels are factored by a multi-rank sub-team.
    let mut rng = Pcg64::seed(1006);
    let a0 = MatrixF64::random(60, 60, &mut rng);
    let base = lu_factor(&a0, 8, &mut engine(4, Lookahead::disabled())).unwrap();
    for depth in [2, 3] {
        let fused =
            lu_factor(&a0, 8, &mut engine(4, Lookahead { depth, panel_workers: 2 })).unwrap();
        assert_eq!(fused.pivots, base.pivots, "d={depth}");
        assert_eq!(fused.lu.max_abs_diff(&base.lu), 0.0, "d={depth}");
    }
}

#[test]
fn lu_shrinking_panel_schedule_is_bitwise_exact() {
    // A forced per-iteration t_p schedule (the malleability hook): the
    // panel team shrinks 2 -> 2 -> 1 across iterations and results must
    // not move a bit. The env var only affects engines with AUTO t_p,
    // and t_p never changes arithmetic, so this is safe under parallel
    // test threads.
    let mut rng = Pcg64::seed(1007);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let base = lu_factor(&a0, 16, &mut engine(4, Lookahead::disabled())).unwrap();
    std::env::set_var("DLA_PANEL_WORKERS", "2,2,1");
    let result = std::panic::catch_unwind(|| {
        let mut fused_engines: Vec<_> = DEPTHS
            .iter()
            .map(|&depth| engine(4, Lookahead { depth, panel_workers: AUTO_PANEL_WORKERS }))
            .collect();
        fused_engines
            .iter_mut()
            .map(|eng| lu_factor(&a0, 16, eng).unwrap())
            .collect::<Vec<_>>()
    });
    std::env::remove_var("DLA_PANEL_WORKERS");
    let factors = result.unwrap_or_else(|e| std::panic::resume_unwind(e));
    for (d, fused) in DEPTHS.iter().zip(factors) {
        assert_eq!(fused.pivots, base.pivots, "depth {d}: schedule changed pivots");
        assert_eq!(fused.lu.max_abs_diff(&base.lu), 0.0, "depth {d}: schedule changed factors");
    }
}

#[test]
fn lu_lookahead_detects_singularity_like_baseline() {
    // Column 3 duplicates column 2: every path must fail at the same
    // column, including when the failure is discovered early by a
    // factored-ahead panel.
    let mut a = MatrixF64::identity(12);
    for i in 0..12 {
        let v = a[(i, 2)];
        a[(i, 3)] = v;
    }
    let base = lu_factor(&a, 4, &mut engine(2, Lookahead::disabled()));
    let Err(jb) = base.map(|_| ()) else {
        panic!("rank-deficient matrix must be detected on the baseline");
    };
    for depth in DEPTHS {
        let la = Lookahead { depth, panel_workers: 1 };
        let fused = lu_factor(&a, 4, &mut engine(2, la));
        let Err(jf) = fused.map(|_| ()) else {
            panic!("rank-deficient matrix must be detected at depth {depth}");
        };
        assert_eq!(jb, jf, "failing column must agree at depth {depth}");
    }
}

#[test]
fn cholesky_lookahead_matches_baseline() {
    let mut rng = Pcg64::seed(1002);
    for (s, b) in [(45, 8), (33, 7), (64, 16)] {
        let a0 = spd(s, &mut rng);
        for threads in thread_sweep() {
            let mut base = a0.clone();
            cholesky_blocked(&mut base, b, &mut engine(threads, Lookahead::disabled())).unwrap();
            for depth in DEPTHS {
                let la = Lookahead { depth, panel_workers: AUTO_PANEL_WORKERS };
                let mut fused = a0.clone();
                cholesky_blocked(&mut fused, b, &mut engine(threads, la)).unwrap();
                // Compare the lower triangles (the upper is workspace).
                for j in 0..s {
                    for i in j..s {
                        assert_eq!(
                            fused[(i, j)].to_bits(),
                            base[(i, j)].to_bits(),
                            "s={s} b={b} x{threads} d={depth}: L({i},{j}) differs"
                        );
                    }
                }
                let res = lapack::cholesky::cholesky_residual(&a0, &fused);
                assert!(res < 1e-11, "s={s} b={b} x{threads} d={depth}: residual {res}");
            }
        }
    }
}

#[test]
fn cholesky_deep_lookahead_detects_non_spd_like_baseline() {
    let mut a0 = MatrixF64::identity(24);
    a0[(17, 17)] = -1.0;
    let mut base = a0.clone();
    let be = cholesky_blocked(&mut base, 4, &mut engine(2, Lookahead::disabled()));
    let Err(jb) = be else { panic!("non-SPD must be detected") };
    for depth in DEPTHS {
        let mut m = a0.clone();
        let la = Lookahead { depth, panel_workers: AUTO_PANEL_WORKERS };
        let fe = cholesky_blocked(&mut m, 4, &mut engine(2, la));
        let Err(jf) = fe else { panic!("non-SPD must be detected at depth {depth}") };
        assert_eq!(jb, jf, "failing column must agree at depth {depth}");
    }
}

#[test]
fn qr_lookahead_matches_baseline() {
    let mut rng = Pcg64::seed(1003);
    for (m, n, b) in [(40, 24, 8), (33, 17, 5), (48, 48, 16)] {
        let a0 = MatrixF64::random(m, n, &mut rng);
        for threads in thread_sweep() {
            let base = qr_blocked(&a0, b, &mut engine(threads, Lookahead::disabled()));
            for depth in DEPTHS {
                let la = Lookahead { depth, panel_workers: AUTO_PANEL_WORKERS };
                let fused = qr_blocked(&a0, b, &mut engine(threads, la));
                assert_eq!(
                    fused.qr.max_abs_diff(&base.qr),
                    0.0,
                    "m={m} n={n} b={b} x{threads} d={depth}: packed factors differ"
                );
                for (j, (tf, tb)) in fused.tau.iter().zip(&base.tau).enumerate() {
                    assert_eq!(
                        tf.to_bits(),
                        tb.to_bits(),
                        "m={m} n={n} b={b} x{threads} d={depth}: tau[{j}] differs"
                    );
                }
                let err = fused.reconstruction_error(&a0);
                assert!(err < 1e-10, "m={m} n={n} b={b} x{threads} d={depth}: |A-QR| = {err}");
            }
        }
    }
}

#[test]
fn lookahead_factorizations_never_spawn_threads() {
    // The no-spawn invariant under deep lookahead: the fused jobs, the
    // chain's factor-ahead work, the sub-team panel factorization and
    // the pooled laswp all run on the same parked team.
    let mut rng = Pcg64::seed(1004);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let mut eng = engine(4, Lookahead { depth: 2, panel_workers: 2 });
    let pool = Arc::clone(eng.pool().expect("parallel plan provisions a pool"));
    assert_eq!(pool.spawned_workers(), 3);
    for _ in 0..3 {
        lu_factor(&a0, 32, &mut eng).unwrap();
    }
    let spd_m = spd(64, &mut rng);
    let mut chol = spd_m.clone();
    cholesky_blocked(&mut chol, 16, &mut eng).unwrap();
    qr_blocked(&a0, 16, &mut eng);
    assert_eq!(
        pool.spawned_workers(),
        3,
        "lookahead factorizations must reuse the pool, never spawn"
    );
    // And the fused jobs actually ran on the pool.
    assert!(pool.stats().jobs > 0);
}

#[test]
fn lookahead_reduces_or_preserves_pool_jobs_shape() {
    // Sanity on the pipeline structure rather than wall-clock (the host
    // may be single-core): with lookahead the panel factorization rides
    // inside the fused trailing-update job, so the per-iteration job
    // count does not grow even though more work moved onto the pool —
    // and the deep queue skips whole jobs in the ramp-down.
    let mut rng = Pcg64::seed(1005);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let mut off = engine(4, Lookahead::disabled());
    lu_factor(&a0, 16, &mut off).unwrap();
    let jobs_off = off.pool().unwrap().stats().jobs;
    let mut last_jobs = u64::MAX;
    for depth in DEPTHS {
        let mut on = engine(4, Lookahead { depth, panel_workers: 1 });
        lu_factor(&a0, 16, &mut on).unwrap();
        let jobs_on = on.pool().unwrap().stats().jobs;
        assert!(
            jobs_on <= jobs_off,
            "fused pipeline must not add pool jobs: d={depth} on={jobs_on} off={jobs_off}"
        );
        assert!(
            jobs_on <= last_jobs,
            "deeper queues must not add pool jobs: d={depth} {jobs_on} > {last_jobs}"
        );
        last_jobs = jobs_on;
    }
}

#[test]
fn deep_lookahead_surfaces_phase_idle_counters() {
    // The per-phase idle split must be populated by the fused rejoins
    // (which bucket is biggest is host-dependent; the accounting just
    // has to be wired through).
    let mut rng = Pcg64::seed(1008);
    let a0 = MatrixF64::random(96, 96, &mut rng);
    let mut eng = engine(4, Lookahead { depth: 2, panel_workers: 1 });
    lu_factor(&a0, 16, &mut eng).unwrap();
    let s = eng.pool().unwrap().stats();
    assert!(
        s.panel_idle_ns + s.update_idle_ns + s.queue_stall_ns > 0,
        "fused rejoins must record per-phase waits: {s:?}"
    );
}
