//! Cross-module integration tests: the full native stack exercised end
//! to end (no PJRT artifacts needed — those are covered by
//! `e2e_artifacts.rs`).

use dla_codesign::arch::{carmel, detect_host, epyc7282, host_xeon};
use dla_codesign::coordinator::{Coordinator, CoordinatorServer, DlaRequest, DlaResponse, ServerConfig};
use dla_codesign::gemm::{ConfigMode, GemmEngine};
use dla_codesign::harness::{self, HarnessOpts};
use dla_codesign::lapack::{self, qr_blocked, syrk_lower};
use dla_codesign::model::autotune::{autotune, SearchSpace};
use dla_codesign::model::{refined_ccp, select, AnalyticScorer, GemmDims, MicroKernel};
use dla_codesign::perfmodel::{gemm_perf, ModelParams};
use dla_codesign::trace::{simulate_gemm, TraceOptions};
use dla_codesign::util::{MatrixF64, Pcg64};

/// A linear-solver pipeline through the coordinator: factor with LU,
/// refine the solution with one step of iterative refinement computed
/// via engine GEMMs — every flop flows through the co-design stack.
#[test]
fn solver_pipeline_with_iterative_refinement() {
    let mut co = Coordinator::new(detect_host(), ConfigMode::Refined);
    let mut rng = Pcg64::seed(1001);
    let n = 96;
    let a = MatrixF64::random_diag_dominant(n, &mut rng);
    let x_true = MatrixF64::random(n, 2, &mut rng);
    let mut rhs = MatrixF64::zeros(n, 2);
    dla_codesign::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut rhs.view_mut());
    let x0 = co.solve(&a, &rhs, 16).unwrap();
    // Residual r = rhs - A x0 via the engine; correction dx = A^{-1} r.
    let mut r = rhs.clone();
    co.engine.gemm(-1.0, a.view(), x0.view(), 1.0, &mut r.view_mut());
    let dx = co.solve(&a, &r, 16).unwrap();
    let x1 = MatrixF64::from_fn(n, 2, |i, j| x0[(i, j)] + dx[(i, j)]);
    let e0 = x0.max_abs_diff(&x_true);
    let e1 = x1.max_abs_diff(&x_true);
    assert!(e1 <= e0 * 1.5, "refinement must not diverge ({e0} -> {e1})");
    assert!(e1 < 1e-9);
}

/// QR and LU agree on the solution of the same system.
#[test]
fn qr_and_lu_solve_agree() {
    let mut rng = Pcg64::seed(1002);
    let n = 40;
    let a = MatrixF64::random_diag_dominant(n, &mut rng);
    let b = MatrixF64::random(n, 1, &mut rng);
    let mut engine = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    // LU solve.
    let lu = lapack::lu_factor(&a, 8, &mut engine).unwrap();
    let x_lu = lu.solve(&b);
    // QR solve: R x = Q^T b.
    let f = qr_blocked(&a, 8, &mut engine);
    let q = f.q_matrix();
    let qt = q.transposed();
    let mut qtb = MatrixF64::zeros(n, 1);
    dla_codesign::gemm::gemm_reference(1.0, qt.view(), b.view(), 0.0, &mut qtb.view_mut());
    let r = f.r_matrix();
    // Back substitution on R.
    let mut x_qr = qtb.clone();
    for i in (0..n).rev() {
        let mut acc = x_qr[(i, 0)];
        for j in i + 1..n {
            acc -= r[(i, j)] * x_qr[(j, 0)];
        }
        x_qr[(i, 0)] = acc / r[(i, i)];
    }
    assert!(x_lu.max_abs_diff(&x_qr) < 1e-8, "LU and QR solutions diverge");
}

/// Cholesky via true SYRK equals Cholesky via full GEMM.
#[test]
fn cholesky_with_syrk_trailing_update() {
    let mut rng = Pcg64::seed(1003);
    let n = 48;
    let m = MatrixF64::random(n, n, &mut rng);
    let mt = m.transposed();
    let mut a = MatrixF64::zeros(n, n);
    dla_codesign::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a.view_mut());
    for i in 0..n {
        a[(i, i)] += n as f64;
    }
    // Hand-rolled blocked Cholesky with syrk_lower trailing updates.
    let mut engine = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let b = 12;
    let mut l = a.clone();
    let mut k = 0;
    while k < n {
        let bb = b.min(n - k);
        {
            let mut d = l.sub_mut(k, k, bb, bb);
            lapack::cholesky::potf2(&mut d).unwrap();
        }
        if k + bb < n {
            let rest = n - k - bb;
            {
                let l11t = l.sub(k, k, bb, bb).to_owned_matrix().transposed();
                let mut a21 = l.sub_mut(k + bb, k, rest, bb);
                lapack::trsm_right_upper(l11t.view(), &mut a21);
            }
            let a21 = l.sub(k + bb, k, rest, bb).to_owned_matrix();
            // syrk over the owned trailing block, then write back.
            let mut c22 = l.sub(k + bb, k + bb, rest, rest).to_owned_matrix();
            syrk_lower(-1.0, &a21, 1.0, &mut c22, 16, &mut engine);
            for j in 0..rest {
                for i in j..rest {
                    l[(k + bb + i, k + bb + j)] = c22[(i, j)];
                }
            }
        }
        k += bb;
    }
    assert!(lapack::cholesky::cholesky_residual(&a, &l) < 1e-11);
    // And matches the library's gemm-based Cholesky.
    let mut l2 = a.clone();
    lapack::cholesky::cholesky_blocked(&mut l2, b, &mut engine).unwrap();
    for j in 0..n {
        for i in j..n {
            assert!((l[(i, j)] - l2[(i, j)]).abs() < 1e-9);
        }
    }
}

/// The analytic selector's choice is never far from the autotuner's best
/// on a small measured grid (the paper's "model is enough" claim as an
/// automated check; generous 40% tolerance for a noisy shared host).
#[test]
fn selector_choice_close_to_autotuned_best() {
    let arch = detect_host();
    let dims = GemmDims::new(256, 256, 64);
    let sel = select(&arch, dims, &AnalyticScorer);
    let kernel = dla_codesign::gemm::microkernel::for_shape(sel.config.mk)
        .expect("selected kernel must be implemented");
    let space = SearchSpace { mc: vec![32, 128, 256], nc: vec![48, 256], kc: vec![32, 64] };
    let tuned = autotune(&kernel, dims, &space, 0.02);
    // Measure the selector's pick through the same harness.
    let pick_space = SearchSpace {
        mc: vec![sel.config.ccp.mc],
        nc: vec![sel.config.ccp.nc],
        kc: vec![sel.config.ccp.kc],
    };
    let picked = autotune(&kernel, dims, &pick_space, 0.02);
    assert!(
        picked.best_gflops > tuned.best_gflops * 0.6,
        "model pick {:.2} GFLOPS too far from tuned best {:.2}",
        picked.best_gflops,
        tuned.best_gflops
    );
}

/// Model/simulator consistency: higher simulated L2 hit ratio implies
/// the perf model ranks that configuration at least as fast, everything
/// else (kernel, dims) equal.
#[test]
fn perfmodel_consistent_with_simulated_hit_ratio() {
    let arch = epyc7282();
    let dims = GemmDims::new(1000, 1000, 64);
    let mk = MicroKernel::new(8, 6);
    let blis = dla_codesign::model::blis_static("epyc").unwrap();
    let cfg_b = dla_codesign::model::ccp::GemmConfig { mk, ccp: blis.ccp.clamp_to(dims) };
    let cfg_m = dla_codesign::model::ccp::GemmConfig { mk, ccp: refined_ccp(&arch, mk, dims).clamp_to(dims) };
    let p = ModelParams::default();
    let eb = gemm_perf(&arch, dims, &cfg_b, false, TraceOptions::sampled(), &p);
    let em = gemm_perf(&arch, dims, &cfg_m, false, TraceOptions::sampled(), &p);
    let (hb, hm) = (eb.l2_hit_ratio.unwrap(), em.l2_hit_ratio.unwrap());
    assert!(hm > hb, "MOD must have the higher simulated L2 hit ratio");
    assert!(em.gflops >= eb.gflops, "higher hit ratio must not model slower");
}

/// The trace generator's coverage accounting is exact for an unsampled
/// run and the sampled counters scale to within 15% of exact.
#[test]
fn sampling_scales_counters_consistently() {
    let arch = carmel();
    let dims = GemmDims::new(600, 600, 64);
    let mk = MicroKernel::new(6, 8);
    let cfg = dla_codesign::model::ccp::GemmConfig {
        mk,
        ccp: dla_codesign::model::Ccp::new(150, 200, 64),
    };
    let exact = simulate_gemm(&arch, dims, &cfg, TraceOptions::default(), false);
    let sampled = simulate_gemm(&arch, dims, &cfg, TraceOptions::sampled(), false);
    assert_eq!(exact.coverage, 1.0);
    assert!(sampled.coverage < 1.0);
    let (e1, ..) = exact.scaled_accesses();
    let (s1, ..) = sampled.scaled_accesses();
    let rel = (e1 - s1).abs() / e1;
    assert!(rel < 0.15, "sampled L1 access estimate off by {:.1}%", rel * 100.0);
}

/// Smoke: every harness experiment runs at tiny sizes and writes TSVs.
#[test]
fn harness_smoke_all_experiments() {
    let mut opts = HarnessOpts::smoke();
    opts.modeled = false; // modeled paths covered by their own unit tests
    harness::tables::run();
    harness::fig6::run(&opts);
    harness::fig9::run(&opts);
    harness::fig10::run(&opts, false);
    harness::fig11::run(&opts, true);
    harness::fig12::run(&opts, harness::fig12::Panel::Sequential);
    for f in ["table1", "table2", "fig6_left", "fig9_host", "fig10_host", "fig11_host", "fig12_host"] {
        let p = format!("results/{f}.tsv");
        assert!(std::path::Path::new(&p).exists(), "{p} missing");
    }
}

/// Server under a mixed concurrent load with an injected failure in the
/// middle: the failure is isolated to its request.
#[test]
fn server_isolates_request_failures() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined).with_workers(2),
    )
    .unwrap();
    let mut rng = Pcg64::seed(1004);
    let mut pending = Vec::new();
    for i in 0..10 {
        let req = if i == 5 {
            // Singular: all-zero matrix.
            DlaRequest::LuFactor { a: MatrixF64::zeros(16, 16), block: 4 }
        } else {
            DlaRequest::Gemm {
                alpha: 1.0,
                a: MatrixF64::random(24, 12, &mut rng),
                b: MatrixF64::random(12, 20, &mut rng),
                beta: 0.0,
                c: MatrixF64::zeros(24, 20),
            }
        };
        pending.push((i, server.submit(req).unwrap()));
    }
    for (i, rx) in pending {
        let resp = rx.recv().unwrap();
        if i == 5 {
            assert!(resp.is_err(), "request 5 must fail");
        } else {
            let ok = resp.unwrap();
            if let DlaResponse::Matrix { result, .. } = ok {
                assert_eq!(result.rows(), 24);
            } else {
                panic!("unexpected response kind");
            }
        }
    }
    let metrics = server.shutdown();
    assert_eq!(metrics.count("gemm"), 9);
}

/// Engines are deterministic: same seed + policy => bitwise-equal output.
#[test]
fn engine_determinism() {
    let run = || {
        let mut rng = Pcg64::seed(1005);
        let a = MatrixF64::random(64, 32, &mut rng);
        let b = MatrixF64::random(32, 48, &mut rng);
        let mut c = MatrixF64::zeros(64, 48);
        let mut e = GemmEngine::new(detect_host(), ConfigMode::Refined);
        e.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
        c
    };
    let c1 = run();
    let c2 = run();
    assert_eq!(c1, c2, "same inputs must produce bitwise-identical results");
}
