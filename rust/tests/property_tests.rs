//! Property-based tests over the whole native stack (testutil's
//! mini-proptest; seeds overridable via DLA_PROPTEST_SEED).
//!
//! These complement the per-module unit tests with randomized invariants:
//! packing round-trips, blocked-GEMM-vs-reference equivalence over
//! arbitrary shapes/CCPs, LU reconstruction, model feasibility bounds and
//! cache-simulator conservation laws.

use dla_codesign::arch::{carmel, epyc7282, host_xeon};
use dla_codesign::cachesim::Hierarchy;
use dla_codesign::gemm::microkernel::registry;
use dla_codesign::gemm::packing::{pack_a, pack_b, packed_a_len, packed_b_len};
use dla_codesign::gemm::{gemm_blocked, gemm_reference, Workspace};
use dla_codesign::lapack::lu_factor;
use dla_codesign::model::analytical::{kc_star, l1_allocation, l2_allocation};
use dla_codesign::model::ccp::GemmConfig;
use dla_codesign::model::{refined_ccp, Ccp, GemmDims};
use dla_codesign::testutil::{forall, PropConfig};
use dla_codesign::util::{MatrixF64, Pcg64};

fn cfgn(cases: usize) -> PropConfig {
    PropConfig { cases, ..Default::default() }
}

#[test]
fn prop_blocked_gemm_equals_reference_for_random_shapes_and_ccps() {
    let kernels = registry();
    forall(
        "blocked_gemm==reference",
        cfgn(40),
        |rng| {
            let m = rng.range(1, 80);
            let n = rng.range(1, 80);
            let k = rng.range(1, 80);
            let kern = rng.range(0, kernels.len());
            let ccp = Ccp::new(rng.range(1, 100), rng.range(1, 100), rng.range(1, 100));
            let alpha = rng.next_f64() * 4.0 - 2.0;
            let beta = rng.next_f64() * 2.0 - 1.0;
            (m, n, k, kern, ccp, alpha, beta, rng.next_u64())
        },
        |&(m, n, k, kern, ccp, alpha, beta, seed)| {
            let imp = kernels[kern];
            let mut rng = Pcg64::seed(seed);
            let a = MatrixF64::random(m, k, &mut rng);
            let b = MatrixF64::random(k, n, &mut rng);
            let mut c = MatrixF64::random(m, n, &mut rng);
            let mut expect = c.clone();
            gemm_reference(alpha, a.view(), b.view(), beta, &mut expect.view_mut());
            let cfg = GemmConfig { mk: imp.spec, ccp };
            let mut ws = Workspace::new();
            gemm_blocked(&cfg, &imp, alpha, a.view(), b.view(), beta, &mut c.view_mut(), &mut ws);
            let err = c.max_abs_diff(&expect);
            let tol = 1e-12 * (k.max(1) as f64) * (1.0 + alpha.abs());
            if err > tol {
                return Err(format!("kernel {} err {err} > {tol}", imp.name));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_packing_roundtrip_any_geometry() {
    forall(
        "packing_roundtrip",
        cfgn(60),
        |rng| (rng.range(1, 60), rng.range(1, 60), rng.range(1, 17), rng.range(1, 17), rng.next_u64()),
        |&(rows, cols, mr, nr, seed)| {
            let mut rng = Pcg64::seed(seed);
            let a = MatrixF64::random(rows, cols, &mut rng);
            // pack_a: element (i, p) must survive; padding must be zero.
            let mut abuf = vec![f64::NAN; packed_a_len(rows, cols, mr)];
            pack_a(a.view(), &mut abuf, mr, 1.0);
            let panels = rows.div_ceil(mr);
            for panel in 0..panels {
                for p in 0..cols {
                    for r in 0..mr {
                        let i = panel * mr + r;
                        let v = abuf[panel * mr * cols + p * mr + r];
                        let want = if i < rows { a[(i, p)] } else { 0.0 };
                        if v != want {
                            return Err(format!("pack_a mismatch at panel {panel} p {p} r {r}"));
                        }
                    }
                }
            }
            // pack_b symmetric check.
            let b = MatrixF64::random(cols, rows, &mut rng);
            let mut bbuf = vec![f64::NAN; packed_b_len(cols, rows, nr)];
            pack_b(b.view(), &mut bbuf, nr);
            let bpanels = rows.div_ceil(nr);
            for panel in 0..bpanels {
                for p in 0..cols {
                    for cidx in 0..nr {
                        let j = panel * nr + cidx;
                        let v = bbuf[panel * nr * cols + p * nr + cidx];
                        let want = if j < rows { b[(p, j)] } else { 0.0 };
                        if v != want {
                            return Err(format!("pack_b mismatch at panel {panel} p {p} c {cidx}"));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lu_reconstruction_random_sizes_and_blocks() {
    forall(
        "lu_PA==LU",
        cfgn(25),
        |rng| (rng.range(2, 96), rng.range(1, 40), rng.next_u64()),
        |&(s, b, seed)| {
            let mut rng = Pcg64::seed(seed);
            let a0 = MatrixF64::random(s, s, &mut rng);
            let mut engine = dla_codesign::gemm::GemmEngine::new(
                host_xeon(),
                dla_codesign::gemm::ConfigMode::Refined,
            );
            match lu_factor(&a0, b, &mut engine) {
                Err(col) => Err(format!("unexpected singularity at {col}")),
                Ok(f) => {
                    let err = f.reconstruction_error(&a0);
                    if err > 1e-10 * s as f64 {
                        return Err(format!("recon err {err}"));
                    }
                    // Pivots must be a valid partial-pivoting sequence:
                    // piv[j] >= j.
                    for (j, &p) in f.pivots.iter().enumerate() {
                        if p < j || p >= s {
                            return Err(format!("invalid pivot {p} at step {j}"));
                        }
                    }
                    Ok(())
                }
            }
        },
    );
}

#[test]
fn prop_refined_model_feasible_on_all_archs() {
    let archs = [carmel(), epyc7282(), host_xeon()];
    forall(
        "refined_model_feasibility",
        cfgn(120),
        |rng| {
            (
                rng.range(0, 3),
                rng.range(1, 5000),
                rng.range(1, 5000),
                rng.range(1, 3000),
                rng.range(1, 17),
                rng.range(1, 17),
            )
        },
        |&(ai, m, n, k, mr, nr)| {
            let arch = &archs[ai];
            let mk = dla_codesign::model::MicroKernel::new(mr, nr);
            let dims = GemmDims::new(m, n, k);
            let ccp = refined_ccp(arch, mk, dims);
            // Feasibility invariants.
            if ccp.kc > kc_star(arch.l1(), mk) {
                return Err(format!("kc {} exceeds L1 optimum", ccp.kc));
            }
            if ccp.kc > k.max(1) {
                return Err("kc exceeds k".into());
            }
            // Br must fit its allocated L1 ways; Ac its L2 ways.
            let a1 = l1_allocation(arch.l1(), mk);
            if ccp.kc * nr * 8 > a1.b * arch.l1().way_bytes() {
                return Err("Br overflows its L1 allocation".into());
            }
            let a2 = l2_allocation(arch.l2(), mk, ccp.kc);
            // mc is clamped by m, so only check when the model chose it.
            let mc_model = (a2.a * arch.l2().sets() * arch.l2().line_bytes) / (ccp.kc * 8);
            if ccp.mc > mc_model.max(mr) && ccp.mc > m {
                return Err(format!("mc {} above both model bound and m", ccp.mc));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_cachesim_conservation() {
    // For any access stream: hits <= accesses at each level, and every
    // L1 miss is an L2 access (walk-down conservation).
    forall(
        "cachesim_conservation",
        cfgn(20),
        |rng| {
            let n = rng.range(1000, 20_000);
            let span = rng.range(1, 1 << 22);
            (n, span as u64, rng.next_u64())
        },
        |&(n, span, seed)| {
            let mut h = Hierarchy::new(&carmel());
            let mut rng = Pcg64::seed(seed);
            for _ in 0..n {
                h.access_line(rng.next_below(span));
            }
            let l1 = h.level_stats(0);
            let l2 = h.level_stats(1);
            let l3 = h.level_stats(2);
            if l1.hits > l1.accesses || l2.hits > l2.accesses || l3.hits > l3.accesses {
                return Err("hits exceed accesses".into());
            }
            if l1.accesses != n as u64 {
                return Err("L1 must see every access".into());
            }
            if l2.accesses != l1.misses() {
                return Err(format!("L2 accesses {} != L1 misses {}", l2.accesses, l1.misses()));
            }
            if l3.accesses != l2.misses() {
                return Err("L3 accesses != L2 misses".into());
            }
            if h.dram_lines() != l3.misses() {
                return Err("DRAM lines != L3 misses".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_parallel_gemm_any_plan_matches_reference() {
    use dla_codesign::gemm::{parallel::gemm_parallel, ParallelLoop};
    use dla_codesign::runtime::pool::WorkerPool;
    let kernels = registry();
    // One persistent pool per width, shared by every generated case — the
    // production shape (and itself a reuse stress test).
    let pools: Vec<WorkerPool> = (1..=4).map(WorkerPool::new).collect();
    forall(
        "parallel_gemm==reference",
        cfgn(15),
        |rng| {
            (
                rng.range(1, 70),
                rng.range(1, 70),
                rng.range(1, 50),
                rng.range(1, 5),
                rng.range(0, 2),
                rng.range(0, kernels.len()),
                rng.next_u64(),
            )
        },
        |&(m, n, k, threads, loop_sel, kern, seed)| {
            let imp = kernels[kern];
            let target = if loop_sel == 0 { ParallelLoop::G3 } else { ParallelLoop::G4 };
            let mut rng = Pcg64::seed(seed);
            let a = MatrixF64::random(m, k, &mut rng);
            let b = MatrixF64::random(k, n, &mut rng);
            let mut c = MatrixF64::random(m, n, &mut rng);
            let mut expect = c.clone();
            gemm_reference(1.0, a.view(), b.view(), 1.0, &mut expect.view_mut());
            let cfg = GemmConfig {
                mk: imp.spec,
                ccp: Ccp::new(4 * imp.spec.mr, 3 * imp.spec.nr, 16),
            };
            gemm_parallel(
                &cfg, &imp, 1.0, a.view(), b.view(), 1.0, &mut c.view_mut(),
                target, &pools[threads - 1],
            );
            let err = c.max_abs_diff(&expect);
            if err > 1e-12 * k.max(1) as f64 {
                return Err(format!("{target:?} x{threads} kernel {} err {err}", imp.name));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_verified_gemm_no_fault_is_bitwise_clean_over_shapes_dtypes_threads() {
    use std::sync::Arc;
    use dla_codesign::gemm::{ConfigMode, GemmElem, GemmEngine, VerifyPolicy};
    use dla_codesign::runtime::pool::WorkerPool;
    use dla_codesign::util::{Elem, Matrix};

    /// One case: the verified engine (detect mode, no fault armed) must
    /// produce the unverified engine's exact bits and report zero
    /// corruption — for any shape, element type, and team width.
    fn check<E: GemmElem + Elem>(
        plain: &mut GemmEngine,
        verified: &mut GemmEngine,
        (m, n, k, seed): (usize, usize, usize, u64),
    ) -> Result<(), String> {
        let mut rng = Pcg64::seed(seed);
        let a = Matrix::<E>::random(m, k, &mut rng);
        let b = Matrix::<E>::random(k, n, &mut rng);
        let c0 = Matrix::<E>::random(m, n, &mut rng);
        let alpha = E::from_f64(1.5);
        let beta = E::from_f64(-0.5);

        let mut c_plain = c0.clone();
        plain.gemm_t(alpha, a.view(), b.view(), beta, &mut c_plain.view_mut());
        let mut c_ver = c0.clone();
        verified.gemm_t(alpha, a.view(), b.view(), beta, &mut c_ver.view_mut());

        if let Some(err) = verified.take_abft_failure() {
            return Err(format!("{}: false positive {err:?}", E::DTYPE.name()));
        }
        let diff = c_ver.max_abs_diff(&c_plain);
        if diff != 0.0 {
            return Err(format!("{}: verified drifted by {diff:e}", E::DTYPE.name()));
        }
        Ok(())
    }

    // Pools/engines are built once (production shape); the explicit
    // empty fault state keeps the CI env knobs out of this property.
    let pool = Arc::new(WorkerPool::with_fault_state(4, None));
    let mut engines: Vec<(GemmEngine, GemmEngine)> = [1usize, 4]
        .iter()
        .map(|&threads| {
            let mk = || {
                let mut e = GemmEngine::new(host_xeon(), ConfigMode::Refined);
                if threads > 1 {
                    e.set_shared_pool(Arc::clone(&pool));
                }
                e
            };
            let mut verified = mk();
            verified.set_verify(VerifyPolicy::Detect);
            (mk(), verified)
        })
        .collect();

    forall(
        "verified_gemm==unverified (no fault)",
        cfgn(24),
        |rng| {
            (
                rng.range(1, 140),
                rng.range(1, 140),
                rng.range(1, 120),
                rng.range(0, 2),
                rng.range(0, 2),
                rng.next_u64(),
            )
        },
        |&(m, n, k, widx, dtype, seed)| {
            let (plain, verified) = &mut engines[widx];
            if dtype == 0 {
                check::<f64>(plain, verified, (m, n, k, seed))
            } else {
                check::<f32>(plain, verified, (m, n, k, seed))
            }
        },
    );
    // The drill must have actually verified something on both widths.
    for (_, verified) in &engines {
        let s = verified.abft_stats().snapshot();
        assert!(s.verified_epochs > 0 && s.detected == 0, "{s:?}");
    }
}
