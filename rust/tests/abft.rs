//! ABFT suite: checksum-verified GEMM/LU against injected bit flips.
//!
//! Exercises the algorithm-based fault-tolerance layer end to end:
//!
//! 1. **Zero false positives, zero drift** — with no fault armed,
//!    verified runs (detect *and* correct) are *bitwise identical* to
//!    unverified runs and never report corruption.
//! 2. **Typed detection** — an injected `flip@R:E` bit flip in a packed
//!    operand surfaces as [`DlaError::DataCorrupt`] (a typed, transient
//!    error), never as a silently wrong matrix.
//! 3. **Correction** — in `Correct` mode the affected tile is recomputed
//!    from pristine sources; the result is bitwise identical to the
//!    fault-free run and the incident is accounted as `corrected`.
//! 4. **Serving semantics** — the coordinator propagates verification
//!    through the pool (and the degraded fallback), reports
//!    [`AbftMetrics`](dla_codesign::coordinator::AbftMetrics), and the
//!    CI `sdc` leg's env knobs (`DLA_VERIFY`, `DLA_FAULTS`) uphold the
//!    "correct bits or typed error" invariant.
//!
//! Tests pin their own plans/policies (no env mutation) except the
//! final env-adaptive drill, which is what the CI leg drives.

use std::sync::Arc;

use dla_codesign::arch::host_xeon;
use dla_codesign::coordinator::{
    CoordinatorServer, DlaError, DlaRequest, DlaResponse, ServerConfig,
};
use dla_codesign::gemm::{ConfigMode, GemmEngine, VerifyPolicy};
use dla_codesign::runtime::{FaultPlan, FaultState, WorkerPool};
use dla_codesign::util::{MatrixF64, Pcg64};

fn plan(spec: &str) -> FaultPlan {
    FaultPlan::parse(spec).expect("test fault spec must parse")
}

/// A pooled engine with `threads` workers, an optional armed fault
/// plan, and the given verification policy.
fn pooled_engine(threads: usize, faults: Option<&str>, verify: VerifyPolicy) -> GemmEngine {
    let state = faults.map(|spec| Arc::new(FaultState::new(plan(spec))));
    let pool = Arc::new(WorkerPool::with_fault_state(threads, state));
    let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    eng.set_shared_pool(pool);
    eng.set_verify(verify);
    eng
}

fn gemm_with(eng: &mut GemmEngine, seed: u64) -> MatrixF64 {
    let mut rng = Pcg64::seed(seed);
    let a = MatrixF64::random(192, 144, &mut rng);
    let b = MatrixF64::random(144, 160, &mut rng);
    let mut c = MatrixF64::random(192, 160, &mut rng);
    eng.gemm(1.25, a.view(), b.view(), -0.5, &mut c.view_mut());
    c
}

/// With no fault armed, detect and correct mode produce the same bits
/// as an unverified engine — sequential and pooled — while counting
/// verified epochs and reporting no corruption.
#[test]
fn verification_without_faults_is_bitwise_clean() {
    // Sequential oracle (verification off).
    let mut base = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    let oracle = gemm_with(&mut base, 810);
    assert_eq!(base.abft_stats().snapshot().verified_epochs, 0, "off mode must not verify");

    for policy in [VerifyPolicy::Detect, VerifyPolicy::Correct] {
        // Sequential verified run.
        let mut seq = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        seq.set_verify(policy);
        let c_seq = gemm_with(&mut seq, 810);
        assert_eq!(
            c_seq.max_abs_diff(&oracle),
            0.0,
            "{policy:?}: sequential verified run must be bitwise identical"
        );
        let s = seq.abft_stats().snapshot();
        assert!(s.verified_epochs >= 1 && s.verified_blocks >= 1, "must actually verify: {s:?}");
        assert_eq!((s.detected, s.corrected, s.uncorrectable), (0, 0, 0), "{s:?}");
        assert!(s.overhead_ns > 0, "checksum work must be accounted");
        assert!(seq.take_abft_failure().is_none());

        // Pooled verified run (4-way team, no fault plan).
        let mut par = pooled_engine(4, None, policy);
        let c_par = gemm_with(&mut par, 810);
        assert_eq!(
            c_par.max_abs_diff(&oracle),
            0.0,
            "{policy:?}: pooled verified run must be bitwise identical"
        );
        let s = par.abft_stats().snapshot();
        assert_eq!((s.detected, s.corrected, s.uncorrectable), (0, 0, 0), "{s:?}");
        assert!(par.take_abft_failure().is_none());
    }
}

/// An armed flip in a packed operand is detected: the engine records a
/// typed [`DlaError::DataCorrupt`] naming the GEMM phase, and the flip
/// is one-shot (a second verified epoch runs clean).
#[test]
fn detect_mode_turns_flip_into_typed_data_corrupt() {
    let mut eng = pooled_engine(4, Some("flip@1:1"), VerifyPolicy::Detect);
    let _ = gemm_with(&mut eng, 811);

    let faults = eng.pool().expect("pooled").fault_state().expect("armed");
    assert_eq!(faults.injected().flips, 1, "the flip must have been delivered");

    let s = eng.abft_stats().snapshot();
    assert!(s.detected >= 1, "the flip must be detected: {s:?}");
    assert_eq!(s.corrected, 0, "detect mode never recomputes");
    let err = eng.take_abft_failure().expect("detection must surface a typed failure");
    match &err {
        DlaError::DataCorrupt { phase, .. } => assert_eq!(*phase, "gemm"),
        other => panic!("expected DataCorrupt, got {other:?}"),
    }
    assert!(err.is_transient(), "SDC is transient — a retry may succeed");
    assert!(eng.take_abft_failure().is_none(), "the failure is claimed exactly once");

    // The shot was one-shot: the next verified epoch is clean and
    // bitwise identical to a fault-free engine.
    let c2 = gemm_with(&mut eng, 812);
    assert!(eng.take_abft_failure().is_none(), "second epoch must be clean");
    let mut base = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    assert_eq!(c2.max_abs_diff(&gemm_with(&mut base, 812)), 0.0);
}

/// Correct mode repairs the flipped tile from pristine sources: the
/// returned matrix is bitwise identical to the fault-free result, no
/// error is recorded, and the incident is accounted as corrected.
#[test]
fn correct_mode_recovers_the_flip_bitwise() {
    let mut eng = pooled_engine(4, Some("flip@1:1"), VerifyPolicy::Correct);
    let c = gemm_with(&mut eng, 813);

    let faults = eng.pool().expect("pooled").fault_state().expect("armed");
    assert_eq!(faults.injected().flips, 1, "the flip must have been delivered");

    let s = eng.abft_stats().snapshot();
    assert!(s.detected >= 1, "the flip must first be detected: {s:?}");
    assert!(s.corrected >= 1, "the flip must be repaired: {s:?}");
    assert_eq!(s.uncorrectable, 0, "a packed-operand flip is always recoverable: {s:?}");
    assert!(eng.take_abft_failure().is_none(), "a corrected run is a clean run");

    let mut base = GemmEngine::new(host_xeon(), ConfigMode::Refined);
    assert_eq!(
        c.max_abs_diff(&gemm_with(&mut base, 813)),
        0.0,
        "the recomputed tile must restore the exact fault-free bits"
    );
}

/// Verified serving, detect mode: with a flip armed, exactly one GEMM
/// request observes [`DlaError::DataCorrupt`]; every other response is
/// bitwise identical to the serial oracle, and the shutdown metrics
/// carry the ABFT ledger (summary line + JSON snapshot).
#[test]
fn served_gemm_under_flip_fails_typed_never_silently_wrong() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_verify(VerifyPolicy::Detect)
            .with_faults(plan("flip@1:2")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(820);
    let n = 6;
    let inputs: Vec<_> = (0..n)
        .map(|_| {
            (
                MatrixF64::random(192, 144, &mut rng),
                MatrixF64::random(144, 160, &mut rng),
                MatrixF64::random(192, 160, &mut rng),
            )
        })
        .collect();
    let mut corrupt = 0usize;
    for (a, b, c0) in &inputs {
        let resp = server.call(DlaRequest::Gemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 1.0,
            c: c0.clone(),
        });
        match resp {
            Err(DlaError::DataCorrupt { phase, .. }) => {
                assert_eq!(phase, "gemm");
                corrupt += 1;
            }
            Err(other) => panic!("only DataCorrupt is acceptable here, got {other:?}"),
            Ok(DlaResponse::Matrix { result, .. }) => {
                let mut oracle = GemmEngine::new(host_xeon(), ConfigMode::Refined);
                let mut c = c0.clone();
                oracle.gemm(1.0, a.view(), b.view(), 1.0, &mut c.view_mut());
                assert_eq!(
                    result.max_abs_diff(&c),
                    0.0,
                    "a served Ok must be bitwise identical to the serial oracle"
                );
            }
            Ok(_) => panic!("unexpected response kind"),
        }
    }
    assert_eq!(corrupt, 1, "the flip costs exactly its victim");

    let faults = server.fault_state().expect("armed");
    assert_eq!(faults.injected().flips, 1);

    let metrics = server.shutdown();
    let abft = *metrics.abft_stats();
    assert!(abft.verified_epochs >= n as u64, "every request ran verified: {abft:?}");
    assert!(abft.detected >= 1, "{abft:?}");
    assert_eq!(abft.corrected, 0, "detect mode never recomputes: {abft:?}");
    let summary = metrics.summary();
    assert!(summary.contains("abft:"), "verified run must report an abft line:\n{summary}");
    assert!(metrics.snapshot_json().contains("\"abft\":{"), "JSON snapshot must carry abft");
}

/// Verified serving, correct mode: the same flip is absorbed — every
/// request succeeds, the victim's bits match the oracle, and the repair
/// is visible in the ABFT ledger.
#[test]
fn served_gemm_in_correct_mode_absorbs_the_flip() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_verify(VerifyPolicy::Correct)
            .with_faults(plan("flip@1:2")),
    )
    .expect("server start");

    let mut rng = Pcg64::seed(821);
    for _ in 0..4 {
        let a = MatrixF64::random(192, 144, &mut rng);
        let b = MatrixF64::random(144, 160, &mut rng);
        let c0 = MatrixF64::random(192, 160, &mut rng);
        let resp = server
            .call(DlaRequest::Gemm {
                alpha: 1.0,
                a: a.clone(),
                b: b.clone(),
                beta: 1.0,
                c: c0.clone(),
            })
            .expect("correct mode must absorb the flip");
        let DlaResponse::Matrix { result, .. } = resp else { panic!("unexpected kind") };
        let mut oracle = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let mut c = c0.clone();
        oracle.gemm(1.0, a.view(), b.view(), 1.0, &mut c.view_mut());
        assert_eq!(result.max_abs_diff(&c), 0.0, "repaired bits must match the oracle");
    }

    let faults = server.fault_state().expect("armed");
    assert_eq!(faults.injected().flips, 1, "the flip must actually have fired");
    let metrics = server.shutdown();
    let abft = *metrics.abft_stats();
    assert!(abft.corrected >= 1, "the repair must be ledgered: {abft:?}");
    assert_eq!(abft.uncorrectable, 0, "{abft:?}");
}

/// Verified factorization: a flip during the trailing-update GEMM of a
/// blocked LU is caught (detect → typed `DataCorrupt`, never a wrong
/// factor) and repaired (correct → factors reconstruct the input).
#[test]
fn served_lu_under_flip_detects_then_corrects() {
    let mut rng = Pcg64::seed(822);
    let a0 = MatrixF64::random_diag_dominant(192, &mut rng);

    // Detect: the factorization must fail typed, not return bad factors.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_verify(VerifyPolicy::Detect)
            .with_faults(plan("flip@1:1")),
    )
    .expect("server start");
    let err = server
        .call(DlaRequest::LuFactor { a: a0.clone(), block: 48 })
        .err()
        .expect("the flipped factorization must fail");
    assert!(matches!(err, DlaError::DataCorrupt { .. }), "got {err:?}");
    assert_eq!(server.fault_state().expect("armed").injected().flips, 1);
    // The same server, next request: factorization is healthy again.
    let resp = server.call(DlaRequest::LuFactor { a: a0.clone(), block: 48 });
    let DlaResponse::Lu { factors, .. } = resp.expect("post-flip factorization") else {
        panic!("unexpected kind")
    };
    assert!(factors.reconstruction_error(&a0) < 1e-10);
    server.shutdown();

    // Correct: the same flip is absorbed and the factors are good.
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_verify(VerifyPolicy::Correct)
            .with_faults(plan("flip@1:1")),
    )
    .expect("server start");
    let resp = server
        .call(DlaRequest::LuFactor { a: a0.clone(), block: 48 })
        .expect("correct mode must absorb the flip");
    let DlaResponse::Lu { factors, .. } = resp else { panic!("unexpected kind") };
    assert!(factors.reconstruction_error(&a0) < 1e-10);
    assert_eq!(server.fault_state().expect("armed").injected().flips, 1);
    let metrics = server.shutdown();
    assert!(metrics.abft_stats().corrected >= 1, "{:?}", metrics.abft_stats());
}

/// Cholesky runs its panel re-verification without false positives and
/// stays bitwise identical to the unverified path.
#[test]
fn served_cholesky_verifies_clean() {
    let spd = |s: usize, rng: &mut Pcg64| {
        let m = MatrixF64::random(s, s, rng);
        let mt = m.transposed();
        let mut a = MatrixF64::zeros(s, s);
        dla_codesign::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a.view_mut());
        for i in 0..s {
            a[(i, i)] += s as f64;
        }
        a
    };
    let mut rng = Pcg64::seed(823);
    let a0 = spd(160, &mut rng);

    let run = |verify: Option<VerifyPolicy>| {
        // Pin an empty plan and an explicit policy so the CI `sdc` leg's
        // env knobs cannot reach this drill.
        let mut cfg = ServerConfig::new(host_xeon(), ConfigMode::Refined)
            .with_workers(1)
            .with_gemm_threads(4)
            .with_faults(FaultPlan::default());
        if verify.is_none() {
            cfg = cfg.with_verify(VerifyPolicy::Off);
        }
        if let Some(v) = verify {
            cfg = cfg.with_verify(v);
        }
        let server = CoordinatorServer::start(cfg).expect("server start");
        let resp = server
            .call(DlaRequest::Cholesky { a: a0.clone(), block: 40 })
            .expect("SPD factorization succeeds");
        let DlaResponse::Matrix { result, .. } = resp else { panic!("unexpected kind") };
        let metrics = server.shutdown();
        (result, *metrics.abft_stats())
    };

    let (plain, _) = run(None);
    let (checked, abft) = run(Some(VerifyPolicy::Detect));
    assert_eq!(checked.max_abs_diff(&plain), 0.0, "verified Cholesky must not drift");
    assert!(abft.verified_blocks >= 1, "panels must actually be verified: {abft:?}");
    assert_eq!(abft.detected, 0, "no fault, no detection: {abft:?}");
}

/// The CI `sdc` leg's contract, adaptive to the environment: a server
/// configured purely from `DLA_VERIFY`/`DLA_FAULTS` answers every GEMM
/// with either the oracle's exact bits or a typed transient error —
/// never a silently wrong matrix. Under the plain tier-1 leg (no env)
/// this degenerates to "everything is Ok and bitwise exact".
#[test]
fn env_driven_serving_never_returns_silently_wrong_bits() {
    let server = CoordinatorServer::start(
        ServerConfig::new(host_xeon(), ConfigMode::Refined).with_workers(1).with_gemm_threads(4),
    )
    .expect("server start");

    let env_faults = std::env::var("DLA_FAULTS").is_ok();
    let mut rng = Pcg64::seed(824);
    let mut failures = 0usize;
    let n = 5;
    for _ in 0..n {
        let a = MatrixF64::random(192, 144, &mut rng);
        let b = MatrixF64::random(144, 160, &mut rng);
        let c0 = MatrixF64::random(192, 160, &mut rng);
        match server.call(DlaRequest::Gemm {
            alpha: 1.0,
            a: a.clone(),
            b: b.clone(),
            beta: 1.0,
            c: c0.clone(),
        }) {
            Ok(DlaResponse::Matrix { result, .. }) => {
                let mut oracle = GemmEngine::new(host_xeon(), ConfigMode::Refined);
                let mut c = c0.clone();
                oracle.gemm(1.0, a.view(), b.view(), 1.0, &mut c.view_mut());
                assert_eq!(result.max_abs_diff(&c), 0.0, "Ok answers must be exact");
            }
            Ok(_) => panic!("unexpected response kind"),
            Err(e) => {
                assert!(e.is_transient(), "only typed transient failures allowed, got {e:?}");
                failures += 1;
            }
        }
    }
    if !env_faults {
        assert_eq!(failures, 0, "no armed fault may fail a request");
    }
    server.shutdown();
}
