//! A single set-associative cache with true-LRU replacement.
//!
//! Tags are kept per-set in MRU-first order, so a hit is usually found at
//! index 0 for the streaming-with-reuse patterns GEMM generates — the
//! common case costs one comparison, keeping the simulator fast enough to
//! replay the multi-hundred-million-access traces of the paper's
//! m = n = 2000 GEMMs in seconds.

use crate::arch::CacheLevel;

/// Hit/miss counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub accesses: u64,
    pub hits: u64,
}

impl CacheStats {
    pub fn misses(&self) -> u64 {
        self.accesses - self.hits
    }

    pub fn hit_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// One set-associative cache level.
pub struct SetAssocCache {
    /// MRU-first tag array, `sets * ways` entries; `u64::MAX` = invalid.
    tags: Vec<u64>,
    sets: usize,
    ways: usize,
    line_shift: u32,
    /// Precomputed log2(sets): tag = line >> set_shift (hot path).
    set_shift: u32,
    set_mask: u64,
    pub stats: CacheStats,
}

const INVALID: u64 = u64::MAX;

impl SetAssocCache {
    /// Build from an architecture cache level description.
    pub fn new(level: &CacheLevel) -> Self {
        let sets = level.sets();
        assert!(sets.is_power_of_two(), "set count must be a power of two (got {sets})");
        assert!(level.line_bytes.is_power_of_two());
        Self {
            tags: vec![INVALID; sets * level.ways],
            sets,
            ways: level.ways,
            line_shift: level.line_bytes.trailing_zeros(),
            set_shift: sets.trailing_zeros(),
            set_mask: (sets - 1) as u64,
            stats: CacheStats::default(),
        }
    }

    pub fn sets(&self) -> usize {
        self.sets
    }

    pub fn ways(&self) -> usize {
        self.ways
    }

    pub fn line_bytes(&self) -> usize {
        1 << self.line_shift
    }

    /// Access one *line* address (byte address; the line index is derived
    /// internally). Returns true on hit. On miss the line is allocated,
    /// evicting the set's LRU entry.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let tag = line >> self.set_shift;
        let base = set * self.ways;
        let set_tags = &mut self.tags[base..base + self.ways];
        self.stats.accesses += 1;
        // MRU-first linear probe.
        if set_tags[0] == tag {
            self.stats.hits += 1;
            return true;
        }
        for i in 1..self.ways {
            if set_tags[i] == tag {
                // Move to front (true LRU).
                set_tags.copy_within(0..i, 1);
                set_tags[0] = tag;
                self.stats.hits += 1;
                return true;
            }
        }
        // Miss: insert at MRU, dropping the LRU tail.
        set_tags.copy_within(0..self.ways - 1, 1);
        set_tags[0] = tag;
        false
    }

    /// Check residency without touching LRU state or counters.
    pub fn probe(&self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let tag = line >> self.set_shift;
        let base = set * self.ways;
        self.tags[base..base + self.ways].contains(&tag)
    }

    /// Invalidate everything and clear statistics.
    pub fn reset(&mut self) {
        self.tags.fill(INVALID);
        self.stats = CacheStats::default();
    }

    /// Number of distinct resident lines (for occupancy assertions).
    pub fn resident_lines(&self) -> usize {
        self.tags.iter().filter(|&&t| t != INVALID).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CacheLevel;

    fn tiny(ways: usize, sets: usize, line: usize) -> SetAssocCache {
        SetAssocCache::new(&CacheLevel {
            size_bytes: ways * sets * line,
            line_bytes: line,
            ways,
            shared_by: 1,
            latency_cycles: 1.0,
        })
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = tiny(2, 4, 64);
        assert!(!c.access(0x1000));
        assert!(c.access(0x1000));
        assert!(c.access(0x1038)); // same 64B line
        assert!(!c.access(0x1040)); // next line
        assert_eq!(c.stats.accesses, 4);
        assert_eq!(c.stats.hits, 2);
    }

    #[test]
    fn lru_eviction_order() {
        // 2-way cache: A, B fill a set; touching A then inserting C must
        // evict B (the LRU), not A.
        let mut c = tiny(2, 4, 64);
        let set_stride = 4 * 64; // lines mapping to the same set
        let (a, b, d) = (0u64, set_stride as u64, 2 * set_stride as u64);
        c.access(a);
        c.access(b);
        c.access(a); // refresh A
        c.access(d); // evicts B
        assert!(c.probe(a));
        assert!(!c.probe(b));
        assert!(c.probe(d));
    }

    #[test]
    fn full_associativity_within_set() {
        let mut c = tiny(4, 2, 64);
        let stride = (2 * 64) as u64;
        // 4 distinct lines in one set all stay resident.
        for i in 0..4 {
            c.access(i * stride);
        }
        for i in 0..4 {
            assert!(c.probe(i * stride), "way {i} should be resident");
        }
        assert_eq!(c.resident_lines(), 4);
    }

    #[test]
    fn working_set_larger_than_cache_thrashes() {
        // Cyclic sweep over 2x the capacity with LRU = zero hits.
        let mut c = tiny(4, 16, 64);
        let lines = 4 * 16 * 2;
        for _round in 0..3 {
            for i in 0..lines {
                c.access(i as u64 * 64);
            }
        }
        assert_eq!(c.stats.hits, 0, "LRU must thrash on a cyclic over-capacity sweep");
    }

    #[test]
    fn working_set_fitting_cache_all_hits_after_warmup() {
        let mut c = tiny(4, 16, 64);
        let lines = 4 * 16;
        for i in 0..lines {
            c.access(i as u64 * 64);
        }
        let warm = c.stats;
        assert_eq!(warm.hits, 0);
        for _ in 0..10 {
            for i in 0..lines {
                assert!(c.access(i as u64 * 64));
            }
        }
    }

    #[test]
    fn reset_clears() {
        let mut c = tiny(2, 2, 64);
        c.access(0);
        c.reset();
        assert_eq!(c.stats, CacheStats::default());
        assert_eq!(c.resident_lines(), 0);
        assert!(!c.probe(0));
    }

    #[test]
    fn paper_geometries_construct() {
        for a in [crate::arch::carmel(), crate::arch::epyc7282()] {
            for l in &a.levels {
                let c = SetAssocCache::new(l);
                assert_eq!(c.sets() * c.ways() * c.line_bytes(), l.size_bytes);
            }
        }
    }
}
