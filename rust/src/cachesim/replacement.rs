//! Replacement-policy variants for the cache simulator (ablation).
//!
//! The paper's analytical model implicitly assumes LRU-like behaviour
//! when it dedicates ways of each set to specific operands. This module
//! provides tree-PLRU (what real L2/L3s typically implement) and random
//! replacement so the sensitivity of the occupancy argument to the
//! replacement policy can be measured (`dla`'s cache_explorer and the
//! `exp_cachesim` bench exercise it).

use crate::arch::CacheLevel;
use crate::util::Pcg64;

/// Replacement policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// True LRU (the default simulator; see [`super::SetAssocCache`]).
    Lru,
    /// Tree pseudo-LRU (power-of-two ways).
    TreePlru,
    /// Uniform random victim.
    Random,
}

/// A set-associative cache with pluggable replacement (slower than the
/// MRU-ordered LRU fast path; used for ablations, not the hot loop).
pub struct PolicyCache {
    tags: Vec<u64>,
    /// Tree-PLRU state bits per set (ways - 1 bits packed in a u64).
    plru: Vec<u64>,
    sets: usize,
    ways: usize,
    line_shift: u32,
    set_mask: u64,
    policy: Policy,
    rng: Pcg64,
    pub accesses: u64,
    pub hits: u64,
}

const INVALID: u64 = u64::MAX;

impl PolicyCache {
    pub fn new(level: &CacheLevel, policy: Policy) -> Self {
        let sets = level.sets();
        assert!(sets.is_power_of_two());
        if policy == Policy::TreePlru {
            assert!(level.ways.is_power_of_two(), "tree-PLRU needs power-of-two ways");
        }
        Self {
            tags: vec![INVALID; sets * level.ways],
            plru: vec![0; sets],
            sets,
            ways: level.ways,
            line_shift: level.line_bytes.trailing_zeros(),
            set_mask: (sets - 1) as u64,
            policy,
            rng: Pcg64::seed(0xCAC4E),
            accesses: 0,
            hits: 0,
        }
    }

    pub fn hit_ratio(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }

    /// Tree-PLRU: walk the tree away from `way` on a touch.
    fn plru_touch(&mut self, set: usize, way: usize) {
        let mut node = 0usize; // tree root at bit 0 (heap layout)
        let mut lo = 0usize;
        let mut hi = self.ways;
        let mut bits = self.plru[set];
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if way < mid {
                bits |= 1 << node; // point away: right subtree is LRU-ish
                node = 2 * node + 1;
                hi = mid;
            } else {
                bits &= !(1 << node);
                node = 2 * node + 2;
                lo = mid;
            }
        }
        self.plru[set] = bits;
    }

    /// Tree-PLRU victim: follow the pointers.
    fn plru_victim(&self, set: usize) -> usize {
        let bits = self.plru[set];
        let mut node = 0usize;
        let mut lo = 0usize;
        let mut hi = self.ways;
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            if bits & (1 << node) != 0 {
                node = 2 * node + 2; // bit set -> victim on the right
                lo = mid;
            } else {
                node = 2 * node + 1;
                hi = mid;
            }
        }
        lo
    }

    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr >> self.line_shift;
        let set = (line & self.set_mask) as usize;
        let tag = line >> self.sets.trailing_zeros();
        let base = set * self.ways;
        self.accesses += 1;
        // Probe.
        let mut found = None;
        let mut free = None;
        for w in 0..self.ways {
            let t = self.tags[base + w];
            if t == tag {
                found = Some(w);
                break;
            }
            if t == INVALID && free.is_none() {
                free = Some(w);
            }
        }
        match (found, self.policy) {
            (Some(w), Policy::TreePlru) => {
                self.plru_touch(set, w);
                self.hits += 1;
                true
            }
            (Some(w), Policy::Lru) => {
                // MRU-first ordering like the fast path.
                self.tags.copy_within(base..base + w, base + 1);
                self.tags[base] = tag;
                self.hits += 1;
                true
            }
            (Some(_), Policy::Random) => {
                self.hits += 1;
                true
            }
            (None, policy) => {
                let victim = if let Some(f) = free {
                    f
                } else {
                    match policy {
                        Policy::Lru => self.ways - 1,
                        Policy::TreePlru => self.plru_victim(set),
                        Policy::Random => self.rng.next_below(self.ways as u64) as usize,
                    }
                };
                match policy {
                    Policy::Lru => {
                        self.tags.copy_within(base..base + victim, base + 1);
                        self.tags[base] = tag;
                    }
                    _ => {
                        self.tags[base + victim] = tag;
                        if policy == Policy::TreePlru {
                            self.plru_touch(set, victim);
                        }
                    }
                }
                false
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CacheLevel;

    fn level(ways: usize, sets: usize) -> CacheLevel {
        CacheLevel { size_bytes: ways * sets * 64, line_bytes: 64, ways, shared_by: 1, latency_cycles: 1.0 }
    }

    #[test]
    fn all_policies_hit_on_repeat() {
        for policy in [Policy::Lru, Policy::TreePlru, Policy::Random] {
            let mut c = PolicyCache::new(&level(4, 16), policy);
            assert!(!c.access(0x40));
            assert!(c.access(0x40), "{policy:?} must hit on repeat");
        }
    }

    #[test]
    fn working_set_within_ways_never_evicts_lru_and_plru() {
        for policy in [Policy::Lru, Policy::TreePlru] {
            let mut c = PolicyCache::new(&level(4, 2), policy);
            let stride = 2 * 64; // same set
            for round in 0..5 {
                for w in 0..4u64 {
                    let hit = c.access(w * stride);
                    if round > 0 {
                        assert!(hit, "{policy:?} evicted a fitting working set");
                    }
                }
            }
        }
    }

    #[test]
    fn lru_policy_cache_agrees_with_fast_path() {
        let lvl = level(8, 64);
        let mut slow = PolicyCache::new(&lvl, Policy::Lru);
        let mut fast = crate::cachesim::SetAssocCache::new(&lvl);
        let mut rng = Pcg64::seed(7);
        for _ in 0..20_000 {
            let addr = rng.next_below(1 << 20);
            let a = slow.access(addr);
            let b = fast.access(addr);
            assert_eq!(a, b, "LRU implementations diverge at {addr:#x}");
        }
        assert_eq!(slow.hits, fast.stats.hits);
    }

    #[test]
    fn plru_diverges_from_lru_on_adversarial_pattern() {
        // 4-way, 1 set. Touch A B C D, re-touch A, insert E:
        //  - true LRU evicts B (least recently used);
        //  - tree-PLRU's pointers select C (the approximation's known
        //    deviation from stack behaviour).
        let lvl = level(4, 1);
        let addr = |w: u64| w * 64; // all map to the single set
        let mut lru = PolicyCache::new(&lvl, Policy::Lru);
        let mut plru = PolicyCache::new(&lvl, Policy::TreePlru);
        for c in [&mut lru, &mut plru] {
            for w in 0..4 {
                c.access(addr(w));
            }
            c.access(addr(0)); // refresh A
            c.access(addr(10)); // insert E -> eviction
        }
        // Under LRU, B (=1) is gone and C (=2) survives.
        assert!(!lru.access(addr(1)), "LRU must have evicted B");
        // Under tree-PLRU, C (=2) is gone and B (=1) survives.
        assert!(plru.access(addr(1)), "PLRU must have kept B");
    }

    #[test]
    fn random_policy_hit_ratio_reasonable() {
        let lvl = level(8, 64);
        let mut c = PolicyCache::new(&lvl, Policy::Random);
        // Working set = half the cache: after warm-up, hit ratio ~ 1.
        let lines = 8 * 64 / 2;
        for _ in 0..10 {
            for i in 0..lines {
                c.access(i as u64 * 64);
            }
        }
        assert!(c.hit_ratio() > 0.8);
    }
}
