//! A multi-level cache hierarchy fed by ranged accesses.
//!
//! [`Hierarchy::touch`] takes `(base_addr, len_bytes)` ranges — e.g. "the
//! micro-kernel loads one mr-element column of `Ar`" — expands them to
//! line-granular accesses, and walks them down L1 -> L2 -> L3 -> memory,
//! allocating on miss at every level (NINE fill).

use crate::arch::Arch;

use super::cache::{CacheStats, SetAssocCache};

/// Classifies accesses for per-operand accounting (matches the paper's
/// per-operand reasoning about which level each operand lives in).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    /// Load of packed `Ac` data in the micro-kernel.
    PackedA,
    /// Load of packed `Bc` (the `Br` micro-panel) in the micro-kernel.
    PackedB,
    /// Micro-tile C read/write.
    TileC,
    /// Packing-time traffic (reads of A/B sources, writes of buffers).
    Packing,
    /// Anything else.
    Other,
}

/// Per-level aggregate statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct LevelStats {
    pub stats: CacheStats,
}

/// The simulated hierarchy for one core.
pub struct Hierarchy {
    levels: Vec<SetAssocCache>,
    /// Accesses that missed every level (DRAM fills).
    pub mem_accesses: u64,
    line_bytes: u64,
}

impl Hierarchy {
    /// Build the hierarchy for an architecture (all its cache levels).
    pub fn new(arch: &Arch) -> Self {
        assert!(!arch.levels.is_empty());
        let line_bytes = arch.levels[0].line_bytes as u64;
        Self {
            levels: arch.levels.iter().map(SetAssocCache::new).collect(),
            mem_accesses: 0,
            line_bytes,
        }
    }

    /// Per-core variant: shared levels are scaled down to this core's
    /// slice (capacity / shared_by), the standard single-core model for a
    /// busy socket. Used by the multicore performance model.
    pub fn new_percore_slice(arch: &Arch) -> Self {
        let mut scaled = arch.clone();
        for l in &mut scaled.levels {
            if l.shared_by > 1 {
                l.size_bytes /= l.shared_by;
                // Keep line size; reduce associativity if possible so the
                // set count stays a power of two.
                if l.ways >= l.shared_by && l.ways % l.shared_by == 0 {
                    l.ways /= l.shared_by;
                } else {
                    // Fall back to halving sets via size (ways kept); the
                    // constructor checks power-of-two sets.
                }
                l.shared_by = 1;
            }
        }
        Self::new(&scaled)
    }

    pub fn num_levels(&self) -> usize {
        self.levels.len()
    }

    pub fn level_stats(&self, idx: usize) -> CacheStats {
        self.levels[idx].stats
    }

    /// Access every cache line overlapped by `[addr, addr + len)`.
    #[inline]
    pub fn touch(&mut self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        let first = addr & !(self.line_bytes - 1);
        let last = (addr + len - 1) & !(self.line_bytes - 1);
        let mut line = first;
        loop {
            self.access_line(line);
            if line == last {
                break;
            }
            line += self.line_bytes;
        }
    }

    /// Single line-granular access walking down the levels.
    #[inline]
    pub fn access_line(&mut self, addr: u64) {
        for l in &mut self.levels {
            if l.access(addr) {
                return;
            }
        }
        self.mem_accesses += 1;
    }

    /// Reset all levels and counters.
    pub fn reset(&mut self) {
        for l in &mut self.levels {
            l.reset();
        }
        self.mem_accesses = 0;
    }

    /// Hit ratio of a level (0 = L1).
    pub fn hit_ratio(&self, idx: usize) -> f64 {
        self.levels[idx].stats.hit_ratio()
    }

    /// Total misses of the last level (DRAM traffic in lines).
    pub fn dram_lines(&self) -> u64 {
        self.mem_accesses
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::carmel;

    #[test]
    fn touch_expands_to_lines() {
        let mut h = Hierarchy::new(&carmel());
        // 100 bytes starting mid-line at 0x20 spans lines 0x0 and 0x40,
        // and byte 0x20+100-1 = 0x83 -> line 0x80: three lines.
        h.touch(0x20, 100);
        assert_eq!(h.level_stats(0).accesses, 3);
        assert_eq!(h.level_stats(0).hits, 0);
        assert_eq!(h.level_stats(1).accesses, 3);
        assert_eq!(h.mem_accesses, 3);
        // Second touch: all L1 hits, lower levels untouched.
        h.touch(0x20, 100);
        assert_eq!(h.level_stats(0).hits, 3);
        assert_eq!(h.level_stats(1).accesses, 3);
    }

    #[test]
    fn l1_capacity_spill_is_caught_by_l2() {
        let mut h = Hierarchy::new(&carmel());
        // Stream 4x the L1 (64 KB) = 256 KB, twice. Second pass: L1
        // thrashes (cyclic LRU) but everything hits in the 2 MB L2.
        let lines = 4 * 64 * 1024 / 64;
        for _ in 0..2 {
            for i in 0..lines {
                h.touch(i as u64 * 64, 1);
            }
        }
        assert_eq!(h.level_stats(0).hits, 0, "L1 must thrash");
        let l2 = h.level_stats(1);
        assert_eq!(l2.accesses, 2 * lines as u64);
        assert_eq!(l2.hits, lines as u64, "second pass must hit L2");
        assert_eq!(h.mem_accesses, lines as u64);
    }

    #[test]
    fn zero_len_touch_is_noop() {
        let mut h = Hierarchy::new(&carmel());
        h.touch(0x1234, 0);
        assert_eq!(h.level_stats(0).accesses, 0);
    }

    #[test]
    fn percore_slice_halves_carmel_l2() {
        // Carmel L2 is shared by 2 cores: the per-core slice is 1 MB.
        let h = Hierarchy::new_percore_slice(&carmel());
        assert_eq!(h.num_levels(), 3);
        // Verified indirectly: a 1.5 MB working set no longer fits the
        // sliced L2 but fits the full one.
        let mut full = Hierarchy::new(&carmel());
        let mut sliced = Hierarchy::new_percore_slice(&carmel());
        let lines = 3 * 512 * 1024 / 64; // 1.5 MB
        for h in [&mut full, &mut sliced] {
            for _ in 0..2 {
                for i in 0..lines {
                    h.touch(i as u64 * 64, 1);
                }
            }
        }
        let full_l2_hits = full.level_stats(1).hits;
        let sliced_l2_hits = sliced.level_stats(1).hits;
        assert!(full_l2_hits > sliced_l2_hits, "slice must lose capacity ({full_l2_hits} vs {sliced_l2_hits})");
    }

    #[test]
    fn reset_restores_cold_state() {
        let mut h = Hierarchy::new(&carmel());
        h.touch(0, 4096);
        h.reset();
        assert_eq!(h.level_stats(0).accesses, 0);
        assert_eq!(h.mem_accesses, 0);
    }
}
