//! Figure 6: the motivating experiment — BLIS static CCPs only, GEMM
//! with m = n = 2000 and growing k. Left: theoretical occupancy (from
//! [`super::tables::fig6_left`]); right: performance, which rises with k
//! as the cache utilization improves.

use crate::arch::{carmel, detect_host};
use crate::gemm::{ConfigMode, GemmEngine};
use crate::model::GemmDims;
use crate::perfmodel::{gemm_perf, ModelParams};
use crate::trace::TraceOptions;
use crate::util::table::{ascii_plot, Table};
use crate::util::timer::measure;
use crate::util::{MatrixF64, Pcg64};

use super::{cfg_blis, HarnessOpts};

/// The k sweep of Figure 6 (right): [64, 240] plus the square case.
pub const FIG6_KS: &[usize] = &[64, 96, 128, 160, 192, 224, 240, 512, 1024, 2000];

/// Modeled Carmel curve (BLIS CCPs).
pub fn modeled_carmel(mn: usize) -> Vec<f64> {
    let arch = carmel();
    let p = ModelParams::default();
    FIG6_KS
        .iter()
        .map(|&k| {
            let dims = GemmDims::new(mn, mn, k);
            gemm_perf(&arch, dims, &cfg_blis(&arch, dims), false, TraceOptions::sampled(), &p).gflops
        })
        .collect()
}

/// Measured host curve (BLIS-style statics on the host engine).
pub fn measured_host(mn: usize) -> Vec<f64> {
    let arch = detect_host();
    let mut engine = GemmEngine::new(arch, ConfigMode::BlisStatic);
    let mut rng = Pcg64::seed(66);
    let kmax = *FIG6_KS.iter().max().unwrap();
    let a_full = MatrixF64::random(mn, kmax.min(2 * mn), &mut rng);
    let b_full = MatrixF64::random(kmax.min(2 * mn), mn, &mut rng);
    let mut c = MatrixF64::zeros(mn, mn);
    FIG6_KS
        .iter()
        .map(|&k| {
            let k_eff = k.min(a_full.cols());
            let dims = GemmDims::new(mn, mn, k_eff);
            let a = a_full.sub(0, 0, mn, k_eff).to_owned_matrix();
            let b = b_full.sub(0, 0, k_eff, mn).to_owned_matrix();
            let meas = measure(2, 0.25, || {
                engine.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
            });
            meas.gflops(dims.flops())
        })
        .collect()
}

pub fn run(opts: &HarnessOpts) {
    // Left: occupancy table.
    let left = super::tables::fig6_left();
    left.print();
    left.write_tsv("results/fig6_left.tsv").ok();

    // Right: performance curves.
    let mut series: Vec<(&str, Vec<f64>)> = Vec::new();
    let modeled;
    let measured;
    if opts.modeled {
        modeled = modeled_carmel(2000);
        series.push(("model/carmel BLIS", modeled.clone()));
    }
    if opts.measured {
        measured = measured_host(opts.gemm_mn);
        series.push(("host BLIS-static", measured.clone()));
    }
    let mut headers = vec!["k".to_string()];
    headers.extend(series.iter().map(|(l, _)| l.to_string()));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Figure 6 (right): BLIS GEMM GFLOPS vs k", &hrefs);
    for (i, &k) in FIG6_KS.iter().enumerate() {
        let mut row = vec![k.to_string()];
        for (_, ys) in &series {
            row.push(format!("{:.2}", ys[i]));
        }
        t.row(&row);
    }
    t.print();
    t.write_tsv("results/fig6_right.tsv").ok();
    println!("{}", ascii_plot("Figure 6 (right)", FIG6_KS, &series, 48));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_curve_rises_with_k() {
        // The figure's defining shape: BLIS performance grows with k
        // (better cache utilization; paper §3.2). The model reproduces
        // the direction with a smaller amplitude than the silicon curve
        // (see EXPERIMENTS.md §Deviations), so assert the trend, not the
        // magnitude.
        let ys = modeled_carmel(2000);
        let first = ys[0];
        let last = ys[ys.len() - 1];
        assert!(
            last > first * 1.03,
            "BLIS GFLOPS must grow from k=64 ({first:.2}) to k=2000 ({last:.2})"
        );
        // And the small-k end must be the minimum of the curve.
        let min = ys.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(first <= min * 1.02, "k=64 must be (near-)slowest");
    }
}
