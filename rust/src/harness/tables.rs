//! Tables 1 and 2: theoretical cache occupancy, BLIS vs MOD CCPs and the
//! alternative micro-kernel family. Pure model — regenerates the paper's
//! numbers exactly (verified digit-for-digit by `model::occupancy` tests).

use crate::arch::carmel;
use crate::model::{blis_static, occupancy_row, refined_ccp, GemmDims, MicroKernel, OccupancyRow};
use crate::util::table::Table;

fn fmt_max(v: Option<f64>) -> String {
    v.map(|x| format!("{x:.1}")).unwrap_or_else(|| "-".into())
}

fn push_row(t: &mut Table, label: &str, r: &OccupancyRow) {
    t.row(&[
        label.to_string(),
        r.k.to_string(),
        r.mc.to_string(),
        r.nc.to_string(),
        r.kc.to_string(),
        r.mr.to_string(),
        r.nr.to_string(),
        format!("{:.1}", r.l1_kib),
        format!("{:.1}", r.l1_pct),
        fmt_max(r.l1_max_pct),
        format!("{:.1}", r.l2_kib),
        format!("{:.1}", r.l2_pct),
        fmt_max(r.l2_max_pct),
    ]);
}

const HEADERS: &[&str] = &[
    "params", "k", "mc", "nc", "kc", "mr", "nr", "L1 KB", "L1 %", "L1 Max", "L2 KB", "L2 %", "L2 Max",
];

/// Table 1: BLIS vs MOD occupancy for MK6x8 on Carmel, m = n = 2000.
pub fn table1() -> Table {
    let arch = carmel();
    let blis = blis_static("carmel").unwrap();
    let mk = MicroKernel::new(6, 8);
    let mut t = Table::new(
        "Table 1: L1|L2 occupation of Br|Ac, Carmel, MK6x8, m=n=2000",
        HEADERS,
    );
    for k in [64, 96, 128, 160, 192, 224, 256, 2000] {
        let dims = GemmDims::new(2000, 2000, k);
        let rb = occupancy_row(&arch, blis.mk, dims, blis.ccp.clamp_to(dims), false);
        push_row(&mut t, "BLIS", &rb);
        let rm = occupancy_row(&arch, mk, dims, refined_ccp(&arch, mk, dims).clamp_to(dims), true);
        push_row(&mut t, "MOD", &rm);
    }
    t
}

/// Table 2: MOD occupancy for the alternative micro-kernels on Carmel.
pub fn table2() -> Table {
    let arch = carmel();
    let mut t = Table::new(
        "Table 2: L1|L2 occupation for alternative micro-kernels, Carmel, m=n=2000",
        HEADERS,
    );
    for k in [64, 128, 192, 256] {
        for (mr, nr) in [(4, 10), (4, 12), (10, 4), (12, 4)] {
            let mk = MicroKernel::new(mr, nr);
            let dims = GemmDims::new(2000, 2000, k);
            let ccp = refined_ccp(&arch, mk, dims).clamp_to(dims);
            let r = occupancy_row(&arch, mk, dims, ccp, true);
            push_row(&mut t, "MOD", &r);
        }
    }
    t
}

/// Figure 6 (left): occupancy table under BLIS CCPs for k in [64, 240]
/// and 2000.
pub fn fig6_left() -> Table {
    let arch = carmel();
    let blis = blis_static("carmel").unwrap();
    let mut t = Table::new(
        "Figure 6 (left): Br|Ac occupancy with BLIS CCPs, Carmel, m=n=2000",
        &["k", "kc", "L1 KB", "L1 %", "L2 KB", "L2 %"],
    );
    for k in [64, 96, 128, 160, 192, 224, 240, 2000] {
        let dims = GemmDims::new(2000, 2000, k);
        let r = occupancy_row(&arch, blis.mk, dims, blis.ccp.clamp_to(dims), false);
        t.row(&[
            k.to_string(),
            r.kc.to_string(),
            format!("{:.1}", r.l1_kib),
            format!("{:.1}", r.l1_pct),
            format!("{:.1}", r.l2_kib),
            format!("{:.1}", r.l2_pct),
        ]);
    }
    t
}

/// Run all three and write TSVs.
pub fn run() {
    for (t, file) in [
        (fig6_left(), "fig6_left"),
        (table1(), "table1"),
        (table2(), "table2"),
    ] {
        t.print();
        println!();
        t.write_tsv(format!("results/{file}.tsv")).ok();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_expected_rows() {
        let t1 = table1().render();
        // Spot checks against the paper's printed values.
        assert!(t1.contains("1792"), "MOD mc=1792 missing");
        assert!(t1.contains("87.5"), "87.5% occupancy missing");
        let t2 = table2().render();
        assert!(t2.contains("1664"), "MK4x10 mc=1664 missing");
        let f6 = fig6_left().render();
        assert!(f6.contains("23.4"), "BLIS max L1 occupancy 23.4% missing");
        assert!(f6.contains("11.0"), "BLIS max L2 occupancy 11.0% missing");
    }
}
