//! Figure 12: LU factorization on the AMD EPYC 7282 — sequential (top),
//! parallel loop G3 on 16 cores (middle; the inversion where BLIS wins
//! through better load balance), and parallel loop G4 (bottom; MOD wins
//! again).

use crate::arch::{detect_host, epyc7282};
use crate::gemm::{ConfigMode, GemmEngine, ParallelLoop, ThreadPlan};
use crate::lapack::lu::{lu_factor, lu_flops};
use crate::model::{GemmDims, MicroKernel};
use crate::perfmodel::{lu_perf, ModelParams};
use crate::util::table::{ascii_plot, Table};
use crate::util::{MatrixF64, Pcg64};

use super::{cfg_blis, cfg_mod, HarnessOpts, PAPER_KS};

type CfgFn = Box<dyn Fn(GemmDims) -> crate::model::ccp::GemmConfig>;

/// The paper's four variants (prefetch contrast + the two MOD kernels).
fn model_variants() -> Vec<(&'static str, bool, CfgFn)> {
    vec![
        ("BLIS no-prefetch", false, Box::new(|d| cfg_blis(&epyc7282(), d))),
        ("BLIS prefetch", true, Box::new(|d| cfg_blis(&epyc7282(), d))),
        ("MOD MK6x8", false, Box::new(|d| cfg_mod(&epyc7282(), MicroKernel::new(6, 8), d))),
        ("MOD MK8x6", false, Box::new(|d| cfg_mod(&epyc7282(), MicroKernel::new(8, 6), d))),
    ]
}

/// Modeled EPYC LU for a given thread count and parallel loop.
pub fn modeled_epyc(s: usize, threads: usize, target: ParallelLoop) -> Vec<(String, Vec<f64>)> {
    let arch = epyc7282();
    let p = ModelParams::default();
    model_variants()
        .into_iter()
        .map(|(label, prefetch, cfg_fn)| {
            let ys = PAPER_KS
                .iter()
                .map(|&b| lu_perf(&arch, s, b, &cfg_fn, threads, target, prefetch, &p).gflops)
                .collect();
            let tgt = if threads > 1 {
                format!(" x{threads}/{}", if target == ParallelLoop::G3 { "G3" } else { "G4" })
            } else {
                String::new()
            };
            (format!("model/epyc {label}{tgt}"), ys)
        })
        .collect()
}

/// Measured host LU. Sequential by default (the sandbox host exposes one
/// core); set `DLA_THREADS=<n>` to run the trailing updates on an
/// `n`-thread persistent pool with loop G4. One engine is reused across
/// the whole `b` sweep, so the pool is spawned once and the config memo
/// cache turns repeated trailing shapes into lookups.
pub fn measured_host(s: usize) -> Vec<(String, Vec<f64>)> {
    let arch = detect_host();
    let threads: usize =
        std::env::var("DLA_THREADS").ok().and_then(|v| v.parse().ok()).unwrap_or(1);
    let mut rng = Pcg64::seed(23);
    let a0 = MatrixF64::random_diag_dominant(s, &mut rng);
    [
        ("BLIS static", ConfigMode::BlisStatic),
        ("MOD MK8x6", ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6))),
    ]
    .into_iter()
    .map(|(label, mode)| {
        let mut engine = GemmEngine::new(arch.clone(), mode.clone());
        if threads > 1 {
            engine = engine.with_plan(ThreadPlan { threads, target: ParallelLoop::G4 });
        }
        let ys = PAPER_KS
            .iter()
            .map(|&b| {
                let mut best = f64::INFINITY;
                for _ in 0..2 {
                    let sw = crate::util::Stopwatch::start();
                    lu_factor(&a0, b, &mut engine).expect("nonsingular");
                    best = best.min(sw.elapsed_secs());
                }
                lu_flops(s) / best / 1e9
            })
            .collect();
        let tag = if threads > 1 { format!(" x{threads}/G4") } else { String::new() };
        (format!("host {label}{tag}"), ys)
    })
    .collect()
}

fn emit(title: &str, file: &str, series: &[(String, Vec<f64>)]) {
    let mut headers = vec!["b".to_string()];
    headers.extend(series.iter().map(|(l, _)| l.clone()));
    for (l, _) in &series[1..] {
        headers.push(format!("speedup {l}"));
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hrefs);
    for (i, &b) in PAPER_KS.iter().enumerate() {
        let mut row = vec![b.to_string()];
        for (_, ys) in series {
            row.push(format!("{:.2}", ys[i]));
        }
        for (_, ys) in &series[1..] {
            row.push(format!("{:.2}", ys[i] / series[0].1[i]));
        }
        t.row(&row);
    }
    t.print();
    t.write_tsv(format!("results/{file}.tsv")).ok();
    let plot: Vec<(&str, Vec<f64>)> = series.iter().map(|(l, y)| (l.as_str(), y.clone())).collect();
    println!("{}", ascii_plot(title, PAPER_KS, &plot, 48));
}

/// Which of the three panels to run.
#[derive(Clone, Copy, PartialEq, Eq)]
pub enum Panel {
    Sequential,
    ParallelG3,
    ParallelG4,
}

pub fn run(opts: &HarnessOpts, panel: Panel) {
    if opts.modeled {
        let s = 10_000;
        match panel {
            Panel::Sequential => emit(
                "Figure 12 (top): LU s=10000 on EPYC, sequential (model)",
                "fig12_seq",
                &modeled_epyc(s, 1, ParallelLoop::G4),
            ),
            Panel::ParallelG3 => emit(
                "Figure 12 (middle): LU s=10000 on EPYC, 16 cores, loop G3 (model)",
                "fig12_g3",
                &modeled_epyc(s, 16, ParallelLoop::G3),
            ),
            Panel::ParallelG4 => emit(
                "Figure 12 (bottom): LU s=10000 on EPYC, 16 cores, loop G4 (model)",
                "fig12_g4",
                &modeled_epyc(s, 16, ParallelLoop::G4),
            ),
        }
    }
    if opts.measured && panel == Panel::Sequential {
        emit(
            &format!("Figure 12 (measured host): LU s={}, sequential", opts.lu_s),
            "fig12_host",
            &measured_host(opts.lu_s),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn g3_vs_g4_inversion() {
        // The paper's headline parallel finding: under loop G3 the MOD
        // configurations lose their edge vs BLIS (imbalance from large
        // mc), while under loop G4 they keep it.
        let s = 4096;
        let g3 = modeled_epyc(s, 16, ParallelLoop::G3);
        let g4 = modeled_epyc(s, 16, ParallelLoop::G4);
        // Compare MOD MK8x6 (index 3) against BLIS no-prefetch (index 0)
        // at b = 64 (index 0).
        let ratio_g3 = g3[3].1[0] / g3[0].1[0];
        let ratio_g4 = g4[3].1[0] / g4[0].1[0];
        assert!(
            ratio_g4 > ratio_g3,
            "MOD/BLIS must improve from G3 ({ratio_g3:.2}) to G4 ({ratio_g4:.2})"
        );
        assert!(ratio_g4 > 1.0, "MOD must beat BLIS under G4 (got {ratio_g4:.2})");
    }
}
