//! Figure 9: GEMM with m = n = 2000 and varying k under distinct CCPs
//! and micro-kernels, single core.
//!
//! - **Modeled (Carmel)**: the paper's exact three variants — R1 = BLIS
//!   statics + MK6x8, R2 = MOD + MK6x8, R3 = MOD + MK12x4 — through the
//!   simulation-backed performance model.
//! - **Measured (host)**: the same experiment run for real on the host
//!   CPU with the AVX2 engine: BLIS-style statics + stock MK8x6 vs MOD
//!   CCPs with MK8x6 and MK12x4.

use crate::arch::{carmel, detect_host};
use crate::gemm::{ConfigMode, GemmEngine};
use crate::model::{GemmDims, MicroKernel};
use crate::perfmodel::{gemm_perf, ModelParams};
use crate::trace::TraceOptions;
use crate::util::table::{ascii_plot, Table};
use crate::util::timer::measure;
use crate::util::{MatrixF64, Pcg64};

use super::{cfg_blis, cfg_mod, HarnessOpts, PAPER_KS};

/// One series of GFLOPS over the k sweep.
pub struct Series {
    pub label: String,
    pub gflops: Vec<f64>,
}

/// Modeled Carmel curves (the paper's R1/R2/R3).
pub fn modeled_carmel(mn: usize) -> (Vec<usize>, Vec<Series>) {
    let arch = carmel();
    let p = ModelParams::default();
    let variants: [(&str, Box<dyn Fn(GemmDims) -> crate::model::ccp::GemmConfig>); 3] = [
        ("R1 BLIS MK6x8", Box::new(move |d| cfg_blis(&carmel(), d))),
        ("R2 MOD MK6x8", Box::new(move |d| cfg_mod(&carmel(), MicroKernel::new(6, 8), d))),
        ("R3 MOD MK12x4", Box::new(move |d| cfg_mod(&carmel(), MicroKernel::new(12, 4), d))),
    ];
    let mut out = Vec::new();
    for (label, cfg_fn) in &variants {
        let gflops = PAPER_KS
            .iter()
            .map(|&k| {
                let dims = GemmDims::new(mn, mn, k);
                gemm_perf(&arch, dims, &cfg_fn(dims), false, TraceOptions::sampled(), &p).gflops
            })
            .collect();
        out.push(Series { label: format!("model/carmel {label}"), gflops });
    }
    (PAPER_KS.to_vec(), out)
}

/// Measured host curves (real wall-clock, AVX2 engine).
pub fn measured_host(mn: usize) -> (Vec<usize>, Vec<Series>) {
    let arch = detect_host();
    let modes: [(&str, ConfigMode); 3] = [
        ("R1 BLIS MK8x6", ConfigMode::BlisStatic),
        ("R2 MOD MK8x6", ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6))),
        ("R3 MOD MK12x4", ConfigMode::RefinedWithKernel(MicroKernel::new(12, 4))),
    ];
    let mut rng = Pcg64::seed(99);
    let kmax = *PAPER_KS.iter().max().unwrap();
    let a_full = MatrixF64::random(mn, kmax, &mut rng);
    let b_full = MatrixF64::random(kmax, mn, &mut rng);
    let mut c = MatrixF64::zeros(mn, mn);
    let mut out = Vec::new();
    for (label, mode) in modes {
        let mut engine = GemmEngine::new(arch.clone(), mode);
        let gflops = PAPER_KS
            .iter()
            .map(|&k| {
                let dims = GemmDims::new(mn, mn, k);
                let a = a_full.sub(0, 0, mn, k).to_owned_matrix();
                let b = b_full.sub(0, 0, k, mn).to_owned_matrix();
                let meas = measure(2, 0.3, || {
                    engine.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut());
                });
                meas.gflops(dims.flops())
            })
            .collect();
        out.push(Series { label: format!("host {label}"), gflops });
    }
    (PAPER_KS.to_vec(), out)
}

/// Build the figure table (+ speedup columns like the paper's inset).
pub fn table(ks: &[usize], series: &[Series]) -> Table {
    let mut headers: Vec<String> = vec!["k".into()];
    headers.extend(series.iter().map(|s| s.label.clone()));
    for s in &series[1..] {
        headers.push(format!("speedup {}", s.label));
    }
    let hrefs: Vec<&str> = headers.iter().map(|h| h.as_str()).collect();
    let mut t = Table::new("Figure 9: GEMM m=n=2000, varying k (GFLOPS)", &hrefs);
    for (i, &k) in ks.iter().enumerate() {
        let mut row = vec![k.to_string()];
        for s in series {
            row.push(format!("{:.2}", s.gflops[i]));
        }
        for s in &series[1..] {
            row.push(format!("{:.2}", s.gflops[i] / series[0].gflops[i]));
        }
        t.row(&row);
    }
    t
}

/// Run the experiment per the options and emit table + TSV + plot.
pub fn run(opts: &HarnessOpts) {
    let mut all: Vec<(Vec<usize>, Vec<Series>)> = Vec::new();
    if opts.modeled {
        all.push(modeled_carmel(2000));
    }
    if opts.measured {
        all.push(measured_host(opts.gemm_mn));
    }
    for (ks, series) in &all {
        let t = table(ks, series);
        t.print();
        let tag = if series[0].label.starts_with("model") { "model" } else { "host" };
        t.write_tsv(format!("results/fig9_{tag}.tsv")).ok();
        let plot_series: Vec<(&str, Vec<f64>)> =
            series.iter().map(|s| (s.label.as_str(), s.gflops.clone())).collect();
        println!("{}", ascii_plot("Figure 9", ks, &plot_series, 48));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_series_reproduce_paper_ranking_at_small_k() {
        let (ks, series) = modeled_carmel(2000);
        assert_eq!(series.len(), 3);
        let idx64 = ks.iter().position(|&k| k == 64).unwrap();
        let (r1, r2, r3) = (series[0].gflops[idx64], series[1].gflops[idx64], series[2].gflops[idx64]);
        // Paper Figure 9 speedups at k=64: R2/R1 = 1.14, R3/R1 = 1.28.
        assert!(r2 > r1, "MOD MK6x8 ({r2:.2}) must beat BLIS ({r1:.2}) at k=64");
        assert!(r3 > r2, "MOD MK12x4 ({r3:.2}) must beat MOD MK6x8 ({r2:.2}) at k=64");
    }

    #[test]
    fn table_contains_speedups() {
        let series = vec![
            Series { label: "a".into(), gflops: vec![1.0, 2.0] },
            Series { label: "b".into(), gflops: vec![2.0, 2.0] },
        ];
        let t = table(&[64, 96], &series).render();
        assert!(t.contains("2.00"));
        assert!(t.contains("speedup b"));
    }
}
