//! Figure 10: LU factorization on the Carmel, varying the algorithmic
//! block size b — sequential (top) and parallel loop-G4 on 8 cores
//! (bottom).
//!
//! The modeled curves use the paper's s = 10000; the measured host curve
//! runs a real (smaller) factorization through the native engine.

use crate::arch::{carmel, detect_host};
use crate::gemm::{ConfigMode, GemmEngine, ParallelLoop};
use crate::lapack::lu::{lu_factor, lu_flops};
use crate::model::{GemmDims, MicroKernel};
use crate::perfmodel::{lu_perf, ModelParams};
use crate::util::table::{ascii_plot, Table};
use crate::util::{MatrixF64, Pcg64};

use super::{cfg_blis, cfg_mod, HarnessOpts, PAPER_KS};

/// The paper's three variants as configuration policies for the model.
fn model_variants() -> Vec<(&'static str, Box<dyn Fn(GemmDims) -> crate::model::ccp::GemmConfig>)> {
    vec![
        ("BLIS MK6x8", Box::new(|d| cfg_blis(&carmel(), d))),
        ("MOD MK6x8", Box::new(|d| cfg_mod(&carmel(), MicroKernel::new(6, 8), d))),
        ("MOD MK12x4", Box::new(|d| cfg_mod(&carmel(), MicroKernel::new(12, 4), d))),
    ]
}

/// Modeled Carmel LU (threads = 1 for the top plot, 8/G4 for the bottom).
pub fn modeled_carmel(s: usize, threads: usize) -> Vec<(String, Vec<f64>)> {
    let arch = carmel();
    let p = ModelParams::default();
    model_variants()
        .into_iter()
        .map(|(label, cfg_fn)| {
            let ys = PAPER_KS
                .iter()
                .map(|&b| {
                    lu_perf(&arch, s, b, &cfg_fn, threads, ParallelLoop::G4, false, &p).gflops
                })
                .collect();
            (format!("model/carmel {label} x{threads}"), ys)
        })
        .collect()
}

/// Measured host LU, sequential.
pub fn measured_host(s: usize) -> Vec<(String, Vec<f64>)> {
    let arch = detect_host();
    let mut rng = Pcg64::seed(17);
    let a0 = MatrixF64::random_diag_dominant(s, &mut rng);
    let modes = [
        ("BLIS static", ConfigMode::BlisStatic),
        ("MOD MK8x6", ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6))),
        ("MOD dynamic", ConfigMode::Refined),
    ];
    modes
        .into_iter()
        .map(|(label, mode)| {
            let ys = PAPER_KS
                .iter()
                .map(|&b| {
                    let mut engine = GemmEngine::new(arch.clone(), mode.clone());
                    // Warm-up factorization, then best of 2.
                    let mut best = f64::INFINITY;
                    for _ in 0..2 {
                        let sw = crate::util::Stopwatch::start();
                        lu_factor(&a0, b, &mut engine).expect("dd matrix is nonsingular");
                        best = best.min(sw.elapsed_secs());
                    }
                    lu_flops(s) / best / 1e9
                })
                .collect();
            (format!("host {label}"), ys)
        })
        .collect()
}

fn emit(title: &str, file: &str, series: &[(String, Vec<f64>)]) {
    let mut headers = vec!["b".to_string()];
    headers.extend(series.iter().map(|(l, _)| l.clone()));
    if series.len() > 1 {
        for (l, _) in &series[1..] {
            headers.push(format!("speedup {l}"));
        }
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hrefs);
    for (i, &b) in PAPER_KS.iter().enumerate() {
        let mut row = vec![b.to_string()];
        for (_, ys) in series {
            row.push(format!("{:.2}", ys[i]));
        }
        if series.len() > 1 {
            for (_, ys) in &series[1..] {
                row.push(format!("{:.2}", ys[i] / series[0].1[i]));
            }
        }
        t.row(&row);
    }
    t.print();
    t.write_tsv(format!("results/{file}.tsv")).ok();
    let plot: Vec<(&str, Vec<f64>)> = series.iter().map(|(l, y)| (l.as_str(), y.clone())).collect();
    println!("{}", ascii_plot(title, PAPER_KS, &plot, 48));
}

pub fn run(opts: &HarnessOpts, parallel: bool) {
    if opts.modeled {
        let s = 10_000; // the paper's size; the model scales fine
        if parallel {
            emit("Figure 10 (bottom): LU s=10000, 8 cores, loop G4 (model)", "fig10_parallel", &modeled_carmel(s, 8));
        } else {
            emit("Figure 10 (top): LU s=10000, sequential (model)", "fig10_seq", &modeled_carmel(s, 1));
        }
    }
    if opts.measured && !parallel {
        emit(
            &format!("Figure 10 (measured host): LU s={}, sequential", opts.lu_s),
            "fig10_host",
            &measured_host(opts.lu_s),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_model_prefers_smaller_b_for_mk12x4() {
        // The paper's Figure 10 story: MOD MK12x4 keeps GEMM fast at
        // small b, so the parallel LU peaks at a smaller b than BLIS and
        // outperforms it there.
        let series = modeled_carmel(4096, 8);
        let blis = &series[0].1;
        let mk12 = &series[2].1;
        let b64 = 0; // index of b = 64
        assert!(
            mk12[b64] > blis[b64],
            "MOD MK12x4 ({:.1}) must beat BLIS ({:.1}) at b=64 in parallel",
            mk12[b64],
            blis[b64]
        );
    }
}
