//! Experiment harness: one submodule per table/figure of the paper's
//! evaluation (see DESIGN.md §5 for the experiment index).
//!
//! Every experiment produces (a) a rendered text table/plot on stdout and
//! (b) a TSV under `results/` for machine consumption. Measured curves
//! run the native engine on the host CPU; platform curves (Carmel/EPYC)
//! come from the simulation-backed performance model (the documented
//! hardware substitution).

pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig6;
pub mod fig9;
pub mod tables;

use crate::arch::Arch;
use crate::model::ccp::GemmConfig;
use crate::model::{blis_static, refined_ccp, GemmDims, MicroKernel};

/// The k-range of the paper's skinny-k sweeps.
pub const PAPER_KS: &[usize] = &[64, 96, 128, 160, 192, 224, 256];

/// Harness-wide options (scaled-down sizes keep the full suite minutes,
/// not hours; pass `--full` to the CLI for paper-size runs).
#[derive(Clone, Copy, Debug)]
pub struct HarnessOpts {
    /// m = n of the GEMM sweeps (paper: 2000).
    pub gemm_mn: usize,
    /// Matrix order of the LU sweeps (paper: 10000).
    pub lu_s: usize,
    /// Run the wall-clock measured (host) curves.
    pub measured: bool,
    /// Run the model-based (Carmel/EPYC) curves.
    pub modeled: bool,
}

impl Default for HarnessOpts {
    fn default() -> Self {
        Self { gemm_mn: 768, lu_s: 1024, measured: true, modeled: true }
    }
}

impl HarnessOpts {
    /// Paper-scale settings.
    pub fn full() -> Self {
        Self { gemm_mn: 2000, lu_s: 4096, measured: true, modeled: true }
    }

    /// Tiny settings for CI-style smoke runs.
    pub fn smoke() -> Self {
        Self { gemm_mn: 192, lu_s: 192, measured: true, modeled: true }
    }
}

/// Build the BLIS-baseline configuration for an arch and problem.
pub fn cfg_blis(arch: &Arch, dims: GemmDims) -> GemmConfig {
    let cfg = blis_static(&arch.name).expect("no BLIS preset for arch");
    GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) }
}

/// Build the refined-model configuration for a pinned micro-kernel.
pub fn cfg_mod(arch: &Arch, mk: MicroKernel, dims: GemmDims) -> GemmConfig {
    GemmConfig { mk, ccp: refined_ccp(arch, mk, dims).clamp_to(dims) }
}

/// Format a speedup column like the paper's tables.
pub fn speedup(ours: f64, baseline: f64) -> String {
    format!("{:.2}", ours / baseline)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::carmel;

    #[test]
    fn cfg_builders() {
        let arch = carmel();
        let dims = GemmDims::new(2000, 2000, 128);
        let b = cfg_blis(&arch, dims);
        assert_eq!(b.ccp.mc, 120);
        let m = cfg_mod(&arch, MicroKernel::new(6, 8), dims);
        assert_eq!(m.ccp.mc, 1792);
    }

    #[test]
    fn speedup_format() {
        assert_eq!(speedup(1.42, 1.0), "1.42");
    }
}
