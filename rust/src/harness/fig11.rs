//! Figure 11: GEMM on the AMD EPYC 7282 — performance (top) and the L2
//! hit ratio "hardware counter" (bottom).
//!
//! Modeled variants follow the paper's four: BLIS without prefetch, BLIS
//! with prefetch, MOD MK6x8, MOD MK8x6. The measured host curves contrast
//! the AVX2 engine's prefetch/no-prefetch kernels and the MOD CCPs.

use crate::arch::{detect_host, epyc7282};
use crate::gemm::{ConfigMode, GemmEngine};
use crate::model::{GemmDims, MicroKernel};
use crate::perfmodel::{gemm_perf, ModelParams};
use crate::trace::TraceOptions;
use crate::util::table::{ascii_plot, Table};
use crate::util::timer::measure;
use crate::util::{MatrixF64, Pcg64};

use super::{cfg_blis, cfg_mod, HarnessOpts, PAPER_KS};

/// Modeled EPYC curves: (label, gflops, l2_hit_ratio) per variant.
pub fn modeled_epyc(mn: usize) -> Vec<(String, Vec<f64>, Vec<f64>)> {
    let arch = epyc7282();
    let p = ModelParams::default();
    type CfgFn = Box<dyn Fn(GemmDims) -> crate::model::ccp::GemmConfig>;
    let variants: Vec<(&str, bool, CfgFn)> = vec![
        ("BLIS no-prefetch", false, Box::new(|d| cfg_blis(&epyc7282(), d))),
        ("BLIS prefetch", true, Box::new(|d| cfg_blis(&epyc7282(), d))),
        ("MOD MK6x8", false, Box::new(|d| cfg_mod(&epyc7282(), MicroKernel::new(6, 8), d))),
        ("MOD MK8x6", false, Box::new(|d| cfg_mod(&epyc7282(), MicroKernel::new(8, 6), d))),
    ];
    variants
        .into_iter()
        .map(|(label, prefetch, cfg_fn)| {
            let mut gf = Vec::new();
            let mut hr = Vec::new();
            for &k in PAPER_KS {
                let dims = GemmDims::new(mn, mn, k);
                let cfg = cfg_fn(dims);
                let est = gemm_perf(&arch, dims, &cfg, prefetch, TraceOptions::sampled(), &p);
                gf.push(est.gflops);
                hr.push(est.l2_hit_ratio.unwrap_or(0.0) * 100.0);
            }
            (format!("model/epyc {label}"), gf, hr)
        })
        .collect()
}

/// Measured host curves: prefetch on/off and MOD CCPs (wall clock).
pub fn measured_host(mn: usize) -> Vec<(String, Vec<f64>)> {
    let arch = detect_host();
    let mut rng = Pcg64::seed(31);
    let kmax = *PAPER_KS.iter().max().unwrap();
    let a_full = MatrixF64::random(mn, kmax, &mut rng);
    let b_full = MatrixF64::random(kmax, mn, &mut rng);
    let mut c = MatrixF64::zeros(mn, mn);
    let blis_host = crate::model::blis_static(&arch.name).unwrap();
    let mut out = Vec::new();
    // (label, kernel name override or None for policy mode, mode)
    let cases: Vec<(&str, Option<&str>, ConfigMode)> = vec![
        ("BLIS no-prefetch", Some("avx2_8x6"), ConfigMode::BlisStatic),
        ("BLIS prefetch", Some("avx2_8x6_pf"), ConfigMode::BlisStatic),
        ("MOD MK8x6", None, ConfigMode::RefinedWithKernel(MicroKernel::new(8, 6))),
        ("MOD MK12x4", None, ConfigMode::RefinedWithKernel(MicroKernel::new(12, 4))),
    ];
    for (label, kernel_name, mode) in cases {
        let mut engine = GemmEngine::new(arch.clone(), mode);
        let ys = PAPER_KS
            .iter()
            .map(|&k| {
                let dims = GemmDims::new(mn, mn, k);
                let a = a_full.sub(0, 0, mn, k).to_owned_matrix();
                let b = b_full.sub(0, 0, k, mn).to_owned_matrix();
                let meas = measure(2, 0.25, || match kernel_name {
                    Some(name) => engine.gemm_with_kernel_name(
                        name,
                        blis_host.ccp,
                        1.0,
                        a.view(),
                        b.view(),
                        0.0,
                        &mut c.view_mut(),
                    ),
                    None => engine.gemm(1.0, a.view(), b.view(), 0.0, &mut c.view_mut()),
                });
                meas.gflops(dims.flops())
            })
            .collect();
        out.push((format!("host {label}"), ys));
    }
    out
}

pub fn run(opts: &HarnessOpts, hitratio: bool) {
    if opts.modeled {
        let series = modeled_epyc(2000);
        // Top: GFLOPS.
        let mut headers = vec!["k".to_string()];
        headers.extend(series.iter().map(|(l, _, _)| l.clone()));
        let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("Figure 11 (top): GEMM on EPYC 7282 (GFLOPS, model)", &hrefs);
        for (i, &k) in PAPER_KS.iter().enumerate() {
            let mut row = vec![k.to_string()];
            for (_, gf, _) in &series {
                row.push(format!("{:.2}", gf[i]));
            }
            t.row(&row);
        }
        t.print();
        t.write_tsv("results/fig11_model.tsv").ok();
        if hitratio {
            // Bottom: L2 hit ratio (the PMU-counter substitute).
            let mut t2 = Table::new("Figure 11 (bottom): L2 hit ratio % (simulated)", &hrefs);
            for (i, &k) in PAPER_KS.iter().enumerate() {
                let mut row = vec![k.to_string()];
                for (_, _, hr) in &series {
                    row.push(format!("{:.1}", hr[i]));
                }
                t2.row(&row);
            }
            t2.print();
            t2.write_tsv("results/fig11_hitratio.tsv").ok();
        }
    }
    if opts.measured {
        let series = measured_host(opts.gemm_mn);
        let mut headers = vec!["k".to_string()];
        headers.extend(series.iter().map(|(l, _)| l.clone()));
        let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new("Figure 11 (measured host): GEMM GFLOPS", &hrefs);
        for (i, &k) in PAPER_KS.iter().enumerate() {
            let mut row = vec![k.to_string()];
            for (_, ys) in &series {
                row.push(format!("{:.2}", ys[i]));
            }
            t.row(&row);
        }
        t.print();
        t.write_tsv("results/fig11_host.tsv").ok();
        let plot: Vec<(&str, Vec<f64>)> =
            series.iter().map(|(l, y)| (l.as_str(), y.clone())).collect();
        println!("{}", ascii_plot("Figure 11 (host)", PAPER_KS, &plot, 48));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l2_hit_ratio_ranking_matches_paper() {
        // Figure 11 (bottom): at small k, MOD's L2 hit ratio exceeds
        // BLIS's on the EPYC geometry.
        let series = modeled_epyc(1000);
        let blis_hr = &series[0].2;
        let mod86_hr = &series[3].2;
        assert!(
            mod86_hr[0] > blis_hr[0],
            "MOD L2 hit ratio ({:.1}%) must exceed BLIS ({:.1}%) at k=64",
            mod86_hr[0],
            blis_hr[0]
        );
    }

    #[test]
    fn prefetch_model_never_slower() {
        let series = modeled_epyc(1000);
        let (no_pf, pf) = (&series[0].1, &series[1].1);
        for i in 0..no_pf.len() {
            assert!(pf[i] >= no_pf[i] * 0.999, "prefetch slower at index {i}");
        }
    }
}
