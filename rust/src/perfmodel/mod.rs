//! Analytical + simulation-backed performance model.
//!
//! Turns cache-simulator counters ([`crate::trace`]) into time/GFLOPS
//! estimates for the paper's platforms, and composes them into the LU
//! figures — including the multicore loop-G3/G4 models that substitute
//! for the 8-core Carmel and 16-core EPYC runs this sandbox cannot
//! execute (DESIGN.md §2).
//!
//! Single-core GEMM: a roofline-style combination
//! `t = max(t_compute, t_mem)` where
//!
//! - `t_compute = flops / peak * overhead(mk)` — micro-kernel issue
//!   overhead shrinks with tile area, plus fringe-tile waste;
//! - `t_mem` adds per-level service costs of the simulated miss counts,
//!   de-rated by a memory-level-parallelism factor (higher when software
//!   prefetching is on — the paper's BLIS-prefetch contrast).
//!
//! Multicore (paper §2.2/§4): per-core slices of shared caches, plus the
//! work-partition imbalance of the chosen loop — G3 distributes
//! `ceil(m/mc)` chunks (coarse; the paper's `10,000/384/16 = 1.62
//! iterations per thread` analysis), G4 distributes `ceil(nc/nr)` chunks
//! (fine), with packing on the critical path.

use crate::arch::Arch;
use crate::gemm::ParallelLoop;
use crate::model::ccp::GemmConfig;
use crate::model::GemmDims;
use crate::trace::{simulate_gemm, GemmSimStats, TraceOptions};

/// Tunable constants of the model (documented estimates; the *shape* of
/// every reproduced curve is insensitive to modest changes here).
#[derive(Clone, Copy, Debug)]
pub struct ModelParams {
    /// Memory-level parallelism without software prefetching.
    pub mlp: f64,
    /// MLP with software prefetching (hides more latency).
    pub mlp_prefetch: f64,
    /// Fixed issue overhead per micro-kernel iteration (cycles),
    /// amortized over the FMA count of the iteration.
    pub issue_cycles: f64,
    /// Extra per-iteration penalty (cycles) charged when the *B-loaded*
    /// dimension dominates (`nr > mr`): models the WAR hazards the paper
    /// observes in MK4x12 vs MK12x4 (§4.2.1).
    pub war_cycles: f64,
    /// Thread barrier cost (seconds) per synchronization point and thread.
    pub barrier_s: f64,
    /// Fraction of peak reached by the unblocked panel factorization
    /// (mostly-sequential, latency-bound: paper §2.1).
    pub pfact_efficiency: f64,
    /// Fraction of peak reached by the triangular solve.
    pub trsm_efficiency: f64,
}

impl Default for ModelParams {
    fn default() -> Self {
        Self {
            mlp: 4.0,
            mlp_prefetch: 10.0,
            issue_cycles: 2.0,
            war_cycles: 1.0,
            barrier_s: 2e-6,
            pfact_efficiency: 0.18,
            trsm_efficiency: 0.35,
        }
    }
}

/// A time/GFLOPS estimate.
#[derive(Clone, Copy, Debug)]
pub struct PerfEstimate {
    pub time_s: f64,
    pub gflops: f64,
    /// Share of time attributed to memory stalls (diagnostics).
    pub mem_bound_frac: f64,
    /// Simulated L2 hit ratio (when simulation backed).
    pub l2_hit_ratio: Option<f64>,
}

/// Compute-side time: peak de-rated by micro-kernel issue overhead and
/// fringe waste.
fn compute_time(arch: &Arch, dims: GemmDims, cfg: &GemmConfig, p: &ModelParams) -> f64 {
    let mk = cfg.mk;
    let lanes = arch.regs.f64_lanes() as f64;
    let fma_per_iter = (mk.mr as f64 / lanes).ceil() * mk.nr as f64;
    let war = if mk.nr > mk.mr { p.war_cycles } else { 0.0 };
    let overhead = 1.0 + (p.issue_cycles + war) / fma_per_iter;
    let m_pad = (dims.m.div_ceil(mk.mr) * mk.mr) as f64 / dims.m.max(1) as f64;
    let n_pad = (dims.n.div_ceil(mk.nr) * mk.nr) as f64 / dims.n.max(1) as f64;
    dims.flops() / (arch.peak_gflops_core() * 1e9) * overhead * m_pad * n_pad
}

/// Memory-side time from simulated per-level accesses.
///
/// L1 hits are free (folded into the FMA pipeline) and L2 *hits* are
/// nearly free: the packed buffers are streamed with unit stride, which
/// hardware prefetchers move L2 -> L1 ahead of use — this is exactly why
/// the paper wants `Ac` resident in L2. What costs time is traffic that
/// *misses* the L2 (served by L3 or DRAM), de-rated by the memory-level
/// parallelism factor.
fn memory_time(arch: &Arch, sim: &GemmSimStats, prefetch: bool, p: &ModelParams) -> f64 {
    let (_l1_acc, l2_acc, l3_acc, dram) = sim.scaled_accesses();
    let l2 = 1.0 * l2_acc; // streaming, prefetch-hidden: ~1 cycle/line
    let l3 = arch.l3().map(|l| l.latency_cycles).unwrap_or(0.0) * l3_acc;
    let mem = arch.mem_latency_cycles * dram;
    let mlp = if prefetch { p.mlp_prefetch } else { p.mlp };
    (l2 + l3 + mem) / mlp / (arch.freq_ghz * 1e9)
}

/// Simulation-backed single-core GEMM estimate.
pub fn gemm_perf(
    arch: &Arch,
    dims: GemmDims,
    cfg: &GemmConfig,
    prefetch: bool,
    opts: TraceOptions,
    params: &ModelParams,
) -> PerfEstimate {
    let sim = simulate_gemm(arch, dims, cfg, opts, false);
    gemm_perf_from_sim(arch, dims, cfg, &sim, prefetch, params)
}

/// As [`gemm_perf`] but reusing an existing simulation result.
pub fn gemm_perf_from_sim(
    arch: &Arch,
    dims: GemmDims,
    cfg: &GemmConfig,
    sim: &GemmSimStats,
    prefetch: bool,
    params: &ModelParams,
) -> PerfEstimate {
    let tc = compute_time(arch, dims, cfg, params);
    let tm = memory_time(arch, sim, prefetch, params);
    // Additive combination: the dominant skinny-k penalties (C-tile
    // latency at macro-kernel boundaries, Bc re-stream misses) are
    // exposures the FMA pipeline cannot hide, so they add to compute
    // time rather than overlapping with it; MLP inside memory_time
    // already accounts for intra-stream overlap.
    let time = tc + tm;
    PerfEstimate {
        time_s: time,
        gflops: dims.flops() / time / 1e9,
        mem_bound_frac: tm / (tc + tm),
        l2_hit_ratio: Some(sim.l2_hit_ratio()),
    }
}

/// Work-partition imbalance factor of parallelizing a loop with
/// `chunks` equal chunks over `threads` threads: slowest thread's load
/// relative to a perfect split (>= 1).
pub fn imbalance_factor(chunks: usize, threads: usize) -> f64 {
    if chunks == 0 || threads <= 1 {
        return 1.0;
    }
    let per = chunks.div_ceil(threads) as f64;
    per * threads as f64 / chunks as f64
}

/// Multicore GEMM estimate for loop G3/G4 parallelization.
pub fn gemm_perf_parallel(
    arch: &Arch,
    dims: GemmDims,
    cfg: &GemmConfig,
    threads: usize,
    target: ParallelLoop,
    prefetch: bool,
    opts: TraceOptions,
    params: &ModelParams,
) -> PerfEstimate {
    if threads <= 1 {
        return gemm_perf(arch, dims, cfg, prefetch, opts, params);
    }
    let ccp = cfg.ccp.clamp_to(dims);
    // Per-core view: shared caches are sliced only under loop G3, where
    // each thread packs its *own* Ac into the shared level. Under G4 all
    // threads stream the same Ac/Bc, so the full capacity applies.
    let slice = target == ParallelLoop::G3;
    let sim = simulate_gemm(arch, dims, cfg, opts, slice);
    let tc = compute_time(arch, dims, cfg, params);
    let tm = memory_time(arch, &sim, prefetch, params);
    // Imbalance of the partitioned loop.
    let (chunks, barriers) = match target {
        ParallelLoop::G3 => {
            let c = dims.m.div_ceil(ccp.mc);
            let b = dims.n.div_ceil(ccp.nc) * dims.k.div_ceil(ccp.kc);
            (c, b)
        }
        ParallelLoop::G4 => {
            let c = ccp.nc.min(dims.n).div_ceil(cfg.mk.nr);
            let b = dims.n.div_ceil(ccp.nc) * dims.k.div_ceil(ccp.kc) * dims.m.div_ceil(ccp.mc);
            (c, b)
        }
    };
    let imb = imbalance_factor(chunks, threads);
    // Packing is not parallelized in our engine: it stays on the leader.
    // Approximate packing traffic cost as part of tm; the serial fraction
    // is its share of total memory lines.
    let serial_pack_frac = 0.12; // measured share of packing in the trace
    let t_base = tc + tm;
    let t_parallel = (t_base * (1.0 - serial_pack_frac)) / threads as f64 * imb;
    let t_serial = t_base * serial_pack_frac;
    let t_sync = barriers as f64 * params.barrier_s * (threads as f64).log2().max(1.0);
    let time = t_parallel + t_serial + t_sync;
    PerfEstimate {
        time_s: time,
        gflops: dims.flops() / time / 1e9,
        mem_bound_frac: tm / (tc + tm),
        l2_hit_ratio: Some(sim.l2_hit_ratio()),
    }
}

/// LU estimate composed per iteration of the blocked algorithm
/// (paper Figure 2): PFACT (sequential) + TSOLVE + trailing GEMM.
///
/// The GEMM term is simulation-backed on a geometric grid of trailing
/// sizes and interpolated between grid points (the access pattern varies
/// smoothly with the trailing dimension).
#[allow(clippy::too_many_arguments)]
pub fn lu_perf(
    arch: &Arch,
    s: usize,
    b: usize,
    config_for: &dyn Fn(GemmDims) -> GemmConfig,
    threads: usize,
    target: ParallelLoop,
    prefetch: bool,
    params: &ModelParams,
) -> PerfEstimate {
    let peak = arch.peak_gflops_core() * 1e9;
    // Build the GEMM rate grid: trailing sizes s-b, and halvings down to b.
    let mut grid_sizes: Vec<usize> = Vec::new();
    let mut sz = s.saturating_sub(b);
    while sz >= b.max(64) {
        grid_sizes.push(sz);
        sz /= 2;
    }
    if grid_sizes.is_empty() {
        grid_sizes.push(b.max(64));
    }
    let grid_rates: Vec<f64> = grid_sizes
        .iter()
        .map(|&r| {
            let dims = GemmDims::new(r, r, b);
            let cfg = config_for(dims);
            let est = if threads > 1 {
                gemm_perf_parallel(arch, dims, &cfg, threads, target, prefetch, TraceOptions::sampled(), params)
            } else {
                gemm_perf(arch, dims, &cfg, prefetch, TraceOptions::sampled(), params)
            };
            est.gflops * 1e9
        })
        .collect();
    let rate_at = |r: usize| -> f64 {
        if r >= grid_sizes[0] {
            return grid_rates[0];
        }
        for w in 0..grid_sizes.len() - 1 {
            let (hi, lo) = (grid_sizes[w], grid_sizes[w + 1]);
            if r <= hi && r >= lo {
                let t = (r - lo) as f64 / (hi - lo).max(1) as f64;
                return grid_rates[w + 1] + t * (grid_rates[w] - grid_rates[w + 1]);
            }
        }
        *grid_rates.last().unwrap()
    };

    let mut total = 0.0f64;
    let mut k = 0;
    while k < s {
        let bb = b.min(s - k);
        let rows = s - k;
        let rest = s - k - bb;
        // PFACT: ~ rows * bb^2 flops, sequential, latency-bound.
        let pf_flops = rows as f64 * (bb * bb) as f64;
        total += pf_flops / (peak * params.pfact_efficiency);
        if rest > 0 {
            // TSOLVE: bb^2 * rest flops; parallelizes with the trailing
            // update's thread count (it is a Level-3 kernel too).
            let ts_flops = (bb * bb) as f64 * rest as f64;
            let ts_thr = if threads > 1 { threads as f64 * 0.6 } else { 1.0 };
            total += ts_flops / (peak * params.trsm_efficiency * ts_thr);
            // GEMM: 2 * rest^2 * bb flops at the interpolated rate.
            let g_flops = 2.0 * (rest * rest) as f64 * bb as f64;
            total += g_flops / rate_at(rest);
        }
        k += bb;
    }
    let flops = crate::lapack::lu::lu_flops(s);
    PerfEstimate { time_s: total, gflops: flops / total / 1e9, mem_bound_frac: 0.0, l2_hit_ratio: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282};
    use crate::model::{blis_static, refined_ccp, MicroKernel};

    fn p() -> ModelParams {
        ModelParams::default()
    }

    fn cfg_blis(arch_name: &str, dims: GemmDims) -> GemmConfig {
        let c = blis_static(arch_name).unwrap();
        GemmConfig { mk: c.mk, ccp: c.ccp.clamp_to(dims) }
    }

    fn cfg_mod(arch: &Arch, mk: MicroKernel, dims: GemmDims) -> GemmConfig {
        GemmConfig { mk, ccp: refined_ccp(arch, mk, dims).clamp_to(dims) }
    }

    #[test]
    fn estimates_are_positive_and_below_peak() {
        let arch = carmel();
        let dims = GemmDims::new(500, 500, 128);
        let cfg = cfg_mod(&arch, MicroKernel::new(6, 8), dims);
        let e = gemm_perf(&arch, dims, &cfg, false, TraceOptions::sampled(), &p());
        assert!(e.time_s > 0.0);
        assert!(e.gflops > 0.0 && e.gflops <= arch.peak_gflops_core());
    }

    #[test]
    fn mod_beats_blis_for_skinny_k_on_carmel() {
        // Reproduces the direction of paper Figure 9 at small k.
        let arch = carmel();
        let dims = GemmDims::new(2000, 2000, 96);
        let blis = gemm_perf(&arch, dims, &cfg_blis("carmel", dims), false, TraceOptions::sampled(), &p());
        let refined = gemm_perf(
            &arch,
            dims,
            &cfg_mod(&arch, MicroKernel::new(6, 8), dims),
            false,
            TraceOptions::sampled(),
            &p(),
        );
        assert!(
            refined.gflops > blis.gflops,
            "MOD ({:.2}) must beat BLIS ({:.2}) at k=96",
            refined.gflops,
            blis.gflops
        );
    }

    #[test]
    fn prefetch_helps_when_memory_bound() {
        let arch = epyc7282();
        let dims = GemmDims::new(1000, 1000, 64);
        let cfg = cfg_blis("epyc", dims);
        let no_pf = gemm_perf(&arch, dims, &cfg, false, TraceOptions::sampled(), &p());
        let pf = gemm_perf(&arch, dims, &cfg, true, TraceOptions::sampled(), &p());
        assert!(pf.gflops >= no_pf.gflops, "prefetch must not hurt");
    }

    #[test]
    fn imbalance_factor_matches_paper_example() {
        // §4.3.2: m=10000, mc=384 -> 27 chunks over 16 threads: some
        // threads get 2, a perfect split would be 27/16 = 1.6875:
        // factor = 2/1.6875 = 1.185.
        let f = imbalance_factor(10_000usize.div_ceil(384), 16);
        assert!((f - 2.0 / (27.0 / 16.0)).abs() < 1e-12);
        // Fine-grained G4 distribution is nearly balanced.
        assert!(imbalance_factor(2000 / 8, 16) < 1.07);
        assert_eq!(imbalance_factor(5, 1), 1.0);
        assert_eq!(imbalance_factor(0, 8), 1.0);
    }

    #[test]
    fn g3_parallel_suffers_with_large_mc() {
        // The Figure 12 (middle) inversion: with 16 threads and the
        // refined model's large mc, G3 parallel MOD loses to G3 parallel
        // BLIS even though MOD wins sequentially.
        let arch = epyc7282();
        let dims = GemmDims::new(2000, 2000, 64);
        let blis = cfg_blis("epyc", dims); // mc = 72 -> many chunks
        let mkb = blis.mk;
        let refined = cfg_mod(&arch, mkb, dims); // mc = 768 -> few chunks
        let tb = gemm_perf_parallel(&arch, dims, &blis, 16, ParallelLoop::G3, false, TraceOptions::sampled(), &p());
        let tm = gemm_perf_parallel(&arch, dims, &refined, 16, ParallelLoop::G3, false, TraceOptions::sampled(), &p());
        let chunks_blis = 2000usize.div_ceil(72);
        let chunks_mod = 2000usize.div_ceil(768);
        assert!(imbalance_factor(chunks_mod, 16) > imbalance_factor(chunks_blis, 16));
        // The G4 ranking flips back in MOD's favour.
        let gb = gemm_perf_parallel(&arch, dims, &blis, 16, ParallelLoop::G4, false, TraceOptions::sampled(), &p());
        let gm = gemm_perf_parallel(&arch, dims, &refined, 16, ParallelLoop::G4, false, TraceOptions::sampled(), &p());
        let g3_ratio = tm.gflops / tb.gflops;
        let g4_ratio = gm.gflops / gb.gflops;
        assert!(
            g4_ratio > g3_ratio,
            "MOD/BLIS ratio must improve from G3 ({g3_ratio:.2}) to G4 ({g4_ratio:.2})"
        );
    }

    #[test]
    fn lu_model_runs_and_scales() {
        let arch = carmel();
        let cfg_fn = |dims: GemmDims| cfg_mod(&carmel(), MicroKernel::new(6, 8), dims);
        let seq = lu_perf(&arch, 1000, 128, &cfg_fn, 1, ParallelLoop::G4, false, &p());
        assert!(seq.gflops > 0.0 && seq.gflops < arch.peak_gflops_core());
        let par = lu_perf(&arch, 1000, 128, &cfg_fn, 8, ParallelLoop::G4, false, &p());
        assert!(par.gflops > seq.gflops, "8 threads must beat 1 in the model");
        assert!(par.gflops < arch.peak_gflops_socket());
    }

    #[test]
    fn lu_large_b_hits_pfact_wall() {
        // Paper Figure 10: as b grows, the mostly-sequential PFACT eats
        // the parallel speedup.
        let arch = carmel();
        let cfg_fn = |dims: GemmDims| cfg_mod(&carmel(), MicroKernel::new(6, 8), dims);
        let b_small = lu_perf(&arch, 2000, 64, &cfg_fn, 8, ParallelLoop::G4, false, &p());
        let b_huge = lu_perf(&arch, 2000, 512, &cfg_fn, 8, ParallelLoop::G4, false, &p());
        assert!(
            b_small.gflops > b_huge.gflops,
            "b=512 ({:.1}) must underperform b=64 ({:.1}) in parallel",
            b_huge.gflops,
            b_small.gflops
        );
    }
}
