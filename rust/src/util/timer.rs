//! Timing helpers: a stopwatch and a repetition-based measurement loop
//! (the paper reports averages over many repetitions; we do the same and
//! additionally keep min/median for robustness on a noisy shared host).

use std::time::{Duration, Instant};

/// A simple stopwatch.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Aggregated timing of repeated runs of one operation.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub median_s: f64,
    pub max_s: f64,
}

impl Measurement {
    /// GFLOPS given the flop count of ONE repetition, using the mean time
    /// (matching the paper's averaged reporting).
    pub fn gflops(&self, flops: f64) -> f64 {
        flops / self.mean_s / 1e9
    }

    /// GFLOPS using the minimum time (least-noise estimate).
    pub fn gflops_best(&self, flops: f64) -> f64 {
        flops / self.min_s / 1e9
    }
}

/// Run `f` repeatedly until both `min_reps` runs and `min_time_s` seconds
/// of accumulated work are reached, then aggregate.
pub fn measure(min_reps: usize, min_time_s: f64, mut f: impl FnMut()) -> Measurement {
    // One warm-up run (population of caches, page faults, lazy init).
    f();
    let mut times = Vec::new();
    let total = Stopwatch::start();
    loop {
        let sw = Stopwatch::start();
        f();
        times.push(sw.elapsed_secs());
        if times.len() >= min_reps && total.elapsed_secs() >= min_time_s {
            break;
        }
        // Hard cap so a badly mis-sized workload cannot hang a bench run.
        if times.len() >= 10_000 {
            break;
        }
    }
    summarize(&times)
}

/// Aggregate a set of per-repetition times (seconds).
pub fn summarize(times: &[f64]) -> Measurement {
    assert!(!times.is_empty());
    let mut sorted = times.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = sorted.iter().sum::<f64>() / sorted.len() as f64;
    Measurement {
        reps: sorted.len(),
        mean_s: mean,
        min_s: sorted[0],
        median_s: sorted[sorted.len() / 2],
        max_s: *sorted.last().unwrap(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summarize_basic() {
        let m = summarize(&[3.0, 1.0, 2.0]);
        assert_eq!(m.reps, 3);
        assert_eq!(m.min_s, 1.0);
        assert_eq!(m.max_s, 3.0);
        assert_eq!(m.median_s, 2.0);
        assert!((m.mean_s - 2.0).abs() < 1e-12);
    }

    #[test]
    fn measure_runs_at_least_min_reps() {
        let mut n = 0usize;
        let m = measure(5, 0.0, || n += 1);
        assert!(m.reps >= 5);
        assert_eq!(n, m.reps + 1); // +1 warm-up
    }

    #[test]
    fn gflops_math() {
        let m = Measurement { reps: 1, mean_s: 0.5, min_s: 0.25, median_s: 0.5, max_s: 0.5 };
        assert!((m.gflops(1e9) - 2.0).abs() < 1e-12);
        assert!((m.gflops_best(1e9) - 4.0).abs() < 1e-12);
    }
}
