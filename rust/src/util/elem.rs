//! Element types of the DLA stack.
//!
//! The paper's analytical model counts cache capacity, SIMD lanes and
//! peak flops in *elements*, not bytes — so the whole stack (matrices,
//! packing, micro-kernels, CCP model, factorizations) is generic over an
//! [`Elem`] and every model entry point takes the element width as a
//! parameter. Two instantiations are provided: `f64` (the historical
//! default — every `f64` code path is the exact pre-generic code after
//! monomorphization, so results stay bitwise identical) and `f32`
//! (double the SIMD lanes, double the cache-resident panel footprint,
//! and the storage type of the mixed-precision solvers in
//! `lapack::refine`).

use std::fmt;

/// Runtime tag for an [`Elem`] instantiation: the dtype key of the
/// engine's memoized config/team-size caches and of the per-precision
/// serving metrics.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum DType {
    F64,
    F32,
}

impl DType {
    /// Element width in bytes (what the cache/CCP arithmetic divides by).
    pub const fn size_bytes(self) -> usize {
        match self {
            DType::F64 => 8,
            DType::F32 => 4,
        }
    }

    pub const fn name(self) -> &'static str {
        match self {
            DType::F64 => "f64",
            DType::F32 => "f32",
        }
    }
}

impl fmt::Display for DType {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A matrix element type. The arithmetic surface is exactly what the
/// blocked algorithms use (ring ops, compare, abs); conversions to/from
/// `f64` serve the mixed-precision demote/promote paths and the
/// f64-valued norm helpers.
pub trait Elem:
    Copy
    + PartialEq
    + PartialOrd
    + Send
    + Sync
    + 'static
    + fmt::Debug
    + fmt::Display
    + std::ops::Add<Output = Self>
    + std::ops::Sub<Output = Self>
    + std::ops::Mul<Output = Self>
    + std::ops::Div<Output = Self>
    + std::ops::Neg<Output = Self>
    + std::ops::AddAssign
    + std::ops::SubAssign
    + std::ops::MulAssign
{
    const ZERO: Self;
    const ONE: Self;
    /// The runtime dtype tag of this instantiation.
    const DTYPE: DType;

    /// Truncating conversion from f64 (exact for `f64`).
    fn from_f64(v: f64) -> Self;
    /// Widening conversion to f64 (exact for both instantiations).
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
}

impl Elem for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const DTYPE: DType = DType::F64;

    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self
    }

    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
}

impl Elem for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const DTYPE: DType = DType::F32;

    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }

    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }

    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_widths_and_names() {
        assert_eq!(DType::F64.size_bytes(), 8);
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::F64.name(), "f64");
        assert_eq!(format!("{}", DType::F32), "f32");
        assert_eq!(<f64 as Elem>::DTYPE, DType::F64);
        assert_eq!(<f32 as Elem>::DTYPE, DType::F32);
    }

    #[test]
    fn conversions_roundtrip() {
        assert_eq!(f64::from_f64(1.5), 1.5);
        assert_eq!(f32::from_f64(1.5), 1.5f32);
        assert_eq!(Elem::to_f64(0.25f32), 0.25);
        assert_eq!(<f32 as Elem>::ONE + <f32 as Elem>::ONE, 2.0f32);
        assert!(Elem::abs(-2.0f32) == 2.0f32);
    }
}
