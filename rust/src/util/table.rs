//! Plain-text table rendering in the style of the paper's tables, plus a
//! TSV writer for machine-readable results under `results/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// Column alignment.
#[derive(Clone, Copy, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A text table builder.
pub struct Table {
    title: String,
    headers: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            aligns: headers.iter().map(|_| Align::Right).collect(),
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (defaults to right-aligned).
    pub fn aligns(mut self, aligns: &[Align]) -> Self {
        assert_eq!(aligns.len(), self.headers.len());
        self.aligns = aligns.to_vec();
        self
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: a row of displayable items.
    pub fn row_disp(&mut self, cells: &[&dyn std::fmt::Display]) -> &mut Self {
        let cells: Vec<String> = cells.iter().map(|c| c.to_string()).collect();
        self.row(&cells)
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "== {} ==", self.title);
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], out: &mut String| {
            out.push('|');
            for i in 0..ncol {
                let cell = &cells[i];
                let pad = widths[i] - cell.len();
                match self.aligns[i] {
                    Align::Left => {
                        let _ = write!(out, " {}{} |", cell, " ".repeat(pad));
                    }
                    Align::Right => {
                        let _ = write!(out, " {}{} |", " ".repeat(pad), cell);
                    }
                }
            }
            out.push('\n');
        };
        out.push_str(&sep);
        out.push('\n');
        fmt_row(&self.headers, &mut out);
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            fmt_row(row, &mut out);
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Write the table as TSV (headers + rows) to `path`, creating parent
    /// directories as needed.
    pub fn write_tsv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "# {}", self.title)?;
        writeln!(f, "{}", self.headers.join("\t"))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join("\t"))?;
        }
        Ok(())
    }
}

/// Render a GFLOPS-vs-x series as a rough ASCII plot (the "figure" analogue
/// for a terminal). `series` is a list of (label, points) with shared xs.
pub fn ascii_plot(title: &str, xs: &[usize], series: &[(&str, Vec<f64>)], width: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "-- {} --", title);
    let ymax = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .fold(0.0f64, f64::max)
        .max(1e-12);
    for (label, ys) in series {
        let _ = writeln!(out, "  {}", label);
        for (x, y) in xs.iter().zip(ys) {
            let n = ((y / ymax) * width as f64).round() as usize;
            let _ = writeln!(out, "  {:>6} | {}{:>8.2}", x, "#".repeat(n), y);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["k", "GFLOPS"]);
        t.row(&["64".into(), "3.10".into()]);
        t.row(&["2000".into(), "10.25".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains("|   64 |"));
        assert!(r.contains("| 2000 |"));
    }

    #[test]
    fn tsv_roundtrip() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        let p = std::env::temp_dir().join("dla_table_test.tsv");
        t.write_tsv(&p).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert!(s.contains("a\tb"));
        assert!(s.contains("1\t2"));
        let _ = std::fs::remove_file(&p);
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
