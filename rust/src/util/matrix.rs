//! Column-major `f64` matrices.
//!
//! The whole DLA stack in this crate (packing, micro-kernels, LU) follows
//! the BLAS/LAPACK convention: matrices are stored column-major with an
//! explicit leading dimension, so sub-matrix views ("panels" in the paper's
//! terminology) are cheap and map 1:1 onto the algorithm descriptions.

use crate::util::rng::Pcg64;
use std::fmt;

/// An owned column-major `f64` matrix.
#[derive(Clone, PartialEq)]
pub struct MatrixF64 {
    rows: usize,
    cols: usize,
    /// Leading dimension (stride between columns). `ld >= rows`.
    ld: usize,
    data: Vec<f64>,
}

impl MatrixF64 {
    /// Zero-filled `rows x cols` matrix with a tight leading dimension.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, ld: rows.max(1), data: vec![0.0; rows.max(1) * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix with entries drawn uniformly from `[-1, 1)`.
    pub fn random(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = rng.next_f64() * 2.0 - 1.0;
            }
        }
        m
    }

    /// A random diagonally-dominant matrix (safe for unpivoted demos and a
    /// well-conditioned input for LU with partial pivoting).
    pub fn random_diag_dominant(n: usize, rng: &mut Pcg64) -> Self {
        let mut m = Self::random(n, n, rng);
        for i in 0..n {
            let row_sum: f64 = (0..n).map(|j| m[(i, j)].abs()).sum();
            m[(i, i)] = row_sum + 1.0;
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build from a row-major slice (convenience for tests).
    pub fn from_row_major(rows: usize, cols: usize, v: &[f64]) -> Self {
        assert_eq!(v.len(), rows * cols);
        Self::from_fn(rows, cols, |i, j| v[i * cols + j])
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Raw column-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    #[inline]
    pub fn as_ptr(&self) -> *const f64 {
        self.data.as_ptr()
    }

    #[inline]
    pub fn as_mut_ptr(&mut self) -> *mut f64 {
        self.data.as_mut_ptr()
    }

    /// Immutable view of the whole matrix.
    pub fn view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, ld: self.ld, data: &self.data }
    }

    /// Immutable view of the sub-matrix starting at `(i, j)` of size
    /// `r x c`.
    pub fn sub(&self, i: usize, j: usize, r: usize, c: usize) -> MatView<'_> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub out of bounds");
        MatView { rows: r, cols: c, ld: self.ld, data: &self.data[j * self.ld + i..] }
    }

    /// Mutable view of the whole matrix.
    pub fn view_mut(&mut self) -> MatViewMut<'_> {
        MatViewMut { rows: self.rows, cols: self.cols, ld: self.ld, data: &mut self.data }
    }

    /// Mutable view of the sub-matrix starting at `(i, j)` of size `r x c`.
    pub fn sub_mut(&mut self, i: usize, j: usize, r: usize, c: usize) -> MatViewMut<'_> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub_mut out of bounds");
        let ld = self.ld;
        MatViewMut { rows: r, cols: c, ld, data: &mut self.data[j * ld + i..] }
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.view().fro_norm()
    }

    /// Max-abs (entrywise infinity) norm.
    pub fn max_abs(&self) -> f64 {
        self.view().max_abs()
    }

    /// `max |self - other|` over all entries.
    pub fn max_abs_diff(&self, other: &MatrixF64) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut d: f64 = 0.0;
        for j in 0..self.cols {
            for i in 0..self.rows {
                d = d.max((self[(i, j)] - other[(i, j)]).abs());
            }
        }
        d
    }

    /// Transposed copy.
    pub fn transposed(&self) -> MatrixF64 {
        MatrixF64::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }
}

impl std::ops::Index<(usize, usize)> for MatrixF64 {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[j * self.ld + i]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatrixF64 {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.ld + i]
    }
}

impl fmt::Debug for MatrixF64 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "MatrixF64 {}x{} (ld={})", self.rows, self.cols, self.ld)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            for j in 0..cmax {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "...")?;
        }
        Ok(())
    }
}

/// Borrowed column-major view (`rows x cols`, stride `ld`).
#[derive(Clone, Copy)]
pub struct MatView<'a> {
    pub rows: usize,
    pub cols: usize,
    pub ld: usize,
    /// Backing slice; element `(i, j)` lives at `data[j * ld + i]`.
    pub data: &'a [f64],
}

impl<'a> MatView<'a> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.ld + i]
    }

    /// Sub-view at `(i, j)` of size `r x c`.
    pub fn sub(&self, i: usize, j: usize, r: usize, c: usize) -> MatView<'a> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub out of bounds");
        MatView { rows: r, cols: c, ld: self.ld, data: &self.data[j * self.ld + i..] }
    }

    pub fn to_owned_matrix(&self) -> MatrixF64 {
        MatrixF64::from_fn(self.rows, self.cols, |i, j| self.at(i, j))
    }

    pub fn fro_norm(&self) -> f64 {
        let mut s = 0.0;
        for j in 0..self.cols {
            for i in 0..self.rows {
                let v = self.at(i, j);
                s += v * v;
            }
        }
        s.sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        let mut d: f64 = 0.0;
        for j in 0..self.cols {
            for i in 0..self.rows {
                d = d.max(self.at(i, j).abs());
            }
        }
        d
    }
}

/// Mutable column-major view.
pub struct MatViewMut<'a> {
    pub rows: usize,
    pub cols: usize,
    pub ld: usize,
    pub data: &'a mut [f64],
}

impl<'a> MatViewMut<'a> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.ld + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.ld + i] = v;
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.ld + i]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> MatView<'_> {
        MatView { rows: self.rows, cols: self.cols, ld: self.ld, data: self.data }
    }

    /// Reborrow a mutable sub-view at `(i, j)` of size `r x c`.
    pub fn sub_mut(&mut self, i: usize, j: usize, r: usize, c: usize) -> MatViewMut<'_> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub_mut out of bounds");
        let ld = self.ld;
        MatViewMut { rows: r, cols: c, ld, data: &mut self.data[j * ld + i..] }
    }

    /// Split into two disjoint mutable column-block views:
    /// `[0, jsplit)` and `[jsplit, cols)`.
    pub fn split_cols_mut(&mut self, jsplit: usize) -> (MatViewMut<'_>, MatViewMut<'_>) {
        assert!(jsplit <= self.cols);
        let ld = self.ld;
        let (left, right) = self.data.split_at_mut(jsplit * ld);
        (
            MatViewMut { rows: self.rows, cols: jsplit, ld, data: left },
            MatViewMut { rows: self.rows, cols: self.cols - jsplit, ld, data: right },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_views() {
        let mut m = MatrixF64::zeros(4, 3);
        m[(2, 1)] = 7.5;
        assert_eq!(m.view().at(2, 1), 7.5);
        let v = m.sub(1, 1, 3, 2);
        assert_eq!(v.at(1, 0), 7.5);
        let mut vm = m.sub_mut(2, 0, 2, 3);
        vm.set(0, 1, -1.0);
        assert_eq!(m[(2, 1)], -1.0);
    }

    #[test]
    fn from_row_major_layout() {
        let m = MatrixF64::from_row_major(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        // Column-major storage: first column is (1, 4).
        assert_eq!(&m.as_slice()[0..2], &[1.0, 4.0]);
    }

    #[test]
    fn norms() {
        let m = MatrixF64::from_row_major(2, 2, &[3., 0., 0., 4.]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn diag_dominant_is_dominant() {
        let mut rng = Pcg64::seed(42);
        let m = MatrixF64::random_diag_dominant(16, &mut rng);
        for i in 0..16 {
            let off: f64 = (0..16).filter(|&j| j != i).map(|j| m[(i, j)].abs()).sum();
            assert!(m[(i, i)].abs() > off);
        }
    }

    #[test]
    fn split_cols_disjoint() {
        let mut m = MatrixF64::zeros(3, 4);
        let mut vm = m.view_mut();
        let (mut l, mut r) = vm.split_cols_mut(2);
        l.set(0, 0, 1.0);
        r.set(2, 1, 2.0);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(2, 3)], 2.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::seed(1);
        let m = MatrixF64::random(5, 7, &mut rng);
        assert_eq!(m.transposed().transposed(), m);
    }
}
