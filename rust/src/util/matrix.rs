//! Column-major matrices, generic over the element type.
//!
//! The whole DLA stack in this crate (packing, micro-kernels, LU) follows
//! the BLAS/LAPACK convention: matrices are stored column-major with an
//! explicit leading dimension, so sub-matrix views ("panels" in the paper's
//! terminology) are cheap and map 1:1 onto the algorithm descriptions.
//!
//! [`Matrix<E>`] (and the borrowed [`MatView`]/[`MatViewMut`]) are generic
//! over an [`Elem`]; the type parameter defaults to `f64`, and
//! [`MatrixF64`] is an alias for `Matrix<f64>`, so every pre-generic call
//! site keeps compiling unchanged — and the monomorphized `f64` code is
//! the exact pre-generic code, preserving bitwise results. [`MatrixF32`]
//! is the single-precision instantiation used by the f32 GEMM path and
//! the mixed-precision solvers.

use crate::util::elem::Elem;
use crate::util::rng::Pcg64;
use std::fmt;

/// An owned column-major matrix of `E` elements.
#[derive(Clone, PartialEq)]
pub struct Matrix<E = f64> {
    rows: usize,
    cols: usize,
    /// Leading dimension (stride between columns). `ld >= rows`.
    ld: usize,
    data: Vec<E>,
}

/// The double-precision matrix the stack historically used everywhere.
pub type MatrixF64 = Matrix<f64>;
/// The single-precision matrix of the f32 SIMD path and the
/// mixed-precision solvers.
pub type MatrixF32 = Matrix<f32>;

impl<E: Elem> Matrix<E> {
    /// Zero-filled `rows x cols` matrix with a tight leading dimension.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, ld: rows.max(1), data: vec![E::ZERO; rows.max(1) * cols] }
    }

    /// Identity matrix of order `n`.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = E::ONE;
        }
        m
    }

    /// Matrix with entries drawn uniformly from `[-1, 1)`. The stream of
    /// f64 draws is identical for every `E` (each draw is rounded to `E`
    /// after the fact), so an f32 matrix from a given seed is the
    /// element-wise rounding of the f64 matrix from that seed.
    pub fn random(rows: usize, cols: usize, rng: &mut Pcg64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = E::from_f64(rng.next_f64() * 2.0 - 1.0);
            }
        }
        m
    }

    /// A random diagonally-dominant matrix (safe for unpivoted demos and a
    /// well-conditioned input for LU with partial pivoting).
    pub fn random_diag_dominant(n: usize, rng: &mut Pcg64) -> Self {
        let mut m = Self::random(n, n, rng);
        for i in 0..n {
            let mut row_sum = E::ZERO;
            for j in 0..n {
                row_sum += m[(i, j)].abs();
            }
            m[(i, i)] = row_sum + E::ONE;
        }
        m
    }

    /// Build from a closure `f(i, j)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> E) -> Self {
        let mut m = Self::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    /// Build from a row-major slice (convenience for tests).
    pub fn from_row_major(rows: usize, cols: usize, v: &[E]) -> Self {
        assert_eq!(v.len(), rows * cols);
        Self::from_fn(rows, cols, |i, j| v[i * cols + j])
    }

    /// Element-wise conversion from another element type (the
    /// demote/promote step of the mixed-precision solvers).
    pub fn convert_from<F: Elem>(src: &Matrix<F>) -> Self {
        Self::from_fn(src.rows(), src.cols(), |i, j| E::from_f64(src[(i, j)].to_f64()))
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn ld(&self) -> usize {
        self.ld
    }

    /// Raw column-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[E] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [E] {
        &mut self.data
    }

    /// True when every entry is finite (no NaN or infinity) — the
    /// admission-validation scan of the serving path. O(rows · cols),
    /// negligible next to the O(n³) work a request buys.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|&v| v.to_f64().is_finite())
    }

    #[inline]
    pub fn as_ptr(&self) -> *const E {
        self.data.as_ptr()
    }

    #[inline]
    pub fn as_mut_ptr(&mut self) -> *mut E {
        self.data.as_mut_ptr()
    }

    /// Immutable view of the whole matrix.
    pub fn view(&self) -> MatView<'_, E> {
        MatView { rows: self.rows, cols: self.cols, ld: self.ld, data: &self.data }
    }

    /// Immutable view of the sub-matrix starting at `(i, j)` of size
    /// `r x c`.
    pub fn sub(&self, i: usize, j: usize, r: usize, c: usize) -> MatView<'_, E> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub out of bounds");
        MatView { rows: r, cols: c, ld: self.ld, data: &self.data[j * self.ld + i..] }
    }

    /// Mutable view of the whole matrix.
    pub fn view_mut(&mut self) -> MatViewMut<'_, E> {
        MatViewMut { rows: self.rows, cols: self.cols, ld: self.ld, data: &mut self.data }
    }

    /// Mutable view of the sub-matrix starting at `(i, j)` of size `r x c`.
    pub fn sub_mut(&mut self, i: usize, j: usize, r: usize, c: usize) -> MatViewMut<'_, E> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub_mut out of bounds");
        let ld = self.ld;
        MatViewMut { rows: r, cols: c, ld, data: &mut self.data[j * ld + i..] }
    }

    /// Frobenius norm (accumulated in f64 for every element type).
    pub fn fro_norm(&self) -> f64 {
        self.view().fro_norm()
    }

    /// Max-abs (entrywise infinity) norm, as f64.
    pub fn max_abs(&self) -> f64 {
        self.view().max_abs()
    }

    /// `max |self - other|` over all entries, as f64.
    pub fn max_abs_diff(&self, other: &Matrix<E>) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let mut d: f64 = 0.0;
        for j in 0..self.cols {
            for i in 0..self.rows {
                d = d.max((self[(i, j)].to_f64() - other[(i, j)].to_f64()).abs());
            }
        }
        d
    }

    /// Transposed copy.
    pub fn transposed(&self) -> Matrix<E> {
        Matrix::from_fn(self.cols, self.rows, |i, j| self[(j, i)])
    }
}

impl<E: Elem> std::ops::Index<(usize, usize)> for Matrix<E> {
    type Output = E;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &E {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[j * self.ld + i]
    }
}

impl<E: Elem> std::ops::IndexMut<(usize, usize)> for Matrix<E> {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut E {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.ld + i]
    }
}

impl<E: Elem> fmt::Debug for Matrix<E> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Matrix<{}> {}x{} (ld={})", E::DTYPE, self.rows, self.cols, self.ld)?;
        let rmax = self.rows.min(8);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            for j in 0..cmax {
                write!(f, "{:>10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > cmax { "..." } else { "" })?;
        }
        if self.rows > rmax {
            writeln!(f, "...")?;
        }
        Ok(())
    }
}

/// Borrowed column-major view (`rows x cols`, stride `ld`).
pub struct MatView<'a, E = f64> {
    pub rows: usize,
    pub cols: usize,
    pub ld: usize,
    /// Backing slice; element `(i, j)` lives at `data[j * ld + i]`.
    pub data: &'a [E],
}

// Manual Clone/Copy: the derive would bound them on `E: Clone`/`E: Copy`
// through the reference field even though a shared borrow is always Copy.
impl<E> Clone for MatView<'_, E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E> Copy for MatView<'_, E> {}

impl<'a, E: Elem> MatView<'a, E> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> E {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.ld + i]
    }

    /// Sub-view at `(i, j)` of size `r x c`.
    pub fn sub(&self, i: usize, j: usize, r: usize, c: usize) -> MatView<'a, E> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub out of bounds");
        MatView { rows: r, cols: c, ld: self.ld, data: &self.data[j * self.ld + i..] }
    }

    pub fn to_owned_matrix(&self) -> Matrix<E> {
        Matrix::from_fn(self.rows, self.cols, |i, j| self.at(i, j))
    }

    pub fn fro_norm(&self) -> f64 {
        let mut s = 0.0;
        for j in 0..self.cols {
            for i in 0..self.rows {
                let v = self.at(i, j).to_f64();
                s += v * v;
            }
        }
        s.sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        let mut d: f64 = 0.0;
        for j in 0..self.cols {
            for i in 0..self.rows {
                d = d.max(self.at(i, j).to_f64().abs());
            }
        }
        d
    }
}

/// Mutable column-major view.
pub struct MatViewMut<'a, E = f64> {
    pub rows: usize,
    pub cols: usize,
    pub ld: usize,
    pub data: &'a mut [E],
}

impl<'a, E: Elem> MatViewMut<'a, E> {
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> E {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.ld + i]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: E) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[j * self.ld + i] = v;
    }

    #[inline]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut E {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[j * self.ld + i]
    }

    /// Reborrow as an immutable view.
    pub fn as_view(&self) -> MatView<'_, E> {
        MatView { rows: self.rows, cols: self.cols, ld: self.ld, data: self.data }
    }

    /// Reborrow a mutable sub-view at `(i, j)` of size `r x c`.
    pub fn sub_mut(&mut self, i: usize, j: usize, r: usize, c: usize) -> MatViewMut<'_, E> {
        assert!(i + r <= self.rows && j + c <= self.cols, "sub_mut out of bounds");
        let ld = self.ld;
        MatViewMut { rows: r, cols: c, ld, data: &mut self.data[j * ld + i..] }
    }

    /// Split into two disjoint mutable column-block views:
    /// `[0, jsplit)` and `[jsplit, cols)`.
    pub fn split_cols_mut(&mut self, jsplit: usize) -> (MatViewMut<'_, E>, MatViewMut<'_, E>) {
        assert!(jsplit <= self.cols);
        let ld = self.ld;
        let (left, right) = self.data.split_at_mut(jsplit * ld);
        (
            MatViewMut { rows: self.rows, cols: jsplit, ld, data: left },
            MatViewMut { rows: self.rows, cols: self.cols - jsplit, ld, data: right },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn index_and_views() {
        let mut m = MatrixF64::zeros(4, 3);
        m[(2, 1)] = 7.5;
        assert_eq!(m.view().at(2, 1), 7.5);
        let v = m.sub(1, 1, 3, 2);
        assert_eq!(v.at(1, 0), 7.5);
        let mut vm = m.sub_mut(2, 0, 2, 3);
        vm.set(0, 1, -1.0);
        assert_eq!(m[(2, 1)], -1.0);
    }

    #[test]
    fn from_row_major_layout() {
        let m = MatrixF64::from_row_major(2, 3, &[1., 2., 3., 4., 5., 6.]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(0, 2)], 3.0);
        assert_eq!(m[(1, 0)], 4.0);
        // Column-major storage: first column is (1, 4).
        assert_eq!(&m.as_slice()[0..2], &[1.0, 4.0]);
    }

    #[test]
    fn norms() {
        let m = MatrixF64::from_row_major(2, 2, &[3., 0., 0., 4.]);
        assert!((m.fro_norm() - 5.0).abs() < 1e-12);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn diag_dominant_is_dominant() {
        let mut rng = Pcg64::seed(42);
        let m = MatrixF64::random_diag_dominant(16, &mut rng);
        for i in 0..16 {
            let off: f64 = (0..16).filter(|&j| j != i).map(|j| m[(i, j)].abs()).sum();
            assert!(m[(i, i)].abs() > off);
        }
    }

    #[test]
    fn split_cols_disjoint() {
        let mut m = MatrixF64::zeros(3, 4);
        let mut vm = m.view_mut();
        let (mut l, mut r) = vm.split_cols_mut(2);
        l.set(0, 0, 1.0);
        r.set(2, 1, 2.0);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(2, 3)], 2.0);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Pcg64::seed(1);
        let m = MatrixF64::random(5, 7, &mut rng);
        assert_eq!(m.transposed().transposed(), m);
    }

    #[test]
    fn f32_matrix_basics() {
        let mut m = MatrixF32::zeros(3, 2);
        m[(1, 0)] = 2.5f32;
        assert_eq!(m.view().at(1, 0), 2.5f32);
        assert_eq!(m.max_abs(), 2.5);
        let id = MatrixF32::identity(3);
        assert_eq!(id[(2, 2)], 1.0f32);
        assert_eq!(id[(0, 2)], 0.0f32);
    }

    #[test]
    fn f32_random_is_rounded_f64_stream() {
        // Same seed: the f32 matrix is the element-wise rounding of the
        // f64 matrix (the draw stream itself is precision-independent).
        let mut r64 = Pcg64::seed(7);
        let mut r32 = Pcg64::seed(7);
        let a = MatrixF64::random(4, 5, &mut r64);
        let b = MatrixF32::random(4, 5, &mut r32);
        for j in 0..5 {
            for i in 0..4 {
                assert_eq!(b[(i, j)], a[(i, j)] as f32);
            }
        }
    }

    #[test]
    fn convert_roundtrip_and_demotion() {
        let mut rng = Pcg64::seed(9);
        let a = MatrixF64::random(6, 4, &mut rng);
        let a32 = MatrixF32::convert_from(&a);
        let back = MatrixF64::convert_from(&a32);
        // Demotion rounds to f32 grid; promoting back is exact.
        assert!(a.max_abs_diff(&back) <= f32::EPSILON as f64);
        for j in 0..4 {
            for i in 0..6 {
                assert_eq!(a32[(i, j)] as f64, back[(i, j)]);
            }
        }
    }
}
