//! A small hand-rolled CLI argument parser (no clap offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and a generated usage string.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (first element must NOT be argv[0]).
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Self {
        let mut args = Args::default();
        let mut iter = it.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = iter.next().unwrap();
                    args.options.insert(body.to_string(), v);
                } else {
                    args.flags.push(body.to_string());
                }
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    /// Parse from the process environment, skipping argv[0].
    pub fn from_env() -> Self {
        Self::parse_from(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name).map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got {v:?}"))).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name).map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got {v:?}"))).unwrap_or(default)
    }

    pub fn get_str<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Comma-separated list of integers, e.g. `--k 64,96,128`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(v) => v
                .split(',')
                .map(|t| t.trim().parse().unwrap_or_else(|_| panic!("--{name} expects a comma-separated integer list, got {v:?}")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixed_forms() {
        let a = parse("fig9 --k 64,96 --arch=carmel --verbose --reps 5");
        assert_eq!(a.positional, vec!["fig9"]);
        assert_eq!(a.get_str("arch", "x"), "carmel");
        assert_eq!(a.get_usize("reps", 0), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.get_usize_list("k", &[]), vec![64, 96]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("cmd");
        assert_eq!(a.get_usize("n", 7), 7);
        assert_eq!(a.get_f64("t", 1.5), 1.5);
        assert!(!a.flag("x"));
        assert_eq!(a.get_usize_list("k", &[1, 2]), vec![1, 2]);
    }

    #[test]
    fn trailing_flag_not_eating_positional() {
        // `--verbose fig9`: "fig9" does not start with --, so it would be
        // consumed as the value; callers put flags last or use `=`.
        let a = parse("fig9 --dry-run");
        assert!(a.flag("dry-run"));
        assert_eq!(a.positional, vec!["fig9"]);
    }
}
