//! Foundation utilities: column-major matrices, RNG, timing, text tables,
//! CLI parsing and small statistics helpers.

pub mod cli;
pub mod elem;
pub mod error;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;

pub use elem::{DType, Elem};
pub use error::DlaError;
pub use matrix::{Matrix, MatrixF32, MatrixF64};
pub use rng::Pcg64;
pub use timer::Stopwatch;

/// Round `x` up to the next multiple of `m` (`m > 0`).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Round `x` down to the previous multiple of `m` (`m > 0`).
#[inline]
pub fn round_down(x: usize, m: usize) -> usize {
    debug_assert!(m > 0);
    (x / m) * m
}

/// `ceil(a / b)` for positive integers.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounding_helpers() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(round_down(9, 8), 8);
        assert_eq!(round_down(7, 8), 0);
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
    }
}
