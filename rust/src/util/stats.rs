//! Small statistics helpers used by the coordinator metrics and the
//! experiment harness.

/// Streaming mean/min/max/count accumulator.
#[derive(Clone, Copy, Debug, Default)]
pub struct Accumulator {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Self { count: 0, sum: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn merge(&mut self, other: &Accumulator) {
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Fixed-bucket latency histogram (log2 buckets over microseconds).
#[derive(Clone, Debug)]
pub struct LatencyHistogram {
    /// bucket i counts latencies in [2^i, 2^(i+1)) microseconds.
    buckets: Vec<u64>,
    acc: Accumulator,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self { buckets: vec![0; 40], acc: Accumulator::new() }
    }

    pub fn record_secs(&mut self, secs: f64) {
        let us = (secs * 1e6).max(0.0);
        self.acc.add(us);
        let idx = if us < 1.0 { 0 } else { (us.log2().floor() as usize).min(self.buckets.len() - 1) };
        self.buckets[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.acc.count
    }

    pub fn mean_us(&self) -> f64 {
        self.acc.mean()
    }

    pub fn max_us(&self) -> f64 {
        if self.acc.count == 0 {
            0.0
        } else {
            self.acc.max
        }
    }

    /// Approximate quantile from bucket boundaries (upper bound of bucket).
    pub fn quantile_us(&self, q: f64) -> f64 {
        assert!((0.0..=1.0).contains(&q));
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut seen = 0;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target {
                return (1u64 << (i + 1)) as f64;
            }
        }
        self.max_us()
    }
}

/// Geometric mean of a slice of positive values.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.ln()).sum();
    (s / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_basics() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0] {
            a.add(x);
        }
        assert_eq!(a.count, 3);
        assert_eq!(a.min, 1.0);
        assert_eq!(a.max, 3.0);
        assert!((a.mean() - 2.0).abs() < 1e-12);
        let mut b = Accumulator::new();
        b.add(10.0);
        a.merge(&b);
        assert_eq!(a.count, 4);
        assert_eq!(a.max, 10.0);
    }

    #[test]
    fn histogram_quantiles_monotone() {
        let mut h = LatencyHistogram::new();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1000);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn geomean_of_equal_is_equal() {
        assert!((geomean(&[4.0, 4.0, 4.0]) - 4.0).abs() < 1e-12);
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
    }
}
