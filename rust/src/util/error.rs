//! Typed errors for the serving path.
//!
//! [`DlaError`] is the single error currency on the
//! `DlaRequest → DlaResponse` route: admission validation, factorization
//! breakdown, deadlines, backpressure and worker loss all surface as one
//! of its variants instead of stringly-typed `anyhow` messages or panics.
//! The taxonomy (and the recovery each variant admits) is documented in
//! the "Failure model" section of `lapack/README.md`.
//!
//! The enum implements `std::error::Error`, so callers that still speak
//! the vendored `anyhow` dialect (the PJRT examples, the benches) convert
//! with `?` for free via the blanket `From<E: Error>` impl.

use std::fmt;

/// Every way a served request can fail, ordered roughly by where on the
/// request path the failure is detected (admission → queue → worker →
/// kernel).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum DlaError {
    /// The request was rejected at admission: non-finite operand entries
    /// (NaN/Inf) or mismatched dimensions. Never retried — the request
    /// can only fail again.
    InvalidInput { reason: String },
    /// A factorization broke down at the given pivot column: an exact
    /// zero pivot in LU, or a non-positive-definite leading minor in
    /// Cholesky. A property of the operand, not of the runtime.
    Singular { pivot: usize },
    /// The per-request deadline expired before a result was produced
    /// (`ServerConfig::with_deadline` / `DLA_DEADLINE_MS`). `waited_ms`
    /// is how long the caller actually waited.
    Timeout { waited_ms: u64 },
    /// The admission queue stayed full through the whole bounded,
    /// jittered retry schedule. `retries` counts the re-attempts made
    /// before giving up — transient by nature; callers may re-submit.
    QueueFull { retries: u32 },
    /// A worker or its reply channel disappeared (thread panicked and
    /// unwound, or the server is shutting down underneath the caller).
    WorkerLost { reason: String },
    /// An unexpected panic was caught and contained on the serving path;
    /// `reason` carries the panic payload. The request that triggered it
    /// fails, the server keeps serving.
    Internal { reason: String },
    /// The overload detector shed this request by policy before it was
    /// admitted: measured queue delay had grown past the analytic service
    /// estimate far enough that serving the `tier` named here would put
    /// Interactive deadlines at risk. `queue_delay_us` is the smoothed
    /// queue wait that tripped the detector. Transient — the caller may
    /// re-submit once load subsides (or at a higher tier).
    Overloaded { tier: &'static str, queue_delay_us: u64 },
    /// The caller cancelled the job through its [`JobHandle`] while it
    /// was still queued; the work was never started. Not transient in the
    /// retry sense — the caller asked for this outcome.
    Cancelled,
    /// ABFT checksum verification caught silent data corruption (a bit
    /// flip in a packed panel, a C tile, or a factored panel) that the
    /// recompute pass — if `DLA_VERIFY=correct` — could not repair.
    /// `phase` names the verified stage ("gemm", "lu-panel", ...),
    /// `tile` the (row, col) origin of the corrupted block. Transient:
    /// the flip lived in runtime state, not in the operand, so a clean
    /// retry is expected to succeed.
    DataCorrupt { phase: &'static str, tile: (usize, usize) },
}

impl fmt::Display for DlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DlaError::InvalidInput { reason } => write!(f, "invalid input: {reason}"),
            DlaError::Singular { pivot } => {
                write!(f, "factorization breakdown at pivot column {pivot}")
            }
            DlaError::Timeout { waited_ms } => {
                write!(f, "deadline expired after {waited_ms} ms")
            }
            DlaError::QueueFull { retries } => {
                write!(f, "admission queue full after {retries} retries")
            }
            DlaError::WorkerLost { reason } => write!(f, "worker lost: {reason}"),
            DlaError::Internal { reason } => write!(f, "internal fault: {reason}"),
            DlaError::Overloaded { tier, queue_delay_us } => {
                write!(f, "overloaded: {tier} tier shed at {queue_delay_us} us queue delay")
            }
            DlaError::Cancelled => write!(f, "cancelled before execution"),
            DlaError::DataCorrupt { phase, tile } => {
                write!(
                    f,
                    "silent data corruption detected in {phase} at tile ({}, {})",
                    tile.0, tile.1
                )
            }
        }
    }
}

impl std::error::Error for DlaError {}

impl DlaError {
    /// True for failures a caller may reasonably retry as-is: transient
    /// runtime conditions rather than properties of the request.
    pub fn is_transient(&self) -> bool {
        matches!(
            self,
            DlaError::Timeout { .. }
                | DlaError::QueueFull { .. }
                | DlaError::WorkerLost { .. }
                | DlaError::Overloaded { .. }
                | DlaError::DataCorrupt { .. }
        )
    }

    /// Render a caught panic payload into a human-readable reason (the
    /// payload of `catch_unwind` is `&str` or `String` in practice).
    pub fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
        if let Some(s) = payload.downcast_ref::<&'static str>() {
            (*s).to_string()
        } else if let Some(s) = payload.downcast_ref::<String>() {
            s.clone()
        } else {
            "panic with a non-string payload".to_string()
        }
    }
}

/// Free-function form of [`DlaError::panic_reason`], for call sites that
/// import it alongside the enum.
pub fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    DlaError::panic_reason(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_specific() {
        let cases: Vec<(DlaError, &str)> = vec![
            (DlaError::InvalidInput { reason: "NaN in a".into() }, "invalid input: NaN in a"),
            (DlaError::Singular { pivot: 3 }, "factorization breakdown at pivot column 3"),
            (DlaError::Timeout { waited_ms: 25 }, "deadline expired after 25 ms"),
            (DlaError::QueueFull { retries: 8 }, "admission queue full after 8 retries"),
            (
                DlaError::Overloaded { tier: "background", queue_delay_us: 900 },
                "overloaded: background tier shed at 900 us queue delay",
            ),
            (DlaError::Cancelled, "cancelled before execution"),
            (
                DlaError::DataCorrupt { phase: "gemm", tile: (128, 256) },
                "silent data corruption detected in gemm at tile (128, 256)",
            ),
        ];
        for (e, text) in cases {
            assert_eq!(format!("{e}"), text);
        }
    }

    #[test]
    fn transient_classification() {
        assert!(DlaError::Timeout { waited_ms: 1 }.is_transient());
        assert!(DlaError::QueueFull { retries: 0 }.is_transient());
        assert!(DlaError::WorkerLost { reason: "x".into() }.is_transient());
        assert!(DlaError::Overloaded { tier: "batch", queue_delay_us: 1 }.is_transient());
        assert!(DlaError::DataCorrupt { phase: "gemm", tile: (0, 0) }.is_transient());
        assert!(!DlaError::Cancelled.is_transient());
        assert!(!DlaError::InvalidInput { reason: "x".into() }.is_transient());
        assert!(!DlaError::Singular { pivot: 0 }.is_transient());
        assert!(!DlaError::Internal { reason: "x".into() }.is_transient());
    }

    #[test]
    fn converts_into_anyhow() {
        fn f() -> anyhow::Result<()> {
            Err(DlaError::Singular { pivot: 2 })?;
            Ok(())
        }
        let e = f().unwrap_err();
        assert_eq!(format!("{e}"), "factorization breakdown at pivot column 2");
    }

    #[test]
    fn panic_payload_rendering() {
        let p = std::panic::catch_unwind(|| panic!("boom {}", 7)).unwrap_err();
        assert_eq!(DlaError::panic_reason(p.as_ref()), "boom 7");
        let q = std::panic::catch_unwind(|| panic!("static boom")).unwrap_err();
        assert_eq!(DlaError::panic_reason(q.as_ref()), "static boom");
    }
}
