//! A small, deterministic PCG-XSH-RR 64/32-based generator.
//!
//! No external RNG crates are available offline, and reproducible streams
//! are required by the experiment harness (every table/figure regeneration
//! uses fixed seeds), so we implement PCG64 (the "PCG-XSL-RR 128/64"
//! variant) directly. Reference: O'Neill, "PCG: A Family of Simple Fast
//! Space-Efficient Statistically Good Algorithms for Random Number
//! Generation" (2014).

/// PCG-XSL-RR 128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a single value (stream constant fixed).
    pub fn seed(seed: u64) -> Self {
        Self::seed_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Seed with an explicit stream selector.
    pub fn seed_stream(seed: u64, stream: u64) -> Self {
        let mut rng = Self { state: 0, inc: ((stream as u128) << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Next 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform `f64` in `[0, 1)` (53 bits of randomness).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` via Lemire's method.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            v.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, v: &'a [T]) -> &'a T {
        &v[self.range(0, v.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::seed(7);
        let mut b = Pcg64::seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Pcg64::seed(8);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::seed(1);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        // Mean of U[0,1) over 10k draws should be close to 0.5.
        assert!((sum / 10_000.0 - 0.5).abs() < 0.02);
    }

    #[test]
    fn bounded_draws_cover_range() {
        let mut rng = Pcg64::seed(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            seen[rng.next_below(10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::seed(5);
        let mut v: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
