//! Model-driven batch planning for the coordinator's request scheduler:
//! the same analytical machinery that picks `mc`/`kc`/`nc` (and the
//! lookahead `t_p`) also decides **which requests are worth coalescing**
//! and **how to partition the worker team across a batch**.
//!
//! The paper's serving-layer consequence: many small requests are
//! exactly the shapes where a full pool dispatch wastes the machine —
//! the G4 `jr` partition hands out `nr`-wide column tiles, so a GEMM
//! with fewer tiles than ranks leaves ranks idle, and even a fully-fed
//! tiny GEMM pays one whole pool epoch (broadcast + barriers) for a few
//! microseconds of math. Like the tiled-algorithm runtimes of Buttari
//! et al. and the kernel-sequence analysis of Peise & Bientinesi (see
//! PAPERS.md), throughput comes from scheduling *sequences* of small
//! kernels onto the machine as one unit:
//!
//! - [`is_batchable`] — admission: a request is batchable when the
//!   [`AnalyticScorer`] single-core estimate is below the policy's
//!   `small_seconds` threshold, or when its G4 grain cannot feed the
//!   team at all (`ceil(n / nr) < threads`).
//! - [`partition_team`] — shares: LPT-style greedy that assigns each
//!   spare rank to the member with the largest estimated per-rank time,
//!   minimizing the fused epoch's makespan. Every member keeps at least
//!   one rank, so every batch member makes progress in every epoch.
//! - [`BatchPolicy`] — the latency/occupancy knobs (`max_batch` full
//!   trigger, `wait_us` coalescing window, `small_seconds` admission
//!   threshold), overridable from the environment (`DLA_BATCH`,
//!   `DLA_BATCH_WAIT_US`) for un-pinned servers.

use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use crate::arch::Arch;
use crate::model::ccp::GemmConfig;
use crate::model::profile::PerfProfile;
use crate::model::selector::{AnalyticScorer, Scorer};
use crate::model::GemmDims;
use crate::util::DType;

/// Default full-bucket dispatch trigger.
pub const DEFAULT_MAX_BATCH: usize = 8;
/// Default coalescing window in microseconds.
pub const DEFAULT_WAIT_US: u64 = 200;
/// Default admission threshold: requests whose single-core estimate is
/// below this are "small" (a full-team dispatch cannot amortize its
/// epoch cost against so little math).
pub const SMALL_GEMM_SECONDS: f64 = 2.0e-4;

/// Latency/occupancy policy of the batched request scheduler.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BatchPolicy {
    /// Dispatch a bucket as soon as it holds this many requests
    /// (a bucket may exceed it transiently; the flusher drains whole
    /// buckets and the engine re-chunks to the team width). `< 2`
    /// disables batching entirely (see [`BatchPolicy::enabled`]).
    pub max_batch: usize,
    /// How long the oldest queued request may wait for companions before
    /// its bucket is dispatched anyway, in microseconds.
    pub wait_us: u64,
    /// Admission threshold in estimated single-core seconds (see
    /// [`is_batchable`]); tests pin `f64::INFINITY` to admit every GEMM.
    pub small_seconds: f64,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_batch: DEFAULT_MAX_BATCH,
            wait_us: DEFAULT_WAIT_US,
            small_seconds: SMALL_GEMM_SECONDS,
        }
    }
}

impl BatchPolicy {
    /// A policy that pins batching **off** (and, unlike leaving the
    /// server config unset, also suppresses the `DLA_BATCH` environment
    /// override — mirror of `Lookahead::disabled`).
    pub fn disabled() -> Self {
        Self { max_batch: 0, ..Self::default() }
    }

    /// Batching is active only when a bucket can actually coalesce.
    pub fn enabled(&self) -> bool {
        self.max_batch >= 2
    }

    pub fn with_max_batch(mut self, n: usize) -> Self {
        self.max_batch = n;
        self
    }

    pub fn with_wait_us(mut self, us: u64) -> Self {
        self.wait_us = us;
        self
    }

    /// Admit every GEMM regardless of size (test/ablation hook).
    pub fn admit_all(mut self) -> Self {
        self.small_seconds = f64::INFINITY;
        self
    }

    /// The coalescing window as a [`Duration`].
    pub fn wait(&self) -> Duration {
        Duration::from_micros(self.wait_us)
    }

    /// Deadline-aware admission: may a request with this much time left
    /// afford to park in the admission queue? A batched request can wait
    /// up to the full coalescing window before its fused dispatch even
    /// starts, so anything with less than **twice** the window remaining
    /// (window + dispatch slack) must bypass the batcher and be served
    /// solo — coalescing trades latency for throughput, and a deadline
    /// caps how much latency the caller is willing to trade.
    /// `None` (no deadline) always fits.
    pub fn fits_deadline(&self, remaining: Option<Duration>) -> bool {
        match remaining {
            None => true,
            Some(r) => r > self.wait().saturating_mul(2),
        }
    }

    /// Environment override for un-pinned servers: `DLA_BATCH` unset /
    /// empty / `0` / `off` / `false` means no batching; `1` / `on` /
    /// `true` enable with the default trigger; a number `>= 2` sets
    /// `max_batch`; anything unparseable is treated as **off** (a typo
    /// must fail towards the plain solo path, not silently enable a
    /// scheduler the operator did not ask for). `DLA_BATCH_WAIT_US`
    /// overrides the window.
    pub fn from_env() -> Option<Self> {
        let v = std::env::var("DLA_BATCH").ok()?;
        let base = match v.trim() {
            "" | "0" | "off" | "false" => return None,
            "1" | "on" | "true" => Self::default(),
            other => match other.parse::<usize>() {
                Ok(n) if n >= 2 => Self::default().with_max_batch(n),
                _ => return None,
            },
        };
        let wait = std::env::var("DLA_BATCH_WAIT_US")
            .ok()
            .and_then(|w| w.trim().parse::<u64>().ok());
        Some(match wait {
            Some(us) => base.with_wait_us(us),
            None => base,
        })
    }
}

/// Single-core seconds estimate for one configured GEMM — the
/// [`AnalyticScorer`] cache-cost model the selector already ranks
/// configurations with, reused here as the batch cost model (uncached;
/// the serving hot paths go through [`BatchPlanner`]). FP64 width; see
/// [`serial_estimate_elem`].
pub fn serial_estimate(arch: &Arch, cfg: GemmConfig, dims: GemmDims) -> f64 {
    serial_estimate_elem(arch, cfg, dims, 8)
}

/// [`serial_estimate`] at an explicit element width in bytes (f32
/// batches run at twice the lane rate, so their shares must come from
/// f32-width estimates).
pub fn serial_estimate_elem(arch: &Arch, cfg: GemmConfig, dims: GemmDims, esize: usize) -> f64 {
    AnalyticScorer.score_elem(arch, dims, cfg.mk, cfg.ccp, esize)
}

/// Memoizing batch planner: admission checks run once per incoming GEMM
/// and team partitioning once per fused dispatch, so — like the
/// engine's config cache and the lookahead team-size memo — the scorer
/// must not re-run for every recurrence of the same shape. Estimates
/// are memoized on `(cfg, dims)`; a hit is one hash lookup. Interior
/// mutability (`RefCell`) because callers hold `&self` on hot paths;
/// each server worker / batcher owns its own planner (not shared across
/// threads). Keys carry the element width, so an f64 and an f32 batch
/// of equal shape never share a (rate-dependent) estimate.
#[derive(Default)]
pub struct BatchPlanner {
    estimates: RefCell<HashMap<(GemmConfig, GemmDims, usize, u64), f64>>,
    /// Optional measurement store (the calibrated serving path): when
    /// attached, estimates blend the analytic score with measured
    /// single-core-equivalent costs, keyed by the store's generation so
    /// a hotter profile re-estimates. `None` (default) keeps every
    /// estimate purely analytic and bitwise identical to the
    /// uncalibrated planner.
    profile: Option<Arc<PerfProfile>>,
}

impl BatchPlanner {
    /// Bound mirroring `GemmEngine::CONFIG_CACHE_CAP`: flush-on-overflow
    /// keeps a long-lived server from growing without bound.
    const CACHE_CAP: usize = 4096;

    pub fn new() -> Self {
        Self::default()
    }

    /// Attach or detach the measurement store (see
    /// `GemmEngine::set_calibration`, which forwards its profile here so
    /// batch admission and shares see the same measured truth as config
    /// selection).
    pub fn set_profile(&mut self, profile: Option<Arc<PerfProfile>>) {
        self.profile = profile;
        self.estimates.borrow_mut().clear();
    }

    /// Drop every memoized estimate.
    pub fn clear(&self) {
        self.estimates.borrow_mut().clear();
    }

    /// Memoized [`serial_estimate`] (FP64 width).
    pub fn estimate(&self, arch: &Arch, cfg: GemmConfig, dims: GemmDims) -> f64 {
        self.estimate_elem(arch, cfg, dims, 8)
    }

    /// Memoized [`serial_estimate_elem`]; the element width is part of
    /// the memo key. With a profile attached the analytic estimate is
    /// blended with measured single-core-equivalent costs
    /// ([`PerfProfile::blend_serial`]); without one (generation pinned
    /// to 0) the value and the memo behavior are exactly the historical
    /// ones.
    pub fn estimate_elem(&self, arch: &Arch, cfg: GemmConfig, dims: GemmDims, esize: usize) -> f64 {
        let gen = self.profile.as_ref().map_or(0, |p| p.generation());
        let key = (cfg, dims, esize, gen);
        if let Some(&t) = self.estimates.borrow().get(&key) {
            return t;
        }
        let mut t = serial_estimate_elem(arch, cfg, dims, esize);
        if let Some(p) = &self.profile {
            let dtype = if esize == 4 { DType::F32 } else { DType::F64 };
            t = p.blend_serial(dims, dtype, cfg, t);
        }
        let mut cache = self.estimates.borrow_mut();
        if cache.len() >= Self::CACHE_CAP {
            cache.clear();
        }
        cache.insert(key, t);
        t
    }

    /// Memoized FP64 estimate in whole microseconds (floor 1 µs) — the
    /// overload detector's analytic cost baseline: queue delay is judged
    /// against what the model says a request *should* cost, and the
    /// detector works in integer microseconds.
    pub fn estimate_us(&self, arch: &Arch, cfg: GemmConfig, dims: GemmDims) -> u64 {
        self.estimate_us_elem(arch, cfg, dims, 8)
    }

    /// [`Self::estimate_us`] at an explicit element width in bytes, so
    /// f32 requests are judged against f32-rate estimates.
    pub fn estimate_us_elem(&self, arch: &Arch, cfg: GemmConfig, dims: GemmDims, esize: usize) -> u64 {
        (self.estimate_elem(arch, cfg, dims, esize) * 1e6).max(1.0) as u64
    }

    /// Is a GEMM of `dims` (configured as `cfg`) worth coalescing
    /// instead of dispatching alone on a `threads`-wide pool? True when
    /// the model says the request is small (estimate below
    /// `policy.small_seconds`) or the G4 column grain cannot feed the
    /// team. Never true on teams that cannot parallelize at all
    /// (`threads < 2`) — there a solo dispatch is already sequential and
    /// batching would only add queueing latency.
    pub fn is_batchable(
        &self,
        arch: &Arch,
        cfg: GemmConfig,
        dims: GemmDims,
        threads: usize,
        policy: &BatchPolicy,
    ) -> bool {
        self.is_batchable_elem(arch, cfg, dims, threads, policy, 8)
    }

    /// [`Self::is_batchable`] at an explicit element width in bytes —
    /// the dtype-aware admission test behind the server's per-precision
    /// buckets (an f32 GEMM is judged small against the f32 rate model,
    /// and its grain check uses the f32 kernel's `nr`).
    pub fn is_batchable_elem(
        &self,
        arch: &Arch,
        cfg: GemmConfig,
        dims: GemmDims,
        threads: usize,
        policy: &BatchPolicy,
        esize: usize,
    ) -> bool {
        if threads < 2 {
            return false;
        }
        if dims.m == 0 || dims.n == 0 || dims.k == 0 {
            return true; // degenerate: trivially small
        }
        let starved = dims.n.div_ceil(cfg.mk.nr) < threads;
        starved || self.estimate_elem(arch, cfg, dims, esize) < policy.small_seconds
    }

    /// Partition a `threads`-wide team across the members of one fused
    /// batch: every member gets at least one rank, and each spare rank
    /// goes to the member with the largest estimated per-rank time
    /// (greedy LPT), minimizing `max_i T_i / shares_i` — the fused epoch
    /// ends when the slowest group does. Deterministic (first-max wins
    /// ties). Returns one share per member, summing to exactly
    /// `threads`.
    ///
    /// Requires `members.len() <= max(threads, 1)`; callers with larger
    /// batches chunk first (`GemmEngine::gemm_batch` does). FP64 width;
    /// see [`Self::partition_team_elem`].
    pub fn partition_team(
        &self,
        arch: &Arch,
        members: &[(GemmConfig, GemmDims)],
        threads: usize,
    ) -> Vec<usize> {
        self.partition_team_elem(arch, members, threads, 8)
    }

    /// [`Self::partition_team`] at an explicit element width in bytes
    /// (what `GemmEngine::gemm_batch_t::<E>` passes, so f32 batches are
    /// partitioned from f32-rate estimates).
    pub fn partition_team_elem(
        &self,
        arch: &Arch,
        members: &[(GemmConfig, GemmDims)],
        threads: usize,
        esize: usize,
    ) -> Vec<usize> {
        assert!(!members.is_empty(), "empty batch");
        let threads = threads.max(1);
        assert!(
            members.len() <= threads,
            "{} members cannot each get a rank on a {}-wide team",
            members.len(),
            threads
        );
        let est: Vec<f64> = members
            .iter()
            .map(|&(cfg, dims)| self.estimate_elem(arch, cfg, dims, esize).max(1e-12))
            .collect();
        let mut shares = vec![1usize; members.len()];
        for _ in members.len()..threads {
            let mut best = 0;
            for i in 1..members.len() {
                if est[i] / shares[i] as f64 > est[best] / shares[best] as f64 {
                    best = i;
                }
            }
            shares[best] += 1;
        }
        shares
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::model::{refined_ccp, MicroKernel};

    fn cfg_for(arch: &Arch, dims: GemmDims) -> GemmConfig {
        let mk = MicroKernel::new(8, 6);
        GemmConfig { mk, ccp: refined_ccp(arch, mk, dims).clamp_to(dims) }
    }

    #[test]
    fn policy_defaults_and_enablement() {
        let p = BatchPolicy::default();
        assert!(p.enabled());
        assert_eq!(p.max_batch, DEFAULT_MAX_BATCH);
        assert_eq!(p.wait(), Duration::from_micros(DEFAULT_WAIT_US));
        assert!(!BatchPolicy::disabled().enabled());
        assert!(!BatchPolicy::default().with_max_batch(1).enabled());
        assert!(BatchPolicy::default().admit_all().small_seconds.is_infinite());
    }

    #[test]
    fn deadline_gates_batched_admission() {
        let p = BatchPolicy::default().with_wait_us(1_000); // 1 ms window
        assert!(p.fits_deadline(None), "no deadline always fits");
        assert!(p.fits_deadline(Some(Duration::from_millis(50))));
        // Less than twice the window left: must bypass the batcher.
        assert!(!p.fits_deadline(Some(Duration::from_millis(2))));
        assert!(!p.fits_deadline(Some(Duration::ZERO)));
    }

    #[test]
    fn small_gemms_admitted_large_ones_not() {
        let arch = host_xeon();
        let planner = BatchPlanner::new();
        let p = BatchPolicy::default();
        let small = GemmDims::new(48, 48, 32);
        assert!(planner.is_batchable(&arch, cfg_for(&arch, small), small, 4, &p));
        // A fat GEMM is model-rejected: its serial estimate dwarfs the
        // threshold and its grain feeds any reasonable team.
        let big = GemmDims::new(1024, 1024, 256);
        assert!(!planner.is_batchable(&arch, cfg_for(&arch, big), big, 4, &p));
        // No team, no batching.
        assert!(!planner.is_batchable(&arch, cfg_for(&arch, small), small, 1, &p));
        // Degenerate shapes are trivially small.
        let degen = GemmDims::new(8, 0, 8);
        assert!(planner.is_batchable(&arch, cfg_for(&arch, small), degen, 4, &p));
    }

    #[test]
    fn grain_starved_gemms_admitted_regardless_of_threshold() {
        let arch = host_xeon();
        let planner = BatchPlanner::new();
        // Threshold zero: only the structural grain test can admit.
        let p = BatchPolicy { small_seconds: 0.0, ..BatchPolicy::default() };
        // n = 6 with nr = 6 is a single jr tile: starved on any team > 1.
        let skinny = GemmDims::new(4096, 6, 64);
        assert!(planner.is_batchable(&arch, cfg_for(&arch, skinny), skinny, 4, &p));
        let wide = GemmDims::new(4096, 4096, 64);
        assert!(!planner.is_batchable(&arch, cfg_for(&arch, wide), wide, 4, &p));
    }

    #[test]
    fn estimates_are_memoized_and_match_the_uncached_model() {
        let arch = host_xeon();
        let planner = BatchPlanner::new();
        let dims = GemmDims::new(48, 48, 32);
        let cfg = cfg_for(&arch, dims);
        let direct = serial_estimate(&arch, cfg, dims);
        assert_eq!(planner.estimate(&arch, cfg, dims), direct);
        // Cached lookups return the exact memoized value.
        assert_eq!(planner.estimate(&arch, cfg, dims), direct);
        assert_eq!(planner.estimates.borrow().len(), 1);
        // The element width is part of the key: an f32-width estimate of
        // the same (cfg, dims) is a separate (and faster) entry.
        let e32 = planner.estimate_elem(&arch, cfg, dims, 4);
        assert_eq!(planner.estimates.borrow().len(), 2, "dtype must not share estimates");
        assert!(e32 < direct, "f32-width estimate must beat f64 at equal shape");
        // The microsecond form floors at 1 and agrees with the seconds
        // estimate.
        let us = planner.estimate_us(&arch, cfg, dims);
        assert!(us >= 1);
        assert_eq!(us, (direct * 1e6).max(1.0) as u64);
        let degen = GemmDims::new(1, 1, 1);
        assert!(planner.estimate_us(&arch, cfg_for(&arch, degen), degen) >= 1);
    }

    #[test]
    fn shares_cover_the_team_and_favor_big_members() {
        let arch = host_xeon();
        let planner = BatchPlanner::new();
        let small = GemmDims::new(24, 24, 8);
        let big = GemmDims::new(96, 96, 64);
        let members =
            [(cfg_for(&arch, small), small), (cfg_for(&arch, big), big), (cfg_for(&arch, small), small)];
        for threads in [3usize, 4, 8, 16] {
            let shares = planner.partition_team(&arch, &members, threads);
            assert_eq!(shares.len(), 3);
            assert_eq!(shares.iter().sum::<usize>(), threads);
            assert!(shares.iter().all(|&s| s >= 1), "{shares:?}");
            // The big member must get at least as many ranks as either
            // small one.
            assert!(shares[1] >= shares[0] && shares[1] >= shares[2], "{shares:?}");
        }
        // Exactly one rank per member when the team is as wide as the
        // batch; a singleton batch takes the whole team.
        assert_eq!(planner.partition_team(&arch, &members, 3), vec![1, 1, 1]);
        assert_eq!(
            planner.partition_team(&arch, &members[..1], 4),
            vec![4],
            "singleton batch owns every rank"
        );
    }

    #[test]
    fn env_policy_parsing() {
        // from_env reads the live environment, so only exercise it when
        // the variable is unset (the CI matrix sets it on purpose).
        if std::env::var("DLA_BATCH").is_err() {
            assert_eq!(BatchPolicy::from_env(), None);
        }
    }

    #[test]
    fn attached_profile_blends_the_estimate() {
        use crate::model::profile::PerfProfile;
        use crate::util::DType;
        let arch = host_xeon();
        let mut planner = BatchPlanner::new();
        let dims = GemmDims::new(48, 48, 32);
        let cfg = cfg_for(&arch, dims);
        let analytic = serial_estimate(&arch, cfg, dims);
        // The machine measures this bucket 10x slower than the model
        // says (single-core observations, so width scaling is identity).
        let profile = Arc::new(PerfProfile::new());
        for _ in 0..32 {
            profile.record(dims, DType::F64, cfg, 1, 10.0 * analytic);
        }
        planner.set_profile(Some(Arc::clone(&profile)));
        let blended = planner.estimate(&arch, cfg, dims);
        assert!(blended > 2.0 * analytic, "blend {blended} ignored the measurements");
        // Detaching restores the exact analytic estimate (off = bitwise
        // identical).
        planner.set_profile(None);
        assert_eq!(planner.estimate(&arch, cfg, dims), analytic);
    }
}
