//! The paper's **refined, dimension-aware** CCP model (§3.3).
//!
//! The original model fixes `kc* ` from L1 alone, then sizes `mc` assuming
//! that `kc`. But for the skinny-`k` GEMMs that blocked factorizations
//! generate (`k = b <= 256`), the *actual* `kc = min(k, kc*)` is much
//! smaller, leaving most of the `Ac` ways of the L2 empty. The refinement
//! simply propagates the effective value at each level:
//!
//! 1. `kc = min(k, kc*)`
//! 2. `mc = f(L2, kc)` using the *effective* kc, clamped by `m`
//! 3. `nc = f(L3, kc, mc)` using the effective kc and mc, clamped by `n`
//!
//! Paper §3.3 check (Carmel, MK6x8, m = n = 2000, k = 224): the original
//! model gives `(672, 480, 341)` while the refinement gives
//! `(1024, 432, 224)` — an L2 occupancy of 87.5% instead of 10.3%.

use crate::arch::Arch;
#[cfg(test)]
use crate::model::analytical::kc_star;
use crate::model::analytical::{kc_star_elem, mc_exact_elem, nc_exact_elem, CCP_GRANULE};
use crate::model::{Ccp, GemmDims, MicroKernel};
use crate::util::round_down;

/// Compute the refined, shape-aware CCPs for `dims` on `arch` with
/// micro-kernel `mk` (FP64 elements; see [`refined_ccp_elem`]).
pub fn refined_ccp(arch: &Arch, mk: MicroKernel, dims: GemmDims) -> Ccp {
    refined_ccp_elem(arch, mk, dims, 8)
}

/// [`refined_ccp`] at an explicit element width in bytes: the same
/// three-step propagation, with every cache fill level counted in
/// elements of that width — an f32 GEMM gets roughly twice the
/// `kc`/`mc`/`nc` of its f64 twin (cache-resident panels hold twice the
/// elements), which is exactly the payoff the dtype-generic stack
/// exposes to the model.
pub fn refined_ccp_elem(arch: &Arch, mk: MicroKernel, dims: GemmDims, esize: usize) -> Ccp {
    // Step 1: effective kc bounded by the problem's k.
    let kc = kc_star_elem(arch.l1(), mk, esize).min(dims.k).max(1);

    // Step 2: mc sized for the effective kc. The granule-rounded value is
    // what the blocked algorithm uses; the exact value feeds the L3 split.
    let mc_x = mc_exact_elem(arch.l2(), mk, kc, esize);
    let mc = round_down(mc_x as usize, CCP_GRANULE)
        .max(mk.mr)
        .min(dims.m.max(mk.mr));

    // Step 3: nc sized for the effective kc/mc.
    let nc = match arch.l3() {
        Some(l3) => round_down(nc_exact_elem(l3, kc, mc_x, esize) as usize, CCP_GRANULE)
            .max(mk.nr)
            .min(dims.n.max(mk.nr)),
        None => round_down(8192, CCP_GRANULE).min(dims.n.max(mk.nr)),
    };

    Ccp { mc, nc, kc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282};

    const MK68: MicroKernel = MicroKernel::new(6, 8);

    fn carmel_mod(k: usize) -> Ccp {
        refined_ccp(&carmel(), MK68, GemmDims::new(2000, 2000, k))
    }

    #[test]
    fn paper_section_3_3_example() {
        // m = n = 2000, k = 224 -> (1024, 432, 224).
        assert_eq!(carmel_mod(224), Ccp::new(1024, 432, 224));
    }

    #[test]
    fn table1_mod_rows() {
        // Every MOD row of Table 1 (Carmel, MK6x8, m = n = 2000).
        assert_eq!(carmel_mod(64), Ccp::new(2000, 512, 64));
        assert_eq!(carmel_mod(96), Ccp::new(2000, 336, 96));
        assert_eq!(carmel_mod(128), Ccp::new(1792, 256, 128));
        assert_eq!(carmel_mod(160), Ccp::new(1424, 400, 160));
        assert_eq!(carmel_mod(192), Ccp::new(1184, 336, 192));
        assert_eq!(carmel_mod(224), Ccp::new(1024, 432, 224));
        // k = 256: kc = 256, mc = 896. (Our L3 rule yields nc = 384 here;
        // the paper's Table 1 lists nc = 512 — see EXPERIMENTS.md §Deviations.)
        let c256 = carmel_mod(256);
        assert_eq!((c256.mc, c256.kc), (896, 256));
        // k = 2000 degenerates to the original model: (672, 480, 341).
        assert_eq!(carmel_mod(2000), Ccp::new(672, 480, 341));
    }

    #[test]
    fn table2_mod_rows() {
        // Table 2: alternative micro-kernels on Carmel, m = n = 2000.
        let cc = carmel();
        let mk = |mr, nr| MicroKernel::new(mr, nr);
        let d = |k| GemmDims::new(2000, 2000, k);
        // k = 128 rows.
        assert_eq!(refined_ccp(&cc, mk(4, 10), d(128)).mc, 1664);
        assert_eq!(refined_ccp(&cc, mk(4, 12), d(128)).mc, 1664);
        assert_eq!(refined_ccp(&cc, mk(10, 4), d(128)).mc, 1792);
        assert_eq!(refined_ccp(&cc, mk(12, 4), d(128)).mc, 1792);
        // k = 192 rows: mc = 1184 for all four shapes.
        for (mr, nr) in [(4, 10), (4, 12), (10, 4), (12, 4)] {
            assert_eq!(refined_ccp(&cc, mk(mr, nr), d(192)).mc, 1184, "MK{mr}x{nr}");
        }
        // k = 256 rows: mc = 896 for all four shapes.
        for (mr, nr) in [(4, 10), (4, 12), (10, 4), (12, 4)] {
            assert_eq!(refined_ccp(&cc, mk(mr, nr), d(256)).mc, 896, "MK{mr}x{nr}");
        }
        // k = 64 rows: mc capped by m = 2000.
        for (mr, nr) in [(4, 10), (4, 12), (10, 4), (12, 4)] {
            assert_eq!(refined_ccp(&cc, mk(mr, nr), d(64)).mc, 2000, "MK{mr}x{nr}");
        }
    }

    #[test]
    fn epyc_section_4_1_examples() {
        // §4.1: MK8x6, m = n = 2000: k = 64 -> (768, 2000, 64);
        // k = 256 -> (192, 2000, 256).
        let e = epyc7282();
        let mk86 = MicroKernel::new(8, 6);
        assert_eq!(refined_ccp(&e, mk86, GemmDims::new(2000, 2000, 64)), Ccp::new(768, 2000, 64));
        assert_eq!(refined_ccp(&e, mk86, GemmDims::new(2000, 2000, 256)), Ccp::new(192, 2000, 256));
    }

    #[test]
    fn refined_never_exceeds_dims_or_original_kc() {
        let archs = [carmel(), epyc7282()];
        for arch in &archs {
            for mk in crate::model::microkernel::candidate_family(&arch.regs) {
                for k in [1, 7, 64, 100, 341, 2000] {
                    let dims = GemmDims::new(500, 700, k);
                    let ccp = refined_ccp(arch, mk, dims);
                    assert!(ccp.kc <= k.max(1));
                    assert!(ccp.kc <= kc_star(arch.l1(), mk));
                    assert!(ccp.mc <= dims.m.max(mk.mr));
                    assert!(ccp.nc <= dims.n.max(mk.nr));
                    assert!(ccp.mc >= 1 && ccp.nc >= 1 && ccp.kc >= 1);
                }
            }
        }
    }

    #[test]
    fn f32_width_grows_the_refined_ccps() {
        // The element-width propagation: for a fixed skinny-k problem the
        // f32 CCPs hold at least as many elements per level, and for a
        // deep-k problem the f32 kc doubles outright.
        let e = epyc7282();
        let mk86 = MicroKernel::new(8, 6);
        let deep = GemmDims::new(2000, 2000, 2000);
        let c64 = refined_ccp_elem(&e, mk86, deep, 8);
        let c32 = refined_ccp_elem(&e, mk86, deep, 4);
        assert_eq!(c32.kc, 2 * c64.kc, "{c32} vs {c64}");
        assert!(c32.mc >= c64.mc);
        // Skinny k: kc is clamped by k for both widths, so the extra L2
        // room goes to mc instead.
        let skinny = GemmDims::new(4000, 4000, 64);
        let s64 = refined_ccp_elem(&e, mk86, skinny, 8);
        let s32 = refined_ccp_elem(&e, mk86, skinny, 4);
        assert_eq!(s64.kc, 64);
        assert_eq!(s32.kc, 64);
        assert!(s32.mc >= 2 * s64.mc - CCP_GRANULE, "{s32} vs {s64}");
        // The f64 wrapper is unchanged.
        assert_eq!(refined_ccp(&e, mk86, skinny), s64);
    }

    #[test]
    fn refined_mc_monotone_nonincreasing_in_k() {
        // Smaller k -> larger (or equal) mc: the heart of the refinement.
        let mut last = usize::MAX;
        for k in [64, 96, 128, 160, 192, 224, 256, 341] {
            let mc = refined_ccp(&carmel(), MK68, GemmDims::new(100_000, 100_000, k)).mc;
            assert!(mc <= last, "mc must not increase with k");
            last = mc;
        }
    }
}
