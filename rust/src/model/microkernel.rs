//! Micro-kernel shapes and their analytical properties (§2.3, §3.4).
//!
//! A micro-kernel `MK_{mr x nr}` performs `kc` rank-1 updates on an
//! `mr x nr` micro-tile of C held in vector registers. Its feasibility is
//! bounded by the register file, and its efficiency by the flops/memops
//! ratio `2 mr nr kc / (2 mr nr + mr kc + kc nr)`.

use crate::arch::RegisterFile;
use std::fmt;

/// A micro-kernel shape.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct MicroKernel {
    pub mr: usize,
    pub nr: usize,
}

impl MicroKernel {
    pub const fn new(mr: usize, nr: usize) -> Self {
        Self { mr, nr }
    }

    /// Vector registers required with the "broadcast-from-lane" coding
    /// style used in the paper's Figure 7 (`vfmaq_laneq_f64` on NEON,
    /// `vfmadd + permute` on AVX2): C is register-resident as
    /// `ceil(mr/lanes) * nr` accumulators, one column of Ar takes
    /// `ceil(mr/lanes)` registers and one row of Br takes
    /// `ceil(nr/lanes)` registers.
    ///
    /// `lanes` is **element-width dependent**: a 256-bit register holds 4
    /// f64 lanes but 8 f32 lanes, so the same register file admits twice
    /// the `mr` for f32 (e.g. AVX2 MK8x6 in f64 vs MK16x6 in f32, both
    /// 15 registers). Pass [`crate::arch::RegisterFile::lanes_for`] of
    /// the element width, not a hardcoded f64 lane count.
    ///
    /// Paper §3.4 check (NEON f64, lanes = 2): MK6x8 = 24 + 3 + 4 = 31,
    /// MK12x4 = 24 + 6 + 2 = 32.
    pub fn vector_regs_needed(&self, lanes: usize) -> usize {
        let cm = self.mr.div_ceil(lanes);
        let cn = self.nr.div_ceil(lanes);
        cm * self.nr + cm + cn
    }

    /// True when the kernel fits the register file without spilling C,
    /// at the FP64 lane count (see [`Self::fits_lanes`] for other
    /// element widths).
    pub fn fits(&self, regs: &RegisterFile) -> bool {
        self.fits_lanes(regs, regs.f64_lanes())
    }

    /// True when the kernel fits the register file without spilling C at
    /// an explicit lane count (element-width aware; see
    /// [`Self::vector_regs_needed`]).
    pub fn fits_lanes(&self, regs: &RegisterFile, lanes: usize) -> bool {
        self.vector_regs_needed(lanes) <= regs.vector_regs
    }

    /// True when at least one dimension is a multiple of the SIMD lane
    /// count (paper §3.4's restriction for candidate micro-kernels).
    pub fn simd_aligned(&self, lanes: usize) -> bool {
        self.mr % lanes == 0 || self.nr % lanes == 0
    }

    /// Flops performed per micro-kernel invocation.
    pub fn flops(&self, kc: usize) -> f64 {
        2.0 * (self.mr * self.nr * kc) as f64
    }

    /// Memory operations (element loads/stores): C read+written once,
    /// Ar and Br streamed once.
    pub fn memops(&self, kc: usize) -> f64 {
        (2 * self.mr * self.nr + self.mr * kc + kc * self.nr) as f64
    }

    /// The flops/memops ratio of §2.3. Paper check at kc = 128:
    /// MK6x8 = 6.5, MK4x10 = 5.5, MK4x12 = 5.7.
    pub fn flops_per_memop(&self, kc: usize) -> f64 {
        self.flops(kc) / self.memops(kc)
    }

    /// "Squarishness" in [0, 1]: 1.0 for mr == nr.
    pub fn squareness(&self) -> f64 {
        let (a, b) = (self.mr.min(self.nr) as f64, self.mr.max(self.nr) as f64);
        a / b
    }
}

impl fmt::Display for MicroKernel {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "MK{}x{}", self.mr, self.nr)
    }
}

/// The candidate micro-kernel family studied by the paper (§3.4, §4) at
/// the FP64 lane count: shapes with at least one SIMD-aligned dimension
/// that avoid spilling. See [`candidate_family_lanes`] for other element
/// widths.
pub fn candidate_family(regs: &RegisterFile) -> Vec<MicroKernel> {
    candidate_family_lanes(regs, regs.f64_lanes())
}

/// The candidate micro-kernel family at an explicit SIMD lane count
/// (element-width dependent: f32 doubles the lanes of the same register
/// file, admitting taller tiles like AVX2 MK16x6).
pub fn candidate_family_lanes(regs: &RegisterFile, lanes: usize) -> Vec<MicroKernel> {
    let mut out = Vec::new();
    for mr in 1..=16 {
        for nr in 1..=16 {
            let mk = MicroKernel::new(mr, nr);
            // Skip degenerate shapes: both dims >= 2 keeps the rank-1
            // update meaningful, and tiny tiles (< 16 flops/iter) are
            // never competitive.
            if mr * nr < 16 {
                continue;
            }
            if mk.simd_aligned(lanes) && mk.fits_lanes(regs, lanes) {
                out.push(mk);
            }
        }
    }
    // Largest compute tiles first, squarest first among equals.
    out.sort_by(|a, b| {
        (b.mr * b.nr)
            .cmp(&(a.mr * a.nr))
            .then(b.squareness().total_cmp(&a.squareness()))
            .then(a.mr.cmp(&b.mr))
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282};

    #[test]
    fn neon_register_counts_match_paper() {
        // §3.4: "MK6x8 employs 24 vector registers to store Cr, 3 for the
        // column of Ar, and 4 for the row of Br, for a total of 31.
        // MK12x4 employs 24 for Cr, 6 for Ar, and 2 for Br: 32."
        assert_eq!(MicroKernel::new(6, 8).vector_regs_needed(2), 31);
        assert_eq!(MicroKernel::new(12, 4).vector_regs_needed(2), 32);
        assert_eq!(MicroKernel::new(4, 12).vector_regs_needed(2), 32);
        let neon = carmel().regs;
        assert!(MicroKernel::new(6, 8).fits(&neon));
        assert!(MicroKernel::new(12, 4).fits(&neon));
        // 8x10 would need 40+4+5 > 32.
        assert!(!MicroKernel::new(8, 10).fits(&neon));
    }

    #[test]
    fn avx2_fits_blis_kernel() {
        let avx2 = epyc7282().regs;
        // BLIS's 8x6 for AVX2: 2*6 + 2 + 2 = 16 regs, exactly the file.
        assert_eq!(MicroKernel::new(8, 6).vector_regs_needed(4), 16);
        assert!(MicroKernel::new(8, 6).fits(&avx2));
        assert!(!MicroKernel::new(8, 8).fits(&avx2));
    }

    #[test]
    fn flops_per_memop_matches_paper() {
        // §3.4: kc = 128 -> MK6x8: 6.5, MK4x10: 5.5, MK4x12: 5.7.
        assert!((MicroKernel::new(6, 8).flops_per_memop(128) - 6.5).abs() < 0.05);
        assert!((MicroKernel::new(4, 10).flops_per_memop(128) - 5.5).abs() < 0.05);
        assert!((MicroKernel::new(4, 12).flops_per_memop(128) - 5.7).abs() < 0.05);
    }

    #[test]
    fn family_contains_papers_kernels() {
        let fam = candidate_family(&carmel().regs);
        for mk in [(6, 8), (12, 4), (4, 12), (10, 4), (4, 10), (8, 6)] {
            assert!(
                fam.contains(&MicroKernel::new(mk.0, mk.1)),
                "family missing MK{}x{}",
                mk.0,
                mk.1
            );
        }
        // Family must respect the register file.
        for mk in &fam {
            assert!(mk.fits(&carmel().regs));
        }
    }

    #[test]
    fn squareness_bounds() {
        assert_eq!(MicroKernel::new(8, 8).squareness(), 1.0);
        assert!(MicroKernel::new(12, 4).squareness() < MicroKernel::new(6, 8).squareness());
    }

    #[test]
    fn f32_lanes_admit_taller_tiles() {
        // AVX2 (16 regs, 256-bit): f64 MK8x6 fits (15 regs) but MK16x6
        // does not (4*6 + 4 + 2 = 30); at f32's 8 lanes MK16x6 fits
        // (2*6 + 2 + 1 = 15) — the element-width dependence the lane
        // parameter exists for.
        let avx2 = epyc7282().regs;
        assert!(MicroKernel::new(8, 6).fits_lanes(&avx2, 4));
        assert!(!MicroKernel::new(16, 6).fits_lanes(&avx2, 4));
        assert!(MicroKernel::new(16, 6).fits_lanes(&avx2, 8));
        assert_eq!(MicroKernel::new(16, 6).vector_regs_needed(8), 15);
        assert_eq!(MicroKernel::new(8, 8).vector_regs_needed(8), 10);
        let fam32 = candidate_family_lanes(&avx2, 8);
        assert!(fam32.contains(&MicroKernel::new(16, 6)));
        assert!(fam32.contains(&MicroKernel::new(8, 8)));
        // The f64 family at the same register file must not contain the
        // 16-row tile.
        assert!(!candidate_family(&avx2).contains(&MicroKernel::new(16, 6)));
    }
}
