//! Empirical CCP autotuner — the "costly optimization search" the
//! paper's analytical model replaces (§3.3). Provided as library code so
//! the ablation bench and the CLI can quantify both sides of the
//! trade-off: search cost vs configuration quality.

use crate::gemm::microkernel::MicroKernelImpl;
use crate::gemm::{gemm_blocked, Workspace};
use crate::model::ccp::GemmConfig;
use crate::model::{Ccp, GemmDims};
use crate::util::timer::measure;
use crate::util::{MatrixF64, Pcg64};

/// Search space description.
#[derive(Clone, Debug)]
pub struct SearchSpace {
    pub mc: Vec<usize>,
    pub nc: Vec<usize>,
    pub kc: Vec<usize>,
}

impl SearchSpace {
    /// A small default grid around powers of two (what hand-tuners try).
    pub fn default_grid(dims: GemmDims) -> Self {
        let caps = |vals: &[usize], max: usize| -> Vec<usize> {
            let mut v: Vec<usize> = vals.iter().copied().filter(|&x| x <= 2 * max).collect();
            if v.is_empty() {
                v.push(max.max(1));
            }
            v
        };
        SearchSpace {
            mc: caps(&[48, 96, 192, 384, 768, 1536, 3072], dims.m),
            nc: caps(&[96, 192, 384, 768, 1536, 3072], dims.n),
            kc: caps(&[32, 64, 128, 256, 512], dims.k),
        }
    }

    pub fn len(&self) -> usize {
        self.mc.len() * self.nc.len() * self.kc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Result of an autotuning run.
#[derive(Clone, Debug)]
pub struct TuneResult {
    pub best: GemmConfig,
    pub best_gflops: f64,
    pub configs_tried: usize,
    pub search_seconds: f64,
    /// (config, gflops) for every point, best first.
    pub all: Vec<(Ccp, f64)>,
}

/// Exhaustively time the grid for one micro-kernel implementation and
/// return the best configuration. `probe_secs` bounds per-point cost.
pub fn autotune(
    kernel: &MicroKernelImpl,
    dims: GemmDims,
    space: &SearchSpace,
    probe_secs: f64,
) -> TuneResult {
    let sw = crate::util::Stopwatch::start();
    let mut rng = Pcg64::seed(0xA0707);
    let a = MatrixF64::random(dims.m, dims.k, &mut rng);
    let b = MatrixF64::random(dims.k, dims.n, &mut rng);
    let mut c = MatrixF64::zeros(dims.m, dims.n);
    let mut ws = Workspace::new();
    let mut all = Vec::new();
    for &mc in &space.mc {
        for &nc in &space.nc {
            for &kc in &space.kc {
                let ccp = Ccp::new(mc, nc, kc).clamp_to(dims);
                let cfg = GemmConfig { mk: kernel.spec, ccp };
                let m = measure(1, probe_secs, || {
                    gemm_blocked(&cfg, kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), &mut ws);
                });
                all.push((ccp, m.gflops_best(dims.flops())));
            }
        }
    }
    all.sort_by(|x, y| y.1.total_cmp(&x.1));
    let (best_ccp, best_gflops) = all[0];
    TuneResult {
        best: GemmConfig { mk: kernel.spec, ccp: best_ccp },
        best_gflops,
        configs_tried: all.len(),
        search_seconds: sw.elapsed_secs(),
        all,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::microkernel::for_shape;
    use crate::model::MicroKernel;

    #[test]
    fn grid_respects_dims() {
        let s = SearchSpace::default_grid(GemmDims::new(100, 100, 40));
        assert!(!s.is_empty());
        assert!(s.mc.iter().all(|&m| m <= 200));
        assert!(s.kc.iter().all(|&k| k <= 80));
    }

    #[test]
    fn autotune_small_problem_finds_reasonable_config() {
        let kernel = for_shape(MicroKernel::new(8, 6)).unwrap();
        let dims = GemmDims::new(64, 64, 32);
        let space = SearchSpace { mc: vec![16, 64], nc: vec![24, 64], kc: vec![16, 32] };
        let res = autotune(&kernel, dims, &space, 0.0);
        assert_eq!(res.configs_tried, 8);
        assert!(res.best_gflops > 0.0);
        assert!(res.search_seconds >= 0.0);
        // Ranked order.
        for w in res.all.windows(2) {
            assert!(w[0].1 >= w[1].1);
        }
        // Best must be a clamped member of the grid.
        assert!(res.best.ccp.mc <= 64 && res.best.ccp.kc <= 32);
    }
}
