//! Model-driven panel-team sizing for the lookahead pipeline: the same
//! analytical machinery that picks `mc`/`kc`/`nc` also picks the thread
//! split `t_p` (panel sub-team) vs `threads - t_p` (update sub-team).
//!
//! The balance the paper calls "delicate" (multi-threaded parallelism vs
//! cache usage) shows up in the fused factorization job as a min-max
//! problem: the job ends when *both* halves finish, so the best split
//! minimizes `max(T_panel(t_p), T_update(threads - t_p))`.
//!
//! - `T_update` comes from the existing [`AnalyticScorer`] — the per-call
//!   cache-cost estimate of the trailing sweep under the *selected*
//!   configuration, divided by the update-team width (the G4 `jr`
//!   partition scales near-linearly at `nr` grain).
//! - `T_panel` is a critical-path model of the unblocked panel kernel
//!   (`getf2`-shaped): per column, the pivot search and multiplier
//!   scaling are leader-sequential, the trailing rank-1 update splits
//!   over the sub-team by column, and every step pays a sub-team barrier
//!   round that grows with the team width. A wider panel team shortens
//!   the parallel term but buys nothing on the serial or sync terms, so
//!   the right `t_p` moves with the panel/update balance every iteration
//!   — Catalán et al.'s malleable thread-level parallelism, driven here
//!   by the same model that picks the CCPs.
//!
//! Selections are memoized on the full problem key, mirroring the
//! engine's config-selection cache: a factorization sweep re-sees the
//! same shrinking shapes across repeated calls, and the hot path must
//! not allocate (a hit is one hash lookup returning a `usize`).

use std::cell::{Cell, RefCell};
use std::collections::HashMap;

use crate::arch::Arch;
use crate::model::ccp::GemmConfig;
use crate::model::selector::{AnalyticScorer, Scorer};
use crate::model::GemmDims;

/// Shape of the panel the sub-team factors (`rows x cols`, rows counted
/// from the panel's diagonal block down to the matrix edge).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct PanelShape {
    pub rows: usize,
    pub cols: usize,
}

impl PanelShape {
    pub const fn new(rows: usize, cols: usize) -> Self {
        Self { rows, cols }
    }
}

/// Hit/miss accounting of the team-size memo cache (exposed alongside
/// the engine's config-cache stats).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TeamSizeStats {
    pub hits: u64,
    pub misses: u64,
}

#[derive(Clone, Copy, PartialEq, Eq, Hash)]
struct Key {
    threads: usize,
    panel: PanelShape,
    update: GemmDims,
    cfg: GemmConfig,
    /// Element width in bytes: the f32 pipeline sees double peak and
    /// different cache costs, so selections are memoized per dtype.
    esize: usize,
    /// Measurement-store generation of the calibrated path (constant 0
    /// on the uncalibrated path): a generation bump re-misses so a
    /// hotter profile can re-balance a previously memoized split.
    gen: u64,
}

/// Efficiency of the scalar panel kernel relative to one core's peak
/// (latency-bound AXPYs over a tall panel; no SIMD, no blocking).
const PANEL_EFF: f64 = 0.08;
/// Cost of one sub-team barrier round, in seconds (condvar wake +
/// cacheline ping). Only paid when the panel team is wider than one.
const BARRIER_S: f64 = 3.0e-7;
/// Barrier rounds per `getf2` column step (pivot publish, swap, scale,
/// update — see `getf2_team`).
const BARRIERS_PER_STEP: f64 = 4.0;

/// Memoizing `t_p` selector. Interior-mutable like the engine's config
/// cache so `&self` lookups work from the drivers' hot loop.
#[derive(Default)]
pub struct TeamSizeSelector {
    cache: RefCell<HashMap<Key, usize>>,
    stats: Cell<TeamSizeStats>,
}

impl TeamSizeSelector {
    /// Bound mirroring `GemmEngine::CONFIG_CACHE_CAP`: flush-on-overflow
    /// keeps a long-lived server engine from growing without bound.
    const CACHE_CAP: usize = 4096;

    pub fn new() -> Self {
        Self::default()
    }

    /// Estimated seconds for the panel critical path on a `t_p`-wide
    /// sub-team, at `esize` bytes per element (f32 panels run at twice
    /// the scalar peak).
    fn panel_time(arch: &Arch, panel: PanelShape, t_p: usize, esize: usize) -> f64 {
        let steps = panel.rows.min(panel.cols);
        let (mut serial_flops, mut par_flops) = (0.0f64, 0.0f64);
        for j in 0..steps {
            let below = (panel.rows - j) as f64;
            let right = panel.cols.saturating_sub(j + 1) as f64;
            // Pivot search + multiplier scaling: leader-only.
            serial_flops += 2.0 * below;
            // Rank-1 update of the trailing sub-panel: column-split.
            par_flops += 2.0 * below * right;
        }
        let rate = arch.peak_gflops_core_for(esize) * 1e9 * PANEL_EFF;
        // Barrier rounds cost more the wider the team (one wake + one
        // cacheline ping per extra rank), so the panel time has a real
        // minimum in t_p and oversizing the panel team is penalized.
        let sync = steps as f64 * BARRIERS_PER_STEP * BARRIER_S * (t_p - 1) as f64;
        serial_flops / rate + par_flops / (rate * t_p as f64) + sync
    }

    /// Run the min-max balance (uncached). `update_1` overrides the
    /// analytic single-core trailing-sweep estimate when the caller has
    /// a measurement-blended one (the calibrated engine path).
    fn compute(arch: &Arch, key: &Key, update_1: Option<f64>) -> usize {
        let t = key.threads;
        if t <= 2 {
            return 1;
        }
        // Single-core trailing-sweep estimate from the cache model, under
        // the configuration the engine actually selected for this shape.
        let update_1 = update_1.unwrap_or_else(|| {
            AnalyticScorer.score_elem(arch, key.update, key.cfg.mk, key.cfg.ccp, key.esize)
        });
        // More ranks than panel columns cannot help the column-split
        // kernel.
        let t_max = (t - 1).min(key.panel.cols.max(1));
        let mut best = (1usize, f64::INFINITY);
        for t_p in 1..=t_max {
            let t_u = (t - t_p) as f64;
            let cost = Self::panel_time(arch, key.panel, t_p, key.esize).max(update_1 / t_u);
            // Strict improvement keeps the smallest t_p on ties: spare
            // ranks help the wide sweep more than the thin panel.
            if cost < best.1 {
                best = (t_p, cost);
            }
        }
        best.0
    }

    /// The model's `t_p` for one fused iteration: panel shape, trailing
    /// sweep dims (the columns the update team will cover), the selected
    /// GEMM configuration and the team width, at FP64 width. Memoized; a
    /// hit is allocation-free.
    pub fn select(
        &self,
        arch: &Arch,
        cfg: GemmConfig,
        panel: PanelShape,
        update: GemmDims,
        threads: usize,
    ) -> usize {
        self.select_elem(arch, cfg, panel, update, threads, 8)
    }

    /// [`Self::select`] at an explicit element width in bytes; the memo
    /// key includes the width, so f32 and f64 factorizations of equal
    /// shape never share a (precision-dependent) selection.
    pub fn select_elem(
        &self,
        arch: &Arch,
        cfg: GemmConfig,
        panel: PanelShape,
        update: GemmDims,
        threads: usize,
        esize: usize,
    ) -> usize {
        self.select_elem_with(arch, cfg, panel, update, threads, esize, 0, None)
    }

    /// The calibrated entry behind [`Self::select_elem`]: `gen` is the
    /// measurement-store generation (part of the memo key; 0 on the
    /// uncalibrated path, so `select_elem` keys exactly as before) and
    /// `update_1` an optional measurement-blended single-core estimate
    /// of the trailing sweep that replaces the analytic one in the
    /// min-max balance.
    #[allow(clippy::too_many_arguments)]
    pub fn select_elem_with(
        &self,
        arch: &Arch,
        cfg: GemmConfig,
        panel: PanelShape,
        update: GemmDims,
        threads: usize,
        esize: usize,
        gen: u64,
        update_1: Option<f64>,
    ) -> usize {
        let key = Key { threads, panel, update, cfg, esize, gen };
        if let Some(&t_p) = self.cache.borrow().get(&key) {
            let mut s = self.stats.get();
            s.hits += 1;
            self.stats.set(s);
            return t_p;
        }
        let t_p = Self::compute(arch, &key, update_1);
        {
            let mut cache = self.cache.borrow_mut();
            if cache.len() >= Self::CACHE_CAP {
                cache.clear();
            }
            cache.insert(key, t_p);
        }
        let mut s = self.stats.get();
        s.misses += 1;
        self.stats.set(s);
        t_p
    }

    pub fn stats(&self) -> TeamSizeStats {
        self.stats.get()
    }

    pub fn len(&self) -> usize {
        self.cache.borrow().len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.borrow().is_empty()
    }

    pub fn clear(&self) {
        self.cache.borrow_mut().clear();
        self.stats.set(TeamSizeStats::default());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::model::{refined_ccp, MicroKernel};

    fn cfg_for(arch: &Arch, dims: GemmDims) -> GemmConfig {
        let mk = MicroKernel::new(8, 6);
        GemmConfig { mk, ccp: refined_ccp(arch, mk, dims).clamp_to(dims) }
    }

    #[test]
    fn narrow_teams_get_one_panel_rank() {
        let arch = host_xeon();
        let sel = TeamSizeSelector::new();
        let dims = GemmDims::new(512, 512, 64);
        let cfg = cfg_for(&arch, dims);
        assert_eq!(sel.select(&arch, cfg, PanelShape::new(512, 64), dims, 1), 1);
        assert_eq!(sel.select(&arch, cfg, PanelShape::new(512, 64), dims, 2), 1);
    }

    #[test]
    fn split_always_leaves_a_nonempty_update_team() {
        let arch = host_xeon();
        let sel = TeamSizeSelector::new();
        for threads in [3, 4, 8, 16] {
            for s in [64usize, 256, 1024, 4096] {
                let dims = GemmDims::new(s, s, 64);
                let cfg = cfg_for(&arch, dims);
                let t_p = sel.select(&arch, cfg, PanelShape::new(s, 64), dims, threads);
                assert!(t_p >= 1 && t_p < threads, "t_p={t_p} threads={threads} s={s}");
            }
        }
    }

    #[test]
    fn team_size_tracks_the_update_panel_balance() {
        // Malleability: with the panel shape held fixed, a *larger*
        // trailing sweep must never get a larger panel team — the update
        // needs those ranks more. (The min-max of a decreasing panel
        // curve against an increasing update curve moves its crossing
        // left as the update grows.)
        let arch = host_xeon();
        let sel = TeamSizeSelector::new();
        let threads = 16;
        let b = 128;
        let panel = PanelShape::new(2048, b);
        let cfg = cfg_for(&arch, GemmDims::new(2048, 2048, b));
        let picks: Vec<usize> = [256usize, 1024, 4096, 16384, 65536]
            .into_iter()
            .map(|n| sel.select(&arch, cfg, panel, GemmDims::new(2048, n, b), threads))
            .collect();
        for w in picks.windows(2) {
            assert!(w[1] <= w[0], "t_p grew with the trailing sweep: {picks:?}");
        }
        assert!(picks.iter().all(|&t| (1..threads).contains(&t)), "{picks:?}");
        // And a panel team never exceeds the panel's column count.
        let thin = PanelShape::new(4096, 2);
        let t_p = sel.select(&arch, cfg, thin, GemmDims::new(64, 64, 2), threads);
        assert!(t_p <= 2, "2-column panel cannot use {t_p} ranks");
    }

    #[test]
    fn blended_update_estimate_shifts_the_balance() {
        let arch = host_xeon();
        let sel = TeamSizeSelector::new();
        let dims = GemmDims::new(2048, 2048, 128);
        let cfg = cfg_for(&arch, dims);
        let panel = PanelShape::new(2048, 128);
        let base = sel.select_elem(&arch, cfg, panel, dims, 16, 8);
        // A measured trailing sweep 8x slower than the model says: the
        // update team needs the ranks more, so t_p must not grow — and
        // the gen-keyed calibrated entry must not collide with the
        // baseline one.
        let analytic = AnalyticScorer.score_elem(&arch, dims, cfg.mk, cfg.ccp, 8);
        let slow = sel.select_elem_with(&arch, cfg, panel, dims, 16, 8, 1, Some(8.0 * analytic));
        assert!(slow <= base, "slower measured update grew t_p: {slow} > {base}");
        assert_eq!(sel.len(), 2, "generation must be part of the memo key");
        // The zero-gen, no-override call is bitwise the plain select.
        assert_eq!(sel.select_elem_with(&arch, cfg, panel, dims, 16, 8, 0, None), base);
        assert_eq!(sel.stats().hits, 1);
    }

    #[test]
    fn selections_are_memoized_with_stats() {
        let arch = host_xeon();
        let sel = TeamSizeSelector::new();
        let dims = GemmDims::new(1024, 1024, 128);
        let cfg = cfg_for(&arch, dims);
        let first = sel.select(&arch, cfg, PanelShape::new(1024, 128), dims, 8);
        assert_eq!(sel.stats(), TeamSizeStats { hits: 0, misses: 1 });
        for _ in 0..3 {
            assert_eq!(sel.select(&arch, cfg, PanelShape::new(1024, 128), dims, 8), first);
        }
        assert_eq!(sel.stats(), TeamSizeStats { hits: 3, misses: 1 });
        assert_eq!(sel.len(), 1);
        // A different team width is a different key.
        sel.select(&arch, cfg, PanelShape::new(1024, 128), dims, 4);
        assert_eq!(sel.stats().misses, 2);
        sel.clear();
        assert_eq!(sel.stats(), TeamSizeStats::default());
        assert!(sel.is_empty());
    }
}
