//! Cache configuration parameters (CCPs) and the static BLIS presets the
//! paper uses as its baseline.

use super::MicroKernel;
use std::fmt;

/// GEMM problem dimensions: `C(m x n) += A(m x k) * B(k x n)`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmDims {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmDims {
    pub const fn new(m: usize, n: usize, k: usize) -> Self {
        Self { m, n, k }
    }

    /// Flop count of the multiply-accumulate (2mnk).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }
}

impl fmt::Display for GemmDims {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}x{}x{}", self.m, self.n, self.k)
    }
}

/// The cache configuration parameters: strides of loops G1/G3/G2.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Ccp {
    pub mc: usize,
    pub nc: usize,
    pub kc: usize,
}

impl Ccp {
    pub const fn new(mc: usize, nc: usize, kc: usize) -> Self {
        Self { mc, nc, kc }
    }

    /// Effective CCPs for a concrete problem: each parameter is clamped by
    /// the matching dimension (the `min(k, kc^B)` remark of §3.1).
    pub fn clamp_to(&self, dims: GemmDims) -> Ccp {
        Ccp {
            mc: self.mc.min(dims.m).max(1),
            nc: self.nc.min(dims.n).max(1),
            kc: self.kc.min(dims.k).max(1),
        }
    }

    /// Bytes of packed-buffer workspace required (`Ac` + `Bc`, FP64).
    pub fn workspace_bytes(&self, mk: MicroKernel) -> usize {
        // Packed buffers are padded up to full micro-panels.
        let mc_pad = self.mc.div_ceil(mk.mr) * mk.mr;
        let nc_pad = self.nc.div_ceil(mk.nr) * mk.nr;
        8 * (mc_pad * self.kc + self.kc * nc_pad)
    }
}

impl fmt::Display for Ccp {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(mc={}, nc={}, kc={})", self.mc, self.nc, self.kc)
    }
}

/// A fully specified GEMM configuration: which micro-kernel and which CCPs.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct GemmConfig {
    pub mk: MicroKernel,
    pub ccp: Ccp,
}

impl fmt::Display for GemmConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}", self.mk, self.ccp)
    }
}

/// The static CCPs + stock micro-kernel that BLIS hard-codes for each of
/// the paper's platforms (§3.1 and §4.1). These are the baseline ("R1"),
/// in the historical FP64 flavour.
pub fn blis_static(arch_name: &str) -> Option<GemmConfig> {
    blis_static_dt(arch_name, crate::util::DType::F64)
}

/// [`blis_static`] per element type: BLIS pins a *separate* static
/// kernel + CCP set per precision (`dgemm` vs `sgemm`), so the f32
/// baseline uses the stock single-precision shapes — double-height
/// micro-tiles and doubled `mc`/`kc` element counts on x86, the NEON
/// 8x12 sgemm shape on ARM.
pub fn blis_static_dt(arch_name: &str, dt: crate::util::DType) -> Option<GemmConfig> {
    use crate::util::DType;
    let lower = arch_name.to_ascii_lowercase();
    if lower.contains("carmel") || lower.contains("arm") {
        Some(match dt {
            // §3.1: MK6x8, (mc, nc, kc) = (120, 3072, 240).
            DType::F64 => GemmConfig { mk: MicroKernel::new(6, 8), ccp: Ccp::new(120, 3072, 240) },
            // BLIS armv8a sgemm: MK8x12 with doubled element counts.
            DType::F32 => GemmConfig { mk: MicroKernel::new(8, 12), ccp: Ccp::new(120, 3072, 640) },
        })
    } else if lower.contains("epyc") || lower.contains("amd") {
        Some(match dt {
            // §4.1: MK8x6 (column-major view of BLIS's 6x8), (72, 2040, 512).
            DType::F64 => GemmConfig { mk: MicroKernel::new(8, 6), ccp: Ccp::new(72, 2040, 512) },
            // BLIS zen sgemm: MK16x6, (144, 4080, 512).
            DType::F32 => GemmConfig { mk: MicroKernel::new(16, 6), ccp: Ccp::new(144, 4080, 512) },
        })
    } else if lower.contains("xeon") || lower.contains("intel") || lower.contains("host") {
        Some(match dt {
            // BLIS haswell defaults (same generation as the host AVX2
            // Xeon): MK8x6 with (mc, nc, kc) = (72, 4080, 256).
            DType::F64 => GemmConfig { mk: MicroKernel::new(8, 6), ccp: Ccp::new(72, 4080, 256) },
            // BLIS haswell sgemm: MK16x6, (144, 4080, 256).
            DType::F32 => GemmConfig { mk: MicroKernel::new(16, 6), ccp: Ccp::new(144, 4080, 256) },
        })
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clamps_follow_the_paper() {
        // §3.1: nc^B = 3072 but for n = 2000 the actual nc is 2000;
        // kc^B = 240 so k = 128 gives kc = 128.
        let blis = blis_static("NVIDIA Carmel").unwrap();
        let eff = blis.ccp.clamp_to(GemmDims::new(2000, 2000, 128));
        assert_eq!(eff, Ccp::new(120, 2000, 128));
        let eff2 = blis.ccp.clamp_to(GemmDims::new(2000, 2000, 2000));
        assert_eq!(eff2, Ccp::new(120, 2000, 240));
    }

    #[test]
    fn presets_exist_for_paper_platforms() {
        assert_eq!(blis_static("NVIDIA Carmel (ARMv8.2)").unwrap().ccp, Ccp::new(120, 3072, 240));
        assert_eq!(blis_static("AMD EPYC 7282").unwrap().ccp, Ccp::new(72, 2040, 512));
        assert!(blis_static("Unknown Arch").is_none());
    }

    #[test]
    fn f32_presets_double_the_tile_height() {
        use crate::util::DType;
        let d = blis_static_dt("AMD EPYC 7282", DType::F64).unwrap();
        let s = blis_static_dt("AMD EPYC 7282", DType::F32).unwrap();
        assert_eq!(s.mk, MicroKernel::new(16, 6), "sgemm doubles the dgemm mr");
        assert_eq!(s.ccp.mc, 2 * d.ccp.mc);
        assert_eq!(blis_static_dt("host", DType::F32).unwrap().mk, MicroKernel::new(16, 6));
        assert_eq!(
            blis_static_dt("NVIDIA Carmel", DType::F32).unwrap().mk,
            MicroKernel::new(8, 12)
        );
        assert!(blis_static_dt("Unknown Arch", DType::F32).is_none());
    }

    #[test]
    fn workspace_padding() {
        let ccp = Ccp::new(100, 100, 50);
        let mk = MicroKernel::new(6, 8);
        // mc padded to 102 (17 panels of 6), nc padded to 104 (13 of 8).
        assert_eq!(ccp.workspace_bytes(mk), 8 * (102 * 50 + 50 * 104));
    }

    #[test]
    fn flops_formula() {
        assert_eq!(GemmDims::new(10, 20, 30).flops(), 12000.0);
    }
}
