//! Runtime co-design selection (the paper's §5 message): per GEMM call,
//! pick both the micro-kernel and the CCPs from the architecture *and* the
//! operand shape, instead of a static per-ISA choice.
//!
//! The selector enumerates the feasible micro-kernel family, derives the
//! refined CCPs for each, and ranks candidates with a pluggable
//! [`Scorer`]. The default [`AnalyticScorer`] estimates the per-flop
//! memory cost the way the paper reasons about it: the L2 residency of
//! `Ac` governs the stream cost of the inner loops, and the micro-kernel's
//! flops/memops ratio governs register traffic.

use crate::arch::Arch;
use crate::model::analytical::{l1_allocation, l2_allocation};
use crate::model::ccp::GemmConfig;
use crate::model::microkernel::candidate_family;
use crate::model::{Ccp, GemmDims, MicroKernel};

/// A scored configuration choice.
#[derive(Clone, Debug)]
pub struct Selection {
    pub config: GemmConfig,
    /// Estimated execution time in seconds (lower is better).
    pub est_time_s: f64,
    /// All candidates considered, best first (for introspection/ablation).
    pub ranked: Vec<(GemmConfig, f64)>,
}

/// Scores a candidate configuration; returns estimated seconds.
pub trait Scorer {
    fn score(&self, arch: &Arch, dims: GemmDims, mk: MicroKernel, ccp: Ccp) -> f64;

    /// Element-width-aware scoring: `esize` is the element size in bytes
    /// (8 = f64, 4 = f32 — twice the lanes and twice the elements per
    /// line). The default ignores the width and delegates to
    /// [`Self::score`]; width-aware scorers (the [`AnalyticScorer`])
    /// override this and implement `score` as `score_elem(.., 8)`.
    fn score_elem(&self, arch: &Arch, dims: GemmDims, mk: MicroKernel, ccp: Ccp, esize: usize) -> f64 {
        let _ = esize;
        self.score(arch, dims, mk, ccp)
    }
}

/// Closed-form cost estimate (no simulation):
///
/// * compute term — `2mnk / peak`, de-rated by micro-kernel efficiency
///   (loop overhead amortized over `mr*nr`, edge-tile waste for
///   non-dividing shapes);
/// * memory term — per-element stream costs of the packed buffers with
///   effective latencies chosen by which level each operand resides in
///   (the paper's L1/L2 residency argument), plus C update traffic
///   amplified by `k/kc` passes.
pub struct AnalyticScorer;

impl AnalyticScorer {
    /// The A-panel *packing* share of [`Scorer::score_elem`]: one
    /// memory-latency pass over `m x k` elements (the
    /// `mf * kf * cyc(l3_lat) / line` addend of the memory term),
    /// de-rated by the same overlap factor the full score applies.
    /// The calibrated selector subtracts this when the k-panel is
    /// already resident from the previous pipeline iteration (the
    /// Peise-style warm-sequence discount) — splitting it out here
    /// keeps the discount exactly consistent with the score it
    /// discounts.
    pub fn pack_a_cost_elem(
        &self,
        arch: &Arch,
        dims: GemmDims,
        mk: MicroKernel,
        ccp: Ccp,
        esize: usize,
    ) -> f64 {
        let (mf, kf) = (dims.m as f64, dims.k as f64);
        let cyc = |c: f64| c / (arch.freq_ghz * 1e9);
        let l3_lat = arch.l3().map(|l| l.latency_cycles).unwrap_or(arch.mem_latency_cycles);
        let line = arch.line_elems_for(esize) as f64;
        let overlap = (mk.flops_per_memop(ccp.kc) / 8.0).min(0.95);
        (1.0 - overlap) * mf * kf * cyc(l3_lat) / line
    }
}

impl Scorer for AnalyticScorer {
    fn score(&self, arch: &Arch, dims: GemmDims, mk: MicroKernel, ccp: Ccp) -> f64 {
        self.score_elem(arch, dims, mk, ccp, 8)
    }

    fn score_elem(&self, arch: &Arch, dims: GemmDims, mk: MicroKernel, ccp: Ccp, esize: usize) -> f64 {
        let GemmDims { m, n, k } = dims;
        let (mf, nf, kf) = (m as f64, n as f64, k as f64);
        let flops = 2.0 * mf * nf * kf;

        // --- Compute term -------------------------------------------------
        // Edge waste: padded tile work for the fringe of each dimension.
        let m_pad = (m.div_ceil(mk.mr) * mk.mr) as f64 / mf.max(1.0);
        let n_pad = (n.div_ceil(mk.nr) * mk.nr) as f64 / nf.max(1.0);
        // Per-iteration loop overhead shrinks with tile area; model as a
        // fixed issue cost amortized over mr*nr FMA lanes.
        let lanes = arch.regs.lanes_for(esize) as f64;
        let fma_per_iter = (mk.mr as f64 / lanes).ceil() * mk.nr as f64;
        let issue_overhead = 1.0 + 2.0 / fma_per_iter;
        let compute_s =
            flops / (arch.peak_gflops_core_for(esize) * 1e9) * m_pad * n_pad * issue_overhead;

        // --- Memory term --------------------------------------------------
        let l1 = arch.l1();
        let l2 = arch.l2();
        let cyc = |c: f64| c / (arch.freq_ghz * 1e9);
        // Does Ac fit its allocated L2 ways? Fraction resident determines
        // the blended latency of streaming A in the micro-kernel.
        let a2 = l2_allocation(l2, mk, ccp.kc);
        let ac_bytes = (ccp.mc * ccp.kc * esize) as f64;
        let ac_cap = (a2.a * l2.way_bytes()) as f64;
        let ac_resident = (ac_cap / ac_bytes).min(1.0);
        let l3_lat = arch.l3().map(|l| l.latency_cycles).unwrap_or(arch.mem_latency_cycles);
        // Elements of A are touched once per (n / nc) pass of loop G1.
        let a_passes = (nf / ccp.nc as f64).max(1.0);
        let a_lat = ac_resident * l2.latency_cycles + (1.0 - ac_resident) * l3_lat;
        let line = arch.line_elems_for(esize) as f64;
        let a_cost = mf * kf * a_passes * cyc(a_lat) / line
            // packing cost: one read from memory + one write, amortized
            + mf * kf * cyc(l3_lat) / line;
        // B micro-panels live in L1 if they fit their ways.
        let a1 = l1_allocation(l1, mk);
        let br_bytes = (ccp.kc * mk.nr * esize) as f64;
        let br_resident = ((a1.b * l1.way_bytes()) as f64 / br_bytes).min(1.0);
        let b_lat = br_resident * l1.latency_cycles + (1.0 - br_resident) * l2.latency_cycles;
        // Each Bc element is re-read once per mc block of loop G3.
        let b_passes = (mf / ccp.mc as f64).max(1.0);
        let b_cost = kf * nf * b_passes * cyc(b_lat) / line + kf * nf * cyc(l3_lat) / line;
        // C is read+written once per kc pass of loop G2.
        let c_passes = (kf / ccp.kc as f64).max(1.0);
        let c_cost = 2.0 * mf * nf * c_passes * cyc(l3_lat) / line;

        // Memory cost overlaps with compute; the un-hidable share grows
        // when flops/memop is low.
        let overlap = (mk.flops_per_memop(ccp.kc) / 8.0).min(0.95);
        compute_s + (1.0 - overlap) * (a_cost + b_cost + c_cost)
    }
}

/// Run the co-design selection for one GEMM call (FP64 elements).
pub fn select(arch: &Arch, dims: GemmDims, scorer: &dyn Scorer) -> Selection {
    select_from(arch, dims, scorer, &candidate_family(&arch.regs))
}

/// As [`select`] but over an explicit candidate family (used by the
/// native engine, which only registers micro-kernels it has code for).
pub fn select_from(
    arch: &Arch,
    dims: GemmDims,
    scorer: &dyn Scorer,
    family: &[MicroKernel],
) -> Selection {
    select_from_elem(arch, dims, scorer, family, 8)
}

/// The element-width-aware selection: refined CCPs are derived at
/// `esize` bytes per element (larger `mc`/`kc`/`nc` for f32) and the
/// scorer ranks with the width-scaled peak/lane/line arithmetic. The
/// `esize = 8` instantiation is exactly [`select_from`].
pub fn select_from_elem(
    arch: &Arch,
    dims: GemmDims,
    scorer: &dyn Scorer,
    family: &[MicroKernel],
    esize: usize,
) -> Selection {
    assert!(!family.is_empty(), "empty micro-kernel family");
    let mut ranked: Vec<(GemmConfig, f64)> = family
        .iter()
        .map(|&mk| {
            let ccp = crate::model::refined::refined_ccp_elem(arch, mk, dims, esize).clamp_to(dims);
            let t = scorer.score_elem(arch, dims, mk, ccp, esize);
            (GemmConfig { mk, ccp }, t)
        })
        .collect();
    ranked.sort_by(|a, b| a.1.total_cmp(&b.1));
    Selection { config: ranked[0].0, est_time_s: ranked[0].1, ranked }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282};

    #[test]
    fn selection_is_feasible_and_clamped() {
        let arch = carmel();
        for k in [8, 64, 256, 2000] {
            let dims = GemmDims::new(2000, 2000, k);
            let sel = select(&arch, dims, &AnalyticScorer);
            assert!(sel.config.mk.fits(&arch.regs));
            assert!(sel.config.ccp.kc <= k);
            assert!(sel.config.ccp.mc <= 2000 && sel.config.ccp.nc <= 2000);
            assert!(sel.est_time_s > 0.0);
            // Ranked list is sorted.
            for w in sel.ranked.windows(2) {
                assert!(w[0].1 <= w[1].1);
            }
        }
    }

    #[test]
    fn skinny_k_changes_the_choice() {
        // The whole point of the paper: the best configuration for a
        // skinny-k GEMM differs from the best for a square one.
        let arch = carmel();
        let skinny = select(&arch, GemmDims::new(2000, 2000, 64), &AnalyticScorer);
        let square = select(&arch, GemmDims::new(2000, 2000, 2000), &AnalyticScorer);
        assert_ne!(
            skinny.config.ccp, square.config.ccp,
            "refined CCPs must differ between skinny and square k"
        );
        // Skinny k gets a larger mc (the L2-filling move).
        assert!(skinny.config.ccp.mc > square.config.ccp.mc);
    }

    #[test]
    fn select_from_respects_family() {
        let arch = epyc7282();
        let fam = [MicroKernel::new(8, 6)];
        let sel = select_from(&arch, GemmDims::new(500, 500, 64), &AnalyticScorer, &fam);
        assert_eq!(sel.config.mk, MicroKernel::new(8, 6));
        assert_eq!(sel.ranked.len(), 1);
    }

    #[test]
    #[should_panic(expected = "empty micro-kernel family")]
    fn empty_family_panics() {
        select_from(&carmel(), GemmDims::new(8, 8, 8), &AnalyticScorer, &[]);
    }

    #[test]
    fn f32_selection_gets_larger_ccps_and_faster_estimates() {
        // Same family, same shape: the f32 selection must see the doubled
        // lanes (lower estimated time) and the larger refined CCPs.
        let arch = epyc7282();
        let fam = [MicroKernel::new(8, 6)];
        let dims = GemmDims::new(2000, 2000, 2000);
        let s64 = select_from_elem(&arch, dims, &AnalyticScorer, &fam, 8);
        let s32 = select_from_elem(&arch, dims, &AnalyticScorer, &fam, 4);
        assert!(s32.config.ccp.kc > s64.config.ccp.kc, "{} vs {}", s32.config, s64.config);
        assert!(s32.est_time_s < s64.est_time_s, "f32 estimate must beat f64 at equal dims");
        // And the f64 wrapper is bit-identical to the esize = 8 call.
        let w = select_from(&arch, dims, &AnalyticScorer, &fam);
        assert_eq!(w.config, s64.config);
        assert_eq!(w.est_time_s, s64.est_time_s);
    }
}
