//! Online measurement-refined selection: a performance store that
//! records **measured** per-(shape-bucket, dtype, config, team-width)
//! GFLOPS from lightweight timing hooks around pool epochs and blends
//! them with the [`AnalyticScorer`]'s priors via confidence-weighted
//! shrinkage.
//!
//! The paper's analytic model is static; Peise & Bientinesi (arxiv
//! 1409.8602, 1402.5897 in PAPERS.md) show cache-aware *measured*
//! models predict kernel sequences far better, especially when operands
//! are cache-warm from a prior kernel. The store is the runtime
//! feedback loop the ROADMAP names: cold entries fall back to the pure
//! model (zero observations → the blend returns the analytic estimate
//! **exactly**), hot entries converge to measured truth, and the
//! engine's warm-state pack discount (see `GemmEngine::plan_config_t`)
//! captures the sequence effect across pipeline iterations.
//!
//! Design constraints inherited from the memo caches it refines:
//!
//! - **Off = bitwise identical.** When no profile is attached the
//!   selectors never consult this module; every existing equivalence
//!   suite must pass unchanged. The blend itself preserves that
//!   property entry-wise: `blend` with zero observations *is* the
//!   analytic score, bit for bit.
//! - **Near-zero overhead on the hot path.** A record is one `Instant`
//!   pair the engine already brackets around its pool dispatch, one
//!   short mutex hold, and a few relaxed atomics. Lookups happen only
//!   on memo *misses* (the generation counter below forces a periodic
//!   re-miss so fresh measurements can change a cached decision).
//! - **Shared.** One `Arc<PerfProfile>` serves every worker engine; the
//!   map sits behind a `Mutex` (never held across a dispatch) and the
//!   counters are atomics.
//!
//! [`AnalyticScorer`]: crate::model::selector::AnalyticScorer

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::model::ccp::GemmConfig;
use crate::model::GemmDims;
use crate::util::DType;

/// Shrinkage prior weight: an entry needs this many observations to pull
/// the blend halfway from the analytic prior to the measured mean.
const PRIOR_WEIGHT: f64 = 4.0;
/// Running-mean window cap: keeps hot entries adaptive (a machine-state
/// change shows up within ~this many observations instead of being
/// averaged away by an unbounded history).
const OBS_WINDOW: u64 = 256;
/// Observations between generation bumps. Memo keys embed the
/// generation, so a bump turns every cached selection into one fresh
/// miss — the point where new measurements (and exploration) can change
/// a decision without per-call store lookups.
const GENERATION_STRIDE: u64 = 32;

/// Calibration switch: pinned [`ServerConfig::with_calibration`] beats
/// `DLA_CALIBRATE` beats the default (**off**). Off means the engines
/// never see a profile — selections stay bitwise identical to the
/// analytic-only stack and the timing hooks compile down to an
/// `Option::is_some` test.
///
/// [`ServerConfig::with_calibration`]: crate::coordinator::ServerConfig::with_calibration
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CalibratePolicy {
    #[default]
    Off,
    On,
}

impl CalibratePolicy {
    pub fn enabled(self) -> bool {
        matches!(self, Self::On)
    }

    /// Environment override for un-pinned servers: `DLA_CALIBRATE`
    /// unset means no override; empty / `0` / `off` / `false` pin
    /// calibration off; `1` / `on` / `true` enable it; anything
    /// unparseable is treated as **off** with one warning line (a typo
    /// must fail towards the plain analytic path, not silently enable
    /// an adaptive selector the operator did not ask for — the
    /// `DLA_BATCH` convention).
    pub fn from_env() -> Option<Self> {
        let v = std::env::var("DLA_CALIBRATE").ok()?;
        match v.trim() {
            "" | "0" | "off" | "false" => Some(Self::Off),
            "1" | "on" | "true" => Some(Self::On),
            other => {
                eprintln!(
                    "dla: unrecognized DLA_CALIBRATE={other:?}; calibration stays off \
                     (expected 0/off/false or 1/on/true)"
                );
                Some(Self::Off)
            }
        }
    }
}

/// Power-of-two shape bucket: GEMMs whose dimension rounds up to the
/// same power of two share measurements. Coarse on purpose — the store
/// must get hot from a serving mix of *similar*, not identical, shapes,
/// and the analytic prior still separates candidates within a bucket.
fn lg_bucket(x: usize) -> u8 {
    x.max(1).next_power_of_two().trailing_zeros() as u8
}

/// One store key: shape bucket, dtype, the configuration fingerprint
/// (`mr`/`nr`/`mc`/`kc`/`nc` — raw numbers, so persistence never has to
/// reconstruct a `MicroKernel`), and the team width the measurement was
/// taken at.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ProfileKey {
    pub bucket: (u8, u8, u8),
    pub dtype: DType,
    pub fp: (usize, usize, usize, usize, usize),
    pub width: usize,
}

impl ProfileKey {
    pub fn new(dims: GemmDims, dtype: DType, cfg: GemmConfig, width: usize) -> Self {
        Self {
            bucket: (lg_bucket(dims.m), lg_bucket(dims.n), lg_bucket(dims.k)),
            dtype,
            fp: (cfg.mk.mr, cfg.mk.nr, cfg.ccp.mc, cfg.ccp.kc, cfg.ccp.nc),
            width: width.max(1),
        }
    }
}

/// Windowed running mean of measured GFLOPS for one key.
#[derive(Clone, Copy, Debug, PartialEq)]
struct Obs {
    count: u64,
    mean_gflops: f64,
}

/// Snapshot of the store's counters (for metrics and tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProfileStats {
    /// Distinct keys currently held.
    pub entries: u64,
    /// Measurements recorded since construction/clear.
    pub observations: u64,
    /// Exploration trials taken (engine-side counter, kept here so every
    /// worker's engine shares one tally).
    pub explorations: u64,
    /// Blend calls that actually mixed a measurement in (≥ 1 obs).
    pub blended: u64,
    /// Current generation (memo-invalidation epoch).
    pub generation: u64,
}

/// The shared measurement store. One per server (behind an `Arc`), or
/// one per engine in tests.
#[derive(Default)]
pub struct PerfProfile {
    store: Mutex<HashMap<ProfileKey, Obs>>,
    observations: AtomicU64,
    explorations: AtomicU64,
    blended: AtomicU64,
    generation: AtomicU64,
}

impl PerfProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Memo-invalidation epoch: starts at 1 (memo keys use generation 0
    /// for "no profile attached", so attaching a profile alone already
    /// separates calibrated from uncalibrated cache entries) and bumps
    /// every [`GENERATION_STRIDE`] observations.
    pub fn generation(&self) -> u64 {
        1 + self.generation.load(Ordering::Relaxed)
    }

    /// Record one measured GEMM: `secs` of wall time for `dims` at
    /// `width` ranks under `cfg`. Degenerate timings (zero flops or a
    /// sub-tick duration) are dropped — a 0-second epoch says nothing
    /// about throughput.
    pub fn record(&self, dims: GemmDims, dtype: DType, cfg: GemmConfig, width: usize, secs: f64) {
        let flops = dims.flops();
        if !(secs > 1e-9) || flops <= 0.0 {
            return;
        }
        let gflops = flops / secs / 1e9;
        let key = ProfileKey::new(dims, dtype, cfg, width);
        {
            let mut store = self.store.lock().unwrap();
            let obs = store.entry(key).or_insert(Obs { count: 0, mean_gflops: 0.0 });
            obs.count += 1;
            // Windowed running mean: the effective sample size saturates
            // at OBS_WINDOW so late observations keep real weight.
            let n = obs.count.min(OBS_WINDOW) as f64;
            obs.mean_gflops += (gflops - obs.mean_gflops) / n;
        }
        let seen = self.observations.fetch_add(1, Ordering::Relaxed) + 1;
        if seen % GENERATION_STRIDE == 0 {
            self.generation.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Confidence-weighted shrinkage blend for one candidate at a known
    /// team width: with `n` observations, the measured mean gets weight
    /// `n / (n + PRIOR_WEIGHT)` and the analytic prior the rest. Zero
    /// observations returns `analytic_secs` **exactly** (no float
    /// arithmetic touches it), so a cold store is bitwise-transparent.
    pub fn blend(
        &self,
        dims: GemmDims,
        dtype: DType,
        cfg: GemmConfig,
        width: usize,
        analytic_secs: f64,
    ) -> f64 {
        let key = ProfileKey::new(dims, dtype, cfg, width);
        let obs = match self.store.lock().unwrap().get(&key) {
            Some(&o) if o.count > 0 && o.mean_gflops > 0.0 => o,
            _ => return analytic_secs,
        };
        self.blended.fetch_add(1, Ordering::Relaxed);
        let measured_secs = dims.flops() / (obs.mean_gflops * 1e9);
        let n = obs.count.min(OBS_WINDOW) as f64;
        let w = n / (n + PRIOR_WEIGHT);
        w * measured_secs + (1.0 - w) * analytic_secs
    }

    /// Blend for the *single-core* estimates the team-size selector and
    /// batch planner work in: measurements taken at any width are
    /// converted to single-core-equivalent seconds (`secs * width` — the
    /// G4 partition scales near-linearly at `nr` grain, the same
    /// assumption `TeamSizeSelector` already makes) and combined
    /// count-weighted across widths. Zero observations in the bucket
    /// returns `analytic_secs` exactly.
    pub fn blend_serial(
        &self,
        dims: GemmDims,
        dtype: DType,
        cfg: GemmConfig,
        analytic_secs: f64,
    ) -> f64 {
        let probe = ProfileKey::new(dims, dtype, cfg, 1);
        let (mut weight, mut serial_sum) = (0.0f64, 0.0f64);
        {
            let store = self.store.lock().unwrap();
            for (key, obs) in store.iter() {
                if key.bucket != probe.bucket || key.dtype != probe.dtype || key.fp != probe.fp {
                    continue;
                }
                if obs.count == 0 || !(obs.mean_gflops > 0.0) {
                    continue;
                }
                let n = obs.count.min(OBS_WINDOW) as f64;
                let serial = dims.flops() / (obs.mean_gflops * 1e9) * key.width as f64;
                weight += n;
                serial_sum += n * serial;
            }
        }
        if weight <= 0.0 {
            return analytic_secs;
        }
        self.blended.fetch_add(1, Ordering::Relaxed);
        let measured = serial_sum / weight;
        let w = weight.min(OBS_WINDOW as f64) / (weight.min(OBS_WINDOW as f64) + PRIOR_WEIGHT);
        w * measured + (1.0 - w) * analytic_secs
    }

    /// Count one exploration trial (the engine calls this when it
    /// dispatches a nearby candidate instead of the blended best).
    pub fn note_exploration(&self) {
        self.explorations.fetch_add(1, Ordering::Relaxed);
    }

    pub fn stats(&self) -> ProfileStats {
        ProfileStats {
            entries: self.store.lock().unwrap().len() as u64,
            observations: self.observations.load(Ordering::Relaxed),
            explorations: self.explorations.load(Ordering::Relaxed),
            blended: self.blended.load(Ordering::Relaxed),
            generation: self.generation(),
        }
    }

    pub fn len(&self) -> usize {
        self.store.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Drop every measurement and reset the counters, bumping the
    /// generation so any memoized decision that consulted the old
    /// measurements re-misses (stale observations must not outlive a
    /// plan or arch change — see `GemmEngine::clear_config_cache`).
    pub fn clear(&self) {
        self.store.lock().unwrap().clear();
        self.observations.store(0, Ordering::Relaxed);
        self.explorations.store(0, Ordering::Relaxed);
        self.blended.store(0, Ordering::Relaxed);
        self.generation.fetch_add(1, Ordering::Relaxed);
    }

    // --- Persistence (`DLA_PROFILE=path`) -------------------------------
    //
    // Hand-rolled JSON (the repo has no serde): a flat entry array of
    // numeric fields plus the dtype name. The writer is canonical
    // (sorted keys) so a save/load/save round-trip is byte-stable.

    /// Serialize the store to a JSON string.
    pub fn to_json(&self) -> String {
        let store = self.store.lock().unwrap();
        let mut entries: Vec<(&ProfileKey, &Obs)> = store.iter().collect();
        entries.sort_by_key(|(k, _)| {
            (k.bucket, k.dtype.size_bytes(), k.fp, k.width)
        });
        let mut out = String::from("{\"version\":1,\"entries\":[");
        for (i, (k, o)) in entries.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"bm\":{},\"bn\":{},\"bk\":{},\"dtype\":\"{}\",\"mr\":{},\"nr\":{},\
                 \"mc\":{},\"kc\":{},\"nc\":{},\"width\":{},\"count\":{},\"gflops\":{}}}",
                k.bucket.0,
                k.bucket.1,
                k.bucket.2,
                k.dtype.name(),
                k.fp.0,
                k.fp.1,
                k.fp.2,
                k.fp.3,
                k.fp.4,
                k.width,
                o.count,
                o.mean_gflops,
            ));
        }
        out.push_str("]}");
        out
    }

    /// Load entries from a JSON string produced by [`Self::to_json`],
    /// replacing the current store. Returns the number of entries
    /// loaded, or an error describing the first malformed field — the
    /// caller must fail toward an **empty** store (never a partial or
    /// corrupt one).
    pub fn load_json(&self, text: &str) -> Result<usize, String> {
        let mut parsed: Vec<(ProfileKey, Obs)> = Vec::new();
        let body = text.trim();
        if !body.starts_with('{') || !body.ends_with('}') {
            return Err("profile is not a JSON object".into());
        }
        let entries_at =
            body.find("\"entries\"").ok_or_else(|| "missing \"entries\" array".to_string())?;
        let open = body[entries_at..]
            .find('[')
            .map(|i| entries_at + i)
            .ok_or_else(|| "missing entries '['".to_string())?;
        let close = body.rfind(']').ok_or_else(|| "missing entries ']'".to_string())?;
        if close < open {
            return Err("malformed entries array".into());
        }
        let array = &body[open + 1..close];
        for chunk in array.split('{').skip(1) {
            let obj = match chunk.find('}') {
                Some(end) => &chunk[..end],
                None => return Err("unterminated entry object".into()),
            };
            let field = |name: &str| -> Result<&str, String> {
                let tag = format!("\"{name}\":");
                let at = obj.find(&tag).ok_or_else(|| format!("entry missing {name:?}"))?;
                let rest = &obj[at + tag.len()..];
                let end = rest.find(',').unwrap_or(rest.len());
                Ok(rest[..end].trim())
            };
            let num = |name: &str| -> Result<u64, String> {
                field(name)?.parse::<u64>().map_err(|_| format!("bad numeric field {name:?}"))
            };
            let dtype = match field("dtype")?.trim_matches('"') {
                "f64" => DType::F64,
                "f32" => DType::F32,
                other => return Err(format!("unknown dtype {other:?}")),
            };
            let gflops = field("gflops")?
                .parse::<f64>()
                .map_err(|_| "bad numeric field \"gflops\"".to_string())?;
            if !(gflops.is_finite() && gflops >= 0.0) {
                return Err("non-finite gflops".into());
            }
            let key = ProfileKey {
                bucket: (num("bm")? as u8, num("bn")? as u8, num("bk")? as u8),
                dtype,
                fp: (
                    num("mr")? as usize,
                    num("nr")? as usize,
                    num("mc")? as usize,
                    num("kc")? as usize,
                    num("nc")? as usize,
                ),
                width: (num("width")? as usize).max(1),
            };
            parsed.push((key, Obs { count: num("count")?, mean_gflops: gflops }));
        }
        let n = parsed.len();
        let mut store = self.store.lock().unwrap();
        store.clear();
        store.extend(parsed);
        Ok(n)
    }

    /// Write the store to `path` (used at server shutdown when
    /// `DLA_PROFILE` is set). Errors are returned, not panicked — a
    /// failed save must never take the server down.
    pub fn save_to_path(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Load the store from `path`. A missing or malformed file fails
    /// toward an empty store with a warning (the `DLA_BATCH`
    /// convention): serving must start, calibration just starts cold.
    pub fn load_from_path(&self, path: &str) -> usize {
        match std::fs::read_to_string(path) {
            Ok(text) => match self.load_json(&text) {
                Ok(n) => n,
                Err(e) => {
                    eprintln!("dla: ignoring malformed profile {path:?}: {e}; starting cold");
                    self.store.lock().unwrap().clear();
                    0
                }
            },
            Err(e) => {
                eprintln!("dla: cannot read DLA_PROFILE={path:?}: {e}; starting cold");
                0
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::model::{refined_ccp, MicroKernel};

    fn cfg_for(dims: GemmDims) -> GemmConfig {
        let arch = host_xeon();
        let mk = MicroKernel::new(8, 6);
        GemmConfig { mk, ccp: refined_ccp(&arch, mk, dims).clamp_to(dims) }
    }

    #[test]
    fn cold_blend_is_exactly_analytic() {
        let p = PerfProfile::new();
        let dims = GemmDims::new(512, 512, 64);
        let cfg = cfg_for(dims);
        let analytic = 1.2345e-3;
        assert_eq!(p.blend(dims, DType::F64, cfg, 4, analytic), analytic);
        assert_eq!(p.blend_serial(dims, DType::F64, cfg, analytic), analytic);
        assert_eq!(p.stats().blended, 0);
    }

    #[test]
    fn observations_pull_the_blend_toward_measured_truth() {
        let p = PerfProfile::new();
        let dims = GemmDims::new(512, 512, 64);
        let cfg = cfg_for(dims);
        // Analytic says 1 ms; the machine actually does it in ~100 µs.
        let analytic = 1.0e-3;
        let measured = dims.flops() / 1.0e-4; // flops/sec
        let secs = dims.flops() / measured;
        let mut last = analytic;
        for _ in 0..64 {
            p.record(dims, DType::F64, cfg, 4, secs);
            let b = p.blend(dims, DType::F64, cfg, 4, analytic);
            assert!(b <= last + 1e-12, "blend must move monotonically toward measured");
            last = b;
        }
        // After 64 observations the blend sits much nearer measured than
        // analytic.
        assert!(last < 0.2 * analytic, "blend {last} still near analytic {analytic}");
        assert!(last > 0.9 * secs, "blend {last} overshot measured {secs}");
        let s = p.stats();
        assert_eq!(s.observations, 64);
        assert!(s.blended >= 64);
        assert!(s.generation > 1, "64 observations must bump the generation");
    }

    #[test]
    fn serial_blend_scales_by_width() {
        let p = PerfProfile::new();
        let dims = GemmDims::new(256, 256, 64);
        let cfg = cfg_for(dims);
        // A 4-wide epoch finishing in t seconds is ~4t of serial work.
        let secs = 1.0e-4;
        for _ in 0..32 {
            p.record(dims, DType::F64, cfg, 4, secs);
        }
        let analytic = 4.0 * secs; // prior agrees with the measurement
        let b = p.blend_serial(dims, DType::F64, cfg, analytic);
        assert!((b - analytic).abs() < 0.05 * analytic, "serial blend {b} vs {analytic}");
    }

    #[test]
    fn degenerate_timings_are_dropped() {
        let p = PerfProfile::new();
        let dims = GemmDims::new(64, 64, 64);
        let cfg = cfg_for(dims);
        p.record(dims, DType::F64, cfg, 1, 0.0);
        p.record(dims, DType::F64, cfg, 1, -1.0);
        p.record(GemmDims::new(0, 64, 64), DType::F64, cfg, 1, 1.0e-3);
        assert!(p.is_empty());
        assert_eq!(p.stats().observations, 0);
    }

    #[test]
    fn clear_resets_and_bumps_generation() {
        let p = PerfProfile::new();
        let dims = GemmDims::new(128, 128, 32);
        let cfg = cfg_for(dims);
        p.record(dims, DType::F64, cfg, 2, 1.0e-4);
        p.note_exploration();
        let g = p.generation();
        p.clear();
        assert!(p.is_empty());
        let s = p.stats();
        assert_eq!((s.observations, s.explorations, s.blended), (0, 0, 0));
        assert!(p.generation() > g, "clear must invalidate memoized decisions");
    }

    #[test]
    fn json_round_trip_preserves_entries_and_blends() {
        let p = PerfProfile::new();
        let d64 = GemmDims::new(512, 512, 64);
        let d32 = GemmDims::new(96, 4096, 96);
        let (c64, c32) = (cfg_for(d64), cfg_for(d32));
        for _ in 0..8 {
            p.record(d64, DType::F64, c64, 4, 2.0e-4);
            p.record(d32, DType::F32, c32, 8, 5.0e-5);
        }
        let json = p.to_json();
        let q = PerfProfile::new();
        assert_eq!(q.load_json(&json).unwrap(), 2);
        // The loaded store blends identically to the original.
        let analytic = 1.0e-3;
        assert_eq!(
            p.blend(d64, DType::F64, c64, 4, analytic),
            q.blend(d64, DType::F64, c64, 4, analytic)
        );
        assert_eq!(
            p.blend(d32, DType::F32, c32, 8, analytic),
            q.blend(d32, DType::F32, c32, 8, analytic)
        );
        // And the writer is canonical: a second save is byte-identical.
        assert_eq!(q.to_json(), json);
    }

    #[test]
    fn malformed_json_fails_toward_empty() {
        let p = PerfProfile::new();
        assert!(p.load_json("not json at all").is_err());
        assert!(p.load_json("{\"version\":1}").is_err());
        assert!(p
            .load_json("{\"version\":1,\"entries\":[{\"bm\":1}]}")
            .is_err());
        assert!(p.is_empty());
        // A valid empty store loads zero entries.
        assert_eq!(p.load_json("{\"version\":1,\"entries\":[]}").unwrap(), 0);
    }

    #[test]
    fn env_policy_parsing() {
        // from_env reads the live environment, so only exercise it when
        // the variable is unset (the CI matrix sets it on purpose).
        if std::env::var("DLA_CALIBRATE").is_err() {
            assert_eq!(CalibratePolicy::from_env(), None);
        }
        assert!(!CalibratePolicy::default().enabled());
        assert!(CalibratePolicy::On.enabled());
    }

    #[test]
    fn buckets_are_coarse_powers_of_two() {
        let a = ProfileKey::new(GemmDims::new(500, 500, 60), DType::F64, cfg_for(GemmDims::new(512, 512, 64)), 4);
        let b = ProfileKey::new(GemmDims::new(512, 512, 64), DType::F64, cfg_for(GemmDims::new(512, 512, 64)), 4);
        assert_eq!(a.bucket, b.bucket, "nearby shapes share a bucket");
        assert_eq!(lg_bucket(1), 0);
        assert_eq!(lg_bucket(0), 0);
        assert_eq!(lg_bucket(64), 6);
        assert_eq!(lg_bucket(65), 7);
    }
}
