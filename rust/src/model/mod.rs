//! Analytical machinery of the paper.
//!
//! - [`microkernel`] — micro-kernel shapes, register-pressure feasibility
//!   and the flops/memops ratio of §2.3.
//! - [`ccp`] — cache-configuration-parameter types and the BLIS static
//!   presets the paper compares against.
//! - [`analytical`] — the original Low-et-al. (TOMS 2016) model: way
//!   allocations per cache level and the shape-independent optimal CCPs.
//! - [`refined`] — the paper's contribution (§3.3): the dimension-aware
//!   refinement `kc = min(k, kc*)` propagated into the `mc`/`nc` choices.
//! - [`occupancy`] — theoretical L1/L2 occupancy used by Tables 1–2 and
//!   Figure 6 (left).
//! - [`selector`] — the runtime co-design selection of (micro-kernel,
//!   CCPs) per GEMM call (§5's "no longer monolithic" message).
//! - [`teamsize`] — the panel/update thread-split selector for the
//!   lookahead pipeline: the same cost model that picks the CCPs also
//!   picks `t_p` per factorization iteration, memoized like the config
//!   cache.
//! - [`batchplan`] — the serving-layer batch planner: the same scorer
//!   decides which requests are too small for a full-team dispatch and
//!   how to partition the team across the members of a fused batch.
//! - [`profile`] — the online measurement store: per-(shape-bucket,
//!   dtype, config, width) measured GFLOPS blended with the analytic
//!   priors via confidence-weighted shrinkage, so selections refine
//!   toward measured truth as the server warms up.

pub mod analytical;
pub mod autotune;
pub mod batchplan;
pub mod ccp;
pub mod microkernel;
pub mod occupancy;
pub mod profile;
pub mod refined;
pub mod selector;
pub mod teamsize;

pub use analytical::{
    kc_star_elem, l1_allocation, l2_allocation, l3_allocation, original_ccp, original_ccp_elem,
    WayAlloc,
};
pub use batchplan::{BatchPlanner, BatchPolicy};
pub use ccp::{blis_static, blis_static_dt, Ccp, GemmDims};
pub use microkernel::{candidate_family_lanes, MicroKernel};
pub use occupancy::{occupancy_row, OccupancyRow};
pub use profile::{CalibratePolicy, PerfProfile, ProfileStats};
pub use refined::{refined_ccp, refined_ccp_elem};
pub use selector::{select, select_from_elem, AnalyticScorer, Scorer, Selection};
pub use teamsize::{PanelShape, TeamSizeSelector, TeamSizeStats};
