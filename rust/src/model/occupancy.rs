//! Theoretical cache-occupancy analysis (Tables 1–2, Figure 6 left).
//!
//! For a GEMM with effective CCPs, the resident blocks are the `kc x nr`
//! micro-panel `Br` in L1 and the `mc x kc` packed buffer `Ac` in L2.
//! "Max" is the share of each level the model's way allocation permits.

use crate::arch::Arch;
use crate::model::analytical::{l1_allocation, l2_allocation};
use crate::model::{Ccp, GemmDims, MicroKernel};

/// One row of the paper's occupancy tables.
#[derive(Clone, Copy, Debug)]
pub struct OccupancyRow {
    pub k: usize,
    pub mc: usize,
    pub nc: usize,
    pub kc: usize,
    pub mr: usize,
    pub nr: usize,
    /// `Br` footprint in KiB and as a fraction of L1.
    pub l1_kib: f64,
    pub l1_pct: f64,
    /// Model maximum share of L1 for `Br` (None for static-CCP rows,
    /// rendered "-" like the paper).
    pub l1_max_pct: Option<f64>,
    /// `Ac` footprint in KiB and as a fraction of L2.
    pub l2_kib: f64,
    pub l2_pct: f64,
    pub l2_max_pct: Option<f64>,
}

/// Compute an occupancy row for a *clamped* CCP choice. `with_max` adds
/// the model's way-allocation maxima (the paper reports these only for
/// MOD rows).
pub fn occupancy_row(
    arch: &Arch,
    mk: MicroKernel,
    dims: GemmDims,
    ccp_effective: Ccp,
    with_max: bool,
) -> OccupancyRow {
    let l1 = arch.l1();
    let l2 = arch.l2();
    let br_bytes = (ccp_effective.kc * mk.nr * 8) as f64;
    let ac_bytes = (ccp_effective.mc * ccp_effective.kc * 8) as f64;
    let (l1_max, l2_max) = if with_max {
        let a1 = l1_allocation(l1, mk);
        let a2 = l2_allocation(l2, mk, ccp_effective.kc);
        (
            Some(100.0 * a1.b as f64 / l1.ways as f64),
            Some(100.0 * a2.a as f64 / l2.ways as f64),
        )
    } else {
        (None, None)
    };
    OccupancyRow {
        k: dims.k,
        mc: ccp_effective.mc,
        nc: ccp_effective.nc,
        kc: ccp_effective.kc,
        mr: mk.mr,
        nr: mk.nr,
        l1_kib: br_bytes / 1024.0,
        l1_pct: 100.0 * br_bytes / l1.size_bytes as f64,
        l1_max_pct: l1_max,
        l2_kib: ac_bytes / 1024.0,
        l2_pct: 100.0 * ac_bytes / l2.size_bytes as f64,
        l2_max_pct: l2_max,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::carmel;
    use crate::model::{blis_static, refined_ccp};

    const MK68: MicroKernel = MicroKernel::new(6, 8);

    fn blis_row(k: usize) -> OccupancyRow {
        let dims = GemmDims::new(2000, 2000, k);
        let cfg = blis_static("carmel").unwrap();
        occupancy_row(&carmel(), cfg.mk, dims, cfg.ccp.clamp_to(dims), false)
    }

    fn mod_row(k: usize) -> OccupancyRow {
        let dims = GemmDims::new(2000, 2000, k);
        let ccp = refined_ccp(&carmel(), MK68, dims);
        occupancy_row(&carmel(), MK68, dims, ccp, true)
    }

    fn close(a: f64, b: f64) -> bool {
        (a - b).abs() < 0.06
    }

    #[test]
    fn table1_blis_rows_match_paper() {
        // (k, L1 KB, L1 %, L2 KB, L2 %) from Table 1's BLIS rows.
        let expect = [
            (64, 4.0, 6.2, 60.0, 2.9),
            (96, 6.0, 9.4, 90.0, 4.4),
            (128, 8.0, 12.5, 120.0, 5.9),
            (160, 10.0, 15.6, 150.0, 7.3),
            (192, 12.0, 18.8, 180.0, 8.8),
            (224, 14.0, 21.9, 210.0, 10.3),
            (256, 15.0, 23.4, 225.0, 11.0),
            (2000, 15.0, 23.4, 225.0, 11.0),
        ];
        for (k, l1kb, l1p, l2kb, l2p) in expect {
            let r = blis_row(k);
            assert!(close(r.l1_kib, l1kb), "k={k} L1 KiB {} != {l1kb}", r.l1_kib);
            assert!(close(r.l1_pct, l1p), "k={k} L1 % {} != {l1p}", r.l1_pct);
            assert!(close(r.l2_kib, l2kb), "k={k} L2 KiB {} != {l2kb}", r.l2_kib);
            assert!(close(r.l2_pct, l2p), "k={k} L2 % {} != {l2p}", r.l2_pct);
            assert!(r.l1_max_pct.is_none());
        }
    }

    #[test]
    fn table1_mod_rows_match_paper() {
        // (k, L1 KB, L1 %, L1 max, L2 KB, L2 %, L2 max) from MOD rows.
        let expect = [
            (64, 4.0, 6.2, 50.0, 1000.0, 48.8, 81.2),
            (96, 6.0, 9.4, 50.0, 1500.0, 73.2, 81.2),
            (128, 8.0, 12.5, 50.0, 1792.0, 87.5, 87.5),
            (160, 10.0, 15.6, 50.0, 1780.0, 86.9, 87.5),
            (192, 12.0, 18.8, 50.0, 1776.0, 86.7, 87.5),
            (224, 14.0, 21.9, 50.0, 1792.0, 87.5, 87.5),
            (256, 16.0, 25.0, 50.0, 1792.0, 87.5, 87.5),
            (2000, 21.3, 33.3, 50.0, 1790.2, 87.4, 87.5),
        ];
        for (k, l1kb, l1p, l1max, l2kb, l2p, l2max) in expect {
            let r = mod_row(k);
            assert!(close(r.l1_kib, l1kb), "k={k} L1 KiB {} != {l1kb}", r.l1_kib);
            assert!(close(r.l1_pct, l1p), "k={k} L1 % {} != {l1p}", r.l1_pct);
            assert!(close(r.l1_max_pct.unwrap(), l1max), "k={k} L1 max");
            assert!(close(r.l2_kib, l2kb), "k={k} L2 KiB {} != {l2kb}", r.l2_kib);
            assert!(close(r.l2_pct, l2p), "k={k} L2 % {} != {l2p}", r.l2_pct);
            assert!(close(r.l2_max_pct.unwrap(), l2max), "k={k} L2 max {} != {l2max}", r.l2_max_pct.unwrap());
        }
    }

    #[test]
    fn table2_rows_match_paper() {
        // Table 2: (mr, nr, k) -> (mc, L1 KB, L1 %, L1 max, L2 KB, L2 %, L2 max).
        let cc = carmel();
        let cases = [
            (4, 10, 64, 2000, 5.0, 7.8, 50.0, 1000.0, 48.8, 75.0),
            (4, 12, 64, 2000, 6.0, 9.4, 50.0, 1000.0, 48.8, 75.0),
            (10, 4, 64, 2000, 2.0, 3.1, 25.0, 1000.0, 48.8, 87.5),
            (12, 4, 64, 2000, 2.0, 3.1, 25.0, 1000.0, 48.8, 87.5),
            (4, 10, 128, 1664, 10.0, 15.6, 50.0, 1664.0, 81.2, 81.2),
            (4, 12, 128, 1664, 12.0, 18.8, 50.0, 1664.0, 81.2, 81.2),
            (10, 4, 128, 1792, 4.0, 6.2, 25.0, 1792.0, 87.5, 87.5),
            (12, 4, 128, 1792, 4.0, 6.2, 25.0, 1792.0, 87.5, 87.5),
            (4, 10, 192, 1184, 15.0, 23.4, 50.0, 1776.0, 86.7, 87.5),
            (4, 12, 192, 1184, 18.0, 28.1, 50.0, 1776.0, 86.7, 87.5),
            (10, 4, 192, 1184, 6.0, 9.4, 25.0, 1776.0, 86.7, 87.5),
            (12, 4, 192, 1184, 6.0, 9.4, 25.0, 1776.0, 86.7, 87.5),
            (4, 10, 256, 896, 20.0, 31.2, 50.0, 1792.0, 87.5, 87.5),
            (4, 12, 256, 896, 24.0, 37.5, 50.0, 1792.0, 87.5, 87.5),
            (10, 4, 256, 896, 8.0, 12.5, 25.0, 1792.0, 87.5, 87.5),
            (12, 4, 256, 896, 8.0, 12.5, 25.0, 1792.0, 87.5, 87.5),
        ];
        for (mr, nr, k, mc, l1kb, l1p, l1max, l2kb, l2p, l2max) in cases {
            let mk = MicroKernel::new(mr, nr);
            let dims = GemmDims::new(2000, 2000, k);
            let ccp = refined_ccp(&cc, mk, dims);
            assert_eq!(ccp.mc, mc, "MK{mr}x{nr} k={k} mc");
            assert_eq!(ccp.kc, k, "MK{mr}x{nr} k={k} kc");
            let r = occupancy_row(&cc, mk, dims, ccp, true);
            assert!(close(r.l1_kib, l1kb), "MK{mr}x{nr} k={k} L1 KiB {}", r.l1_kib);
            assert!(close(r.l1_pct, l1p), "MK{mr}x{nr} k={k} L1 %");
            assert!(close(r.l1_max_pct.unwrap(), l1max), "MK{mr}x{nr} k={k} L1 max");
            assert!(close(r.l2_kib, l2kb), "MK{mr}x{nr} k={k} L2 KiB {}", r.l2_kib);
            assert!(close(r.l2_pct, l2p), "MK{mr}x{nr} k={k} L2 %");
            assert!(close(r.l2_max_pct.unwrap(), l2max), "MK{mr}x{nr} k={k} L2 max {}", r.l2_max_pct.unwrap());
        }
    }

    #[test]
    fn occupancy_never_exceeds_cache() {
        for k in [1, 17, 64, 341, 4096] {
            let r = mod_row(k);
            assert!(r.l1_pct <= 100.0 && r.l2_pct <= 100.0);
            if let (Some(m1), Some(m2)) = (r.l1_max_pct, r.l2_max_pct) {
                assert!(r.l1_pct <= m1 + 0.1, "k={k}: L1 occupancy above model max");
                assert!(r.l2_pct <= m2 + 0.1, "k={k}: L2 occupancy above model max");
            }
        }
    }
}
