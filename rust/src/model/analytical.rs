//! The original analytical CCP model of Low et al. (TOMS 2016), as
//! summarized in paper §3.2–§3.3.
//!
//! Per cache level, the `W` ways of each set are allocated: one line per
//! set is reserved for the output micro-tile `C`, and the remaining
//! `W - 1` are split between the two input operands proportionally to
//! their footprint-per-set ratio. The fill-level parameters follow:
//!
//! - **L1** hosts the `kc x nr` micro-panel `Br` while `mr x kc`
//!   micro-panels of `Ac` stream through: split by `nr : mr`, then
//!   `kc* = C_Ar * S1 * line / (mr * 8)`.
//! - **L2** hosts the `mc x kc` packed buffer `Ac` while `kc x nr`
//!   micro-panels of `Bc` stream: split by `nr : kc`, then
//!   `mc* = C_Ac * S2 * line / (kc * 8)`.
//! - **L3** hosts the `kc x nc` packed buffer `Bc` while `mc x kc` blocks
//!   of `A` stream: split by `kc : mc`, then
//!   `nc* = C_Bc * S3 * line / (kc * 8)`.
//!
//! `mc`/`nc` are rounded down to multiples of [`CCP_GRANULE`] — this
//! reproduces every CCP row published in the paper's Tables 1–2 (e.g.
//! `mc = 1424` at `kc = 160`, `nc = 480` at `kc = 341` on Carmel, and
//! `(768, 2000, 64)`/`(192, 2000, 256)` on the EPYC).

use crate::arch::{Arch, CacheLevel};
use crate::model::{Ccp, MicroKernel};
use crate::util::round_down;

/// Granule that published CCPs are rounded down to (elements).
pub const CCP_GRANULE: usize = 16;

/// Way allocation of one cache level: lines per set for C, A and B.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WayAlloc {
    pub c: usize,
    pub a: usize,
    pub b: usize,
}

impl WayAlloc {
    pub fn total(&self) -> usize {
        self.c + self.a + self.b
    }
}

/// Split `w - 1` ways between A and B proportionally to `a_weight :
/// b_weight`, reserving one way for C and at least one way for each
/// operand. B receives `ceil((w-1) * b_weight / (a_weight + b_weight))`.
fn split_ways_ceil_b(w: usize, a_weight: f64, b_weight: f64) -> WayAlloc {
    assert!(w >= 3, "need at least 3 ways to hold C, A and B");
    let avail = w - 1;
    let b = ((avail as f64) * b_weight / (a_weight + b_weight)).ceil() as usize;
    let b = b.clamp(1, avail - 1);
    WayAlloc { c: 1, a: avail - b, b }
}

/// As above but rounding B's share to nearest (used at L3; reproduces the
/// paper's published `nc` values).
fn split_ways_round_b(w: usize, a_weight: f64, b_weight: f64) -> WayAlloc {
    assert!(w >= 3, "need at least 3 ways to hold C, A and B");
    let avail = w - 1;
    let b = ((avail as f64) * b_weight / (a_weight + b_weight)).round() as usize;
    let b = b.clamp(1, avail - 1);
    WayAlloc { c: 1, a: avail - b, b }
}

/// L1 way allocation for a micro-kernel: split by `mr : nr`
/// (paper §3.2: MK6x8 on Carmel -> 1 line C, 1 line A, 2 lines B).
pub fn l1_allocation(l1: &CacheLevel, mk: MicroKernel) -> WayAlloc {
    split_ways_ceil_b(l1.ways, mk.mr as f64, mk.nr as f64)
}

/// Optimal `kc*`: largest kc such that the `mr x kc` A micro-panel fits
/// its L1 ways AND the `kc x nr` B micro-panel fits its L1 ways (FP64
/// elements; see [`kc_star_elem`] for other widths).
pub fn kc_star(l1: &CacheLevel, mk: MicroKernel) -> usize {
    kc_star_elem(l1, mk, 8)
}

/// [`kc_star`] at an explicit element width in bytes: the cache holds
/// `line_bytes / esize` elements per line, so halving the width doubles
/// the cache-optimal `kc` (the f32 payoff the element-generic stack
/// exploits).
pub fn kc_star_elem(l1: &CacheLevel, mk: MicroKernel, esize: usize) -> usize {
    let alloc = l1_allocation(l1, mk);
    let per_way_bytes = l1.sets() * l1.line_bytes;
    let kc_a = alloc.a * per_way_bytes / (mk.mr * esize);
    let kc_b = alloc.b * per_way_bytes / (mk.nr * esize);
    kc_a.min(kc_b).max(1)
}

/// L2 way allocation given the effective `kc`: split by `kc : nr`
/// (paper §3.2: ratio `kc/nr = 240/8 = 30` -> 14 lines for A on Carmel).
pub fn l2_allocation(l2: &CacheLevel, mk: MicroKernel, kc: usize) -> WayAlloc {
    split_ways_ceil_b(l2.ways, kc as f64, mk.nr as f64)
}

/// Optimal `mc` for a given `kc` (exact, before granule rounding; FP64
/// elements — see [`mc_exact_elem`]).
pub fn mc_exact(l2: &CacheLevel, mk: MicroKernel, kc: usize) -> f64 {
    mc_exact_elem(l2, mk, kc, 8)
}

/// [`mc_exact`] at an explicit element width in bytes.
pub fn mc_exact_elem(l2: &CacheLevel, mk: MicroKernel, kc: usize, esize: usize) -> f64 {
    let alloc = l2_allocation(l2, mk, kc);
    (alloc.a * l2.sets() * l2.line_bytes) as f64 / (kc * esize) as f64
}

/// L3 way allocation given effective `kc` and (exact) `mc`: split by
/// `mc : kc` — `Bc`'s per-set footprint scales with `kc`, the streaming
/// `Ac` block's with `mc`.
pub fn l3_allocation(l3: &CacheLevel, kc: usize, mc_exact: f64) -> WayAlloc {
    split_ways_round_b(l3.ways, mc_exact, kc as f64)
}

/// Optimal `nc` for given `kc`/`mc` (exact, before granule rounding;
/// FP64 elements — see [`nc_exact_elem`]).
pub fn nc_exact(l3: &CacheLevel, kc: usize, mc: f64) -> f64 {
    nc_exact_elem(l3, kc, mc, 8)
}

/// [`nc_exact`] at an explicit element width in bytes.
pub fn nc_exact_elem(l3: &CacheLevel, kc: usize, mc: f64, esize: usize) -> f64 {
    let alloc = l3_allocation(l3, kc, mc);
    (alloc.b * l3.sets() * l3.line_bytes) as f64 / (kc * esize) as f64
}

/// The **original** (shape-independent) model: compute `(mc*, nc*, kc*)`
/// from the architecture alone, with `kc` fixed at its L1 optimum (FP64
/// elements).
///
/// Paper §3.3 check (Carmel, MK6x8): `(672, 480, 341)`.
pub fn original_ccp(arch: &Arch, mk: MicroKernel) -> Ccp {
    original_ccp_elem(arch, mk, 8)
}

/// [`original_ccp`] at an explicit element width in bytes: every level's
/// fill parameter counts elements of that width, so f32 doubles
/// `kc*`/`mc*`/`nc*` (up to granule rounding).
pub fn original_ccp_elem(arch: &Arch, mk: MicroKernel, esize: usize) -> Ccp {
    let kc = kc_star_elem(arch.l1(), mk, esize);
    let mc_x = mc_exact_elem(arch.l2(), mk, kc, esize);
    let mc = round_down(mc_x as usize, CCP_GRANULE).max(mk.mr);
    let nc = match arch.l3() {
        Some(l3) => round_down(nc_exact_elem(l3, kc, mc_x, esize) as usize, CCP_GRANULE).max(mk.nr),
        // No L3: stage B panels straight from memory; pick a large nc.
        None => round_down(8192, CCP_GRANULE),
    };
    Ccp { mc, nc, kc }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282};

    #[test]
    fn carmel_l1_allocation_matches_paper() {
        // §3.2: "one line of each cache set should be dedicated to C,
        // while the remaining lines should be distributed between the
        // entries of B and A proportionally to nr/mr = 8/6": 1 A, 2 B.
        let a = l1_allocation(carmel().l1(), MicroKernel::new(6, 8));
        assert_eq!(a, WayAlloc { c: 1, a: 1, b: 2 });
        // -> "up to 32 KB (50%) of the L1 to Br".
        assert_eq!(a.b * carmel().l1().way_bytes(), 32 * 1024);
    }

    #[test]
    fn carmel_l2_allocation_matches_paper() {
        // §3.2: ratio kc/nr = 240/8 = 30 -> "14 lines per set to A,
        // yielding a maximum usage of 1.75 MB (87.5%) of the L2".
        let a = l2_allocation(carmel().l2(), MicroKernel::new(6, 8), 240);
        assert_eq!(a, WayAlloc { c: 1, a: 14, b: 1 });
        assert_eq!(a.a * carmel().l2().way_bytes(), 1792 * 1024);
    }

    #[test]
    fn carmel_original_model_matches_paper() {
        // §3.3 / Table 1 row k=2000: (mc, nc, kc) = (672, 480, 341).
        let ccp = original_ccp(&carmel(), MicroKernel::new(6, 8));
        assert_eq!(ccp.kc, 341);
        assert_eq!(ccp.mc, 672);
        assert_eq!(ccp.nc, 480);
    }

    #[test]
    fn epyc_kc_star() {
        // §4.1: the refined model picks kc = 256 for MK8x6 when k >= 256.
        assert_eq!(kc_star(epyc7282().l1(), MicroKernel::new(8, 6)), 256);
        assert_eq!(kc_star(epyc7282().l1(), MicroKernel::new(6, 8)), 256);
    }

    #[test]
    fn way_alloc_invariants() {
        for arch in [carmel(), epyc7282()] {
            for mk in crate::model::microkernel::candidate_family(&arch.regs) {
                let a1 = l1_allocation(arch.l1(), mk);
                assert_eq!(a1.total(), arch.l1().ways);
                assert!(a1.a >= 1 && a1.b >= 1);
                for kc in [32, 64, 341, 512] {
                    let a2 = l2_allocation(arch.l2(), mk, kc);
                    assert_eq!(a2.total(), arch.l2().ways);
                    assert!(a2.a >= 1 && a2.b >= 1);
                }
            }
        }
    }

    #[test]
    fn f32_width_doubles_kc_star() {
        // Halving the element width doubles how many elements the same
        // L1 ways hold: kc*(f32) = 2 * kc*(f64) exactly (both divisions
        // are exact for power-of-two way capacities).
        for arch in [carmel(), epyc7282()] {
            for mk in [MicroKernel::new(8, 6), MicroKernel::new(6, 8)] {
                let k64 = kc_star_elem(arch.l1(), mk, 8);
                let k32 = kc_star_elem(arch.l1(), mk, 4);
                assert_eq!(k32, 2 * k64, "{mk} on {}", arch.name);
                assert_eq!(kc_star(arch.l1(), mk), k64, "wrapper must stay f64");
            }
        }
        // And the full original model picks a strictly larger mc too.
        let c64 = original_ccp_elem(&epyc7282(), MicroKernel::new(8, 6), 8);
        let c32 = original_ccp_elem(&epyc7282(), MicroKernel::new(8, 6), 4);
        assert!(c32.kc > c64.kc && c32.mc >= c64.mc, "{c32} vs {c64}");
    }

    #[test]
    fn kc_star_fits_l1_by_construction() {
        for arch in [carmel(), epyc7282()] {
            for mk in crate::model::microkernel::candidate_family(&arch.regs) {
                let kc = kc_star(arch.l1(), mk);
                let alloc = l1_allocation(arch.l1(), mk);
                let way = arch.l1().way_bytes();
                assert!(mk.mr * kc * 8 <= alloc.a * way, "{mk} A micro-panel overflows");
                assert!(kc * mk.nr * 8 <= alloc.b * way, "{mk} B micro-panel overflows");
            }
        }
    }
}
