//! `dla` — the launcher for the co-design DLA stack.
//!
//! Subcommands regenerate every table and figure of the paper, inspect
//! the analytical model, run the cache simulator, or exercise the
//! serving layer. See `dla help`.

use dla_codesign::arch::{preset_by_name, PRESET_NAMES};
use dla_codesign::harness::{self, fig12::Panel, HarnessOpts};
use dla_codesign::model::{refined_ccp, select, AnalyticScorer, GemmDims, MicroKernel};
use dla_codesign::util::cli::Args;

const USAGE: &str = r#"dla — co-design of the dense linear algebra stack (paper reproduction)

USAGE: dla <command> [options]

COMMANDS
  tables              Regenerate Table 1, Table 2 and Figure 6 (left)
  fig6                Figure 6: BLIS occupancy + GFLOPS vs k
  fig9                Figure 9: GEMM variants on Carmel (model) + host (measured)
  fig10 [--parallel]  Figure 10: LU vs b on Carmel (seq / 8-core G4)
  fig11 [--hitratio]  Figure 11: GEMM on EPYC + simulated L2 hit ratio
  fig12 [--panel P]   Figure 12: LU on EPYC; P = seq | g3 | g4 (default all)
  all                 Every experiment above, in paper order
  model               Show CCP selections for --arch/--m/--n/--k [--mk MRxNR]
  select              Run the dynamic selector and print the ranked family
  arch [--arch NAME]  Print an architecture description

OPTIONS
  --arch NAME         carmel | epyc7282 | host | tpu-vmem   (default carmel)
  --mn N              GEMM sweep m = n for measured curves  (default 768)
  --lu-s N            LU order for measured curves          (default 1024)
  --full              Paper-scale sizes (mn=2000, lu-s=4096)
  --smoke             Tiny sizes for CI smoke runs
  --no-measured       Skip wall-clock (host) curves
  --no-modeled        Skip model (Carmel/EPYC) curves
"#;

fn opts_from(args: &Args) -> HarnessOpts {
    let mut o = if args.flag("full") {
        HarnessOpts::full()
    } else if args.flag("smoke") {
        HarnessOpts::smoke()
    } else {
        HarnessOpts::default()
    };
    o.gemm_mn = args.get_usize("mn", o.gemm_mn);
    o.lu_s = args.get_usize("lu-s", o.lu_s);
    if args.flag("no-measured") {
        o.measured = false;
    }
    if args.flag("no-modeled") {
        o.modeled = false;
    }
    o
}

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let opts = opts_from(&args);
    match cmd {
        "tables" => harness::tables::run(),
        "fig6" => harness::fig6::run(&opts),
        "fig9" => harness::fig9::run(&opts),
        "fig10" => harness::fig10::run(&opts, args.flag("parallel")),
        "fig11" => harness::fig11::run(&opts, true),
        "fig12" => match args.get_str("panel", "all") {
            "seq" => harness::fig12::run(&opts, Panel::Sequential),
            "g3" => harness::fig12::run(&opts, Panel::ParallelG3),
            "g4" => harness::fig12::run(&opts, Panel::ParallelG4),
            _ => {
                harness::fig12::run(&opts, Panel::Sequential);
                harness::fig12::run(&opts, Panel::ParallelG3);
                harness::fig12::run(&opts, Panel::ParallelG4);
            }
        },
        "all" => {
            harness::tables::run();
            harness::fig6::run(&opts);
            harness::fig9::run(&opts);
            harness::fig10::run(&opts, false);
            harness::fig10::run(&opts, true);
            harness::fig11::run(&opts, true);
            harness::fig12::run(&opts, Panel::Sequential);
            harness::fig12::run(&opts, Panel::ParallelG3);
            harness::fig12::run(&opts, Panel::ParallelG4);
        }
        "model" => {
            let arch = preset_by_name(args.get_str("arch", "carmel")).expect("unknown arch");
            let dims = GemmDims::new(
                args.get_usize("m", 2000),
                args.get_usize("n", 2000),
                args.get_usize("k", 128),
            );
            let mk_str = args.get_str("mk", "6x8");
            let (mr, nr) = mk_str.split_once('x').expect("--mk like 6x8");
            let mk = MicroKernel::new(mr.parse().unwrap(), nr.parse().unwrap());
            let orig = dla_codesign::model::original_ccp(&arch, mk);
            let refd = refined_ccp(&arch, mk, dims);
            println!("arch: {}", arch.name);
            println!("GEMM {dims}, micro-kernel MK{mk_str}");
            println!("  original model : {orig}");
            println!("  refined model  : {refd}");
        }
        "select" => {
            let arch = preset_by_name(args.get_str("arch", "carmel")).expect("unknown arch");
            let dims = GemmDims::new(
                args.get_usize("m", 2000),
                args.get_usize("n", 2000),
                args.get_usize("k", 128),
            );
            let sel = select(&arch, dims, &AnalyticScorer);
            println!("arch: {} | GEMM {dims}", arch.name);
            println!("chosen: {} (est {:.3} ms)\n", sel.config, sel.est_time_s * 1e3);
            println!("ranked candidates:");
            for (cfg, t) in sel.ranked.iter().take(10) {
                println!("  {:<40} {:>9.3} ms", cfg.to_string(), t * 1e3);
            }
        }
        "arch" => {
            let name = args.get_str("arch", "carmel");
            match preset_by_name(name) {
                Some(a) => {
                    println!("{}", a.name);
                    println!(
                        "  cores: {} | {:.2} GHz | peak {:.1} GFLOPS/core",
                        a.cores,
                        a.freq_ghz,
                        a.peak_gflops_core()
                    );
                    println!("  vector: {} regs x {} bits", a.regs.vector_regs, a.regs.vector_bits);
                    for (i, l) in a.levels.iter().enumerate() {
                        println!(
                            "  L{}: {:>8.0} KiB, {:>2}-way, {}B lines, {} sets, shared by {}",
                            i + 1,
                            l.size_kib(),
                            l.ways,
                            l.line_bytes,
                            l.sets(),
                            l.shared_by
                        );
                    }
                }
                None => println!("unknown arch {name:?}; presets: {}", PRESET_NAMES.join(", ")),
            }
        }
        "help" | "--help" | "-h" => print!("{USAGE}"),
        other => {
            eprintln!("unknown command {other:?}\n");
            print!("{USAGE}");
            std::process::exit(2);
        }
    }
}
