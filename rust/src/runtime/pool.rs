//! Persistent fork-join worker pool for the multi-threaded GEMM runtime.
//!
//! The seed implementation spawned fresh OS threads with
//! `std::thread::scope` inside the innermost `ic` loop of the parallel
//! drivers, so a 4096² LU at b = 256 paid thread-creation cost thousands
//! of times per factorization. Catalán et al. and Buttari et al. (see
//! PAPERS.md) both show that multicore DLA only scales when a persistent
//! worker team is amortized across the whole factorization. This module
//! provides that team:
//!
//! - **Parked workers.** [`WorkerPool::new`] spawns `threads - 1` workers
//!   once; they park on a condvar between jobs. [`WorkerPool::spawned_workers`]
//!   exposes the birth count so tests can assert that running GEMMs
//!   creates zero additional threads.
//! - **Epoch broadcast.** [`WorkerPool::run`] publishes one job (a
//!   `Fn(&PoolCtx)` closure) under a mutex, bumps an epoch counter and
//!   wakes every worker. The caller participates as rank 0, then blocks
//!   until the active-worker count drains to zero. The closure's borrow
//!   lifetime is erased (`transmute` to `'static`, the classic scoped-pool
//!   trick); the completion handshake is what makes that sound — `run`
//!   cannot return while any worker still holds the reference.
//! - **Cooperative-phase barrier.** [`PoolCtx::barrier`] is a reusable
//!   barrier sized to the team. The GEMM drivers use it to separate
//!   *pack* phases (all ranks jointly fill a shared packed buffer) from
//!   *compute* phases (all ranks read it) — the BLIS-style overlap the
//!   paper's §2.2 parallel analysis assumes. Every rank must execute the
//!   same barrier sequence; empty work partitions still hit each barrier.
//! - **Per-worker pinned workspaces.** Each rank owns a
//!   [`Workspace`] (packing buffers) that lives as long as the pool, so
//!   the hot path never allocates and buffers stay warm in that worker's
//!   cache across factorization steps. Rank-private access goes through
//!   [`PoolCtx::workspace`]; the G4 driver instead borrows rank 0's
//!   workspace up front for the team-shared `Ac`/`Bc`.
//!
//! Concurrent `run` calls from different owners of a shared pool (the
//! coordinator server hands one pool to every worker engine) serialize on
//! an internal leader lock, which also keeps the machine from being
//! oversubscribed.
//!
//! # Sub-teams (lookahead)
//!
//! The lookahead-fused LAPACK drivers split one broadcast job into two
//! cooperating halves: a small *panel* team factors the next panel while
//! the *update* team finishes the trailing GEMM columns. [`PoolCtx::split`]
//! partitions the ranks into those two sub-teams, each with its **own
//! reusable barrier** ([`SubTeam::barrier`]) so the teams synchronize
//! internally without ever blocking on each other; the job rejoins at a
//! single full-team [`PoolCtx::barrier`]. The split is per-job state only
//! — nothing persists on the pool, and consecutive jobs may split at
//! different widths (or not at all).
//!
//! # Team groups (batched multi-job epochs)
//!
//! The batched request scheduler goes further: one broadcast executes
//! **N independent jobs** — e.g. N small GEMMs coalesced by the
//! coordinator — by partitioning the ranks into N *groups*, one per
//! batch member. [`PoolCtx::group`] maps this rank to its group
//! (contiguous rank ranges from a shares table every rank passes
//! identically), and each group gets its **own reusable barrier**
//! ([`TeamGroup::barrier`]) so members never synchronize with each
//! other: group `i` can be packing its member's `Bc` while group `j` is
//! deep in its member's compute loop. Like the split, grouping is
//! per-job state only; the pool pre-allocates `threads` group barriers
//! (the maximum useful group count) at construction.
//!
//! # Idle accounting
//!
//! [`WorkerPool::stats`] exposes two pool-idle counters the coordinator
//! metrics surface: `leader_wait_ns` (time the caller spent blocked in
//! `run` after finishing its own rank-0 share, i.e. waiting for the
//! slowest worker) and `idle_ns` (wall time between the end of one job
//! and the start of the next, when every worker is parked). The second is
//! the blind spot lookahead attacks: a factorization that runs `getf2` /
//! `laswp` / TSOLVE between pooled trailing updates leaves the whole team
//! parked for that long, and the fused drivers move that work inside the
//! job.
//!
//! # Fault tolerance (epoch recovery)
//!
//! A panicked job used to be terminal for the caller: the panic was
//! re-thrown out of [`WorkerPool::run`]. The pool now treats a poisoned
//! epoch as *recoverable* — [`WorkerPool::try_run`] catches the unwound
//! panic on every rank, poisons the barriers so no rank blocks forever,
//! drains the completion handshake, `clear_poison`s every barrier,
//! resets the per-worker workspaces (a panicked job may have left a
//! packing buffer half-written), and returns a typed [`EpochError`]
//! naming the first panicking rank and its payload. `run` keeps the old
//! panicking contract for callers that treat a panic as a bug. The
//! [`PoolStats`] counters `epochs_poisoned` / `recoveries` record how
//! often the protocol ran; `runtime::faults` can inject panics and
//! delays at the same hook points the real failures use (`DLA_FAULTS`).

// The serving path must stay panic-free: every unwrap/expect below is
// either allow-listed with a justification or lives in test code.
#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::gemm::blocked::Workspace;
use crate::runtime::faults::FaultState;
use crate::util::error::DlaError;

/// Core-affinity placement for the pool workers (the first step of the
/// ROADMAP NUMA item): pinning each worker at spawn means the pinned
/// [`Workspace`] buffers it grows inside jobs are first-touched on its
/// own core. `DLA_PIN=compact|scatter|none` selects the policy for pools
/// built with [`WorkerPool::new`]; the default is `None` (no pinning —
/// the sandbox and CI hosts often expose a single core).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PinPolicy {
    /// No affinity calls at all.
    #[default]
    None,
    /// Worker rank `r` pins to core `r % cores` (ranks packed onto
    /// adjacent cores; best when the team shares an L2/L3 slice).
    Compact,
    /// Worker rank `r` pins to core `(r * stride) % cores` with
    /// `stride = max(1, cores / team)` (ranks spread across the chip;
    /// best when each wants private cache and memory bandwidth).
    Scatter,
}

impl PinPolicy {
    /// Parse `DLA_PIN`; unset, empty or unknown values mean [`Self::None`].
    pub fn from_env() -> Self {
        match std::env::var("DLA_PIN").ok().as_deref().map(str::trim) {
            Some("compact") => Self::Compact,
            Some("scatter") => Self::Scatter,
            _ => Self::None,
        }
    }

    /// The core a worker of `rank` (in a `threads`-wide team) pins to,
    /// or `None` when the policy disables pinning.
    fn core_for(self, rank: usize, threads: usize, cores: usize) -> Option<usize> {
        if cores == 0 {
            return None;
        }
        match self {
            Self::None => None,
            Self::Compact => Some(rank % cores),
            Self::Scatter => {
                let stride = (cores / threads.max(1)).max(1);
                Some((rank * stride) % cores)
            }
        }
    }
}

/// Pin the calling thread to `core` (Linux only; a no-op elsewhere).
/// Uses the glibc symbol std already links, so no extra dependency.
#[cfg(target_os = "linux")]
fn pin_current_thread(core: usize) {
    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const u64) -> i32;
    }
    const MASK_WORDS: usize = 16; // 1024 CPUs
    let mut mask = [0u64; MASK_WORDS];
    let word = (core / 64) % MASK_WORDS;
    mask[word] |= 1u64 << (core % 64);
    // Best effort: a failure (e.g. a cgroup that excludes the core) just
    // leaves the thread unpinned.
    unsafe {
        sched_setaffinity(0, MASK_WORDS * 8, mask.as_ptr());
    }
}

#[cfg(not(target_os = "linux"))]
fn pin_current_thread(_core: usize) {}

fn apply_pin(policy: PinPolicy, rank: usize, threads: usize) {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if let Some(core) = policy.core_for(rank, threads, cores) {
        pin_current_thread(core);
    }
}

/// The job signature: executed once per rank, in parallel. As a bare
/// type alias the trait object's default lifetime is `'static`, which is
/// exactly what the broadcast slot stores; `run` instead spells its
/// parameter type out so the borrow-lifetime stays flexible.
type Job = dyn Fn(&PoolCtx<'_>) + Sync;

struct State {
    /// Bumped once per broadcast; workers detect new work by comparing
    /// against the last epoch they executed.
    epoch: u64,
    /// The current job. `'static` is a lie told by `run` (see module
    /// docs); never retained past the completion handshake.
    job: Option<&'static Job>,
    /// Workers still executing the current job.
    active: usize,
    /// Set when a worker's job panicked; reported by the leader.
    panicked: bool,
    /// The first panicking worker's (rank, payload) for the typed
    /// [`EpochError`]; cleared by the leader after each poisoned epoch.
    panic_info: Option<(usize, String)>,
    /// Set by `Drop` to retire the team.
    shutdown: bool,
}

/// A broadcast epoch that ended in a caught panic, returned by
/// [`WorkerPool::try_run`] after the pool has fully recovered (barriers
/// drained and un-poisoned, workspaces reset): the *job* failed, the
/// *pool* is ready for the next job. Operand state the job was mutating
/// is unspecified — callers re-run from owned inputs or fail the request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum EpochError {
    /// A worker rank's job share panicked; `rank` is the first panicker.
    WorkerPanic { rank: usize, message: String },
    /// The caller's own rank-0 share panicked (reported instead of
    /// re-thrown so one bad request cannot unwind a serving thread).
    LeaderPanic { message: String },
}

impl std::fmt::Display for EpochError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EpochError::WorkerPanic { rank, message } => {
                write!(f, "pool worker rank {rank} panicked: {message}")
            }
            EpochError::LeaderPanic { message } => {
                write!(f, "pool leader (rank 0) panicked: {message}")
            }
        }
    }
}

impl std::error::Error for EpochError {}

impl EpochError {
    /// The panic payload rendered by [`DlaError::panic_reason`].
    pub fn message(&self) -> &str {
        match self {
            EpochError::WorkerPanic { message, .. } | EpochError::LeaderPanic { message } => message,
        }
    }
}

struct Shared {
    state: Mutex<State>,
    work_cv: Condvar,
    done_cv: Condvar,
    barrier: PoolBarrier,
    /// Independent barriers for the two sub-teams of a split job
    /// (index 0: panel team, index 1: update team). Sized at wait time
    /// (`wait_n`) because the split width is chosen per job.
    sub_barriers: [PoolBarrier; 2],
    /// Independent barriers for the groups of a batched multi-job epoch
    /// (one per possible group, i.e. `threads` of them). Sized at wait
    /// time (`wait_n`) because the group widths are job parameters.
    group_barriers: Vec<PoolBarrier>,
    births: AtomicUsize,
    /// Completed broadcast jobs.
    jobs: AtomicU64,
    /// Nanoseconds the leader spent in `run`'s completion handshake after
    /// finishing its own rank-0 work (waiting for the slowest worker).
    leader_wait_ns: AtomicU64,
    /// Nanoseconds between the end of one job and the start of the next
    /// (the whole team parked; the classic factorization serial section).
    idle_ns: AtomicU64,
    /// Rank-nanoseconds the *panel* sub-team of split jobs spent blocked
    /// at the rejoin barrier (its panel work finished before the trailing
    /// sweep did). See [`PoolCtx::rejoin_timed`].
    panel_idle_ns: AtomicU64,
    /// Rank-nanoseconds the *update* sub-team spent blocked at the rejoin
    /// barrier (the trailing sweep finished before the panel work).
    update_idle_ns: AtomicU64,
    /// Rank-nanoseconds panel-team ranks spent at the rejoin barrier of
    /// jobs whose panel queue was **empty** (nothing left to factor ahead
    /// — the lookahead pipeline's ramp-down stall).
    queue_stall_ns: AtomicU64,
    /// Bytes zero-filled into the pinned per-worker [`Workspace`] buffers
    /// at spawn (the NUMA first-touch; see [`prefault_workspace`]).
    prefaulted_bytes: AtomicU64,
    /// Broadcast epochs that ended in a caught panic (injected or real).
    epochs_poisoned: AtomicU64,
    /// Poisoned epochs fully recovered from (barriers cleared, workspaces
    /// reset, a typed error returned); equals `epochs_poisoned` unless a
    /// recovery is in flight.
    recoveries: AtomicU64,
    /// Tile tasks executed by the DAG scheduler (`runtime/dag.rs`),
    /// summed over ranks and drains.
    dag_tasks: AtomicU64,
    /// Successful steals: tasks a rank took FIFO from another rank's
    /// deque because its own was empty.
    dag_steals: AtomicU64,
    /// Failed steal probes (victim deque empty at inspection) — the DAG
    /// path's idle metric, counted per probe rather than in wall time.
    dag_steal_fails: AtomicU64,
    /// High-water mark of any single rank's deque depth (fetch_max),
    /// bounding the scheduler's ready-queue memory footprint.
    dag_deque_high_water: AtomicU64,
    /// Armed fault-injection plan (`DLA_FAULTS` or an explicit plan);
    /// `None` costs one branch per job.
    faults: Option<Arc<FaultState>>,
    /// End of the most recent job, for the idle-gap accounting.
    last_job_end: Mutex<Option<Instant>>,
    workspaces: Vec<Mutex<Workspace>>,
}

/// Elements zero-filled into each packing buffer of a pinned per-worker
/// [`Workspace`] at spawn: 1 MiB per buffer, enough to cover a typical
/// `Ac`/`Bc` footprint so steady-state jobs touch pre-faulted pages.
const PREFAULT_ELEMS: usize = 1 << 17;

/// First-touch a workspace's packing buffers **on the calling thread**
/// (the zero-fill write is what places the pages on the toucher's NUMA
/// node under first-touch placement). Workers call this right after
/// pinning, before their first job, closing the ROADMAP remnant where
/// the buffers were first-touched lazily inside the first job. Returns
/// the bytes touched; [`Workspace::ensure`] never shrinks, so the
/// placement persists for the pool's lifetime.
fn prefault_workspace(ws: &mut Workspace) -> u64 {
    if ws.a_buf.len() < PREFAULT_ELEMS {
        ws.a_buf.resize(PREFAULT_ELEMS, 0.0);
    }
    if ws.b_buf.len() < PREFAULT_ELEMS {
        ws.b_buf.resize(PREFAULT_ELEMS, 0.0);
    }
    (8 * (ws.a_buf.len() + ws.b_buf.len())) as u64
}

/// Lock, shrugging off poison: a panicked job is re-thrown by the leader,
/// and the pool must stay usable afterwards (the protected state is a
/// plain broadcast slot / packing buffer, always left consistent).
fn lock_pool<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// The panic message of ranks killed by a *poisoned barrier* (as opposed
/// to the rank whose job actually failed): a symptom, not a root cause,
/// so the epoch-error reporting prefers any other payload over it.
const POISON_ECHO: &str = "pool barrier poisoned by a panicked rank";

/// A reusable barrier with **poisoning**: when any rank's job panics, the
/// rank poisons the barrier before reporting done, which wakes every
/// waiter and makes it panic too (instead of blocking forever for an
/// arrival that can never come — `std::sync::Barrier` has no such
/// escape). The cascading panics are caught per-rank, the completion
/// handshake drains normally, and the leader re-throws once.
struct PoolBarrier {
    lock: Mutex<BarrierState>,
    cv: Condvar,
    count: usize,
}

struct BarrierState {
    arrived: usize,
    generation: u64,
    poisoned: bool,
}

impl PoolBarrier {
    fn new(count: usize) -> Self {
        Self {
            lock: Mutex::new(BarrierState { arrived: 0, generation: 0, poisoned: false }),
            cv: Condvar::new(),
            count,
        }
    }

    fn wait(&self) {
        self.wait_n(self.count);
    }

    /// Wait for `count` arrivals instead of the constructed team size —
    /// the sub-team barriers are sized per job (the split width is a job
    /// parameter), so every participant passes the (identical) sub-team
    /// size at wait time.
    fn wait_n(&self, count: usize) {
        let mut st = lock_pool(&self.lock);
        if st.poisoned {
            panic!("{}", POISON_ECHO);
        }
        let gen = st.generation;
        st.arrived += 1;
        if st.arrived == count {
            st.arrived = 0;
            st.generation += 1;
            self.cv.notify_all();
            return;
        }
        while st.generation == gen && !st.poisoned {
            st = self.cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        if st.poisoned {
            panic!("{}", POISON_ECHO);
        }
    }

    /// Whether a panicked rank has poisoned this barrier (polled by the
    /// barrier-free DAG drain, which otherwise never observes a peer's
    /// death).
    fn is_poisoned(&self) -> bool {
        lock_pool(&self.lock).poisoned
    }

    /// Wake every waiter with a panic; idempotent.
    fn poison(&self) {
        let mut st = lock_pool(&self.lock);
        st.poisoned = true;
        self.cv.notify_all();
    }

    /// Reset after a poisoned job has fully drained (leader-only, called
    /// once `active == 0`, so no rank can be inside `wait`).
    fn clear_poison(&self) {
        let mut st = lock_pool(&self.lock);
        st.poisoned = false;
        st.arrived = 0;
        st.generation += 1;
    }
}

/// Per-rank execution context handed to every job invocation.
pub struct PoolCtx<'p> {
    /// This participant's rank in `0..threads` (rank 0 is the caller).
    pub rank: usize,
    /// Team size (pool threads, including the caller).
    pub threads: usize,
    shared: &'p Shared,
}

impl<'p> PoolCtx<'p> {
    /// Wait until every rank of the team reaches this point. Reusable;
    /// all ranks must call it the same number of times per job.
    pub fn barrier(&self) {
        if self.threads > 1 {
            self.shared.barrier.wait();
        }
    }

    /// Lock this rank's pinned workspace (uncontended: each rank only
    /// ever locks its own index).
    pub fn workspace(&self) -> MutexGuard<'p, Workspace> {
        lock_pool(&self.shared.workspaces[self.rank])
    }

    /// The rejoin barrier of a split job, with per-phase idle accounting:
    /// this rank's wait time is attributed to its sub-team — panel-team
    /// waits count as `panel_idle_ns` (or `queue_stall_ns` when the
    /// caller flags that the panel queue was empty, i.e. the panel team
    /// had nothing to factor ahead), update-team waits as
    /// `update_idle_ns`. All counters are **rank-nanoseconds** (summed
    /// over ranks). Synchronization-equivalent to [`PoolCtx::barrier`];
    /// every rank of the job must call it the same way.
    pub fn rejoin_timed(&self, sub: &SubTeam<'_>, queue_empty: bool) {
        if self.threads <= 1 {
            return;
        }
        let t0 = Instant::now();
        self.shared.barrier.wait();
        let waited = t0.elapsed().as_nanos() as u64;
        let slot = if sub.panel {
            if queue_empty {
                &self.shared.queue_stall_ns
            } else {
                &self.shared.panel_idle_ns
            }
        } else {
            &self.shared.update_idle_ns
        };
        slot.fetch_add(waited, Ordering::Relaxed);
    }

    /// Whether this job's team barrier has been poisoned by a panicked
    /// rank. The DAG drain (`runtime/dag.rs`) never blocks on barriers,
    /// so its idle ranks poll this instead: a rank that dies *outside*
    /// any tile task leaves the graph's task count stuck, and the poison
    /// it sets on the way out is the survivors' only exit signal.
    pub fn job_poisoned(&self) -> bool {
        self.shared.barrier.is_poisoned()
    }

    /// Fold one rank's DAG-drain tallies into the pool counters: tasks
    /// executed, successful steals, failed steal probes, and this rank's
    /// deque high-water mark (merged with `fetch_max` so the pool-level
    /// figure is the max over ranks and drains). Called once per rank at
    /// the end of a `runtime/dag.rs` drain — per-task atomics on the hot
    /// path would serialize the very stalls the scheduler removes.
    pub fn note_dag_stats(&self, tasks: u64, steals: u64, steal_fails: u64, high_water: u64) {
        self.shared.dag_tasks.fetch_add(tasks, Ordering::Relaxed);
        self.shared.dag_steals.fetch_add(steals, Ordering::Relaxed);
        self.shared.dag_steal_fails.fetch_add(steal_fails, Ordering::Relaxed);
        self.shared.dag_deque_high_water.fetch_max(high_water, Ordering::Relaxed);
    }

    /// Partition the team into contiguous *groups* — one per entry of
    /// `shares`, entry `i` taking the next `shares[i]` ranks — and return
    /// this rank's group. The batched multi-GEMM driver uses one group
    /// per coalesced request; each group has an independent reusable
    /// barrier so groups never block on each other.
    ///
    /// Every rank of the job must call this with the same `shares`;
    /// entries must be positive and sum to exactly `threads`.
    pub fn group(&self, shares: &[usize]) -> TeamGroup<'p> {
        assert!(!shares.is_empty(), "empty shares table");
        let mut lo = 0;
        for (index, &share) in shares.iter().enumerate() {
            assert!(share > 0, "group {index} has no ranks");
            if self.rank < lo + share {
                return TeamGroup {
                    index,
                    rank: self.rank - lo,
                    threads: share,
                    barrier: &self.shared.group_barriers[index],
                };
            }
            lo += share;
        }
        panic!(
            "shares {:?} sum to {} but the team is {} wide",
            shares,
            shares.iter().sum::<usize>(),
            self.threads
        );
    }

    /// Split the team into a *panel* sub-team (ranks `< panel_workers`,
    /// leader included) and an *update* sub-team (the rest), each with an
    /// independent reusable barrier. Every rank of the job must call this
    /// with the same `panel_workers`, and the two halves must not
    /// `PoolCtx::barrier` until both have finished their sub-team work
    /// (the rejoin). `panel_workers` is clamped to `[1, threads - 1]` so
    /// both sub-teams are non-empty whenever `threads > 1`.
    pub fn split(&self, panel_workers: usize) -> SubTeam<'p> {
        let t_p = panel_workers.clamp(1, self.threads.saturating_sub(1).max(1));
        if self.rank < t_p {
            SubTeam {
                panel: true,
                rank: self.rank,
                threads: t_p.min(self.threads),
                barrier: Some(&self.shared.sub_barriers[0]),
            }
        } else {
            SubTeam {
                panel: false,
                rank: self.rank - t_p,
                threads: self.threads - t_p,
                barrier: Some(&self.shared.sub_barriers[1]),
            }
        }
    }
}

/// One group of a batched multi-job epoch (see [`PoolCtx::group`]):
/// group index, group-local rank and size, plus a barrier private to this
/// group.
pub struct TeamGroup<'p> {
    /// Which `shares` entry this group corresponds to.
    pub index: usize,
    /// Rank within the group, `0..threads`.
    pub rank: usize,
    /// Group size.
    pub threads: usize,
    barrier: &'p PoolBarrier,
}

impl TeamGroup<'_> {
    /// Wait until every rank of **this group** reaches this point.
    /// Independent of every other group and of the full-team barrier.
    pub fn barrier(&self) {
        if self.threads > 1 {
            self.barrier.wait_n(self.threads);
        }
    }
}

/// One half of a split team (see [`PoolCtx::split`]): sub-team-local rank
/// and size plus a barrier private to this half.
pub struct SubTeam<'p> {
    /// True for the panel sub-team, false for the update sub-team.
    pub panel: bool,
    /// Rank within the sub-team, `0..threads`.
    pub rank: usize,
    /// Sub-team size.
    pub threads: usize,
    barrier: Option<&'p PoolBarrier>,
}

impl SubTeam<'_> {
    /// A degenerate one-rank panel team, used by the sequential fallback
    /// paths (no pool, or a single-thread pool) so panel tasks run
    /// identically with zero synchronization.
    pub fn solo_panel() -> SubTeam<'static> {
        SubTeam { panel: true, rank: 0, threads: 1, barrier: None }
    }

    /// Wait until every rank of **this sub-team** reaches this point.
    /// Independent of the other sub-team and of the full-team barrier.
    pub fn barrier(&self) {
        if self.threads > 1 {
            if let Some(b) = self.barrier {
                b.wait_n(self.threads);
            }
        }
    }
}

/// Pool idle-time accounting (see the module docs): cumulative since pool
/// construction, taken with [`WorkerPool::stats`].
///
/// The epoch boundary these counters are keyed on — `run` entered,
/// leader handshake completed — is also the measurement quantum of the
/// calibration layer: a calibrated engine wraps exactly one dispatch
/// (one pool epoch, or its sequential equivalent) per timing sample it
/// feeds to [`crate::model::PerfProfile`], so the measured seconds line
/// up one-to-one with the `jobs` counter here and no timing hook ever
/// reaches inside an epoch. Epoch recovery after a poisoned job runs
/// *before* the dispatch returns, so a panicking epoch never records a
/// sample at all (the unwinding dispatch skips the hook) and the store
/// cannot absorb a corrupted timing.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    /// Completed broadcast jobs.
    pub jobs: u64,
    /// Leader time blocked in the completion handshake (its own work done,
    /// waiting for the slowest worker), in nanoseconds.
    pub leader_wait_ns: u64,
    /// Wall time between jobs — the whole team parked — in nanoseconds.
    pub idle_ns: u64,
    /// Rank-nanoseconds panel-team ranks waited at split-job rejoins with
    /// panel work done (the update sweep was the long pole).
    pub panel_idle_ns: u64,
    /// Rank-nanoseconds update-team ranks waited at split-job rejoins
    /// (the panel critical path was the long pole).
    pub update_idle_ns: u64,
    /// Rank-nanoseconds panel-team ranks waited at rejoins of jobs whose
    /// panel queue was empty (lookahead ramp-down: nothing to factor).
    pub queue_stall_ns: u64,
    /// Bytes of pinned per-worker workspace zero-filled at spawn (the
    /// NUMA first-touch; grows as each worker starts, constant after the
    /// first completed job).
    pub prefaulted_bytes: u64,
    /// Broadcast epochs that ended in a caught panic.
    pub epochs_poisoned: u64,
    /// Poisoned epochs fully recovered from (drained, barriers cleared,
    /// workspaces reset, typed error returned).
    pub recoveries: u64,
    /// Tile tasks executed by the DAG scheduler (all ranks, all drains).
    pub dag_tasks: u64,
    /// Successful FIFO steals from other ranks' deques.
    pub dag_steals: u64,
    /// Failed steal probes (victim empty) — DAG idle, counted per probe.
    pub dag_steal_fails: u64,
    /// High-water mark of any single rank's deque depth.
    pub dag_deque_high_water: u64,
}

/// A persistent team of `threads - 1` parked workers plus the caller.
pub struct WorkerPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    /// Serializes `run` calls (a shared pool may have several owners).
    run_lock: Mutex<()>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn the team with the affinity policy from the `DLA_PIN`
    /// environment variable (default: no pinning) and the fault plan
    /// from `DLA_FAULTS` (default: none). `threads` counts the caller,
    /// so `new(1)` spawns nothing and `run` executes jobs inline.
    pub fn new(threads: usize) -> Self {
        Self::build(threads, PinPolicy::from_env(), FaultState::from_env())
    }

    /// Spawn the team with an explicit [`PinPolicy`]. Each worker pins
    /// itself as the very first thing it does, before touching its
    /// workspace, so buffer growth inside jobs is first-touched on the
    /// pinned core. The caller (rank 0) is never pinned — it is the
    /// application's thread.
    pub fn with_pinning(threads: usize, pin: PinPolicy) -> Self {
        Self::build(threads, pin, FaultState::from_env())
    }

    /// Spawn the team with an explicit (already armed) fault-injection
    /// state, shared with the caller — the chaos tests and the server
    /// inject faults programmatically this way, independent of the
    /// environment. `None` disables injection even if `DLA_FAULTS` is
    /// set.
    pub fn with_fault_state(threads: usize, faults: Option<Arc<FaultState>>) -> Self {
        Self::build(threads, PinPolicy::from_env(), faults)
    }

    fn build(threads: usize, pin: PinPolicy, faults: Option<Arc<FaultState>>) -> Self {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State {
                epoch: 0,
                job: None,
                active: 0,
                panicked: false,
                panic_info: None,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            barrier: PoolBarrier::new(threads),
            sub_barriers: [PoolBarrier::new(threads), PoolBarrier::new(threads)],
            group_barriers: (0..threads).map(|_| PoolBarrier::new(threads)).collect(),
            births: AtomicUsize::new(0),
            jobs: AtomicU64::new(0),
            leader_wait_ns: AtomicU64::new(0),
            idle_ns: AtomicU64::new(0),
            panel_idle_ns: AtomicU64::new(0),
            update_idle_ns: AtomicU64::new(0),
            queue_stall_ns: AtomicU64::new(0),
            prefaulted_bytes: AtomicU64::new(0),
            epochs_poisoned: AtomicU64::new(0),
            recoveries: AtomicU64::new(0),
            dag_tasks: AtomicU64::new(0),
            dag_steals: AtomicU64::new(0),
            dag_steal_fails: AtomicU64::new(0),
            dag_deque_high_water: AtomicU64::new(0),
            faults,
            last_job_end: Mutex::new(None),
            workspaces: (0..threads).map(|_| Mutex::new(Workspace::new())).collect(),
        });
        // Rank 0 is the caller's thread: first-touch its workspace here,
        // synchronously. Workers touch their own right after pinning.
        {
            let mut ws0 = lock_pool(&shared.workspaces[0]);
            let bytes = prefault_workspace(&mut ws0);
            shared.prefaulted_bytes.fetch_add(bytes, Ordering::Relaxed);
        }
        let mut handles = Vec::with_capacity(threads - 1);
        for rank in 1..threads {
            let sh = Arc::clone(&shared);
            // Allow-listed: failing to spawn an OS thread at pool
            // construction is unrecoverable setup, not a serving fault.
            #[allow(clippy::expect_used)]
            let h = std::thread::Builder::new()
                .name(format!("gemm-pool-{rank}"))
                .spawn(move || worker_loop(sh, rank, pin))
                .expect("spawning pool worker");
            handles.push(h);
        }
        Self { shared, handles, run_lock: Mutex::new(()), threads }
    }

    /// Team size, including the caller.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Total worker threads ever spawned by this pool. Constant
    /// (`threads - 1`) after the first completed job; the regression
    /// tests assert it stays constant across arbitrarily many GEMMs.
    pub fn spawned_workers(&self) -> usize {
        self.shared.births.load(Ordering::SeqCst)
    }

    /// Lock a rank's pinned workspace from outside a job (the G4 driver
    /// borrows rank 0's workspace for the team-shared packed buffers).
    ///
    /// Do not hold the rank-r guard while a job calls
    /// `PoolCtx::workspace` on the same rank — that would self-deadlock.
    pub fn workspace(&self, rank: usize) -> MutexGuard<'_, Workspace> {
        lock_pool(&self.shared.workspaces[rank])
    }

    /// Cumulative pool idle accounting (jobs run, leader drain-wait,
    /// between-job parked time). Atomic snapshot-free reads: counters are
    /// monotone and only advanced by completed jobs.
    pub fn stats(&self) -> PoolStats {
        PoolStats {
            jobs: self.shared.jobs.load(Ordering::Relaxed),
            leader_wait_ns: self.shared.leader_wait_ns.load(Ordering::Relaxed),
            idle_ns: self.shared.idle_ns.load(Ordering::Relaxed),
            panel_idle_ns: self.shared.panel_idle_ns.load(Ordering::Relaxed),
            update_idle_ns: self.shared.update_idle_ns.load(Ordering::Relaxed),
            queue_stall_ns: self.shared.queue_stall_ns.load(Ordering::Relaxed),
            prefaulted_bytes: self.shared.prefaulted_bytes.load(Ordering::Relaxed),
            epochs_poisoned: self.shared.epochs_poisoned.load(Ordering::Relaxed),
            recoveries: self.shared.recoveries.load(Ordering::Relaxed),
            dag_tasks: self.shared.dag_tasks.load(Ordering::Relaxed),
            dag_steals: self.shared.dag_steals.load(Ordering::Relaxed),
            dag_steal_fails: self.shared.dag_steal_fails.load(Ordering::Relaxed),
            dag_deque_high_water: self.shared.dag_deque_high_water.load(Ordering::Relaxed),
        }
    }

    /// The armed fault-injection state, if any (shared with the server
    /// that owns this pool so admission hooks see the same counters).
    pub fn fault_state(&self) -> Option<Arc<FaultState>> {
        self.shared.faults.clone()
    }

    /// Record the idle gap since the previous job ended and stamp the new
    /// job start; called with the leader lock held.
    fn note_job_start(&self, now: Instant) {
        let last = lock_pool(&self.shared.last_job_end);
        if let Some(end) = *last {
            let gap = now.saturating_duration_since(end).as_nanos() as u64;
            self.shared.idle_ns.fetch_add(gap, Ordering::Relaxed);
        }
    }

    fn note_job_end(&self) {
        *lock_pool(&self.shared.last_job_end) = Some(Instant::now());
        self.shared.jobs.fetch_add(1, Ordering::Relaxed);
    }

    /// Clear every barrier's poison after a drained epoch (leader-only,
    /// `active == 0`: no rank can be inside a `wait`).
    fn clear_all_poison(&self) {
        self.shared.barrier.clear_poison();
        for b in &self.shared.sub_barriers {
            b.clear_poison();
        }
        for b in &self.shared.group_barriers {
            b.clear_poison();
        }
    }

    /// Reset every rank's pinned workspace after a poisoned epoch: a
    /// panicked job may have left packing buffers half-written or
    /// oversized, and the next job must start from the same state a
    /// fresh pool would. The buffers are re-prefaulted on the leader
    /// (placement is best-effort during recovery); the spawn-time
    /// `prefaulted_bytes` accounting is deliberately not touched — it
    /// records the first-touch, not resets.
    fn reset_workspaces(&self) {
        for slot in &self.shared.workspaces {
            let mut ws = lock_pool(slot);
            *ws = Workspace::new();
            let _ = prefault_workspace(&mut ws);
        }
    }

    /// Execute `job` once per rank (the caller runs rank 0 in place) and
    /// return when every rank has finished. A panic on any rank is
    /// re-thrown here — callers that must survive a bad job use
    /// [`Self::try_run`] instead; the pool itself recovers either way.
    pub fn run(&self, job: &(dyn Fn(&PoolCtx<'_>) + Sync)) {
        if let Err(e) = self.try_run(job) {
            match e {
                // Re-throw with the original message as the payload so
                // `#[should_panic(expected = ...)]` callers still match.
                EpochError::LeaderPanic { message } => {
                    std::panic::resume_unwind(Box::new(message))
                }
                EpochError::WorkerPanic { .. } => {
                    panic!("a pool worker panicked during a broadcast job")
                }
            }
        }
    }

    /// Execute `job` once per rank and return `Err` instead of
    /// panicking when any rank's share panics. By the time this returns
    /// the epoch has fully drained and the pool is recovered: barriers
    /// un-poisoned, workspaces reset, counters advanced — the next
    /// `run`/`try_run` behaves as on a fresh pool. Whatever operand
    /// memory the job was mutating is left in an unspecified state.
    pub fn try_run(&self, job: &(dyn Fn(&PoolCtx<'_>) + Sync)) -> Result<(), EpochError> {
        let _leader = lock_pool(&self.run_lock);
        self.note_job_start(Instant::now());
        if self.threads == 1 {
            // Inline path: still bump the epoch (fault shots key on it)
            // and still isolate the panic.
            let epoch = {
                let mut st = lock_pool(&self.shared.state);
                st.epoch += 1;
                st.epoch
            };
            let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if let Some(f) = &self.shared.faults {
                    f.before_job(0, epoch);
                }
                let ctx = PoolCtx { rank: 0, threads: 1, shared: self.shared.as_ref() };
                job(&ctx);
            }));
            self.note_job_end();
            return match result {
                Ok(()) => Ok(()),
                Err(payload) => {
                    self.shared.epochs_poisoned.fetch_add(1, Ordering::Relaxed);
                    self.reset_workspaces();
                    self.shared.recoveries.fetch_add(1, Ordering::Relaxed);
                    Err(EpochError::LeaderPanic { message: DlaError::panic_reason(payload.as_ref()) })
                }
            };
        }
        // SAFETY: the 'static lifetime is erased only for the duration of
        // this call; the done_cv handshake below guarantees every worker
        // has returned from `job` (and the state lock round-trip makes
        // that a happens-before edge) before `try_run` returns and the
        // borrow expires. The leader's own share runs under catch_unwind
        // for the same reason: this frame must never unwind while a
        // worker still holds the reference.
        let job_static: &'static Job =
            unsafe { std::mem::transmute::<&(dyn Fn(&PoolCtx<'_>) + Sync), &'static Job>(job) };
        let epoch = {
            let mut st = lock_pool(&self.shared.state);
            st.job = Some(job_static);
            st.active = self.threads - 1;
            st.epoch += 1;
            self.shared.work_cv.notify_all();
            st.epoch
        };
        // On a leader panic the barriers are poisoned so no worker can
        // block waiting for rank 0's arrival; the handshake then drains.
        let leader_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Some(f) = &self.shared.faults {
                f.before_job(0, epoch);
            }
            let ctx = PoolCtx { rank: 0, threads: self.threads, shared: self.shared.as_ref() };
            job(&ctx);
        }));
        if leader_result.is_err() {
            self.shared.barrier.poison();
            for b in &self.shared.sub_barriers {
                b.poison();
            }
            for b in &self.shared.group_barriers {
                b.poison();
            }
        }
        let wait_t0 = Instant::now();
        let mut st = lock_pool(&self.shared.state);
        while st.active > 0 {
            st = self
                .shared
                .done_cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
        st.job = None;
        let worker_panicked = st.panicked;
        st.panicked = false;
        let worker_info = st.panic_info.take();
        drop(st);
        self.shared
            .leader_wait_ns
            .fetch_add(wait_t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        self.note_job_end();
        if !(worker_panicked || leader_result.is_err()) {
            return Ok(());
        }
        // Recovery: every rank is out of the job (active == 0), so no
        // one can be parked inside a barrier — clear the poison, reset
        // the workspaces the dead job may have corrupted, and report.
        self.shared.epochs_poisoned.fetch_add(1, Ordering::Relaxed);
        self.clear_all_poison();
        self.reset_workspaces();
        self.shared.recoveries.fetch_add(1, Ordering::Relaxed);
        Err(match leader_result {
            Err(payload) => {
                let message = DlaError::panic_reason(payload.as_ref());
                match worker_info {
                    // The leader died *because* a worker poisoned the
                    // barrier it was parked on: report the root cause.
                    Some((rank, root)) if message == POISON_ECHO => {
                        EpochError::WorkerPanic { rank, message: root }
                    }
                    _ => EpochError::LeaderPanic { message },
                }
            }
            Ok(()) => {
                let (rank, message) = worker_info
                    .unwrap_or_else(|| (usize::MAX, "panicked rank left no payload".to_string()));
                EpochError::WorkerPanic { rank, message }
            }
        })
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = lock_pool(&self.shared.state);
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>, rank: usize, pin: PinPolicy) {
    let threads = shared.workspaces.len();
    apply_pin(pin, rank, threads);
    // First-touch the pinned workspace *after* pinning and *before* the
    // first job, so the pages land on this worker's core/node.
    {
        let mut ws = lock_pool(&shared.workspaces[rank]);
        let bytes = prefault_workspace(&mut ws);
        shared.prefaulted_bytes.fetch_add(bytes, Ordering::Relaxed);
    }
    shared.births.fetch_add(1, Ordering::SeqCst);
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = lock_pool(&shared.state);
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    // Allow-listed: a bumped epoch without a published
                    // job is a broken broadcast invariant (pool bug),
                    // not a request-path failure.
                    #[allow(clippy::expect_used)]
                    break st.job.expect("epoch bumped without a job");
                }
                st = shared.work_cv.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
            }
        };
        let result = {
            let ctx = PoolCtx { rank, threads, shared: shared.as_ref() };
            // The fault hook runs inside catch_unwind so an injected
            // panic unwinds through exactly the real-failure machinery.
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                if let Some(f) = &shared.faults {
                    f.before_job(rank, seen);
                }
                job(&ctx)
            }))
        };
        if result.is_err() {
            // Wake (and panic out) any rank blocked on a barrier arrival
            // this rank will never make; the cascade drains the job. The
            // sub-team and group barriers are poisoned too — a split or
            // grouped job may have ranks parked on any of them.
            shared.barrier.poison();
            for b in &shared.sub_barriers {
                b.poison();
            }
            for b in &shared.group_barriers {
                b.poison();
            }
        }
        let mut st = lock_pool(&shared.state);
        if let Err(payload) = result {
            st.panicked = true;
            // Record the root cause: the first panicker wins, except
            // that a barrier-poison echo never displaces (and is itself
            // displaced by) a real payload — drain order between the
            // root rank and the ranks its poison woke is a race.
            let msg = DlaError::panic_reason(payload.as_ref());
            let displace = match &st.panic_info {
                None => true,
                Some((_, existing)) => existing == POISON_ECHO && msg != POISON_ECHO,
            };
            if displace {
                st.panic_info = Some((rank, msg));
            }
        }
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_one();
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn broadcast_reaches_every_rank_exactly_once() {
        let pool = WorkerPool::new(4);
        let mask = AtomicU64::new(0);
        pool.run(&|ctx| {
            mask.fetch_or(1 << ctx.rank, Ordering::SeqCst);
            assert_eq!(ctx.threads, 4);
        });
        assert_eq!(mask.load(Ordering::SeqCst), 0b1111);
    }

    #[test]
    fn workers_spawn_once_across_many_jobs() {
        let pool = WorkerPool::new(3);
        let hits = AtomicU64::new(0);
        for _ in 0..10 {
            pool.run(&|_ctx| {
                hits.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(hits.load(Ordering::SeqCst), 30);
        // Births are complete once a job has finished (every worker must
        // have executed it), and never grow again.
        assert_eq!(pool.spawned_workers(), 2);
    }

    #[test]
    fn barrier_separates_phases() {
        let pool = WorkerPool::new(4);
        let phase1 = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
        let sums = Mutex::new(Vec::new());
        pool.run(&|ctx| {
            phase1[ctx.rank].store(ctx.rank as u64 + 1, Ordering::SeqCst);
            ctx.barrier();
            // After the barrier every rank must observe all writes.
            let total: u64 = phase1.iter().map(|a| a.load(Ordering::SeqCst)).sum();
            sums.lock().unwrap().push(total);
        });
        let sums = sums.into_inner().unwrap();
        assert_eq!(sums.len(), 4);
        assert!(sums.iter().all(|&s| s == 1 + 2 + 3 + 4), "{sums:?}");
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        let hits = AtomicU64::new(0);
        pool.run(&|ctx| {
            assert_eq!((ctx.rank, ctx.threads), (0, 1));
            ctx.barrier(); // no-op, must not block
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 1);
        assert_eq!(pool.spawned_workers(), 0);
    }

    #[test]
    fn per_rank_workspaces_are_distinct_and_persistent() {
        let pool = WorkerPool::new(3);
        pool.run(&|ctx| {
            let mut ws = ctx.workspace();
            ws.a_buf.resize(ctx.rank + 1, 0.0);
        });
        pool.run(&|ctx| {
            let ws = ctx.workspace();
            assert_eq!(ws.a_buf.len(), ctx.rank + 1, "workspace must persist per rank");
        });
    }

    #[test]
    fn worker_panic_is_propagated_to_the_leader() {
        let pool = WorkerPool::new(2);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|ctx| {
                if ctx.rank == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err());
        // The pool survives and runs subsequent jobs.
        let ok = AtomicU64::new(0);
        pool.run(&|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn panic_in_a_barrier_job_poisons_instead_of_deadlocking() {
        // Without barrier poisoning this test would hang forever: ranks
        // 0 and 1 would wait for an arrival rank 2 can never make.
        let pool = WorkerPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|ctx| {
                if ctx.rank == 2 {
                    panic!("die before the barrier");
                }
                ctx.barrier();
            });
        }));
        assert!(result.is_err());
        // The barrier is clean again: a multi-barrier job completes.
        let hits = AtomicU64::new(0);
        pool.run(&|ctx| {
            ctx.barrier();
            hits.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn split_teams_have_local_ranks_and_independent_barriers() {
        let pool = WorkerPool::new(4);
        let panel_mask = AtomicU64::new(0);
        let update_mask = AtomicU64::new(0);
        let panel_sum = AtomicU64::new(0);
        pool.run(&|ctx| {
            let sub = ctx.split(2);
            if sub.panel {
                assert_eq!(sub.threads, 2);
                panel_mask.fetch_or(1 << sub.rank, Ordering::SeqCst);
                // Sub-team barrier must release with only the panel
                // ranks arriving (the update team never touches it).
                panel_sum.fetch_add(sub.rank as u64 + 1, Ordering::SeqCst);
                sub.barrier();
                assert_eq!(panel_sum.load(Ordering::SeqCst), 3);
                sub.barrier();
            } else {
                assert_eq!(sub.threads, 2);
                update_mask.fetch_or(1 << sub.rank, Ordering::SeqCst);
                sub.barrier();
                sub.barrier();
            }
            ctx.barrier(); // rejoin
        });
        assert_eq!(panel_mask.load(Ordering::SeqCst), 0b11);
        assert_eq!(update_mask.load(Ordering::SeqCst), 0b11);
    }

    #[test]
    fn split_clamps_panel_width() {
        let pool = WorkerPool::new(3);
        let panel_count = AtomicU64::new(0);
        pool.run(&|ctx| {
            // Asking for more panel workers than threads-1 must still
            // leave a non-empty update team.
            let sub = ctx.split(16);
            if sub.panel {
                panel_count.fetch_add(1, Ordering::SeqCst);
            }
            sub.barrier();
            ctx.barrier();
        });
        assert_eq!(panel_count.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn solo_panel_subteam_is_inert() {
        let sub = SubTeam::solo_panel();
        assert!(sub.panel);
        assert_eq!((sub.rank, sub.threads), (0, 1));
        sub.barrier(); // must not block
    }

    #[test]
    fn stats_count_jobs_and_idle_gaps() {
        let pool = WorkerPool::new(2);
        let s0 = pool.stats();
        // No jobs yet; only the spawn-time workspace prefault shows up.
        assert_eq!((s0.jobs, s0.leader_wait_ns, s0.idle_ns), (0, 0, 0));
        assert!(s0.prefaulted_bytes > 0, "rank 0 prefault is synchronous: {s0:?}");
        pool.run(&|_| {});
        let s1 = pool.stats();
        assert_eq!(s1.jobs, 1);
        std::thread::sleep(std::time::Duration::from_millis(5));
        pool.run(&|_| {});
        let s2 = pool.stats();
        assert_eq!(s2.jobs, 2);
        // The 5ms gap between the jobs is pool idle time.
        assert!(s2.idle_ns >= 4_000_000, "idle gap not accounted: {s2:?}");
        assert!(s2.leader_wait_ns >= s1.leader_wait_ns);
    }

    #[test]
    fn stats_count_leader_wait_when_workers_lag() {
        let pool = WorkerPool::new(2);
        pool.run(&|ctx| {
            if ctx.rank == 1 {
                std::thread::sleep(std::time::Duration::from_millis(10));
            }
        });
        let s = pool.stats();
        assert!(
            s.leader_wait_ns >= 5_000_000,
            "leader must account the drain wait: {s:?}"
        );
    }

    #[test]
    fn panic_in_a_split_job_poisons_sub_barriers_too() {
        let pool = WorkerPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|ctx| {
                let sub = ctx.split(1);
                if sub.panel {
                    panic!("panel dies");
                }
                // Update ranks park on their sub-barrier and must be
                // woken by the poison cascade instead of hanging: their
                // own sub-team is complete, so give them an arrival that
                // cannot complete without the panel's rejoin.
                sub.barrier();
                ctx.barrier();
            });
        }));
        assert!(result.is_err());
        // Pool (and both sub-barriers) usable again afterwards.
        let hits = AtomicU64::new(0);
        pool.run(&|ctx| {
            let sub = ctx.split(1);
            sub.barrier();
            hits.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn workspaces_prefaulted_at_spawn() {
        let pool = WorkerPool::new(3);
        // After one completed job every worker has started (and each
        // prefaults before its first job), so the touch accounting is
        // complete and stable.
        pool.run(&|ctx| {
            let ws = ctx.workspace();
            assert!(ws.a_buf.len() >= PREFAULT_ELEMS, "rank {} Ac not prefaulted", ctx.rank);
            assert!(ws.b_buf.len() >= PREFAULT_ELEMS, "rank {} Bc not prefaulted", ctx.rank);
        });
        let expect = (3 * 2 * PREFAULT_ELEMS * 8) as u64;
        assert_eq!(pool.stats().prefaulted_bytes, expect);
        // The counter is a spawn-time record, not per-job.
        pool.run(&|_| {});
        assert_eq!(pool.stats().prefaulted_bytes, expect);
    }

    #[test]
    fn groups_partition_contiguously_with_local_ranks() {
        let pool = WorkerPool::new(4);
        let masks = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
        let sums = [AtomicU64::new(0), AtomicU64::new(0), AtomicU64::new(0)];
        pool.run(&|ctx| {
            let grp = ctx.group(&[2, 1, 1]);
            masks[grp.index].fetch_or(1 << grp.rank, Ordering::SeqCst);
            // Group barriers must release with only that group's ranks
            // arriving (other groups never touch them).
            sums[grp.index].fetch_add(grp.rank as u64 + 1, Ordering::SeqCst);
            grp.barrier();
            let expect = (grp.threads * (grp.threads + 1) / 2) as u64;
            assert_eq!(sums[grp.index].load(Ordering::SeqCst), expect);
            grp.barrier();
        });
        assert_eq!(masks[0].load(Ordering::SeqCst), 0b11, "group 0 = global ranks 0,1");
        assert_eq!(masks[1].load(Ordering::SeqCst), 0b1);
        assert_eq!(masks[2].load(Ordering::SeqCst), 0b1);
    }

    #[test]
    fn single_rank_groups_have_inert_barriers() {
        let pool = WorkerPool::new(2);
        let hits = AtomicU64::new(0);
        pool.run(&|ctx| {
            let grp = ctx.group(&[1, 1]);
            assert_eq!((grp.rank, grp.threads), (0, 1));
            grp.barrier(); // width-1 group: must not block
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn panic_in_a_grouped_job_poisons_group_barriers_too() {
        let pool = WorkerPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|ctx| {
                let grp = ctx.group(&[2, 1]);
                if grp.index == 1 {
                    panic!("group 1 dies");
                }
                // Group 0's ranks park on their group barrier; the poison
                // cascade must wake them instead of hanging. Their own
                // group is complete, so add an arrival that cannot
                // complete: the full-team barrier needs group 1 too.
                grp.barrier();
                ctx.barrier();
            });
        }));
        assert!(result.is_err());
        // Pool (and the group barriers) usable again afterwards.
        let hits = AtomicU64::new(0);
        pool.run(&|ctx| {
            let grp = ctx.group(&[2, 1]);
            grp.barrier();
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn pin_policy_core_assignment() {
        assert_eq!(PinPolicy::None.core_for(0, 4, 8), None);
        assert_eq!(PinPolicy::Compact.core_for(3, 4, 8), Some(3));
        assert_eq!(PinPolicy::Compact.core_for(9, 4, 8), Some(1));
        // Scatter spreads a 4-wide team over 8 cores at stride 2.
        assert_eq!(PinPolicy::Scatter.core_for(1, 4, 8), Some(2));
        assert_eq!(PinPolicy::Scatter.core_for(3, 4, 8), Some(6));
        // More ranks than cores wraps; zero cores disables.
        assert_eq!(PinPolicy::Scatter.core_for(5, 8, 2), Some(1));
        assert_eq!(PinPolicy::Compact.core_for(0, 4, 0), None);
    }

    #[test]
    fn pinned_pool_still_broadcasts() {
        // Pinning is best-effort; on any host the pool must stay correct.
        for pin in [PinPolicy::Compact, PinPolicy::Scatter] {
            let pool = WorkerPool::with_pinning(3, pin);
            let hits = AtomicU64::new(0);
            pool.run(&|ctx| {
                ctx.barrier();
                hits.fetch_add(1, Ordering::SeqCst);
            });
            assert_eq!(hits.load(Ordering::SeqCst), 3, "{pin:?}");
        }
    }

    #[test]
    fn rejoin_timed_attributes_phase_idle() {
        let pool = WorkerPool::new(3);
        // Panel team finishes instantly; update team sleeps: the panel
        // ranks' rejoin wait must land in panel_idle_ns.
        pool.run(&|ctx| {
            let sub = ctx.split(1);
            if !sub.panel {
                std::thread::sleep(std::time::Duration::from_millis(8));
            }
            ctx.rejoin_timed(&sub, false);
        });
        let s = pool.stats();
        assert!(s.panel_idle_ns >= 4_000_000, "panel idle not accounted: {s:?}");
        // Update team waits on a slow panel with an empty queue: the
        // panel wait is a queue stall, the update wait is update idle.
        pool.run(&|ctx| {
            let sub = ctx.split(1);
            if sub.panel {
                std::thread::sleep(std::time::Duration::from_millis(8));
            }
            ctx.rejoin_timed(&sub, true);
        });
        let s2 = pool.stats();
        assert!(s2.update_idle_ns >= 4_000_000, "update idle not accounted: {s2:?}");
        // The empty-queue flag only classifies *panel* waits.
        assert_eq!(s2.panel_idle_ns, s.panel_idle_ns);
    }

    #[test]
    fn try_run_reports_worker_panic_as_typed_error() {
        let pool = WorkerPool::new(3);
        let err = pool
            .try_run(&|ctx| {
                if ctx.rank == 2 {
                    panic!("rank 2 blew up");
                }
                ctx.barrier();
            })
            .unwrap_err();
        assert_eq!(err, EpochError::WorkerPanic { rank: 2, message: "rank 2 blew up".into() });
        let s = pool.stats();
        assert_eq!((s.epochs_poisoned, s.recoveries), (1, 1));
        // Recovered: a healthy multi-barrier job completes and counters
        // do not advance further.
        let hits = AtomicU64::new(0);
        pool.try_run(&|ctx| {
            ctx.barrier();
            hits.fetch_add(1, Ordering::SeqCst);
            ctx.barrier();
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        let s2 = pool.stats();
        assert_eq!((s2.epochs_poisoned, s2.recoveries), (1, 1));
    }

    #[test]
    fn try_run_reports_leader_panic_as_typed_error() {
        let pool = WorkerPool::new(2);
        let err = pool
            .try_run(&|ctx| {
                if ctx.rank == 0 {
                    panic!("leader share failed");
                }
            })
            .unwrap_err();
        assert_eq!(err, EpochError::LeaderPanic { message: "leader share failed".into() });
        pool.try_run(&|_| {}).unwrap();
    }

    #[test]
    fn try_run_prefers_root_cause_over_poison_echo() {
        // The leader parks on the full-team barrier and dies from the
        // poison cascade; the error must still name the worker that
        // actually panicked, with its payload.
        let pool = WorkerPool::new(3);
        let err = pool
            .try_run(&|ctx| {
                if ctx.rank == 1 {
                    panic!("root cause on rank 1");
                }
                ctx.barrier();
            })
            .unwrap_err();
        assert_eq!(
            err,
            EpochError::WorkerPanic { rank: 1, message: "root cause on rank 1".into() }
        );
    }

    #[test]
    fn try_run_isolates_inline_single_thread_panics() {
        let pool = WorkerPool::new(1);
        let err = pool.try_run(&|_| panic!("inline boom")).unwrap_err();
        assert_eq!(err, EpochError::LeaderPanic { message: "inline boom".into() });
        let s = pool.stats();
        assert_eq!((s.epochs_poisoned, s.recoveries), (1, 1));
        let ok = AtomicU64::new(0);
        pool.try_run(&|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn recovery_resets_workspaces_but_not_prefault_accounting() {
        let pool = WorkerPool::new(2);
        pool.run(&|_| {}); // both workers up, prefault accounting stable
        let prefaulted = pool.stats().prefaulted_bytes;
        // A job that corrupts its workspace and then dies.
        let err = pool.try_run(&|ctx| {
            let mut ws = ctx.workspace();
            ws.a_buf.resize(3, 7.0);
            drop(ws);
            panic!("die after corrupting the workspace");
        });
        assert!(err.is_err());
        // Workspaces are back to the prefaulted spawn state...
        pool.try_run(&|ctx| {
            let ws = ctx.workspace();
            assert_eq!(ws.a_buf.len(), PREFAULT_ELEMS, "rank {} not reset", ctx.rank);
            assert!(ws.a_buf.iter().all(|&v| v == 0.0));
        })
        .unwrap();
        // ...and the first-touch accounting did not double-count.
        assert_eq!(pool.stats().prefaulted_bytes, prefaulted);
    }

    #[test]
    fn injected_fault_panics_like_a_real_one() {
        use crate::runtime::faults::{FaultPlan, FaultState};
        let faults =
            Arc::new(FaultState::new(FaultPlan::parse("panic@1:2").expect("plan parses")));
        let pool = WorkerPool::with_fault_state(3, Some(Arc::clone(&faults)));
        // Epoch 1: before the shot.
        pool.try_run(&|ctx| ctx.barrier()).unwrap();
        // Epoch 2: rank 1's shot fires inside the job machinery.
        let err = pool.try_run(&|ctx| ctx.barrier()).unwrap_err();
        match err {
            EpochError::WorkerPanic { rank, message } => {
                assert_eq!(rank, 1);
                assert!(message.contains("injected fault"), "{message}");
            }
            other => panic!("unexpected error {other:?}"),
        }
        assert_eq!(faults.injected().panics, 1);
        // One-shot: the pool serves clean epochs afterwards.
        let hits = AtomicU64::new(0);
        pool.try_run(&|ctx| {
            ctx.barrier();
            hits.fetch_add(1, Ordering::SeqCst);
        })
        .unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 3);
        let s = pool.stats();
        assert_eq!((s.epochs_poisoned, s.recoveries), (1, 1));
    }

    #[test]
    fn leader_panic_waits_for_workers_and_rethrows() {
        // `run` must not unwind past the completion handshake (workers
        // still hold the job reference); on a leader panic it poisons,
        // drains, then re-throws.
        let pool = WorkerPool::new(3);
        let worker_done = AtomicU64::new(0);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(&|ctx| {
                if ctx.rank == 0 {
                    panic!("leader dies");
                }
                worker_done.fetch_add(1, Ordering::SeqCst);
            });
        }));
        assert!(result.is_err());
        assert_eq!(worker_done.load(Ordering::SeqCst), 2, "workers drained before rethrow");
        // Still usable afterwards.
        pool.run(&|ctx| {
            ctx.barrier();
        });
    }
}
