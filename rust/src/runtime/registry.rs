//! Artifact registry: parses `artifacts/manifest.tsv`, compiles every
//! HLO-text artifact once, and serves executables by name or by
//! (kind, params) query — the lookup the coordinator's dispatch uses.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::PjrtEngine;

/// Artifact categories emitted by aot.py.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArtifactKind {
    Gemm,
    GemmUpdate,
    LuStep,
    LuFull,
}

impl ArtifactKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gemm" => Self::Gemm,
            "gemm_update" => Self::GemmUpdate,
            "lu_step" => Self::LuStep,
            "lu_full" => Self::LuFull,
            other => bail!("unknown artifact kind {other:?}"),
        })
    }
}

/// One compiled artifact.
pub struct Artifact {
    pub name: String,
    pub kind: ArtifactKind,
    pub params: BTreeMap<String, String>,
    pub exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Integer parameter accessor (`m`, `n`, `k`, `s`, `b`).
    pub fn param_usize(&self, key: &str) -> Result<usize> {
        self.params
            .get(key)
            .with_context(|| format!("artifact {} missing param {key}", self.name))?
            .parse()
            .with_context(|| format!("artifact {} param {key} not an integer", self.name))
    }

    pub fn variant(&self) -> &str {
        self.params.get("variant").map(|s| s.as_str()).unwrap_or("default")
    }
}

/// The registry of all compiled artifacts.
pub struct Registry {
    pub engine: PjrtEngine,
    artifacts: Vec<Artifact>,
}

impl Registry {
    /// Load and compile everything listed in `<dir>/manifest.tsv`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let engine = PjrtEngine::cpu()?;
        let manifest = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {} (run `make artifacts` first)", manifest.display()))?;
        let mut artifacts = Vec::new();
        for line in text.lines() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 4 {
                bail!("malformed manifest line: {line:?}");
            }
            let (name, file, kind, params) = (cols[0], cols[1], cols[2], cols[3]);
            let kind = ArtifactKind::parse(kind)?;
            let mut map = BTreeMap::new();
            for pair in params.split(';').filter(|p| !p.is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .with_context(|| format!("malformed param {pair:?} in {name}"))?;
                map.insert(k.to_string(), v.to_string());
            }
            let exe = engine.compile_hlo_text(&dir.join(file))?;
            artifacts.push(Artifact { name: name.to_string(), kind, params: map, exe });
        }
        if artifacts.is_empty() {
            bail!("manifest {} lists no artifacts", manifest.display());
        }
        Ok(Self { engine, artifacts })
    }

    /// Default artifact directory (repo-root `artifacts/`), honouring the
    /// `DLA_ARTIFACTS` environment variable.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("DLA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.iter().map(|a| a.name.as_str()).collect()
    }

    pub fn by_name(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    /// All artifacts of a kind.
    pub fn by_kind(&self, kind: ArtifactKind) -> Vec<&Artifact> {
        self.artifacts.iter().filter(|a| a.kind == kind).collect()
    }

    /// Find a GEMM artifact matching exact dimensions, preferring the
    /// requested variant (the co-design dispatch: the selector names a
    /// micro-kernel analogue, the registry serves a compiled tile).
    pub fn find_gemm(&self, m: usize, n: usize, k: usize, prefer_variant: &str) -> Option<&Artifact> {
        let matches: Vec<&Artifact> = self
            .artifacts
            .iter()
            .filter(|a| {
                a.kind == ArtifactKind::Gemm
                    && a.param_usize("m").ok() == Some(m)
                    && a.param_usize("n").ok() == Some(n)
                    && a.param_usize("k").ok() == Some(k)
            })
            .collect();
        matches
            .iter()
            .find(|a| a.variant() == prefer_variant)
            .copied()
            .or_else(|| matches.first().copied())
    }

    /// Find the LU-step artifact for a given order/block.
    pub fn find_lu_step(&self, s: usize, b: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| {
            a.kind == ArtifactKind::LuStep
                && a.param_usize("s").ok() == Some(s)
                && a.param_usize("b").ok() == Some(b)
        })
    }

    pub fn find_lu_full(&self, s: usize, b: usize) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| {
            a.kind == ArtifactKind::LuFull
                && a.param_usize("s").ok() == Some(s)
                && a.param_usize("b").ok() == Some(b)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse() {
        assert_eq!(ArtifactKind::parse("gemm").unwrap(), ArtifactKind::Gemm);
        assert_eq!(ArtifactKind::parse("lu_step").unwrap(), ArtifactKind::LuStep);
        assert!(ArtifactKind::parse("bogus").is_err());
    }

    // Full registry loading requires artifact files; covered by
    // rust/tests/e2e_artifacts.rs which runs after `make artifacts`.
}
