//! Tile-DAG dataflow scheduler: a statically enumerated task graph with
//! atomic in-degree counters, drained by the persistent pool workers
//! through per-worker work-stealing deques — **no stop-the-world
//! barriers** between tile tasks.
//!
//! The LAPACK drivers decompose a factorization into b×b tile tasks
//! (GETRF/TRSM/GEMM for LU, POTRF/TRSM/SYRK slices for Cholesky,
//! GEQRT/LARFB slices for QR), enumerate the graph up front with a
//! [`GraphBuilder`], and drain it inside **one** broadcast job of the
//! existing [`super::pool::WorkerPool`] (zero thread spawns, and the
//! pool's poison/recovery machinery applies unchanged): each rank loops
//! popping from its own deque and stealing from the others until the
//! graph is empty. This is the Buttari–Langou–Kurzak–Dongarra tile
//! dataflow model (arXiv 0709.1272) grafted onto our persistent pool.
//!
//! # Ready-queue protocol (Chase–Lev-style discipline)
//!
//! Each rank owns one deque of ready task ids:
//!
//! - **LIFO local pops** (`pop_back`): a task readied by this rank's
//!   last completion touches the tiles it just wrote — popping newest
//!   first keeps the working set cache-warm (depth-first descent of the
//!   DAG, exactly the Chase–Lev owner end);
//! - **FIFO steals** (`pop_front`): thieves take the *oldest* ready
//!   task, which sits closest to the DAG's frontier and is least likely
//!   to share cache lines with the victim's current tile.
//!
//! The deques here are mutex-protected ring buffers rather than the
//! lock-free Chase–Lev array: every pop/steal brackets a tile task that
//! is thousands of cycles of packed GEMM, so the lock is never the
//! bottleneck, and the protocol (owner LIFO / thief FIFO, one owner per
//! deque) is the part that matters for locality.
//!
//! # Dependency protocol
//!
//! Every edge `a -> b` contributes one unit to `b`'s in-degree counter.
//! Completing `a` decrements each successor with `AcqRel`; the rank that
//! observes the count hit zero pushes the successor onto **its own**
//! deque (the new task reads tiles this rank just wrote). The
//! read-modify-write chain on the counter gives every predecessor's
//! writes a happens-before edge to the task's execution; stolen tasks
//! inherit it through the deque mutex.
//!
//! # Termination, cancellation and panic recovery
//!
//! A shared `remaining` count reaches zero exactly when every task ran —
//! idle ranks spin (yielding) on it instead of blocking on a barrier.
//! Three things can end a drain early:
//!
//! - [`TaskGraph::cancel`] — a task hit a *typed* breakdown (singular
//!   pivot, non-SPD block): completed work stops publishing successors
//!   and every rank unwinds out cleanly; the driver reads its error slot.
//! - A **panic inside a task**: a drop guard flips the same abort flag
//!   before the unwind leaves the task, then the panic propagates into
//!   the pool's catch/poison/recover machinery exactly like any job
//!   panic.
//! - A **rank dying outside any task** (e.g. an injected fault fires in
//!   the pool's pre-job hook): no task guard runs, so idle ranks also
//!   poll [`super::pool::PoolCtx::job_poisoned`] — the dying rank
//!   poisons the pool barriers on its way out, which the survivors
//!   observe and exit on instead of spinning forever on a `remaining`
//!   that can no longer reach zero.
//!
//! Per-rank tallies (tasks executed, steals, failed steal probes, deque
//! high-water mark) are folded into [`super::pool::PoolStats`] once per
//! drain via [`super::pool::PoolCtx::note_dag_stats`].

#![deny(clippy::unwrap_used, clippy::expect_used)]

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use super::pool::PoolCtx;

/// Lock, shrugging off poison (same contract as the pool's own helper:
/// the protected state is a plain id queue, always left consistent, and
/// a panicked drain is re-thrown by the pool leader anyway).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Builder for a [`TaskGraph`]: add tasks, then edges, then [`seal`].
///
/// [`seal`]: GraphBuilder::seal
#[derive(Default)]
pub struct GraphBuilder {
    succ: Vec<Vec<u32>>,
    indeg: Vec<u32>,
}

impl GraphBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a task; returns its id (dense, starting at 0).
    pub fn add_task(&mut self) -> usize {
        self.succ.push(Vec::new());
        self.indeg.push(0);
        self.succ.len() - 1
    }

    /// Add the dependency edge `from -> to` (`to` cannot start until
    /// `from` completed). Duplicate edges are legal (each contributes
    /// one in-degree unit and one matching decrement).
    pub fn add_edge(&mut self, from: usize, to: usize) {
        assert!(from < self.succ.len() && to < self.succ.len(), "edge endpoint out of range");
        assert_ne!(from, to, "self-edge would deadlock the drain");
        self.succ[from].push(to as u32);
        self.indeg[to] += 1;
    }

    /// Freeze the graph for one drain by a `threads`-wide team. Panics
    /// on a cyclic graph (a driver bug — a cycle would spin every rank
    /// forever), verified with a full Kahn pass; the graphs here are a
    /// few thousand tasks at most, so the check is noise next to one
    /// tile GEMM.
    pub fn seal(self, threads: usize) -> TaskGraph {
        let n = self.succ.len();
        let threads = threads.max(1);
        let roots: Vec<u32> =
            (0..n as u32).filter(|&t| self.indeg[t as usize] == 0).collect();
        // Kahn pass over a scratch copy of the in-degrees.
        let mut scratch = self.indeg.clone();
        let mut stack: Vec<u32> = roots.clone();
        let mut seen = 0usize;
        while let Some(t) = stack.pop() {
            seen += 1;
            for &s in &self.succ[t as usize] {
                scratch[s as usize] -= 1;
                if scratch[s as usize] == 0 {
                    stack.push(s);
                }
            }
        }
        assert_eq!(seen, n, "task graph has a cycle ({} of {n} tasks reachable)", seen);
        TaskGraph {
            succ: self.succ,
            indeg: self.indeg.into_iter().map(AtomicU32::new).collect(),
            roots,
            remaining: AtomicUsize::new(n),
            abort: AtomicBool::new(false),
            deques: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
        }
    }
}

/// A sealed, single-use task graph: in-degree counters, successor lists
/// and the per-rank ready deques. Build one per factorization; a drained
/// graph cannot be re-armed (the counters are consumed).
pub struct TaskGraph {
    succ: Vec<Vec<u32>>,
    indeg: Vec<AtomicU32>,
    roots: Vec<u32>,
    /// Tasks not yet completed; 0 terminates the drain.
    remaining: AtomicUsize,
    /// Stop scheduling: set by [`TaskGraph::cancel`] (typed breakdown)
    /// or by the unwind guard of a panicking task.
    abort: AtomicBool,
    deques: Vec<Mutex<VecDeque<u32>>>,
}

impl TaskGraph {
    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.succ.len()
    }

    pub fn is_empty(&self) -> bool {
        self.succ.is_empty()
    }

    /// Cancel the drain: no further successors are published, and every
    /// rank exits its drain loop after the task it is currently running.
    /// Used for typed breakdowns (singular pivot, non-SPD diagonal) —
    /// the driver records the error in its own slot, cancels, and reads
    /// the slot back after the pool job returns cleanly.
    pub fn cancel(&self) {
        self.abort.store(true, Ordering::Release);
    }

    /// Whether the drain was cancelled (or a task panicked).
    pub fn cancelled(&self) -> bool {
        self.abort.load(Ordering::Acquire)
    }
}

/// Flips the graph's abort flag if the wrapped scope unwinds, so sibling
/// ranks stop spinning for successors a dead task will never publish.
struct AbortOnUnwind<'a>(&'a AtomicBool);

impl Drop for AbortOnUnwind<'_> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            self.0.store(true, Ordering::Release);
        }
    }
}

/// One rank's share of a pool-wide drain: call from every rank of a
/// single broadcast job (`pool.run(&|ctx| execute_rank(&g, ctx, ...))`).
/// `run_task` receives the task id; it runs with no locks held (the
/// rank's deque is unlocked around it).
///
/// The graph must have been sealed with the pool's thread count.
pub fn execute_rank<F: FnMut(usize)>(g: &TaskGraph, ctx: &PoolCtx<'_>, mut run_task: F) {
    let threads = ctx.threads.min(g.deques.len());
    let rank = ctx.rank;
    assert!(
        rank < g.deques.len(),
        "graph sealed for {} ranks, executed by rank {rank}",
        g.deques.len()
    );
    let (mut tasks, mut steals, mut steal_fails, mut hwm) = (0u64, 0u64, 0u64, 0u64);
    // Seed this rank's deque with its round-robin share of the roots.
    {
        let mut dq = lock(&g.deques[rank]);
        for (i, &root) in g.roots.iter().enumerate() {
            if i % threads == rank {
                dq.push_back(root);
            }
        }
        hwm = hwm.max(dq.len() as u64);
    }
    loop {
        // LIFO local pop: the most recently readied tile reads what this
        // rank just wrote — the cache-warm end of the deque.
        let popped = lock(&g.deques[rank]).pop_back();
        let task = match popped {
            Some(t) => t,
            None => {
                if g.remaining.load(Ordering::Acquire) == 0 || g.abort.load(Ordering::Acquire) {
                    break;
                }
                if ctx.job_poisoned() {
                    // A rank died outside any task (no abort guard ran):
                    // `remaining` can never reach zero, so exit on the
                    // poison the dying rank left on the pool barriers.
                    break;
                }
                // FIFO steal sweep, round-robin from the next rank up.
                let mut stolen = None;
                for off in 1..threads {
                    let victim = (rank + off) % threads;
                    if let Some(t) = lock(&g.deques[victim]).pop_front() {
                        steals += 1;
                        stolen = Some(t);
                        break;
                    }
                    steal_fails += 1;
                }
                match stolen {
                    Some(t) => t,
                    None => {
                        std::thread::yield_now();
                        continue;
                    }
                }
            }
        };
        {
            // If run_task panics, flag the abort before unwinding into
            // the pool's poison/recovery machinery.
            let _guard = AbortOnUnwind(&g.abort);
            run_task(task as usize);
        }
        tasks += 1;
        if !g.abort.load(Ordering::Acquire) {
            let mut dq = lock(&g.deques[rank]);
            for &s in &g.succ[task as usize] {
                // AcqRel: release this task's writes to whoever runs the
                // successor, acquire the other predecessors' writes when
                // this decrement is the one that reaches zero.
                if g.indeg[s as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                    dq.push_back(s);
                }
            }
            hwm = hwm.max(dq.len() as u64);
        }
        g.remaining.fetch_sub(1, Ordering::AcqRel);
    }
    ctx.note_dag_stats(tasks, steals, steal_fails, hwm);
}

/// Inline drain on the calling thread (engines with no pool, i.e. a
/// 1-thread plan): same LIFO descent as a 1-rank pool drain, so the
/// task execution order — and for the bitwise-deterministic tile
/// decompositions, every result bit — matches the pooled path.
pub fn execute_serial<F: FnMut(usize)>(g: &TaskGraph, mut run_task: F) {
    let mut stack: Vec<u32> = g.roots.clone();
    while let Some(task) = stack.pop() {
        run_task(task as usize);
        if g.abort.load(Ordering::Acquire) {
            return;
        }
        for &s in &g.succ[task as usize] {
            if g.indeg[s as usize].fetch_sub(1, Ordering::AcqRel) == 1 {
                stack.push(s);
            }
        }
        g.remaining.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::runtime::pool::WorkerPool;
    use std::sync::atomic::AtomicU64;

    /// A diamond a -> {b, c} -> d must run a first and d last.
    #[test]
    fn diamond_order_respected_serial() {
        let mut gb = GraphBuilder::new();
        let (a, b, c, d) = (gb.add_task(), gb.add_task(), gb.add_task(), gb.add_task());
        gb.add_edge(a, b);
        gb.add_edge(a, c);
        gb.add_edge(b, d);
        gb.add_edge(c, d);
        let g = gb.seal(1);
        let mut order = Vec::new();
        execute_serial(&g, |t| order.push(t));
        assert_eq!(order.len(), 4);
        assert_eq!(order[0], a);
        assert_eq!(order[3], d);
    }

    #[test]
    fn pooled_drain_runs_every_task_once_and_counts() {
        let pool = WorkerPool::new(4);
        let mut gb = GraphBuilder::new();
        // A 3-wide, 20-deep grid: task (r, c) depends on (r-1, c).
        let ids: Vec<Vec<usize>> = (0..3)
            .map(|_| (0..20).map(|_| gb.add_task()).collect())
            .collect();
        for chain in &ids {
            for w in chain.windows(2) {
                gb.add_edge(w[0], w[1]);
            }
        }
        let g = gb.seal(pool.threads());
        let ran: Vec<AtomicU64> = (0..g.len()).map(|_| AtomicU64::new(0)).collect();
        let before = pool.stats();
        pool.run(&|ctx| {
            execute_rank(&g, ctx, |t| {
                ran[t].fetch_add(1, Ordering::Relaxed);
            })
        });
        for (t, r) in ran.iter().enumerate() {
            assert_eq!(r.load(Ordering::Relaxed), 1, "task {t} ran a wrong number of times");
        }
        let after = pool.stats();
        assert_eq!(after.dag_tasks - before.dag_tasks, g.len() as u64);
        assert!(after.dag_deque_high_water >= 1);
    }

    #[test]
    fn cancel_stops_scheduling_dependents() {
        let mut gb = GraphBuilder::new();
        let a = gb.add_task();
        let b = gb.add_task();
        gb.add_edge(a, b);
        let g = gb.seal(1);
        let mut ran = Vec::new();
        execute_serial(&g, |t| {
            ran.push(t);
            g.cancel();
        });
        assert_eq!(ran, vec![a]);
        assert!(g.cancelled());
    }

    #[test]
    #[should_panic(expected = "cycle")]
    fn cycle_rejected_at_seal() {
        let mut gb = GraphBuilder::new();
        let a = gb.add_task();
        let b = gb.add_task();
        gb.add_edge(a, b);
        gb.add_edge(b, a);
        let _ = gb.seal(2);
    }
}
