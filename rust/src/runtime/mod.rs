//! Runtime infrastructure for the serving stack.
//!
//! Two halves live here:
//!
//! - [`pool`] — the **persistent fork-join worker pool** that backs the
//!   multi-threaded GEMM drivers (`gemm::parallel`): parked workers, an
//!   epoch/barrier task broadcast, and per-worker pinned packing
//!   workspaces. This is the amortized worker team Catalán et al. and
//!   Buttari et al. show multicore DLA needs (see PAPERS.md), replacing
//!   the seed's spawn-per-macro-block threading. A panicked job poisons
//!   the epoch, drains, and is reported as a typed
//!   [`pool::EpochError`] — the pool recovers instead of dying.
//! - [`dag`] — the **tile-DAG dataflow scheduler**: statically
//!   enumerated task graphs with atomic in-degree counters, drained by
//!   the pool's ranks through per-worker work-stealing deques (LIFO
//!   local pops, FIFO steals) inside a single broadcast job — the
//!   barrier-free execution model of Buttari et al. for the blocked
//!   factorizations, selected via `DLA_SCHED=dag`.
//! - [`faults`] — the fault-injection harness behind the chaos suite
//!   (`DLA_FAULTS`): one-shot rank panics, slow-rank delays, request
//!   stalls and forced queue-full at admission, all free when un-armed.
//! - **PJRT bridge** (`pjrt` feature): loads the AOT artifacts
//!   (`artifacts/*.hlo.txt`, produced by `python/compile/aot.py`) and
//!   executes them from Rust — the bridge between Layer 3 (this crate)
//!   and Layers 1/2 (JAX + Pallas). Python never runs at request time:
//!   the HLO text is parsed by XLA's text parser
//!   (`HloModuleProto::from_text_file`, which reassigns instruction ids —
//!   see /opt/xla-example/README.md for why text, not serialized protos),
//!   compiled once per artifact on the PJRT CPU client, and cached.
//!   Compile-gated because the `xla` crate is unavailable in the offline
//!   build environment; enable the `pjrt` feature and supply the crate to
//!   restore [`convert`], [`registry`], [`PjrtEngine`] and the artifact
//!   LU driver.

pub mod dag;
pub mod faults;
pub mod pool;

#[cfg(feature = "pjrt")]
pub mod convert;
#[cfg(feature = "pjrt")]
pub mod registry;

#[cfg(feature = "pjrt")]
pub use convert::{literal_to_matrix, matrix_to_literal};
#[cfg(feature = "pjrt")]
pub use registry::{Artifact, ArtifactKind, Registry};

pub use dag::{execute_rank, execute_serial, GraphBuilder, TaskGraph};
pub use faults::{FaultCounters, FaultPlan, FaultState};
pub use pool::{EpochError, PinPolicy, PoolCtx, PoolStats, SubTeam, WorkerPool};

#[cfg(feature = "pjrt")]
use anyhow::{Context, Result};

/// A process-wide PJRT client handle.
#[cfg(feature = "pjrt")]
pub struct PjrtEngine {
    pub client: xla::PjRtClient,
}

#[cfg(feature = "pjrt")]
impl PjrtEngine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load one HLO-text file and compile it to an executable.
    pub fn compile_hlo_text(&self, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))
    }
}

/// Execute a compiled artifact on literals and un-tuple the result
/// (aot.py lowers with `return_tuple=True`, so outputs are always a
/// top-level tuple).
#[cfg(feature = "pjrt")]
pub fn execute_tupled(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[xla::Literal],
) -> Result<Vec<xla::Literal>> {
    let result = exe.execute::<xla::Literal>(inputs)?[0][0]
        .to_literal_sync()
        .context("fetching result literal")?;
    result.to_tuple().context("untupling result")
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    // These tests need the PJRT client; they are exercised together with
    // the artifact files in `rust/tests/e2e_artifacts.rs`. Here we only
    // check client construction (cheap, no artifacts required).
    #[test]
    fn cpu_client_comes_up() {
        let eng = PjrtEngine::cpu().expect("PJRT CPU client");
        assert!(eng.platform().to_lowercase().contains("cpu") || !eng.platform().is_empty());
    }
}
