//! Fault injection for the chaos test suite and for staging drills.
//!
//! A [`FaultPlan`] describes *which* failures to inject — a one-shot
//! panic at a given (rank, epoch), a per-job delay on one rank, a
//! per-request stall in the server worker, a burst of forced queue-full
//! rejections at admission — and a [`FaultState`] arms the plan with the
//! one-shot/count-down bookkeeping. The pool and the coordinator server
//! consult the armed state at three hook points:
//!
//! - [`FaultState::before_job`] — called by every rank **inside** the
//!   pool's `catch_unwind` region, so an injected panic unwinds through
//!   exactly the machinery a real job panic does (barrier poisoning,
//!   drain, typed `EpochError`).
//! - [`FaultState::stall_request`] — called by a server worker before
//!   handling a dequeued request (exercises deadline expiry).
//! - [`FaultState::admission_queue_full`] — consulted by `submit` before
//!   the real `try_send` (exercises backpressure retries).
//!
//! The hooks are **free when no plan is armed**: the pool stores
//! `Option<Arc<FaultState>>` and every hook site is a single `Option`
//! check per *job* or per *request* — never per element — so release
//! builds without `DLA_FAULTS` pay one branch on paths that are already
//! dominated by locking. No cargo feature is needed.
//!
//! # `DLA_FAULTS` grammar
//!
//! Comma-separated tokens; unknown tokens are ignored (a typo must fail
//! toward "no fault injected", never toward a surprise panic):
//!
//! - `panic@R:E` — one-shot panic on rank `R` at the `E`-th broadcast
//!   epoch (1-based, counted since pool construction; fires on the first
//!   epoch `>= E` so the shot cannot be missed).
//! - `slow@R:MS` — rank `R` sleeps `MS` milliseconds at the start of
//!   every job (the asymmetric "slow core" drill).
//! - `stall:MS` — every served request stalls `MS` milliseconds in the
//!   worker before being handled.
//! - `queuefull:N` — the next `N` admission attempts see a full queue.
//! - `flood:N` — the server injects `N` synthetic Background-tier
//!   requests at admission when it starts (a canned overload, so load
//!   shedding is testable without an external generator).
//! - `flip@R:E[:BIT]` — one-shot silent-data-corruption drill: rank `R`
//!   flips bit `BIT` (default 62, an exponent bit — a loud corruption)
//!   of one element of its own just-packed A panel on the first
//!   **verified** GEMM epoch `>= E` (1-based, counted by
//!   [`FaultState::begin_verified_epoch`]). The flip lands *before* the
//!   pack-complete barrier and only in the flipping rank's own share,
//!   so it is exactly the data race-free shape of a real SDC event in a
//!   packed buffer. Consumed only by verified dispatches — unverified
//!   work is never corrupted (armed-but-benign legs stay green).
//! - `1` / `on` / `arm` — arm an empty plan (hooks active, no faults).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// A declarative description of the faults to inject (see module docs
/// for the `DLA_FAULTS` grammar that builds one from the environment).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// One-shot panic: (rank, 1-based epoch). Fires once, on the first
    /// epoch `>=` the target, only on the named rank.
    pub panic_at: Option<(usize, u64)>,
    /// Per-job delay: (rank, milliseconds slept at the start of every
    /// job on that rank).
    pub slow: Option<(usize, u64)>,
    /// Per-request stall in the server worker, in milliseconds.
    pub stall_ms: Option<u64>,
    /// Number of admission attempts forced to observe a full queue.
    pub queue_full: u64,
    /// Number of synthetic Background-tier requests the server injects
    /// at admission when it starts (the canned-overload drill).
    pub flood: u64,
    /// One-shot bit flip: (rank, 1-based verified epoch, bit index).
    /// Fires once, on the first verified GEMM epoch `>=` the target,
    /// only on the named rank, corrupting its own packed-A share.
    pub flip: Option<(usize, u64, u32)>,
}

impl FaultPlan {
    /// Parse the `DLA_FAULTS` environment variable; `None` when unset,
    /// empty, `0` or `off` (the hooks stay un-armed).
    pub fn from_env() -> Option<Self> {
        Self::parse(std::env::var("DLA_FAULTS").ok()?.as_str())
    }

    /// Parse a fault spec (the `DLA_FAULTS` grammar). `None` disarms.
    pub fn parse(spec: &str) -> Option<Self> {
        let spec = spec.trim();
        if spec.is_empty() || spec == "0" || spec.eq_ignore_ascii_case("off") {
            return None;
        }
        let mut plan = FaultPlan::default();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if let Some(rest) = tok.strip_prefix("panic@") {
                if let Some((r, e)) = parse_pair(rest) {
                    plan.panic_at = Some((r as usize, e));
                }
            } else if let Some(rest) = tok.strip_prefix("slow@") {
                if let Some((r, ms)) = parse_pair(rest) {
                    plan.slow = Some((r as usize, ms));
                }
            } else if let Some(rest) = tok.strip_prefix("stall:") {
                if let Ok(ms) = rest.parse::<u64>() {
                    plan.stall_ms = Some(ms);
                }
            } else if let Some(rest) = tok.strip_prefix("queuefull:") {
                if let Ok(n) = rest.parse::<u64>() {
                    plan.queue_full = n;
                }
            } else if let Some(rest) = tok.strip_prefix("flood:") {
                if let Ok(n) = rest.parse::<u64>() {
                    plan.flood = n;
                }
            } else if let Some(rest) = tok.strip_prefix("flip@") {
                // `R:E` or `R:E:BIT`; default bit 62 (an f64 exponent
                // bit, so the corruption is far outside any tolerance).
                let mut it = rest.splitn(3, ':');
                let r = it.next().and_then(|s| s.trim().parse::<usize>().ok());
                let e = it.next().and_then(|s| s.trim().parse::<u64>().ok());
                let bit = match it.next() {
                    Some(s) => s.trim().parse::<u32>().ok(),
                    None => Some(62),
                };
                if let (Some(r), Some(e), Some(bit)) = (r, e, bit) {
                    plan.flip = Some((r, e, bit.min(63)));
                }
            }
            // "1" / "on" / "arm" / anything unrecognized: armed, no-op.
        }
        Some(plan)
    }

    /// True when the plan injects nothing (armed hooks, zero faults).
    pub fn is_empty(&self) -> bool {
        *self == FaultPlan::default()
    }
}

fn parse_pair(s: &str) -> Option<(u64, u64)> {
    let (a, b) = s.split_once(':')?;
    Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
}

/// Counts of faults actually delivered (not merely planned), for test
/// assertions and the metrics `resilience:` line.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// One-shot panics fired.
    pub panics: u64,
    /// Slow-rank delays and request stalls slept.
    pub delays: u64,
    /// Admission attempts forced to see a full queue.
    pub queue_full: u64,
    /// Synthetic flood requests actually injected at server start.
    pub floods: u64,
    /// One-shot bit flips delivered into a packed buffer.
    pub flips: u64,
}

/// An armed [`FaultPlan`]: the plan plus the one-shot / count-down state
/// the hooks mutate. Shared (`Arc`) between a pool and the server that
/// owns it so both consult the same shot counters.
#[derive(Debug)]
pub struct FaultState {
    plan: FaultPlan,
    panic_fired: AtomicBool,
    flip_fired: AtomicBool,
    queue_full_left: AtomicU64,
    flood_left: AtomicU64,
    /// 1-based count of verified GEMM dispatches begun against this
    /// state (the epoch clock the `flip@` shot is gated on). Tracked
    /// here rather than on the pool because only verified dispatches
    /// may consume the flip.
    verified_epoch: AtomicU64,
    panics: AtomicU64,
    delays: AtomicU64,
    queue_fulls: AtomicU64,
    floods: AtomicU64,
    flips: AtomicU64,
}

impl FaultState {
    /// Arm a plan.
    pub fn new(plan: FaultPlan) -> Self {
        let queue_full_left = AtomicU64::new(plan.queue_full);
        let flood_left = AtomicU64::new(plan.flood);
        Self {
            plan,
            panic_fired: AtomicBool::new(false),
            flip_fired: AtomicBool::new(false),
            queue_full_left,
            flood_left,
            verified_epoch: AtomicU64::new(0),
            panics: AtomicU64::new(0),
            delays: AtomicU64::new(0),
            queue_fulls: AtomicU64::new(0),
            floods: AtomicU64::new(0),
            flips: AtomicU64::new(0),
        }
    }

    /// Arm the `DLA_FAULTS` plan, if any.
    pub fn from_env() -> Option<Arc<Self>> {
        FaultPlan::parse(std::env::var("DLA_FAULTS").ok()?.as_str()).map(|p| Arc::new(Self::new(p)))
    }

    /// The plan this state was armed with.
    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Faults delivered so far.
    pub fn injected(&self) -> FaultCounters {
        FaultCounters {
            panics: self.panics.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            queue_full: self.queue_fulls.load(Ordering::Relaxed),
            floods: self.floods.load(Ordering::Relaxed),
            flips: self.flips.load(Ordering::Relaxed),
        }
    }

    /// Verified-GEMM hook: advance the verified-epoch clock and return
    /// the new (1-based) epoch. Called once per verified dispatch by the
    /// engine; the returned epoch is what [`Self::take_flip`] gates on.
    pub fn begin_verified_epoch(&self) -> u64 {
        self.verified_epoch.fetch_add(1, Ordering::AcqRel) + 1
    }

    /// Packing hook: claim the one-shot bit flip if this (rank, verified
    /// epoch) is at or past the planned shot. Returns the bit index to
    /// flip in the rank's own packed share; `None` on every call after
    /// the shot fires (or when no flip is planned).
    pub fn take_flip(&self, rank: usize, verified_epoch: u64) -> Option<u32> {
        let (r, e, bit) = self.plan.flip?;
        if rank != r || verified_epoch < e {
            return None;
        }
        if self
            .flip_fired
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            self.flips.fetch_add(1, Ordering::Relaxed);
            return Some(bit);
        }
        None
    }

    /// Server hook: claim the planned flood exactly once (the first
    /// server to start against this armed state injects the burst; any
    /// later server sees zero). Records the claimed count as delivered.
    pub fn take_flood(&self) -> u64 {
        let n = self.flood_left.swap(0, Ordering::AcqRel);
        if n > 0 {
            self.floods.fetch_add(n, Ordering::Relaxed);
        }
        n
    }

    /// Pool hook: called by every rank at the start of its job share,
    /// inside the `catch_unwind` region, with the 1-based broadcast
    /// epoch. May sleep (slow rank) and may panic (one-shot).
    pub fn before_job(&self, rank: usize, epoch: u64) {
        if let Some((r, ms)) = self.plan.slow {
            if rank == r && ms > 0 {
                self.delays.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
        if let Some((r, e)) = self.plan.panic_at {
            if rank == r
                && epoch >= e
                && self
                    .panic_fired
                    .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
                    .is_ok()
            {
                self.panics.fetch_add(1, Ordering::Relaxed);
                panic!("injected fault: panic at rank {rank} epoch {epoch}");
            }
        }
    }

    /// Server hook: stall the worker before handling a dequeued request
    /// (drives requests past their deadline in the chaos tests).
    pub fn stall_request(&self) {
        if let Some(ms) = self.plan.stall_ms {
            if ms > 0 {
                self.delays.fetch_add(1, Ordering::Relaxed);
                std::thread::sleep(Duration::from_millis(ms));
            }
        }
    }

    /// Admission hook: true when this attempt must behave as if the
    /// queue were full (count-down of the planned burst).
    pub fn admission_queue_full(&self) -> bool {
        if self.queue_full_left.load(Ordering::Relaxed) == 0 {
            return false;
        }
        if self
            .queue_full_left
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
            .is_ok()
        {
            self.queue_fulls.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn grammar_round_trip() {
        let p = FaultPlan::parse(
            "panic@1:3, slow@2:15, stall:40, queuefull:5, flood:64, flip@1:2:51",
        )
        .unwrap();
        assert_eq!(p.panic_at, Some((1, 3)));
        assert_eq!(p.slow, Some((2, 15)));
        assert_eq!(p.stall_ms, Some(40));
        assert_eq!(p.queue_full, 5);
        assert_eq!(p.flood, 64);
        assert_eq!(p.flip, Some((1, 2, 51)));
        assert!(!p.is_empty());
    }

    #[test]
    fn flip_grammar_defaults_and_rejects() {
        // Default bit is 62 (exponent bit — loud).
        assert_eq!(FaultPlan::parse("flip@1:2").unwrap().flip, Some((1, 2, 62)));
        // Out-of-range bit indices clamp to 63 instead of disarming.
        assert_eq!(FaultPlan::parse("flip@0:1:99").unwrap().flip, Some((0, 1, 63)));
        // Malformed specs fail toward no fault.
        assert_eq!(FaultPlan::parse("flip@x:2").unwrap().flip, None);
        assert_eq!(FaultPlan::parse("flip@1").unwrap().flip, None);
        assert_eq!(FaultPlan::parse("flip@1:2:zz").unwrap().flip, None);
    }

    #[test]
    fn flip_shot_is_one_shot_epoch_and_rank_gated() {
        let st = FaultState::new(FaultPlan::parse("flip@1:3").unwrap());
        assert_eq!(st.begin_verified_epoch(), 1);
        assert_eq!(st.begin_verified_epoch(), 2);
        // Wrong rank, early epoch: no fire.
        assert_eq!(st.take_flip(0, 3), None);
        assert_eq!(st.take_flip(1, 2), None);
        assert_eq!(st.injected().flips, 0);
        // Epoch past the target still fires (the shot cannot be missed).
        assert_eq!(st.take_flip(1, 4), Some(62));
        assert_eq!(st.injected().flips, 1);
        // One-shot: never again.
        assert_eq!(st.take_flip(1, 5), None);
        assert_eq!(st.injected().flips, 1);
    }

    #[test]
    fn flood_is_claimed_exactly_once() {
        let st = FaultState::new(FaultPlan::parse("flood:7").unwrap());
        assert_eq!(st.take_flood(), 7);
        assert_eq!(st.take_flood(), 0);
        assert_eq!(st.injected().floods, 7);
    }

    #[test]
    fn disarm_and_armed_empty() {
        assert_eq!(FaultPlan::parse(""), None);
        assert_eq!(FaultPlan::parse("0"), None);
        assert_eq!(FaultPlan::parse("off"), None);
        assert_eq!(FaultPlan::parse("OFF"), None);
        let armed = FaultPlan::parse("1").unwrap();
        assert!(armed.is_empty());
        assert!(FaultPlan::parse("on").unwrap().is_empty());
        assert!(FaultPlan::parse("arm").unwrap().is_empty());
    }

    #[test]
    fn unknown_tokens_fail_toward_no_fault() {
        let p = FaultPlan::parse("panik@1:3, slow@x:y, wat, slow@2:7").unwrap();
        assert_eq!(p.panic_at, None);
        assert_eq!(p.slow, Some((2, 7)));
    }

    #[test]
    fn panic_shot_is_one_shot_and_epoch_gated() {
        let st = FaultState::new(FaultPlan::parse("panic@1:3").unwrap());
        // Wrong rank, early epoch: no fire.
        st.before_job(0, 3);
        st.before_job(1, 2);
        assert_eq!(st.injected().panics, 0);
        // Epoch past the target still fires (the shot cannot be missed).
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| st.before_job(1, 4)));
        assert!(r.is_err());
        assert_eq!(st.injected().panics, 1);
        // One-shot: never again.
        st.before_job(1, 5);
        assert_eq!(st.injected().panics, 1);
    }

    #[test]
    fn queue_full_burst_counts_down() {
        let st = FaultState::new(FaultPlan::parse("queuefull:2").unwrap());
        assert!(st.admission_queue_full());
        assert!(st.admission_queue_full());
        assert!(!st.admission_queue_full());
        assert!(!st.admission_queue_full());
        assert_eq!(st.injected().queue_full, 2);
    }

    #[test]
    fn empty_plan_hooks_are_inert() {
        let st = FaultState::new(FaultPlan::default());
        st.before_job(0, 1);
        st.stall_request();
        assert!(!st.admission_queue_full());
        assert_eq!(st.injected(), FaultCounters::default());
    }
}
