//! Conversions between this crate's column-major [`MatrixF64`] and XLA
//! literals (row-major, XLA's default rank-2 layout — matching the JAX
//! arrays the artifacts were lowered from).

use crate::util::MatrixF64;
use anyhow::{ensure, Context, Result};

/// Column-major matrix -> row-major f64 literal of shape `[rows, cols]`.
pub fn matrix_to_literal(m: &MatrixF64) -> Result<xla::Literal> {
    let (r, c) = (m.rows(), m.cols());
    let mut row_major = Vec::with_capacity(r * c);
    for i in 0..r {
        for j in 0..c {
            row_major.push(m[(i, j)]);
        }
    }
    xla::Literal::vec1(&row_major)
        .reshape(&[r as i64, c as i64])
        .context("reshaping matrix literal")
}

/// Row-major f64 literal -> column-major matrix.
pub fn literal_to_matrix(lit: &xla::Literal) -> Result<MatrixF64> {
    let shape = lit.array_shape().context("literal has no array shape")?;
    let dims = shape.dims();
    ensure!(dims.len() == 2, "expected rank-2 literal, got rank {}", dims.len());
    let (r, c) = (dims[0] as usize, dims[1] as usize);
    let v = lit.to_vec::<f64>().context("reading f64 literal")?;
    ensure!(v.len() == r * c, "literal size mismatch");
    Ok(MatrixF64::from_row_major(r, c, &v))
}

/// i64 vector literal.
pub fn vec_to_literal_i64(v: &[i64]) -> xla::Literal {
    xla::Literal::vec1(v)
}

/// Read an i64 vector literal.
pub fn literal_to_vec_i64(lit: &xla::Literal) -> Result<Vec<i64>> {
    lit.to_vec::<i64>().context("reading i64 literal")
}

/// Scalar i64 literal (loop counters like the LU step index).
pub fn scalar_i64(v: i64) -> xla::Literal {
    xla::Literal::scalar(v)
}

/// Read a scalar boolean-ish literal (exported `ok` flags are PRED).
pub fn literal_to_bool(lit: &xla::Literal) -> Result<bool> {
    // PRED has no direct host type in the xla crate; convert to S32.
    let as_i32 = lit.convert(xla::PrimitiveType::S32).context("converting pred literal")?;
    let v = as_i32.get_first_element::<i32>().context("reading pred literal")?;
    Ok(v != 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn matrix_roundtrip() {
        let mut rng = Pcg64::seed(5);
        let m = MatrixF64::random(7, 5, &mut rng);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&lit).unwrap();
        assert_eq!(back.rows(), 7);
        assert_eq!(back.cols(), 5);
        assert!(m.max_abs_diff(&back) == 0.0);
    }

    #[test]
    fn layout_is_row_major() {
        // Element (0, 1) must be the second entry of the flat row-major
        // buffer the literal sees.
        let m = MatrixF64::from_row_major(2, 2, &[1., 2., 3., 4.]);
        let lit = matrix_to_literal(&m).unwrap();
        assert_eq!(lit.to_vec::<f64>().unwrap(), vec![1., 2., 3., 4.]);
    }

    #[test]
    fn i64_roundtrip() {
        let v = vec![3i64, 1, 4, 1, 5];
        let lit = vec_to_literal_i64(&v);
        assert_eq!(literal_to_vec_i64(&lit).unwrap(), v);
    }

    #[test]
    fn rank_mismatch_rejected() {
        let lit = xla::Literal::vec1(&[1.0f64, 2.0]);
        assert!(literal_to_matrix(&lit).is_err());
    }
}
