//! Blocked Cholesky factorization (extension): a second LAPACK-level
//! consumer of the co-design GEMM, demonstrating that the paper's
//! skinny-k trailing updates (`k = b`) are not LU-specific.
//!
//! Right-looking lower Cholesky: for each `b`-column panel,
//!
//! ```text
//! A11 = L11 L11^T          (unblocked potf2)
//! A21 := A21 L11^{-T}      (trsm, right upper)
//! A22 := A22 - A21 A21^T   (syrk, cast as the skinny-k GEMM)
//! ```
//!
//! With the engine's [`crate::gemm::Lookahead`] enabled, the SYRK sweep
//! runs as the queue-based deep pipeline: up to `depth` panels stay
//! factored ahead of the trailing sweep — the fused job updates the
//! columns entering the lookahead window, the panel task replays the
//! in-window SYRK slices on them and runs `potf2` + panel TRSM, and the
//! update sub-team sweeps the remainder — the same work queue as the
//! lookahead LU, minus pivoting. Factors are bitwise identical to the
//! serialized path at every depth.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::gemm::abft::{lower_panel_colsums, verify_chol_panel, AbftPhase, AbftStats};
use crate::gemm::{gemm_blocked, GemmElem, GemmEngine, MicroKernelImpl, SchedPolicy, Workspace};
use crate::model::GemmDims;
use crate::runtime::dag::{execute_rank, execute_serial, GraphBuilder};
use crate::runtime::pool::SubTeam;
use crate::util::elem::Elem;
use crate::util::matrix::{Matrix, MatrixF64, MatView, MatViewMut};

use super::pfact::{SharedPanel, NO_ERR};
use super::trsm::trsm_right_upper;

/// Unblocked lower Cholesky of a small `q x q` block (in place; upper
/// triangle left untouched). Returns `Err(j)` when the matrix is not
/// positive definite at step j.
pub fn potf2(a: &mut MatViewMut<'_>) -> Result<(), usize> {
    potf2_t::<f64>(a)
}

/// [`potf2`] per element type. The square root goes through f64
/// (`E::from_f64(d.to_f64().sqrt())`), which is the identity
/// composition for `E = f64` — the historical path bit for bit — and a
/// correctly-converted f64 sqrt for f32.
pub fn potf2_t<E: Elem>(a: &mut MatViewMut<'_, E>) -> Result<(), usize> {
    let q = a.rows;
    assert_eq!(a.cols, q);
    for j in 0..q {
        let mut d = a.at(j, j);
        for t in 0..j {
            let l = a.at(j, t);
            d -= l * l;
        }
        if d.to_f64() <= 0.0 {
            return Err(j);
        }
        let d = E::from_f64(d.to_f64().sqrt());
        a.set(j, j, d);
        let inv = E::ONE / d;
        for i in j + 1..q {
            let mut v = a.at(i, j);
            for t in 0..j {
                v -= a.at(i, t) * a.at(j, t);
            }
            a.set(i, j, v * inv);
        }
    }
    Ok(())
}

/// Pre-factorization lower-triangle column sums of a panel
/// (f64-accumulated, overhead-accounted). Taken before `potf2`; only
/// entries `i >= j` are read — the strict upper triangle still holds
/// untouched symmetric input and stays out of the checksum entirely.
fn chol_panel_pre_sums<E: Elem>(panel: MatView<'_, E>, stats: &AbftStats) -> (Vec<f64>, Vec<f64>) {
    let t0 = std::time::Instant::now();
    let sums = lower_panel_colsums(panel);
    stats.add_overhead(t0.elapsed());
    sums
}

/// Detect-only ABFT re-verification of a factored Cholesky panel
/// (`potf2` + panel TRSM both applied): the factored L must reproduce
/// the pre-factorization lower column sums via the suffix-sum identity
/// checked by [`verify_chol_panel`]. A mismatch is recorded on the
/// engine's [`AbftStats`]; the caller surfaces it as
/// `DlaError::DataCorrupt { phase: "chol-panel", .. }`.
fn chol_panel_check<E: Elem>(
    panel: MatView<'_, E>,
    pre: &(Vec<f64>, Vec<f64>),
    origin: (usize, usize),
    stats: &AbftStats,
) {
    let t0 = std::time::Instant::now();
    let ok = verify_chol_panel(panel, &pre.0, &pre.1);
    stats.add_overhead(t0.elapsed());
    if ok {
        stats.block_done();
    } else {
        stats.detection();
        stats.record_failure(AbftPhase::CholPanel, origin);
    }
}

/// Blocked lower Cholesky in place; only the lower triangle of `a` is
/// referenced and overwritten with L. Trailing updates run through the
/// engine so they follow the co-design policy (and, like LU, reuse the
/// engine's persistent worker pool and memoized per-shape selections).
/// With the engine's lookahead enabled the SYRK sweep overlaps the next
/// panel's `potf2` + TRSM (module docs); results are bitwise identical.
pub fn cholesky_blocked(a: &mut MatrixF64, block: usize, engine: &mut GemmEngine) -> Result<(), usize> {
    let block = if block == 0 { engine.dag_tile_size_t::<f64>(a.rows()) } else { block };
    match engine.sched() {
        SchedPolicy::Dag => cholesky_blocked_dag::<f64>(a, block, engine),
        SchedPolicy::Lookahead if engine.lookahead().enabled() => {
            cholesky_blocked_lookahead(a, block, engine)
        }
        SchedPolicy::Lookahead => cholesky_blocked_baseline(a, block, engine),
    }
}

/// The dtype-generic blocked Cholesky behind [`cholesky_blocked`]: DAG
/// or serialized baseline. The deep-lookahead pipeline stays f64-only;
/// f64 callers reach it through [`cholesky_blocked`].
pub fn cholesky_blocked_t<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<(), usize> {
    let block = if block == 0 { engine.dag_tile_size_t::<E>(a.rows()) } else { block };
    match engine.sched() {
        SchedPolicy::Dag => cholesky_blocked_dag(a, block, engine),
        SchedPolicy::Lookahead => cholesky_blocked_baseline(a, block, engine),
    }
}

fn cholesky_blocked_baseline<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<(), usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s);
    assert!(block >= 1);
    let verify = engine.verify().enabled();
    let mut k = 0;
    while k < s {
        let b = block.min(s - k);
        let pre = verify.then(|| chol_panel_pre_sums(a.sub(k, k, s - k, b), engine.abft_stats()));
        // A11 = L11 L11^T
        {
            let mut a11 = a.sub_mut(k, k, b, b);
            potf2_t(&mut a11).map_err(|j| k + j)?;
        }
        if k + b < s {
            let rest = s - k - b;
            // A21 := A21 * L11^{-T}  (right solve with upper U = L11^T).
            {
                let l11t = a.sub(k, k, b, b).to_owned_matrix().transposed();
                let mut a21 = a.sub_mut(k + b, k, rest, b);
                trsm_right_upper(l11t.view(), &mut a21);
            }
            // A22 := A22 - A21 * A21^T (skinny-k GEMM with k = b).
            {
                let a21 = a.sub(k + b, k, rest, b).to_owned_matrix();
                let a21t = a21.transposed();
                let mut a22 = a.sub_mut(k + b, k + b, rest, rest);
                engine.gemm_t(E::from_f64(-1.0), a21.view(), a21t.view(), E::ONE, &mut a22);
            }
        }
        // Re-verify once the whole panel (potf2 + TRSM) is in place.
        if let Some(pre) = &pre {
            chol_panel_check(a.sub(k, k, s - k, b), pre, (k, k), engine.abft_stats());
        }
        k += b;
    }
    Ok(())
}

/// One node of the Cholesky tile DAG (see [`cholesky_blocked_dag`]).
#[derive(Clone, Copy)]
enum CholTask {
    /// ABFT pre-sums, `potf2` + panel TRSM, ABFT re-check on panel `t`.
    Panel { t: usize },
    /// Step-`t` SYRK slice on trailing block-column `j > t`.
    Update { t: usize, j: usize },
}

/// The tile-DAG dataflow pipeline (`DLA_SCHED=dag`): `Panel(t)` and
/// `Update(t, j)` tasks with edges `Panel(t) <- Update(t-1, t)`,
/// `Update(t, j) <- Panel(t)` and `<- Update(t-1, j)`, drained by the
/// pool ranks through work-stealing deques in one broadcast job
/// ([`crate::runtime::dag`]). Unlike LU there is no pivoting, so
/// nothing rewrites a factored panel: `Update(t, j)` reads `A21` of
/// step `t` zero-copy from the live matrix (stable after `Panel(t)`)
/// and needs no snapshots. Each update runs the step's GEMM slice under
/// the config planned on the **full** trailing dims, so the factor is
/// bitwise identical to the serialized baseline (`tests/dag.rs`).
fn cholesky_blocked_dag<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<(), usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s);
    assert!(block >= 1);
    let panels = s.div_ceil(block);
    let col_of = |t: usize| (t * block).min(s);
    let width_of = |t: usize| col_of(t + 1) - col_of(t);
    let abft_on = engine.verify().enabled();
    let abft_stats = std::sync::Arc::clone(engine.abft_stats());
    // Per-step SYRK configs on the full trailing dims (bitwise doctrine;
    // pre-planned — the engine's config memo is not Sync).
    let plans: Vec<(crate::model::ccp::GemmConfig, MicroKernelImpl<E>)> = (0..panels)
        .map(|t| {
            let rest = s - col_of(t + 1);
            let dims = if rest > 0 {
                GemmDims::new(rest, rest, width_of(t))
            } else {
                GemmDims::new(1, 1, 1) // last panel: never used
            };
            engine.plan_kernel_t::<E>(dims)
        })
        .collect();
    let err = AtomicUsize::new(NO_ERR);
    // --- Static task graph -------------------------------------------
    let mut gb = GraphBuilder::new();
    let mut tasks: Vec<CholTask> = Vec::new();
    let mut update_id: Vec<Vec<usize>> = vec![Vec::new(); panels]; // [t][j - t - 1]
    for t in 0..panels {
        let pid = gb.add_task();
        tasks.push(CholTask::Panel { t });
        if t > 0 {
            gb.add_edge(update_id[t - 1][0], pid); // Update(t-1, t)
        }
        for j in (t + 1)..panels {
            let id = gb.add_task();
            tasks.push(CholTask::Update { t, j });
            gb.add_edge(pid, id);
            if t > 0 {
                gb.add_edge(update_id[t - 1][j - t], id); // Update(t-1, j)
            }
            update_id[t].push(id);
        }
    }
    let pool = engine.pool().cloned();
    let threads = pool.as_ref().map_or(1, |p| p.threads());
    let graph = gb.seal(threads);
    let mut av = a.view_mut();
    let shared = SharedPanel::new(&mut av);
    let graph_ref = &graph;
    let body = |task: usize, ws: &mut Workspace| match tasks[task] {
        CholTask::Panel { t } => {
            let k = col_of(t);
            let b = width_of(t);
            // SAFETY: block-column t's earlier writers (Update(0..t, t))
            // are predecessors; its later readers (Update(t, ·)) are
            // successors; concurrent tasks touch other block-columns.
            let mut pv = unsafe { shared.sub(k, k, s - k, b).view_mut() };
            let pre = abft_on.then(|| chol_panel_pre_sums(pv.as_view(), &abft_stats));
            if let Err(j) = factor_panel(&mut pv, b) {
                err.store(k + j, Ordering::Release);
                graph_ref.cancel();
                return;
            }
            if let Some(pre) = &pre {
                chol_panel_check(pv.as_view(), pre, (k, k), &abft_stats);
            }
        }
        CholTask::Update { t, j } => {
            let k = col_of(t);
            let b = width_of(t);
            let o = k + b;
            let (cj, bj) = (col_of(j), width_of(j));
            // SAFETY: block-column j's previous writer Update(t-1, j) is
            // a predecessor; A21 of step t is stable (no task writes
            // block-column t after Panel(t)), so the immutable views
            // below may be shared with the step's other update tasks.
            unsafe {
                let a21 = shared.sub(o, k, s - o, b).view();
                // B = (A21)^T restricted to block-column j's columns
                // = transpose of A21's rows [cj - o, cj - o + bj).
                let bslice = shared.sub(cj, k, bj, b).to_owned_matrix().transposed();
                let (cfg, kern) = &plans[t];
                let mut c_s = shared.sub(o, cj, s - o, bj).view_mut();
                gemm_blocked(
                    cfg,
                    kern,
                    E::from_f64(-1.0),
                    a21,
                    bslice.view(),
                    E::ONE,
                    &mut c_s,
                    ws,
                );
            }
        }
    };
    if !graph.is_empty() {
        match &pool {
            Some(p) => {
                let job = |ctx: &crate::runtime::pool::PoolCtx<'_>| {
                    execute_rank(&graph, ctx, |t| {
                        let mut ws = ctx.workspace();
                        body(t, &mut ws);
                    });
                };
                p.run(&job);
            }
            None => {
                let mut ws = Workspace::new();
                execute_serial(&graph, |t| body(t, &mut ws));
            }
        }
    }
    let failed = err.load(Ordering::Acquire);
    if failed != NO_ERR {
        return Err(failed);
    }
    Ok(())
}

/// Factor one panel in place: `potf2` on the `b x b` diagonal block, then
/// the panel TRSM on the rows below it. Runs on the panel sub-team leader
/// inside the fused trailing update (and up front for panel 0).
fn factor_panel<E: Elem>(pv: &mut MatViewMut<'_, E>, b: usize) -> Result<(), usize> {
    let rows = pv.rows;
    {
        let mut a11 = pv.sub_mut(0, 0, b, b);
        potf2_t(&mut a11)?;
    }
    if b < rows {
        let l11t = pv.as_view().sub(0, 0, b, b).to_owned_matrix().transposed();
        let mut a21 = pv.sub_mut(b, 0, rows - b, b);
        trsm_right_upper(l11t.view(), &mut a21);
    }
    Ok(())
}

/// The queue-based deep pipeline (same skeleton as the LU work queue,
/// minus pivoting): every iteration enters with up to `depth` panels
/// factored ahead. The fused job's full team updates the columns
/// entering the lookahead window with this iteration's SYRK slice, then
/// the panel task replays the in-window iterations' SYRK slices on them
/// and factors them (`potf2` + panel TRSM, leader-sequential — unlike
/// LU's cooperative `getf2_team`, so the panel team is always one rank
/// and every other rank stays in the update sweep), while the update
/// sub-team sweeps the remainder. Per-column op order matches the
/// serialized baseline exactly, so the factor is bitwise identical.
fn cholesky_blocked_lookahead(
    a: &mut MatrixF64,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<(), usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s);
    let depth = engine.lookahead().depth.max(1);
    let panels = s.div_ceil(block);
    let col_of = |t: usize| (t * block).min(s);
    let width_of = |t: usize| col_of(t + 1) - col_of(t);
    let chain_ws = Mutex::new(Workspace::new());
    // ABFT panel re-verification (detect-only): owned stats handle +
    // flag, because the fused-job call holds the engine mutably while
    // the chain closure runs on the pool.
    let abft_on = engine.verify().enabled();
    let abft_stats = std::sync::Arc::clone(engine.abft_stats());
    // Panel 0 up front.
    {
        let b0 = width_of(0);
        let mut pv = a.sub_mut(0, 0, s, b0);
        let pre = abft_on.then(|| chol_panel_pre_sums(pv.as_view(), &abft_stats));
        factor_panel(&mut pv, b0)?;
        if let Some(pre) = &pre {
            chol_panel_check(pv.as_view(), pre, (0, 0), &abft_stats);
        }
    }
    let mut nf = 1usize;
    for t in 0..panels {
        let k = col_of(t);
        let b = width_of(t);
        if k + b >= s {
            continue;
        }
        let rest = s - k - b;
        let wend = col_of(nf);
        let nf_new = (t + 1 + depth).min(panels);
        if nf_new == nf {
            // Queue exhausted ⇒ the window covers the whole trailing
            // matrix; skip the would-be queue-empty job (no tail left).
            debug_assert!(wend >= s);
            continue;
        }
        let o = k + b;
        let head = [(wend - o, col_of(nf_new) - o)];
        let tail = (col_of(nf_new) - o, rest);
        // Configs to replay iterations (t, nf_new - 1) on the entering
        // columns, planned on each iteration's full trailing dims.
        let chain_plans: Vec<(crate::model::ccp::GemmConfig, crate::gemm::MicroKernelImpl)> =
            ((t + 1)..nf_new.saturating_sub(1))
                .map(|i| {
                    let mi = s - col_of(i) - width_of(i);
                    engine.plan_kernel(GemmDims::new(mi, mi, width_of(i)))
                })
                .collect();
        let errs: Vec<AtomicUsize> = (nf..nf_new).map(|_| AtomicUsize::new(NO_ERR)).collect();
        let a21 = a.sub(o, k, rest, b).to_owned_matrix();
        let a21t = a21.transposed();
        let mut a22 = a.sub_mut(o, o, rest, rest);
        let shared = SharedPanel::new(&mut a22);
        let chain = |sub: &SubTeam<'_>| {
            if sub.rank != 0 {
                return;
            }
            let mut wsg = chain_ws.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for (wi, w) in (nf..nf_new).enumerate() {
                let (cw, bw) = (col_of(w), width_of(w));
                let wc = cw - o;
                for i in (t + 1)..w {
                    let (ci, bi) = (col_of(i), width_of(i));
                    // SAFETY: the update team only touches tail columns;
                    // this task is the sole writer of the entering
                    // columns and sole reader of the stable in-window
                    // panels it replays from.
                    unsafe {
                        let a21i =
                            shared.sub(ci - o + bi, ci - o, s - ci - bi, bi).to_owned_matrix();
                        // B = (A21_i)^T restricted to panel w's columns
                        // = transpose of A21_i's rows [cw - ci - bi, +bw).
                        let bslice =
                            shared.sub(cw - o, ci - o, bw, bi).to_owned_matrix().transposed();
                        let (cfg_i, kern_i) = &chain_plans[i - (t + 1)];
                        let mut c_s = shared.sub(ci - o + bi, wc, s - ci - bi, bw).view_mut();
                        gemm_blocked(
                            cfg_i, kern_i, -1.0, a21i.view(), bslice.view(), 1.0, &mut c_s,
                            &mut wsg,
                        );
                    }
                }
                // SAFETY: as above; panel w's columns are fully updated.
                let mut pv = unsafe { shared.sub(wc, wc, s - cw, bw).view_mut() };
                let pre = abft_on.then(|| chol_panel_pre_sums(pv.as_view(), &abft_stats));
                if let Err(j) = factor_panel(&mut pv, bw) {
                    errs[wi].store(j, Ordering::Release);
                    return;
                }
                if let Some(pre) = &pre {
                    chol_panel_check(pv.as_view(), pre, (cw, cw), &abft_stats);
                }
            }
        };
        engine.gemm_fused_trailing_ranges(
            -1.0,
            a21.view(),
            a21t.view(),
            &mut a22,
            &head,
            tail,
            1,
            false, // never queue-empty: empty jobs are skipped above
            &chain,
        );
        for (wi, w) in (nf..nf_new).enumerate() {
            let failed = errs[wi].load(Ordering::Acquire);
            if failed != NO_ERR {
                return Err(col_of(w) + failed);
            }
        }
        nf = nf_new;
    }
    Ok(())
}

/// `max|A - L L^T|` over the lower triangle, normalized by `max|A|`.
pub fn cholesky_residual(a0: &MatrixF64, l_packed: &MatrixF64) -> f64 {
    let s = a0.rows();
    let l = MatrixF64::from_fn(s, s, |i, j| if i >= j { l_packed[(i, j)] } else { 0.0 });
    let lt = l.transposed();
    let mut llt = MatrixF64::zeros(s, s);
    crate::gemm::gemm_reference(1.0, l.view(), lt.view(), 0.0, &mut llt.view_mut());
    let mut err: f64 = 0.0;
    for j in 0..s {
        for i in j..s {
            err = err.max((a0[(i, j)] - llt[(i, j)]).abs());
        }
    }
    err / a0.max_abs().max(1e-300)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::ConfigMode;
    use crate::util::{MatrixF64, Pcg64};

    fn spd(s: usize, rng: &mut Pcg64) -> MatrixF64 {
        // A = M M^T + s*I is SPD.
        let m = MatrixF64::random(s, s, rng);
        let mt = m.transposed();
        let mut a = MatrixF64::zeros(s, s);
        crate::gemm::gemm_reference(1.0, m.view(), mt.view(), 0.0, &mut a.view_mut());
        for i in 0..s {
            a[(i, i)] += s as f64;
        }
        a
    }

    #[test]
    fn blocked_cholesky_reconstructs() {
        let mut rng = Pcg64::seed(60);
        for (s, b) in [(16, 4), (45, 8), (64, 64), (33, 7)] {
            let a0 = spd(s, &mut rng);
            let mut a = a0.clone();
            let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
            cholesky_blocked(&mut a, b, &mut eng).unwrap();
            let err = cholesky_residual(&a0, &a);
            assert!(err < 1e-11, "s={s} b={b}: residual {err}");
        }
    }

    #[test]
    fn blocked_matches_unblocked() {
        let mut rng = Pcg64::seed(61);
        let a0 = spd(24, &mut rng);
        let mut ab = a0.clone();
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        cholesky_blocked(&mut ab, 6, &mut eng).unwrap();
        let mut au = a0.clone();
        potf2(&mut au.view_mut()).unwrap();
        // Compare lower triangles.
        for j in 0..24 {
            for i in j..24 {
                assert!((ab[(i, j)] - au[(i, j)]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn non_spd_rejected() {
        let mut a = MatrixF64::identity(8);
        a[(5, 5)] = -1.0;
        let mut eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        assert_eq!(cholesky_blocked(&mut a, 4, &mut eng), Err(5));
    }
}
