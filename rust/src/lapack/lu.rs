//! Right-looking blocked LU factorization with partial pivoting — the
//! paper's Figure 2 algorithm, verbatim:
//!
//! ```text
//! for k = 0, b, 2b, ...          (loop F1)
//!   PFACT : [A11; A21] = [L11; L21] U11   (panel, partial pivoting)
//!   swaps : apply pivots to A(:, left) and A(:, right)
//!   TSOLVE: A12 := Lower_unit(A11)^{-1} A12
//!   GEMM  : A22 := A22 - A21 * A12        (trailing update, k-dim = b)
//! ```
//!
//! The trailing GEMM has `m = n = s - k - b` (shrinking) and constant
//! `k = b` — the skinny-k shape whose cache behaviour the paper studies.
//!
//! The whole driver is **generic over the element type**
//! ([`lu_blocked_t`] / [`lu_factor_t`]): the f64 instantiation is the
//! historical code path bit for bit, and the f32 instantiation runs the
//! same pooled lookahead pipeline with the width-aware configs — it is
//! the factorization stage of the mixed-precision solver in
//! [`crate::lapack::refine`].
//!
//! # Dynamic deep lookahead (the work-queue pipeline)
//!
//! With a [`crate::gemm::Lookahead`] policy enabled on the engine,
//! [`lu_blocked`] runs a queue-based pipeline that keeps up to
//! `Lookahead::depth` panels factored ahead of the trailing sweep. Each
//! iteration starts with its panel **already factored** (pivots
//! recorded, swaps *not yet applied*), applies the deferred swaps left
//! of the panel and right of the in-flight window ([`laswp_parallel`] on
//! the pool), TSOLVEs A12 right of the window, and issues one fused pool
//! job ([`GemmEngine::gemm_fused_trailing_ranges`]) that
//!
//! 1. updates the columns *entering* the window with the whole team
//!    (in-window columns were already updated by earlier jobs and are
//!    excluded),
//! 2. splits: a `t_p`-rank panel sub-team — sized per iteration by the
//!    malleable team-size model ([`crate::model::teamsize`]) — replays
//!    the in-window iterations on the entering columns (restricted
//!    swaps, TSOLVE slice, trailing-update slice) and factors them
//!    ([`getf2_team`]), while the update sub-team sweeps the remainder,
//! 3. rejoins at a single timed team barrier (per-phase idle counters).
//!
//! Deferring swaps past concurrent updates is exact: the trailing GEMM
//! updates each row independently, so permuting rows after the update
//! equals permuting before; the chain replays ops per column in exactly
//! the baseline's order. Pivots and factors are **bitwise identical** to
//! the non-lookahead pooled path for every depth (asserted by
//! `tests/lookahead.rs`): all paths plan one config per iteration on the
//! full trailing shape, which fixes every element's k-accumulation
//! order, and `getf2_team` replays `getf2`'s exact comparison and update
//! sequence.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::gemm::abft::{panel_colsums, verify_lu_panel, AbftPhase, AbftStats};
use crate::gemm::{gemm_blocked, GemmElem, GemmEngine, MicroKernelImpl, SchedPolicy, Workspace};
use crate::model::{GemmDims, PanelShape};
use crate::runtime::dag::{execute_rank, execute_serial, GraphBuilder};
use crate::runtime::pool::SubTeam;
use crate::util::elem::Elem;
use crate::util::matrix::{Matrix, MatrixF64, MatView, MatViewMut};

use super::pfact::{getf2, getf2_team, laswp, laswp_parallel, SharedPanel, NO_ERR};
use super::trsm::trsm_left_lower_unit;

/// Result of a blocked LU factorization (generic over the element type;
/// default `f64`, so pre-generic code keeps compiling unchanged).
pub struct LuFactors<E = f64> {
    /// Factored matrix: strictly-lower = L (unit diag), upper = U.
    pub lu: Matrix<E>,
    /// Pivot rows per step, LAPACK ipiv convention (0-based, relative to
    /// the whole matrix): at step j, rows j and pivots[j] were swapped.
    pub pivots: Vec<usize>,
    /// Algorithmic block size used.
    pub block: usize,
}

impl<E: Elem> LuFactors<E> {
    /// Apply the recorded permutation to a fresh copy of `x` (compute
    /// `P * x` where `P A = L U`).
    pub fn permute(&self, x: &Matrix<E>) -> Matrix<E> {
        let mut px = x.clone();
        for (j, &pj) in self.pivots.iter().enumerate() {
            if j != pj {
                for c in 0..px.cols() {
                    let t = px[(j, c)];
                    px[(j, c)] = px[(pj, c)];
                    px[(pj, c)] = t;
                }
            }
        }
        px
    }

    /// Explicit L factor (s x s, unit lower).
    pub fn l_matrix(&self) -> Matrix<E> {
        let s = self.lu.rows();
        Matrix::from_fn(s, s, |i, j| {
            if i == j {
                E::ONE
            } else if i > j {
                self.lu[(i, j)]
            } else {
                E::ZERO
            }
        })
    }

    /// Explicit U factor (s x s, upper).
    pub fn u_matrix(&self) -> Matrix<E> {
        let s = self.lu.rows();
        Matrix::from_fn(s, s, |i, j| if i <= j { self.lu[(i, j)] } else { E::ZERO })
    }

    /// Solve `A x = rhs` using the factorization (forward + backward
    /// substitution on the permuted right-hand side, in `E` precision).
    pub fn solve(&self, rhs: &Matrix<E>) -> Matrix<E> {
        let s = self.lu.rows();
        assert_eq!(rhs.rows(), s);
        let mut x = self.permute(rhs);
        // Forward: L y = P rhs (unit lower).
        trsm_left_lower_unit(self.lu.view(), &mut x.view_mut());
        // Backward: U x = y.
        for c in 0..x.cols() {
            for jj in (0..s).rev() {
                let mut acc = x[(jj, c)];
                for t in jj + 1..s {
                    let delta = self.lu[(jj, t)] * x[(t, c)];
                    acc -= delta;
                }
                x[(jj, c)] = acc / self.lu[(jj, jj)];
            }
        }
        x
    }

    /// Residual `max|P A - L U|` against the original matrix, normalized
    /// by `max|A|` (cheap full-reconstruction check used by tests and the
    /// end-to-end example).
    pub fn reconstruction_error(&self, a0: &Matrix<E>) -> f64 {
        let pa = self.permute(a0);
        let l = self.l_matrix();
        let u = self.u_matrix();
        let mut lu = Matrix::<E>::zeros(pa.rows(), pa.cols());
        crate::gemm::gemm_reference(E::ONE, l.view(), u.view(), E::ZERO, &mut lu.view_mut());
        pa.max_abs_diff(&lu) / a0.max_abs().max(1e-300)
    }
}

/// Apply the panel's row interchanges to the columns left of it and to
/// the columns from `right_from` rightward, on the worker pool when the
/// engine has one (the `laswp` satellite: the seed swapped rows with a
/// sequential per-row loop over the full width while the whole team
/// idled). The gap `[k + b, right_from)` is the deep-lookahead window:
/// those in-flight panels received this panel's swaps inside the fused
/// chains that readied them (the baseline passes `right_from = k + b`,
/// i.e. no gap).
fn apply_panel_swaps<E: Elem>(
    a: &mut Matrix<E>,
    k: usize,
    right_from: usize,
    piv_local: &[usize],
    engine: &GemmEngine,
) {
    let s = a.rows();
    let pool = engine.pool().cloned();
    let mut swap = |view: &mut MatViewMut<'_, E>| match &pool {
        Some(p) => laswp_parallel(view, k, piv_local, p),
        None => laswp(view, k, piv_local),
    };
    if k > 0 {
        let mut left = a.sub_mut(0, 0, s, k);
        swap(&mut left);
    }
    if right_from < s {
        let mut right = a.sub_mut(0, right_from, s, s - right_from);
        swap(&mut right);
    }
}

/// Pre-factorization column sums of a panel, f64-accumulated
/// (overhead-accounted on `stats`). Column sums are invariant under the
/// panel's own row interchanges, so they can be taken *before* `getf2`
/// and checked against the factored `L`/`U` afterwards.
fn lu_panel_pre_sums<E: Elem>(panel: MatView<'_, E>, stats: &AbftStats) -> (Vec<f64>, Vec<f64>) {
    let t0 = std::time::Instant::now();
    let sums = panel_colsums(panel);
    stats.add_overhead(t0.elapsed());
    sums
}

/// Detect-only ABFT re-verification of a factored panel: the factored
/// `L`/`U` must reproduce the pre-factorization column sums via the
/// permutation-invariant identity checked by
/// [`verify_lu_panel`]. A mismatch is recorded on
/// the engine's [`AbftStats`] with the panel's global origin; the driver
/// finishes and the caller surfaces the failure as
/// `DlaError::DataCorrupt { phase: "lu-panel", .. }` (panels are not
/// recomputed — correction covers the packed GEMM operands only).
fn lu_panel_check<E: Elem>(
    panel: MatView<'_, E>,
    pre: &(Vec<f64>, Vec<f64>),
    origin: (usize, usize),
    stats: &AbftStats,
) {
    let t0 = std::time::Instant::now();
    let ok = verify_lu_panel(panel, &pre.0, &pre.1);
    stats.add_overhead(t0.elapsed());
    if ok {
        stats.block_done();
    } else {
        stats.detection();
        stats.record_failure(AbftPhase::LuPanel, origin);
    }
}

/// Blocked right-looking LU with partial pivoting, in place over `a`,
/// trailing updates through the supplied [`GemmEngine`] (this is where
/// the co-design policy — CCPs + micro-kernel per call — takes effect).
/// With the engine's [`crate::gemm::Lookahead`] policy enabled this runs
/// the fused lookahead pipeline (see the module docs); results are
/// bitwise identical either way.
///
/// Returns `Err(col)` when the factorization breaks down at global
/// column `col`: the pivot search found an exact zero **or a non-finite
/// value** (NaN/Inf inputs poison the pivot column — see
/// [`super::pfact::getf2`]). The coordinator surfaces this as
/// `DlaError::Singular { pivot: col }`.
///
/// The engine amortizes two costs across the factorization sweep: its
/// persistent worker pool (parallel plans spawn threads once, not per
/// trailing update) and its config-selection memo cache (each distinct
/// trailing shape `(s-k-b) x (s-k-b) x b` runs the scorer once; repeated
/// factorizations of equal order are pure cache hits).
pub fn lu_blocked(a: &mut MatrixF64, block: usize, engine: &mut GemmEngine) -> Result<Vec<usize>, usize> {
    lu_blocked_t::<f64>(a, block, engine)
}

/// The dtype-generic blocked LU behind [`lu_blocked`]: an f32
/// factorization runs the identical (baseline or lookahead) pipeline on
/// the same shared pool, under the f32-width model configs.
pub fn lu_blocked_t<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<Vec<usize>, usize> {
    // `block == 0` is the model-selection sentinel: the analytic scorer
    // picks the tile width for this order and dtype.
    let block = if block == 0 { engine.dag_tile_size_t::<E>(a.rows()) } else { block };
    match engine.sched() {
        SchedPolicy::Dag => lu_blocked_dag(a, block, engine),
        SchedPolicy::Lookahead => {
            if engine.lookahead().enabled() {
                lu_blocked_lookahead(a, block, engine)
            } else {
                lu_blocked_baseline(a, block, engine)
            }
        }
    }
}

/// The non-lookahead pipeline: factor panel, swap, solve, update —
/// strictly serialized per iteration.
fn lu_blocked_baseline<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<Vec<usize>, usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s, "LU requires a square matrix");
    assert!(block >= 1);
    let verify = engine.verify().enabled();
    let mut pivots = vec![0usize; s];
    let mut k = 0;
    while k < s {
        let b = block.min(s - k);
        // --- PFACT on the panel A[k.., k..k+b] --------------------------
        {
            let mut panel = a.sub_mut(k, k, s - k, b);
            let pre = verify.then(|| lu_panel_pre_sums(panel.as_view(), engine.abft_stats()));
            let mut piv_local = vec![0usize; b];
            getf2(&mut panel, &mut piv_local).map_err(|j| k + j)?;
            if let Some(pre) = &pre {
                lu_panel_check(panel.as_view(), pre, (k, k), engine.abft_stats());
            }
            for (j, pj) in piv_local.iter().enumerate() {
                pivots[k + j] = k + pj;
            }
        }
        // --- Row interchanges on the left and right of the panel --------
        {
            let piv_local: Vec<usize> = (0..b).map(|j| pivots[k + j] - k).collect();
            apply_panel_swaps(a, k, k + b, &piv_local, engine);
        }
        if k + b < s {
            let rest = s - k - b;
            // --- TSOLVE: A12 := L11^{-1} A12 ----------------------------
            {
                let l11 = a.sub(k, k, b, b).to_owned_matrix();
                let mut a12 = a.sub_mut(k, k + b, b, rest);
                trsm_left_lower_unit(l11.view(), &mut a12);
            }
            // --- GEMM: A22 -= A21 * A12 (k-dimension = b) ---------------
            {
                let a21 = a.sub(k + b, k, rest, b).to_owned_matrix();
                let a12 = a.sub(k, k + b, b, rest).to_owned_matrix();
                let mut a22 = a.sub_mut(k + b, k + b, rest, rest);
                engine.gemm_t(E::from_f64(-1.0), a21.view(), a12.view(), E::ONE, &mut a22);
            }
        }
        k += b;
    }
    Ok(pivots)
}

/// One node of the LU tile DAG (see [`lu_blocked_dag`]).
#[derive(Clone, Copy)]
enum LuTask {
    /// PFACT on panel `t` (ABFT pre-sums / `getf2` / re-check), pivot
    /// publication, and the `L11`/`L21` snapshots the update tasks read.
    Panel { t: usize },
    /// Deferred step-`t` row interchanges on finished block-column
    /// `j < t` (the "left of the panel" half of the baseline's swap).
    Left { t: usize, j: usize },
    /// Step-`t` ops on trailing block-column `j > t`: row interchanges,
    /// TSOLVE slice, and the trailing-update GEMM slice.
    Update { t: usize, j: usize },
}

/// The tile-DAG dataflow pipeline (`DLA_SCHED=dag`): the factorization
/// is decomposed into per-block-column tasks — `Panel(t)`, `Update(t,
/// j)` for `j > t`, `Left(t, j)` for `j < t` — with explicit dataflow
/// edges
///
/// - `Panel(t) <- Update(t-1, t)` (the panel must receive step t-1),
/// - `Update(t, j) <- Panel(t)` and `<- Update(t-1, j)`,
/// - `Left(t, j) <- Panel(t)` and `<- Left(t-1, j)` when `j < t - 1`
///   (for `j = t - 1` the `Panel(t-1) -> Update(t-1, t) -> Panel(t)`
///   chain already orders the hand-off),
///
/// drained by the pool ranks through per-worker work-stealing deques
/// ([`crate::runtime::dag`]) inside **one** broadcast job — no
/// stop-the-world barrier between iterations, and zero thread spawns.
///
/// `Panel(t)` snapshots `L11`/`L21` into per-step scratch before
/// publishing: `Left(t+1, t)` swaps rows of live block-column `t`
/// concurrently with `Update(t, j)` reads, so the update tasks read the
/// frozen snapshot, never the live panel. Each `Update(t, j)` runs the
/// baseline's exact per-column op sequence (swap, TSOLVE, GEMM slice
/// under the step's config planned on the **full** trailing dims), so
/// factors and pivots are bitwise identical to the serialized baseline
/// — the same argument as the lookahead chain, asserted by
/// `tests/dag.rs`.
///
/// Breakdown (zero/non-finite pivot) stores the failing global column
/// in an error slot and cancels the graph: in-flight tasks finish,
/// nothing new is scheduled, and the driver returns `Err(col)`.
fn lu_blocked_dag<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<Vec<usize>, usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s, "LU requires a square matrix");
    assert!(block >= 1);
    let panels = s.div_ceil(block);
    let col_of = |t: usize| (t * block).min(s);
    let width_of = |t: usize| col_of(t + 1) - col_of(t);
    let abft_on = engine.verify().enabled();
    let abft_stats = std::sync::Arc::clone(engine.abft_stats());
    // Per-step trailing-GEMM configs, planned on the FULL trailing dims
    // (the bitwise doctrine: every column slice of step t runs under the
    // config the serialized baseline would use for the whole update).
    // Planned up front — the engine's config memo is not Sync.
    let plans: Vec<(crate::model::ccp::GemmConfig, MicroKernelImpl<E>)> = (0..panels)
        .map(|t| {
            let rest = s - col_of(t + 1);
            let dims = if rest > 0 {
                GemmDims::new(rest, rest, width_of(t))
            } else {
                GemmDims::new(1, 1, 1) // last panel: never used
            };
            engine.plan_kernel_t::<E>(dims)
        })
        .collect();
    // Per-step L11 / L21 snapshot storage (written once by Panel(t),
    // read concurrently by every Update(t, j)).
    let mut l11_store: Vec<Matrix<E>> =
        (0..panels).map(|t| Matrix::zeros(width_of(t), width_of(t))).collect();
    let mut a21_store: Vec<Matrix<E>> = (0..panels)
        .map(|t| Matrix::zeros((s - col_of(t + 1)).max(1), width_of(t)))
        .collect();
    let l11_sp: Vec<SharedPanel<E>> = l11_store
        .iter_mut()
        .map(|m| {
            let mut v = m.view_mut();
            SharedPanel::new(&mut v)
        })
        .collect();
    let a21_sp: Vec<SharedPanel<E>> = a21_store
        .iter_mut()
        .map(|m| {
            let mut v = m.view_mut();
            SharedPanel::new(&mut v)
        })
        .collect();
    // Pivot slots (published by Panel(t) with Release; consumed by the
    // swap tasks, which are graph-ordered after it) and the breakdown
    // slot. Panels are totally ordered by the dependency chain, so at
    // most one panel can fail before the cancellation lands.
    let pivots_a: Vec<AtomicUsize> = (0..s).map(|_| AtomicUsize::new(0)).collect();
    let err = AtomicUsize::new(NO_ERR);
    // --- Static task graph -------------------------------------------
    let mut gb = GraphBuilder::new();
    let mut tasks: Vec<LuTask> = Vec::new();
    // update_id[t][j - t - 1] = Update(t, j); left_id[t][j] = Left(t, j).
    let mut update_id: Vec<Vec<usize>> = vec![Vec::new(); panels];
    let mut left_id: Vec<Vec<usize>> = vec![Vec::new(); panels];
    for t in 0..panels {
        let pid = gb.add_task();
        tasks.push(LuTask::Panel { t });
        if t > 0 {
            gb.add_edge(update_id[t - 1][0], pid); // Update(t-1, t)
        }
        for j in 0..t {
            let id = gb.add_task();
            tasks.push(LuTask::Left { t, j });
            gb.add_edge(pid, id);
            if j + 1 < t {
                gb.add_edge(left_id[t - 1][j], id);
            }
            left_id[t].push(id);
        }
        for j in (t + 1)..panels {
            let id = gb.add_task();
            tasks.push(LuTask::Update { t, j });
            gb.add_edge(pid, id);
            if t > 0 {
                gb.add_edge(update_id[t - 1][j - t], id); // Update(t-1, j)
            }
            update_id[t].push(id);
        }
    }
    let pool = engine.pool().cloned();
    let threads = pool.as_ref().map_or(1, |p| p.threads());
    let graph = gb.seal(threads);
    let mut av = a.view_mut();
    let shared = SharedPanel::new(&mut av);
    let graph_ref = &graph;
    let body = |task: usize, ws: &mut Workspace| match tasks[task] {
        LuTask::Panel { t } => {
            let k = col_of(t);
            let b = width_of(t);
            let rest = s - k - b;
            // SAFETY: Panel(t) is block-column t's sole toucher here —
            // every earlier writer (Update(0..t, t)) is a predecessor,
            // and later readers/writers (Update(t, ·) read snapshots,
            // Left(·, t) swaps) are successors.
            let mut pv = unsafe { shared.sub(k, k, s - k, b).view_mut() };
            let pre = abft_on.then(|| lu_panel_pre_sums(pv.as_view(), &abft_stats));
            let mut piv_local = vec![0usize; b];
            if let Err(j) = getf2(&mut pv, &mut piv_local) {
                err.store(k + j, Ordering::Release);
                graph_ref.cancel();
                return;
            }
            if let Some(pre) = &pre {
                lu_panel_check(pv.as_view(), pre, (k, k), &abft_stats);
            }
            for (j, pj) in piv_local.iter().enumerate() {
                pivots_a[k + j].store(k + pj, Ordering::Release);
            }
            if rest > 0 {
                // Freeze L11 / L21 for the update tasks: Left(t+1, t)
                // will swap the live panel while they run.
                // SAFETY: the snapshots are written only here, and every
                // reader is a graph successor.
                unsafe {
                    let mut l11d = l11_sp[t].view_mut();
                    for c in 0..b {
                        for r in 0..b {
                            l11d.set(r, c, pv.at(r, c));
                        }
                    }
                    let mut a21d = a21_sp[t].view_mut();
                    for c in 0..b {
                        for r in 0..rest {
                            a21d.set(r, c, pv.at(b + r, c));
                        }
                    }
                }
            }
        }
        LuTask::Left { t, j } => {
            let k = col_of(t);
            let b = width_of(t);
            let (cj, bj) = (col_of(j), width_of(j));
            let piv_local: Vec<usize> =
                (0..b).map(|jj| pivots_a[k + jj].load(Ordering::Acquire) - k).collect();
            // SAFETY: block-column j's previous writer (Left(t-1, j) or,
            // for j = t - 1, Panel(t-1) via the panel chain) is a
            // predecessor; concurrent tasks touch other block-columns.
            unsafe {
                let mut colsj = shared.sub(0, cj, s, bj).view_mut();
                laswp(&mut colsj, k, &piv_local);
            }
        }
        LuTask::Update { t, j } => {
            let k = col_of(t);
            let b = width_of(t);
            let o = k + b;
            let (cj, bj) = (col_of(j), width_of(j));
            let piv_local: Vec<usize> =
                (0..b).map(|jj| pivots_a[k + jj].load(Ordering::Acquire) - k).collect();
            // SAFETY: block-column j's previous writer Update(t-1, j) is
            // a predecessor; L11/L21 are frozen snapshots (read-only
            // after Panel(t)); concurrent tasks touch other columns.
            unsafe {
                {
                    let mut colsj = shared.sub(0, cj, s, bj).view_mut();
                    laswp(&mut colsj, k, &piv_local);
                }
                {
                    let l11 = l11_sp[t].view();
                    let mut a12 = shared.sub(k, cj, b, bj).view_mut();
                    trsm_left_lower_unit(l11, &mut a12);
                }
                {
                    let b12 = shared.sub(k, cj, b, bj).to_owned_matrix();
                    let a21 = a21_sp[t].view();
                    let (cfg, kern) = &plans[t];
                    let mut c_s = shared.sub(o, cj, s - o, bj).view_mut();
                    gemm_blocked(
                        cfg,
                        kern,
                        E::from_f64(-1.0),
                        a21,
                        b12.view(),
                        E::ONE,
                        &mut c_s,
                        ws,
                    );
                }
            }
        }
    };
    if !graph.is_empty() {
        match &pool {
            Some(p) => {
                let job = |ctx: &crate::runtime::pool::PoolCtx<'_>| {
                    execute_rank(&graph, ctx, |t| {
                        let mut ws = ctx.workspace();
                        body(t, &mut ws);
                    });
                };
                p.run(&job);
            }
            None => {
                let mut ws = Workspace::new();
                execute_serial(&graph, |t| body(t, &mut ws));
            }
        }
    }
    let failed = err.load(Ordering::Acquire);
    if failed != NO_ERR {
        return Err(failed);
    }
    Ok(pivots_a.iter().map(|p| p.load(Ordering::Acquire)).collect())
}

/// The dynamic deep-lookahead pipeline (module docs): a work-queue of
/// pending panels keeps up to `Lookahead::depth` panels factored ahead
/// of the trailing sweep.
///
/// Invariant at the top of iteration `t` (with `nf` = first unfactored
/// panel, clamped to `min(t + depth, panels)` by the previous job):
///
/// - panels `0..nf` are factored, their pivots recorded;
/// - the in-flight **window** columns `[col(t+1), col(nf))` have
///   received *every* op (swaps / TSOLVE / GEMM) of iterations
///   `0..their own panel index` — applied by the fused chains that
///   readied them;
/// - columns `>= col(nf)` have received the ops of iterations `0..t`
///   exactly.
///
/// Iteration `t` then (1) applies panel `t`'s deferred swaps left of the
/// panel and right of the window, (2) TSOLVEs row-block `t` right of the
/// window, and (3) issues one fused job whose full team first updates
/// the columns *entering* the window, whose panel sub-team (sized by the
/// malleable team-size model) replays the in-window iterations on those
/// columns and factors them (`getf2_team`), and whose update sub-team
/// sweeps the remainder. Per-column op order — and therefore every bit
/// of the result — is identical to the serialized baseline.
fn lu_blocked_lookahead<E: GemmElem>(
    a: &mut Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<Vec<usize>, usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s, "LU requires a square matrix");
    assert!(block >= 1);
    let la = engine.lookahead(); // resolved once; per-iteration calls reuse it
    let depth = la.depth.max(1);
    let panels = s.div_ceil(block);
    let col_of = |t: usize| (t * block).min(s);
    let width_of = |t: usize| col_of(t + 1) - col_of(t);
    let mut pivots = vec![0usize; s];
    // Scratch for the chain's restricted mini-updates; one allocation
    // per factorization, locked only by the panel sub-team leader.
    let chain_ws = Mutex::new(Workspace::new());
    // ABFT panel re-verification (detect-only): captured as an owned
    // stats handle + flag because the fused-job call below holds the
    // engine mutably while the chain closure runs on the pool.
    let abft_on = engine.verify().enabled();
    let abft_stats = std::sync::Arc::clone(engine.abft_stats());
    // Factor panel 0 up front (nothing to overlap it with yet).
    {
        let b0 = width_of(0);
        let mut panel = a.sub_mut(0, 0, s, b0);
        let pre = abft_on.then(|| lu_panel_pre_sums(panel.as_view(), &abft_stats));
        let mut piv_local = vec![0usize; b0];
        getf2(&mut panel, &mut piv_local)?;
        if let Some(pre) = &pre {
            lu_panel_check(panel.as_view(), pre, (0, 0), &abft_stats);
        }
        pivots[..b0].copy_from_slice(&piv_local);
    }
    let mut nf = 1usize; // work-queue head: first unfactored panel
    for t in 0..panels {
        let k = col_of(t);
        let b = width_of(t);
        debug_assert!(nf > t, "panel {t} must be factored before its iteration");
        let wend = col_of(nf);
        // --- Deferred swaps of panel t: left of the panel and right of
        // the window (in-window columns got them inside the chains).
        {
            let piv_local: Vec<usize> = (0..b).map(|j| pivots[k + j] - k).collect();
            apply_panel_swaps(a, k, wend, &piv_local, engine);
        }
        if k + b >= s {
            continue; // last panel: nothing trailing
        }
        let rest = s - k - b;
        // --- TSOLVE row-block t right of the window (the window slice
        // of A12 was solved when those panels were readied).
        if wend < s {
            let l11 = a.sub(k, k, b, b).to_owned_matrix();
            let mut a12r = a.sub_mut(k, wend, b, s - wend);
            trsm_left_lower_unit(l11.view(), &mut a12r);
        }
        let nf_new = (t + 1 + depth).min(panels);
        if nf_new == nf {
            // The queue can only fail to advance once every panel is
            // factored (nf == panels), and then the window covers the
            // whole trailing matrix — the drivers *skip* would-be
            // queue-empty jobs instead of stalling a panel team on them
            // (wend == s here, so there is no tail to sweep either).
            debug_assert!(wend >= s);
            continue;
        }
        // --- One fused job: head = columns entering the window, tail =
        // the remainder; the in-window prefix [0, wend - o) is excluded
        // (already updated past iteration t).
        let o = k + b; // a22 origin (absolute row/col)
        let head = [(wend - o, col_of(nf_new) - o)];
        let tail = (col_of(nf_new) - o, rest);
        let t_p = engine.panel_team_size_t::<E>(
            la,
            t,
            PanelShape::new(s - wend, width_of(nf)),
            GemmDims::new(rest, rest, b),
        );
        // Configs the chain needs to replay iterations (t, nf_new - 1)
        // restricted to entering columns — planned on each iteration's
        // *full* trailing dims, exactly as its own fused job will plan.
        let chain_plans: Vec<(crate::model::ccp::GemmConfig, MicroKernelImpl<E>)> =
            ((t + 1)..nf_new.saturating_sub(1))
                .map(|i| {
                    let mi = s - col_of(i) - width_of(i);
                    engine.plan_kernel_t::<E>(GemmDims::new(mi, mi, width_of(i)))
                })
                .collect();
        // Pivot slots and error flags, one set per entering panel.
        let piv_next: Vec<Vec<AtomicUsize>> = (nf..nf_new)
            .map(|w| (0..width_of(w)).map(|_| AtomicUsize::new(0)).collect())
            .collect();
        let errs: Vec<AtomicUsize> = (nf..nf_new).map(|_| AtomicUsize::new(NO_ERR)).collect();
        let a21 = a.sub(o, k, rest, b).to_owned_matrix();
        let a12 = a.sub(k, o, b, rest).to_owned_matrix();
        let mut a22 = a.sub_mut(o, o, rest, rest);
        let shared = SharedPanel::new(&mut a22);
        let pivots_ref = &pivots;
        let chain = |sub: &SubTeam<'_>| {
            for (wi, w) in (nf..nf_new).enumerate() {
                let (cw, bw) = (col_of(w), width_of(w));
                let wc = cw - o; // panel w's columns, a22-relative
                if sub.rank == 0 {
                    // Replay iterations (t, w) on panel w's columns:
                    // swaps, TSOLVE slice, trailing-update slice — the
                    // exact per-column op order of the baseline.
                    let mut wsg =
                        chain_ws.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
                    for i in (t + 1)..w {
                        let (ci, bi) = (col_of(i), width_of(i));
                        let piv_i: Vec<usize> = if i < nf {
                            (0..bi).map(|j| pivots_ref[ci + j] - ci).collect()
                        } else {
                            piv_next[i - nf].iter().map(|p| p.load(Ordering::Acquire)).collect()
                        };
                        // SAFETY (all shared accesses below): the update
                        // team only touches tail columns; within the
                        // panel team, rank 0 is the sole writer here and
                        // the getf2_team barriers order the hand-offs.
                        unsafe {
                            let mut wcols = shared.sub(0, wc, rest, bw).view_mut();
                            laswp(&mut wcols, ci - o, &piv_i);
                            let l11 = shared.sub(ci - o, ci - o, bi, bi).to_owned_matrix();
                            let mut a12s = shared.sub(ci - o, wc, bi, bw).view_mut();
                            trsm_left_lower_unit(l11.view(), &mut a12s);
                            let a21i = shared.sub(ci - o + bi, ci - o, s - ci - bi, bi)
                                .to_owned_matrix();
                            let b12 = shared.sub(ci - o, wc, bi, bw).to_owned_matrix();
                            let (cfg_i, kern_i) = &chain_plans[i - (t + 1)];
                            let mut c_s = shared.sub(ci - o + bi, wc, s - ci - bi, bw).view_mut();
                            gemm_blocked(
                                cfg_i,
                                kern_i,
                                E::from_f64(-1.0),
                                a21i.view(),
                                b12.view(),
                                E::ONE,
                                &mut c_s,
                                &mut wsg,
                            );
                        }
                    }
                }
                // Panel w is ready: the whole panel sub-team factors it.
                let panel_sh = shared.sub(wc, wc, s - cw, bw);
                // ABFT pre-sums on the readied panel (after the replay,
                // before factoring). SAFETY: rank 0 is the sole toucher
                // of these columns until the first getf2_team barrier,
                // where the other ranks are still waiting.
                let pre = (abft_on && sub.rank == 0)
                    .then(|| unsafe { lu_panel_pre_sums(panel_sh.view_mut().as_view(), &abft_stats) });
                getf2_team(&panel_sh, &piv_next[wi], &errs[wi], sub);
                if errs[wi].load(Ordering::Acquire) != NO_ERR {
                    return; // uniform: every rank observes the error
                }
                // SAFETY: getf2_team's final barrier ordered every
                // rank's writes before this read, and no rank writes
                // panel w's columns again within this job.
                if let Some(pre) = &pre {
                    unsafe {
                        lu_panel_check(panel_sh.view_mut().as_view(), pre, (cw, cw), &abft_stats);
                    }
                }
            }
        };
        engine.gemm_fused_trailing_ranges_t::<E>(
            E::from_f64(-1.0),
            a21.view(),
            a12.view(),
            &mut a22,
            &head,
            tail,
            t_p,
            false, // never queue-empty: empty jobs are skipped above
            &chain,
        );
        for (wi, w) in (nf..nf_new).enumerate() {
            let failed = errs[wi].load(Ordering::Acquire);
            if failed != NO_ERR {
                return Err(col_of(w) + failed);
            }
            let cw = col_of(w);
            for (j, pj) in piv_next[wi].iter().enumerate() {
                pivots[cw + j] = cw + pj.load(Ordering::Acquire);
            }
        }
        nf = nf_new;
    }
    Ok(pivots)
}

/// Convenience wrapper returning [`LuFactors`] (FP64). Inherits the
/// breakdown contract of [`lu_blocked`]: `Err(col)` on a zero or
/// non-finite pivot at global column `col`.
pub fn lu_factor(a0: &MatrixF64, block: usize, engine: &mut GemmEngine) -> Result<LuFactors, usize> {
    lu_factor_t::<f64>(a0, block, engine)
}

/// [`lu_factor`] per element type.
pub fn lu_factor_t<E: GemmElem>(
    a0: &Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<LuFactors<E>, usize> {
    let mut a = a0.clone();
    let pivots = lu_blocked_t::<E>(&mut a, block, engine)?;
    Ok(LuFactors { lu: a, pivots, block })
}

/// Flop count of an LU factorization of order s (2/3 s^3 to leading order;
/// exact: `s^2(s-1)/2 * ...` — we use the standard `2/3 s^3 - s^2/2` form
/// the paper's GFLOPS plots divide by).
pub fn lu_flops(s: usize) -> f64 {
    let sf = s as f64;
    2.0 / 3.0 * sf * sf * sf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::ConfigMode;
    use crate::util::{MatrixF32, MatrixF64, Pcg64};

    fn engine() -> GemmEngine {
        GemmEngine::new(host_xeon(), ConfigMode::Refined)
    }

    #[test]
    fn lu_reconstructs_pa() {
        let mut rng = Pcg64::seed(42);
        for (s, b) in [(16, 4), (50, 8), (64, 64), (37, 5), (96, 32)] {
            let a0 = MatrixF64::random(s, s, &mut rng);
            let f = lu_factor(&a0, b, &mut engine()).unwrap();
            let err = f.reconstruction_error(&a0);
            assert!(err < 1e-10, "s={s} b={b}: |PA - LU| = {err}");
        }
    }

    #[test]
    fn lu_matches_unblocked_getf2() {
        // The blocked algorithm must produce exactly the same factors and
        // pivots as the unblocked reference (partial pivoting is
        // deterministic).
        let mut rng = Pcg64::seed(43);
        let a0 = MatrixF64::random(24, 24, &mut rng);
        let f = lu_factor(&a0, 6, &mut engine()).unwrap();
        let mut ref_a = a0.clone();
        let mut ref_piv = vec![0usize; 24];
        getf2(&mut ref_a.view_mut(), &mut ref_piv).unwrap();
        assert_eq!(f.pivots, ref_piv, "pivot sequence differs from getf2");
        assert!(f.lu.max_abs_diff(&ref_a) < 1e-9, "factors differ from getf2");
    }

    #[test]
    fn lu_block_size_does_not_change_result() {
        let mut rng = Pcg64::seed(44);
        let a0 = MatrixF64::random(48, 48, &mut rng);
        let f1 = lu_factor(&a0, 4, &mut engine()).unwrap();
        let f2 = lu_factor(&a0, 16, &mut engine()).unwrap();
        let f3 = lu_factor(&a0, 48, &mut engine()).unwrap();
        assert!(f1.lu.max_abs_diff(&f2.lu) < 1e-9);
        assert!(f1.lu.max_abs_diff(&f3.lu) < 1e-9);
        assert_eq!(f1.pivots, f2.pivots);
        assert_eq!(f1.pivots, f3.pivots);
    }

    #[test]
    fn lu_solve_linear_system() {
        let mut rng = Pcg64::seed(45);
        let a0 = MatrixF64::random_diag_dominant(40, &mut rng);
        let x_true = MatrixF64::random(40, 3, &mut rng);
        let mut rhs = MatrixF64::zeros(40, 3);
        crate::gemm::gemm_reference(1.0, a0.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let f = lu_factor(&a0, 8, &mut engine()).unwrap();
        let x = f.solve(&rhs);
        assert!(x.max_abs_diff(&x_true) < 1e-8);
    }

    #[test]
    fn lu_singular_detected() {
        let mut a = MatrixF64::zeros(8, 8);
        for i in 0..8 {
            a[(i, i)] = 1.0;
        }
        // Make column 3 linearly dependent (equal to column 2).
        for i in 0..8 {
            let v = a[(i, 2)];
            a[(i, 3)] = v;
        }
        let err = lu_factor(&a, 4, &mut engine());
        assert!(err.is_err(), "rank-deficient matrix must be detected");
    }

    #[test]
    fn lu_block_larger_than_matrix() {
        let mut rng = Pcg64::seed(46);
        let a0 = MatrixF64::random(10, 10, &mut rng);
        let f = lu_factor(&a0, 64, &mut engine()).unwrap();
        assert!(f.reconstruction_error(&a0) < 1e-11);
    }

    #[test]
    fn pivot_growth_bounded() {
        // With partial pivoting all multipliers are <= 1.
        let mut rng = Pcg64::seed(47);
        let a0 = MatrixF64::random(30, 30, &mut rng);
        let f = lu_factor(&a0, 8, &mut engine()).unwrap();
        for j in 0..30 {
            for i in j + 1..30 {
                assert!(f.lu[(i, j)].abs() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn f32_lu_reconstructs_pa_and_single_panel_equals_getf2() {
        // The generic driver at E = f32: same pipeline, f32 tolerances.
        let mut rng = Pcg64::seed(48);
        let a0 = MatrixF32::random(48, 48, &mut rng);
        let f = lu_factor_t::<f32>(&a0, 8, &mut engine()).unwrap();
        assert!(f.reconstruction_error(&a0) < 1e-4, "{}", f.reconstruction_error(&a0));
        // With block >= s the blocked driver degenerates to one getf2
        // call: bitwise identical to the unblocked f32 reference.
        let f1 = lu_factor_t::<f32>(&a0, 48, &mut engine()).unwrap();
        let mut ref_a = a0.clone();
        let mut ref_piv = vec![0usize; 48];
        getf2(&mut ref_a.view_mut(), &mut ref_piv).unwrap();
        assert_eq!(f1.pivots, ref_piv, "single-panel f32 pivots differ from f32 getf2");
        assert_eq!(f1.lu.max_abs_diff(&ref_a), 0.0, "single-panel path must equal f32 getf2");
        // And an f32 solve on a well-conditioned system is f32-accurate.
        let a0 = MatrixF32::random_diag_dominant(40, &mut rng);
        let x_true = MatrixF32::random(40, 2, &mut rng);
        let mut rhs = MatrixF32::zeros(40, 2);
        crate::gemm::gemm_reference(1.0f32, a0.view(), x_true.view(), 0.0f32, &mut rhs.view_mut());
        let f = lu_factor_t::<f32>(&a0, 8, &mut engine()).unwrap();
        assert!(f.solve(&rhs).max_abs_diff(&x_true) < 1e-3);
    }

    #[test]
    fn flops_formula_scale() {
        assert!((lu_flops(1000) - 2.0 / 3.0 * 1e9).abs() < 1e3);
    }
}
