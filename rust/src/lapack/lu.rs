//! Right-looking blocked LU factorization with partial pivoting — the
//! paper's Figure 2 algorithm, verbatim:
//!
//! ```text
//! for k = 0, b, 2b, ...          (loop F1)
//!   PFACT : [A11; A21] = [L11; L21] U11   (panel, partial pivoting)
//!   swaps : apply pivots to A(:, left) and A(:, right)
//!   TSOLVE: A12 := Lower_unit(A11)^{-1} A12
//!   GEMM  : A22 := A22 - A21 * A12        (trailing update, k-dim = b)
//! ```
//!
//! The trailing GEMM has `m = n = s - k - b` (shrinking) and constant
//! `k = b` — the skinny-k shape whose cache behaviour the paper studies.
//!
//! # Static lookahead (the fused pipeline)
//!
//! With a [`crate::gemm::Lookahead`] policy enabled on the engine,
//! [`lu_blocked`] runs the fused pipeline instead: each iteration starts
//! with its panel **already factored** (pivots recorded, swaps *not yet
//! applied*), applies the deferred swaps to the columns left and right of
//! the panel ([`laswp_parallel`] on the pool), solves A12, and then issues
//! one fused pool job ([`GemmEngine::gemm_fused_trailing`]) that
//!
//! 1. updates the next panel's `b` columns of A22 with the whole team,
//! 2. splits: a `t_p`-rank panel sub-team factors that freshly-updated
//!    panel ([`getf2_team`]) while the update sub-team finishes the
//!    remaining `n - b` columns,
//! 3. rejoins at a single team barrier.
//!
//! Deferring the next panel's swaps past the concurrent remainder update
//! is exact: the trailing GEMM updates each row independently, so
//! permuting rows after the update equals permuting before. Pivots and
//! factors are **bitwise identical** to the non-lookahead pooled path
//! (asserted by `tests/lookahead.rs`): the fused driver plans one config
//! for the full trailing shape, which fixes every element's
//! k-accumulation order, and `getf2_team` replays `getf2`'s exact
//! comparison and update sequence.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::gemm::GemmEngine;
use crate::util::matrix::MatrixF64;

use super::pfact::{getf2, getf2_team, laswp, laswp_parallel, SharedPanel, NO_ERR};
use super::trsm::trsm_left_lower_unit;

/// Result of a blocked LU factorization.
pub struct LuFactors {
    /// Factored matrix: strictly-lower = L (unit diag), upper = U.
    pub lu: MatrixF64,
    /// Pivot rows per step, LAPACK ipiv convention (0-based, relative to
    /// the whole matrix): at step j, rows j and pivots[j] were swapped.
    pub pivots: Vec<usize>,
    /// Algorithmic block size used.
    pub block: usize,
}

impl LuFactors {
    /// Apply the recorded permutation to a fresh copy of `x` (compute
    /// `P * x` where `P A = L U`).
    pub fn permute(&self, x: &MatrixF64) -> MatrixF64 {
        let mut px = x.clone();
        for (j, &pj) in self.pivots.iter().enumerate() {
            if j != pj {
                for c in 0..px.cols() {
                    let t = px[(j, c)];
                    px[(j, c)] = px[(pj, c)];
                    px[(pj, c)] = t;
                }
            }
        }
        px
    }

    /// Explicit L factor (s x s, unit lower).
    pub fn l_matrix(&self) -> MatrixF64 {
        let s = self.lu.rows();
        MatrixF64::from_fn(s, s, |i, j| {
            if i == j {
                1.0
            } else if i > j {
                self.lu[(i, j)]
            } else {
                0.0
            }
        })
    }

    /// Explicit U factor (s x s, upper).
    pub fn u_matrix(&self) -> MatrixF64 {
        let s = self.lu.rows();
        MatrixF64::from_fn(s, s, |i, j| if i <= j { self.lu[(i, j)] } else { 0.0 })
    }

    /// Solve `A x = rhs` using the factorization (forward + backward
    /// substitution on the permuted right-hand side).
    pub fn solve(&self, rhs: &MatrixF64) -> MatrixF64 {
        let s = self.lu.rows();
        assert_eq!(rhs.rows(), s);
        let mut x = self.permute(rhs);
        // Forward: L y = P rhs (unit lower).
        trsm_left_lower_unit(self.lu.view(), &mut x.view_mut());
        // Backward: U x = y.
        for c in 0..x.cols() {
            for jj in (0..s).rev() {
                let mut acc = x[(jj, c)];
                for t in jj + 1..s {
                    acc -= self.lu[(jj, t)] * x[(t, c)];
                }
                x[(jj, c)] = acc / self.lu[(jj, jj)];
            }
        }
        x
    }

    /// Residual `max|P A - L U|` against the original matrix, normalized
    /// by `max|A|` (cheap full-reconstruction check used by tests and the
    /// end-to-end example).
    pub fn reconstruction_error(&self, a0: &MatrixF64) -> f64 {
        let pa = self.permute(a0);
        let l = self.l_matrix();
        let u = self.u_matrix();
        let mut lu = MatrixF64::zeros(pa.rows(), pa.cols());
        crate::gemm::gemm_reference(1.0, l.view(), u.view(), 0.0, &mut lu.view_mut());
        pa.max_abs_diff(&lu) / a0.max_abs().max(1e-300)
    }
}

/// Apply the panel's row interchanges to the columns left and right of
/// it, on the worker pool when the engine has one (the `laswp` satellite:
/// the seed swapped rows with a sequential per-row loop over the full
/// width while the whole team idled).
fn apply_panel_swaps(
    a: &mut MatrixF64,
    k: usize,
    b: usize,
    piv_local: &[usize],
    engine: &GemmEngine,
) {
    let s = a.rows();
    let pool = engine.pool().cloned();
    let mut swap = |view: &mut crate::util::matrix::MatViewMut<'_>| match &pool {
        Some(p) => laswp_parallel(view, k, piv_local, p),
        None => laswp(view, k, piv_local),
    };
    if k > 0 {
        let mut left = a.sub_mut(0, 0, s, k);
        swap(&mut left);
    }
    if k + b < s {
        let mut right = a.sub_mut(0, k + b, s, s - k - b);
        swap(&mut right);
    }
}

/// Blocked right-looking LU with partial pivoting, in place over `a`,
/// trailing updates through the supplied [`GemmEngine`] (this is where
/// the co-design policy — CCPs + micro-kernel per call — takes effect).
/// With the engine's [`crate::gemm::Lookahead`] policy enabled this runs
/// the fused lookahead pipeline (see the module docs); results are
/// bitwise identical either way.
///
/// The engine amortizes two costs across the factorization sweep: its
/// persistent worker pool (parallel plans spawn threads once, not per
/// trailing update) and its config-selection memo cache (each distinct
/// trailing shape `(s-k-b) x (s-k-b) x b` runs the scorer once; repeated
/// factorizations of equal order are pure cache hits).
pub fn lu_blocked(a: &mut MatrixF64, block: usize, engine: &mut GemmEngine) -> Result<Vec<usize>, usize> {
    if engine.lookahead().enabled() {
        lu_blocked_lookahead(a, block, engine)
    } else {
        lu_blocked_baseline(a, block, engine)
    }
}

/// The non-lookahead pipeline: factor panel, swap, solve, update —
/// strictly serialized per iteration.
fn lu_blocked_baseline(
    a: &mut MatrixF64,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<Vec<usize>, usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s, "LU requires a square matrix");
    assert!(block >= 1);
    let mut pivots = vec![0usize; s];
    let mut k = 0;
    while k < s {
        let b = block.min(s - k);
        // --- PFACT on the panel A[k.., k..k+b] --------------------------
        {
            let mut panel = a.sub_mut(k, k, s - k, b);
            let mut piv_local = vec![0usize; b];
            getf2(&mut panel, &mut piv_local).map_err(|j| k + j)?;
            for (j, pj) in piv_local.iter().enumerate() {
                pivots[k + j] = k + pj;
            }
        }
        // --- Row interchanges on the left and right of the panel --------
        {
            let piv_local: Vec<usize> = (0..b).map(|j| pivots[k + j] - k).collect();
            apply_panel_swaps(a, k, b, &piv_local, engine);
        }
        if k + b < s {
            let rest = s - k - b;
            // --- TSOLVE: A12 := L11^{-1} A12 ----------------------------
            {
                let l11 = a.sub(k, k, b, b).to_owned_matrix();
                let mut a12 = a.sub_mut(k, k + b, b, rest);
                trsm_left_lower_unit(l11.view(), &mut a12);
            }
            // --- GEMM: A22 -= A21 * A12 (k-dimension = b) ---------------
            {
                let a21 = a.sub(k + b, k, rest, b).to_owned_matrix();
                let a12 = a.sub(k, k + b, b, rest).to_owned_matrix();
                let mut a22 = a.sub_mut(k + b, k + b, rest, rest);
                engine.gemm(-1.0, a21.view(), a12.view(), 1.0, &mut a22);
            }
        }
        k += b;
    }
    Ok(pivots)
}

/// The fused lookahead pipeline (module docs): every iteration enters
/// with its panel already factored — by the up-front `getf2` for panel 0,
/// then by the panel sub-team of the previous iteration's fused job — so
/// the worker pool never sits parked behind a panel factorization.
fn lu_blocked_lookahead(
    a: &mut MatrixF64,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<Vec<usize>, usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s, "LU requires a square matrix");
    assert!(block >= 1);
    let la = engine.lookahead();
    let mut pivots = vec![0usize; s];
    // Factor panel 0 up front (nothing to overlap it with yet).
    {
        let b0 = block.min(s);
        let mut panel = a.sub_mut(0, 0, s, b0);
        let mut piv_local = vec![0usize; b0];
        getf2(&mut panel, &mut piv_local)?;
        pivots[..b0].copy_from_slice(&piv_local);
    }
    let mut k = 0;
    while k < s {
        let b = block.min(s - k);
        // Invariant: panel [k.., k..k+b] is factored, pivots[k..k+b] are
        // recorded (absolute), and its swaps are still deferred.
        let piv_local: Vec<usize> = (0..b).map(|j| pivots[k + j] - k).collect();
        apply_panel_swaps(a, k, b, &piv_local, engine);
        if k + b < s {
            let rest = s - k - b;
            // --- TSOLVE: A12 := L11^{-1} A12 ----------------------------
            {
                let l11 = a.sub(k, k, b, b).to_owned_matrix();
                let mut a12 = a.sub_mut(k, k + b, b, rest);
                trsm_left_lower_unit(l11.view(), &mut a12);
            }
            // --- Fused GEMM + PFACT(k+1): the whole team updates the
            // next panel's columns of A22, then the panel sub-team
            // factors them while the update sub-team finishes the rest.
            let next_b = block.min(rest);
            let a21 = a.sub(k + b, k, rest, b).to_owned_matrix();
            let a12 = a.sub(k, k + b, b, rest).to_owned_matrix();
            let mut a22 = a.sub_mut(k + b, k + b, rest, rest);
            let panel_shared = SharedPanel::new(&mut a22.sub_mut(0, 0, rest, next_b));
            let piv_next: Vec<AtomicUsize> = (0..next_b).map(|_| AtomicUsize::new(0)).collect();
            let err = AtomicUsize::new(NO_ERR);
            engine.gemm_fused_trailing(
                -1.0,
                a21.view(),
                a12.view(),
                &mut a22,
                next_b,
                la.panel_workers,
                &|sub| getf2_team(&panel_shared, &piv_next, &err, sub),
            );
            let failed = err.load(Ordering::Acquire);
            if failed != NO_ERR {
                return Err(k + b + failed);
            }
            for (j, pj) in piv_next.iter().enumerate() {
                pivots[k + b + j] = k + b + pj.load(Ordering::Acquire);
            }
        }
        k += b;
    }
    Ok(pivots)
}

/// Convenience wrapper returning [`LuFactors`].
pub fn lu_factor(a0: &MatrixF64, block: usize, engine: &mut GemmEngine) -> Result<LuFactors, usize> {
    let mut a = a0.clone();
    let pivots = lu_blocked(&mut a, block, engine)?;
    Ok(LuFactors { lu: a, pivots, block })
}

/// Flop count of an LU factorization of order s (2/3 s^3 to leading order;
/// exact: `s^2(s-1)/2 * ...` — we use the standard `2/3 s^3 - s^2/2` form
/// the paper's GFLOPS plots divide by).
pub fn lu_flops(s: usize) -> f64 {
    let sf = s as f64;
    2.0 / 3.0 * sf * sf * sf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::ConfigMode;
    use crate::util::{MatrixF64, Pcg64};

    fn engine() -> GemmEngine {
        GemmEngine::new(host_xeon(), ConfigMode::Refined)
    }

    #[test]
    fn lu_reconstructs_pa() {
        let mut rng = Pcg64::seed(42);
        for (s, b) in [(16, 4), (50, 8), (64, 64), (37, 5), (96, 32)] {
            let a0 = MatrixF64::random(s, s, &mut rng);
            let f = lu_factor(&a0, b, &mut engine()).unwrap();
            let err = f.reconstruction_error(&a0);
            assert!(err < 1e-10, "s={s} b={b}: |PA - LU| = {err}");
        }
    }

    #[test]
    fn lu_matches_unblocked_getf2() {
        // The blocked algorithm must produce exactly the same factors and
        // pivots as the unblocked reference (partial pivoting is
        // deterministic).
        let mut rng = Pcg64::seed(43);
        let a0 = MatrixF64::random(24, 24, &mut rng);
        let f = lu_factor(&a0, 6, &mut engine()).unwrap();
        let mut ref_a = a0.clone();
        let mut ref_piv = vec![0usize; 24];
        getf2(&mut ref_a.view_mut(), &mut ref_piv).unwrap();
        assert_eq!(f.pivots, ref_piv, "pivot sequence differs from getf2");
        assert!(f.lu.max_abs_diff(&ref_a) < 1e-9, "factors differ from getf2");
    }

    #[test]
    fn lu_block_size_does_not_change_result() {
        let mut rng = Pcg64::seed(44);
        let a0 = MatrixF64::random(48, 48, &mut rng);
        let f1 = lu_factor(&a0, 4, &mut engine()).unwrap();
        let f2 = lu_factor(&a0, 16, &mut engine()).unwrap();
        let f3 = lu_factor(&a0, 48, &mut engine()).unwrap();
        assert!(f1.lu.max_abs_diff(&f2.lu) < 1e-9);
        assert!(f1.lu.max_abs_diff(&f3.lu) < 1e-9);
        assert_eq!(f1.pivots, f2.pivots);
        assert_eq!(f1.pivots, f3.pivots);
    }

    #[test]
    fn lu_solve_linear_system() {
        let mut rng = Pcg64::seed(45);
        let a0 = MatrixF64::random_diag_dominant(40, &mut rng);
        let x_true = MatrixF64::random(40, 3, &mut rng);
        let mut rhs = MatrixF64::zeros(40, 3);
        crate::gemm::gemm_reference(1.0, a0.view(), x_true.view(), 0.0, &mut rhs.view_mut());
        let f = lu_factor(&a0, 8, &mut engine()).unwrap();
        let x = f.solve(&rhs);
        assert!(x.max_abs_diff(&x_true) < 1e-8);
    }

    #[test]
    fn lu_singular_detected() {
        let mut a = MatrixF64::zeros(8, 8);
        for i in 0..8 {
            a[(i, i)] = 1.0;
        }
        // Make column 3 linearly dependent (equal to column 2).
        for i in 0..8 {
            let v = a[(i, 2)];
            a[(i, 3)] = v;
        }
        let err = lu_factor(&a, 4, &mut engine());
        assert!(err.is_err(), "rank-deficient matrix must be detected");
    }

    #[test]
    fn lu_block_larger_than_matrix() {
        let mut rng = Pcg64::seed(46);
        let a0 = MatrixF64::random(10, 10, &mut rng);
        let f = lu_factor(&a0, 64, &mut engine()).unwrap();
        assert!(f.reconstruction_error(&a0) < 1e-11);
    }

    #[test]
    fn pivot_growth_bounded() {
        // With partial pivoting all multipliers are <= 1.
        let mut rng = Pcg64::seed(47);
        let a0 = MatrixF64::random(30, 30, &mut rng);
        let f = lu_factor(&a0, 8, &mut engine()).unwrap();
        for j in 0..30 {
            for i in j + 1..30 {
                assert!(f.lu[(i, j)].abs() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn flops_formula_scale() {
        assert!((lu_flops(1000) - 2.0 / 3.0 * 1e9).abs() < 1e3);
    }
}
