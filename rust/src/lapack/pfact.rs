//! Panel factorization (PFACT) with partial pivoting — LAPACK's `getf2` —
//! and the row-interchange helper `laswp`.
//!
//! PFACT is the mostly-sequential kernel on the critical path of the
//! blocked LU (paper §2.1): right-looking rank-1 updates on a tall-skinny
//! `p x b` panel. Two cooperative variants break the strict
//! LAPACK-on-top-of-BLAS layering the paper argues against:
//!
//! - [`getf2_team`] runs the panel factorization on a lookahead *panel
//!   sub-team* ([`crate::runtime::pool::SubTeam`]): the sub-team leader
//!   does the (inherently sequential) pivot search and column scaling,
//!   while row interchanges and the trailing rank-1 update are split over
//!   the sub-team by column. Bitwise identical to [`getf2`].
//! - [`laswp_parallel`] applies a pivot sequence with the column range
//!   split across the whole worker pool; each rank applies the full pivot
//!   order to its own columns, so the permutation is exact.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::runtime::pool::{SubTeam, WorkerPool};
use crate::util::elem::Elem;
use crate::util::matrix::{Matrix, MatViewMut};

/// A raw shared view of a panel handed to a cooperating sub-team. Every
/// rank of the team receives the same copy and coordinates its disjoint
/// writes through the sub-team barrier. Generic over the element type
/// (default `f64`), like the rest of the stack.
pub struct SharedPanel<E = f64> {
    ptr: *mut E,
    pub rows: usize,
    pub cols: usize,
    pub ld: usize,
}

// SAFETY: shared mutation is coordinated by the sub-team barrier
// discipline of the functions below (disjoint column ranges between
// barriers); the wrapper itself only carries the pointer across threads.
unsafe impl<E> Send for SharedPanel<E> {}
unsafe impl<E> Sync for SharedPanel<E> {}

impl<E> Clone for SharedPanel<E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E> Copy for SharedPanel<E> {}

impl<E: Elem> SharedPanel<E> {
    pub fn new(v: &mut MatViewMut<'_, E>) -> Self {
        Self { ptr: v.data.as_mut_ptr(), rows: v.rows, cols: v.cols, ld: v.ld }
    }

    /// A sub-region of this shared view (same aliasing discipline): the
    /// deep-lookahead chains address individual panels, `L11`/`A21`
    /// blocks and column slices of one big shared trailing-matrix view
    /// through this.
    pub fn sub(&self, i: usize, j: usize, rows: usize, cols: usize) -> SharedPanel<E> {
        assert!(i + rows <= self.rows && j + cols <= self.cols, "SharedPanel::sub out of range");
        SharedPanel {
            // SAFETY: in-bounds by the assert; the pointer stays within
            // the parent allocation.
            ptr: unsafe { self.ptr.add(j * self.ld + i) },
            rows,
            cols,
            ld: self.ld,
        }
    }

    /// Copy this region into an owned matrix.
    ///
    /// # Safety
    /// No other rank may be mutating the region (same contract as
    /// [`Self::view_mut`]).
    pub unsafe fn to_owned_matrix(&self) -> Matrix<E> {
        Matrix::from_fn(self.rows, self.cols, |i, j| self.at(i, j))
    }

    /// Rebuild an immutable view — the read-only analog of
    /// [`Self::view_mut`], for DAG tile tasks that *concurrently read*
    /// a stable region (a factored panel, a snapshot) without copying.
    ///
    /// # Safety
    /// No rank may be mutating the region for the lifetime of the
    /// returned view; concurrent readers are fine.
    pub unsafe fn view<'a>(&self) -> crate::util::matrix::MatView<'a, E> {
        let len = if self.cols == 0 { 0 } else { (self.cols - 1) * self.ld + self.rows };
        crate::util::matrix::MatView {
            rows: self.rows,
            cols: self.cols,
            ld: self.ld,
            data: std::slice::from_raw_parts(self.ptr, len),
        }
    }

    /// Rebuild a mutable view.
    ///
    /// # Safety
    /// The caller must guarantee exclusive access to the panel region for
    /// the lifetime of the returned view (e.g. only sub-team rank 0 calls
    /// this, or calls are separated by sub-team barriers).
    pub unsafe fn view_mut<'a>(&self) -> MatViewMut<'a, E> {
        let len = if self.cols == 0 { 0 } else { (self.cols - 1) * self.ld + self.rows };
        MatViewMut {
            rows: self.rows,
            cols: self.cols,
            ld: self.ld,
            data: std::slice::from_raw_parts_mut(self.ptr, len),
        }
    }

    /// Read one element. The caller must respect the sub-team discipline
    /// (no concurrent writer of this element between barriers).
    #[inline]
    pub fn at(&self, i: usize, j: usize) -> E {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe { *self.ptr.add(j * self.ld + i) }
    }

    #[inline]
    fn set(&self, i: usize, j: usize, v: E) {
        debug_assert!(i < self.rows && j < self.cols);
        unsafe { *self.ptr.add(j * self.ld + i) = v }
    }
}

/// Unblocked LU with partial pivoting of a `p x q` panel (in place).
///
/// On return the strictly-lower part holds the unit-lower factor L (unit
/// diagonal implicit) and the upper part holds U. `pivots[j] = i` records
/// that row `j` was swapped with row `i >= j` at step j (LAPACK ipiv
/// convention, 0-based).
///
/// Returns `Err(j)` if an exact zero pivot is met at column j (matrix
/// singular to working precision), or if the selected pivot is
/// non-finite (NaN/Inf contamination): a NaN pivot would otherwise
/// poison every multiplier it scales and surface as a nonsense result
/// instead of a typed breakdown.
pub fn getf2<E: Elem>(a: &mut MatViewMut<'_, E>, pivots: &mut [usize]) -> Result<(), usize> {
    let p = a.rows;
    let q = a.cols;
    let steps = p.min(q);
    assert!(pivots.len() >= steps, "pivot buffer too small");
    for j in 0..steps {
        // Find the pivot: argmax |A(i, j)| over i >= j.
        let mut imax = j;
        let mut vmax = a.at(j, j).abs();
        for i in j + 1..p {
            let v = a.at(i, j).abs();
            if v > vmax {
                vmax = v;
                imax = i;
            }
        }
        pivots[j] = imax;
        if vmax == E::ZERO || !vmax.to_f64().is_finite() {
            return Err(j);
        }
        // Swap rows j and imax across the whole panel.
        if imax != j {
            for c in 0..q {
                let t = a.at(j, c);
                let v = a.at(imax, c);
                a.set(j, c, v);
                a.set(imax, c, t);
            }
        }
        // Scale the sub-column and apply the rank-1 update to the
        // trailing sub-panel.
        let pivot = a.at(j, j);
        let inv = E::ONE / pivot;
        for i in j + 1..p {
            let l = a.at(i, j) * inv;
            a.set(i, j, l);
        }
        for c in j + 1..q {
            let ujc = a.at(j, c);
            if ujc == E::ZERO {
                continue;
            }
            // Column-major AXPY down column c.
            let col_off = c * a.ld;
            let lcol_off = j * a.ld;
            for i in j + 1..p {
                let delta = a.data[lcol_off + i] * ujc;
                a.data[col_off + i] -= delta;
            }
        }
    }
    Ok(())
}

/// Apply the row interchanges recorded by [`getf2`] to another block of
/// the same matrix rows (LAPACK `laswp`): for each step j, swap rows
/// `offset + j` and `offset + pivots[j]`.
pub fn laswp<E: Elem>(a: &mut MatViewMut<'_, E>, offset: usize, pivots: &[usize]) {
    for (j, &pj) in pivots.iter().enumerate() {
        let r1 = offset + j;
        let r2 = offset + pj;
        if r1 == r2 {
            continue;
        }
        for c in 0..a.cols {
            let t = a.at(r1, c);
            let v = a.at(r2, c);
            a.set(r1, c, v);
            a.set(r2, c, t);
        }
    }
}

/// Sentinel for "no failure" in the shared error slots of the team
/// routines below.
pub const NO_ERR: usize = usize::MAX;

/// Row-interchange work below which forking the pool costs more than the
/// swaps themselves (elements touched = 2 * pivots * cols).
const LASWP_PARALLEL_MIN_ELEMS: usize = 16 * 1024;

/// [`laswp`] on the worker pool: the column range is split across ranks
/// and each rank applies the **full pivot sequence, in order,** to its
/// own columns. Row swaps never cross columns, so per-column order is all
/// that matters and the result is identical to the sequential `laswp`
/// (the regression tests assert equality element-for-element). Columns
/// are walked outermost so each column's cache lines are touched once per
/// sweep instead of once per pivot.
pub fn laswp_parallel<E: Elem>(
    a: &mut MatViewMut<'_, E>,
    offset: usize,
    pivots: &[usize],
    pool: &WorkerPool,
) {
    if pool.threads() == 1 || 2 * pivots.len() * a.cols < LASWP_PARALLEL_MIN_ELEMS {
        laswp(a, offset, pivots);
        return;
    }
    let cols = a.cols;
    let ld = a.ld;
    let base = SharedPanel::new(a);
    pool.run(&|ctx| {
        let (lo, hi) = crate::gemm::parallel::partition_rank(cols, ctx.threads, ctx.rank, 1);
        for c in lo..hi {
            // SAFETY: ranks own disjoint column ranges.
            let col = unsafe { std::slice::from_raw_parts_mut(base.ptr.add(c * ld), base.rows) };
            for (j, &pj) in pivots.iter().enumerate() {
                if j != pj {
                    col.swap(offset + j, offset + pj);
                }
            }
        }
    });
}

/// [`getf2`] run cooperatively by a lookahead panel sub-team, bitwise
/// identical to the sequential routine. Sub-team rank 0 performs the
/// pivot search and the multiplier scaling (both inherently sequential);
/// the full-panel row interchange and the trailing rank-1 update are
/// split over the sub-team by column, synchronized on the sub-team
/// barrier. With a one-rank team every barrier is a no-op and this *is*
/// `getf2`.
///
/// `pivots_out[j]` receives the step-j pivot row; on an exact zero pivot
/// at column j, `err` is set to j (from [`NO_ERR`]) and every rank
/// returns with the panel in the same state sequential `getf2` leaves on
/// `Err(j)`.
///
/// Every rank of `team` must call this with identical arguments, and no
/// rank outside the team may touch the panel or the output slots until
/// the team rejoins the full job.
pub fn getf2_team<E: Elem>(
    panel: &SharedPanel<E>,
    pivots_out: &[AtomicUsize],
    err: &AtomicUsize,
    team: &SubTeam<'_>,
) {
    let p = panel.rows;
    let q = panel.cols;
    let steps = p.min(q);
    assert!(pivots_out.len() >= steps, "pivot buffer too small");
    for j in 0..steps {
        if team.rank == 0 {
            // Pivot search: argmax |A(i, j)| over i >= j — the exact
            // comparison sequence of `getf2`, so ties break identically.
            let mut imax = j;
            let mut vmax = panel.at(j, j).abs();
            for i in j + 1..p {
                let v = panel.at(i, j).abs();
                if v > vmax {
                    vmax = v;
                    imax = i;
                }
            }
            pivots_out[j].store(imax, Ordering::Release);
            // Same breakdown condition as `getf2`: exact zero or a
            // non-finite pivot both end the factorization at column j.
            if vmax == E::ZERO || !vmax.to_f64().is_finite() {
                err.store(j, Ordering::Release);
            }
        }
        team.barrier(); // pivot (and a possible error) published
        if err.load(Ordering::Acquire) != NO_ERR {
            return;
        }
        let imax = pivots_out[j].load(Ordering::Acquire);
        // Swap rows j and imax across the whole panel, split by column.
        if imax != j {
            let (lo, hi) = crate::gemm::parallel::partition_rank(q, team.threads, team.rank, 1);
            for c in lo..hi {
                let t = panel.at(j, c);
                let v = panel.at(imax, c);
                panel.set(j, c, v);
                panel.set(imax, c, t);
            }
            team.barrier(); // swap complete before anyone reads row j
        }
        if team.rank == 0 {
            // Scale the sub-column into multipliers.
            let pivot = panel.at(j, j);
            let inv = E::ONE / pivot;
            for i in j + 1..p {
                let l = panel.at(i, j) * inv;
                panel.set(i, j, l);
            }
        }
        team.barrier(); // multipliers published
        // Rank-1 update of the trailing sub-panel, split by column; each
        // column's arithmetic is exactly the sequential AXPY.
        let rem = q - j - 1;
        let (lo, hi) = crate::gemm::parallel::partition_rank(rem, team.threads, team.rank, 1);
        for c in j + 1 + lo..j + 1 + hi {
            let ujc = panel.at(j, c);
            if ujc == E::ZERO {
                continue;
            }
            for i in j + 1..p {
                let v = panel.at(i, c) - panel.at(i, j) * ujc;
                panel.set(i, c, v);
            }
        }
        team.barrier(); // update complete before the next pivot search
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MatrixF64, Pcg64};

    /// Reconstruct P*A0 and L*U from a factored panel and compare.
    fn verify_panel(a0: &MatrixF64, fact: &MatrixF64, pivots: &[usize]) {
        let p = a0.rows();
        let q = a0.cols();
        let steps = p.min(q);
        // Build permuted copy of A0.
        let mut pa = a0.clone();
        laswp(&mut pa.view_mut(), 0, &pivots[..steps]);
        // L (p x steps, unit diag) * U (steps x q).
        let mut lu = MatrixF64::zeros(p, q);
        for i in 0..p {
            for j in 0..q {
                let mut acc = 0.0;
                for t in 0..steps {
                    let l = if i == t {
                        1.0
                    } else if i > t {
                        fact[(i, t)]
                    } else {
                        0.0
                    };
                    let u = if t <= j { if t < steps { fact[(t, j)] } else { 0.0 } } else { 0.0 };
                    acc += l * u;
                }
                lu[(i, j)] = acc;
            }
        }
        assert!(pa.max_abs_diff(&lu) < 1e-10 * (p as f64), "PA != LU for panel");
    }

    #[test]
    fn getf2_square() {
        let mut rng = Pcg64::seed(100);
        let a0 = MatrixF64::random(8, 8, &mut rng);
        let mut a = a0.clone();
        let mut piv = vec![0usize; 8];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        verify_panel(&a0, &a, &piv);
    }

    #[test]
    fn getf2_tall_panel() {
        let mut rng = Pcg64::seed(101);
        let a0 = MatrixF64::random(40, 8, &mut rng);
        let mut a = a0.clone();
        let mut piv = vec![0usize; 8];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        verify_panel(&a0, &a, &piv);
    }

    #[test]
    fn getf2_picks_largest_pivot() {
        // First column is [1, -9, 3]^T: pivot row must be 1.
        let mut a = MatrixF64::from_row_major(3, 3, &[1., 2., 3., -9., 5., 6., 3., 8., 10.]);
        let mut piv = vec![0usize; 3];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        assert_eq!(piv[0], 1);
        // Multipliers are bounded by 1 in magnitude with partial pivoting.
        for j in 0..3 {
            for i in j + 1..3 {
                assert!(a[(i, j)].abs() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn getf2_detects_singularity() {
        let mut a = MatrixF64::zeros(3, 3);
        a[(0, 0)] = 1.0; // column 1 is entirely zero below/at the diagonal
        a[(0, 1)] = 2.0;
        a[(0, 2)] = 3.0;
        let mut piv = vec![0usize; 3];
        assert_eq!(getf2(&mut a.view_mut(), &mut piv), Err(1));
    }

    #[test]
    fn getf2_treats_non_finite_pivot_as_breakdown() {
        // A NaN on the diagonal wins no comparison, so it stays the
        // selected pivot; the factorization must stop with a typed
        // breakdown at that column rather than scale by NaN.
        let mut rng = Pcg64::seed(103);
        let mut a = MatrixF64::random(6, 6, &mut rng);
        a[(2, 2)] = f64::NAN;
        // Make column 2 otherwise tiny so the NaN slot is the argmax seed.
        for i in 0..6 {
            if i != 2 {
                a[(i, 2)] = 0.0;
            }
        }
        let mut piv = vec![0usize; 6];
        assert_eq!(getf2(&mut a.view_mut(), &mut piv), Err(2));
    }

    #[test]
    fn laswp_applies_same_permutation() {
        let mut rng = Pcg64::seed(7);
        let a0 = MatrixF64::random(6, 4, &mut rng);
        let mut a = a0.clone();
        let mut piv = vec![0usize; 4];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        // laswp on an identity tracks the permutation matrix.
        let mut perm = MatrixF64::identity(6);
        laswp(&mut perm.view_mut(), 0, &piv);
        // Rows of perm * a0 must equal the pivoted order getf2 used.
        let mut pa = MatrixF64::zeros(6, 4);
        crate::gemm::gemm_reference(1.0, perm.view(), a0.view(), 0.0, &mut pa.view_mut());
        let mut pa2 = a0.clone();
        laswp(&mut pa2.view_mut(), 0, &piv);
        assert!(pa.max_abs_diff(&pa2) < 1e-14);
    }

    #[test]
    fn laswp_with_offset() {
        let mut a = MatrixF64::from_fn(4, 1, |i, _| i as f64);
        laswp(&mut a.view_mut(), 2, &[1]); // swap rows 2 and 3
        assert_eq!(a[(2, 0)], 3.0);
        assert_eq!(a[(3, 0)], 2.0);
    }

    #[test]
    fn laswp_parallel_matches_sequential() {
        let mut rng = Pcg64::seed(200);
        // Big enough to clear the parallel threshold, plus a small case
        // that takes the sequential fallback.
        for (rows, cols, b, threads) in [(96, 200, 24, 3), (96, 300, 17, 4), (12, 6, 3, 2)] {
            let a0 = MatrixF64::random(rows, cols, &mut rng);
            // A realistic pivot sequence: from factoring a random panel.
            let mut panel = MatrixF64::random(rows, b, &mut rng);
            let mut piv = vec![0usize; b];
            getf2(&mut panel.view_mut(), &mut piv).unwrap();
            let mut seq = a0.clone();
            laswp(&mut seq.view_mut(), 0, &piv);
            let mut par = a0.clone();
            let pool = WorkerPool::new(threads);
            laswp_parallel(&mut par.view_mut(), 0, &piv, &pool);
            assert_eq!(par.max_abs_diff(&seq), 0.0, "{rows}x{cols} b={b} x{threads}");
            // With an offset too (pivots drawn from a shorter panel so
            // offset + pivot stays in range, as in a real factorization).
            let mut panel2 = MatrixF64::random(rows - 3, b, &mut rng);
            let mut piv2 = vec![0usize; b];
            getf2(&mut panel2.view_mut(), &mut piv2).unwrap();
            let mut seq2 = a0.clone();
            laswp(&mut seq2.view_mut(), 3, &piv2);
            let mut par2 = a0.clone();
            laswp_parallel(&mut par2.view_mut(), 3, &piv2, &pool);
            assert_eq!(par2.max_abs_diff(&seq2), 0.0);
        }
    }

    #[test]
    fn getf2_team_solo_matches_sequential() {
        let mut rng = Pcg64::seed(201);
        for (p, q) in [(24, 8), (16, 16), (40, 7)] {
            let a0 = MatrixF64::random(p, q, &mut rng);
            let mut seq = a0.clone();
            let mut piv_seq = vec![0usize; q];
            getf2(&mut seq.view_mut(), &mut piv_seq).unwrap();
            let mut team_m = a0.clone();
            let pivots: Vec<AtomicUsize> = (0..q).map(|_| AtomicUsize::new(0)).collect();
            let err = AtomicUsize::new(NO_ERR);
            {
                let mut v = team_m.view_mut();
                let shared = SharedPanel::new(&mut v);
                getf2_team(&shared, &pivots, &err, &crate::runtime::pool::SubTeam::solo_panel());
            }
            assert_eq!(err.load(Ordering::SeqCst), NO_ERR);
            let piv_team: Vec<usize> =
                pivots.iter().map(|x| x.load(Ordering::SeqCst)).collect();
            assert_eq!(piv_team, piv_seq);
            assert_eq!(team_m.max_abs_diff(&seq), 0.0, "p={p} q={q}");
        }
    }

    #[test]
    fn getf2_team_split_matches_sequential() {
        let mut rng = Pcg64::seed(202);
        let (p, q) = (48, 11);
        let a0 = MatrixF64::random(p, q, &mut rng);
        let mut seq = a0.clone();
        let mut piv_seq = vec![0usize; q];
        getf2(&mut seq.view_mut(), &mut piv_seq).unwrap();
        for (threads, t_p) in [(3, 2), (4, 3), (2, 1)] {
            let pool = WorkerPool::new(threads);
            let mut team_m = a0.clone();
            let pivots: Vec<AtomicUsize> = (0..q).map(|_| AtomicUsize::new(0)).collect();
            let err = AtomicUsize::new(NO_ERR);
            {
                let mut v = team_m.view_mut();
                let shared = SharedPanel::new(&mut v);
                pool.run(&|ctx| {
                    let sub = ctx.split(t_p);
                    if sub.panel {
                        getf2_team(&shared, &pivots, &err, &sub);
                    }
                    ctx.barrier(); // rejoin
                });
            }
            assert_eq!(err.load(Ordering::SeqCst), NO_ERR);
            let piv_team: Vec<usize> = pivots.iter().map(|x| x.load(Ordering::SeqCst)).collect();
            assert_eq!(piv_team, piv_seq, "x{threads} t_p={t_p}");
            assert_eq!(team_m.max_abs_diff(&seq), 0.0, "x{threads} t_p={t_p}");
        }
    }

    #[test]
    fn getf2_team_detects_singularity_like_sequential() {
        let mut a = MatrixF64::zeros(4, 4);
        a[(0, 0)] = 1.0;
        a[(0, 1)] = 2.0;
        a[(0, 2)] = 3.0;
        let mut seq = a.clone();
        let mut piv = vec![0usize; 4];
        assert_eq!(getf2(&mut seq.view_mut(), &mut piv), Err(1));
        let pivots: Vec<AtomicUsize> = (0..4).map(|_| AtomicUsize::new(0)).collect();
        let err = AtomicUsize::new(NO_ERR);
        let mut v = a.view_mut();
        let shared = SharedPanel::new(&mut v);
        getf2_team(&shared, &pivots, &err, &crate::runtime::pool::SubTeam::solo_panel());
        assert_eq!(err.load(Ordering::SeqCst), 1);
    }
}
