//! Panel factorization (PFACT) with partial pivoting — LAPACK's `getf2` —
//! and the row-interchange helper `laswp`.
//!
//! PFACT is the mostly-sequential kernel on the critical path of the
//! blocked LU (paper §2.1): right-looking rank-1 updates on a tall-skinny
//! `p x b` panel.

use crate::util::matrix::MatViewMut;

/// Unblocked LU with partial pivoting of a `p x q` panel (in place).
///
/// On return the strictly-lower part holds the unit-lower factor L (unit
/// diagonal implicit) and the upper part holds U. `pivots[j] = i` records
/// that row `j` was swapped with row `i >= j` at step j (LAPACK ipiv
/// convention, 0-based).
///
/// Returns `Err(j)` if an exact zero pivot is met at column j (matrix
/// singular to working precision).
pub fn getf2(a: &mut MatViewMut<'_>, pivots: &mut [usize]) -> Result<(), usize> {
    let p = a.rows;
    let q = a.cols;
    let steps = p.min(q);
    assert!(pivots.len() >= steps, "pivot buffer too small");
    for j in 0..steps {
        // Find the pivot: argmax |A(i, j)| over i >= j.
        let mut imax = j;
        let mut vmax = a.at(j, j).abs();
        for i in j + 1..p {
            let v = a.at(i, j).abs();
            if v > vmax {
                vmax = v;
                imax = i;
            }
        }
        pivots[j] = imax;
        if vmax == 0.0 {
            return Err(j);
        }
        // Swap rows j and imax across the whole panel.
        if imax != j {
            for c in 0..q {
                let t = a.at(j, c);
                let v = a.at(imax, c);
                a.set(j, c, v);
                a.set(imax, c, t);
            }
        }
        // Scale the sub-column and apply the rank-1 update to the
        // trailing sub-panel.
        let pivot = a.at(j, j);
        let inv = 1.0 / pivot;
        for i in j + 1..p {
            let l = a.at(i, j) * inv;
            a.set(i, j, l);
        }
        for c in j + 1..q {
            let ujc = a.at(j, c);
            if ujc == 0.0 {
                continue;
            }
            // Column-major AXPY down column c.
            let col_off = c * a.ld;
            let lcol_off = j * a.ld;
            for i in j + 1..p {
                a.data[col_off + i] -= a.data[lcol_off + i] * ujc;
            }
        }
    }
    Ok(())
}

/// Apply the row interchanges recorded by [`getf2`] to another block of
/// the same matrix rows (LAPACK `laswp`): for each step j, swap rows
/// `offset + j` and `offset + pivots[j]`.
pub fn laswp(a: &mut MatViewMut<'_>, offset: usize, pivots: &[usize]) {
    for (j, &pj) in pivots.iter().enumerate() {
        let r1 = offset + j;
        let r2 = offset + pj;
        if r1 == r2 {
            continue;
        }
        for c in 0..a.cols {
            let t = a.at(r1, c);
            let v = a.at(r2, c);
            a.set(r1, c, v);
            a.set(r2, c, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{MatrixF64, Pcg64};

    /// Reconstruct P*A0 and L*U from a factored panel and compare.
    fn verify_panel(a0: &MatrixF64, fact: &MatrixF64, pivots: &[usize]) {
        let p = a0.rows();
        let q = a0.cols();
        let steps = p.min(q);
        // Build permuted copy of A0.
        let mut pa = a0.clone();
        laswp(&mut pa.view_mut(), 0, &pivots[..steps]);
        // L (p x steps, unit diag) * U (steps x q).
        let mut lu = MatrixF64::zeros(p, q);
        for i in 0..p {
            for j in 0..q {
                let mut acc = 0.0;
                for t in 0..steps {
                    let l = if i == t {
                        1.0
                    } else if i > t {
                        fact[(i, t)]
                    } else {
                        0.0
                    };
                    let u = if t <= j { if t < steps { fact[(t, j)] } else { 0.0 } } else { 0.0 };
                    acc += l * u;
                }
                lu[(i, j)] = acc;
            }
        }
        assert!(pa.max_abs_diff(&lu) < 1e-10 * (p as f64), "PA != LU for panel");
    }

    #[test]
    fn getf2_square() {
        let mut rng = Pcg64::seed(100);
        let a0 = MatrixF64::random(8, 8, &mut rng);
        let mut a = a0.clone();
        let mut piv = vec![0usize; 8];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        verify_panel(&a0, &a, &piv);
    }

    #[test]
    fn getf2_tall_panel() {
        let mut rng = Pcg64::seed(101);
        let a0 = MatrixF64::random(40, 8, &mut rng);
        let mut a = a0.clone();
        let mut piv = vec![0usize; 8];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        verify_panel(&a0, &a, &piv);
    }

    #[test]
    fn getf2_picks_largest_pivot() {
        // First column is [1, -9, 3]^T: pivot row must be 1.
        let mut a = MatrixF64::from_row_major(3, 3, &[1., 2., 3., -9., 5., 6., 3., 8., 10.]);
        let mut piv = vec![0usize; 3];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        assert_eq!(piv[0], 1);
        // Multipliers are bounded by 1 in magnitude with partial pivoting.
        for j in 0..3 {
            for i in j + 1..3 {
                assert!(a[(i, j)].abs() <= 1.0 + 1e-12);
            }
        }
    }

    #[test]
    fn getf2_detects_singularity() {
        let mut a = MatrixF64::zeros(3, 3);
        a[(0, 0)] = 1.0; // column 1 is entirely zero below/at the diagonal
        a[(0, 1)] = 2.0;
        a[(0, 2)] = 3.0;
        let mut piv = vec![0usize; 3];
        assert_eq!(getf2(&mut a.view_mut(), &mut piv), Err(1));
    }

    #[test]
    fn laswp_applies_same_permutation() {
        let mut rng = Pcg64::seed(7);
        let a0 = MatrixF64::random(6, 4, &mut rng);
        let mut a = a0.clone();
        let mut piv = vec![0usize; 4];
        getf2(&mut a.view_mut(), &mut piv).unwrap();
        // laswp on an identity tracks the permutation matrix.
        let mut perm = MatrixF64::identity(6);
        laswp(&mut perm.view_mut(), 0, &piv);
        // Rows of perm * a0 must equal the pivoted order getf2 used.
        let mut pa = MatrixF64::zeros(6, 4);
        crate::gemm::gemm_reference(1.0, perm.view(), a0.view(), 0.0, &mut pa.view_mut());
        let mut pa2 = a0.clone();
        laswp(&mut pa2.view_mut(), 0, &piv);
        assert!(pa.max_abs_diff(&pa2) < 1e-14);
    }

    #[test]
    fn laswp_with_offset() {
        let mut a = MatrixF64::from_fn(4, 1, |i, _| i as f64);
        laswp(&mut a.view_mut(), 2, &[1]); // swap rows 2 and 3
        assert_eq!(a[(2, 0)], 3.0);
        assert_eq!(a[(3, 0)], 2.0);
    }
}
