//! Level-3 BLAS kernels built on top of GEMM (paper §1: "for
//! portability, a majority of the Level-3 BLAS are built on top of the
//! general matrix multiplication kernel").
//!
//! - [`syrk_lower`] — symmetric rank-k update `C := alpha A A^T + beta C`
//!   (lower triangle only): the Cholesky trailing update, done properly
//!   (diagonal blocks get a half-flop triangular update, off-diagonal
//!   blocks are plain GEMMs through the co-design engine).
//! - [`trsm_blocked_left_lower_unit`] — the LU TSOLVE at scale: the
//!   triangular factor is processed in `nb x nb` diagonal blocks with the
//!   bulk of the flops cast as GEMM (exactly how LAPACK casts TRSM).
//!
//! Every GEMM here flows through the caller's [`GemmEngine`], so these
//! kernels inherit its persistent worker pool and memoized per-shape
//! config selection — the per-block shapes recur across the whole sweep.

use crate::gemm::GemmEngine;
use crate::util::matrix::{MatrixF64, MatViewMut};

use super::trsm::trsm_left_lower_unit;

/// `C := alpha * A * A^T + beta * C`, updating only the lower triangle of
/// the `n x n` matrix `c`; `a` is `n x k`. Off-diagonal blocks flow
/// through the engine's GEMM (and thus the co-design selection).
pub fn syrk_lower(
    alpha: f64,
    a: &MatrixF64,
    beta: f64,
    c: &mut MatrixF64,
    block: usize,
    engine: &mut GemmEngine,
) {
    let n = c.rows();
    assert_eq!(c.cols(), n, "C must be square");
    assert_eq!(a.rows(), n, "A row mismatch");
    let k = a.cols();
    let nb = block.max(1);
    let mut i = 0;
    while i < n {
        let ib = nb.min(n - i);
        // Diagonal block: triangular update, half the flops.
        {
            let mut cd = c.sub_mut(i, i, ib, ib);
            for jj in 0..ib {
                for ii in jj..ib {
                    let mut acc = 0.0;
                    for p in 0..k {
                        acc += a[(i + ii, p)] * a[(i + jj, p)];
                    }
                    let old = cd.at(ii, jj);
                    cd.set(ii, jj, alpha * acc + beta * old);
                }
            }
        }
        // Off-diagonal block row: C[i+ib.., i..i+ib] += A[i+ib..,:] A[i..i+ib,:]^T.
        if i + ib < n {
            let rows = n - i - ib;
            let a_low = a.sub(i + ib, 0, rows, k).to_owned_matrix();
            let a_diag_t = a.sub(i, 0, ib, k).to_owned_matrix().transposed();
            let mut c_block = c.sub_mut(i + ib, i, rows, ib);
            engine.gemm(alpha, a_low.view(), a_diag_t.view(), beta, &mut c_block);
        }
        i += nb;
    }
}

/// Blocked `B := Lower_unit(L)^{-1} B` for a large `q x q` L: diagonal
/// `nb x nb` blocks are solved with the unblocked kernel, and the
/// remaining updates are GEMMs `B2 -= L21 * B1` through the engine.
pub fn trsm_blocked_left_lower_unit(
    l: &MatrixF64,
    b: &mut MatViewMut<'_>,
    block: usize,
    engine: &mut GemmEngine,
) {
    let q = l.rows();
    assert_eq!(l.cols(), q);
    assert_eq!(b.rows, q);
    let nb = block.max(1);
    let n = b.cols;
    let mut i = 0;
    while i < q {
        let ib = nb.min(q - i);
        // Solve the diagonal block.
        {
            let l_diag = l.sub(i, i, ib, ib).to_owned_matrix();
            let mut b_blk = b.sub_mut(i, 0, ib, n);
            trsm_left_lower_unit(l_diag.view(), &mut b_blk);
        }
        // GEMM update of the rows below: B[i+ib..] -= L[i+ib.., i..i+ib] * B[i..i+ib].
        if i + ib < q {
            let rows = q - i - ib;
            let l21 = l.sub(i + ib, i, rows, ib).to_owned_matrix();
            let b1 = b.as_view().sub(i, 0, ib, n).to_owned_matrix();
            let mut b2 = b.sub_mut(i + ib, 0, rows, n);
            engine.gemm(-1.0, l21.view(), b1.view(), 1.0, &mut b2);
        }
        i += nb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::{gemm_reference, ConfigMode};
    use crate::util::Pcg64;

    fn engine() -> GemmEngine {
        GemmEngine::new(host_xeon(), ConfigMode::Refined)
    }

    #[test]
    fn syrk_matches_gemm_lower_triangle() {
        let mut rng = Pcg64::seed(70);
        for (n, k, nb) in [(20, 8, 6), (33, 15, 8), (16, 16, 16), (7, 3, 2)] {
            let a = MatrixF64::random(n, k, &mut rng);
            let c0 = MatrixF64::random(n, n, &mut rng);
            let mut c = c0.clone();
            syrk_lower(-1.0, &a, 1.0, &mut c, nb, &mut engine());
            // Reference: full GEMM, compare lower triangles.
            let at = a.transposed();
            let mut full = c0.clone();
            gemm_reference(-1.0, a.view(), at.view(), 1.0, &mut full.view_mut());
            for j in 0..n {
                for i in j..n {
                    assert!(
                        (c[(i, j)] - full[(i, j)]).abs() < 1e-11,
                        "n={n} k={k} nb={nb} ({i},{j})"
                    );
                }
                // Upper triangle untouched.
                for i in 0..j {
                    assert_eq!(c[(i, j)], c0[(i, j)], "upper triangle must be untouched");
                }
            }
        }
    }

    #[test]
    fn blocked_trsm_matches_unblocked() {
        let mut rng = Pcg64::seed(71);
        for (q, n, nb) in [(24, 10, 8), (37, 5, 6), (16, 16, 16)] {
            let l = MatrixF64::from_fn(q, q, |i, j| {
                if i > j {
                    rng.next_f64() - 0.5
                } else if i == j {
                    1.0
                } else {
                    0.0
                }
            });
            let b0 = MatrixF64::random(q, n, &mut rng);
            let mut b_blk = b0.clone();
            trsm_blocked_left_lower_unit(&l, &mut b_blk.view_mut(), nb, &mut engine());
            let mut b_ref = b0.clone();
            trsm_left_lower_unit(l.view(), &mut b_ref.view_mut());
            assert!(b_blk.max_abs_diff(&b_ref) < 1e-10, "q={q} n={n} nb={nb}");
        }
    }

    #[test]
    fn syrk_half_flop_diagonal_is_exact() {
        // A single diagonal block (n <= nb) must still be exact.
        let mut rng = Pcg64::seed(72);
        let a = MatrixF64::random(5, 9, &mut rng);
        let mut c = MatrixF64::zeros(5, 5);
        syrk_lower(1.0, &a, 0.0, &mut c, 64, &mut engine());
        let at = a.transposed();
        let mut full = MatrixF64::zeros(5, 5);
        gemm_reference(1.0, a.view(), at.view(), 0.0, &mut full.view_mut());
        for j in 0..5 {
            for i in j..5 {
                assert!((c[(i, j)] - full[(i, j)]).abs() < 1e-12);
            }
        }
    }
}
