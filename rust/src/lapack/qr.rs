//! Blocked Householder QR (extension): a third LAPACK-level consumer of
//! the co-design GEMM.
//!
//! The blocked algorithm follows LAPACK's `geqrf`: factor a `b`-column
//! panel with Householder reflectors (`geqr2`), build the compact-WY
//! triangular factor `T` (`larft`), and apply `(I - V T V^T)^T` to the
//! trailing columns with two GEMM-rich steps (`larfb`) — the trailing
//! update again has inner dimension `b`, the paper's skinny-k shape.
//!
//! With the engine's [`crate::gemm::Lookahead`] enabled, the final (and
//! dominant) `A2 -= V * (T^T V^T A2)` GEMM runs on the queue-based deep
//! pipeline: up to `depth` panels stay factored ahead — the fused job's
//! full team applies the compact-WY update to the columns entering the
//! lookahead window, the panel task replays the in-window iterations'
//! update slices on them and runs `geqr2`, and the update sub-team
//! sweeps the remainder, reusing the packed V. Factors and tau are
//! bitwise identical to the serialized path at every depth.

use std::sync::Mutex;

use crate::gemm::{gemm_blocked, GemmElem, GemmEngine, MicroKernelImpl, SchedPolicy, Workspace};
use crate::model::GemmDims;
use crate::runtime::dag::{execute_rank, execute_serial, GraphBuilder};
use crate::runtime::pool::SubTeam;
use crate::util::elem::Elem;
use crate::util::matrix::{Matrix, MatrixF64, MatViewMut};

use super::pfact::SharedPanel;

/// Result of a blocked QR factorization (generic over the element type;
/// default `f64`, so pre-generic code keeps compiling unchanged).
pub struct QrFactors<E = f64> {
    /// Packed factors: R in the upper triangle, Householder vectors V
    /// (unit lower trapezoid, implicit leading 1) below the diagonal.
    pub qr: Matrix<E>,
    /// Scalar reflector coefficients tau, one per column.
    pub tau: Vec<E>,
    pub block: usize,
}

impl<E: Elem> QrFactors<E> {
    /// Assemble the explicit `m x m` orthogonal factor Q (test/demo use).
    pub fn q_matrix(&self) -> Matrix<E> {
        let m = self.qr.rows();
        let n = self.qr.cols().min(m);
        let mut q = Matrix::from_fn(m, m, |i, j| if i == j { E::ONE } else { E::ZERO });
        // Apply H_0 H_1 ... H_{n-1} to I from the left, in reverse.
        for j in (0..n).rev() {
            let tau = self.tau[j];
            if tau.to_f64() == 0.0 {
                continue;
            }
            // v = [0_{j}, 1, qr[j+1.., j]]
            let mut v = vec![E::ZERO; m];
            v[j] = E::ONE;
            for i in j + 1..m {
                v[i] = self.qr[(i, j)];
            }
            // Q := (I - tau v v^T) Q
            for c in 0..m {
                let mut dot = E::ZERO;
                for r in j..m {
                    dot += v[r] * q[(r, c)];
                }
                let s = tau * dot;
                for r in j..m {
                    let upd = q[(r, c)] - s * v[r];
                    q[(r, c)] = upd;
                }
            }
        }
        q
    }

    /// Explicit R (upper triangular/trapezoidal).
    pub fn r_matrix(&self) -> Matrix<E> {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        Matrix::from_fn(m, n, |i, j| if i <= j { self.qr[(i, j)] } else { E::ZERO })
    }

    /// `max |A - Q R| / max|A|`.
    pub fn reconstruction_error(&self, a0: &Matrix<E>) -> f64 {
        let q = self.q_matrix();
        let r = self.r_matrix();
        let mut qr = Matrix::<E>::zeros(a0.rows(), a0.cols());
        crate::gemm::gemm_reference(E::ONE, q.view(), r.view(), E::ZERO, &mut qr.view_mut());
        qr.max_abs_diff(a0) / a0.max_abs().max(1e-300)
    }

    /// `max |Q^T Q - I|` (orthogonality).
    pub fn orthogonality_error(&self) -> f64 {
        let q = self.q_matrix();
        let qt = q.transposed();
        let mut qtq = Matrix::<E>::zeros(q.rows(), q.rows());
        crate::gemm::gemm_reference(E::ONE, qt.view(), q.view(), E::ZERO, &mut qtq.view_mut());
        let eye = Matrix::from_fn(q.rows(), q.rows(), |i, j| if i == j { E::ONE } else { E::ZERO });
        qtq.max_abs_diff(&eye)
    }
}

/// Unblocked Householder QR of a panel (LAPACK `geqr2`), in place.
pub fn geqr2(a: &mut MatViewMut<'_>, tau: &mut [f64]) {
    geqr2_t::<f64>(a, tau);
}

/// [`geqr2`] per element type. The column norm goes through f64
/// (`E::from_f64((alpha^2 + xnorm2).to_f64().sqrt())`) — the identity
/// composition for `E = f64`, so the historical path is bit for bit
/// unchanged, and a correctly-converted f64 sqrt for f32.
pub fn geqr2_t<E: Elem>(a: &mut MatViewMut<'_, E>, tau: &mut [E]) {
    let (m, n) = (a.rows, a.cols);
    let steps = m.min(n);
    assert!(tau.len() >= steps);
    for j in 0..steps {
        // Householder vector for column j below the diagonal.
        let alpha = a.at(j, j);
        let mut xnorm2 = E::ZERO;
        for i in j + 1..m {
            let v = a.at(i, j);
            xnorm2 += v * v;
        }
        if xnorm2.to_f64() == 0.0 {
            tau[j] = E::ZERO;
            continue;
        }
        let norm = E::from_f64((alpha * alpha + xnorm2).to_f64().sqrt());
        let beta = if alpha.to_f64() >= 0.0 { E::from_f64(-norm.to_f64()) } else { norm };
        let tj = (beta - alpha) / beta;
        tau[j] = tj;
        let scale = E::ONE / (alpha - beta);
        for i in j + 1..m {
            let v = a.at(i, j) * scale;
            a.set(i, j, v);
        }
        a.set(j, j, beta);
        // Apply H_j to the remaining panel columns: A := (I - tau v v^T) A.
        for c in j + 1..n {
            let mut dot = a.at(j, c);
            for i in j + 1..m {
                dot += a.at(i, j) * a.at(i, c);
            }
            let s = tj * dot;
            let upd0 = a.at(j, c) - s;
            a.set(j, c, upd0);
            for i in j + 1..m {
                let upd = a.at(i, c) - s * a.at(i, j);
                a.set(i, c, upd);
            }
        }
    }
}

/// Build the upper-triangular compact-WY factor T (LAPACK `larft`,
/// forward/columnwise) for the b reflectors stored in `v` (unit lower
/// trapezoid, `rows x b`).
fn larft<E: Elem>(v: &Matrix<E>, tau: &[E]) -> Matrix<E> {
    let b = v.cols();
    let rows = v.rows();
    let mut t = Matrix::<E>::zeros(b, b);
    for j in 0..b {
        t[(j, j)] = tau[j];
        if tau[j].to_f64() == 0.0 {
            continue;
        }
        // t[0..j, j] = -tau_j * T[0..j, 0..j] * V[:, 0..j]^T v_j
        let mut w = vec![E::ZERO; j];
        for c in 0..j {
            // dot of V[:, c] (unit at row c) with v_j (unit at row j).
            let mut dot = if j < rows { v[(j, c)] } else { E::ZERO }; // V[j, c] * v_j[j] (=1)
            for r in j + 1..rows {
                dot += v[(r, c)] * v[(r, j)];
            }
            w[c] = dot;
        }
        for r in 0..j {
            let mut acc = E::ZERO;
            for c in r..j {
                acc += t[(r, c)] * w[c];
            }
            t[(r, j)] = E::from_f64(-tau[j].to_f64()) * acc;
        }
    }
    t
}

/// Blocked QR: factor `a` (m x n, m >= n) in place with block size `b`;
/// trailing updates go through the co-design engine. The three GEMMs per
/// panel recur with per-step shapes, so the engine's config memo cache
/// reduces selector work to one scoring pass per distinct shape. With
/// the engine's lookahead enabled the queue-based deep pipeline keeps up
/// to `depth` panels factored ahead of the trailing sweep (module docs);
/// results are bitwise identical at every depth.
pub fn qr_blocked(a0: &MatrixF64, block: usize, engine: &mut GemmEngine) -> QrFactors {
    let (m, n) = (a0.rows(), a0.cols());
    assert!(m >= n, "qr_blocked expects m >= n");
    let mut a = a0.clone();
    let mut tau = vec![0.0; n];
    let b = if block == 0 { engine.dag_tile_size_t::<f64>(m) } else { block.max(1) };
    match engine.sched() {
        SchedPolicy::Dag => qr_dag::<f64>(&mut a, &mut tau, b, engine),
        SchedPolicy::Lookahead if engine.lookahead().enabled() => {
            qr_lookahead(&mut a, &mut tau, b, engine)
        }
        SchedPolicy::Lookahead => qr_baseline(&mut a, &mut tau, b, engine),
    }
    QrFactors { qr: a, tau, block: b }
}

/// The dtype-generic blocked QR behind [`qr_blocked`]: DAG or serialized
/// baseline. The deep-lookahead pipeline stays f64-only; f64 callers
/// reach it through [`qr_blocked`].
pub fn qr_blocked_t<E: GemmElem>(
    a0: &Matrix<E>,
    block: usize,
    engine: &mut GemmEngine,
) -> QrFactors<E> {
    let (m, n) = (a0.rows(), a0.cols());
    assert!(m >= n, "qr_blocked_t expects m >= n");
    let mut a = a0.clone();
    let mut tau = vec![E::ZERO; n];
    let b = if block == 0 { engine.dag_tile_size_t::<E>(m) } else { block.max(1) };
    match engine.sched() {
        SchedPolicy::Dag => qr_dag(&mut a, &mut tau, b, engine),
        SchedPolicy::Lookahead => qr_baseline(&mut a, &mut tau, b, engine),
    }
    QrFactors { qr: a, tau, block: b }
}

/// The serialized path: factor the panel, then apply the compact-WY
/// update to the whole trailing matrix, per iteration.
fn qr_baseline<E: GemmElem>(a: &mut Matrix<E>, tau: &mut [E], b: usize, engine: &mut GemmEngine) {
    let (m, n) = (a.rows(), a.cols());
    let mut k = 0;
    while k < n {
        let bb = b.min(n - k);
        let rows = m - k;
        {
            let mut panel = a.sub_mut(k, k, rows, bb);
            geqr2_t(&mut panel, &mut tau[k..k + bb]);
        }
        // Trailing update: A2 := (I - V T V^T)^T A2 = A2 - V T^T (V^T A2).
        if k + bb < n {
            let cols = n - k - bb;
            // V: rows x bb unit-lower-trapezoid from the factored panel.
            let v = Matrix::from_fn(rows, bb, |i, j| {
                if i == j {
                    E::ONE
                } else if i > j {
                    a[(k + i, k + j)]
                } else {
                    E::ZERO
                }
            });
            let t = larft(&v, &tau[k..k + bb]);
            let a2 = a.sub(k, k + bb, rows, cols).to_owned_matrix();
            // W = V^T A2  (bb x cols): skinny-k GEMM, k-dim = rows.
            let vt = v.transposed();
            let mut w = Matrix::<E>::zeros(bb, cols);
            engine.gemm_t(E::ONE, vt.view(), a2.view(), E::ZERO, &mut w.view_mut());
            // W := T^T W (small triangular multiply).
            let tt = t.transposed();
            let mut tw = Matrix::<E>::zeros(bb, cols);
            engine.gemm_t(E::ONE, tt.view(), w.view(), E::ZERO, &mut tw.view_mut());
            // A2 := A2 - V W: the paper's skinny-k trailing update.
            let mut a2m = a.sub_mut(k, k + bb, rows, cols);
            engine.gemm_t(E::from_f64(-1.0), v.view(), tw.view(), E::ONE, &mut a2m);
        }
        k += bb;
    }
}

/// One node of the QR tile DAG (see [`qr_dag`]).
#[derive(Clone, Copy)]
enum QrTask {
    /// `geqr2` on panel `t`, tau publication, and the `V`/`V^T`/`T^T`
    /// snapshots the update tasks read.
    Panel { t: usize },
    /// Step-`t` compact-WY update slice on trailing block-column `j > t`.
    Update { t: usize, j: usize },
}

/// The tile-DAG dataflow pipeline (`DLA_SCHED=dag`): `Panel(t)` and
/// `Update(t, j)` tasks with edges `Panel(t) <- Update(t-1, t)`,
/// `Update(t, j) <- Panel(t)` and `<- Update(t-1, j)`, drained by the
/// pool ranks through work-stealing deques in one broadcast job
/// ([`crate::runtime::dag`]). `Panel(t)` materializes `V_t` / `V_t^T` /
/// `T_t^T` once into shared scratch (read concurrently, zero-copy, by
/// every `Update(t, ·)`); each update runs the baseline's three GEMMs
/// (`W = V^T A2`, `TW = T^T W`, `A2 -= V TW`) restricted to its
/// block-column, under configs planned on the step's **full** trailing
/// dims — so factors and tau are bitwise identical to the serialized
/// baseline (`tests/dag.rs`).
fn qr_dag<E: GemmElem>(a: &mut Matrix<E>, tau: &mut [E], b: usize, engine: &mut GemmEngine) {
    let (m, n) = (a.rows(), a.cols());
    assert!(b >= 1);
    let panels = n.div_ceil(b);
    let col_of = |t: usize| (t * b).min(n);
    let width_of = |t: usize| col_of(t + 1) - col_of(t);
    // Per-step (W, TW, update) configs on the full trailing dims
    // (bitwise doctrine; pre-planned — the config memo is not Sync).
    type PlanT<E> = (crate::model::ccp::GemmConfig, MicroKernelImpl<E>);
    let plans: Vec<(PlanT<E>, PlanT<E>, PlanT<E>)> = (0..panels)
        .map(|t| {
            let (k, bb) = (col_of(t), width_of(t));
            let (rows, cols) = (m - k, n - k - bb);
            if cols > 0 {
                (
                    engine.plan_kernel_t::<E>(GemmDims::new(bb, cols, rows)),
                    engine.plan_kernel_t::<E>(GemmDims::new(bb, cols, bb)),
                    engine.plan_kernel_t::<E>(GemmDims::new(rows, cols, bb)),
                )
            } else {
                let dummy = GemmDims::new(1, 1, 1); // last panel: never used
                (
                    engine.plan_kernel_t::<E>(dummy),
                    engine.plan_kernel_t::<E>(dummy),
                    engine.plan_kernel_t::<E>(dummy),
                )
            }
        })
        .collect();
    // Shared scratch written once by Panel(t), read concurrently by the
    // step's update tasks: V (unit lower trapezoid), V^T, T^T, and the
    // tau slices (disjoint rows of one column vector).
    let mut v_store: Vec<Matrix<E>> =
        (0..panels).map(|t| Matrix::zeros(m - col_of(t), width_of(t))).collect();
    let mut vt_store: Vec<Matrix<E>> =
        (0..panels).map(|t| Matrix::zeros(width_of(t), m - col_of(t))).collect();
    let mut tt_store: Vec<Matrix<E>> =
        (0..panels).map(|t| Matrix::zeros(width_of(t), width_of(t))).collect();
    let mut tau_mat: Matrix<E> = Matrix::zeros(n.max(1), 1);
    let v_sp: Vec<SharedPanel<E>> = v_store
        .iter_mut()
        .map(|mm| {
            let mut vv = mm.view_mut();
            SharedPanel::new(&mut vv)
        })
        .collect();
    let vt_sp: Vec<SharedPanel<E>> = vt_store
        .iter_mut()
        .map(|mm| {
            let mut vv = mm.view_mut();
            SharedPanel::new(&mut vv)
        })
        .collect();
    let tt_sp: Vec<SharedPanel<E>> = tt_store
        .iter_mut()
        .map(|mm| {
            let mut vv = mm.view_mut();
            SharedPanel::new(&mut vv)
        })
        .collect();
    let tau_sp = {
        let mut tv = tau_mat.view_mut();
        SharedPanel::new(&mut tv)
    };
    // --- Static task graph -------------------------------------------
    let mut gb = GraphBuilder::new();
    let mut tasks: Vec<QrTask> = Vec::new();
    let mut update_id: Vec<Vec<usize>> = vec![Vec::new(); panels]; // [t][j - t - 1]
    for t in 0..panels {
        let pid = gb.add_task();
        tasks.push(QrTask::Panel { t });
        if t > 0 {
            gb.add_edge(update_id[t - 1][0], pid); // Update(t-1, t)
        }
        for j in (t + 1)..panels {
            let id = gb.add_task();
            tasks.push(QrTask::Update { t, j });
            gb.add_edge(pid, id);
            if t > 0 {
                gb.add_edge(update_id[t - 1][j - t], id); // Update(t-1, j)
            }
            update_id[t].push(id);
        }
    }
    let pool = engine.pool().cloned();
    let threads = pool.as_ref().map_or(1, |p| p.threads());
    let graph = gb.seal(threads);
    let mut av = a.view_mut();
    let shared = SharedPanel::new(&mut av);
    let body = |task: usize, ws: &mut Workspace| match tasks[task] {
        QrTask::Panel { t } => {
            let (k, bb) = (col_of(t), width_of(t));
            let rows = m - k;
            // SAFETY: block-column t's earlier writers (Update(0..t, t))
            // are predecessors; concurrent tasks touch other columns.
            let mut pv = unsafe { shared.sub(k, k, rows, bb).view_mut() };
            let mut tau_local = vec![E::ZERO; bb];
            geqr2_t(&mut pv, &mut tau_local);
            // Publish tau (disjoint rows per panel).
            // SAFETY: sole writer of rows k..k+bb; readers are graph
            // successors (or the post-drain copy).
            unsafe {
                let mut td = tau_sp.sub(k, 0, bb, 1).view_mut();
                for j in 0..bb {
                    td.set(j, 0, tau_local[j]);
                }
            }
            if k + bb < n {
                // Materialize V / V^T / T^T once for the update tasks.
                let v = Matrix::from_fn(rows, bb, |i, j| {
                    if i == j {
                        E::ONE
                    } else if i > j {
                        pv.at(i, j)
                    } else {
                        E::ZERO
                    }
                });
                let tmat = larft(&v, &tau_local);
                // SAFETY: snapshots are written only here; every reader
                // is a graph successor.
                unsafe {
                    let mut vd = v_sp[t].view_mut();
                    let mut vtd = vt_sp[t].view_mut();
                    for c in 0..bb {
                        for r in 0..rows {
                            vd.set(r, c, v[(r, c)]);
                            vtd.set(c, r, v[(r, c)]);
                        }
                    }
                    let mut ttd = tt_sp[t].view_mut();
                    for c in 0..bb {
                        for r in 0..bb {
                            ttd.set(c, r, tmat[(r, c)]);
                        }
                    }
                }
            }
        }
        QrTask::Update { t, j } => {
            let (k, bb) = (col_of(t), width_of(t));
            let rows = m - k;
            let (cj, bj) = (col_of(j), width_of(j));
            let ((cfg_w, kern_w), (cfg_tw, kern_tw), (cfg_u, kern_u)) = &plans[t];
            // SAFETY: block-column j's previous writer Update(t-1, j) is
            // a predecessor; V/V^T/T^T are frozen snapshots (read-only
            // after Panel(t)); concurrent tasks touch other columns.
            unsafe {
                let a2s = shared.sub(k, cj, rows, bj).to_owned_matrix();
                let mut w = Matrix::<E>::zeros(bb, bj);
                gemm_blocked(
                    cfg_w,
                    kern_w,
                    E::ONE,
                    vt_sp[t].view(),
                    a2s.view(),
                    E::ZERO,
                    &mut w.view_mut(),
                    ws,
                );
                let mut tw = Matrix::<E>::zeros(bb, bj);
                gemm_blocked(
                    cfg_tw,
                    kern_tw,
                    E::ONE,
                    tt_sp[t].view(),
                    w.view(),
                    E::ZERO,
                    &mut tw.view_mut(),
                    ws,
                );
                let mut c_s = shared.sub(k, cj, rows, bj).view_mut();
                gemm_blocked(
                    cfg_u,
                    kern_u,
                    E::from_f64(-1.0),
                    v_sp[t].view(),
                    tw.view(),
                    E::ONE,
                    &mut c_s,
                    ws,
                );
            }
        }
    };
    if !graph.is_empty() {
        match &pool {
            Some(p) => {
                let job = |ctx: &crate::runtime::pool::PoolCtx<'_>| {
                    execute_rank(&graph, ctx, |t| {
                        let mut ws = ctx.workspace();
                        body(t, &mut ws);
                    });
                };
                p.run(&job);
            }
            None => {
                let mut ws = Workspace::new();
                execute_serial(&graph, |t| body(t, &mut ws));
            }
        }
    }
    for (i, slot) in tau.iter_mut().enumerate().take(n) {
        *slot = tau_mat[(i, 0)];
    }
}

/// The queue-based deep-lookahead path (same work-queue skeleton as the
/// LU pipeline): iteration `t` computes `W`/`TW` only for the columns
/// right of the in-flight window (the window slices were consumed when
/// those panels were readied), the fused job's full team applies the
/// compact-WY update to the columns entering the window, and the panel
/// task replays the in-window iterations' update slices on them and runs
/// `geqr2` (leader-sequential, so the panel team is one rank) while the
/// update sub-team sweeps the remainder. Every GEMM — full-width,
/// entering-slice or chain-slice — runs under the configuration planned
/// for that iteration's *full* trailing dims, so factors and tau are
/// bitwise identical to the baseline at every depth.
fn qr_lookahead(a: &mut MatrixF64, tau: &mut [f64], b: usize, engine: &mut GemmEngine) {
    let (m, n) = (a.rows(), a.cols());
    let depth = engine.lookahead().depth.max(1);
    let panels = n.div_ceil(b);
    let col_of = |t: usize| (t * b).min(n);
    let width_of = |t: usize| col_of(t + 1) - col_of(t);
    let chain_ws = Mutex::new(Workspace::new());
    // Panel 0 up front (nothing to overlap it with yet).
    {
        let b0 = width_of(0);
        let mut panel = a.sub_mut(0, 0, m, b0);
        geqr2(&mut panel, &mut tau[..b0]);
    }
    let mut nf = 1usize;
    for t in 0..panels {
        let k = col_of(t);
        let bb = width_of(t);
        if k + bb >= n {
            continue;
        }
        let rows = m - k;
        let cols = n - k - bb;
        let wend = col_of(nf);
        let nf_new = (t + 1 + depth).min(panels);
        if nf_new == nf {
            // Queue exhausted ⇒ the window covers every trailing column;
            // skip the would-be queue-empty job (no tail left).
            debug_assert!(wend >= n);
            continue;
        }
        // V_t / T_t from the factored panel (stable: nothing right of
        // iteration t writes panel t's columns again).
        let v = MatrixF64::from_fn(rows, bb, |i, j| {
            if i == j {
                1.0
            } else if i > j {
                a[(k + i, k + j)]
            } else {
                0.0
            }
        });
        let tmat = larft(&v, &tau[k..k + bb]);
        // W/TW for the columns right of the window only, under configs
        // planned on the FULL trailing dims (bitwise identical to the
        // baseline's full-width GEMMs restricted to these columns; the
        // window slices were computed by the chains that readied those
        // panels). TW is laid into a full-width, zero-padded B so the
        // fused driver's column ranges index it directly.
        let (cfg_w, _) = engine.plan_kernel(GemmDims::new(bb, cols, rows));
        let (cfg_tw, _) = engine.plan_kernel(GemmDims::new(bb, cols, bb));
        let mut tw_full = MatrixF64::zeros(bb, cols);
        if wend < n {
            let right = n - wend;
            let a2r = a.sub(k, wend, rows, right).to_owned_matrix();
            let vt = v.transposed();
            let mut w_r = MatrixF64::zeros(bb, right);
            engine.gemm_with_config(&cfg_w, 1.0, vt.view(), a2r.view(), 0.0, &mut w_r.view_mut());
            // TW lands directly in the column-offset window of the
            // full-width B buffer the fused driver will index.
            let tt = tmat.transposed();
            let off = wend - k - bb;
            let mut tw_view = tw_full.sub_mut(0, off, bb, right);
            engine.gemm_with_config(&cfg_tw, 1.0, tt.view(), w_r.view(), 0.0, &mut tw_view);
        }
        let head = [(wend - k - bb, col_of(nf_new) - k - bb)];
        let tail = (col_of(nf_new) - k - bb, cols);
        // Per-iteration (W, TW, update) configs for the chain's replay of
        // iterations (t, nf_new - 1) on the entering columns.
        type Plan = (crate::model::ccp::GemmConfig, crate::gemm::MicroKernelImpl);
        let chain_plans: Vec<(Plan, Plan, Plan)> = ((t + 1)..nf_new.saturating_sub(1))
            .map(|i| {
                let (ci, bi) = (col_of(i), width_of(i));
                let (ri, ni) = (m - ci, n - ci - bi);
                (
                    engine.plan_kernel(GemmDims::new(bi, ni, ri)),
                    engine.plan_kernel(GemmDims::new(bi, ni, bi)),
                    engine.plan_kernel(GemmDims::new(ri, ni, bi)),
                )
            })
            .collect();
        let tau_next: Mutex<Vec<Vec<f64>>> = Mutex::new(vec![Vec::new(); nf_new - nf]);
        let tau_ro: &[f64] = tau;
        let mut a2m = a.sub_mut(k, k + bb, rows, cols);
        let shared = SharedPanel::new(&mut a2m);
        let chain = |sub: &SubTeam<'_>| {
            if sub.rank != 0 {
                return;
            }
            let mut wsg = chain_ws.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            let mut taus = tau_next.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            for (wi, w) in (nf..nf_new).enumerate() {
                let (cw, bw) = (col_of(w), width_of(w));
                let wc = cw - k - bb; // panel w's columns, a2m-relative
                for i in (t + 1)..w {
                    let (ci, bi) = (col_of(i), width_of(i));
                    let ri = m - ci;
                    // SAFETY (all shared accesses): the update team only
                    // touches tail columns; this task is the sole writer
                    // of the entering columns and reads only stable
                    // in-window panels besides them.
                    unsafe {
                        // V_i / T_i rebuilt from the in-window panel.
                        let pcol = ci - k - bb;
                        let pview = shared.sub(ci - k, pcol, ri, bi);
                        let vi = MatrixF64::from_fn(ri, bi, |r, c| {
                            if r == c {
                                1.0
                            } else if r > c {
                                pview.at(r, c)
                            } else {
                                0.0
                            }
                        });
                        let tau_i: Vec<f64> = if i < nf {
                            tau_ro[ci..ci + bi].to_vec()
                        } else {
                            taus[i - nf].clone()
                        };
                        let ti = larft(&vi, &tau_i);
                        // W_s = V_i^T A2_slice, TW_s = T_i^T W_s,
                        // slice -= V_i TW_s — each under iteration i's
                        // full-dims config.
                        let a2s = shared.sub(ci - k, wc, ri, bw).to_owned_matrix();
                        let ((cfg_w_i, kern_w_i), (cfg_t_i, kern_t_i), (cfg_u_i, kern_u_i)) =
                            &chain_plans[i - (t + 1)];
                        let vit = vi.transposed();
                        let mut w_s = MatrixF64::zeros(bi, bw);
                        gemm_blocked(
                            cfg_w_i, kern_w_i, 1.0, vit.view(), a2s.view(), 0.0,
                            &mut w_s.view_mut(), &mut wsg,
                        );
                        let tit = ti.transposed();
                        let mut tw_s = MatrixF64::zeros(bi, bw);
                        gemm_blocked(
                            cfg_t_i, kern_t_i, 1.0, tit.view(), w_s.view(), 0.0,
                            &mut tw_s.view_mut(), &mut wsg,
                        );
                        let mut c_s = shared.sub(ci - k, wc, ri, bw).view_mut();
                        gemm_blocked(
                            cfg_u_i, kern_u_i, -1.0, vi.view(), tw_s.view(), 1.0, &mut c_s,
                            &mut wsg,
                        );
                    }
                }
                // Panel w is ready: factor it and record its tau.
                // SAFETY: as above.
                let mut pv = unsafe { shared.sub(cw - k, wc, m - cw, bw).view_mut() };
                let mut tw_tau = vec![0.0f64; bw];
                geqr2(&mut pv, &mut tw_tau);
                taus[wi] = tw_tau;
            }
        };
        engine.gemm_fused_trailing_ranges(
            -1.0,
            v.view(),
            tw_full.view(),
            &mut a2m,
            &head,
            tail,
            1,
            false, // never queue-empty: empty jobs are skipped above
            &chain,
        );
        let taus = tau_next.into_inner().unwrap_or_else(std::sync::PoisonError::into_inner);
        for (wi, w) in (nf..nf_new).enumerate() {
            let cw = col_of(w);
            tau[cw..cw + taus[wi].len()].copy_from_slice(&taus[wi]);
        }
        nf = nf_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::ConfigMode;
    use crate::util::Pcg64;

    fn engine() -> GemmEngine {
        GemmEngine::new(host_xeon(), ConfigMode::Refined)
    }

    #[test]
    fn qr_reconstructs_a() {
        let mut rng = Pcg64::seed(80);
        for (m, n, b) in [(16, 16, 4), (40, 24, 8), (33, 17, 5), (24, 24, 24)] {
            let a0 = MatrixF64::random(m, n, &mut rng);
            let f = qr_blocked(&a0, b, &mut engine());
            let recon = f.reconstruction_error(&a0);
            let ortho = f.orthogonality_error();
            assert!(recon < 1e-10, "m={m} n={n} b={b}: |A-QR| = {recon}");
            assert!(ortho < 1e-10, "m={m} n={n} b={b}: |QtQ-I| = {ortho}");
        }
    }

    #[test]
    fn blocked_matches_unblocked() {
        let mut rng = Pcg64::seed(81);
        let a0 = MatrixF64::random(30, 18, &mut rng);
        let blocked = qr_blocked(&a0, 6, &mut engine());
        let mut unb = a0.clone();
        let mut tau = vec![0.0; 18];
        geqr2(&mut unb.view_mut(), &mut tau);
        assert!(blocked.qr.max_abs_diff(&unb) < 1e-9, "factors differ");
        for (a, b) in blocked.tau.iter().zip(&tau) {
            assert!((a - b).abs() < 1e-10, "tau differs");
        }
    }

    #[test]
    fn r_diagonal_nonzero_for_full_rank() {
        let mut rng = Pcg64::seed(82);
        let a0 = MatrixF64::random(20, 12, &mut rng);
        let f = qr_blocked(&a0, 4, &mut engine());
        for j in 0..12 {
            assert!(f.qr[(j, j)].abs() > 1e-8, "R[{j},{j}] suspiciously small");
        }
    }

    #[test]
    fn tall_skinny_panel_only() {
        // n <= b: single panel, no trailing update.
        let mut rng = Pcg64::seed(83);
        let a0 = MatrixF64::random(50, 8, &mut rng);
        let f = qr_blocked(&a0, 32, &mut engine());
        assert!(f.reconstruction_error(&a0) < 1e-11);
    }
}
