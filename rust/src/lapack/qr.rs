//! Blocked Householder QR (extension): a third LAPACK-level consumer of
//! the co-design GEMM.
//!
//! The blocked algorithm follows LAPACK's `geqrf`: factor a `b`-column
//! panel with Householder reflectors (`geqr2`), build the compact-WY
//! triangular factor `T` (`larft`), and apply `(I - V T V^T)^T` to the
//! trailing columns with two GEMM-rich steps (`larfb`) — the trailing
//! update again has inner dimension `b`, the paper's skinny-k shape.
//!
//! With the engine's [`crate::gemm::Lookahead`] enabled, the final (and
//! dominant) `A2 -= V * (T^T V^T A2)` GEMM runs as the fused split-team
//! update: the team applies it to the next panel's `b` columns first, the
//! panel sub-team leader then runs `geqr2` on that freshly-updated panel
//! while the update sub-team finishes the remaining columns. The packed V
//! is shared by both column phases. Factors and tau are bitwise identical
//! to the serialized path.

use std::sync::Mutex;

use crate::gemm::GemmEngine;
use crate::util::matrix::{MatrixF64, MatViewMut};

use super::pfact::SharedPanel;

/// Result of a blocked QR factorization.
pub struct QrFactors {
    /// Packed factors: R in the upper triangle, Householder vectors V
    /// (unit lower trapezoid, implicit leading 1) below the diagonal.
    pub qr: MatrixF64,
    /// Scalar reflector coefficients tau, one per column.
    pub tau: Vec<f64>,
    pub block: usize,
}

impl QrFactors {
    /// Assemble the explicit `m x m` orthogonal factor Q (test/demo use).
    pub fn q_matrix(&self) -> MatrixF64 {
        let m = self.qr.rows();
        let n = self.qr.cols().min(m);
        let mut q = MatrixF64::identity(m);
        // Apply H_0 H_1 ... H_{n-1} to I from the left, in reverse.
        for j in (0..n).rev() {
            let tau = self.tau[j];
            if tau == 0.0 {
                continue;
            }
            // v = [0_{j}, 1, qr[j+1.., j]]
            let mut v = vec![0.0; m];
            v[j] = 1.0;
            for i in j + 1..m {
                v[i] = self.qr[(i, j)];
            }
            // Q := (I - tau v v^T) Q
            for c in 0..m {
                let mut dot = 0.0;
                for r in j..m {
                    dot += v[r] * q[(r, c)];
                }
                let s = tau * dot;
                for r in j..m {
                    let upd = q[(r, c)] - s * v[r];
                    q[(r, c)] = upd;
                }
            }
        }
        q
    }

    /// Explicit R (upper triangular/trapezoidal).
    pub fn r_matrix(&self) -> MatrixF64 {
        let (m, n) = (self.qr.rows(), self.qr.cols());
        MatrixF64::from_fn(m, n, |i, j| if i <= j { self.qr[(i, j)] } else { 0.0 })
    }

    /// `max |A - Q R| / max|A|`.
    pub fn reconstruction_error(&self, a0: &MatrixF64) -> f64 {
        let q = self.q_matrix();
        let r = self.r_matrix();
        let mut qr = MatrixF64::zeros(a0.rows(), a0.cols());
        crate::gemm::gemm_reference(1.0, q.view(), r.view(), 0.0, &mut qr.view_mut());
        qr.max_abs_diff(a0) / a0.max_abs().max(1e-300)
    }

    /// `max |Q^T Q - I|` (orthogonality).
    pub fn orthogonality_error(&self) -> f64 {
        let q = self.q_matrix();
        let qt = q.transposed();
        let mut qtq = MatrixF64::zeros(q.rows(), q.rows());
        crate::gemm::gemm_reference(1.0, qt.view(), q.view(), 0.0, &mut qtq.view_mut());
        qtq.max_abs_diff(&MatrixF64::identity(q.rows()))
    }
}

/// Unblocked Householder QR of a panel (LAPACK `geqr2`), in place.
pub fn geqr2(a: &mut MatViewMut<'_>, tau: &mut [f64]) {
    let (m, n) = (a.rows, a.cols);
    let steps = m.min(n);
    assert!(tau.len() >= steps);
    for j in 0..steps {
        // Householder vector for column j below the diagonal.
        let alpha = a.at(j, j);
        let mut xnorm2 = 0.0;
        for i in j + 1..m {
            let v = a.at(i, j);
            xnorm2 += v * v;
        }
        if xnorm2 == 0.0 {
            tau[j] = 0.0;
            continue;
        }
        let norm = (alpha * alpha + xnorm2).sqrt();
        let beta = if alpha >= 0.0 { -norm } else { norm };
        let tj = (beta - alpha) / beta;
        tau[j] = tj;
        let scale = 1.0 / (alpha - beta);
        for i in j + 1..m {
            let v = a.at(i, j) * scale;
            a.set(i, j, v);
        }
        a.set(j, j, beta);
        // Apply H_j to the remaining panel columns: A := (I - tau v v^T) A.
        for c in j + 1..n {
            let mut dot = a.at(j, c);
            for i in j + 1..m {
                dot += a.at(i, j) * a.at(i, c);
            }
            let s = tj * dot;
            let upd0 = a.at(j, c) - s;
            a.set(j, c, upd0);
            for i in j + 1..m {
                let upd = a.at(i, c) - s * a.at(i, j);
                a.set(i, c, upd);
            }
        }
    }
}

/// Build the upper-triangular compact-WY factor T (LAPACK `larft`,
/// forward/columnwise) for the b reflectors stored in `v` (unit lower
/// trapezoid, `rows x b`).
fn larft(v: &MatrixF64, tau: &[f64]) -> MatrixF64 {
    let b = v.cols();
    let rows = v.rows();
    let mut t = MatrixF64::zeros(b, b);
    for j in 0..b {
        t[(j, j)] = tau[j];
        if tau[j] == 0.0 {
            continue;
        }
        // t[0..j, j] = -tau_j * T[0..j, 0..j] * V[:, 0..j]^T v_j
        let mut w = vec![0.0; j];
        for c in 0..j {
            // dot of V[:, c] (unit at row c) with v_j (unit at row j).
            let mut dot = if j < rows { v[(j, c)] } else { 0.0 }; // V[j, c] * v_j[j] (=1)
            for r in j + 1..rows {
                dot += v[(r, c)] * v[(r, j)];
            }
            w[c] = dot;
        }
        for r in 0..j {
            let mut acc = 0.0;
            for c in r..j {
                acc += t[(r, c)] * w[c];
            }
            t[(r, j)] = -tau[j] * acc;
        }
    }
    t
}

/// Blocked QR: factor `a` (m x n, m >= n) in place with block size `b`;
/// trailing updates go through the co-design engine. The three GEMMs per
/// panel recur with per-step shapes, so the engine's config memo cache
/// reduces selector work to one scoring pass per distinct shape. With the
/// engine's lookahead enabled the final GEMM overlaps the next panel's
/// `geqr2` (module docs); results are bitwise identical.
pub fn qr_blocked(a0: &MatrixF64, block: usize, engine: &mut GemmEngine) -> QrFactors {
    let (m, n) = (a0.rows(), a0.cols());
    assert!(m >= n, "qr_blocked expects m >= n");
    let mut a = a0.clone();
    let mut tau = vec![0.0; n];
    let b = block.max(1);
    let la = engine.lookahead();
    if la.enabled() {
        // Panel 0 up front; each iteration then enters with its panel
        // factored and overlaps the next `geqr2` with the trailing GEMM.
        let b0 = b.min(n);
        let mut panel = a.sub_mut(0, 0, m, b0);
        geqr2(&mut panel, &mut tau[..b0]);
    }
    let mut k = 0;
    while k < n {
        let bb = b.min(n - k);
        let rows = m - k;
        // Panel factorization (already done by the previous iteration's
        // fused job — or the warm-up above — on the lookahead path).
        if !la.enabled() {
            let mut panel = a.sub_mut(k, k, rows, bb);
            geqr2(&mut panel, &mut tau[k..k + bb]);
        }
        // Trailing update: A2 := (I - V T V^T)^T A2 = A2 - V T^T (V^T A2).
        if k + bb < n {
            let cols = n - k - bb;
            // V: rows x bb unit-lower-trapezoid from the factored panel.
            let v = MatrixF64::from_fn(rows, bb, |i, j| {
                if i == j {
                    1.0
                } else if i > j {
                    a[(k + i, k + j)]
                } else {
                    0.0
                }
            });
            let t = larft(&v, &tau[k..k + bb]);
            let a2 = a.sub(k, k + bb, rows, cols).to_owned_matrix();
            // W = V^T A2  (bb x cols): skinny-k GEMM, k-dim = rows.
            let vt = v.transposed();
            let mut w = MatrixF64::zeros(bb, cols);
            engine.gemm(1.0, vt.view(), a2.view(), 0.0, &mut w.view_mut());
            // W := T^T W (small triangular multiply).
            let tt = t.transposed();
            let mut tw = MatrixF64::zeros(bb, cols);
            engine.gemm(1.0, tt.view(), w.view(), 0.0, &mut tw.view_mut());
            // A2 := A2 - V W: the paper's skinny-k trailing update.
            let mut a2m = a.sub_mut(k, k + bb, rows, cols);
            if la.enabled() {
                // Fused: the next panel lives in rows [bb..] of A2's
                // first next_b columns; factor it on the panel sub-team
                // once phase 1 has finished those columns.
                let next_b = b.min(cols);
                let panel_shared = SharedPanel::new(&mut a2m.sub_mut(bb, 0, rows - bb, next_b));
                let tau_next = Mutex::new(vec![0.0f64; next_b]);
                // geqr2 is leader-sequential (Householder norms are
                // reductions; no team variant yet), so a 1-rank panel
                // team keeps the remaining ranks in the update sweep.
                engine.gemm_fused_trailing(
                    -1.0,
                    v.view(),
                    tw.view(),
                    &mut a2m,
                    next_b,
                    1,
                    &|sub| {
                        if sub.rank == 0 {
                            // SAFETY: phase 1 is complete; the update team
                            // only touches columns >= next_b, and rows
                            // [0, bb) of the panel columns are final.
                            let mut pv = unsafe { panel_shared.view_mut() };
                            let mut t = tau_next.lock().unwrap();
                            geqr2(&mut pv, &mut t);
                        }
                    },
                );
                let tau_next = tau_next.into_inner().unwrap();
                tau[k + bb..k + bb + next_b].copy_from_slice(&tau_next);
            } else {
                engine.gemm(-1.0, v.view(), tw.view(), 1.0, &mut a2m);
            }
        }
        k += bb;
    }
    QrFactors { qr: a, tau, block: b }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::ConfigMode;
    use crate::util::Pcg64;

    fn engine() -> GemmEngine {
        GemmEngine::new(host_xeon(), ConfigMode::Refined)
    }

    #[test]
    fn qr_reconstructs_a() {
        let mut rng = Pcg64::seed(80);
        for (m, n, b) in [(16, 16, 4), (40, 24, 8), (33, 17, 5), (24, 24, 24)] {
            let a0 = MatrixF64::random(m, n, &mut rng);
            let f = qr_blocked(&a0, b, &mut engine());
            let recon = f.reconstruction_error(&a0);
            let ortho = f.orthogonality_error();
            assert!(recon < 1e-10, "m={m} n={n} b={b}: |A-QR| = {recon}");
            assert!(ortho < 1e-10, "m={m} n={n} b={b}: |QtQ-I| = {ortho}");
        }
    }

    #[test]
    fn blocked_matches_unblocked() {
        let mut rng = Pcg64::seed(81);
        let a0 = MatrixF64::random(30, 18, &mut rng);
        let blocked = qr_blocked(&a0, 6, &mut engine());
        let mut unb = a0.clone();
        let mut tau = vec![0.0; 18];
        geqr2(&mut unb.view_mut(), &mut tau);
        assert!(blocked.qr.max_abs_diff(&unb) < 1e-9, "factors differ");
        for (a, b) in blocked.tau.iter().zip(&tau) {
            assert!((a - b).abs() < 1e-10, "tau differs");
        }
    }

    #[test]
    fn r_diagonal_nonzero_for_full_rank() {
        let mut rng = Pcg64::seed(82);
        let a0 = MatrixF64::random(20, 12, &mut rng);
        let f = qr_blocked(&a0, 4, &mut engine());
        for j in 0..12 {
            assert!(f.qr[(j, j)].abs() > 1e-8, "R[{j},{j}] suspiciously small");
        }
    }

    #[test]
    fn tall_skinny_panel_only() {
        // n <= b: single panel, no trailing update.
        let mut rng = Pcg64::seed(83);
        let a0 = MatrixF64::random(50, 8, &mut rng);
        let f = qr_blocked(&a0, 32, &mut engine());
        assert!(f.reconstruction_error(&a0) < 1e-11);
    }
}
