//! Triangular solves (TSOLVE). Two cases are needed by the blocked
//! factorizations:
//!
//! - `trsm_left_lower_unit`: `B := L^{-1} B` with L unit lower triangular
//!   (the LU trailing-update solve `U12 = L11^{-1} A12` of paper §2.1);
//! - `trsm_right_upper`: `B := B U^{-1}` with U upper triangular,
//!   transposed-right form used by blocked Cholesky.
//!
//! Both are forward/back substitutions over the small `b x b` triangle;
//! the flop volume is `O(b^2 n)`, a lower-order term next to the GEMM, so
//! a cache-friendly loop order (column-major AXPY) is sufficient here.

use crate::util::elem::Elem;
use crate::util::matrix::{MatView, MatViewMut};

/// `B := Lower_unit(L)^{-1} * B`, where `l` is `q x q` (only its strictly
/// lower part is referenced; unit diagonal assumed) and `b` is `q x n`.
pub fn trsm_left_lower_unit<E: Elem>(l: MatView<'_, E>, b: &mut MatViewMut<'_, E>) {
    let q = l.rows;
    assert_eq!(l.cols, q, "L must be square");
    assert_eq!(b.rows, q, "B row mismatch");
    let n = b.cols;
    // Forward substitution, one column of B at a time; inner loop is a
    // column-major AXPY over L's column j.
    for c in 0..n {
        let bcol = c * b.ld;
        for j in 0..q {
            let xj = b.data[bcol + j];
            if xj == E::ZERO {
                continue;
            }
            let lcol = j * l.ld;
            for i in j + 1..q {
                let delta = l.data[lcol + i] * xj;
                b.data[bcol + i] -= delta;
            }
        }
    }
}

/// `B := B * Upper(U)^{-1}`, where `u` is `q x q` (upper triangle
/// referenced, non-unit diagonal) and `b` is `m x q`.
pub fn trsm_right_upper<E: Elem>(u: MatView<'_, E>, b: &mut MatViewMut<'_, E>) {
    let q = u.rows;
    assert_eq!(u.cols, q, "U must be square");
    assert_eq!(b.cols, q, "B col mismatch");
    let m = b.rows;
    for j in 0..q {
        // B(:, j) = (B(:, j) - sum_{t<j} B(:, t) U(t, j)) / U(j, j)
        let ucol = j * u.ld;
        for t in 0..j {
            let utj = u.data[ucol + t];
            if utj == E::ZERO {
                continue;
            }
            let (bt, bj) = (t * b.ld, j * b.ld);
            for i in 0..m {
                let delta = b.data[bt + i] * utj;
                b.data[bj + i] -= delta;
            }
        }
        let ujj = u.data[ucol + j];
        assert!(ujj != E::ZERO, "singular U in trsm_right_upper");
        let inv = E::ONE / ujj;
        let bj = j * b.ld;
        for i in 0..m {
            b.data[bj + i] *= inv;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_reference;
    use crate::util::{MatrixF64, Pcg64};

    fn unit_lower(q: usize, rng: &mut Pcg64) -> MatrixF64 {
        MatrixF64::from_fn(q, q, |i, j| {
            if i == j {
                1.0
            } else if i > j {
                rng.next_f64() - 0.5
            } else {
                0.0
            }
        })
    }

    fn upper(q: usize, rng: &mut Pcg64) -> MatrixF64 {
        MatrixF64::from_fn(q, q, |i, j| {
            if i < j {
                rng.next_f64() - 0.5
            } else if i == j {
                1.0 + rng.next_f64() // well away from zero
            } else {
                0.0
            }
        })
    }

    #[test]
    fn left_lower_unit_solves() {
        let mut rng = Pcg64::seed(21);
        let q = 16;
        let l = unit_lower(q, &mut rng);
        let x_true = MatrixF64::random(q, 9, &mut rng);
        // B = L * X; solve must recover X.
        let mut b = MatrixF64::zeros(q, 9);
        gemm_reference(1.0, l.view(), x_true.view(), 0.0, &mut b.view_mut());
        trsm_left_lower_unit(l.view(), &mut b.view_mut());
        assert!(b.max_abs_diff(&x_true) < 1e-10);
    }

    #[test]
    fn left_lower_ignores_upper_and_diagonal_of_l() {
        let mut rng = Pcg64::seed(22);
        let q = 8;
        let mut l = unit_lower(q, &mut rng);
        let x_true = MatrixF64::random(q, 3, &mut rng);
        let mut b = MatrixF64::zeros(q, 3);
        gemm_reference(1.0, l.view(), x_true.view(), 0.0, &mut b.view_mut());
        // Poison the upper triangle + diagonal: result must not change.
        for j in 0..q {
            for i in 0..=j {
                l[(i, j)] = f64::NAN;
            }
        }
        trsm_left_lower_unit(l.view(), &mut b.view_mut());
        assert!(b.max_abs_diff(&x_true) < 1e-10);
    }

    #[test]
    fn right_upper_solves() {
        let mut rng = Pcg64::seed(23);
        let q = 12;
        let u = upper(q, &mut rng);
        let x_true = MatrixF64::random(7, q, &mut rng);
        let mut b = MatrixF64::zeros(7, q);
        gemm_reference(1.0, x_true.view(), u.view(), 0.0, &mut b.view_mut());
        trsm_right_upper(u.view(), &mut b.view_mut());
        assert!(b.max_abs_diff(&x_true) < 1e-9);
    }

    #[test]
    fn trivial_sizes() {
        let l = MatrixF64::identity(1);
        let mut b = MatrixF64::from_row_major(1, 1, &[5.0]);
        trsm_left_lower_unit(l.view(), &mut b.view_mut());
        assert_eq!(b[(0, 0)], 5.0);
        // Zero-width B.
        let mut b0 = MatrixF64::zeros(1, 0);
        trsm_left_lower_unit(l.view(), &mut b0.view_mut());
    }
}
