//! The LAPACK-level layer: blocked algorithms built on the GEMM engine,
//! exactly as the paper's Figure 2 stack (LAPACK -> Level-3 BLAS -> GEMM
//! -> micro-kernel).
//!
//! - [`pfact`] — unblocked panel factorization with partial pivoting
//!   (PFACT; LAPACK's `getf2`) and the row-interchange helper `laswp`.
//! - [`trsm`] — triangular solves (TSOLVE; the cases the LU and Cholesky
//!   algorithms need).
//! - [`lu`] — the right-looking blocked LU of paper Figure 2, with
//!   partial pivoting, whose trailing update is the skinny-k GEMM the
//!   whole paper is about.
//! - [`cholesky`] — blocked Cholesky (extension; a second consumer of the
//!   co-design GEMM showing the approach generalizes beyond LU).
//! - [`qr`] — blocked Householder QR (compact-WY), a third consumer.
//! - [`refine`] — the mixed-precision LU solve: factor in f32 on the
//!   pooled lookahead pipeline (the dtype-generic [`lu::lu_factor_t`]),
//!   iteratively refine the solution to f64 residual accuracy, fall
//!   back cleanly to the plain f64 path when f32 cannot converge.
//!
//! All three factorizations run a **dynamic deep-lookahead work queue**
//! when the engine's [`crate::gemm::Lookahead`] policy is enabled (the
//! default for multi-thread plans): up to `depth` panels stay factored
//! ahead of the trailing sweep, readied by a malleable panel sub-team
//! (sized per iteration by the team-size model) *inside* the fused
//! trailing-update jobs, with results bitwise identical to the
//! serialized path at every depth. With `DLA_SCHED=dag` (or
//! [`crate::gemm::SchedPolicy::Dag`] pinned on the engine) they instead
//! run as **tile DAGs**: per-block-column tasks with explicit dataflow
//! edges, drained by the pool ranks through work-stealing deques in one
//! broadcast job ([`crate::runtime::dag`]) — still bitwise identical.
//! See `README.md` in this directory for both write-ups (queue states,
//! malleability rule, deferred-swap windows, DAG task/dependency rules,
//! `DLA_LOOKAHEAD`/`DLA_PANEL_WORKERS`/`DLA_PIN`/`DLA_SCHED`
//! semantics).

pub mod cholesky;
pub mod level3;
pub mod lu;
pub mod pfact;
pub mod qr;
pub mod refine;
pub mod trsm;

pub use cholesky::{cholesky_blocked, cholesky_blocked_t, cholesky_residual, potf2, potf2_t};
pub use level3::{syrk_lower, trsm_blocked_left_lower_unit};
pub use lu::{lu_blocked, lu_blocked_t, lu_factor, lu_factor_t, lu_flops, LuFactors};
pub use qr::{geqr2, geqr2_t, qr_blocked, qr_blocked_t, QrFactors};
pub use pfact::{getf2, getf2_team, laswp, laswp_parallel, SharedPanel, NO_ERR};
pub use refine::{lu_solve_f64, lu_solve_mixed, RefineOptions, RefineResult};
pub use trsm::{trsm_left_lower_unit, trsm_right_upper};
