//! Mixed-precision LU solve with iterative refinement — the classic
//! workload the dtype-generic stack opens (Langou et al., "Exploiting
//! the performance of 32 bit floating point arithmetic in obtaining 64
//! bit accuracy"; see PAPERS.md):
//!
//! 1. **Factor in f32** on the pooled lookahead pipeline
//!    ([`crate::lapack::lu::lu_factor_t`] at `E = f32`): half the memory
//!    traffic, twice the SIMD lanes, and the model's f32-width CCPs —
//!    the O(n³) work at roughly twice the rate.
//! 2. **Refine to f64**: iterate `r = b - A x` (f64 GEMM on the same
//!    pool), solve the correction `A d = r` with the retained f32
//!    factors (O(n²) per iteration), and update `x += d` in f64, until
//!    the scaled residual reaches f64 accuracy.
//! 3. **Fall back cleanly**: if the f32 factorization hits a zero pivot,
//!    or the refinement stagnates or diverges (the matrix is too
//!    ill-conditioned for f32 factors to contract the error), re-solve
//!    entirely in f64 — the answer is then exactly the plain-f64 path's.
//!
//! Both precisions run on one engine and one shared worker pool; the
//! coordinator exposes this as the `MixedSolve` request kind and reports
//! the per-precision split (f32 factor seconds vs f64 refine seconds,
//! iteration counts, fallbacks) in its metrics.

use crate::gemm::GemmEngine;
use crate::util::matrix::{MatrixF32, MatrixF64};
use crate::util::Stopwatch;

use super::lu::{lu_factor_t, LuFactors};

/// Knobs of the mixed-precision solver.
#[derive(Clone, Copy, Debug)]
pub struct RefineOptions {
    /// Algorithmic block size of both the f32 and the (fallback) f64
    /// factorization.
    pub block: usize,
    /// Refinement iteration cap; hitting it without convergence
    /// triggers the f64 fallback.
    pub max_iters: usize,
    /// Convergence target for the scaled residual
    /// `|b - Ax|_max / (|A|_max |x|_max + |b|_max)`.
    pub tol: f64,
}

impl Default for RefineOptions {
    fn default() -> Self {
        Self { block: 64, max_iters: 12, tol: 1e-12 }
    }
}

/// Result of a mixed-precision solve, with the per-precision breakdown
/// the serving metrics report.
pub struct RefineResult {
    /// The solution (f64).
    pub x: MatrixF64,
    /// Refinement iterations executed (0 when the f32 factorization
    /// already failed and the solve went straight to f64).
    pub iterations: usize,
    /// The f32 path could not reach f64 accuracy (or hit a zero pivot)
    /// and the solve was redone in f64.
    pub fell_back: bool,
    /// Final scaled residual of the returned `x`.
    pub residual: f64,
    /// Seconds spent in the f32 factorization (0 when it failed).
    pub f32_factor_seconds: f64,
    /// Seconds spent in the f64 residual/correction loop.
    pub refine_seconds: f64,
    /// Seconds spent in the f64 fallback factorization + solve (0 when
    /// not taken).
    pub fallback_seconds: f64,
}

/// Scaled residual `|b - Ax|_max / (|A|_max |x|_max + |b|_max)`,
/// computing `r = b - A x` through the engine (pooled when parallel).
/// Returns `(residual, r)` so the caller can reuse `r` as the
/// correction right-hand side.
fn scaled_residual(
    engine: &mut GemmEngine,
    a: &MatrixF64,
    b: &MatrixF64,
    x: &MatrixF64,
    anorm: f64,
    bnorm: f64,
) -> (f64, MatrixF64) {
    let mut r = b.clone();
    engine.gemm(-1.0, a.view(), x.view(), 1.0, &mut r.view_mut());
    let denom = (anorm * x.max_abs() + bnorm).max(f64::MIN_POSITIVE);
    (r.max_abs() / denom, r)
}

/// Solve `A x = b` by f32 LU factorization + f64 iterative refinement
/// (see the module docs). `A` must be square; `b` may have any number of
/// right-hand-side columns. Returns `Err(col)` only when **both** the
/// f32 and the fallback f64 factorization report singularity at `col`.
pub fn lu_solve_mixed(
    a: &MatrixF64,
    b: &MatrixF64,
    opts: &RefineOptions,
    engine: &mut GemmEngine,
) -> Result<RefineResult, usize> {
    let s = a.rows();
    assert_eq!(a.cols(), s, "mixed solve requires a square matrix");
    assert_eq!(b.rows(), s, "rhs row mismatch");
    let anorm = a.max_abs();
    let bnorm = b.max_abs();

    // --- Stage 1: factor in f32 on the pooled pipeline ------------------
    let sw = Stopwatch::start();
    let a32 = MatrixF32::convert_from(a);
    let f32_factors = lu_factor_t::<f32>(&a32, opts.block, engine);
    // Only time *retained* f32 factorizations: the metric reports the
    // per-precision split of work that contributed to the answer.
    let f32_factor_seconds = if f32_factors.is_ok() { sw.elapsed_secs() } else { 0.0 };

    let mut iterations = 0usize;
    let mut refine_seconds = 0.0;
    if let Ok(factors32) = f32_factors {
        // --- Stage 2: f64 residual / f32 correction loop ----------------
        let sw = Stopwatch::start();
        let mut x = MatrixF64::convert_from(&factors32.solve(&MatrixF32::convert_from(b)));
        let (mut rel, mut r) = scaled_residual(engine, a, b, &x, anorm, bnorm);
        let mut stalled = false;
        while rel > opts.tol && iterations < opts.max_iters && !stalled {
            let d32 = factors32.solve(&MatrixF32::convert_from(&r));
            for c in 0..x.cols() {
                for i in 0..s {
                    x[(i, c)] += d32[(i, c)] as f64;
                }
            }
            iterations += 1;
            let prev = rel;
            let (next, next_r) = scaled_residual(engine, a, b, &x, anorm, bnorm);
            // A healthy refinement contracts the residual by
            // ~cond(A) * eps_f32 per pass; anything above half the
            // previous residual means the f32 factors cannot drive the
            // error down and the loop would just burn GEMMs. A NaN/Inf
            // residual (overflowed f32 corrections) stalls explicitly —
            // NaN loses every `>` comparison, so without this guard the
            // exit would hinge on the loop condition's NaN semantics
            // instead of a deliberate bail to the clean f64 fallback.
            stalled = !next.is_finite() || next > 0.5 * prev;
            rel = next;
            r = next_r;
        }
        refine_seconds = sw.elapsed_secs();
        if rel <= opts.tol {
            return Ok(RefineResult {
                x,
                iterations,
                fell_back: false,
                residual: rel,
                f32_factor_seconds,
                refine_seconds,
                fallback_seconds: 0.0,
            });
        }
    }

    // --- Stage 3: clean f64 fallback ------------------------------------
    // Either the f32 factorization failed outright or the refinement
    // could not reach tol: redo the solve entirely in f64. The result is
    // exactly what the plain-f64 path produces on this engine.
    let sw = Stopwatch::start();
    let factors = super::lu::lu_factor(a, opts.block, engine)?;
    let x = factors.solve(b);
    let fallback_seconds = sw.elapsed_secs();
    let (rel, _) = scaled_residual(engine, a, b, &x, anorm, bnorm);
    Ok(RefineResult {
        x,
        iterations,
        fell_back: true,
        residual: rel,
        f32_factor_seconds,
        refine_seconds,
        fallback_seconds,
    })
}

/// Plain f64 factor + solve through the same engine — the baseline the
/// ablation harness compares [`lu_solve_mixed`] against.
pub fn lu_solve_f64(
    a: &MatrixF64,
    b: &MatrixF64,
    block: usize,
    engine: &mut GemmEngine,
) -> Result<MatrixF64, usize> {
    let factors: LuFactors = super::lu::lu_factor(a, block, engine)?;
    Ok(factors.solve(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::host_xeon;
    use crate::gemm::ConfigMode;
    use crate::util::Pcg64;

    fn engine() -> GemmEngine {
        GemmEngine::new(host_xeon(), ConfigMode::Refined)
    }

    #[test]
    fn well_conditioned_system_converges_to_f64_accuracy() {
        let mut rng = Pcg64::seed(314);
        let a = MatrixF64::random_diag_dominant(96, &mut rng);
        let x_true = MatrixF64::random(96, 2, &mut rng);
        let mut b = MatrixF64::zeros(96, 2);
        crate::gemm::gemm_reference(1.0, a.view(), x_true.view(), 0.0, &mut b.view_mut());
        let res = lu_solve_mixed(&a, &b, &RefineOptions { block: 24, ..Default::default() },
                                 &mut engine())
            .unwrap();
        assert!(!res.fell_back, "well-conditioned system must not fall back");
        assert!(res.residual <= 1e-10, "residual {}", res.residual);
        assert!(res.iterations >= 1, "f32 start cannot already be at f64 accuracy");
        assert!(res.x.max_abs_diff(&x_true) < 1e-9);
    }

    #[test]
    fn singular_matrix_errors_through_both_paths() {
        let a = MatrixF64::zeros(8, 8);
        let b = MatrixF64::zeros(8, 1);
        assert!(lu_solve_mixed(&a, &b, &RefineOptions::default(), &mut engine()).is_err());
    }

    #[test]
    fn ill_conditioned_system_falls_back_to_f64() {
        // Hilbert matrix of order 12: cond ~ 1e16, far beyond what f32
        // factors can refine. The solver must detect the stall and hand
        // back exactly the plain-f64 answer.
        let n = 12;
        let a = MatrixF64::from_fn(n, n, |i, j| 1.0 / ((i + j + 1) as f64));
        let mut rng = Pcg64::seed(7);
        let b = MatrixF64::random(n, 1, &mut rng);
        let opts = RefineOptions { block: 4, max_iters: 6, ..Default::default() };
        let res = lu_solve_mixed(&a, &b, &opts, &mut engine()).unwrap();
        assert!(res.fell_back, "cond ~1e16 must trigger the f64 fallback");
        let x64 = lu_solve_f64(&a, &b, opts.block, &mut engine()).unwrap();
        assert_eq!(res.x.max_abs_diff(&x64), 0.0, "fallback must equal the plain f64 solve");
    }

    #[test]
    fn per_precision_timings_are_reported() {
        let mut rng = Pcg64::seed(99);
        let a = MatrixF64::random_diag_dominant(64, &mut rng);
        let b = MatrixF64::random(64, 1, &mut rng);
        let res = lu_solve_mixed(&a, &b, &RefineOptions { block: 16, ..Default::default() },
                                 &mut engine())
            .unwrap();
        assert!(res.f32_factor_seconds > 0.0);
        assert!(res.refine_seconds > 0.0);
        assert_eq!(res.fallback_seconds, 0.0);
    }
}
