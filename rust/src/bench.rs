//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by every `rust/benches/exp_*.rs` target (built with
//! `harness = false`): each case is measured with warm-up + repetition
//! (paper: "average numbers collected for a large number of repetitions")
//! and reported as a table plus TSV under `results/`.

use crate::util::table::Table;
use crate::util::timer::{measure, Measurement};

/// One benchmark case result.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub measurement: Measurement,
    pub flops: f64,
}

impl CaseResult {
    pub fn gflops(&self) -> f64 {
        self.measurement.gflops(self.flops)
    }
}

/// A named group of benchmark cases.
pub struct BenchGroup {
    pub name: String,
    pub min_reps: usize,
    pub min_time_s: f64,
    results: Vec<CaseResult>,
}

impl BenchGroup {
    pub fn new(name: &str) -> Self {
        // Defaults tuned for the experiment harness: enough repetitions
        // for stability, bounded wall-time per case. Override per group
        // with the DLA_BENCH_REPS / DLA_BENCH_SECS environment knobs.
        let min_reps = std::env::var("DLA_BENCH_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(3);
        let min_time_s =
            std::env::var("DLA_BENCH_SECS").ok().and_then(|v| v.parse().ok()).unwrap_or(0.5);
        Self { name: name.to_string(), min_reps, min_time_s, results: Vec::new() }
    }

    /// Time a case; `flops` is per-repetition work for GFLOPS reporting.
    pub fn case(&mut self, name: &str, flops: f64, f: impl FnMut()) -> &CaseResult {
        let m = measure(self.min_reps, self.min_time_s, f);
        eprintln!(
            "  {:<40} {:>10.3} ms   {:>8.2} GFLOPS  ({} reps)",
            name,
            m.mean_s * 1e3,
            flops / m.mean_s / 1e9,
            m.reps
        );
        self.results.push(CaseResult { name: name.to_string(), measurement: m, flops });
        self.results.last().unwrap()
    }

    /// Record an externally computed result (e.g. model-based estimates
    /// that are not wall-clock measured).
    pub fn record(&mut self, name: &str, seconds: f64, flops: f64) {
        let m = Measurement { reps: 1, mean_s: seconds, min_s: seconds, median_s: seconds, max_s: seconds };
        self.results.push(CaseResult { name: name.to_string(), measurement: m, flops });
    }

    pub fn results(&self) -> &[CaseResult] {
        &self.results
    }

    /// Render the group as a table.
    pub fn table(&self) -> Table {
        let mut t = Table::new(&self.name, &["case", "mean ms", "min ms", "GFLOPS", "reps"]);
        for r in &self.results {
            t.row(&[
                r.name.clone(),
                format!("{:.3}", r.measurement.mean_s * 1e3),
                format!("{:.3}", r.measurement.min_s * 1e3),
                format!("{:.2}", r.gflops()),
                r.measurement.reps.to_string(),
            ]);
        }
        t
    }

    /// Print the table and write `results/<file>.tsv`.
    pub fn finish(&self, file: &str) {
        let t = self.table();
        t.print();
        let path = format!("results/{file}.tsv");
        if let Err(e) = t.write_tsv(&path) {
            eprintln!("warning: could not write {path}: {e}");
        }
    }
}

/// Minimal JSON trend-file emitter (serde is unavailable offline): a flat
/// list of `{"case": ..., "metric": value, ...}` entries under a named
/// header, written e.g. to `BENCH_gemm.json` so successive PRs can track
/// the performance trajectory with plain tooling.
pub struct JsonBench {
    name: String,
    entries: Vec<String>,
}

fn json_escape(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            '\n' => vec!['\\', 'n'],
            c => vec![c],
        })
        .collect()
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

impl JsonBench {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), entries: Vec::new() }
    }

    /// Append one entry: a case label plus numeric fields.
    pub fn entry(&mut self, case: &str, fields: &[(&str, f64)]) {
        let mut parts = vec![format!("\"case\": \"{}\"", json_escape(case))];
        for (k, v) in fields {
            parts.push(format!("\"{}\": {}", json_escape(k), json_num(*v)));
        }
        self.entries.push(format!("    {{{}}}", parts.join(", ")));
    }

    /// Render the document.
    pub fn render(&self) -> String {
        format!(
            "{{\n  \"bench\": \"{}\",\n  \"entries\": [\n{}\n  ]\n}}\n",
            json_escape(&self.name),
            self.entries.join(",\n")
        )
    }

    /// Write to `path` (creating parent directories as needed).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_bench_renders_valid_entries() {
        let mut j = JsonBench::new("pool vs spawn");
        j.entry("pooled x4", &[("seconds", 0.25), ("gflops", 8.0)]);
        j.entry("spawn \"legacy\"", &[("seconds", f64::NAN)]);
        let doc = j.render();
        assert!(doc.contains("\"bench\": \"pool vs spawn\""));
        assert!(doc.contains("\"seconds\": 0.250000"));
        assert!(doc.contains("\"gflops\": 8.000000"));
        assert!(doc.contains("spawn \\\"legacy\\\""));
        assert!(doc.contains("\"seconds\": null"));
        // Balanced braces/brackets (cheap well-formedness check).
        assert_eq!(doc.matches('{').count(), doc.matches('}').count());
        assert_eq!(doc.matches('[').count(), doc.matches(']').count());
    }

    #[test]
    fn group_collects_cases() {
        std::env::set_var("DLA_BENCH_REPS", "2");
        std::env::set_var("DLA_BENCH_SECS", "0.0");
        let mut g = BenchGroup::new("t");
        let mut x = 0u64;
        g.case("noop", 1e6, || x = x.wrapping_add(1));
        g.record("model", 0.5, 1e9);
        assert_eq!(g.results().len(), 2);
        assert!((g.results()[1].gflops() - 2.0).abs() < 1e-12);
        let rendered = g.table().render();
        assert!(rendered.contains("noop") && rendered.contains("model"));
        std::env::remove_var("DLA_BENCH_REPS");
        std::env::remove_var("DLA_BENCH_SECS");
    }
}
