//! Architecture presets.
//!
//! The Carmel and EPYC geometries are taken verbatim from the paper
//! (§3.1 Figure 5 and §4.1 Figure 8). Latency figures are documented
//! estimates used only by the performance model; the *shape* of every
//! reproduced curve is driven by the geometry, which is exact.

use super::{Arch, CacheLevel, RegisterFile};

/// Names accepted by [`preset_by_name`].
pub const PRESET_NAMES: &[&str] = &["carmel", "epyc7282", "host", "tpu-vmem"];

/// NVIDIA Carmel (ARMv8.2) on the Jetson AGX Xavier, as in paper §3.1:
/// per-core 64 KB 4-way L1d; 2 MB 16-way L2 shared by a core pair;
/// 4 MB 16-way L3 shared by all 8 cores; 128-bit NEON, 32 vector regs.
pub fn carmel() -> Arch {
    Arch {
        name: "NVIDIA Carmel (ARMv8.2, NEON)".into(),
        levels: vec![
            CacheLevel { size_bytes: 64 * 1024, line_bytes: 64, ways: 4, shared_by: 1, latency_cycles: 4.0 },
            CacheLevel { size_bytes: 2 * 1024 * 1024, line_bytes: 64, ways: 16, shared_by: 2, latency_cycles: 14.0 },
            CacheLevel { size_bytes: 4 * 1024 * 1024, line_bytes: 64, ways: 16, shared_by: 8, latency_cycles: 38.0 },
        ],
        regs: RegisterFile { vector_regs: 32, vector_bits: 128 },
        // MAXN mode pins cores at 2.265 GHz.
        freq_ghz: 2.265,
        // Two 128-bit FMA pipes per core.
        fma_per_cycle: 2.0,
        cores: 8,
        mem_latency_cycles: 180.0,
    }
}

/// AMD EPYC 7282 ("Rome"), as in paper §4.1: per-core 32 KB 8-way L1d and
/// 512 KB 8-way L2; 16 MB 16-way L3 per 4-core CCX (4 CCXs per socket);
/// AVX2 (256-bit), 16 vector regs; frequency pinned to 2.3 GHz (§4.1).
pub fn epyc7282() -> Arch {
    Arch {
        name: "AMD EPYC 7282 (x86-64, AVX2)".into(),
        levels: vec![
            CacheLevel { size_bytes: 32 * 1024, line_bytes: 64, ways: 8, shared_by: 1, latency_cycles: 4.0 },
            CacheLevel { size_bytes: 512 * 1024, line_bytes: 64, ways: 8, shared_by: 1, latency_cycles: 12.0 },
            CacheLevel { size_bytes: 16 * 1024 * 1024, line_bytes: 64, ways: 16, shared_by: 4, latency_cycles: 40.0 },
        ],
        regs: RegisterFile { vector_regs: 16, vector_bits: 256 },
        freq_ghz: 2.3,
        // Rome: two 256-bit FMA pipes per core.
        fma_per_cycle: 2.0,
        cores: 16,
        mem_latency_cycles: 220.0,
    }
}

/// The local sandbox host (Intel Xeon, AVX2+FMA, 1 visible core). Cache
/// sizes follow a typical Skylake-SP-like virtualized topology and are
/// overridden by [`super::detect_host`] when sysfs exposes real values.
pub fn host_xeon() -> Arch {
    Arch {
        name: "Host Intel Xeon (AVX2)".into(),
        levels: vec![
            CacheLevel { size_bytes: 32 * 1024, line_bytes: 64, ways: 8, shared_by: 1, latency_cycles: 4.0 },
            CacheLevel { size_bytes: 1024 * 1024, line_bytes: 64, ways: 16, shared_by: 1, latency_cycles: 14.0 },
            CacheLevel { size_bytes: 32 * 1024 * 1024, line_bytes: 64, ways: 11, shared_by: 1, latency_cycles: 44.0 },
        ],
        regs: RegisterFile { vector_regs: 16, vector_bits: 256 },
        freq_ghz: 2.1,
        fma_per_cycle: 2.0,
        cores: 1,
        mem_latency_cycles: 200.0,
    }
}

/// TPU-style "VMEM" pseudo-hierarchy used for the Pallas BlockSpec sizing
/// (DESIGN.md §Hardware-Adaptation): one ~16 MB software-managed level.
/// Associativity is irrelevant for a scratchpad; we model it as fully
/// associative with one set so the same CCP machinery can size tiles.
pub fn tpu_vmem() -> Arch {
    Arch {
        name: "TPU VMEM scratchpad model".into(),
        levels: vec![
            CacheLevel { size_bytes: 16 * 1024 * 1024, line_bytes: 512, ways: 32768, shared_by: 1, latency_cycles: 1.0 },
            // HBM stands in as the "next level".
            CacheLevel { size_bytes: 16 * 1024 * 1024 * 1024, line_bytes: 512, ways: 32768, shared_by: 1, latency_cycles: 100.0 },
        ],
        regs: RegisterFile { vector_regs: 64, vector_bits: 8 * 128 * 64 },
        freq_ghz: 0.94,
        fma_per_cycle: 128.0 * 128.0,
        cores: 1,
        mem_latency_cycles: 500.0,
    }
}

/// Look up a preset by CLI name.
pub fn preset_by_name(name: &str) -> Option<Arch> {
    match name {
        "carmel" => Some(carmel()),
        "epyc7282" | "epyc" => Some(epyc7282()),
        "host" => Some(super::detect_host()),
        "tpu-vmem" => Some(tpu_vmem()),
        _ => None,
    }
}
