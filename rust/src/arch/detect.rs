//! Host cache-geometry detection from Linux sysfs
//! (`/sys/devices/system/cpu/cpu0/cache/index*`), falling back to the
//! static [`super::host_xeon`] preset when sysfs is unavailable (e.g.
//! inside minimal containers).

use super::{Arch, CacheLevel};
use std::fs;
use std::path::Path;

fn read_trim(p: &Path) -> Option<String> {
    fs::read_to_string(p).ok().map(|s| s.trim().to_string())
}

/// Parse sysfs sizes like "32K", "1024K", "32M".
fn parse_size(s: &str) -> Option<usize> {
    let s = s.trim();
    if let Some(v) = s.strip_suffix('K') {
        v.parse::<usize>().ok().map(|k| k * 1024)
    } else if let Some(v) = s.strip_suffix('M') {
        v.parse::<usize>().ok().map(|m| m * 1024 * 1024)
    } else {
        s.parse::<usize>().ok()
    }
}

fn detect_levels() -> Option<Vec<CacheLevel>> {
    let base = Path::new("/sys/devices/system/cpu/cpu0/cache");
    if !base.exists() {
        return None;
    }
    let mut levels: Vec<(u32, CacheLevel)> = Vec::new();
    for idx in 0..8 {
        let dir = base.join(format!("index{idx}"));
        if !dir.exists() {
            break;
        }
        let ctype = read_trim(&dir.join("type"))?;
        if ctype == "Instruction" {
            continue;
        }
        let level: u32 = read_trim(&dir.join("level"))?.parse().ok()?;
        let size = parse_size(&read_trim(&dir.join("size"))?)?;
        let ways: usize = read_trim(&dir.join("ways_of_associativity"))?.parse().ok()?;
        let line: usize = read_trim(&dir.join("coherency_line_size"))?.parse().ok()?;
        let shared = read_trim(&dir.join("shared_cpu_list"))
            .map(|l| l.split(',').count())
            .unwrap_or(1);
        if ways == 0 || line == 0 {
            continue; // fully-assoc encodings we do not model
        }
        levels.push((
            level,
            CacheLevel {
                size_bytes: size,
                line_bytes: line,
                ways,
                shared_by: shared,
                // Rough per-level latency defaults; refined by perfmodel
                // calibration, not load-bearing for curve shapes.
                latency_cycles: match level {
                    1 => 4.0,
                    2 => 14.0,
                    _ => 44.0,
                },
            },
        ));
    }
    levels.sort_by_key(|(l, _)| *l);
    let out: Vec<CacheLevel> = levels.into_iter().map(|(_, c)| c).collect();
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

/// Detect the host architecture; any field sysfs cannot provide falls back
/// to the [`super::host_xeon`] preset.
pub fn detect_host() -> Arch {
    let mut arch = super::host_xeon();
    if let Some(levels) = detect_levels() {
        arch.levels = levels;
        arch.name = format!("{} (sysfs-detected caches)", arch.name);
    }
    arch
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sizes() {
        assert_eq!(parse_size("32K"), Some(32 * 1024));
        assert_eq!(parse_size("16M"), Some(16 * 1024 * 1024));
        assert_eq!(parse_size("123"), Some(123));
        assert_eq!(parse_size("x"), None);
    }

    #[test]
    fn detect_host_always_yields_usable_arch() {
        let a = detect_host();
        assert!(!a.levels.is_empty());
        assert!(a.l1().size_bytes >= 16 * 1024);
        assert!(a.l1().sets() > 0);
        assert!(a.peak_gflops_core() > 0.0);
    }
}
