//! Architecture descriptions: cache geometry, SIMD/register files, and
//! presets for the paper's evaluation platforms.
//!
//! The paper's whole argument is driven by cache geometry arithmetic
//! (§3.2–§3.3), so this module is the ground truth every model, simulator
//! and selector consumes.

mod detect;
mod presets;

pub use detect::detect_host;
pub use presets::{carmel, epyc7282, host_xeon, preset_by_name, tpu_vmem, PRESET_NAMES};

/// One level of a cache hierarchy.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheLevel {
    /// Total capacity in bytes (per cache instance, not per core).
    pub size_bytes: usize,
    /// Cache line size in bytes.
    pub line_bytes: usize,
    /// Associativity (ways per set).
    pub ways: usize,
    /// Number of cores sharing one instance of this cache.
    pub shared_by: usize,
    /// Approximate access latency in core cycles (used by the performance
    /// model; values are documented estimates, not vendor specs).
    pub latency_cycles: f64,
}

impl CacheLevel {
    /// Number of sets.
    pub fn sets(&self) -> usize {
        self.size_bytes / (self.ways * self.line_bytes)
    }

    /// Capacity of a single way in bytes.
    pub fn way_bytes(&self) -> usize {
        self.size_bytes / self.ways
    }

    /// Capacity in KiB (for table rendering).
    pub fn size_kib(&self) -> f64 {
        self.size_bytes as f64 / 1024.0
    }
}

/// SIMD register file description.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RegisterFile {
    /// Number of architectural vector registers.
    pub vector_regs: usize,
    /// Vector register width in bits.
    pub vector_bits: usize,
}

impl RegisterFile {
    /// FP64 lanes per vector register.
    pub fn f64_lanes(&self) -> usize {
        self.vector_bits / 64
    }

    /// Lanes per vector register for an element of `elem_bytes` bytes
    /// (e.g. 4 for f32: twice the FP64 lane count on every SIMD ISA).
    pub fn lanes_for(&self, elem_bytes: usize) -> usize {
        self.vector_bits / (8 * elem_bytes)
    }
}

/// A target architecture: cache hierarchy (L1 first) + compute resources.
#[derive(Clone, Debug, PartialEq)]
pub struct Arch {
    pub name: String,
    /// Cache levels ordered L1 data cache first.
    pub levels: Vec<CacheLevel>,
    pub regs: RegisterFile,
    /// Core clock in GHz (paper: MAXN for Carmel, 2.3 GHz pinned for EPYC).
    pub freq_ghz: f64,
    /// FP64 FMA operations issued per cycle per core (each FMA counts as
    /// one instruction over `regs.f64_lanes()` lanes; 2 flops per lane).
    pub fma_per_cycle: f64,
    /// Physical cores in the socket.
    pub cores: usize,
    /// Approximate DRAM access latency in cycles.
    pub mem_latency_cycles: f64,
}

impl Arch {
    pub fn l1(&self) -> &CacheLevel {
        &self.levels[0]
    }

    pub fn l2(&self) -> &CacheLevel {
        &self.levels[1]
    }

    pub fn l3(&self) -> Option<&CacheLevel> {
        self.levels.get(2)
    }

    /// Peak FP64 GFLOPS of one core:
    /// `freq * fma_per_cycle * lanes * 2` (multiply + add).
    pub fn peak_gflops_core(&self) -> f64 {
        self.freq_ghz * self.fma_per_cycle * self.regs.f64_lanes() as f64 * 2.0
    }

    /// Peak GFLOPS of one core at a given element width in bytes (f32
    /// doubles the lane count and therefore the peak).
    pub fn peak_gflops_core_for(&self, elem_bytes: usize) -> f64 {
        self.freq_ghz * self.fma_per_cycle * self.regs.lanes_for(elem_bytes) as f64 * 2.0
    }

    /// Peak FP64 GFLOPS of the full socket.
    pub fn peak_gflops_socket(&self) -> f64 {
        self.peak_gflops_core() * self.cores as f64
    }

    /// FP64 elements per cache line (all models count in elements).
    pub fn line_elems(&self) -> usize {
        self.levels[0].line_bytes / 8
    }

    /// Elements per cache line at a given element width in bytes.
    pub fn line_elems_for(&self, elem_bytes: usize) -> usize {
        self.levels[0].line_bytes / elem_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn carmel_geometry_matches_paper() {
        // §3.1: 64 KB 4-assoc L1; 2 MB 16-assoc L2 shared by 2; 4 MB
        // 16-way L3 shared by 8.
        let a = carmel();
        assert_eq!(a.l1().size_bytes, 64 * 1024);
        assert_eq!(a.l1().ways, 4);
        assert_eq!(a.l1().sets(), 256);
        assert_eq!(a.l1().way_bytes(), 16 * 1024);
        assert_eq!(a.l2().size_bytes, 2 * 1024 * 1024);
        assert_eq!(a.l2().ways, 16);
        assert_eq!(a.l2().sets(), 2048);
        assert_eq!(a.l2().shared_by, 2);
        let l3 = a.l3().unwrap();
        assert_eq!(l3.size_bytes, 4 * 1024 * 1024);
        assert_eq!(l3.ways, 16);
        assert_eq!(l3.sets(), 4096);
        assert_eq!(a.regs.vector_regs, 32);
        assert_eq!(a.regs.f64_lanes(), 2);
        assert_eq!(a.cores, 8);
    }

    #[test]
    fn epyc_geometry_matches_paper() {
        // §4.1: 32 KB L1d / 512 KB L2 per core, 16 MB L3 per 4-core CCX.
        let a = epyc7282();
        assert_eq!(a.l1().size_bytes, 32 * 1024);
        assert_eq!(a.l1().ways, 8);
        assert_eq!(a.l1().sets(), 64);
        assert_eq!(a.l2().size_bytes, 512 * 1024);
        assert_eq!(a.l2().sets(), 1024);
        assert_eq!(a.l2().shared_by, 1);
        let l3 = a.l3().unwrap();
        assert_eq!(l3.size_bytes, 16 * 1024 * 1024);
        assert_eq!(l3.shared_by, 4);
        assert_eq!(a.regs.vector_regs, 16);
        assert_eq!(a.regs.f64_lanes(), 4);
        assert_eq!(a.cores, 16);
        assert!((a.freq_ghz - 2.3).abs() < 1e-9);
    }

    #[test]
    fn peak_gflops() {
        let e = epyc7282();
        // 2.3 GHz * 2 FMA/cyc * 4 lanes * 2 flops = 36.8 GFLOPS/core.
        assert!((e.peak_gflops_core() - 36.8).abs() < 1e-9);
        assert!((e.peak_gflops_socket() - 16.0 * 36.8).abs() < 1e-6);
    }

    #[test]
    fn element_width_scaling() {
        let e = epyc7282();
        // f32 doubles lanes, elements-per-line and peak GFLOPS.
        assert_eq!(e.regs.lanes_for(8), e.regs.f64_lanes());
        assert_eq!(e.regs.lanes_for(4), 2 * e.regs.f64_lanes());
        assert_eq!(e.line_elems_for(8), e.line_elems());
        assert_eq!(e.line_elems_for(4), 2 * e.line_elems());
        assert!((e.peak_gflops_core_for(8) - e.peak_gflops_core()).abs() < 1e-12);
        assert!((e.peak_gflops_core_for(4) - 2.0 * e.peak_gflops_core()).abs() < 1e-9);
    }

    #[test]
    fn preset_lookup() {
        for name in PRESET_NAMES {
            assert!(preset_by_name(name).is_some(), "missing preset {name}");
        }
        assert!(preset_by_name("carmel").unwrap().name.contains("Carmel"));
        assert!(preset_by_name("nope").is_none());
    }
}
