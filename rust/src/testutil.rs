//! Minimal property-testing helper (proptest is unavailable offline).
//!
//! [`forall`] runs a property over generated cases with a deterministic
//! (env-overridable) seed and, on failure, greedily shrinks via the
//! user-provided `shrink` candidates before panicking with the smallest
//! reproducer it found.

use crate::util::Pcg64;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        let seed = std::env::var("DLA_PROPTEST_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0xD1A_5EED);
        let cases = std::env::var("DLA_PROPTEST_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        Self { cases, seed }
    }
}

/// Run `prop` over `cfg.cases` generated inputs. `prop` returns
/// `Err(message)` to signal a violation.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cfg: PropConfig,
    mut generate: impl FnMut(&mut Pcg64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg64::seed(cfg.seed);
    for case_idx in 0..cfg.cases {
        let input = generate(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property {name} failed at case {case_idx} (seed {}):\n  input: {input:?}\n  {msg}\n  \
                 rerun with DLA_PROPTEST_SEED={} to reproduce",
                cfg.seed, cfg.seed
            );
        }
    }
}

/// [`forall`] with shrinking: on failure, repeatedly tries the candidates
/// from `shrink(input)` (smaller inputs first) while they still fail.
pub fn forall_shrink<T: std::fmt::Debug + Clone>(
    name: &str,
    cfg: PropConfig,
    mut generate: impl FnMut(&mut Pcg64) -> T,
    shrink: impl Fn(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Pcg64::seed(cfg.seed);
    for case_idx in 0..cfg.cases {
        let input = generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            // Greedy shrink loop.
            let mut smallest = input.clone();
            let mut msg = first_msg;
            'outer: loop {
                for cand in shrink(&smallest) {
                    if let Err(m) = prop(&cand) {
                        smallest = cand;
                        msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property {name} failed at case {case_idx} (seed {}):\n  shrunk input: {smallest:?}\n  {msg}\n  \
                 rerun with DLA_PROPTEST_SEED={} to reproduce",
                cfg.seed, cfg.seed
            );
        }
    }
}

/// Convenience generators.
pub mod gen {
    use crate::util::{MatrixF64, Pcg64};

    /// Random dimension in [1, max].
    pub fn dim(rng: &mut Pcg64, max: usize) -> usize {
        rng.range(1, max + 1)
    }

    /// Random matrix with dims in [1, max_dim].
    pub fn matrix(rng: &mut Pcg64, max_dim: usize) -> MatrixF64 {
        let r = dim(rng, max_dim);
        let c = dim(rng, max_dim);
        MatrixF64::random(r, c, rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        forall(
            "count",
            PropConfig { cases: 10, seed: 1 },
            |rng| rng.range(0, 100),
            |_| {
                n += 1;
                Ok(())
            },
        );
        assert_eq!(n, 10);
    }

    #[test]
    #[should_panic(expected = "property bad failed")]
    fn failing_property_panics_with_seed() {
        forall(
            "bad",
            PropConfig { cases: 10, seed: 2 },
            |rng| rng.range(0, 100),
            |&x| if x < 1000 { Err(format!("x = {x}")) } else { Ok(()) },
        );
    }

    #[test]
    fn shrinking_finds_smaller_case() {
        let result = std::panic::catch_unwind(|| {
            forall_shrink(
                "shrinkme",
                PropConfig { cases: 5, seed: 3 },
                |rng| rng.range(50, 100),
                |&x| if x > 10 { vec![x / 2, x - 1] } else { vec![] },
                |&x| if x >= 10 { Err("too big".into()) } else { Ok(()) },
            );
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink must reach the boundary value 10.
        assert!(msg.contains("shrunk input: 10"), "got: {msg}");
    }
}
