//! Memory-trace generation for the blocked GEMM algorithm.
//!
//! [`simulate_gemm`] replays, access by access, the exact data movements
//! of the five-loop algorithm in [`crate::gemm::blocked`] — packing reads
//! and writes, micro-kernel streams of `Ar`/`Br`, and `Cr` tile traffic —
//! through a [`Hierarchy`]. This turns the paper's qualitative Figure 4
//! ("which buffer lives in which level") into measured per-level hit
//! ratios, substituting for the PMU counters of paper Figure 11 (bottom).
//!
//! For large problems a sampling mode simulates only the first
//! `max_g3_blocks` iterations of loop G3 per (jc, pc) pair — the access
//! pattern of subsequent `ic` blocks is statistically identical (same
//! buffers, same strides), so hit ratios converge after a few blocks.

use crate::arch::Arch;
use crate::cachesim::{CacheStats, Hierarchy};
use crate::model::ccp::GemmConfig;
use crate::model::GemmDims;

/// Disjoint base addresses for each region (1 GiB apart).
const A_BASE: u64 = 0x1_0000_0000;
const B_BASE: u64 = 0x2_0000_0000;
const C_BASE: u64 = 0x3_0000_0000;
const AC_BASE: u64 = 0x4_0000_0000;
const BC_BASE: u64 = 0x5_0000_0000;

/// Trace-generation options.
#[derive(Clone, Copy, Debug)]
pub struct TraceOptions {
    /// Simulate at most this many G3 (`ic`) blocks per (jc, pc) pair and
    /// scale the counters up; `usize::MAX` = exact full trace.
    pub max_g3_blocks: usize,
    /// Simulate at most this many G1 (`jc`) blocks; `usize::MAX` = all.
    pub max_g1_blocks: usize,
    /// Skip packing traffic (isolates micro-kernel behaviour).
    pub skip_packing: bool,
}

impl Default for TraceOptions {
    fn default() -> Self {
        Self { max_g3_blocks: usize::MAX, max_g1_blocks: usize::MAX, skip_packing: false }
    }
}

impl TraceOptions {
    /// Fast, statistically-converged sampling (used by the LU model).
    pub fn sampled() -> Self {
        Self { max_g3_blocks: 3, max_g1_blocks: 2, skip_packing: false }
    }
}

/// Simulation result: per-level counters plus scaling bookkeeping.
#[derive(Clone, Copy, Debug)]
pub struct GemmSimStats {
    pub l1: CacheStats,
    pub l2: CacheStats,
    pub l3: Option<CacheStats>,
    /// Lines fetched from DRAM.
    pub dram_lines: u64,
    /// Fraction of the full G3 iteration space actually simulated
    /// (1.0 = exact). Counters are *not* pre-scaled; multiply by
    /// `1.0 / coverage` for full-problem estimates.
    pub coverage: f64,
    pub flops: f64,
}

impl GemmSimStats {
    pub fn l2_hit_ratio(&self) -> f64 {
        self.l2.hit_ratio()
    }

    /// DRAM lines scaled to the full problem.
    pub fn dram_lines_scaled(&self) -> f64 {
        self.dram_lines as f64 / self.coverage
    }

    /// Per-level accesses scaled to the full problem:
    /// `(l1, l2, l3, dram)`.
    pub fn scaled_accesses(&self) -> (f64, f64, f64, f64) {
        let s = 1.0 / self.coverage;
        (
            self.l1.accesses as f64 * s,
            self.l2.accesses as f64 * s,
            self.l3.map(|l| l.accesses as f64).unwrap_or(0.0) * s,
            self.dram_lines as f64 * s,
        )
    }
}

/// Replay the blocked GEMM access stream on `arch`'s hierarchy.
///
/// `percore_slice` scales shared levels down to one core's share
/// (multicore modelling); the sequential figures use `false`.
pub fn simulate_gemm(
    arch: &Arch,
    dims: GemmDims,
    cfg: &GemmConfig,
    opts: TraceOptions,
    percore_slice: bool,
) -> GemmSimStats {
    let mut h = if percore_slice {
        Hierarchy::new_percore_slice(arch)
    } else {
        Hierarchy::new(arch)
    };
    let (m, n, k) = (dims.m, dims.n, dims.k);
    let ccp = cfg.ccp.clamp_to(dims);
    let (mc, nc, kc) = (ccp.mc, ccp.nc, ccp.kc);
    let (mr, nr) = (cfg.mk.mr, cfg.mk.nr);
    let lda = m as u64; // column strides in elements
    let ldb = k as u64;
    let ldc = m as u64;

    let mut g3_total = 0u64;
    let mut g3_simulated = 0u64;
    let g3_per_pair = m.div_ceil(mc) as u64;

    let mut jc = 0;
    let mut g1_seen = 0usize;
    while jc < n {
        if g1_seen >= opts.max_g1_blocks {
            // Account the skipped (jc, pc, ic) triples in the coverage.
            g3_total += k.div_ceil(kc) as u64 * g3_per_pair;
            jc += nc;
            continue;
        }
        g1_seen += 1;
        let nc_eff = nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = kc.min(k - pc);
            // ---- pack Bc: read B(pc..pc+kc, jc..jc+nc), write Bc ------
            if !opts.skip_packing {
                for j in 0..nc_eff {
                    let col = B_BASE + 8 * ((jc + j) as u64 * ldb + pc as u64);
                    h.touch(col, 8 * kc_eff as u64);
                }
                // Buffer writes: contiguous stream over the packed panel.
                h.touch(BC_BASE, 8 * (kc_eff * nc_eff) as u64);
            }
            let mut ic = 0;
            let mut g3_seen = 0usize;
            while ic < m {
                let mc_eff = mc.min(m - ic);
                g3_total += 1;
                if g3_seen >= opts.max_g3_blocks {
                    ic += mc;
                    continue;
                }
                g3_seen += 1;
                g3_simulated += 1;
                // ---- pack Ac: read A(ic.., pc..), write Ac -------------
                if !opts.skip_packing {
                    for p in 0..kc_eff {
                        let col = A_BASE + 8 * ((pc + p) as u64 * lda + ic as u64);
                        h.touch(col, 8 * mc_eff as u64);
                    }
                    h.touch(AC_BASE, 8 * (kc_eff * mc_eff) as u64);
                }
                // ---- macro-kernel: loops G4/G5 -------------------------
                let mut jr = 0;
                while jr < nc_eff {
                    let nr_eff = nr.min(nc_eff - jr);
                    let b_panel = BC_BASE + 8 * ((jr / nr) * nr * kc_eff) as u64;
                    let mut ir = 0;
                    while ir < mc_eff {
                        let mr_eff = mr.min(mc_eff - ir);
                        let a_panel = AC_BASE + 8 * ((ir / mr) * mr * kc_eff) as u64;
                        // C tile read (once, before the rank-1 loop).
                        for j in 0..nr_eff {
                            let col = C_BASE + 8 * ((jc + jr + j) as u64 * ldc + (ic + ir) as u64);
                            h.touch(col, 8 * mr_eff as u64);
                        }
                        // kc rank-1 updates: column of Ar + row of Br.
                        for p in 0..kc_eff {
                            h.touch(a_panel + 8 * (p * mr) as u64, 8 * mr as u64);
                            h.touch(b_panel + 8 * (p * nr) as u64, 8 * nr as u64);
                        }
                        // C tile write-back.
                        for j in 0..nr_eff {
                            let col = C_BASE + 8 * ((jc + jr + j) as u64 * ldc + (ic + ir) as u64);
                            h.touch(col, 8 * mr_eff as u64);
                        }
                        ir += mr;
                    }
                    jr += nr;
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }

    let coverage = if g3_total == 0 { 1.0 } else { g3_simulated as f64 / g3_total as f64 };
    GemmSimStats {
        l1: h.level_stats(0),
        l2: h.level_stats(1),
        l3: if h.num_levels() > 2 { Some(h.level_stats(2)) } else { None },
        dram_lines: h.dram_lines(),
        coverage,
        flops: dims.flops() * coverage,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::carmel;
    use crate::model::ccp::GemmConfig;
    use crate::model::{blis_static, refined_ccp, Ccp, MicroKernel};

    fn dims(k: usize) -> GemmDims {
        GemmDims::new(500, 500, k)
    }

    #[test]
    fn exact_trace_access_count_matches_formula() {
        // Small exact case: count micro-kernel + packing accesses.
        let d = GemmDims::new(48, 48, 32);
        let mk = MicroKernel::new(8, 6);
        let cfg = GemmConfig { mk, ccp: Ccp::new(24, 24, 16) };
        let s = simulate_gemm(&carmel(), d, &cfg, TraceOptions::default(), false);
        assert_eq!(s.coverage, 1.0);
        // L1 accesses (line-granular) are deterministic; sanity-bound
        // them: at least one access per 64B of compulsory traffic, and
        // far fewer than per-element counts.
        assert!(s.l1.accesses > 1000);
        let elem_ops = 2.0 * 48.0 * 48.0 * 32.0;
        assert!((s.l1.accesses as f64) < elem_ops);
    }

    #[test]
    fn mod_ccps_beat_blis_on_l2_hits_for_skinny_k() {
        // The paper's central claim, measured in simulation: for skinny k
        // at the paper's problem size (m = n = 2000), the refined CCPs
        // keep far more of the streamed traffic inside the L2. With the
        // BLIS statics (mc = 120) the whole Bc is swept once per ic block
        // — 17 re-reads that the C stream keeps evicting — while MOD's
        // mc = 2000 makes it 1 pass.
        let arch = carmel();
        let d = GemmDims::new(2000, 2000, 96);
        let blis = blis_static("carmel").unwrap();
        let blis_cfg = GemmConfig { mk: blis.mk, ccp: blis.ccp.clamp_to(d) };
        let mod_cfg = GemmConfig {
            mk: blis.mk,
            ccp: refined_ccp(&arch, blis.mk, d).clamp_to(d),
        };
        let sb = simulate_gemm(&arch, d, &blis_cfg, TraceOptions::sampled(), false);
        let sm = simulate_gemm(&arch, d, &mod_cfg, TraceOptions::sampled(), false);
        // MOD serves more accesses from L2 and sends less traffic to L3.
        let l3_blis = sb.scaled_accesses().2;
        let l3_mod = sm.scaled_accesses().2;
        assert!(
            l3_mod < l3_blis,
            "MOD {l3_mod} vs BLIS {l3_blis} L3-level accesses (L2 misses)"
        );
        assert!(
            sm.l2_hit_ratio() > sb.l2_hit_ratio(),
            "MOD L2 hit ratio {} must exceed BLIS {}",
            sm.l2_hit_ratio(),
            sb.l2_hit_ratio()
        );
    }

    #[test]
    fn sampled_trace_close_to_exact() {
        let arch = carmel();
        let d = dims(64);
        let mk = MicroKernel::new(6, 8);
        let cfg = GemmConfig { mk, ccp: Ccp::new(120, 512, 64) };
        let exact = simulate_gemm(&arch, d, &cfg, TraceOptions::default(), false);
        let sampled = simulate_gemm(&arch, d, &cfg, TraceOptions::sampled(), false);
        assert!(sampled.coverage < 1.0);
        let r_exact = exact.l2_hit_ratio();
        let r_samp = sampled.l2_hit_ratio();
        assert!(
            (r_exact - r_samp).abs() < 0.08,
            "sampled L2 ratio {r_samp} far from exact {r_exact}"
        );
    }

    #[test]
    fn skip_packing_reduces_traffic() {
        let d = dims(64);
        let cfg = GemmConfig { mk: MicroKernel::new(6, 8), ccp: Ccp::new(120, 256, 64) };
        let with = simulate_gemm(&carmel(), d, &cfg, TraceOptions::default(), false);
        let without = simulate_gemm(
            &carmel(),
            d,
            &cfg,
            TraceOptions { skip_packing: true, ..Default::default() },
            false,
        );
        assert!(without.l1.accesses < with.l1.accesses);
    }

    #[test]
    fn flops_scaled_by_coverage() {
        let d = dims(64);
        let cfg = GemmConfig { mk: MicroKernel::new(6, 8), ccp: Ccp::new(64, 128, 64) };
        let s = simulate_gemm(&carmel(), d, &cfg, TraceOptions::sampled(), false);
        assert!((s.flops - d.flops() * s.coverage).abs() < 1.0);
    }
}
