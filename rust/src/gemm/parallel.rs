//! Multi-threaded GEMM: loop G3 / loop G4 parallelization (paper §2.2).
//!
//! - **G4** ("when the L2 is shared"): all threads share one packed `Ac`
//!   and `Bc`; the `jr` loop over `nc` is partitioned at `nr` granularity.
//!   Distribution grain is small (`nr`), so 16 threads are easily fed —
//!   the behaviour paper §4.3.2 observes on the bottom plot of Figure 12.
//! - **G3** ("when L1 and L2 are private"): the `ic` loop over `m` is
//!   partitioned at `mc` granularity; each thread packs its own `Ac` into
//!   a private workspace. With the refined model's *large* `mc` there are
//!   few iterations to hand out (`m/mc` chunks), reproducing the paper's
//!   G3 load-imbalance analysis (`10,000/384/16 = 1.62 iterations per
//!   thread`).
//!
//! The host sandbox exposes a single core, so these paths are validated
//! for correctness here while parallel *performance* figures come from
//! [`crate::perfmodel`] (see DESIGN.md substitutions).

use crate::model::ccp::GemmConfig;
use crate::util::matrix::{MatView, MatViewMut};

use super::blocked::{macro_kernel, Workspace};
use super::microkernel::MicroKernelImpl;
use super::packing::{pack_a, pack_b};

/// Which loop the threads split (paper §2.2 discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelLoop {
    /// Partition `ic` over `m` (grain `mc`, private `Ac` per thread).
    G3,
    /// Partition `jr` over `nc` (grain `nr`, shared `Ac`/`Bc`).
    G4,
}

/// A threading plan for one GEMM call.
#[derive(Clone, Copy, Debug)]
pub struct ThreadPlan {
    pub threads: usize,
    pub target: ParallelLoop,
}

impl ThreadPlan {
    pub fn sequential() -> Self {
        Self { threads: 1, target: ParallelLoop::G4 }
    }
}

/// Send-able raw pointer to C (threads write disjoint tiles).
#[derive(Clone, Copy)]
struct SendPtr(*mut f64);
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// Accessor (not a field read) so closures capture the whole wrapper
    /// instead of the raw pointer under edition-2021 disjoint capture.
    fn ptr(&self) -> *mut f64 {
        self.0
    }
}

/// Split `total` items into `parts` contiguous chunks at `grain`
/// alignment; returns (start, end) per part. Chunks may be empty.
pub fn partition(total: usize, parts: usize, grain: usize) -> Vec<(usize, usize)> {
    assert!(parts > 0 && grain > 0);
    let blocks = total.div_ceil(grain);
    let per = blocks.div_ceil(parts);
    (0..parts)
        .map(|t| {
            let lo = (t * per * grain).min(total);
            let hi = ((t + 1) * per * grain).min(total);
            (lo, hi)
        })
        .collect()
}

/// Multi-threaded blocked GEMM: `C = alpha*A*B + beta*C`.
///
/// `workspaces` must provide one [`Workspace`] per thread for G3 (private
/// `Ac`); for G4 only `workspaces[0]` is used.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl,
    alpha: f64,
    a: MatView<'_>,
    b: MatView<'_>,
    beta: f64,
    c: &mut MatViewMut<'_>,
    plan: ThreadPlan,
    workspaces: &mut [Workspace],
) {
    assert!(workspaces.len() >= plan.threads.max(1), "one workspace per thread required");
    if plan.threads <= 1 {
        super::blocked::gemm_blocked(cfg, kernel, alpha, a, b, beta, c, &mut workspaces[0]);
        return;
    }
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, n, k) = (a.rows, b.cols, a.cols);
    // beta scaling once, up front (single-threaded; O(mn)).
    if beta != 1.0 {
        for j in 0..c.cols {
            let col = &mut c.data[j * c.ld..j * c.ld + c.rows];
            if beta == 0.0 {
                col.fill(0.0);
            } else {
                for v in col {
                    *v *= beta;
                }
            }
        }
    }
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    let ccp = cfg.ccp.clamp_to(crate::model::GemmDims::new(m, n, k));
    let eff = GemmConfig { mk: cfg.mk, ccp };
    for ws in workspaces.iter_mut() {
        ws.ensure(&eff);
    }
    match plan.target {
        ParallelLoop::G4 => gemm_parallel_g4(&eff, kernel, alpha, a, b, c, plan.threads, &mut workspaces[0]),
        ParallelLoop::G3 => gemm_parallel_g3(&eff, kernel, alpha, a, b, c, plan.threads, workspaces),
    }
}

fn gemm_parallel_g4(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl,
    alpha: f64,
    a: MatView<'_>,
    b: MatView<'_>,
    c: &mut MatViewMut<'_>,
    threads: usize,
    ws: &mut Workspace,
) {
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let (mc, nc, kc) = (cfg.ccp.mc, cfg.ccp.nc, cfg.ccp.kc);
    let ldc = c.ld;
    let mut jc = 0;
    while jc < n {
        let nc_eff = nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = kc.min(k - pc);
            pack_b(b.sub(pc, jc, kc_eff, nc_eff), &mut ws.b_buf, cfg.mk.nr);
            let mut ic = 0;
            while ic < m {
                let mc_eff = mc.min(m - ic);
                pack_a(a.sub(ic, pc, mc_eff, kc_eff), &mut ws.a_buf, cfg.mk.mr, alpha);
                let base = SendPtr(unsafe { c.data.as_mut_ptr().add(jc * ldc + ic) });
                let parts = partition(nc_eff, threads, cfg.mk.nr);
                let a_buf = &ws.a_buf;
                let b_buf = &ws.b_buf;
                std::thread::scope(|s| {
                    for &(lo, hi) in parts.iter().skip(1) {
                        if lo >= hi {
                            continue;
                        }
                        let base = base;
                        s.spawn(move || unsafe {
                            macro_kernel(kernel, kc_eff, mc_eff, nc_eff, a_buf, b_buf, base.ptr(), ldc, (lo, hi));
                        });
                    }
                    // Leader takes the first chunk.
                    let (lo, hi) = parts[0];
                    if lo < hi {
                        unsafe {
                            macro_kernel(kernel, kc_eff, mc_eff, nc_eff, a_buf, b_buf, base.ptr(), ldc, (lo, hi));
                        }
                    }
                });
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

fn gemm_parallel_g3(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl,
    alpha: f64,
    a: MatView<'_>,
    b: MatView<'_>,
    c: &mut MatViewMut<'_>,
    threads: usize,
    workspaces: &mut [Workspace],
) {
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let (mc, nc, kc) = (cfg.ccp.mc, cfg.ccp.nc, cfg.ccp.kc);
    let ldc = c.ld;
    // The shared Bc lives in workspace 0; split A workspaces off first so
    // each worker gets a disjoint &mut Workspace.
    let (ws0, rest) = workspaces.split_first_mut().unwrap();
    let mut jc = 0;
    while jc < n {
        let nc_eff = nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = kc.min(k - pc);
            pack_b(b.sub(pc, jc, kc_eff, nc_eff), &mut ws0.b_buf, cfg.mk.nr);
            let b_buf = &ws0.b_buf;
            // Partition the ic range at mc granularity (the paper's point:
            // only ceil(m/mc) chunks exist to distribute).
            let parts = partition(m, threads, mc);
            let base = SendPtr(unsafe { c.data.as_mut_ptr().add(jc * ldc) });
            std::thread::scope(|s| {
                let mut rest_iter = rest.iter_mut();
                for (t, &(lo, hi)) in parts.iter().enumerate().skip(1) {
                    let ws_t = rest_iter.next().expect("workspace per thread");
                    if lo >= hi {
                        continue;
                    }
                    let base = base;
                    s.spawn(move || {
                        let mut ic = lo;
                        while ic < hi {
                            let mc_eff = mc.min(hi - ic);
                            pack_a(a.sub(ic, pc, mc_eff, kc_eff), &mut ws_t.a_buf, cfg.mk.mr, alpha);
                            unsafe {
                                macro_kernel(
                                    kernel, kc_eff, mc_eff, nc_eff, &ws_t.a_buf, b_buf,
                                    base.ptr().add(ic), ldc, (0, nc_eff),
                                );
                            }
                            ic += mc;
                        }
                        let _ = t;
                    });
                }
                // Leader handles chunk 0 with ws0's a_buf.
                let (lo, hi) = parts[0];
                let mut ic = lo;
                while ic < hi {
                    let mc_eff = mc.min(hi - ic);
                    pack_a(a.sub(ic, pc, mc_eff, kc_eff), &mut ws0.a_buf, cfg.mk.mr, alpha);
                    unsafe {
                        macro_kernel(
                            kernel, kc_eff, mc_eff, nc_eff, &ws0.a_buf, b_buf,
                            base.ptr().add(ic), ldc, (0, nc_eff),
                        );
                    }
                    ic += mc;
                }
            });
            pc += kc;
        }
        jc += nc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_reference;
    use crate::gemm::microkernel::for_shape;
    use crate::model::{Ccp, MicroKernel};
    use crate::util::{MatrixF64, Pcg64};

    fn run_parallel(target: ParallelLoop, threads: usize, m: usize, n: usize, k: usize, ccp: Ccp) {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp };
        let mut rng = Pcg64::seed((m + n + k + threads) as u64);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut expect = c.clone();
        gemm_reference(1.0, a.view(), b.view(), 1.0, &mut expect.view_mut());
        let mut wss: Vec<Workspace> = (0..threads).map(|_| Workspace::new()).collect();
        gemm_parallel(
            &cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c.view_mut(),
            ThreadPlan { threads, target }, &mut wss,
        );
        assert!(
            c.max_abs_diff(&expect) < 1e-12 * (k as f64),
            "{target:?} x{threads} {m}x{n}x{k} diverges"
        );
    }

    #[test]
    fn g4_matches_reference() {
        run_parallel(ParallelLoop::G4, 2, 64, 96, 40, Ccp::new(32, 24, 16));
        run_parallel(ParallelLoop::G4, 4, 61, 53, 47, Ccp::new(37, 29, 13));
        run_parallel(ParallelLoop::G4, 3, 100, 30, 20, Ccp::new(48, 12, 8));
    }

    #[test]
    fn g3_matches_reference() {
        run_parallel(ParallelLoop::G3, 2, 64, 96, 40, Ccp::new(32, 24, 16));
        run_parallel(ParallelLoop::G3, 4, 61, 53, 47, Ccp::new(16, 29, 13));
        run_parallel(ParallelLoop::G3, 3, 100, 30, 20, Ccp::new(24, 12, 8));
    }

    #[test]
    fn more_threads_than_work() {
        // 8 threads but only 2 mc chunks / tiny nc: empty chunks allowed.
        run_parallel(ParallelLoop::G3, 8, 20, 12, 10, Ccp::new(16, 12, 8));
        run_parallel(ParallelLoop::G4, 8, 20, 12, 10, Ccp::new(16, 12, 8));
    }

    #[test]
    fn single_thread_delegates_to_blocked() {
        run_parallel(ParallelLoop::G3, 1, 33, 21, 17, Ccp::new(16, 12, 8));
    }

    #[test]
    fn partition_covers_and_aligns() {
        for (total, parts, grain) in [(100, 4, 8), (7, 3, 8), (0, 2, 4), (64, 16, 6)] {
            let p = partition(total, parts, grain);
            assert_eq!(p.len(), parts);
            // Coverage without gaps/overlap.
            let mut pos = 0;
            for &(lo, hi) in &p {
                assert_eq!(lo, pos.min(total));
                assert!(hi >= lo);
                pos = hi;
            }
            assert_eq!(p.last().unwrap().1, total);
            // Alignment of interior boundaries.
            for &(lo, _) in &p {
                assert!(lo == total || lo % grain == 0);
            }
        }
    }
}
