//! Multi-threaded GEMM on the persistent worker pool: loop G3 / loop G4
//! parallelization (paper §2.2) without per-block thread spawns.
//!
//! # Architecture
//!
//! The seed implementation called `std::thread::scope` inside the
//! innermost `ic` loop, spawning fresh OS threads for every macro-block —
//! thousands of spawns for one large LU. This version broadcasts **one
//! job per GEMM call** to a [`WorkerPool`] of parked workers
//! (`runtime::pool`): every rank executes the same G1/G2(/G3) loop nest
//! and synchronizes with the pool barrier, so after pool construction the
//! steady state performs **zero thread spawns** (asserted by the
//! `pool_runtime` regression tests).
//!
//! - **G4** ("when the L2 is shared"): all ranks share one packed `Ac`
//!   and `Bc` (pinned in the pool's rank-0 workspace); the `jr` loop over
//!   `nc` is partitioned at `nr` granularity. Distribution grain is small
//!   (`nr`), so 16 threads are easily fed — the behaviour paper §4.3.2
//!   observes on the bottom plot of Figure 12.
//! - **G3** ("when L1 and L2 are private"): the `ic` loop over `m` is
//!   partitioned at `mc` granularity; each rank packs its own `Ac` into
//!   its pinned pool workspace. With the refined model's *large* `mc`
//!   there are few iterations to hand out (`m/mc` chunks), reproducing
//!   the paper's G3 load-imbalance analysis (`10,000/384/16 = 1.62
//!   iterations per thread`).
//!
//! # Cooperative packing & barrier protocol
//!
//! Packing is **cooperative**: instead of the leader packing serially
//! while workers idle, every rank packs a disjoint micro-panel range of
//! the shared buffer (`Bc` split over `nc` at `nr` granularity; for G4
//! also `Ac` split over `mc` at `mr` granularity). Because micro-panels
//! are the packed layout's unit, rank boundaries fall exactly on buffer
//! offsets `(lo/grain) * grain * kc` and the cooperative result is
//! byte-identical to a serial pack. The barrier discipline, which every
//! rank must follow even when its own partition is empty:
//!
//! ```text
//! G4, per (jc, pc):   barrier      // prior compute done: Bc may be overwritten
//!                     pack Bc cooperatively
//!     per ic:         barrier      // prior compute done: Ac may be overwritten
//!                     pack Ac cooperatively
//!                     barrier      // both packs complete: buffers readable
//!                     compute own jr-range of the macro-kernel
//!
//! G3, per (jc, pc):   barrier      // prior compute done: Bc may be overwritten
//!                     pack Bc cooperatively
//!                     barrier      // Bc complete
//!     per own ic:     pack private Ac; compute full jr-range
//! ```
//!
//! Rank boundaries are `mc`/`nr`-aligned and each C tile is written by
//! exactly one rank with exactly the sequential operation order, so the
//! parallel paths are **bitwise identical** to [`gemm_blocked`] — the
//! determinism tests assert `max_abs_diff == 0.0` exactly.
//!
//! The host sandbox exposes a single core, so these paths are validated
//! for correctness here while parallel *performance* figures come from
//! [`crate::perfmodel`] (see DESIGN.md substitutions).

use crate::model::ccp::GemmConfig;
use crate::model::GemmDims;
use crate::runtime::pool::{PoolCtx, SubTeam, WorkerPool};
use crate::util::elem::Elem;
use crate::util::matrix::{MatView, MatViewMut};

use super::abft::{gemm_blocked_abft, verified_macro_kernel, AbftCtx, CheckSums};
use super::blocked::{gemm_blocked, macro_kernel, scale_c, Workspace};
use super::microkernel::MicroKernelImpl;
use super::packing::{pack_a, pack_b, packed_a_len, packed_b_len};

/// Which loop the threads split (paper §2.2 discussion).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ParallelLoop {
    /// Partition `ic` over `m` (grain `mc`, private `Ac` per thread).
    G3,
    /// Partition `jr` over `nc` (grain `nr`, shared `Ac`/`Bc`).
    G4,
}

/// A threading plan for one GEMM call.
#[derive(Clone, Copy, Debug)]
pub struct ThreadPlan {
    pub threads: usize,
    pub target: ParallelLoop,
}

impl ThreadPlan {
    pub fn sequential() -> Self {
        Self { threads: 1, target: ParallelLoop::G4 }
    }
}

/// Send-able raw pointer to C (threads write disjoint tiles). Generic
/// over the element type; defaults to `f64` so pre-generic code keeps
/// compiling unchanged.
pub(crate) struct SendPtr<E = f64>(pub(crate) *mut E);
unsafe impl<E> Send for SendPtr<E> {}
unsafe impl<E> Sync for SendPtr<E> {}

impl<E> Clone for SendPtr<E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E> Copy for SendPtr<E> {}

impl<E> SendPtr<E> {
    /// Accessor (not a field read) so closures capture the whole wrapper
    /// instead of the raw pointer under edition-2021 disjoint capture.
    pub(crate) fn ptr(&self) -> *mut E {
        self.0
    }
}

/// A packed buffer shared across ranks. Mutation is only ever through
/// disjoint micro-panel ranges between barriers; reads only happen after
/// the barrier that ends the pack phase.
struct SharedBuf<E = f64> {
    ptr: *mut E,
    len: usize,
}
unsafe impl<E> Send for SharedBuf<E> {}
unsafe impl<E> Sync for SharedBuf<E> {}

impl<E> Clone for SharedBuf<E> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<E> Copy for SharedBuf<E> {}

impl<E: Elem> SharedBuf<E> {
    fn new(buf: &mut [E]) -> Self {
        Self { ptr: buf.as_mut_ptr(), len: buf.len() }
    }

    /// A window `[off, off + len)` of this buffer (used to address one
    /// packed-`Ac` slot of the fused driver's per-iteration big buffer).
    fn window(&self, off: usize, len: usize) -> Self {
        assert!(off + len <= self.len);
        // SAFETY: in-bounds by the assert; aliasing discipline is the
        // caller's (same contract as `range_mut`).
        Self { ptr: unsafe { self.ptr.add(off) }, len }
    }

    /// # Safety
    /// The `[off, off + len)` range must be disjoint from every range any
    /// other rank mutates before the next barrier.
    #[allow(clippy::mut_from_ref)] // aliasing discipline documented above
    unsafe fn range_mut(&self, off: usize, len: usize) -> &mut [E] {
        debug_assert!(off + len <= self.len);
        std::slice::from_raw_parts_mut(self.ptr.add(off), len)
    }

    /// # Safety
    /// No rank may mutate the buffer between the barrier that completed
    /// the pack and the barrier that allows the next pack.
    unsafe fn as_slice(&self) -> &[E] {
        std::slice::from_raw_parts(self.ptr, self.len)
    }
}

/// This rank's contiguous share of `total` items at `grain` alignment:
/// whole blocks are dealt out as evenly as possible (remainder blocks go
/// one-each to the lowest ranks), so chunk sizes differ by at most one
/// block. Constant-time, allocation-free (called in inner loops).
pub fn partition_rank(total: usize, parts: usize, rank: usize, grain: usize) -> (usize, usize) {
    assert!(parts > 0 && grain > 0 && rank < parts);
    let blocks = total.div_ceil(grain);
    let base = blocks / parts;
    let rem = blocks % parts;
    let start = rank * base + rank.min(rem);
    let count = base + usize::from(rank < rem);
    let lo = (start * grain).min(total);
    let hi = ((start + count) * grain).min(total);
    (lo, hi)
}

/// Split `total` items into `parts` contiguous chunks at `grain`
/// alignment; returns (start, end) per part. Chunks may be empty, and
/// block counts differ by at most one (the seed's `div_ceil`-of-
/// `div_ceil` scheme packed the whole remainder into the early chunks,
/// e.g. 10 blocks / 4 threads → 3,3,3,1 with idle tails; this yields
/// 3,3,2,2).
pub fn partition(total: usize, parts: usize, grain: usize) -> Vec<(usize, usize)> {
    (0..parts).map(|rank| partition_rank(total, parts, rank, grain)).collect()
}

/// Cooperatively pack the `kc_eff x nc_eff` block `b_block` into `buf`:
/// this rank packs the `nr`-aligned column range assigned by
/// [`partition_rank`]. Byte-identical to a serial [`pack_b`].
fn coop_pack_b<E: Elem>(
    rank: usize,
    threads: usize,
    b_block: MatView<'_, E>,
    buf: SharedBuf<E>,
    nr: usize,
) {
    let (kc_eff, nc_eff) = (b_block.rows, b_block.cols);
    let (lo, hi) = partition_rank(nc_eff, threads, rank, nr);
    if lo < hi {
        let off = (lo / nr) * nr * kc_eff;
        let len = packed_b_len(kc_eff, hi - lo, nr);
        // SAFETY: ranges from partition_rank are disjoint across ranks.
        let dst = unsafe { buf.range_mut(off, len) };
        pack_b(b_block.sub(0, lo, kc_eff, hi - lo), dst, nr);
    }
}

/// Cooperatively pack the `mc_eff x kc_eff` block `a_block` into `buf`:
/// this rank packs the `mr`-aligned row range assigned by
/// [`partition_rank`]. Byte-identical to a serial [`pack_a`].
fn coop_pack_a<E: Elem>(
    rank: usize,
    threads: usize,
    a_block: MatView<'_, E>,
    buf: SharedBuf<E>,
    mr: usize,
    alpha: E,
) {
    let (mc_eff, kc_eff) = (a_block.rows, a_block.cols);
    let (lo, hi) = partition_rank(mc_eff, threads, rank, mr);
    if lo < hi {
        let off = (lo / mr) * mr * kc_eff;
        let len = packed_a_len(hi - lo, kc_eff, mr);
        // SAFETY: ranges from partition_rank are disjoint across ranks.
        let dst = unsafe { buf.range_mut(off, len) };
        pack_a(a_block.sub(lo, 0, hi - lo, kc_eff), dst, mr, alpha);
    }
}

/// `C *= beta`, split over columns on the pool for large C (small C is
/// scaled in place by the caller thread — forking costs more than it
/// saves). Column-wise arithmetic is identical to the sequential
/// [`scale_c`], preserving bitwise determinism.
pub(crate) fn scale_c_parallel<E: Elem>(beta: E, c: &mut MatViewMut<'_, E>, pool: &WorkerPool) {
    if beta == E::ONE {
        return;
    }
    const PARALLEL_ELEMS: usize = 256 * 256;
    if pool.threads() == 1 || c.rows * c.cols < PARALLEL_ELEMS {
        scale_c(beta, c);
        return;
    }
    let (rows, cols, ld) = (c.rows, c.cols, c.ld);
    let base = SendPtr(c.data.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        let (lo, hi) = partition_rank(cols, ctx.threads, ctx.rank, 1);
        for j in lo..hi {
            // SAFETY: ranks own disjoint column ranges of C.
            let col = unsafe { std::slice::from_raw_parts_mut(base.ptr().add(j * ld), rows) };
            if beta == E::ZERO {
                col.fill(E::ZERO);
            } else {
                for v in col {
                    *v *= beta;
                }
            }
        }
    });
}

/// Multi-threaded blocked GEMM: `C = alpha*A*B + beta*C`, broadcast as a
/// single job on `pool` (see the module docs for the barrier protocol).
/// With a single-thread pool this degenerates to [`gemm_blocked`] on the
/// pool's rank-0 workspace.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    beta: E,
    c: &mut MatViewMut<'_, E>,
    target: ParallelLoop,
    pool: &WorkerPool,
) {
    gemm_parallel_abft(cfg, kernel, alpha, a, b, beta, c, target, pool, None);
}

/// [`gemm_parallel`] with an optional ABFT context: when `abft` is
/// `Some`, every macro-block runs the checksum-verified epilogue (and the
/// armed `flip@` drill gets its injection points). `None` is the exact
/// unverified path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_parallel_abft<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    beta: E,
    c: &mut MatViewMut<'_, E>,
    target: ParallelLoop,
    pool: &WorkerPool,
    abft: Option<&AbftCtx<'_>>,
) {
    assert_eq!(kernel.spec, cfg.mk, "kernel/config shape mismatch");
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    assert_eq!(c.rows, a.rows, "C row mismatch");
    assert_eq!(c.cols, b.cols, "C col mismatch");
    if pool.threads() == 1 {
        let mut ws = pool.workspace(0);
        match abft {
            Some(ctx) => gemm_blocked_abft(cfg, kernel, alpha, a, b, beta, c, &mut ws, ctx),
            None => gemm_blocked(cfg, kernel, alpha, a, b, beta, c, &mut ws),
        }
        return;
    }
    let (m, n, k) = (a.rows, b.cols, a.cols);
    scale_c_parallel(beta, c, pool);
    if m == 0 || n == 0 || k == 0 || alpha == E::ZERO {
        return;
    }
    let ccp = cfg.ccp.clamp_to(GemmDims::new(m, n, k));
    let eff = GemmConfig { mk: cfg.mk, ccp };
    match target {
        ParallelLoop::G4 => gemm_parallel_g4(&eff, kernel, alpha, a, b, c, pool, abft),
        ParallelLoop::G3 => gemm_parallel_g3(&eff, kernel, alpha, a, b, c, pool, abft),
    }
}

/// One whole G4-schedule sweep of a single GEMM, executed by the `rank`
/// of a `threads`-wide (sub-)team: the full G1/G2/G3 loop nest with
/// cooperative packing into the given shared buffers. `sync` must be the
/// barrier of exactly the ranks executing this call (the full team in
/// [`gemm_parallel_g4`], one member group in [`gemm_batch_parallel`]),
/// and every one of those ranks must make this call with identical
/// arguments. Per-element arithmetic — and therefore every bit of C — is
/// identical to [`gemm_blocked`] with the same (clamped) configuration,
/// for **any** team width including 1.
#[allow(clippy::too_many_arguments)]
fn g4_sweep<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    cbase: SendPtr<E>,
    ldc: usize,
    a_shared: SharedBuf<E>,
    b_shared: SharedBuf<E>,
    rank: usize,
    threads: usize,
    sync: &dyn Fn(),
    abft: Option<&AbftCtx<'_>>,
) {
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let (mc, nc, kc) = (cfg.ccp.mc, cfg.ccp.nc, cfg.ccp.kc);
    let (mr, nr) = (cfg.mk.mr, cfg.mk.nr);
    let mut jc = 0; // Loop G1
    while jc < n {
        let nc_eff = nc.min(n - jc);
        let mut pc = 0; // Loop G2
        while pc < k {
            let kc_eff = kc.min(k - pc);
            sync(); // prior compute done: Bc may be overwritten
            coop_pack_b(rank, threads, b.sub(pc, jc, kc_eff, nc_eff), b_shared, nr);
            let mut ic = 0; // Loop G3
            while ic < m {
                let mc_eff = mc.min(m - ic);
                sync(); // prior compute done: Ac may be overwritten
                coop_pack_a(rank, threads, a.sub(ic, pc, mc_eff, kc_eff), a_shared, mr, alpha);
                if let Some(actx) = abft {
                    // Injection drill: a rank may flip one bit in its own
                    // just-packed (pre-barrier, so un-raced) Ac share.
                    let (flo, fhi) = partition_rank(mc_eff, threads, rank, mr);
                    if flo < fhi {
                        let off = (flo / mr) * mr * kc_eff;
                        let len = packed_a_len(fhi - flo, kc_eff, mr);
                        // SAFETY: same disjoint range this rank just
                        // packed; the pack-complete barrier is below.
                        actx.maybe_flip(rank, unsafe { a_shared.range_mut(off, len) });
                    }
                }
                sync(); // packs complete: buffers readable
                let (lo, hi) = partition_rank(nc_eff, threads, rank, nr);
                if lo < hi {
                    // SAFETY: pack phases are barrier-complete; each
                    // rank updates a disjoint jr-range of C.
                    match abft {
                        Some(actx) => {
                            let a_src = a.sub(ic, pc, mc_eff, kc_eff);
                            let b_src = b.sub(pc, jc, kc_eff, nc_eff);
                            let sums = CheckSums::from_views_timed(
                                a_src,
                                alpha,
                                b_src.sub(0, lo, kc_eff, hi - lo),
                                actx.stats,
                            );
                            unsafe {
                                verified_macro_kernel(
                                    kernel,
                                    kc_eff,
                                    mc_eff,
                                    nc_eff,
                                    a_shared.as_slice(),
                                    b_shared.as_slice(),
                                    cbase.ptr().add(jc * ldc + ic),
                                    ldc,
                                    (lo, hi),
                                    alpha,
                                    a_src,
                                    b_src,
                                    &sums,
                                    actx,
                                    (ic, jc),
                                );
                            }
                        }
                        None => unsafe {
                            macro_kernel(
                                kernel,
                                kc_eff,
                                mc_eff,
                                nc_eff,
                                a_shared.as_slice(),
                                b_shared.as_slice(),
                                cbase.ptr().add(jc * ldc + ic),
                                ldc,
                                (lo, hi),
                            );
                        },
                    }
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

fn gemm_parallel_g4<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    c: &mut MatViewMut<'_, E>,
    pool: &WorkerPool,
    abft: Option<&AbftCtx<'_>>,
) {
    let ldc = c.ld;
    // The team-shared Ac/Bc are pinned in the pool's rank-0 workspace;
    // size them while we hold the lock, then share raw views. Keeping the
    // guard for the whole job both pins the buffers and excludes any
    // other (erroneous) borrower.
    let mut ws0 = pool.workspace(0);
    let a_need = packed_a_len(cfg.ccp.mc, cfg.ccp.kc, cfg.mk.mr);
    let b_need = packed_b_len(cfg.ccp.kc, cfg.ccp.nc, cfg.mk.nr);
    let (a_buf, b_buf) = ws0.bufs_mut::<E>(a_need, b_need);
    let a_shared = SharedBuf::new(a_buf);
    let b_shared = SharedBuf::new(b_buf);
    let cbase = SendPtr(c.data.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        g4_sweep(
            cfg, kernel, alpha, a, b, cbase, ldc, a_shared, b_shared, ctx.rank, ctx.threads,
            &|| ctx.barrier(), abft,
        );
    });
    drop(ws0);
}

fn gemm_parallel_g3<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    c: &mut MatViewMut<'_, E>,
    pool: &WorkerPool,
    abft: Option<&AbftCtx<'_>>,
) {
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let (mc, nc, kc) = (cfg.ccp.mc, cfg.ccp.nc, cfg.ccp.kc);
    let (mr, nr) = (cfg.mk.mr, cfg.mk.nr);
    let a_need = packed_a_len(mc, kc, mr);
    let b_need = packed_b_len(kc, nc, nr);
    let ldc = c.ld;
    // The team-shared Bc (and rank 0's private Ac) live in the rank-0
    // workspace, locked by the leader for the duration of the job; ranks
    // 1.. pin their own workspaces inside the job. The G3 ic-partition is
    // mc-aligned, so each rank's macro-blocks coincide exactly with the
    // sequential schedule.
    let mut ws0 = pool.workspace(0);
    let (a0_elems, b0_elems) = ws0.bufs_mut::<E>(a_need, b_need);
    let a0_buf = SharedBuf::new(a0_elems);
    let b_shared = SharedBuf::new(b0_elems);
    let cbase = SendPtr(c.data.as_mut_ptr());
    pool.run(&|ctx: &PoolCtx<'_>| {
        let (rank, threads) = (ctx.rank, ctx.threads);
        // Rank 0's Ac is the leader-locked workspace buffer; other ranks
        // use their own pinned pool workspace.
        let mut ws_own = if rank == 0 { None } else { Some(ctx.workspace()) };
        if let Some(ws) = ws_own.as_mut() {
            ws.ensure_elems::<E>(a_need, b_need);
        }
        let (lo, hi) = partition_rank(m, threads, rank, mc);
        let mut jc = 0; // Loop G1
        while jc < n {
            let nc_eff = nc.min(n - jc);
            let mut pc = 0; // Loop G2
            while pc < k {
                let kc_eff = kc.min(k - pc);
                ctx.barrier(); // prior compute done: Bc may be overwritten
                coop_pack_b(rank, threads, b.sub(pc, jc, kc_eff, nc_eff), b_shared, nr);
                ctx.barrier(); // Bc complete
                let mut ic = lo; // Loop G3 over this rank's chunk
                while ic < hi {
                    let mc_eff = mc.min(hi - ic);
                    let a_buf: &mut [E] = match ws_own.as_mut() {
                        Some(ws) => ws.bufs_mut::<E>(a_need, 0).0,
                        // SAFETY: only rank 0 touches the rank-0 buffer.
                        None => unsafe { a0_buf.range_mut(0, a0_buf.len) },
                    };
                    pack_a(a.sub(ic, pc, mc_eff, kc_eff), a_buf, mr, alpha);
                    if let Some(actx) = abft {
                        // Injection drill on this rank's private Ac.
                        let len = packed_a_len(mc_eff, kc_eff, mr);
                        actx.maybe_flip(rank, &mut a_buf[..len]);
                    }
                    // SAFETY: Bc is barrier-complete; each rank updates a
                    // disjoint (mc-aligned) row-range of C.
                    match abft {
                        Some(actx) => {
                            let a_src = a.sub(ic, pc, mc_eff, kc_eff);
                            let b_src = b.sub(pc, jc, kc_eff, nc_eff);
                            let sums = CheckSums::from_views_timed(
                                a_src, alpha, b_src, actx.stats,
                            );
                            unsafe {
                                verified_macro_kernel(
                                    kernel,
                                    kc_eff,
                                    mc_eff,
                                    nc_eff,
                                    a_buf,
                                    b_shared.as_slice(),
                                    cbase.ptr().add(jc * ldc + ic),
                                    ldc,
                                    (0, nc_eff),
                                    alpha,
                                    a_src,
                                    b_src,
                                    &sums,
                                    actx,
                                    (ic, jc),
                                );
                            }
                        }
                        None => unsafe {
                            macro_kernel(
                                kernel,
                                kc_eff,
                                mc_eff,
                                nc_eff,
                                a_buf,
                                b_shared.as_slice(),
                                cbase.ptr().add(jc * ldc + ic),
                                ldc,
                                (0, nc_eff),
                            );
                        },
                    }
                    ic += mc;
                }
                pc += kc;
            }
            jc += nc;
        }
    });
    drop(ws0);
}

/// Packed-`Ac` layout for the fused trailing driver: one write-once slot
/// per `(pc, ic)` macro-block of A, laid out pc-major. Slots are packed
/// exactly once per call and read by both column phases, so the
/// factorization's k-panel is packed once per iteration instead of once
/// per phase. Offsets are closed-form because every ic block before the
/// last is a full `mc` (and every pc block before the last a full `kc`).
#[derive(Clone, Copy)]
struct PackedALayout {
    m: usize,
    k: usize,
    mc: usize,
    kc: usize,
    mr: usize,
}

impl PackedALayout {
    /// Packed rows summed over the ic blocks: each block zero-pads its
    /// own `mc_eff` up to whole `mr` micro-panels, so when `mr` does not
    /// divide `mc` this is strictly more than `ceil(m/mr)*mr` — the pc
    /// stride must use this per-block sum, not a ceil over the whole `m`
    /// (that under-sizes the buffer and aliases neighbouring slots).
    fn padded_rows(&self) -> usize {
        let full = self.m / self.mc;
        let rem = self.m % self.mc;
        full * self.mc.div_ceil(self.mr) * self.mr
            + if rem > 0 { rem.div_ceil(self.mr) * self.mr } else { 0 }
    }

    fn total_len(&self) -> usize {
        // Every pc block stores `padded_rows` rows for each of its
        // kc_eff k-values, and the kc_eff sum over all pc blocks is k.
        self.padded_rows() * self.k
    }

    fn offset(&self, pc: usize, ic: usize) -> usize {
        let kc_eff = self.kc.min(self.k - pc);
        (pc / self.kc) * self.padded_rows() * self.kc
            + (ic / self.mc) * self.mc.div_ceil(self.mr) * self.mr * kc_eff
    }

    fn block_len(&self, pc: usize, ic: usize) -> usize {
        let kc_eff = self.kc.min(self.k - pc);
        let mc_eff = self.mc.min(self.m - ic);
        packed_a_len(mc_eff, kc_eff, self.mr)
    }
}

/// One column-phase sweep of the fused driver: the executing (sub-)team
/// updates C columns `[cols.0, cols.1)` from the shared packed-A slots,
/// packing them cooperatively on the first pass when `pack_a_slots`.
/// `sync` must be the barrier of exactly the ranks executing this call
/// (full-team barrier in phase 1, update sub-team barrier in phase 2),
/// and every one of those ranks must make this call with identical
/// arguments.
#[allow(clippy::too_many_arguments)]
fn fused_col_sweep<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    cbase: SendPtr<E>,
    ldc: usize,
    cols: (usize, usize),
    pack_a_slots: bool,
    layout: PackedALayout,
    a_shared: SharedBuf<E>,
    b_shared: SharedBuf<E>,
    rank: usize,
    threads: usize,
    sync: &dyn Fn(),
    abft: Option<&AbftCtx<'_>>,
) {
    let (m, k) = (a.rows, a.cols);
    let (mc, nc, kc) = (cfg.ccp.mc, cfg.ccp.nc, cfg.ccp.kc);
    let (mr, nr) = (cfg.mk.mr, cfg.mk.nr);
    let (col_lo, col_hi) = cols;
    let mut first_pass = pack_a_slots;
    let mut jc = col_lo; // Loop G1 over this phase's column range
    while jc < col_hi {
        let nc_eff = nc.min(col_hi - jc);
        let mut pc = 0; // Loop G2
        while pc < k {
            let kc_eff = kc.min(k - pc);
            sync(); // prior compute done: Bc may be overwritten
            coop_pack_b(rank, threads, b.sub(pc, jc, kc_eff, nc_eff), b_shared, nr);
            if first_pass {
                // Pack every Ac slot of this pc block. Slots are
                // write-once and mutually disjoint, so no barrier is
                // needed between them — only the one pack-complete
                // barrier below.
                let mut ic = 0;
                while ic < m {
                    let mc_eff = mc.min(m - ic);
                    let slot = a_shared.window(layout.offset(pc, ic), layout.block_len(pc, ic));
                    coop_pack_a(rank, threads, a.sub(ic, pc, mc_eff, kc_eff), slot, mr, alpha);
                    ic += mc;
                }
                if let Some(actx) = abft {
                    // Injection drill: flip a bit in this rank's own
                    // just-packed share of the first Ac slot, before the
                    // pack-complete barrier publishes it.
                    let mc_eff0 = mc.min(m);
                    let (flo, fhi) = partition_rank(mc_eff0, threads, rank, mr);
                    if flo < fhi {
                        let slot =
                            a_shared.window(layout.offset(pc, 0), layout.block_len(pc, 0));
                        let off = (flo / mr) * mr * kc_eff;
                        let len = packed_a_len(fhi - flo, kc_eff, mr);
                        // SAFETY: same disjoint range this rank packed.
                        actx.maybe_flip(rank, unsafe { slot.range_mut(off, len) });
                    }
                }
            }
            sync(); // packs complete: buffers readable
            let (lo, hi) = partition_rank(nc_eff, threads, rank, nr);
            if lo < hi {
                let mut ic = 0; // Loop G3
                while ic < m {
                    let mc_eff = mc.min(m - ic);
                    let off = layout.offset(pc, ic);
                    let len = layout.block_len(pc, ic);
                    // SAFETY: packs are barrier-complete; each rank
                    // updates a disjoint jr-range of C.
                    match abft {
                        Some(actx) => {
                            let a_src = a.sub(ic, pc, mc_eff, kc_eff);
                            let b_src = b.sub(pc, jc, kc_eff, nc_eff);
                            let sums = CheckSums::from_views_timed(
                                a_src,
                                alpha,
                                b_src.sub(0, lo, kc_eff, hi - lo),
                                actx.stats,
                            );
                            unsafe {
                                verified_macro_kernel(
                                    kernel,
                                    kc_eff,
                                    mc_eff,
                                    nc_eff,
                                    &a_shared.as_slice()[off..off + len],
                                    b_shared.as_slice(),
                                    cbase.ptr().add(jc * ldc + ic),
                                    ldc,
                                    (lo, hi),
                                    alpha,
                                    a_src,
                                    b_src,
                                    &sums,
                                    actx,
                                    (ic, jc),
                                );
                            }
                        }
                        None => unsafe {
                            macro_kernel(
                                kernel,
                                kc_eff,
                                mc_eff,
                                nc_eff,
                                &a_shared.as_slice()[off..off + len],
                                b_shared.as_slice(),
                                cbase.ptr().add(jc * ldc + ic),
                                ldc,
                                (lo, hi),
                            );
                        },
                    }
                    ic += mc;
                }
            }
            pc += kc;
        }
        first_pass = false;
        jc += nc;
    }
}

/// Lookahead-fused trailing update (`C += alpha * A * B`, beta fixed at
/// 1): the first `split_col` columns of C are updated **first** by the
/// whole team; the team then splits — `panel_workers` ranks run
/// `panel_task` (e.g. factoring the next panel inside those
/// freshly-updated columns) while the remaining ranks sweep the other
/// columns — and everyone rejoins at a single team barrier.
///
/// This is the depth-1 special case of [`gemm_fused_trailing_ranges`]
/// (head = the panel columns, tail = everything after them); see there
/// for the full contract and the bitwise-identity argument.
#[allow(clippy::too_many_arguments)]
pub fn gemm_fused_trailing<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    c: &mut MatViewMut<'_, E>,
    split_col: usize,
    panel_workers: usize,
    panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    pool: &WorkerPool,
) {
    let n = b.cols;
    assert!(split_col <= n, "split_col out of range");
    gemm_fused_trailing_ranges(
        cfg,
        kernel,
        alpha,
        a,
        b,
        c,
        &[(0, split_col)],
        (split_col, n),
        panel_workers,
        false,
        panel_task,
        pool,
    );
}

/// The general lookahead-fused trailing update the deep-lookahead
/// pipeline drives (`C += alpha * A * B`, beta fixed at 1):
///
/// 1. **Head** — the full team updates each column range of `head`, in
///    order (the pending panels entering the lookahead window).
/// 2. **Split** — `panel_workers` ranks run `panel_task` on the head
///    columns (factor-ahead work-queue) while the update sub-team sweeps
///    the `tail` range (the remainder of the trailing matrix).
/// 3. **Rejoin** — one timed full-team barrier
///    ([`crate::runtime::pool::PoolCtx::rejoin_timed`]) that attributes
///    each rank's wait to panel idle, update idle, or — when
///    `panel_queue_empty` — queue-empty stall.
///
/// Columns outside `head ∪ tail` are **not touched**: the deep pipeline
/// excludes in-window columns that earlier fused jobs already updated.
/// `head` ranges must be ascending and disjoint and end at or before
/// `tail.0`; the k-panel of A is packed once (write-once slots shared by
/// every phase).
///
/// Per-element arithmetic is bitwise identical to [`gemm_parallel`] /
/// [`gemm_blocked`] with the same (clamped) configuration over any
/// column decomposition: the split never changes an element's
/// k-accumulation — every micro-kernel accumulates its tile from zero
/// and adds into C once per `pc` block, in ascending `pc` order,
/// regardless of tile geometry.
///
/// `panel_task` runs exactly once per panel-team rank (once total on a
/// single-thread pool), only after every head range is complete; it must
/// touch only memory disjoint from the tail columns and from A and B.
#[allow(clippy::too_many_arguments)]
pub fn gemm_fused_trailing_ranges<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    c: &mut MatViewMut<'_, E>,
    head: &[(usize, usize)],
    tail: (usize, usize),
    panel_workers: usize,
    panel_queue_empty: bool,
    panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    pool: &WorkerPool,
) {
    gemm_fused_trailing_ranges_abft(
        cfg, kernel, alpha, a, b, c, head, tail, panel_workers, panel_queue_empty, panel_task,
        pool, None,
    );
}

/// [`gemm_fused_trailing_ranges`] with an optional ABFT context: `Some`
/// runs every trailing-update macro-block through the checksum-verified
/// epilogue (the lookahead pipelines' verified mode), `None` is the
/// exact unverified path.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_fused_trailing_ranges_abft<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    c: &mut MatViewMut<'_, E>,
    head: &[(usize, usize)],
    tail: (usize, usize),
    panel_workers: usize,
    panel_queue_empty: bool,
    panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    pool: &WorkerPool,
    abft: Option<&AbftCtx<'_>>,
) {
    assert_eq!(kernel.spec, cfg.mk, "kernel/config shape mismatch");
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    assert_eq!(c.rows, a.rows, "C row mismatch");
    assert_eq!(c.cols, b.cols, "C col mismatch");
    let (m, n, k) = (a.rows, b.cols, a.cols);
    let mut prev_hi = 0;
    for &(lo, hi) in head {
        assert!(lo <= hi && hi <= n, "head range out of bounds");
        assert!(lo >= prev_hi, "head ranges must be ascending and disjoint");
        prev_hi = hi;
    }
    assert!(tail.0 <= tail.1 && tail.1 <= n, "tail range out of bounds");
    assert!(prev_hi <= tail.0, "head must end at or before the tail");
    if m == 0 || n == 0 || k == 0 || alpha == E::ZERO {
        // Nothing to update, but callers rely on the panel task running.
        panel_task(&SubTeam::solo_panel());
        return;
    }
    let ccp = cfg.ccp.clamp_to(GemmDims::new(m, n, k));
    let eff = GemmConfig { mk: cfg.mk, ccp };
    if pool.threads() == 1 {
        let mut ws = pool.workspace(0);
        gemm_fused_trailing_ranges_seq(
            &eff, kernel, alpha, a, b, c, head, tail, panel_task, &mut ws, abft,
        );
        return;
    }
    let layout = PackedALayout { m, k, mc: ccp.mc, kc: ccp.kc, mr: eff.mk.mr };
    let ldc = c.ld;
    let mut ws0 = pool.workspace(0);
    // The big packed-A buffer holds one write-once slot per (pc, ic)
    // macro-block; always at least one block's worth.
    let abig = layout.total_len().max(packed_a_len(ccp.mc, ccp.kc, eff.mk.mr));
    let b_need = packed_b_len(ccp.kc, ccp.nc, eff.mk.nr);
    let (a_buf, b_buf) = ws0.bufs_mut::<E>(abig, b_need);
    let a_shared = SharedBuf::new(a_buf);
    let b_shared = SharedBuf::new(b_buf);
    let cbase = SendPtr(c.data.as_mut_ptr());
    // The Ac slots are packed cooperatively by whichever phase first
    // sweeps a non-empty range; every rank derives the same answer from
    // the (identical) range arguments.
    let any_head = head.iter().any(|&(lo, hi)| hi > lo);
    pool.run(&|ctx: &PoolCtx<'_>| {
        // Phase 1: the full team updates the pending-panel ranges in
        // order (and packs every Ac slot, write-once, on the first
        // non-empty range).
        let mut packed = false;
        for &(lo, hi) in head {
            fused_col_sweep(
                &eff, kernel, alpha, a, b, cbase, ldc, (lo, hi), !packed, layout, a_shared,
                b_shared, ctx.rank, ctx.threads, &|| ctx.barrier(), abft,
            );
            packed = packed || hi > lo;
        }
        ctx.barrier(); // head columns final; Bc free for the update team
        let sub = ctx.split(panel_workers);
        if sub.panel {
            panel_task(&sub);
        } else {
            // Phase 2: the update sub-team sweeps the tail, reusing the
            // packed Ac slots (packing them here only if no head range
            // packed them).
            fused_col_sweep(
                &eff, kernel, alpha, a, b, cbase, ldc, tail, !any_head, layout, a_shared,
                b_shared, sub.rank, sub.threads, &|| sub.barrier(), abft,
            );
        }
        // Rejoin: panel results and tail columns published; waits are
        // attributed per phase.
        ctx.rejoin_timed(&sub, panel_queue_empty);
    });
    drop(ws0);
}

/// The fused schedule executed inline (no pool, or a single-thread pool):
/// update the head ranges, run the panel task solo, update the tail.
/// Identical operation order — and therefore identical results — to the
/// split-team driver.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_fused_trailing_ranges_seq<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    c: &mut MatViewMut<'_, E>,
    head: &[(usize, usize)],
    tail: (usize, usize),
    panel_task: &(dyn Fn(&SubTeam<'_>) + Sync),
    ws: &mut Workspace,
    abft: Option<&AbftCtx<'_>>,
) {
    let mut run = |b1: MatView<'_, E>, c1: &mut MatViewMut<'_, E>, ws: &mut Workspace| match abft
    {
        Some(ctx) => gemm_blocked_abft(cfg, kernel, alpha, a, b1, E::ONE, c1, ws, ctx),
        None => gemm_blocked(cfg, kernel, alpha, a, b1, E::ONE, c1, ws),
    };
    for &(lo, hi) in head {
        if hi > lo {
            let b1 = b.sub(0, lo, b.rows, hi - lo);
            let mut c1 = c.sub_mut(0, lo, c.rows, hi - lo);
            run(b1, &mut c1, ws);
        }
    }
    panel_task(&SubTeam::solo_panel());
    if tail.1 > tail.0 {
        let b2 = b.sub(0, tail.0, b.rows, tail.1 - tail.0);
        let mut c2 = c.sub_mut(0, tail.0, c.rows, tail.1 - tail.0);
        run(b2, &mut c2, ws);
    }
}

/// One member of a fused multi-GEMM batch job: an independent
/// `C = alpha * A * B + beta * C` with its **own** configuration and
/// kernel (the per-call co-design selection the paper argues for is kept
/// per request, batching or not).
pub struct BatchGemm<'a, E = f64> {
    pub cfg: GemmConfig,
    pub kernel: MicroKernelImpl<E>,
    pub alpha: E,
    pub a: MatView<'a, E>,
    pub b: MatView<'a, E>,
    pub beta: E,
    pub c: MatViewMut<'a, E>,
}

/// Per-member job descriptor shared with the pool closure (raw C base +
/// clamped config; views of A/B are `Copy` and `Sync`).
struct MemberDesc<'a, E> {
    cfg: GemmConfig,
    kernel: MicroKernelImpl<E>,
    alpha: E,
    beta: E,
    a: MatView<'a, E>,
    b: MatView<'a, E>,
    cbase: SendPtr<E>,
    rows: usize,
    cols: usize,
    ldc: usize,
    /// Nothing to accumulate (`C = beta * C` only).
    degenerate: bool,
}

/// `C *= beta` through a raw base pointer: reconstructs the view and
/// delegates to the one true [`scale_c`], so batched members stay
/// bitwise identical to the solo path by construction.
///
/// # Safety
/// `base` must point to a valid `rows x cols` column-major block with
/// stride `ldc >= rows` that no other rank touches until the caller's
/// next group barrier.
unsafe fn scale_c_raw<E: Elem>(beta: E, base: *mut E, rows: usize, cols: usize, ldc: usize) {
    if beta == E::ONE || rows == 0 || cols == 0 {
        return;
    }
    let len = ldc * (cols - 1) + rows;
    let data = std::slice::from_raw_parts_mut(base, len);
    scale_c(beta, &mut MatViewMut { rows, cols, ld: ldc, data });
}

/// Execute N **independent** GEMMs as one fused pool epoch: the team is
/// partitioned into one [`crate::runtime::pool::TeamGroup`] per member
/// (contiguous rank ranges from `shares`, every entry `>= 1` and the sum
/// exactly `pool.threads()`), and each group runs its member's full
/// [`g4_sweep`] — cooperative packing into that group's own packed
/// slots (pinned in the group leader's pool workspace), the member's own
/// clamped configuration, and a group-private barrier, so groups never
/// synchronize with each other. This is what turns "N small requests,
/// each serialized on the pool leader lock" into "one broadcast that
/// keeps every rank busy".
///
/// **Bitwise identity:** a group of width `w` executes exactly the
/// schedule [`gemm_parallel`] (target G4) runs on a `w`-wide pool, which
/// is bitwise identical to [`gemm_blocked`] for any `w` — so every
/// member's C is bit-for-bit what a solo dispatch of that request would
/// have produced, regardless of grouping (the batching tests assert
/// exact equality).
///
/// With a single-thread pool the members run inline, in order, through
/// [`gemm_blocked`] — the same degenerate path a solo dispatch takes.
pub fn gemm_batch_parallel<E: Elem>(
    members: &mut [BatchGemm<'_, E>],
    shares: &[usize],
    pool: &WorkerPool,
) {
    assert_eq!(members.len(), shares.len(), "one share per batch member");
    for m in members.iter() {
        assert_eq!(m.kernel.spec, m.cfg.mk, "kernel/config shape mismatch");
        assert_eq!(m.a.cols, m.b.rows, "inner dimension mismatch");
        assert_eq!(m.c.rows, m.a.rows, "C row mismatch");
        assert_eq!(m.c.cols, m.b.cols, "C col mismatch");
    }
    if pool.threads() == 1 {
        // Inline fallback: exactly the solo dispatch path, member by
        // member, on the pool's rank-0 workspace.
        let mut ws = pool.workspace(0);
        for m in members.iter_mut() {
            gemm_blocked(&m.cfg, &m.kernel, m.alpha, m.a, m.b, m.beta, &mut m.c, &mut ws);
        }
        return;
    }
    assert_eq!(
        shares.iter().sum::<usize>(),
        pool.threads(),
        "shares must cover the whole team"
    );
    // Each group's shared Ac/Bc are pinned in its leader's (= first
    // global rank's) pool workspace. Lock order matters for deadlock
    // freedom with concurrent drivers: rank 0 first (every pool driver
    // takes workspace(0) before the run lock, making it the de-facto
    // driver lock), then the remaining leaders in ascending rank order.
    let mut descs: Vec<MemberDesc<'_, E>> = Vec::with_capacity(members.len());
    let mut guards = Vec::with_capacity(members.len());
    let mut bufs: Vec<(SharedBuf<E>, SharedBuf<E>)> = Vec::with_capacity(members.len());
    let mut leader = 0usize;
    for (m, &share) in members.iter_mut().zip(shares) {
        assert!(share > 0, "every member needs at least one rank");
        let (rows, cols, k) = (m.a.rows, m.b.cols, m.a.cols);
        let ccp = m.cfg.ccp.clamp_to(GemmDims::new(rows, cols, k));
        let eff = GemmConfig { mk: m.cfg.mk, ccp };
        let mut ws = pool.workspace(leader);
        let a_need = packed_a_len(ccp.mc, ccp.kc, eff.mk.mr);
        let b_need = packed_b_len(ccp.kc, ccp.nc, eff.mk.nr);
        let (a_buf, b_buf) = ws.bufs_mut::<E>(a_need, b_need);
        bufs.push((SharedBuf::new(a_buf), SharedBuf::new(b_buf)));
        guards.push(ws);
        descs.push(MemberDesc {
            cfg: eff,
            kernel: m.kernel,
            alpha: m.alpha,
            beta: m.beta,
            a: m.a,
            b: m.b,
            cbase: SendPtr(m.c.data.as_mut_ptr()),
            rows,
            cols,
            ldc: m.c.ld,
            degenerate: rows == 0 || cols == 0 || k == 0 || m.alpha == E::ZERO,
        });
        leader += share;
    }
    pool.run(&|ctx: &PoolCtx<'_>| {
        let grp = ctx.group(shares);
        let d = &descs[grp.index];
        let (a_shared, b_shared) = bufs[grp.index];
        // Beta scaling by the group's local rank 0; the sweep's first
        // group barrier orders it before any rank's compute reads C.
        if grp.rank == 0 {
            // SAFETY: only local rank 0 writes, and only to this
            // member's C.
            unsafe { scale_c_raw(d.beta, d.cbase.ptr(), d.rows, d.cols, d.ldc) };
        }
        if d.degenerate {
            // Every group rank derives the same answer from the same
            // descriptor: no barrier imbalance.
            return;
        }
        g4_sweep(
            &d.cfg, &d.kernel, d.alpha, d.a, d.b, d.cbase, d.ldc, a_shared, b_shared, grp.rank,
            grp.threads, &|| grp.barrier(), None,
        );
    });
    drop(guards);
}

/// The seed's spawn-per-macro-block G4 driver, retained **only** as the
/// ablation baseline (`exp_ablation` case "spawn-per-block" and the pool
/// regression tests): it spawns fresh OS threads inside the `ic` loop,
/// which is exactly the overhead the persistent pool removes.
#[allow(clippy::too_many_arguments)]
pub fn gemm_parallel_spawning(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl,
    alpha: f64,
    a: MatView<'_>,
    b: MatView<'_>,
    beta: f64,
    c: &mut MatViewMut<'_>,
    threads: usize,
    ws: &mut Workspace,
) {
    assert_eq!(a.cols, b.rows);
    assert_eq!(c.rows, a.rows);
    assert_eq!(c.cols, b.cols);
    let (m, n, k) = (a.rows, b.cols, a.cols);
    scale_c(beta, c);
    if m == 0 || n == 0 || k == 0 || alpha == 0.0 {
        return;
    }
    let ccp = cfg.ccp.clamp_to(GemmDims::new(m, n, k));
    let eff = GemmConfig { mk: cfg.mk, ccp };
    ws.ensure(&eff);
    let (mc, nc, kc) = (ccp.mc, ccp.nc, ccp.kc);
    let ldc = c.ld;
    let mut jc = 0;
    while jc < n {
        let nc_eff = nc.min(n - jc);
        let mut pc = 0;
        while pc < k {
            let kc_eff = kc.min(k - pc);
            pack_b(b.sub(pc, jc, kc_eff, nc_eff), &mut ws.b_buf, eff.mk.nr);
            let mut ic = 0;
            while ic < m {
                let mc_eff = mc.min(m - ic);
                pack_a(a.sub(ic, pc, mc_eff, kc_eff), &mut ws.a_buf, eff.mk.mr, alpha);
                let base = SendPtr(unsafe { c.data.as_mut_ptr().add(jc * ldc + ic) });
                let parts = partition(nc_eff, threads, eff.mk.nr);
                let a_buf = &ws.a_buf;
                let b_buf = &ws.b_buf;
                std::thread::scope(|s| {
                    for &(lo, hi) in parts.iter().skip(1) {
                        if lo >= hi {
                            continue;
                        }
                        let base = base;
                        s.spawn(move || unsafe {
                            macro_kernel(kernel, kc_eff, mc_eff, nc_eff, a_buf, b_buf, base.ptr(), ldc, (lo, hi));
                        });
                    }
                    // Leader takes the first chunk.
                    let (lo, hi) = parts[0];
                    if lo < hi {
                        unsafe {
                            macro_kernel(kernel, kc_eff, mc_eff, nc_eff, a_buf, b_buf, base.ptr(), ldc, (lo, hi));
                        }
                    }
                });
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::gemm_reference;
    use crate::gemm::microkernel::for_shape;
    use crate::model::{Ccp, MicroKernel};
    use crate::util::{MatrixF64, Pcg64};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    fn run_parallel(target: ParallelLoop, threads: usize, m: usize, n: usize, k: usize, ccp: Ccp) {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp };
        let mut rng = Pcg64::seed((m + n + k + threads) as u64);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        // Reference for accuracy...
        let mut expect = c.clone();
        gemm_reference(1.0, a.view(), b.view(), 1.0, &mut expect.view_mut());
        // ...and the sequential blocked path for bitwise determinism.
        let mut c_seq = c.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c_seq.view_mut(), &mut ws);
        let pool = WorkerPool::new(threads);
        gemm_parallel(
            &cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c.view_mut(), target, &pool,
        );
        assert!(
            c.max_abs_diff(&expect) < 1e-12 * (k as f64),
            "{target:?} x{threads} {m}x{n}x{k} diverges from reference"
        );
        assert_eq!(
            c.max_abs_diff(&c_seq),
            0.0,
            "{target:?} x{threads} {m}x{n}x{k} must be bitwise identical to gemm_blocked"
        );
    }

    #[test]
    fn g4_matches_reference() {
        run_parallel(ParallelLoop::G4, 2, 64, 96, 40, Ccp::new(32, 24, 16));
        run_parallel(ParallelLoop::G4, 4, 61, 53, 47, Ccp::new(37, 29, 13));
        run_parallel(ParallelLoop::G4, 3, 100, 30, 20, Ccp::new(48, 12, 8));
    }

    #[test]
    fn g3_matches_reference() {
        run_parallel(ParallelLoop::G3, 2, 64, 96, 40, Ccp::new(32, 24, 16));
        run_parallel(ParallelLoop::G3, 4, 61, 53, 47, Ccp::new(16, 29, 13));
        run_parallel(ParallelLoop::G3, 3, 100, 30, 20, Ccp::new(24, 12, 8));
    }

    #[test]
    fn more_threads_than_work() {
        // 8 threads but only 2 mc chunks / tiny nc: empty chunks allowed.
        run_parallel(ParallelLoop::G3, 8, 20, 12, 10, Ccp::new(16, 12, 8));
        run_parallel(ParallelLoop::G4, 8, 20, 12, 10, Ccp::new(16, 12, 8));
    }

    #[test]
    fn single_thread_pool_delegates_to_blocked() {
        run_parallel(ParallelLoop::G3, 1, 33, 21, 17, Ccp::new(16, 12, 8));
    }

    #[test]
    fn pool_is_reusable_across_calls_and_targets() {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(32, 24, 16) };
        let pool = WorkerPool::new(3);
        let mut rng = Pcg64::seed(99);
        for (i, target) in [ParallelLoop::G4, ParallelLoop::G3, ParallelLoop::G4]
            .into_iter()
            .enumerate()
        {
            let (m, n, k) = (40 + 7 * i, 30 + 5 * i, 20 + 3 * i);
            let a = MatrixF64::random(m, k, &mut rng);
            let b = MatrixF64::random(k, n, &mut rng);
            let mut c = MatrixF64::zeros(m, n);
            let mut expect = MatrixF64::zeros(m, n);
            gemm_reference(1.0, a.view(), b.view(), 0.0, &mut expect.view_mut());
            gemm_parallel(
                &cfg, &kernel, 1.0, a.view(), b.view(), 0.0, &mut c.view_mut(), target, &pool,
            );
            assert!(c.max_abs_diff(&expect) < 1e-12 * k as f64, "call {i} ({target:?})");
        }
        assert_eq!(pool.spawned_workers(), 2, "reuse must not spawn more workers");
    }

    #[test]
    fn parallel_beta_scaling_large_c_is_exact() {
        // 300x300 crosses the parallel scale_c threshold.
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(64, 48, 16) };
        let mut rng = Pcg64::seed(7);
        let (m, n, k) = (300, 300, 9);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut c_seq = c.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), -0.5, &mut c_seq.view_mut(), &mut ws);
        let pool = WorkerPool::new(3);
        gemm_parallel(
            &cfg, &kernel, 1.0, a.view(), b.view(), -0.5, &mut c.view_mut(),
            ParallelLoop::G4, &pool,
        );
        assert_eq!(c.max_abs_diff(&c_seq), 0.0, "beta path must stay bitwise deterministic");
    }

    #[test]
    fn spawning_baseline_matches_blocked() {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(32, 24, 16) };
        let mut rng = Pcg64::seed(31);
        let (m, n, k) = (61, 53, 29);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut c_seq = c.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c_seq.view_mut(), &mut ws);
        let mut ws2 = Workspace::new();
        gemm_parallel_spawning(
            &cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c.view_mut(), 3, &mut ws2,
        );
        assert_eq!(c.max_abs_diff(&c_seq), 0.0);
    }

    #[test]
    fn fused_trailing_bitwise_matches_blocked_and_runs_panel_task() {
        // The fused driver must produce C bitwise identical to one full
        // gemm_blocked with the same config, for any column split —
        // including splits that do not align to nr (the non-divisible
        // block sizes of a real LU).
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(32, 24, 16) };
        let mut rng = Pcg64::seed(123);
        let (m, n, k) = (61, 53, 13);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let c0 = MatrixF64::random(m, n, &mut rng);
        let mut c_ref = c0.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, -1.0, a.view(), b.view(), 1.0, &mut c_ref.view_mut(), &mut ws);
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            for t_p in [1, 2] {
                for split in [0, 5, 24, n] {
                    let mut c = c0.clone();
                    let ran = AtomicU64::new(0);
                    gemm_fused_trailing(
                        &cfg, &kernel, -1.0, a.view(), b.view(), &mut c.view_mut(), split, t_p,
                        &|sub| {
                            assert!(sub.panel);
                            ran.fetch_add(1, Ordering::SeqCst);
                            sub.barrier();
                        },
                        &pool,
                    );
                    assert_eq!(
                        c.max_abs_diff(&c_ref),
                        0.0,
                        "fused x{threads} t_p={t_p} split={split} diverges from blocked"
                    );
                    let expect_ranks = if threads == 1 { 1 } else { t_p.min(threads - 1) as u64 };
                    assert_eq!(ran.load(Ordering::SeqCst), expect_ranks, "panel task rank count");
                }
            }
        }
    }

    #[test]
    fn fused_trailing_packed_slots_survive_mr_not_dividing_mc() {
        // Regression: the packed-A slot layout must size pc-block strides
        // as the SUM of per-ic-block padding. With mc=16, mr=12 each full
        // ic block pads 16 -> 24 rows, so three blocks of m=40 need
        // 24+24+12=60 packed rows — more than ceil(40/12)*12=48. The old
        // ceil-over-m stride aliased the last slot of one pc block onto
        // the next block's first slot. Two pc blocks (k=20 > kc=10) make
        // the aliasing observable as corrupted results.
        let mk = MicroKernel::new(12, 4);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(16, 12, 10) };
        let mut rng = Pcg64::seed(456);
        let (m, n, k) = (40, 36, 20);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let c0 = MatrixF64::random(m, n, &mut rng);
        let mut c_ref = c0.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c_ref.view_mut(), &mut ws);
        for threads in [1, 3] {
            let pool = WorkerPool::new(threads);
            let mut c = c0.clone();
            gemm_fused_trailing(
                &cfg, &kernel, 1.0, a.view(), b.view(), &mut c.view_mut(), 8, 1, &|_| {}, &pool,
            );
            assert_eq!(
                c.max_abs_diff(&c_ref),
                0.0,
                "x{threads}: packed-A slots must not alias when mr does not divide mc"
            );
        }
    }

    #[test]
    fn fused_ranges_cover_and_exclude_exactly() {
        // The multi-range driver must (a) produce bitwise-identical
        // results to one full gemm_blocked on every covered column, and
        // (b) leave excluded columns untouched — the deep-lookahead
        // pipeline relies on both.
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(32, 24, 16) };
        let mut rng = Pcg64::seed(789);
        let (m, n, k) = (61, 53, 13);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let c0 = MatrixF64::random(m, n, &mut rng);
        let mut c_ref = c0.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, -1.0, a.view(), b.view(), 1.0, &mut c_ref.view_mut(), &mut ws);
        // Covered: [5,12) ∪ [20,26) ∪ [26,53). Excluded: [0,5) ∪ [12,20).
        let head = [(5usize, 12usize), (20, 26)];
        let tail = (26usize, n);
        let covered =
            |j: usize| head.iter().any(|&(lo, hi)| (lo..hi).contains(&j)) || (tail.0..tail.1).contains(&j);
        for threads in [1, 2, 4] {
            let pool = WorkerPool::new(threads);
            for t_p in [1, 2] {
                let mut c = c0.clone();
                let ran = AtomicU64::new(0);
                gemm_fused_trailing_ranges(
                    &cfg, &kernel, -1.0, a.view(), b.view(), &mut c.view_mut(), &head, tail,
                    t_p, false,
                    &|sub| {
                        ran.fetch_add(1, Ordering::SeqCst);
                        sub.barrier();
                    },
                    &pool,
                );
                for j in 0..n {
                    for i in 0..m {
                        let expect = if covered(j) { c_ref[(i, j)] } else { c0[(i, j)] };
                        assert_eq!(
                            c[(i, j)].to_bits(),
                            expect.to_bits(),
                            "x{threads} t_p={t_p} C({i},{j}) wrong (covered={})",
                            covered(j)
                        );
                    }
                }
                let expect_ranks = if threads == 1 { 1 } else { t_p.min(threads - 1) as u64 };
                assert_eq!(ran.load(Ordering::SeqCst), expect_ranks);
            }
        }
    }

    #[test]
    fn fused_ranges_empty_head_packs_in_the_tail() {
        // All head ranges empty: the tail sweep must still see packed Ac
        // slots (regression for the pack-on-first-nonempty logic).
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(16, 12, 8) };
        let mut rng = Pcg64::seed(790);
        let (m, n, k) = (40, 30, 20);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let c0 = MatrixF64::random(m, n, &mut rng);
        let mut c_ref = c0.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c_ref.view_mut(), &mut ws);
        let pool = WorkerPool::new(3);
        let mut c = c0.clone();
        gemm_fused_trailing_ranges(
            &cfg, &kernel, 1.0, a.view(), b.view(), &mut c.view_mut(), &[(0, 0)],
            (0, n), 1, true, &|_| {}, &pool,
        );
        assert_eq!(c.max_abs_diff(&c_ref), 0.0, "tail-only sweep must still be exact");
    }

    #[test]
    fn fused_trailing_panel_task_sees_updated_panel_columns() {
        // The panel task must observe the phase-1 update already applied
        // to the first split columns (that is the whole point of the
        // pipeline ordering).
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(16, 12, 8) };
        let mut rng = Pcg64::seed(321);
        let (m, n, k, split) = (40, 30, 8, 7);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::zeros(m, n);
        let mut expect_panel = MatrixF64::zeros(m, split);
        gemm_reference(1.0, a.view(), b.sub(0, 0, k, split), 0.0, &mut expect_panel.view_mut());
        let cptr = SendPtr(c.view_mut().data.as_mut_ptr());
        let ldc = c.ld();
        let seen_err = Mutex::new(-1.0f64);
        let pool = WorkerPool::new(3);
        gemm_fused_trailing(
            &cfg, &kernel, 1.0, a.view(), b.view(), &mut c.view_mut(), split, 1,
            &|sub| {
                if sub.rank == 0 {
                    let mut err: f64 = 0.0;
                    for j in 0..split {
                        for i in 0..m {
                            // SAFETY: phase 1 is complete and the update
                            // team only touches columns >= split.
                            let v = unsafe { *cptr.ptr().add(j * ldc + i) };
                            err = err.max((v - expect_panel[(i, j)]).abs());
                        }
                    }
                    *seen_err.lock().unwrap() = err;
                }
            },
            &pool,
        );
        let err = *seen_err.lock().unwrap();
        assert!(err >= 0.0, "panel task did not run");
        assert!(err < 1e-12 * k as f64, "panel columns not updated before the task: {err}");
    }

    #[test]
    fn batch_members_bitwise_match_blocked_for_any_shares() {
        // Each member of a fused batch must come out bit-for-bit equal to
        // a solo gemm_blocked with the same config — for any team
        // partition, including 1-rank groups and uneven shares.
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let mut rng = Pcg64::seed(2024);
        let shapes = [(40usize, 24usize, 16usize), (17, 33, 9), (24, 40, 8)];
        let coeffs = [(1.0, 0.0), (-1.0, 1.0), (0.5, -2.0)];
        let mut inputs = Vec::new();
        for (i, &(m, n, k)) in shapes.iter().enumerate() {
            let a = MatrixF64::random(m, k, &mut rng);
            let b = MatrixF64::random(k, n, &mut rng);
            let c0 = MatrixF64::random(m, n, &mut rng);
            let ccp = Ccp::new(16 + 8 * i, 12, 8);
            inputs.push((a, b, c0, GemmConfig { mk, ccp }, coeffs[i]));
        }
        // Reference: solo gemm_blocked per member.
        let mut refs = Vec::new();
        let mut ws = Workspace::new();
        for (a, b, c0, cfg, (alpha, beta)) in &inputs {
            let mut c = c0.clone();
            gemm_blocked(cfg, &kernel, *alpha, a.view(), b.view(), *beta, &mut c.view_mut(), &mut ws);
            refs.push(c);
        }
        for (threads, shares) in
            [(1usize, vec![1usize, 1, 1]), (3, vec![1, 1, 1]), (4, vec![2, 1, 1]), (6, vec![1, 3, 2])]
        {
            let pool = WorkerPool::new(threads);
            let mut cs: Vec<MatrixF64> = inputs.iter().map(|(_, _, c0, _, _)| c0.clone()).collect();
            let mut members: Vec<BatchGemm<'_>> = Vec::new();
            for ((a, b, _, cfg, (alpha, beta)), c) in inputs.iter().zip(cs.iter_mut()) {
                members.push(BatchGemm {
                    cfg: *cfg,
                    kernel,
                    alpha: *alpha,
                    a: a.view(),
                    b: b.view(),
                    beta: *beta,
                    c: c.view_mut(),
                });
            }
            gemm_batch_parallel(&mut members, &shares, &pool);
            drop(members);
            for (i, (c, expect)) in cs.iter().zip(&refs).enumerate() {
                assert_eq!(
                    c.max_abs_diff(expect),
                    0.0,
                    "member {i} diverges at x{threads} shares {shares:?}"
                );
            }
        }
    }

    #[test]
    fn batch_degenerate_members_only_scale() {
        // alpha = 0 and k = 0 members must still apply beta, and empty
        // members must not wedge their group's barriers.
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(16, 12, 8) };
        let mut rng = Pcg64::seed(11);
        let a = MatrixF64::random(12, 8, &mut rng);
        let b = MatrixF64::random(8, 10, &mut rng);
        let c0 = MatrixF64::random(12, 10, &mut rng);
        let pool = WorkerPool::new(3);
        let mut c_zero_alpha = c0.clone();
        let mut c_live = c0.clone();
        let mut members = vec![
            BatchGemm {
                cfg,
                kernel,
                alpha: 0.0,
                a: a.view(),
                b: b.view(),
                beta: -0.5,
                c: c_zero_alpha.view_mut(),
            },
            BatchGemm {
                cfg,
                kernel,
                alpha: 1.0,
                a: a.view(),
                b: b.view(),
                beta: 1.0,
                c: c_live.view_mut(),
            },
        ];
        gemm_batch_parallel(&mut members, &[2, 1], &pool);
        drop(members);
        let mut expect_scaled = c0.clone();
        scale_c(-0.5, &mut expect_scaled.view_mut());
        assert_eq!(c_zero_alpha.max_abs_diff(&expect_scaled), 0.0);
        let mut expect_live = c0.clone();
        let mut ws = Workspace::new();
        gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut expect_live.view_mut(), &mut ws);
        assert_eq!(c_live.max_abs_diff(&expect_live), 0.0);
    }

    #[test]
    fn partition_covers_and_aligns() {
        for (total, parts, grain) in [(100, 4, 8), (7, 3, 8), (0, 2, 4), (64, 16, 6)] {
            let p = partition(total, parts, grain);
            assert_eq!(p.len(), parts);
            // Coverage without gaps/overlap.
            let mut pos = 0;
            for &(lo, hi) in &p {
                assert_eq!(lo, pos.min(total));
                assert!(hi >= lo);
                pos = hi;
            }
            assert_eq!(p.last().unwrap().1, total);
            // Alignment of interior boundaries.
            for &(lo, _) in &p {
                assert!(lo == total || lo % grain == 0);
            }
        }
    }

    #[test]
    fn partition_is_balanced() {
        // The seed's scheme gave 10 blocks / 4 threads -> 3,3,3,1 (and
        // worse: trailing empty chunks). Block counts must now differ by
        // at most one.
        for (total, parts, grain) in [(100, 4, 10), (70, 4, 7), (33, 5, 1), (160, 16, 10)] {
            let p = partition(total, parts, grain);
            let counts: Vec<usize> = p.iter().map(|&(lo, hi)| (hi - lo).div_ceil(grain)).collect();
            let max = *counts.iter().max().unwrap();
            let min = *counts.iter().min().unwrap();
            assert!(max - min <= 1, "unbalanced {counts:?} for total={total} grain={grain}");
        }
        // The motivating example: 10 blocks over 4 threads -> 3,3,2,2.
        let p = partition(100, 4, 10);
        let counts: Vec<usize> = p.iter().map(|&(lo, hi)| (hi - lo) / 10).collect();
        assert_eq!(counts, vec![3, 3, 2, 2]);
    }
}
