//! Algorithm-based fault tolerance (ABFT) for the blocked GEMM drivers
//! and the LAPACK panel factorizations: Huang–Abraham-style checksum
//! verification at macro-block granularity, with an optional one-round
//! recompute that repairs corruption in the packed operands.
//!
//! # Scheme
//!
//! The co-designed stack owns the packed-buffer format, which makes the
//! classical checksum trick nearly free: for every `(jc, pc, ic)`
//! macro-block the verified drivers carry
//!
//! - `acs[p] = Σ_i alpha*A[i, p]` — column sums of the packed-`Ac`
//!   block (alpha folded, exactly as packing folds it), plus the
//!   matching absolute sums `aabs`;
//! - `brs[p] = Σ_j B[p, j]` over the verified column range, plus
//!   absolute sums `babs`.
//!
//! Both are accumulated in `f64` **from the source operands** (for the
//! sequential driver they are additionally stored at the tail of the
//! packed buffers — see `pack_a_checked` / `pack_b_checked`), so the
//! reference sums stay clean no matter where a flip lands. After the
//! macro-kernel updates its C region the epilogue checks two
//! independent invariants against the pre-update column/row sums of C:
//!
//! - **column check** — `Δcol[j] ≈ Σ_p acs[p] * Bc[p, j]`, which
//!   catches corruption in the packed `Ac` and in the C tiles;
//! - **row check** — `Δrow[i] ≈ Σ_p Ac[i, p] * brs[p]`, which catches
//!   corruption in the packed `Bc` (invisible to the column check,
//!   because a flipped `Bc` entry perturbs both of its sides equally).
//!
//! Tolerances scale with the block dimensions and the absolute-value
//! sums (`eps * 4*(dim1 + dim2 + 16) * (magnitude + |C_pre| + 1)`), so
//! rounding never trips a false positive while an exponent-bit flip —
//! many orders of magnitude outside the bound — always does.
//!
//! In `Detect` mode a mismatch records a typed failure
//! ([`AbftStats::take_failure`] → `DlaError::DataCorrupt`). In
//! `Correct` mode the epilogue restores the saved C region, privately
//! repacks the block from the (clean) source views, recomputes once —
//! bitwise identical to the original schedule, because the verified
//! column range is `nr`-aligned — and re-verifies; only a second
//! mismatch fails typed.
//!
//! The factored panels of LU/Cholesky get their own detect-only checks
//! ([`verify_lu_panel`], [`verify_chol_panel`]): the pre-factorization
//! column sums are invariant under partial pivoting, so
//! `colsum_j(P·A) = Σ_t colsum(L[:,t]) · U[t, j]` verifies the panel
//! without knowing the pivot order.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::model::ccp::GemmConfig;
use crate::runtime::faults::FaultState;
use crate::util::elem::{DType, Elem};
use crate::util::matrix::{MatView, MatViewMut};

use super::blocked::{macro_kernel, scale_c, Workspace};
use super::microkernel::MicroKernelImpl;
use super::packing::{
    pack_a, pack_a_checked, pack_b, pack_b_checked, packed_a_len, packed_a_len_checked,
    packed_b_len, packed_b_len_checked,
};

/// How much checksum verification a GEMM/factorization request gets.
/// Resolved by the coordinator as pinned-config-beats-`DLA_VERIFY`; a
/// bare engine defaults to `Off` (the environment is deliberately *not*
/// consulted at engine construction, so armed CI legs cannot flip
/// unrelated engines into verified mode).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum VerifyPolicy {
    /// No verification: the exact pre-ABFT code paths.
    #[default]
    Off,
    /// Verify every macro-block; a mismatch fails typed
    /// (`DlaError::DataCorrupt`) without recomputing. Fault-free results
    /// are bitwise identical to `Off`.
    Detect,
    /// Verify, and on a mismatch restore + recompute the block once from
    /// the source operands before failing typed.
    Correct,
}

impl VerifyPolicy {
    /// Parse a `DLA_VERIFY` value; `None` for empty/unknown spellings
    /// (which must fail toward "no verification", like the fault
    /// grammar fails toward "no fault").
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" => Some(VerifyPolicy::Off),
            "detect" | "on" | "1" => Some(VerifyPolicy::Detect),
            "correct" => Some(VerifyPolicy::Correct),
            _ => None,
        }
    }

    /// The `DLA_VERIFY` environment policy, if set and well-formed.
    pub fn from_env() -> Option<Self> {
        Self::parse(std::env::var("DLA_VERIFY").ok()?.as_str())
    }

    /// True when verification work happens at all.
    pub fn enabled(&self) -> bool {
        !matches!(self, VerifyPolicy::Off)
    }

    pub const fn name(&self) -> &'static str {
        match self {
            VerifyPolicy::Off => "off",
            VerifyPolicy::Detect => "detect",
            VerifyPolicy::Correct => "correct",
        }
    }
}

/// Which verified stage detected a corruption (the `phase` of
/// `DlaError::DataCorrupt`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AbftPhase {
    /// A GEMM macro-block epilogue check.
    Gemm,
    /// The post-`getf2` LU panel check.
    LuPanel,
    /// The post-`potf2` Cholesky panel check.
    CholPanel,
}

impl AbftPhase {
    pub const fn as_str(self) -> &'static str {
        match self {
            AbftPhase::Gemm => "gemm",
            AbftPhase::LuPanel => "lu-panel",
            AbftPhase::CholPanel => "chol-panel",
        }
    }

    const fn code(self) -> u64 {
        match self {
            AbftPhase::Gemm => 1,
            AbftPhase::LuPanel => 2,
            AbftPhase::CholPanel => 3,
        }
    }

    fn from_code(c: u64) -> Self {
        match c {
            2 => AbftPhase::LuPanel,
            3 => AbftPhase::CholPanel,
            _ => AbftPhase::Gemm,
        }
    }
}

/// A point-in-time copy of the ABFT counters (what the coordinator
/// merges into its `AbftMetrics`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AbftCounters {
    /// Verified GEMM dispatches (one per engine-level call, not per
    /// block).
    pub verified_epochs: u64,
    /// Macro-block regions that ran the checksum epilogue.
    pub verified_blocks: u64,
    /// Checksum mismatches observed (before any recompute).
    pub detected: u64,
    /// Mismatches repaired by the one-round recompute.
    pub corrected: u64,
    /// Mismatches that survived the recompute (correct mode only).
    pub uncorrectable: u64,
    /// Cumulative time spent computing/verifying checksums, in ns
    /// (summed across ranks, so it over-counts wall clock on purpose —
    /// it is the *work* overhead the ablation measures).
    pub overhead_ns: u64,
}

/// Shared, thread-safe ABFT accounting for one engine: counters plus a
/// first-writer-wins record of the failure that should surface as the
/// request's typed error. Ranks record concurrently; the driver thread
/// claims the failure after the pool job completes.
#[derive(Debug, Default)]
pub struct AbftStats {
    verified_epochs: AtomicU64,
    verified_blocks: AtomicU64,
    detected: AtomicU64,
    corrected: AtomicU64,
    uncorrectable: AtomicU64,
    overhead_ns: AtomicU64,
    failure_set: AtomicBool,
    failure_claimed: AtomicBool,
    failure_phase: AtomicU64,
    failure_row: AtomicU64,
    failure_col: AtomicU64,
}

impl AbftStats {
    pub fn new() -> Self {
        Self::default()
    }

    /// Count one verified engine-level dispatch.
    pub fn begin_epoch(&self) {
        self.verified_epochs.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn block_done(&self) {
        self.verified_blocks.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn detection(&self) {
        self.detected.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn correction(&self) {
        self.corrected.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn uncorrectable(&self) {
        self.uncorrectable.fetch_add(1, Ordering::Relaxed);
    }

    pub(crate) fn add_overhead(&self, d: Duration) {
        self.overhead_ns.fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }

    /// Record a corruption that must surface as a typed error. First
    /// writer wins (concurrent ranks may detect the same epoch's flip);
    /// later failures are still counted, just not re-recorded until the
    /// pending one is claimed.
    pub fn record_failure(&self, phase: AbftPhase, tile: (usize, usize)) {
        if self
            .failure_set
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            self.failure_phase.store(phase.code(), Ordering::Relaxed);
            self.failure_row.store(tile.0 as u64, Ordering::Relaxed);
            self.failure_col.store(tile.1 as u64, Ordering::Relaxed);
            self.failure_claimed.store(false, Ordering::Release);
        }
    }

    /// Claim the pending failure, if any: returns `(phase, tile)` once
    /// per recorded corruption. Drivers call this after every verified
    /// compute call to convert out-of-band rank-side detection into the
    /// request's typed error.
    pub fn take_failure(&self) -> Option<(AbftPhase, (usize, usize))> {
        if !self.failure_set.load(Ordering::Acquire) {
            return None;
        }
        if self
            .failure_claimed
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_err()
        {
            return None;
        }
        let phase = AbftPhase::from_code(self.failure_phase.load(Ordering::Relaxed));
        let tile = (
            self.failure_row.load(Ordering::Relaxed) as usize,
            self.failure_col.load(Ordering::Relaxed) as usize,
        );
        self.failure_set.store(false, Ordering::Release);
        Some((phase, tile))
    }

    /// Snapshot the counters.
    pub fn snapshot(&self) -> AbftCounters {
        AbftCounters {
            verified_epochs: self.verified_epochs.load(Ordering::Relaxed),
            verified_blocks: self.verified_blocks.load(Ordering::Relaxed),
            detected: self.detected.load(Ordering::Relaxed),
            corrected: self.corrected.load(Ordering::Relaxed),
            uncorrectable: self.uncorrectable.load(Ordering::Relaxed),
            overhead_ns: self.overhead_ns.load(Ordering::Relaxed),
        }
    }
}

/// The per-dispatch verification context threaded (as
/// `Option<&AbftCtx>`) through the blocked/parallel drivers. `Sync`:
/// shared by reference across every rank of a pool job.
pub(crate) struct AbftCtx<'a> {
    pub policy: VerifyPolicy,
    pub stats: &'a AbftStats,
    /// The armed fault plan, for the `flip@R:E[:bit]` drill; `None`
    /// outside chaos runs (the zero-cost-when-unarmed contract).
    pub faults: Option<&'a FaultState>,
    /// This dispatch's 1-based verified epoch (the `flip@` clock).
    pub epoch: u64,
}

impl AbftCtx<'_> {
    /// Injection hook: flip one bit of `packed` (the calling rank's own
    /// just-packed, pre-barrier share) if the armed plan has an
    /// unconsumed `flip@` shot for this (rank, epoch). The first element
    /// of a rank's share is always a live (never padding) row, so a
    /// delivered flip is always a *consequential* corruption.
    pub(crate) fn maybe_flip<E: Elem>(&self, rank: usize, packed: &mut [E]) {
        if let Some(f) = self.faults {
            if !packed.is_empty() {
                if let Some(bit) = f.take_flip(rank, self.epoch) {
                    flip_bit_in_slice(packed, 0, bit);
                }
            }
        }
    }
}

/// XOR bit `bit` (modulo the element width) of `buf[idx]`.
pub(crate) fn flip_bit_in_slice<E: Elem>(buf: &mut [E], idx: usize, bit: u32) {
    assert!(idx < buf.len(), "flip target out of bounds");
    let bits = (std::mem::size_of::<E>() * 8) as u32;
    let bit = bit % bits;
    // SAFETY: idx is in bounds (asserted) and every Elem is a plain
    // byte-flippable float with no invalid bit patterns.
    unsafe {
        let p = (buf.as_mut_ptr().add(idx) as *mut u8).add((bit / 8) as usize);
        *p ^= 1u8 << (bit % 8);
    }
}

/// Machine epsilon of the element type, as the f64 the checks accumulate
/// in.
fn eps_for(dt: DType) -> f64 {
    match dt {
        DType::F32 => f32::EPSILON as f64,
        DType::F64 => f64::EPSILON,
    }
}

/// The reference checksums for one verified macro-block region: A-block
/// column sums (alpha-folded, f64-accumulated) and B-block row sums over
/// the verified column range, each with the matching absolute sums that
/// scale the tolerance.
pub(crate) struct CheckSums {
    pub acs: Vec<f64>,
    pub aabs: Vec<f64>,
    pub brs: Vec<f64>,
    pub babs: Vec<f64>,
}

impl CheckSums {
    /// Compute from the clean source views: `a_src` is the `mc_eff x
    /// kc_eff` A block, `b_cols` the `kc_eff x w` verified slice of the
    /// B block.
    pub(crate) fn from_views<E: Elem>(a_src: MatView<'_, E>, alpha: E, b_cols: MatView<'_, E>) -> Self {
        let kc_eff = a_src.cols;
        debug_assert_eq!(b_cols.rows, kc_eff);
        let al = alpha.to_f64();
        let mut acs = vec![0.0f64; kc_eff];
        let mut aabs = vec![0.0f64; kc_eff];
        for p in 0..kc_eff {
            let col = &a_src.data[p * a_src.ld..p * a_src.ld + a_src.rows];
            let mut s = 0.0;
            let mut sa = 0.0;
            for &v in col {
                let v = al * v.to_f64();
                s += v;
                sa += v.abs();
            }
            acs[p] = s;
            aabs[p] = sa;
        }
        let mut brs = vec![0.0f64; kc_eff];
        let mut babs = vec![0.0f64; kc_eff];
        for j in 0..b_cols.cols {
            for p in 0..kc_eff {
                let v = b_cols.at(p, j).to_f64();
                brs[p] += v;
                babs[p] += v.abs();
            }
        }
        Self { acs, aabs, brs, babs }
    }

    /// Timed wrapper: the checksum pass is the overhead the ablation
    /// measures.
    pub(crate) fn from_views_timed<E: Elem>(
        a_src: MatView<'_, E>,
        alpha: E,
        b_cols: MatView<'_, E>,
        stats: &AbftStats,
    ) -> Self {
        let t0 = Instant::now();
        let s = Self::from_views(a_src, alpha, b_cols);
        stats.add_overhead(t0.elapsed());
        s
    }

    /// Read the checksums a `pack_a_checked` / `pack_b_checked` pair
    /// appended at the tails of the packed buffers (the sequential
    /// driver's layout: `[sums; kc_eff][abs sums; kc_eff]` right after
    /// the packed micro-panels).
    pub(crate) fn from_tails<E: Elem>(a_tail: &[E], b_tail: &[E], kc_eff: usize) -> Self {
        let grab = |t: &[E], off: usize| -> Vec<f64> {
            t[off..off + kc_eff].iter().map(|v| v.to_f64()).collect()
        };
        Self {
            acs: grab(a_tail, 0),
            aabs: grab(a_tail, kc_eff),
            brs: grab(b_tail, 0),
            babs: grab(b_tail, kc_eff),
        }
    }
}

/// Post-update verification of one C region (`mc_eff` rows x `w` cols
/// starting at packed-B column `bcol0`): both the column and the row
/// invariant, with NaN-poisoned sums counting as corrupt.
///
/// # Safety
/// `creg` must point at the first verified column of a valid column-major
/// region of at least `mc_eff x w` elements with stride `ldc`.
#[allow(clippy::too_many_arguments)]
unsafe fn region_checks<E: Elem>(
    kc_eff: usize,
    mc_eff: usize,
    w: usize,
    a_buf: &[E],
    b_buf: &[E],
    bcol0: usize,
    creg: *const E,
    ldc: usize,
    pre_col: &[f64],
    pre_col_abs: &[f64],
    pre_row: &[f64],
    pre_row_abs: &[f64],
    sums: &CheckSums,
    mr: usize,
    nr: usize,
) -> bool {
    let eps = eps_for(E::DTYPE);
    let mut post_col = vec![0.0f64; w];
    let mut post_row = vec![0.0f64; mc_eff];
    for j in 0..w {
        for i in 0..mc_eff {
            let v = (*creg.add(j * ldc + i)).to_f64();
            post_col[j] += v;
            post_row[i] += v;
        }
    }
    // Column check (catches packed-A and C corruption): the update each
    // column received must match the checksum product acs · Bc[:, j].
    let kcol = 4.0 * (mc_eff + kc_eff + 16) as f64;
    for j in 0..w {
        let col = bcol0 + j;
        let base = (col / nr) * nr * kc_eff + col % nr;
        let mut e = 0.0f64;
        let mut t = 0.0f64;
        for p in 0..kc_eff {
            let bv = b_buf[base + p * nr].to_f64();
            e += sums.acs[p] * bv;
            t += sums.aabs[p] * bv.abs();
        }
        let tol = eps * kcol * (t + pre_col_abs[j] + 1.0);
        let delta = post_col[j] - pre_col[j] - e;
        // `!(x <= tol)` (not `x > tol`) so a NaN delta reads as corrupt.
        if !(delta.abs() <= tol) {
            return false;
        }
    }
    // Row check (catches packed-B corruption, which perturbs both sides
    // of the column check equally): Δrow[i] ≈ Ac[i, :] · brs.
    let krow = 4.0 * (w + kc_eff + 16) as f64;
    for i in 0..mc_eff {
        let base = (i / mr) * mr * kc_eff + i % mr;
        let mut e = 0.0f64;
        let mut u = 0.0f64;
        for p in 0..kc_eff {
            let av = a_buf[base + p * mr].to_f64();
            e += av * sums.brs[p];
            u += av.abs() * sums.babs[p];
        }
        let tol = eps * krow * (u + pre_row_abs[i] + 1.0);
        let delta = post_row[i] - pre_row[i] - e;
        if !(delta.abs() <= tol) {
            return false;
        }
    }
    true
}

/// Run [`macro_kernel`] on one packed block with the ABFT epilogue:
/// pre-sums, kernel, checksum verification, and — in `Correct` mode — a
/// single restore-repack-recompute round before recording a typed
/// failure. Fault-free results are bitwise identical to a bare
/// `macro_kernel` call (the kernel invocation itself is untouched; the
/// recompute path only runs after a detected corruption).
///
/// `a_src`/`b_src` are the *source* views the packed block was built
/// from (`mc_eff x kc_eff` and `kc_eff x nc_eff`); `tile` is the global
/// (row, col) origin of the block, used for error reporting.
///
/// # Safety
/// Same contract as [`macro_kernel`].
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn verified_macro_kernel<E: Elem>(
    kernel: &MicroKernelImpl<E>,
    kc_eff: usize,
    mc_eff: usize,
    nc_eff: usize,
    a_buf: &[E],
    b_buf: &[E],
    c_ptr: *mut E,
    ldc: usize,
    jr_range: (usize, usize),
    alpha: E,
    a_src: MatView<'_, E>,
    b_src: MatView<'_, E>,
    sums: &CheckSums,
    ctx: &AbftCtx<'_>,
    tile: (usize, usize),
) {
    let (lo, hi) = jr_range;
    if lo >= hi || mc_eff == 0 || kc_eff == 0 {
        macro_kernel(kernel, kc_eff, mc_eff, nc_eff, a_buf, b_buf, c_ptr, ldc, jr_range);
        return;
    }
    let w = hi - lo;
    let t0 = Instant::now();
    let mut pre_col = vec![0.0f64; w];
    let mut pre_col_abs = vec![0.0f64; w];
    let mut pre_row = vec![0.0f64; mc_eff];
    let mut pre_row_abs = vec![0.0f64; mc_eff];
    for j in 0..w {
        for i in 0..mc_eff {
            let v = (*c_ptr.add((lo + j) * ldc + i)).to_f64();
            pre_col[j] += v;
            pre_col_abs[j] += v.abs();
            pre_row[i] += v;
            pre_row_abs[i] += v.abs();
        }
    }
    // Correct mode keeps a private copy of the region so a detected
    // corruption can be rolled back and recomputed.
    let saved: Option<Vec<E>> = if ctx.policy == VerifyPolicy::Correct {
        let mut s = Vec::with_capacity(mc_eff * w);
        for j in 0..w {
            for i in 0..mc_eff {
                s.push(*c_ptr.add((lo + j) * ldc + i));
            }
        }
        Some(s)
    } else {
        None
    };
    ctx.stats.add_overhead(t0.elapsed());

    macro_kernel(kernel, kc_eff, mc_eff, nc_eff, a_buf, b_buf, c_ptr, ldc, jr_range);

    let t1 = Instant::now();
    let (mr, nr) = (kernel.spec.mr, kernel.spec.nr);
    let clean = region_checks(
        kc_eff,
        mc_eff,
        w,
        a_buf,
        b_buf,
        lo,
        c_ptr.add(lo * ldc) as *const E,
        ldc,
        &pre_col,
        &pre_col_abs,
        &pre_row,
        &pre_row_abs,
        sums,
        mr,
        nr,
    );
    ctx.stats.block_done();
    if clean {
        ctx.stats.add_overhead(t1.elapsed());
        return;
    }
    ctx.stats.detection();
    let tile = (tile.0, tile.1 + lo);
    let Some(saved) = saved else {
        // Detect mode: surface immediately, leaving the (corrupt)
        // region in place — the request will fail typed before the
        // result is handed back.
        ctx.stats.record_failure(AbftPhase::Gemm, tile);
        ctx.stats.add_overhead(t1.elapsed());
        return;
    };
    // Correct mode: roll back the region, privately repack this rank's
    // operands from the clean sources and recompute once. `lo` is
    // nr-aligned (the jr partition grain), so the standalone repack of
    // columns [lo, hi) is bitwise identical to the corresponding slice
    // of the shared packed buffer — and therefore so is the recomputed
    // region when the sources are clean.
    for j in 0..w {
        for i in 0..mc_eff {
            *c_ptr.add((lo + j) * ldc + i) = saved[j * mc_eff + i];
        }
    }
    let mut a2 = vec![E::ZERO; packed_a_len(mc_eff, kc_eff, mr)];
    pack_a(a_src, &mut a2, mr, alpha);
    let mut b2 = vec![E::ZERO; packed_b_len(kc_eff, w, nr)];
    pack_b(b_src.sub(0, lo, kc_eff, w), &mut b2, nr);
    macro_kernel(kernel, kc_eff, mc_eff, w, &a2, &b2, c_ptr.add(lo * ldc), ldc, (0, w));
    let clean2 = region_checks(
        kc_eff,
        mc_eff,
        w,
        &a2,
        &b2,
        0,
        c_ptr.add(lo * ldc) as *const E,
        ldc,
        &pre_col,
        &pre_col_abs,
        &pre_row,
        &pre_row_abs,
        sums,
        mr,
        nr,
    );
    if clean2 {
        ctx.stats.correction();
    } else {
        ctx.stats.uncorrectable();
        ctx.stats.record_failure(AbftPhase::Gemm, tile);
    }
    ctx.stats.add_overhead(t1.elapsed());
}

/// The sequential verified blocked GEMM: the exact `gemm_blocked` loop
/// nest with checksummed packing (`pack_a_checked` / `pack_b_checked`
/// append the reference sums at the buffer tails) and the verified
/// macro-kernel epilogue. Fault-free results are bitwise identical to
/// `gemm_blocked` with the same configuration.
#[allow(clippy::too_many_arguments)]
pub(crate) fn gemm_blocked_abft<E: Elem>(
    cfg: &GemmConfig,
    kernel: &MicroKernelImpl<E>,
    alpha: E,
    a: MatView<'_, E>,
    b: MatView<'_, E>,
    beta: E,
    c: &mut MatViewMut<'_, E>,
    ws: &mut Workspace,
    ctx: &AbftCtx<'_>,
) {
    assert_eq!(kernel.spec, cfg.mk, "kernel/config shape mismatch");
    assert_eq!(a.cols, b.rows, "inner dimension mismatch");
    assert_eq!(c.rows, a.rows, "C row mismatch");
    assert_eq!(c.cols, b.cols, "C col mismatch");
    let (m, n, k) = (a.rows, b.cols, a.cols);
    scale_c(beta, c);
    if m == 0 || n == 0 || k == 0 || alpha == E::ZERO {
        return;
    }
    let ccp = cfg.ccp.clamp_to(crate::model::GemmDims::new(m, n, k));
    let (mc, nc, kc) = (ccp.mc, ccp.nc, ccp.kc);
    let (mr, nr) = (cfg.mk.mr, cfg.mk.nr);
    let a_need = packed_a_len_checked(mc, kc, mr);
    let b_need = packed_b_len_checked(kc, nc, nr);
    let (a_buf, b_buf) = ws.bufs_mut::<E>(a_need, b_need);

    let mut jc = 0; // Loop G1
    while jc < n {
        let nc_eff = nc.min(n - jc);
        let mut pc = 0; // Loop G2
        while pc < k {
            let kc_eff = kc.min(k - pc);
            let b_src = b.sub(pc, jc, kc_eff, nc_eff);
            let tb = Instant::now();
            pack_b_checked(b_src, b_buf, nr);
            ctx.stats.add_overhead(tb.elapsed());
            let b_base = packed_b_len(kc_eff, nc_eff, nr);
            let mut ic = 0; // Loop G3
            while ic < m {
                let mc_eff = mc.min(m - ic);
                let a_src = a.sub(ic, pc, mc_eff, kc_eff);
                let ta = Instant::now();
                pack_a_checked(a_src, a_buf, mr, alpha);
                ctx.stats.add_overhead(ta.elapsed());
                let a_base = packed_a_len(mc_eff, kc_eff, mr);
                // The injection point: the checksums above were
                // accumulated from the source view, so a flip here
                // corrupts only the packed data — never the reference.
                ctx.maybe_flip(0, &mut a_buf[..a_base]);
                let sums = CheckSums::from_tails(
                    &a_buf[a_base..a_base + 2 * kc_eff],
                    &b_buf[b_base..b_base + 2 * kc_eff],
                    kc_eff,
                );
                let c_ptr = unsafe { c.data.as_mut_ptr().add(jc * c.ld + ic) };
                unsafe {
                    verified_macro_kernel(
                        kernel,
                        kc_eff,
                        mc_eff,
                        nc_eff,
                        &a_buf[..a_base],
                        &b_buf[..b_base],
                        c_ptr,
                        c.ld,
                        (0, nc_eff),
                        alpha,
                        a_src,
                        b_src,
                        &sums,
                        ctx,
                        (ic, jc),
                    );
                }
                ic += mc;
            }
            pc += kc;
        }
        jc += nc;
    }
}

/// Full-column sums (value + absolute) of a panel view, f64-accumulated:
/// the pre-factorization reference for [`verify_lu_panel`].
pub(crate) fn panel_colsums<E: Elem>(p: MatView<'_, E>) -> (Vec<f64>, Vec<f64>) {
    let mut s = vec![0.0f64; p.cols];
    let mut sa = vec![0.0f64; p.cols];
    for j in 0..p.cols {
        let col = &p.data[j * p.ld..j * p.ld + p.rows];
        for &v in col {
            let v = v.to_f64();
            s[j] += v;
            sa[j] += v.abs();
        }
    }
    (s, sa)
}

/// Lower-region column sums (`i >= j`) of a panel view: the
/// pre-factorization reference for [`verify_chol_panel`] (only the lower
/// triangle of the Cholesky panel is factored state; the strict upper
/// part still holds the untouched symmetric source).
pub(crate) fn lower_panel_colsums<E: Elem>(p: MatView<'_, E>) -> (Vec<f64>, Vec<f64>) {
    let mut s = vec![0.0f64; p.cols];
    let mut sa = vec![0.0f64; p.cols];
    for j in 0..p.cols {
        for i in j..p.rows {
            let v = p.at(i, j).to_f64();
            s[j] += v;
            sa[j] += v.abs();
        }
    }
    (s, sa)
}

/// Verify a just-factored LU panel (`r x b`, unit-lower `L` below the
/// diagonal, `U` on and above) against its pre-factorization column sums.
/// Partial pivoting permutes rows, and column sums are permutation
/// invariant, so `pre[j] = colsum_j(P·A) = Σ_{t<=j} w[t]·U[t,j]` with
/// `w[t] = 1 + Σ_{i>t} L[i,t]` — no pivot bookkeeping needed. Detect
/// only: panels are recomputed nowhere (the correction scope is the GEMM
/// packed operands).
pub(crate) fn verify_lu_panel<E: Elem>(panel: MatView<'_, E>, pre: &[f64], pre_abs: &[f64]) -> bool {
    let (r, b) = (panel.rows, panel.cols);
    debug_assert_eq!(pre.len(), b);
    let eps = eps_for(E::DTYPE);
    let tmax = r.min(b);
    let mut w = vec![0.0f64; tmax];
    let mut wabs = vec![0.0f64; tmax];
    for (t, (wt, wat)) in w.iter_mut().zip(wabs.iter_mut()).enumerate() {
        let mut s = 1.0f64; // the implicit unit diagonal of L
        let mut sa = 1.0f64;
        for i in t + 1..r {
            let v = panel.at(i, t).to_f64();
            s += v;
            sa += v.abs();
        }
        *wt = s;
        *wat = sa;
    }
    let scale = eps * 4.0 * (r + b + 16) as f64;
    for j in 0..b {
        let mut check = 0.0f64;
        let mut mag = 0.0f64;
        for t in 0..(j + 1).min(tmax) {
            let u = panel.at(t, j).to_f64();
            check += w[t] * u;
            mag += wabs[t] * u.abs();
        }
        let tol = scale * (mag + pre_abs[j] + 1.0);
        let delta = pre[j] - check;
        if !(delta.abs() <= tol) {
            return false;
        }
    }
    true
}

/// Verify a just-factored Cholesky panel (`r x b` lower-trapezoidal `L`:
/// `L11` in the top `b x b` lower triangle, `L21` below) against the
/// lower-region column sums of the pre-factorization panel:
/// `pre[j] = Σ_{i>=j} (L·Lᵀ)[i,j] = Σ_{t<=j} L[j,t] · Σ_{i>=j} L[i,t]`.
/// The strict upper triangle is never read (it holds unfactored source
/// data). Detect only.
pub(crate) fn verify_chol_panel<E: Elem>(
    panel: MatView<'_, E>,
    pre: &[f64],
    pre_abs: &[f64],
) -> bool {
    let (r, b) = (panel.rows, panel.cols);
    debug_assert_eq!(pre.len(), b);
    let eps = eps_for(E::DTYPE);
    let tmax = r.min(b);
    let mut post = vec![0.0f64; b];
    let mut mag = vec![0.0f64; b];
    let mut sfx = vec![0.0f64; b];
    let mut sfxa = vec![0.0f64; b];
    for t in 0..tmax {
        // Suffix sums over the column: sfx[j] = Σ_{i>=j} L[i,t] for the
        // j in [t, b) that consume them, via one exact backward pass.
        let mut s = 0.0f64;
        let mut sa = 0.0f64;
        for i in (t..r).rev() {
            let v = panel.at(i, t).to_f64();
            s += v;
            sa += v.abs();
            if i < b {
                sfx[i] = s;
                sfxa[i] = sa;
            }
        }
        for j in t..b {
            let l = panel.at(j, t).to_f64();
            post[j] += l * sfx[j];
            mag[j] += l.abs() * sfxa[j];
        }
    }
    let scale = eps * 4.0 * (r + b + 16) as f64;
    for j in 0..b {
        let tol = scale * (mag[j] + pre_abs[j] + 1.0);
        let delta = pre[j] - post[j];
        if !(delta.abs() <= tol) {
            return false;
        }
    }
    true
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::gemm::microkernel::for_shape;
    use crate::model::{Ccp, MicroKernel};
    use crate::runtime::faults::FaultPlan;
    use crate::util::{MatrixF64, Pcg64};

    #[test]
    fn policy_parse() {
        assert_eq!(VerifyPolicy::parse("off"), Some(VerifyPolicy::Off));
        assert_eq!(VerifyPolicy::parse("detect"), Some(VerifyPolicy::Detect));
        assert_eq!(VerifyPolicy::parse("CORRECT"), Some(VerifyPolicy::Correct));
        assert_eq!(VerifyPolicy::parse(""), None);
        assert_eq!(VerifyPolicy::parse("wat"), None);
        assert!(!VerifyPolicy::Off.enabled());
        assert!(VerifyPolicy::Detect.enabled());
        assert_eq!(VerifyPolicy::Correct.name(), "correct");
    }

    #[test]
    fn failure_record_is_first_writer_wins_and_claimed_once() {
        let st = AbftStats::new();
        assert_eq!(st.take_failure(), None);
        st.record_failure(AbftPhase::Gemm, (12, 34));
        st.record_failure(AbftPhase::LuPanel, (1, 2)); // loses the race
        assert_eq!(st.take_failure(), Some((AbftPhase::Gemm, (12, 34))));
        assert_eq!(st.take_failure(), None);
        // The slot is free again after the claim.
        st.record_failure(AbftPhase::CholPanel, (5, 6));
        assert_eq!(st.take_failure(), Some((AbftPhase::CholPanel, (5, 6))));
    }

    #[test]
    fn bit_flip_is_loud_and_involutive() {
        let mut buf = vec![1.0f64, 2.0, 3.0];
        flip_bit_in_slice(&mut buf, 1, 62);
        assert_ne!(buf[1], 2.0);
        assert!(buf[1].abs() > 1e10 || buf[1].abs() < 1e-10 || !buf[1].is_finite());
        flip_bit_in_slice(&mut buf, 1, 62);
        assert_eq!(buf, vec![1.0, 2.0, 3.0]);
        // f32: the bit index wraps into the element width.
        let mut b32 = vec![1.0f32; 2];
        flip_bit_in_slice(&mut b32, 0, 62); // -> bit 30: f32 exponent
        assert_ne!(b32[0], 1.0f32);
    }

    fn ctx_on<'a>(
        stats: &'a AbftStats,
        faults: Option<&'a FaultState>,
        policy: VerifyPolicy,
    ) -> AbftCtx<'a> {
        AbftCtx { policy, stats, faults, epoch: 1 }
    }

    #[test]
    fn sequential_detect_catches_flip_and_correct_repairs_it() {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(32, 24, 16) };
        let mut rng = Pcg64::seed(42);
        let a = MatrixF64::random(50, 40, &mut rng);
        let b = MatrixF64::random(40, 30, &mut rng);
        let c0 = MatrixF64::random(50, 30, &mut rng);

        // Clean baseline.
        let mut c_ref = c0.clone();
        let mut ws = Workspace::new();
        crate::gemm::gemm_blocked(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c_ref.view_mut(), &mut ws);

        // Detect + armed flip on rank 0: typed failure, no silent wrong
        // answer escapes.
        let stats = AbftStats::new();
        let faults = FaultState::new(FaultPlan::parse("flip@0:1").unwrap());
        assert_eq!(faults.begin_verified_epoch(), 1);
        let ctx = ctx_on(&stats, Some(&faults), VerifyPolicy::Detect);
        let mut c1 = c0.clone();
        let mut ws1 = Workspace::new();
        gemm_blocked_abft(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c1.view_mut(), &mut ws1, &ctx);
        assert_eq!(faults.injected().flips, 1);
        let snap = stats.snapshot();
        assert_eq!(snap.detected, 1, "the flip must be detected");
        assert!(stats.take_failure().is_some());

        // Correct mode repairs the same flip bitwise.
        let stats2 = AbftStats::new();
        let faults2 = FaultState::new(FaultPlan::parse("flip@0:1").unwrap());
        faults2.begin_verified_epoch();
        let ctx2 = ctx_on(&stats2, Some(&faults2), VerifyPolicy::Correct);
        let mut c2 = c0.clone();
        let mut ws2 = Workspace::new();
        gemm_blocked_abft(&cfg, &kernel, 1.0, a.view(), b.view(), 1.0, &mut c2.view_mut(), &mut ws2, &ctx2);
        assert_eq!(faults2.injected().flips, 1);
        let snap2 = stats2.snapshot();
        assert_eq!(snap2.detected, 1);
        assert_eq!(snap2.corrected, 1);
        assert_eq!(snap2.uncorrectable, 0);
        assert_eq!(stats2.take_failure(), None);
        assert_eq!(c2.max_abs_diff(&c_ref), 0.0, "corrected result must be bitwise clean");
    }

    #[test]
    fn verified_fault_free_is_bitwise_identical_and_flags_nothing() {
        let mk = MicroKernel::new(8, 6);
        let kernel = for_shape(mk).unwrap();
        let cfg = GemmConfig { mk, ccp: Ccp::new(37, 29, 13) };
        let mut rng = Pcg64::seed(7);
        let a = MatrixF64::random(61, 47, &mut rng);
        let b = MatrixF64::random(47, 53, &mut rng);
        let c0 = MatrixF64::random(61, 53, &mut rng);
        let mut c_ref = c0.clone();
        let mut ws = Workspace::new();
        crate::gemm::gemm_blocked(&cfg, &kernel, -0.5, a.view(), b.view(), 2.0, &mut c_ref.view_mut(), &mut ws);
        let stats = AbftStats::new();
        let ctx = ctx_on(&stats, None, VerifyPolicy::Detect);
        let mut c1 = c0.clone();
        let mut ws1 = Workspace::new();
        gemm_blocked_abft(&cfg, &kernel, -0.5, a.view(), b.view(), 2.0, &mut c1.view_mut(), &mut ws1, &ctx);
        assert_eq!(c1.max_abs_diff(&c_ref), 0.0);
        let snap = stats.snapshot();
        assert_eq!(snap.detected, 0);
        assert!(snap.verified_blocks > 0);
        assert_eq!(stats.take_failure(), None);
    }

    #[test]
    fn lu_panel_check_accepts_clean_and_rejects_corrupt() {
        // Build a known L·U, factor "result" = combined panel storage.
        let (r, b) = (10, 4);
        let mut rng = Pcg64::seed(11);
        let mut lower = MatrixF64::zeros(r, b);
        let mut upper = MatrixF64::zeros(b, b);
        for t in 0..b {
            for i in t + 1..r {
                lower[(i, t)] = (rng.next_f64() - 0.5) * 0.9;
            }
            for j in t..b {
                upper[(t, j)] = rng.next_f64() + 0.5;
            }
        }
        // A = L·U with an explicit unit-diagonal L.
        let mut lmat = lower.clone();
        for t in 0..b {
            lmat[(t, t)] = 1.0;
        }
        let mut a = MatrixF64::zeros(r, b);
        for j in 0..b {
            for i in 0..r {
                let mut s = 0.0;
                for t in 0..b {
                    s += lmat[(i, t)] * upper[(t, j)];
                }
                a[(i, j)] = s;
            }
        }
        let (pre, pre_abs) = panel_colsums(a.view());
        // The factored panel: L below the diagonal, U on/above.
        let mut panel = MatrixF64::zeros(r, b);
        for j in 0..b {
            for i in 0..r {
                panel[(i, j)] = if i > j { lower[(i, j)] } else { upper[(i, j)] };
            }
        }
        assert!(verify_lu_panel(panel.view(), &pre, &pre_abs));
        let mut bad = panel.clone();
        bad[(2, 1)] += 1.0;
        assert!(!verify_lu_panel(bad.view(), &pre, &pre_abs));
    }

    #[test]
    fn chol_panel_check_accepts_clean_and_rejects_corrupt() {
        let (r, b) = (9, 3);
        let mut rng = Pcg64::seed(13);
        let mut l = MatrixF64::zeros(r, b);
        for t in 0..b {
            l[(t, t)] = 1.0 + rng.next_f64();
            for i in t + 1..r {
                l[(i, t)] = (rng.next_f64() - 0.5) * 0.8;
            }
        }
        // Lower region of A = (L·Lᵀ) restricted to i >= j, j < b.
        let mut a = MatrixF64::zeros(r, b);
        for j in 0..b {
            for i in j..r {
                let mut s = 0.0;
                for t in 0..=j {
                    s += l[(i, t)] * l[(j, t)];
                }
                a[(i, j)] = s;
            }
        }
        let (pre, pre_abs) = lower_panel_colsums(a.view());
        // The factored panel is L in the lower region; poison the strict
        // upper part to prove it is never read.
        let mut panel = l.clone();
        for j in 1..b {
            for i in 0..j {
                panel[(i, j)] = f64::NAN;
            }
        }
        assert!(verify_chol_panel(panel.view(), &pre, &pre_abs));
        let mut bad = panel.clone();
        bad[(4, 1)] *= 4.0;
        assert!(!verify_chol_panel(bad.view(), &pre, &pre_abs));
    }

    #[test]
    fn checksum_tails_match_view_computation() {
        let mut rng = Pcg64::seed(3);
        let a = MatrixF64::random(13, 7, &mut rng);
        let b = MatrixF64::random(7, 11, &mut rng);
        let (mr, nr) = (4, 6);
        let mut abuf = vec![0.0f64; packed_a_len_checked(13, 7, mr)];
        let mut bbuf = vec![0.0f64; packed_b_len_checked(7, 11, nr)];
        pack_a_checked(a.view(), &mut abuf, mr, -2.0);
        pack_b_checked(b.view(), &mut bbuf, nr);
        let a_base = packed_a_len(13, 7, mr);
        let b_base = packed_b_len(7, 11, nr);
        let tails = CheckSums::from_tails(&abuf[a_base..a_base + 14], &bbuf[b_base..b_base + 14], 7);
        let views = CheckSums::from_views(a.view(), -2.0, b.view());
        for p in 0..7 {
            assert!((tails.acs[p] - views.acs[p]).abs() < 1e-12);
            assert!((tails.aabs[p] - views.aabs[p]).abs() < 1e-12);
            assert!((tails.brs[p] - views.brs[p]).abs() < 1e-12);
            assert!((tails.babs[p] - views.babs[p]).abs() < 1e-12);
        }
    }
}
