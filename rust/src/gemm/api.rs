//! The co-design GEMM API: the paper's proposal made concrete.
//!
//! A [`GemmEngine`] owns an architecture description, the registry of
//! runnable micro-kernels and a workspace pool. Its [`ConfigMode`] selects
//! the paper's three compared policies:
//!
//! - [`ConfigMode::BlisStatic`] — baseline R1: a single stock micro-kernel
//!   and CCPs fixed per architecture, only clamped by the dimensions.
//! - [`ConfigMode::OriginalModel`] — Low-et-al. CCPs, shape-independent.
//! - [`ConfigMode::Refined`] — the contribution: per-call dynamic
//!   selection of micro-kernel + CCPs from the refined dimension-aware
//!   model (§3.3/§3.4).
//! - [`ConfigMode::Fixed`] — pin an explicit configuration (used by the
//!   experiment harness to reproduce a specific paper variant).

use crate::arch::Arch;
use crate::model::ccp::GemmConfig;
use crate::model::selector::{select_from, AnalyticScorer};
use crate::model::{blis_static, original_ccp, refined_ccp, GemmDims, MicroKernel};
use crate::util::matrix::{MatView, MatViewMut};

use super::blocked::{gemm_blocked, Workspace};
use super::microkernel::{for_shape, registry, MicroKernelImpl};
use super::parallel::{gemm_parallel, ThreadPlan};

/// Configuration policy for the engine.
#[derive(Clone, Debug)]
pub enum ConfigMode {
    /// BLIS-like baseline: static CCPs + single stock micro-kernel.
    BlisStatic,
    /// Original analytical model (shape-independent CCPs), stock kernel.
    OriginalModel,
    /// The paper's refined dimension-aware model with dynamic
    /// micro-kernel selection over the runnable family.
    Refined,
    /// Refined CCPs for one pinned micro-kernel shape.
    RefinedWithKernel(MicroKernel),
    /// Fully pinned configuration.
    Fixed(GemmConfig),
}

/// The engine: arch + kernels + workspaces + policy.
pub struct GemmEngine {
    pub arch: Arch,
    pub mode: ConfigMode,
    pub plan: ThreadPlan,
    kernels: Vec<MicroKernelImpl>,
    workspaces: Vec<Workspace>,
    /// Last configuration chosen (introspection for tests/harness).
    pub last_config: Option<GemmConfig>,
}

impl GemmEngine {
    /// Engine with every kernel runnable on this host.
    pub fn new(arch: Arch, mode: ConfigMode) -> Self {
        Self::with_kernels(arch, mode, registry())
    }

    /// Engine restricted to an explicit kernel set.
    pub fn with_kernels(arch: Arch, mode: ConfigMode, kernels: Vec<MicroKernelImpl>) -> Self {
        assert!(!kernels.is_empty(), "no micro-kernels available");
        Self {
            arch,
            mode,
            plan: ThreadPlan::sequential(),
            kernels,
            workspaces: vec![Workspace::new()],
            last_config: None,
        }
    }

    /// Set the threading plan (one workspace per thread is provisioned).
    pub fn with_plan(mut self, plan: ThreadPlan) -> Self {
        while self.workspaces.len() < plan.threads.max(1) {
            self.workspaces.push(Workspace::new());
        }
        self.plan = plan;
        self
    }

    /// The micro-kernel shapes eligible for *dynamic selection*: prefetch
    /// variants are explicit choices, and when SIMD implementations exist
    /// the scalar fallbacks are excluded — the analytical scorer ranks
    /// shapes by cache behaviour and register-file arithmetic, which only
    /// compares like-for-like implementations (a scalar 8x8 would rank
    /// well on paper and run an order of magnitude slower).
    pub fn family(&self) -> Vec<MicroKernel> {
        let any_simd = self.kernels.iter().any(|k| k.simd);
        let mut f: Vec<MicroKernel> = self
            .kernels
            .iter()
            .filter(|k| !k.prefetch && (!any_simd || k.simd))
            .map(|k| k.spec)
            .collect();
        f.sort();
        f.dedup();
        f
    }

    fn implementation_for(&self, spec: MicroKernel) -> MicroKernelImpl {
        self.kernels
            .iter()
            .find(|k| k.spec == spec && !k.prefetch)
            .copied()
            .or_else(|| for_shape(spec))
            .unwrap_or_else(|| panic!("no implementation for {spec}"))
    }

    /// Resolve the configuration this engine would use for `dims`.
    pub fn plan_config(&self, dims: GemmDims) -> GemmConfig {
        match &self.mode {
            ConfigMode::BlisStatic => {
                let cfg = blis_static(&self.arch.name)
                    .expect("no BLIS static preset for this architecture");
                GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) }
            }
            ConfigMode::OriginalModel => {
                let mk = blis_static(&self.arch.name).map(|c| c.mk).unwrap_or(MicroKernel::new(8, 6));
                GemmConfig { mk, ccp: original_ccp(&self.arch, mk).clamp_to(dims) }
            }
            ConfigMode::Refined => {
                select_from(&self.arch, dims, &AnalyticScorer, &self.family()).config
            }
            ConfigMode::RefinedWithKernel(mk) => {
                GemmConfig { mk: *mk, ccp: refined_ccp(&self.arch, *mk, dims).clamp_to(dims) }
            }
            ConfigMode::Fixed(cfg) => GemmConfig { mk: cfg.mk, ccp: cfg.ccp.clamp_to(dims) },
        }
    }

    /// `C = alpha * A * B + beta * C`.
    pub fn gemm(
        &mut self,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = self.plan_config(dims);
        let kernel = self.implementation_for(cfg.mk);
        self.last_config = Some(cfg);
        if self.plan.threads > 1 {
            gemm_parallel(&cfg, &kernel, alpha, a, b, beta, c, self.plan, &mut self.workspaces);
        } else {
            gemm_blocked(&cfg, &kernel, alpha, a, b, beta, c, &mut self.workspaces[0]);
        }
    }

    /// Run with an explicit configuration, bypassing the policy (used by
    /// the experiment harness).
    pub fn gemm_with_config(
        &mut self,
        cfg: &GemmConfig,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let kernel = self.implementation_for(cfg.mk);
        self.last_config = Some(*cfg);
        if self.plan.threads > 1 {
            gemm_parallel(&cfg.clone(), &kernel, alpha, a, b, beta, c, self.plan, &mut self.workspaces);
        } else {
            gemm_blocked(cfg, &kernel, alpha, a, b, beta, c, &mut self.workspaces[0]);
        }
    }

    /// Run with an explicit named kernel (including prefetch variants).
    pub fn gemm_with_kernel_name(
        &mut self,
        name: &str,
        ccp: crate::model::Ccp,
        alpha: f64,
        a: MatView<'_>,
        b: MatView<'_>,
        beta: f64,
        c: &mut MatViewMut<'_>,
    ) {
        let kernel = self
            .kernels
            .iter()
            .find(|k| k.name == name)
            .copied()
            .unwrap_or_else(|| panic!("kernel {name} not registered"));
        let dims = GemmDims::new(a.rows, b.cols, a.cols);
        let cfg = GemmConfig { mk: kernel.spec, ccp: ccp.clamp_to(dims) };
        self.last_config = Some(cfg);
        gemm_blocked(&cfg, &kernel, alpha, a, b, beta, c, &mut self.workspaces[0]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{carmel, epyc7282, host_xeon};
    use crate::gemm::gemm_reference;
    use crate::util::{MatrixF64, Pcg64};

    fn check_engine(mut eng: GemmEngine, m: usize, n: usize, k: usize) -> GemmConfig {
        let mut rng = Pcg64::seed(77);
        let a = MatrixF64::random(m, k, &mut rng);
        let b = MatrixF64::random(k, n, &mut rng);
        let mut c = MatrixF64::random(m, n, &mut rng);
        let mut expect = c.clone();
        gemm_reference(1.5, a.view(), b.view(), 0.5, &mut expect.view_mut());
        eng.gemm(1.5, a.view(), b.view(), 0.5, &mut c.view_mut());
        assert!(c.max_abs_diff(&expect) < 1e-12 * k as f64, "engine mode {:?}", eng.mode);
        eng.last_config.unwrap()
    }

    #[test]
    fn all_modes_correct() {
        for mode in [
            ConfigMode::BlisStatic,
            ConfigMode::OriginalModel,
            ConfigMode::Refined,
            ConfigMode::RefinedWithKernel(MicroKernel::new(12, 4)),
        ] {
            check_engine(GemmEngine::new(carmel(), mode), 70, 50, 30);
        }
    }

    #[test]
    fn refined_mode_adapts_ccp_to_k() {
        let eng = GemmEngine::new(epyc7282(), ConfigMode::Refined);
        let skinny = eng.plan_config(GemmDims::new(2000, 2000, 64));
        let fat = eng.plan_config(GemmDims::new(2000, 2000, 2000));
        assert!(skinny.ccp.mc > fat.ccp.mc, "refined mc must grow as k shrinks");
        assert_eq!(skinny.ccp.kc, 64);
    }

    #[test]
    fn blis_static_mode_pins_ccp() {
        let eng = GemmEngine::new(carmel(), ConfigMode::BlisStatic);
        let cfg = eng.plan_config(GemmDims::new(2000, 2000, 128));
        assert_eq!(cfg.ccp, crate::model::Ccp::new(120, 2000, 128));
        assert_eq!(cfg.mk, MicroKernel::new(6, 8));
    }

    #[test]
    fn parallel_engine_correct() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined)
            .with_plan(ThreadPlan { threads: 3, target: crate::gemm::ParallelLoop::G4 });
        check_engine(eng, 90, 70, 40);
    }

    #[test]
    fn engine_family_nonempty_and_deduped() {
        let eng = GemmEngine::new(host_xeon(), ConfigMode::Refined);
        let fam = eng.family();
        assert!(!fam.is_empty());
        let mut f2 = fam.clone();
        f2.dedup();
        assert_eq!(fam, f2);
    }
}
